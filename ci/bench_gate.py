#!/usr/bin/env python3
"""Bench regression gate (open since PR 5).

Compares the `current` run in BENCH_sim.json against the recorded
`baseline` series and the experiment reports against their paper
claims:

* wall-time medians: `current` must stay under REGRESSION_FACTOR x
  `baseline` per bench name (generous — CI runners are noisy; only
  real regressions trip it);
* `figures` scalars: the experiments are deterministic given
  (effort, seed), so a scalar drifting more than FIGURE_REL_TOL from
  the baseline value means the measured physics changed — that must
  be a deliberate, re-recorded change, not an accident;
* `paper_ref` scalars (from reports/<id>.json): the measured value
  must stay within PAPER_REL_TOL of the paper's stated number.

Until a `baseline` series exists the first two checks are skipped
(the seed containers had no Rust toolchain; CI records the first
baseline on main), so the gate arms itself automatically.

Usage: bench_gate.py BENCH_sim.json [reports_dir]
"""

import json
import sys

REGRESSION_FACTOR = 2.5  # current median may be up to 2.5x baseline
FIGURE_REL_TOL = 0.25    # figures scalars may drift 25% from baseline
PAPER_REL_TOL = 0.50     # measured vs paper claim, reproduction-grade


def fail(msgs):
    for m in msgs:
        print(f"GATE FAIL: {m}")
    sys.exit(1)


def run_by_label(doc, label):
    for run in doc.get("runs", []):
        if run.get("label") == label:
            return run
    return None


def gate_bench(doc):
    errors = []
    baseline = run_by_label(doc, "baseline")
    current = run_by_label(doc, "current")
    if baseline is None:
        print("no recorded baseline series yet; bench gate disarmed")
        return errors
    if current is None:
        print("no current series in this run; bench gate skipped")
        return errors

    base_medians = {r["name"]: r["median_ns"] for r in baseline.get("results", [])}
    for r in current.get("results", []):
        name, med = r["name"], r["median_ns"]
        base = base_medians.get(name)
        if base is None or base <= 0:
            continue  # new bench, or degenerate baseline: nothing to gate
        if med > REGRESSION_FACTOR * base:
            errors.append(
                f"bench '{name}': median {med:.0f} ns is "
                f"{med / base:.2f}x the baseline {base:.0f} ns "
                f"(limit {REGRESSION_FACTOR}x)"
            )

    base_figs = baseline.get("figures", {})
    for exp, scalars in current.get("figures", {}).items():
        for name, value in scalars.items():
            base = base_figs.get(exp, {}).get(name)
            if base is None or not isinstance(base, (int, float)):
                continue
            denom = max(abs(base), 1e-12)
            drift = abs(value - base) / denom
            if drift > FIGURE_REL_TOL:
                errors.append(
                    f"figure {exp}.{name}: {value:.6g} drifted "
                    f"{100 * drift:.1f}% from the baseline {base:.6g} "
                    f"(limit {100 * FIGURE_REL_TOL:.0f}%)"
                )
    return errors


def gate_paper_refs(reports_dir):
    import glob
    import os

    errors = []
    checked = 0
    for path in sorted(glob.glob(os.path.join(reports_dir, "*.json"))):
        with open(path) as f:
            doc = json.load(f)
        for section in doc.get("sections", []):
            ref = section.get("paper_ref")
            value = section.get("value")
            if not ref or not isinstance(value, (int, float)):
                continue
            expected = ref.get("expected")
            if not isinstance(expected, (int, float)) or expected == 0:
                continue
            checked += 1
            rel = abs(value - expected) / abs(expected)
            if rel > PAPER_REL_TOL:
                errors.append(
                    f"{os.path.basename(path)} '{section.get('name')}': "
                    f"measured {value:.6g} is {100 * rel:.1f}% from the "
                    f"paper's {expected:.6g} (limit {100 * PAPER_REL_TOL:.0f}%)"
                )
    print(f"paper_ref gate: {checked} claimed scalars checked")
    return errors


def main():
    if len(sys.argv) < 2:
        print(__doc__)
        sys.exit(2)
    with open(sys.argv[1]) as f:
        doc = json.load(f)
    errors = gate_bench(doc)
    if len(sys.argv) > 2:
        errors += gate_paper_refs(sys.argv[2])
    if errors:
        fail(errors)
    print("bench gate: OK")


if __name__ == "__main__":
    main()

"""Self-test for the bench regression gate (bench_gate.py).

The gate guards every CI run, so its own behaviour is pinned here
against synthetic BENCH_sim.json / reports fixtures:

* **disarmed** — with no recorded `baseline` series the wall-time and
  figure checks must pass silently (the gate arms itself only once CI
  records a baseline on main);
* **median drift** — a `current` median more than 2.5x its baseline
  must fail, and one just under the limit must not;
* **figure drift** — a deterministic experiment scalar drifting more
  than 25% from its baseline value must fail;
* **paper_ref deviation** — a report scalar further than 50% from the
  paper's stated number must fail, and missing/zero expectations are
  skipped rather than divided by.

Run with: python3 -m pytest -q ci/test_bench_gate.py
"""

import copy
import json
import unittest

import bench_gate


def series(label, results=(), figures=None):
    return {
        "label": label,
        "results": [
            {"name": n, "median_ns": m} for (n, m) in results
        ],
        "figures": figures or {},
    }


def doc(*runs):
    return {"runs": list(runs)}


BASELINE = series(
    "baseline",
    results=[("simcore/iteration", 1000.0), ("experiment/fig17", 5000.0)],
    figures={"fig17": {"wihetnoc_latency_reduction_pct": 40.0}},
)


class GateBench(unittest.TestCase):
    def test_disarmed_without_baseline(self):
        current = series("current", results=[("simcore/iteration", 9_999_999.0)])
        self.assertEqual(bench_gate.gate_bench(doc(current)), [])

    def test_skipped_without_current(self):
        self.assertEqual(bench_gate.gate_bench(doc(BASELINE)), [])

    def test_median_within_limit_passes(self):
        current = series(
            "current",
            results=[("simcore/iteration", 2.4 * 1000.0)],
            figures={"fig17": {"wihetnoc_latency_reduction_pct": 41.0}},
        )
        self.assertEqual(bench_gate.gate_bench(doc(BASELINE, current)), [])

    def test_median_drift_fails(self):
        current = series("current", results=[("simcore/iteration", 2.6 * 1000.0)])
        errors = bench_gate.gate_bench(doc(BASELINE, current))
        self.assertEqual(len(errors), 1)
        self.assertIn("simcore/iteration", errors[0])
        self.assertIn("2.60x", errors[0])

    def test_new_bench_without_baseline_entry_is_not_gated(self):
        current = series("current", results=[("fault_inject/compile", 123456.0)])
        self.assertEqual(bench_gate.gate_bench(doc(BASELINE, current)), [])

    def test_figure_scalar_drift_fails(self):
        current = series(
            "current",
            figures={"fig17": {"wihetnoc_latency_reduction_pct": 20.0}},
        )
        errors = bench_gate.gate_bench(doc(BASELINE, current))
        self.assertEqual(len(errors), 1)
        self.assertIn("fig17.wihetnoc_latency_reduction_pct", errors[0])

    def test_design_figs_search_scalars_are_gated_once_recorded(self):
        # the design-search convergence scalars ride the same figures
        # mechanism as every other experiment: stable values pass, a
        # drifted evals_to_99pct_hypervolume fails
        base = series(
            "baseline",
            figures={
                "design_figs": {
                    "evals_to_99pct_hypervolume": 2408.0,
                    "evals_after_front_stable_pct": 35.0,
                }
            },
        )
        steady = series(
            "current",
            figures={
                "design_figs": {
                    "evals_to_99pct_hypervolume": 2408.0,
                    "evals_after_front_stable_pct": 35.0,
                }
            },
        )
        self.assertEqual(bench_gate.gate_bench(doc(base, steady)), [])
        drifted = copy.deepcopy(steady)
        drifted["figures"]["design_figs"]["evals_to_99pct_hypervolume"] = 4000.0
        errors = bench_gate.gate_bench(doc(base, drifted))
        self.assertEqual(len(errors), 1)
        self.assertIn("design_figs.evals_to_99pct_hypervolume", errors[0])

    def test_serving_figs_knee_scalars_are_gated_once_recorded(self):
        # the serving tail-latency scalars ride the same figures
        # mechanism: a steady knee passes, a collapsed knee-throughput
        # ratio fails
        base = series(
            "baseline",
            figures={
                "serving_figs": {
                    "wihetnoc_knee_throughput_x": 1.8,
                    "wihetnoc_p99_at_0p7_load_reduction_x": 1.4,
                }
            },
        )
        steady = series(
            "current",
            figures={
                "serving_figs": {
                    "wihetnoc_knee_throughput_x": 1.8,
                    "wihetnoc_p99_at_0p7_load_reduction_x": 1.4,
                }
            },
        )
        self.assertEqual(bench_gate.gate_bench(doc(base, steady)), [])
        drifted = copy.deepcopy(steady)
        drifted["figures"]["serving_figs"]["wihetnoc_knee_throughput_x"] = 0.9
        errors = bench_gate.gate_bench(doc(base, drifted))
        self.assertEqual(len(errors), 1)
        self.assertIn("serving_figs.wihetnoc_knee_throughput_x", errors[0])

    def test_serving_figs_scalars_disarmed_while_trajectory_empty(self):
        # same empty-runs[] story as design_figs: a current-only series
        # carrying the serving knee scalars must not arm the gate
        current = series(
            "current",
            figures={"serving_figs": {"wihetnoc_knee_throughput_x": 1.8}},
        )
        self.assertEqual(bench_gate.gate_bench(doc(current)), [])

    def test_design_figs_scalars_disarmed_while_trajectory_empty(self):
        # BENCH_sim.json still ships with an empty runs[] (no toolchain
        # in the authoring containers): a current-only series carrying
        # the new search scalars must not arm the gate
        current = series(
            "current",
            figures={"design_figs": {"evals_to_99pct_hypervolume": 2408.0}},
        )
        self.assertEqual(bench_gate.gate_bench(doc(current)), [])
        self.assertEqual(bench_gate.gate_bench({"runs": []}), [])


class GatePaperRefs(unittest.TestCase):
    REPORT = {
        "sections": [
            {
                "name": "wihetnoc_latency_reduction_pct",
                "value": 38.0,
                "paper_ref": {"expected": 40.0},
            },
            # no paper claim: never gated
            {"name": "advantage_collapse_fault_pct", "value": 3.0},
            # zero expectation: skipped, not divided by
            {"name": "degenerate", "value": 1.0, "paper_ref": {"expected": 0}},
        ]
    }

    def write_reports(self, tmpdir, report):
        path = tmpdir / "fig17.json"
        path.write_text(json.dumps(report))
        return str(tmpdir)

    def test_within_tolerance_passes(self):
        import pathlib
        import tempfile

        with tempfile.TemporaryDirectory() as d:
            reports = self.write_reports(pathlib.Path(d), self.REPORT)
            self.assertEqual(bench_gate.gate_paper_refs(reports), [])

    def test_deviation_fails(self):
        import pathlib
        import tempfile

        bad = copy.deepcopy(self.REPORT)
        bad["sections"][0]["value"] = 10.0  # 75% off the paper's 40.0
        with tempfile.TemporaryDirectory() as d:
            reports = self.write_reports(pathlib.Path(d), bad)
            errors = bench_gate.gate_paper_refs(reports)
        self.assertEqual(len(errors), 1)
        self.assertIn("wihetnoc_latency_reduction_pct", errors[0])
        self.assertIn("75.0%", errors[0])


if __name__ == "__main__":
    unittest.main()

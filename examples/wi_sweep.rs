//! Standalone WI-count + channel-count sweep (the Fig 12/13 design-space
//! exploration) with CSV output for plotting.
//!
//! Run: `cargo run --release --example wi_sweep [--effort full]`

use wihetnoc::energy::network::message_edp;
use wihetnoc::energy::params::EnergyParams;
use wihetnoc::experiments::{Ctx, Effort};
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::traffic::trace::training_trace;

fn main() {
    let effort = if std::env::args().any(|a| a == "--effort=full" || a == "full") {
        Effort::Full
    } else {
        Effort::Quick
    };
    let mut ctx = Ctx::new(effort, 42);
    let energy = EnergyParams::default();
    println!("n_wi,channels,msg_edp,latency,wireless_util,fallback_rate");
    for channels in 1..=4usize {
        for n_wi in [4usize, 8, 12, 16, 24, 32, 40] {
            if n_wi % channels != 0 {
                continue;
            }
            let inst = ctx.wihet_variant(n_wi, channels);
            let sys = ctx.sys.clone();
            let tm = ctx.traffic("lenet");
            let cfg = ctx.trace_cfg();
            let (trace, _) = training_trace(&sys, &tm.phases, &cfg);
            let rep =
                NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
                    .run(&trace);
            println!(
                "{},{},{:.1},{:.2},{:.4},{:.4}",
                n_wi,
                channels,
                message_edp(&inst.topo, &rep, &energy),
                rep.latency.mean(),
                rep.wireless_utilization(),
                rep.air_fallbacks as f64 / rep.delivered_packets.max(1) as f64,
            );
        }
    }
}

//! Standalone WI-count + channel-count sweep (the Fig 12/13 design-space
//! exploration) with CSV output for plotting. Accepts an optional
//! platform string so the sweep runs on any chip:
//!
//! Run: `cargo run --release --example wi_sweep [PLATFORM] [--effort full]`
//!      e.g. `... --example wi_sweep 12x12:cpus=8,mcs=8`

use wihetnoc::experiments::{Ctx, Effort};
use wihetnoc::energy::network::message_edp;
use wihetnoc::energy::params::EnergyParams;
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::traffic::trace::training_trace;
use wihetnoc::{ModelId, Platform, Scenario, WihetError};

fn main() -> Result<(), WihetError> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let effort = if args.iter().any(|a| a == "--effort=full" || a == "full") {
        Effort::Full
    } else {
        Effort::Quick
    };
    let platform: Platform = args
        .iter()
        .find(|a| !a.starts_with("--") && *a != "full")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or_else(Platform::paper);
    let scenario = Scenario::new(platform, ModelId::LeNet)
        .with_effort(effort)
        .with_seed(42);
    let mut ctx = Ctx::for_scenario(&scenario)?;
    let energy = EnergyParams::default();
    let max_wi = ctx.sys.num_tiles();
    println!("n_wi,channels,msg_edp,latency,wireless_util,fallback_rate");
    for channels in 1..=4usize {
        for n_wi in [4usize, 8, 12, 16, 24, 32, 40] {
            if n_wi % channels != 0 || n_wi > max_wi {
                continue;
            }
            let inst = ctx.wihet_variant(n_wi, channels);
            let sys = ctx.sys.clone();
            let tm = ctx.traffic(ModelId::LeNet);
            let cfg = ctx.trace_cfg();
            let (trace, _) = training_trace(&sys, &tm.phases, &cfg);
            let rep =
                NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
                    .run(&trace);
            println!(
                "{},{},{:.1},{:.2},{:.4},{:.4}",
                n_wi,
                channels,
                message_edp(&inst.topo, &rep, &energy),
                rep.latency.mean(),
                rep.wireless_utilization(),
                rep.air_fallbacks as f64 / rep.delivered_packets.max(1) as f64,
            );
        }
    }
    Ok(())
}

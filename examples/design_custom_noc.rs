//! Using the design methodology on a *different* platform — the paper's
//! §5 claim that the flow "can be used for any composition of
//! CPUs/GPUs/MCs and system size". Here: a 16-tile edge-inference chip
//! (12 GPU, 2 CPU, 2 MC) running CDBNet, designed end to end and compared
//! against its mesh.
//!
//! Run: `cargo run --release --example design_custom_noc`

use wihetnoc::energy::network::message_edp;
use wihetnoc::energy::params::EnergyParams;
use wihetnoc::model::{cdbnet, SystemConfig};
use wihetnoc::noc::analysis::analyze;
use wihetnoc::noc::builder::{mesh_opt, wi_het_noc, DesignConfig};
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::noc::topology::Topology;
use wihetnoc::traffic::phases::model_phases;
use wihetnoc::traffic::trace::{training_trace, TraceConfig};

fn main() {
    let sys = SystemConfig::small_4x4();
    println!(
        "custom platform: {} tiles = {} GPU + {} CPU + {} MC",
        sys.num_tiles(),
        sys.gpus().len(),
        sys.cpus().len(),
        sys.mcs().len()
    );

    // workload: CDBNet at batch 16
    let tm = model_phases(&sys, &cdbnet(), 16);
    let fij = tm.fij(&sys);

    // scale the design knobs with the platform: fewer WIs and channels
    let mut cfg = DesignConfig::quick(7);
    cfg.k_max = 5;
    cfg.n_wi = 4;
    cfg.gpu_channels = 2;
    cfg.max_link_mm = Some(10.0); // 4x4 on the same 20 mm die -> 5 mm pitch
    let inst = wi_het_noc(&sys, &fij, &cfg);

    let mesh_topo = Topology::mesh(&sys);
    let (am, aw) = (analyze(&mesh_topo, &fij), analyze(&inst.topo, &fij));
    println!(
        "wireline objectives (U_mean / sigma): mesh {:.4}/{:.4} -> WiHetNoC {:.4}/{:.4}",
        am.u_mean, am.u_std, aw.u_mean, aw.u_std
    );
    println!(
        "WIs: {:?}",
        inst.air.wis.iter().map(|w| (w.router, w.channel)).collect::<Vec<_>>()
    );

    // head-to-head simulation
    let mesh = mesh_opt(&sys, true);
    let tcfg = TraceConfig { scale: 0.1, ..Default::default() };
    let energy = EnergyParams::default();
    for (name, inst) in [("mesh", &mesh), ("wihetnoc", &inst)] {
        let (trace, _) = training_trace(&sys, &tm.phases, &tcfg);
        let rep = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
            .run(&trace);
        println!(
            "{name:<9} latency {:>7.2} | cpu-mc {:>7.2} | msg EDP {:>9.0}",
            rep.latency.mean(),
            rep.cpu_mc_latency.mean(),
            message_edp(&inst.topo, &rep, &energy),
        );
    }
}

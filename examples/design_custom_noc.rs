//! Using the design methodology on a *different* platform — the paper's
//! §5 claim that the flow "can be used for any composition of
//! CPUs/GPUs/MCs and system size". With the typed API that is a one-line
//! scenario edit: parse a platform string, hand it to `NocDesigner`.
//! Here: a 16-tile edge-inference chip (12 GPU, 2 CPU, 2 MC) running
//! CDBNet, designed end to end and compared against its mesh — then the
//! same flow again on the paper's 8x8 for contrast. Each platform
//! closes by scaling the designed chip out to a 4-chip data-parallel
//! fabric (ring allreduce over alpha-beta inter-chip links), then
//! breaks the network on purpose — a dead wireline link plus jammed
//! wireless channels — to show the graceful-degradation machinery.
//!
//! Run: `cargo run --release --example design_custom_noc`

use wihetnoc::energy::network::message_edp;
use wihetnoc::energy::params::EnergyParams;
use wihetnoc::fabric::{run_fabric, Fabric};
use wihetnoc::noc::analysis::analyze;
use wihetnoc::noc::builder::{NocDesigner, NocKind};
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::noc::topology::Topology;
use wihetnoc::schedule::{run_schedule, run_schedule_faults};
use wihetnoc::traffic::phases::model_phases;
use wihetnoc::traffic::trace::{training_trace, TraceConfig};
use wihetnoc::workload::lower_id;
use wihetnoc::{MappingPolicy, ModelId, Platform, Scenario, SchedulePolicy, WihetError};

fn run_platform(platform: Platform, model: ModelId, batch: usize) -> Result<(), WihetError> {
    let scenario = Scenario::new(platform, model).with_seed(7).with_batch(batch);
    let sys = scenario.build_system()?;
    println!(
        "\nplatform {}: {} tiles = {} GPU + {} CPU + {} MC",
        scenario.platform,
        sys.num_tiles(),
        sys.gpus().len(),
        sys.cpus().len(),
        sys.mcs().len()
    );

    let tm = model_phases(&sys, &scenario.model.spec(), batch);
    let fij = tm.fij(&sys);

    // the designer scales k_max/n_wi/channels with the platform; nudge
    // k_max down for the tiny chip to show explicit knob control. The
    // traffic derived above is reused rather than re-derived.
    let mut designer = NocDesigner::new(sys.clone())
        .traffic(fij.clone())
        .seed(scenario.seed);
    if sys.num_tiles() <= 16 {
        designer = designer.k_max(5);
    }
    let mesh = designer.clone().kind(NocKind::MeshXyYx).build()?;
    let inst = designer.build()?;

    let mesh_topo = Topology::mesh(&sys);
    let (am, aw) = (analyze(&mesh_topo, &fij), analyze(&inst.topo, &fij));
    println!(
        "wireline objectives (U_mean / sigma): mesh {:.4}/{:.4} -> WiHetNoC {:.4}/{:.4}",
        am.u_mean, am.u_std, aw.u_mean, aw.u_std
    );
    println!(
        "WIs: {:?}",
        inst.air.wis.iter().map(|w| (w.router, w.channel)).collect::<Vec<_>>()
    );

    // head-to-head simulation
    let tcfg = TraceConfig { scale: 0.1, ..Default::default() };
    let energy = EnergyParams::default();
    for (name, inst) in [("mesh", &mesh), ("wihetnoc", &inst)] {
        let (trace, _) = training_trace(&sys, &tm.phases, &tcfg);
        let rep = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
            .run(&trace);
        println!(
            "{name:<9} latency {:>7.2} | cpu-mc {:>7.2} | msg EDP {:>9.0}",
            rep.latency.mean(),
            rep.cpu_mc_latency.mean(),
            message_edp(&inst.topo, &rep, &energy),
        );
    }

    // overlap microbatches on the same instances: a pipelined mapping
    // plus a GPipe schedule turns the iteration into concurrent NoC
    // phases (the schedule subsystem, end to end)
    let mapping = MappingPolicy::LayerPipelined { stages: 2 };
    let piped = lower_id(&scenario.model, &mapping, &sys, batch)?;
    let gpipe = SchedulePolicy::GPipe { microbatches: 4 };
    for (name, inst) in [("mesh", &mesh), ("wihetnoc", &inst)] {
        let serial = run_schedule(&sys, inst, &piped, &SchedulePolicy::Serial, &tcfg)?;
        let gp = run_schedule(&sys, inst, &piped, &gpipe, &tcfg)?;
        println!(
            "{name:<9} {gpipe} over {mapping}: makespan {} vs serial {} ({:.2}x) | bubble {:>5.1}% | peak link concurrency {}",
            gp.makespan,
            serial.makespan,
            serial.makespan as f64 / gp.makespan.max(1) as f64,
            100.0 * gp.bubble_fraction,
            gp.peak_link_concurrency,
        );
    }

    // scale the designed chip out: the same instances on a 4-chip
    // data-parallel fabric, gradients allreduced over 25 GB/s links —
    // the collective's on-chip traffic rides the gated timeline, the
    // inter-chip hops are charged from the alpha-beta model
    let fabric: Fabric = "4:topo=ring".parse()?;
    let grad = scenario.model.spec().total_weight_bytes();
    for (name, inst) in [("mesh", &mesh), ("wihetnoc", &inst)] {
        let fr = run_fabric(&sys, inst, &piped, &gpipe, &fabric, grad, &tcfg)?;
        println!(
            "{name:<9} fabric {fabric} ({}): {} B/chip on the wire in {} steps | iteration {} cyc (chip makespan {}) | comm overhead {:>5.1}%",
            fr.algorithm,
            fr.wire_bytes_per_chip,
            fr.steps,
            fr.iteration_cycles,
            fr.schedule.makespan,
            fr.comm_overhead_pct,
        );
    }
    // break the network on purpose: jam every wireless channel for the
    // first 50k cycles and kill one wireline link. The MAC retries with
    // exponential backoff then falls back to wireline; the routing
    // layer repairs around the dead link — the chip degrades instead
    // of failing, and the report says exactly how much it cost
    let plan: wihetnoc::FaultPlan =
        "wire:link=3;air:ch=0,burst=50000;air:ch=1,burst=50000".parse()?;
    for (name, inst) in [("mesh", &mesh), ("wihetnoc", &inst)] {
        let clean = run_schedule(&sys, inst, &piped, &gpipe, &tcfg)?;
        let hurt = run_schedule_faults(&sys, inst, &piped, &gpipe, &tcfg, &plan)?;
        let rs = hurt.resilience();
        println!(
            "{name:<9} under '{plan}': makespan {} vs clean {} | {} faults, {} rerouted, {} retries, {} fallback flits, {} undeliverable",
            hurt.makespan,
            clean.makespan,
            rs.faults_injected,
            rs.packets_rerouted,
            rs.retries,
            rs.fallback_flits,
            rs.undeliverable_after_repair,
        );
    }
    Ok(())
}

fn main() -> Result<(), WihetError> {
    // custom 16-tile edge chip, straight from a platform string
    run_platform("4x4:cpus=2,mcs=2".parse()?, ModelId::CdbNet, 16)?;
    // the paper's platform through the exact same code path
    run_platform("8x8".parse()?, ModelId::LeNet, 32)?;
    Ok(())
}

//! End-to-end driver (DESIGN.md §7): train LeNet for several hundred
//! steps THROUGH THE FULL STACK — Pallas kernels inside the JAX train-step,
//! AOT-lowered to HLO, executed from Rust via PJRT with zero Python on the
//! request path — while the NoC toolchain co-simulates the induced on-chip
//! traffic and reports the paper's Fig 19 metrics.
//!
//! Run: `make artifacts && cargo run --release --example train_lenet`
//! Env: STEPS (default 300), SEED (default 42).

use wihetnoc::coordinator::cosim::cosimulate;
use wihetnoc::coordinator::{TrainConfig, Trainer};
use wihetnoc::model::lenet;
use wihetnoc::noc::builder::{NocDesigner, NocKind};
use wihetnoc::runtime::Runtime;
use wihetnoc::traffic::phases::model_phases;
use wihetnoc::traffic::trace::TraceConfig;
use wihetnoc::Scenario;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let steps: usize = std::env::var("STEPS").ok().and_then(|s| s.parse().ok()).unwrap_or(300);
    let seed: u64 = std::env::var("SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(42);

    // ---- phase 1: real training through PJRT ----
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut rt = Runtime::new(&dir)?;
    let batch = rt.manifest.batch;
    println!("platform {} | lenet | batch {batch} | {steps} steps", rt.platform());
    let mut trainer = Trainer::new(&mut rt, lenet(), seed)?;
    let cfg = TrainConfig { steps, batch, seed, log_every: (steps / 15).max(1) };
    let log = trainer.train(&cfg)?;
    println!("\nloss curve:");
    for (step, loss) in &log.losses {
        let bar = "#".repeat((loss * 12.0).min(80.0) as usize);
        println!("  step {step:>5}  {loss:>8.4}  {bar}");
    }
    println!(
        "\nloss {:.4} -> {:.4} (tail mean {:.4}) | {:.1} ms/step PJRT",
        log.first_loss(),
        log.last_loss(),
        log.tail_mean(3),
        1e3 * log.execute_secs / steps as f64
    );
    assert!(
        log.tail_mean(3) < log.first_loss(),
        "training did not reduce the loss — see EXPERIMENTS.md"
    );

    // ---- phase 2: NoC co-simulation of this workload (Fig 19) ----
    println!("\nco-simulating the training iteration on mesh / HetNoC / WiHetNoC ...");
    let scenario = Scenario::paper().with_seed(seed).with_batch(batch);
    let sys = scenario.build_system()?;
    let spec = lenet();
    let designer = NocDesigner::for_scenario(&scenario)?; // derives the traffic once
    let mesh = designer.clone().kind(NocKind::MeshXyYx).build()?;
    let het = designer.clone().kind(NocKind::HetNoc).build()?;
    let wihet = designer.build()?;
    let tcfg = TraceConfig { scale: 0.1, ..Default::default() };
    let tm = model_phases(&sys, &spec, batch);
    let rep = cosimulate(&sys, &tm, &[&mesh, &het, &wihet], &tcfg)?;
    println!("\n{:<10} {:>8} {:>8}   (normalized to mesh; paper: WiHetNoC 0.87 / 0.75)", "noc", "exec", "EDP");
    for (i, name) in ["mesh", "hetnoc", "wihetnoc"].iter().enumerate() {
        println!(
            "{:<10} {:>8.3} {:>8.3}",
            name,
            rep.exec_vs_baseline(i),
            rep.edp_vs_baseline(i)
        );
    }
    Ok(())
}

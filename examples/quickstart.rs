//! Quickstart for the typed scenario API: describe the paper's 64-tile
//! platform as a `Scenario`, design a WiHetNoC and the optimized-mesh
//! baseline with `NocDesigner`, simulate one LeNet training iteration's
//! traffic on both, and print the comparison.
//!
//! Run: `cargo run --release --example quickstart`

use wihetnoc::energy::network::{message_edp, network_energy_pj};
use wihetnoc::energy::params::EnergyParams;
use wihetnoc::noc::builder::{NocDesigner, NocKind};
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::traffic::phases::model_phases;
use wihetnoc::traffic::trace::{training_trace, TraceConfig};
use wihetnoc::{Scenario, WihetError};

fn main() -> Result<(), WihetError> {
    // 1. the scenario: the paper's 8x8 chip (56 GPU + 4 CPU + 4 MC)
    //    training LeNet. Swap the platform for "4x4" or "12x12:cpus=8,
    //    mcs=8" and everything downstream follows.
    let scenario = Scenario::paper().with_seed(42);
    let sys = scenario.build_system()?;

    // 2. the workload: LeNet training traffic (per-layer fwd+bwd phases)
    let tm = model_phases(&sys, &scenario.model.spec(), scenario.batch);
    println!(
        "{} iteration on {}: {} phases, {:.1}% many-to-few traffic",
        scenario.model,
        scenario.platform,
        tm.phases.len(),
        100.0 * tm.many_to_few_fraction(&sys)
    );

    // 3. design both NoCs (AMOSA wireline + wireless overlay + ALASH for
    //    the WiHetNoC; XY+YX routing for the mesh baseline), reusing the
    //    traffic model already derived above
    let t0 = std::time::Instant::now();
    let designer = NocDesigner::new(sys.clone())
        .traffic(tm.fij(&sys))
        .seed(scenario.seed);
    let wihet = designer.clone().build()?;
    println!(
        "designed WiHetNoC in {:.1}s: k_max={}, {} WIs on {} channels, {} virtual layers",
        t0.elapsed().as_secs_f64(),
        wihet.topo.k_max(),
        wihet.air.wis.len(),
        wihet.air.num_channels,
        wihet.routes.num_layers,
    );
    let mesh = designer.kind(NocKind::MeshXyYx).build()?;

    // 4. simulate both NoCs on the same traffic
    let tcfg = TraceConfig { scale: 0.1, ..Default::default() };
    let energy = EnergyParams::default();
    println!("\n{:<10} {:>10} {:>10} {:>12} {:>12}", "noc", "latency", "cpu-mc", "pJ/packet", "msg EDP");
    for (name, inst) in [("mesh", &mesh), ("wihetnoc", &wihet)] {
        let (trace, _) = training_trace(&sys, &tm.phases, &tcfg);
        let rep = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
            .run(&trace);
        let e = network_energy_pj(&inst.topo, &rep, &energy);
        println!(
            "{:<10} {:>10.2} {:>10.2} {:>12.1} {:>12.0}",
            name,
            rep.latency.mean(),
            rep.cpu_mc_latency.mean(),
            e.total_pj() / rep.delivered_packets as f64,
            message_edp(&inst.topo, &rep, &energy),
        );
    }
    println!("\n(expect WiHetNoC to win both latency columns and message EDP)");
    Ok(())
}

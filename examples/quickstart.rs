//! Quickstart: design a WiHetNoC for the paper's 64-tile heterogeneous
//! system, simulate one LeNet training iteration's traffic on it and on
//! the optimized-mesh baseline, and print the comparison.
//!
//! Run: `cargo run --release --example quickstart`

use wihetnoc::energy::network::{message_edp, network_energy_pj};
use wihetnoc::energy::params::EnergyParams;
use wihetnoc::model::{lenet, SystemConfig};
use wihetnoc::noc::builder::{mesh_opt, wi_het_noc, DesignConfig};
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::traffic::phases::model_phases;
use wihetnoc::traffic::trace::{training_trace, TraceConfig};

fn main() {
    // 1. the platform: 56 GPUs + 4 CPUs + 4 MCs on an 8x8 grid
    let sys = SystemConfig::paper_8x8();

    // 2. the workload: LeNet training traffic (per-layer fwd+bwd phases)
    let tm = model_phases(&sys, &lenet(), 32);
    println!(
        "LeNet iteration: {} phases, {:.1}% many-to-few traffic",
        tm.phases.len(),
        100.0 * tm.many_to_few_fraction(&sys)
    );

    // 3. design the WiHetNoC (AMOSA wireline + wireless overlay + ALASH)
    let fij = tm.fij(&sys);
    let cfg = DesignConfig::quick(42); // DesignConfig::default() = paper effort
    let t0 = std::time::Instant::now();
    let wihet = wi_het_noc(&sys, &fij, &cfg);
    println!(
        "designed WiHetNoC in {:.1}s: k_max={}, {} WIs on {} channels, {} virtual layers",
        t0.elapsed().as_secs_f64(),
        wihet.topo.k_max(),
        wihet.air.wis.len(),
        wihet.air.num_channels,
        wihet.routes.num_layers,
    );

    // 4. simulate both NoCs on the same traffic
    let mesh = mesh_opt(&sys, true);
    let tcfg = TraceConfig { scale: 0.1, ..Default::default() };
    let energy = EnergyParams::default();
    println!("\n{:<10} {:>10} {:>10} {:>12} {:>12}", "noc", "latency", "cpu-mc", "pJ/packet", "msg EDP");
    for (name, inst) in [("mesh", &mesh), ("wihetnoc", &wihet)] {
        let (trace, _) = training_trace(&sys, &tm.phases, &tcfg);
        let rep = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
            .run(&trace);
        let e = network_energy_pj(&inst.topo, &rep, &energy);
        println!(
            "{:<10} {:>10.2} {:>10.2} {:>12.1} {:>12.0}",
            name,
            rep.latency.mean(),
            rep.cpu_mc_latency.mean(),
            e.total_pj() / rep.delivered_packets as f64,
            message_edp(&inst.topo, &rep, &energy),
        );
    }
    println!("\n(expect WiHetNoC to win both latency columns and message EDP)");
}

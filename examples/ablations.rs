//! Ablations over the WiHetNoC design choices (DESIGN.md §5 calls these
//! out): what does each ingredient buy?
//!
//!   A0  optimized mesh, XY            (baseline routing)
//!   A1  optimized mesh, XY+YX         (+ minimal-adaptive routing [29])
//!   A2  AMOSA wireline only (HetNoC)  (+ irregular topology)
//!   A3  WiHetNoC, no dedicated CPU ch (+ wireless, shared channels only)
//!   A4  WiHetNoC full                 (+ dedicated CPU-MC channel)
//!
//! A0-A2 come straight from `NocDesigner`; A3/A4 are assembled from the
//! ingredient-level builder functions because the shared-channel variant
//! is *not* a supported design point — that is the ablation.
//!
//! Run: `cargo run --release --example ablations`

use wihetnoc::energy::network::message_edp;
use wihetnoc::energy::params::EnergyParams;
use wihetnoc::noc::builder::{optimize_wireline, DesignConfig, NocDesigner, NocInstance, NocKind};
use wihetnoc::noc::routing::RouteSet;
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::optim::wiplace::build_wireless;
use wihetnoc::traffic::phases::model_phases;
use wihetnoc::traffic::trace::{training_trace, TraceConfig};
use wihetnoc::{Scenario, WihetError};

fn main() -> Result<(), WihetError> {
    let scenario = Scenario::paper().with_seed(42);
    let sys = scenario.build_system()?;
    let tm = model_phases(&sys, &scenario.model.spec(), scenario.batch);
    let fij = tm.fij(&sys);
    let cfg = DesignConfig::quick(scenario.seed);
    let energy = EnergyParams::default();
    let tcfg = TraceConfig { scale: 0.1, ..Default::default() };

    let designer = || NocDesigner::new(sys.clone()).traffic(fij.clone()).seed(scenario.seed);

    // shared wireline topology for A3/A4 (one AMOSA run, zero copies)
    let topo = std::sync::Arc::new(optimize_wireline(&sys, &fij, &cfg));
    let air = build_wireless(&topo, &fij, &sys.cpus(), &sys.mcs(), cfg.n_wi, cfg.gpu_channels);

    // A3: wireless but no dedicated-channel policy — every pair may use
    // any channel and nothing is force-enabled.
    let all_channels: Vec<usize> = (0..air.num_channels).collect();
    let a3_routes = RouteSet::alash(&topo, &air, Some(&fij), |_, _| all_channels.clone(), 5);
    let a3 = NocInstance {
        kind: NocKind::WiHetNoc,
        topo: topo.clone(),
        routes: a3_routes,
        air: air.clone(),
    };
    // A4: the full design (dedicated CPU-MC channel + forced air)
    let a4_routes = wihetnoc::noc::builder::alash_routes(&sys, &topo, &air, &fij);
    let a4 = NocInstance { kind: NocKind::WiHetNoc, topo, routes: a4_routes, air };

    let variants: Vec<(&str, NocInstance)> = vec![
        ("A0 mesh XY", designer().kind(NocKind::MeshXy).build()?),
        ("A1 mesh XY+YX", designer().kind(NocKind::MeshXyYx).build()?),
        ("A2 HetNoC (wireline)", designer().kind(NocKind::HetNoc).build()?),
        ("A3 wireless, shared ch", a3),
        ("A4 WiHetNoC full", a4),
    ];

    println!(
        "{:<24} {:>9} {:>9} {:>11} {:>9}",
        "variant", "latency", "cpu-mc", "msg EDP", "air %"
    );
    for (name, inst) in &variants {
        let (trace, _) = training_trace(&sys, &tm.phases, &tcfg);
        let rep = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
            .run(&trace);
        println!(
            "{:<24} {:>9.2} {:>9.2} {:>11.0} {:>8.1}%",
            name,
            rep.latency.mean(),
            rep.cpu_mc_latency.mean(),
            message_edp(&inst.topo, &rep, &energy),
            100.0 * rep.wireless_utilization(),
        );
    }
    println!("\n(each row adds one design ingredient; the CPU-MC column is the dedicated channel's contribution: A4 vs A3 under load)");
    Ok(())
}

"""L2 model tests: Table 1 geometry, forward shapes, training dynamics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.shapes import MODELS, cdbnet, check_table1, lenet


def test_table1_shapes():
    check_table1()


@pytest.mark.parametrize("name", list(MODELS))
def test_layer_chain_consistent(name):
    spec = MODELS[name]()
    cur = spec.input_shape
    for layer in spec.layers:
        assert layer.in_shape == cur, f"{layer.name} input mismatch"
        cur = layer.out_shape
    assert cur == (1, 1, spec.num_classes)


@pytest.mark.parametrize("name", list(MODELS))
def test_param_shapes_match_specs(name):
    spec = MODELS[name]()
    params = M.init_params(spec)
    structs = M.input_specs(spec, 4, True)
    assert len(structs) == len(params) + 2
    for p, s in zip(params, structs):
        assert p.shape == s.shape and p.dtype == s.dtype


def test_lenet_param_count():
    # C1: 5*5*1*16+16, C2: 5*5*16*16+16, C3: 5*5*16*128+128, F1: 128*10+10
    spec = lenet()
    total = sum(int(np.prod(p.shape)) for p in M.init_params(spec))
    expect = (25 * 16 + 16) + (25 * 16 * 16 + 16) + (25 * 16 * 128 + 128) + (128 * 10 + 10)
    assert total == expect
    assert total == sum(l.weight_count for l in spec.layers)


def test_cdbnet_weight_accounting():
    spec = cdbnet()
    total = sum(int(np.prod(p.shape)) for p in M.init_params(spec))
    assert total == sum(l.weight_count for l in spec.layers)


@pytest.mark.parametrize("name", list(MODELS))
def test_forward_shape_and_finite(name):
    spec = MODELS[name]()
    params = M.init_params(spec)
    x, _ = M.synthetic_batch(spec, 3)
    logits = M.forward(spec, params, x)
    assert logits.shape == (3, spec.num_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", list(MODELS))
def test_train_step_reduces_loss(name):
    spec = MODELS[name]()
    params = M.init_params(spec)
    x, y = M.synthetic_batch(spec, 8)
    step = jax.jit(M.make_train_step_fn(spec, lr=0.01))
    out = step(*params, x, y)
    first = float(out[-1])
    for _ in range(15):
        out = step(*out[:-1], x, y)
    last = float(out[-1])
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first, f"{name}: loss {first} -> {last}"


def test_train_step_updates_every_param():
    spec = lenet()
    params = M.init_params(spec)
    x, y = M.synthetic_batch(spec, 4)
    out = M.make_train_step_fn(spec, lr=0.1)(*params, x, y)
    for i, (old, new) in enumerate(zip(params, out[:-1])):
        assert old.shape == new.shape
        assert not np.allclose(np.asarray(old), np.asarray(new)), f"param {i} frozen"


def test_loss_matches_crossentropy_oracle():
    spec = lenet()
    params = M.init_params(spec)
    x, y = M.synthetic_batch(spec, 4)
    loss = M.loss_fn(spec, params, x, y)
    logits = M.forward(spec, params, x)
    p = jax.nn.softmax(logits)
    want = -np.mean(np.log(np.sum(np.asarray(p) * np.asarray(y), axis=1)))
    np.testing.assert_allclose(float(loss), want, rtol=1e-5)


def test_synthetic_batch_deterministic_and_learnable():
    spec = lenet()
    x1, y1 = M.synthetic_batch(spec, 16, seed=5)
    x2, y2 = M.synthetic_batch(spec, 16, seed=5)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    x3, _ = M.synthetic_batch(spec, 16, seed=6)
    assert not np.allclose(np.asarray(x1), np.asarray(x3))
    # one-hot labels
    assert np.all(np.sum(np.asarray(y1), axis=1) == 1.0)


def test_init_deterministic():
    spec = cdbnet()
    a = M.init_params(spec, seed=3)
    b = M.init_params(spec, seed=3)
    for p, q in zip(a, b):
        np.testing.assert_array_equal(np.asarray(p), np.asarray(q))

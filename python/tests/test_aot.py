"""AOT path tests: HLO-text lowering + manifest consistency.

Uses batch=4 throughout so lowering stays fast; the real artifacts are
produced by `make artifacts` at batch=32.
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile.shapes import MODELS, lenet


def test_to_hlo_text_entry_and_roundtrip_safety():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    s = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.lower_entry(fn, (s, s))
    assert "ENTRY" in text and "HloModule" in text
    assert "f32[2,2]" in text


def test_forward_entry_lowers_with_pallas():
    spec = lenet()
    specs = M.input_specs(spec, 4, False)
    text = aot.lower_entry(M.make_forward_fn(spec), specs)
    assert "ENTRY" in text
    # logits shape appears as the (tupled) root
    assert "f32[4,10]" in text


def test_build_artifacts_manifest(tmp_path):
    manifest = aot.build_artifacts(str(tmp_path), batch=4, models=["lenet"])
    names = {e["name"] for e in manifest["entries"]}
    assert names == {"lenet_train_step", "lenet_forward", "matmul_micro"}
    for e in manifest["entries"]:
        path = tmp_path / e["path"]
        assert path.exists() and path.stat().st_size > 0
        assert "ENTRY" in path.read_text()[:200000]
    ts = next(e for e in manifest["entries"] if e["kind"] == "train_step")
    # train_step: params + x + y inputs; params + loss outputs
    assert len(ts["inputs"]) == ts["num_params"] + 2
    assert ts["num_outputs"] == ts["num_params"] + 1
    # manifest JSON round-trips
    j = json.loads((tmp_path / "manifest.json").read_text())
    assert j["batch"] == 4


def test_manifest_layer_metadata_consistent(tmp_path):
    manifest = aot.build_artifacts(str(tmp_path), batch=4, models=["lenet"])
    model = manifest["models"][0]
    layers = model["layers"]
    # chaining and byte accounting
    for prev, cur in zip(layers, layers[1:]):
        assert prev["out_shape"] == cur["in_shape"]
    c1 = layers[0]
    assert c1["name"] == "C1"
    assert c1["macs"] == 4 * 29 * 29 * 16 * 25 * 1
    assert c1["weight_bytes"] == (25 * 16 + 16) * 4
    assert c1["in_bytes"] == 4 * 33 * 33 * 1 * 4


def test_fingerprint_stable():
    assert aot.source_fingerprint() == aot.source_fingerprint()
    assert len(aot.source_fingerprint()) == 64


@pytest.mark.parametrize("name", list(MODELS))
def test_input_specs_match_manifest_convention(name):
    spec = MODELS[name]()
    structs = M.input_specs(spec, 4, True)
    n_params = 2 * len(M.param_layers(spec))
    assert len(structs) == n_params + 2
    h, w, c = spec.input_shape
    assert structs[-2].shape == (4, h, w, c)
    assert structs[-1].shape == (4, spec.num_classes)

"""L1 kernel correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and tile sizes for the GEMM); gradients of the
custom-VJP ops are compared against JAX autodiff of the reference
implementations. This is the core correctness signal for the compute stack.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, maxpool, avgpool, conv2d, dense, im2col
from compile.kernels.matmul import mxu_utilization, vmem_bytes
from compile.kernels.ref import (
    ref_avgpool, ref_conv2d, ref_dense, ref_lrn, ref_matmul, ref_maxpool,
)

SETTINGS = dict(max_examples=15, deadline=None)


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- matmul

@settings(**SETTINGS)
@given(
    m=st.integers(1, 90),
    k=st.integers(1, 90),
    n=st.integers(1, 90),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    x, y = rand(seed, m, k), rand(seed + 1, k, n)
    np.testing.assert_allclose(matmul(x, y), ref_matmul(x, y), rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    bm=st.sampled_from([8, 16, 32, 128]),
    bn=st.sampled_from([8, 16, 32, 128]),
    bk=st.sampled_from([8, 16, 32, 128]),
)
def test_matmul_tile_sweep(bm, bn, bk):
    x, y = rand(7, 50, 70), rand(8, 70, 30)
    got = matmul(x, y, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref_matmul(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_identity():
    x = rand(3, 17, 17)
    np.testing.assert_allclose(matmul(x, jnp.eye(17)), x, rtol=1e-5, atol=1e-5)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul(rand(0, 3, 4), rand(1, 5, 6))
    with pytest.raises(ValueError):
        matmul(rand(0, 3), rand(1, 3, 2))


def test_matmul_zero_padding_exact():
    # Padding must contribute exactly zero, even with adversarial values.
    x = jnp.full((9, 13), 1e30, jnp.float32)
    y = jnp.full((13, 5), 1e-30, jnp.float32)
    np.testing.assert_allclose(matmul(x, y), ref_matmul(x, y), rtol=1e-5)


def test_mxu_utilization_bounds():
    assert 0.0 < mxu_utilization(1, 1, 1) <= 1.0
    assert mxu_utilization(128, 128, 128) == 1.0
    assert vmem_bytes(128, 128, 128) == 4 * 3 * 128 * 128


def test_pick_tiles_respects_vmem_budget():
    from compile.kernels.matmul import pick_tiles, vmem_bytes, VMEM_BUDGET_BYTES

    for m, k, n in [(26912, 25, 16), (7200, 800, 32), (50000, 2048, 2048), (8, 8, 8)]:
        bm, bn, bk = pick_tiles(m, k, n)
        assert vmem_bytes(bm, bn, bk) <= VMEM_BUDGET_BYTES, (m, k, n)
        assert bm % 8 == 0 and bn % 8 == 0 and bk % 8 == 0


def test_pick_tiles_minimizes_grid_for_small_problems():
    from compile.kernels.matmul import pick_tiles

    # LeNet C1 GEMM: everything fits in one or a few tiles
    bm, bn, bk = pick_tiles(26912, 25, 16)
    assert bk >= 32 and bn >= 16
    assert -(-26912 // bm) <= 8, f"grid too fine: bm={bm}"


# ---------------------------------------------------------------- pooling

@settings(**SETTINGS)
@given(
    ih=st.integers(4, 33),
    ksize=st.sampled_from([2, 3]),
    stride=st.sampled_from([1, 2, 3]),
    ceil_mode=st.booleans(),
    c=st.sampled_from([1, 3, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_maxpool_matches_ref(ih, ksize, stride, ceil_mode, c, seed):
    x = rand(seed, 2, ih, ih, c)
    got = maxpool(x, ksize, stride, ceil_mode)
    want = ref_maxpool(x, ksize, stride, ceil_mode=ceil_mode)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@settings(**SETTINGS)
@given(
    ih=st.integers(4, 33),
    ksize=st.sampled_from([2, 3, 7]),
    stride=st.sampled_from([1, 2, 7]),
    seed=st.integers(0, 2**31 - 1),
)
def test_avgpool_matches_ref(ih, ksize, stride, seed):
    if ksize > ih:
        return
    x = rand(seed, 2, ih, ih, 4)
    got = avgpool(x, ksize, stride, False)
    want = ref_avgpool(x, ksize, stride)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_pool_window_too_large():
    with pytest.raises(ValueError):
        maxpool(rand(0, 1, 3, 3, 1), 5, 1, False)


def test_maxpool_grad_matches_ref():
    x = rand(11, 2, 11, 11, 4)
    g = jax.grad(lambda x: jnp.sum(jnp.cos(maxpool(x, 2, 2, False))))(x)
    gr = jax.grad(lambda x: jnp.sum(jnp.cos(ref_maxpool(x, 2, 2))))(x)
    np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-5)


def test_maxpool_ceil_grad_matches_ref():
    x = rand(12, 1, 29, 29, 2)
    g = jax.grad(lambda x: jnp.sum(maxpool(x, 2, 2, True)))(x)
    gr = jax.grad(lambda x: jnp.sum(ref_maxpool(x, 2, 2, ceil_mode=True)))(x)
    np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-5)


def test_avgpool_grad_matches_ref():
    x = rand(13, 2, 15, 15, 3)
    g = jax.grad(lambda x: jnp.sum(jnp.sin(avgpool(x, 3, 2, False))))(x)
    gr = jax.grad(lambda x: jnp.sum(jnp.sin(ref_avgpool(x, 3, 2))))(x)
    np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-5)


def test_avgpool_overlapping_windows_grad():
    # stride < ksize: each input position feeds several windows.
    x = rand(14, 1, 9, 9, 2)
    g = jax.grad(lambda x: jnp.sum(avgpool(x, 3, 1, False)))(x)
    gr = jax.grad(lambda x: jnp.sum(ref_avgpool(x, 3, 1)))(x)
    np.testing.assert_allclose(g, gr, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------- conv2d

@settings(**SETTINGS)
@given(
    ih=st.integers(6, 20),
    ci=st.sampled_from([1, 3, 8]),
    co=st.sampled_from([4, 16]),
    padding=st.sampled_from(["VALID", "SAME"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_ref(ih, ci, co, padding, seed):
    x = rand(seed, 2, ih, ih, ci)
    w = rand(seed + 1, 5, 5, ci, co) * 0.2
    b = rand(seed + 2, co)
    got = conv2d(x, w, b, padding)
    want = ref_conv2d(x, w, b, padding=padding)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_im2col_shape_and_content():
    x = rand(20, 1, 4, 4, 2)
    p = im2col(x, 3, 3)
    assert p.shape == (1, 2, 2, 18)
    # first patch, first slice position == x[0, 0:2? ...]: verify corner value
    np.testing.assert_allclose(p[0, 0, 0, :2], x[0, 0, 0, :])
    np.testing.assert_allclose(p[0, 1, 1, -2:], x[0, 3, 3, :])


@pytest.mark.parametrize("padding", ["VALID", "SAME"])
def test_conv2d_grads_match_ref(padding):
    x = rand(21, 2, 10, 10, 3)
    w = rand(22, 5, 5, 3, 4) * 0.2
    b = rand(23, 4)
    f = lambda x, w, b: jnp.sum(jnp.sin(conv2d(x, w, b, padding)))
    fr = lambda x, w, b: jnp.sum(jnp.sin(ref_conv2d(x, w, b, padding=padding)))
    g = jax.grad(f, (0, 1, 2))(x, w, b)
    gr = jax.grad(fr, (0, 1, 2))(x, w, b)
    for a, r in zip(g, gr):
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)


def test_conv2d_1x1_kernel():
    x, w, b = rand(24, 1, 7, 7, 3), rand(25, 1, 1, 3, 5), rand(26, 5)
    np.testing.assert_allclose(
        conv2d(x, w, b, "VALID"), ref_conv2d(x, w, b, padding="VALID"),
        rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- dense

@settings(**SETTINGS)
@given(
    b=st.integers(1, 40),
    i=st.integers(1, 80),
    o=st.integers(1, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_matches_ref(b, i, o, seed):
    x, w, bias = rand(seed, b, i), rand(seed + 1, i, o), rand(seed + 2, o)
    np.testing.assert_allclose(dense(x, w, bias), ref_dense(x, w, bias),
                               rtol=1e-4, atol=1e-4)


def test_dense_grads_match_ref():
    x, w, b = rand(30, 6, 20), rand(31, 20, 10), rand(32, 10)
    f = lambda x, w, b: jnp.sum(jnp.tanh(dense(x, w, b)))
    fr = lambda x, w, b: jnp.sum(jnp.tanh(ref_dense(x, w, b)))
    g = jax.grad(f, (0, 1, 2))(x, w, b)
    gr = jax.grad(fr, (0, 1, 2))(x, w, b)
    for a, r in zip(g, gr):
        np.testing.assert_allclose(a, r, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- lrn oracle

def test_lrn_normalizes():
    x = rand(40, 1, 4, 4, 8)
    y = ref_lrn(x)
    assert y.shape == x.shape
    # LRN shrinks magnitudes (denominator >= 1 for k=1)
    assert float(jnp.max(jnp.abs(y))) <= float(jnp.max(jnp.abs(x))) + 1e-6

"""Layer-shape algebra for LeNet and CDBNet (paper Table 1).

Single source of truth for layer geometry on the Python side; the Rust side
(`rust/src/model/cnn.rs`) re-derives the same table independently and an
integration test cross-checks the two via the AOT manifest.

Table 1 entries are layer *outputs*:
  LeNet  (MNIST,  33x33x1):  C1 5x5x16 -> 29x29x16; P1 max 2/2 ceil -> 15;
         C2 5x5x16 -> 11x11x16; P2 max 2/2 -> 5; C3 5x5x128 -> 1x1x128;
         F1 128 -> 10.
  CDBNet (CIFAR10, 31x31x3): C1 5x5x32 SAME -> 31x31x32; P1 max 3/2 -> 15;
         LRN; C2 5x5x32 SAME -> 15x15x32; P2 avg 3/2 -> 7;
         C3 5x5x64 SAME -> 7x7x64; P3 avg 7/7 -> 1x1x64; F1 64 -> 10.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

BYTES_PER_ELEM = 4  # f32


@dataclass
class Layer:
    """One CNN layer: geometry plus derived traffic/compute quantities."""

    name: str                      # e.g. "C1", "P1", "F1", "LRN"
    kind: str                      # conv | maxpool | avgpool | dense | lrn
    in_shape: Tuple[int, int, int]  # (H, W, C), per-sample
    out_shape: Tuple[int, int, int]
    kernel: int = 0                # square kernel / window / pool size
    stride: int = 1
    padding: str = "VALID"         # conv only
    ceil_mode: bool = False        # pool only

    @property
    def weight_count(self) -> int:
        if self.kind == "conv":
            kh = kw = self.kernel
            return kh * kw * self.in_shape[2] * self.out_shape[2] + self.out_shape[2]
        if self.kind == "dense":
            fan_in = self.in_shape[0] * self.in_shape[1] * self.in_shape[2]
            return fan_in * self.out_shape[2] + self.out_shape[2]
        return 0

    def macs(self, batch: int) -> int:
        """Multiply-accumulates for one forward pass of `batch` samples."""
        oh, ow, oc = self.out_shape
        ih, iw, ic = self.in_shape
        if self.kind == "conv":
            return batch * oh * ow * oc * self.kernel * self.kernel * ic
        if self.kind == "dense":
            return batch * (ih * iw * ic) * oc
        if self.kind in ("maxpool", "avgpool"):
            return batch * oh * ow * oc * self.kernel * self.kernel
        if self.kind == "lrn":
            return batch * ih * iw * ic * 5
        return 0

    def in_bytes(self, batch: int) -> int:
        h, w, c = self.in_shape
        return batch * h * w * c * BYTES_PER_ELEM

    def out_bytes(self, batch: int) -> int:
        h, w, c = self.out_shape
        return batch * h * w * c * BYTES_PER_ELEM

    def weight_bytes(self) -> int:
        return self.weight_count * BYTES_PER_ELEM

    def to_dict(self, batch: int) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "in_shape": list(self.in_shape),
            "out_shape": list(self.out_shape),
            "kernel": self.kernel,
            "stride": self.stride,
            "weight_bytes": self.weight_bytes(),
            "in_bytes": self.in_bytes(batch),
            "out_bytes": self.out_bytes(batch),
            "macs": self.macs(batch),
        }


def _conv_out(ih: int, iw: int, k: int, padding: str) -> Tuple[int, int]:
    if padding == "SAME":
        return ih, iw
    return ih - k + 1, iw - k + 1


def _pool_out(ih: int, iw: int, k: int, s: int, ceil_mode: bool) -> Tuple[int, int]:
    if ceil_mode:
        return -(-(ih - k) // s) + 1, -(-(iw - k) // s) + 1
    return (ih - k) // s + 1, (iw - k) // s + 1


@dataclass
class ModelSpec:
    name: str
    input_shape: Tuple[int, int, int]
    num_classes: int
    layers: List[Layer] = field(default_factory=list)

    def _cur(self) -> Tuple[int, int, int]:
        return self.layers[-1].out_shape if self.layers else self.input_shape

    def conv(self, name: str, k: int, co: int, padding: str = "VALID") -> "ModelSpec":
        ih, iw, ci = self._cur()
        oh, ow = _conv_out(ih, iw, k, padding)
        assert oh > 0 and ow > 0, f"{name}: conv {k}x{k} does not fit {ih}x{iw}"
        self.layers.append(Layer(name, "conv", (ih, iw, ci), (oh, ow, co),
                                 kernel=k, padding=padding))
        return self

    def pool(self, name: str, kind: str, k: int, s: int, ceil_mode: bool = False) -> "ModelSpec":
        ih, iw, c = self._cur()
        oh, ow = _pool_out(ih, iw, k, s, ceil_mode)
        assert oh > 0 and ow > 0, f"{name}: pool {k}/{s} does not fit {ih}x{iw}"
        self.layers.append(Layer(name, kind, (ih, iw, c), (oh, ow, c),
                                 kernel=k, stride=s, ceil_mode=ceil_mode))
        return self

    def lrn(self, name: str = "LRN") -> "ModelSpec":
        s = self._cur()
        self.layers.append(Layer(name, "lrn", s, s, kernel=5))
        return self

    def dense(self, name: str) -> "ModelSpec":
        ih, iw, c = self._cur()
        self.layers.append(Layer(name, "dense", (ih, iw, c), (1, 1, self.num_classes)))
        return self

    def to_dict(self, batch: int) -> dict:
        return {
            "name": self.name,
            "input_shape": list(self.input_shape),
            "num_classes": self.num_classes,
            "batch": batch,
            "layers": [l.to_dict(batch) for l in self.layers],
        }


def lenet() -> ModelSpec:
    m = ModelSpec("lenet", (33, 33, 1), 10)
    m.conv("C1", 5, 16)
    m.pool("P1", "maxpool", 2, 2, ceil_mode=True)
    m.conv("C2", 5, 16)
    m.pool("P2", "maxpool", 2, 2)
    m.conv("C3", 5, 128)
    m.dense("F1")
    return m


def cdbnet() -> ModelSpec:
    m = ModelSpec("cdbnet", (31, 31, 3), 10)
    m.conv("C1", 5, 32, padding="SAME")
    m.pool("P1", "maxpool", 3, 2)
    m.lrn()
    m.conv("C2", 5, 32, padding="SAME")
    m.pool("P2", "avgpool", 3, 2)
    m.conv("C3", 5, 64, padding="SAME")
    m.pool("P3", "avgpool", 7, 7)
    m.dense("F1")
    return m


MODELS = {"lenet": lenet, "cdbnet": cdbnet}


def check_table1() -> None:
    """Assert the derived shapes match paper Table 1 (outputs reading)."""
    ln = lenet()
    by = {l.name: l.out_shape for l in ln.layers}
    assert by["C1"] == (29, 29, 16), by
    assert by["C2"] == (11, 11, 16), by
    assert by["C3"] == (1, 1, 128), by
    cd = cdbnet()
    by = {l.name: l.out_shape for l in cd.layers}
    assert by["C1"] == (31, 31, 32), by
    assert by["C2"] == (15, 15, 32), by
    assert by["C3"] == (7, 7, 64), by


if __name__ == "__main__":
    check_table1()
    print("Table 1 shape check OK")

"""AOT lowering: JAX -> HLO *text* artifacts + manifest for the Rust runtime.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format: the
image's xla_extension 0.5.1 rejects jax>=0.5 protos whose instruction ids
exceed INT_MAX, while the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Outputs in ``artifacts/``:
  <model>_train_step.hlo.txt   flat (*params, x, y) -> (*params', loss)
  <model>_forward.hlo.txt      flat (*params, x)    -> (logits,)
  matmul_micro.hlo.txt         small GEMM used by runtime smoke tests
  manifest.json                entry-point signatures + per-layer metadata
                               consumed by rust/src/runtime/manifest.rs and
                               cross-checked against rust/src/model/cnn.rs

Run: ``python -m compile.aot --out-dir ../artifacts`` (idempotent; the
Makefile skips it when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
from typing import List

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .shapes import MODELS, check_table1

DEFAULT_BATCH = 32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_dicts(specs) -> List[dict]:
    return [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs]


def lower_entry(fn, specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def build_artifacts(out_dir: str, batch: int, models: List[str]) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    check_table1()
    manifest = {"version": 1, "batch": batch, "entries": [], "models": []}

    for name in models:
        spec = MODELS[name]()
        manifest["models"].append(spec.to_dict(batch))

        for kind, fn, with_labels, extra_out in (
            ("train_step", M.make_train_step_fn(spec), True, 1),
            ("forward", M.make_forward_fn(spec), False, 1),
        ):
            specs = M.input_specs(spec, batch, with_labels)
            text = lower_entry(fn, specs)
            fname = f"{name}_{kind}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            n_params = 2 * len(M.param_layers(spec))
            manifest["entries"].append({
                "name": f"{name}_{kind}",
                "model": name,
                "kind": kind,
                "path": fname,
                "inputs": _spec_dicts(specs),
                "num_params": n_params,
                # train_step returns (*params, loss); forward returns (logits,)
                "num_outputs": (n_params + 1) if kind == "train_step" else 1,
                "lr": M.DEFAULT_LR if kind == "train_step" else None,
            })
            print(f"lowered {fname}: {len(text)} chars")

    # Micro GEMM artifact for runtime smoke tests / benches.
    def micro(x, y):
        return (jnp.matmul(x, y) + 1.0,)

    s = jax.ShapeDtypeStruct((8, 8), jnp.float32)
    text = lower_entry(micro, (s, s))
    with open(os.path.join(out_dir, "matmul_micro.hlo.txt"), "w") as f:
        f.write(text)
    manifest["entries"].append({
        "name": "matmul_micro", "model": None, "kind": "micro",
        "path": "matmul_micro.hlo.txt",
        "inputs": _spec_dicts([s, s]), "num_params": 0, "num_outputs": 1,
        "lr": None,
    })

    manifest_path = os.path.join(out_dir, "manifest.json")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {manifest_path} ({len(manifest['entries'])} entries)")
    return manifest


def source_fingerprint() -> str:
    """Hash of the compile package — lets `make artifacts` skip clean runs."""
    root = os.path.dirname(__file__)
    h = hashlib.sha256()
    for dirpath, _, files in sorted(os.walk(root)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(dirpath, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument("--models", nargs="*", default=list(MODELS))
    args = ap.parse_args()
    build_artifacts(args.out_dir, args.batch, args.models)
    with open(os.path.join(args.out_dir, ".fingerprint"), "w") as f:
        f.write(source_fingerprint())


if __name__ == "__main__":
    main()

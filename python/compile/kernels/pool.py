"""L1 Pallas pooling kernels (max and average), NHWC.

Pooling is memory-bound; the kernel's job on TPU is to stream HBM->VMEM
once and reduce in-register. The grid walks the batch axis; each grid step
holds one image's full feature map in VMEM (LeNet/CDBNet maps are at most
31*31*32*4 B = 123 KiB — comfortably resident) and produces the pooled map
by ``kh*kw`` static strided slices, which XLA/Mosaic fuse into a single
window reduction.

Ceil-mode (LeNet's 29 -> 15 maxpool) is handled by the caller padding with
the reduction identity (-inf for max, 0 for avg); average pooling divides by
the full window size (count_include_pad=True), matching ref.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


POOL_VMEM_BUDGET = 4 * 1024 * 1024


def _pool_kernel(x_ref, o_ref, *, kh, kw, sh, sw, op):
    x = x_ref[...]  # (bb, ih, iw, c)
    _, oh, ow, _ = o_ref.shape
    acc = None
    for i in range(kh):
        for j in range(kw):
            sl = jax.lax.slice(
                x,
                (0, i, j, 0),
                (x.shape[0], i + (oh - 1) * sh + 1, j + (ow - 1) * sw + 1, x.shape[3]),
                (1, sh, sw, 1),
            )
            if acc is None:
                acc = sl
            elif op == "max":
                acc = jnp.maximum(acc, sl)
            else:
                acc = acc + sl
    if op == "avg":
        acc = acc / float(kh * kw)
    o_ref[...] = acc


def _pool(x, kh, kw, sh, sw, op, ceil_mode, interpret):
    if x.ndim != 4:
        raise ValueError(f"pool expects NHWC rank-4 input, got {x.shape}")
    b, ih, iw, c = x.shape

    def out_dim(i, k, s):
        if ceil_mode:
            return -(-(i - k) // s) + 1
        return (i - k) // s + 1

    oh, ow = out_dim(ih, kh, sh), out_dim(iw, kw, sw)
    if oh < 1 or ow < 1:
        raise ValueError(f"pool window ({kh},{kw}) larger than input {x.shape}")
    # ceil mode: pad right/bottom with the reduction identity.
    need_h = (oh - 1) * sh + kh
    need_w = (ow - 1) * sw + kw
    if need_h > ih or need_w > iw:
        pad_val = -jnp.inf if op == "max" else 0.0
        x = jnp.pad(
            x,
            ((0, 0), (0, need_h - ih), (0, need_w - iw), (0, 0)),
            constant_values=pad_val,
        )

    # Batch-block: as many images per grid step as fit the VMEM budget —
    # coarse grids amortize the HBM->VMEM streams on TPU and the per-step
    # interpreter overhead on CPU (§Perf).
    per_image = x.shape[1] * x.shape[2] * c * 4
    bb = max(1, min(b, POOL_VMEM_BUDGET // max(per_image, 1)))
    if b % bb != 0:
        # pad batch to a multiple of the block (sliced back below)
        pad_val = -jnp.inf if op == "max" else 0.0
        pad_b = -(-b // bb) * bb - b
        x = jnp.pad(x, ((0, pad_b), (0, 0), (0, 0), (0, 0)), constant_values=pad_val)
    out = pl.pallas_call(
        functools.partial(_pool_kernel, kh=kh, kw=kw, sh=sh, sw=sw, op=op),
        grid=(x.shape[0] // bb,),
        in_specs=[pl.BlockSpec((bb, x.shape[1], x.shape[2], c), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((bb, oh, ow, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], oh, ow, c), x.dtype),
        interpret=interpret,
    )(x)
    return out[:b]


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def maxpool(x, ksize, stride, ceil_mode=False):
    """Max pooling, NHWC, Pallas forward. ``ksize``/``stride`` are ints.

    Backward routes the cotangent to the max position(s); ties split evenly
    (ties have measure zero for float inputs).
    """
    return _pool(x, ksize, ksize, stride, stride, "max", ceil_mode, True)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def avgpool(x, ksize, stride, ceil_mode=False):
    """Average pooling (count_include_pad), NHWC, Pallas forward."""
    return _pool(x, ksize, ksize, stride, stride, "avg", ceil_mode, True)


def _padded_geometry(shape, ksize, stride, ceil_mode):
    b, ih, iw, c = shape

    def out_dim(i):
        return (-(-(i - ksize) // stride) + 1) if ceil_mode else ((i - ksize) // stride + 1)

    oh, ow = out_dim(ih), out_dim(iw)
    return oh, ow, (oh - 1) * stride + ksize, (ow - 1) * stride + ksize


def _maxpool_fwd(x, ksize, stride, ceil_mode):
    out = _pool(x, ksize, ksize, stride, stride, "max", ceil_mode, True)
    return out, (x, out)


def _maxpool_bwd(ksize, stride, ceil_mode, res, dy):
    x, out = res
    b, ih, iw, c = x.shape
    oh, ow, need_h, need_w = _padded_geometry(x.shape, ksize, stride, ceil_mode)
    xp = x
    if need_h > ih or need_w > iw:
        xp = jnp.pad(x, ((0, 0), (0, need_h - ih), (0, need_w - iw), (0, 0)),
                     constant_values=-jnp.inf)
    # Count ties per window, then split dy evenly among them.
    masks, cnt = [], 0
    for i in range(ksize):
        for j in range(ksize):
            sl = jax.lax.slice(xp, (0, i, j, 0),
                               (b, i + (oh - 1) * stride + 1, j + (ow - 1) * stride + 1, c),
                               (1, stride, stride, 1))
            m = (sl == out).astype(dy.dtype)
            masks.append(m)
            cnt = cnt + m
    share = dy / jnp.maximum(cnt, 1.0)
    dxp = jnp.zeros_like(xp)
    idx = 0
    for i in range(ksize):
        for j in range(ksize):
            dxp = dxp.at[:, i:i + (oh - 1) * stride + 1:stride,
                         j:j + (ow - 1) * stride + 1:stride, :].add(masks[idx] * share)
            idx += 1
    return (dxp[:, :ih, :iw, :],)


def _avgpool_fwd(x, ksize, stride, ceil_mode):
    out = _pool(x, ksize, ksize, stride, stride, "avg", ceil_mode, True)
    return out, (x.shape,)


def _avgpool_bwd(ksize, stride, ceil_mode, res, dy):
    (xshape,) = res
    b, ih, iw, c = xshape
    oh, ow, need_h, need_w = _padded_geometry(xshape, ksize, stride, ceil_mode)
    share = dy / float(ksize * ksize)
    dxp = jnp.zeros((b, max(need_h, ih), max(need_w, iw), c), dy.dtype)
    for i in range(ksize):
        for j in range(ksize):
            dxp = dxp.at[:, i:i + (oh - 1) * stride + 1:stride,
                         j:j + (ow - 1) * stride + 1:stride, :].add(share)
    return (dxp[:, :ih, :iw, :],)


maxpool.defvjp(_maxpool_fwd, _maxpool_bwd)
avgpool.defvjp(_avgpool_fwd, _avgpool_bwd)

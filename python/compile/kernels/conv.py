"""conv2d = im2col + Pallas tiled matmul, with a custom VJP whose backward
pass is *also* GEMM-shaped and runs on the same Pallas kernel.

Hardware adaptation (DESIGN.md §3): the paper's workload runs convolutions
on CUDA GPUs (thread-per-output-pixel). The MXU formulation is im2col +
systolic matmul: patches are gathered once (a layout transform XLA fuses
into the producing op on TPU) and the arithmetic intensity lives entirely in
the (B*OH*OW, KH*KW*CI) x (KH*KW*CI, CO) GEMM that `kernels.matmul` tiles
for VMEM.

Backward (stride-1 convs only — all LeNet/CDBNet convs are stride 1):
  dW = P^T  @ dYm          (GEMM, Pallas)
  dB = sum(dYm, axis=0)
  dP = dYm  @ Wm^T         (GEMM, Pallas)
  dX = col2im(dP)          (overlap-add of KH*KW static slices)
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .matmul import matmul


def _pad_same(x, kh, kw):
    ph, pw = kh // 2, kw // 2
    return jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0))), ph, pw


def im2col(x: jax.Array, kh: int, kw: int) -> jax.Array:
    """(B,H,W,C) -> (B, OH, OW, KH*KW*C) patch tensor, stride 1, VALID.

    KH*KW static slices concatenated on the channel axis; on TPU this is the
    HBM->VMEM gather that the BlockSpec schedule of the GEMM consumes.
    """
    b, h, w, c = x.shape
    oh, ow = h - kh + 1, w - kw + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(jax.lax.slice(x, (0, i, j, 0), (b, i + oh, j + ow, c)))
    return jnp.concatenate(cols, axis=-1)


def _col2im(dp: jax.Array, h: int, w: int, kh: int, kw: int) -> jax.Array:
    """Adjoint of `im2col`: overlap-add patches back to (B,H,W,C)."""
    b, oh, ow, kc = dp.shape
    c = kc // (kh * kw)
    dx = jnp.zeros((b, h, w, c), dp.dtype)
    idx = 0
    for i in range(kh):
        for j in range(kw):
            piece = jax.lax.slice(dp, (0, 0, 0, idx * c), (b, oh, ow, (idx + 1) * c))
            dx = jax.lax.dynamic_update_slice(
                dx,
                jax.lax.dynamic_slice(dx, (0, i, j, 0), (b, oh, ow, c)) + piece,
                (0, i, j, 0),
            )
            idx += 1
    return dx


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def conv2d(x: jax.Array, w: jax.Array, b: jax.Array, padding: str = "VALID"):
    """Stride-1 2D convolution. x: NHWC, w: HWIO, b: (O,).

    padding: "VALID" or "SAME".
    """
    return _conv2d_fwd(x, w, b, padding)[0]


def _conv2d_fwd(x, w, b, padding):
    kh, kw, ci, co = w.shape
    xb, ph, pw = (x, 0, 0) if padding == "VALID" else _pad_same(x, kh, kw)
    bsz, h, wdt, _ = xb.shape
    oh, ow = h - kh + 1, wdt - kw + 1
    patches = im2col(xb, kh, kw)  # (B, OH, OW, KH*KW*CI)
    pm = patches.reshape(bsz * oh * ow, kh * kw * ci)
    wm = w.reshape(kh * kw * ci, co)
    ym = matmul(pm, wm) + b
    y = ym.reshape(bsz, oh, ow, co)
    return y, (pm, wm, xb.shape, (kh, kw, ci, co), (ph, pw), x.shape)


def _conv2d_bwd(padding, res, dy):
    pm, wm, xb_shape, (kh, kw, ci, co), (ph, pw), x_shape = res
    bsz, h, wdt, _ = xb_shape
    oh, ow = h - kh + 1, wdt - kw + 1
    dym = dy.reshape(bsz * oh * ow, co)
    dwm = matmul(pm.T, dym)                      # (KH*KW*CI, CO)
    db = jnp.sum(dym, axis=0)
    dpm = matmul(dym, wm.T)                      # (M, KH*KW*CI)
    dp = dpm.reshape(bsz, oh, ow, kh * kw * ci)
    dxb = _col2im(dp, h, wdt, kh, kw)
    if ph or pw:
        dxb = dxb[:, ph:ph + x_shape[1], pw:pw + x_shape[2], :]
    return dxb, dwm.reshape(kh, kw, ci, co), db


conv2d.defvjp(_conv2d_fwd, _conv2d_bwd)

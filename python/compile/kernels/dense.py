"""Fully-connected layer on the Pallas tiled matmul, custom VJP.

dX = dY @ W^T and dW = X^T @ dY are the same GEMM kernel, so the FC
backward also exercises the MXU path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import matmul


@jax.custom_vjp
def dense(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, I), w: (I, O), b: (O,) -> (B, O)."""
    return _dense_fwd(x, w, b)[0]


def _dense_fwd(x, w, b):
    return matmul(x, w) + b, (x, w)


def _dense_bwd(res, dy):
    x, w = res
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)

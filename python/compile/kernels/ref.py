"""Pure-jnp correctness oracles for every Pallas kernel.

These are the ground truth for pytest: each kernel in this package must
match its `ref_*` counterpart to float32 tolerance on randomized shape
sweeps (see python/tests/test_kernels.py). They use only stock jax.numpy /
lax ops — no Pallas — so any disagreement implicates the kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def _ref_pool(x, ksize, stride, ceil_mode, init, op, is_avg):
    b, ih, iw, c = x.shape

    def out_dim(i):
        return (-(-(i - ksize) // stride) + 1) if ceil_mode else ((i - ksize) // stride + 1)

    oh, ow = out_dim(ih), out_dim(iw)
    need_h = (oh - 1) * stride + ksize
    need_w = (ow - 1) * stride + ksize
    if need_h > ih or need_w > iw:
        x = jnp.pad(x, ((0, 0), (0, need_h - ih), (0, need_w - iw), (0, 0)),
                    constant_values=init)
    out = jax.lax.reduce_window(
        x, init, op,
        window_dimensions=(1, ksize, ksize, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )
    if is_avg:
        out = out / float(ksize * ksize)
    return out


def ref_maxpool(x, ksize, stride, *, ceil_mode=False):
    return _ref_pool(x, ksize, stride, ceil_mode, -jnp.inf, jax.lax.max, False)


def ref_avgpool(x, ksize, stride, *, ceil_mode=False):
    return _ref_pool(x, ksize, stride, ceil_mode, 0.0, jax.lax.add, True)


def ref_conv2d(x, w, b=None, *, padding="VALID"):
    """NHWC x, HWIO w, stride-1 convolution (the only stride the models use)."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(1, 1), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        out = out + b
    return out


def ref_dense(x, w, b=None):
    out = ref_matmul(x, w)
    if b is not None:
        out = out + b
    return out


def ref_lrn(x, *, size=5, alpha=1e-4, beta=0.75, k=1.0):
    """Local response normalization across channels (AlexNet/ccv style)."""
    sq = x * x
    half = size // 2
    c = x.shape[-1]
    padded = jnp.pad(sq, ((0, 0), (0, 0), (0, 0), (half, half)))
    acc = jnp.zeros_like(x)
    for i in range(size):
        acc = acc + jax.lax.slice_in_dim(padded, i, i + c, axis=3)
    return x / jnp.power(k + (alpha / size) * acc, beta)

"""L1 Pallas tiled-matmul kernel — the compute hot-spot of CNN training.

Every convolution and fully-connected layer in LeNet/CDBNet is lowered to
GEMM (im2col for convs), and this kernel is the GEMM. It is written the way
an MXU-targeting kernel is written:

  * the grid walks (M/bm, N/bn, K/bk) tiles; K is the innermost (fastest)
    grid axis so a given output tile stays resident while the reduction runs;
  * each step multiplies a (bm, bk) LHS panel by a (bk, bn) RHS panel — on a
    real TPU these land in VMEM via the BlockSpec index maps below and feed
    the 128x128 systolic array; on this CPU build the same schedule runs
    under ``interpret=True`` (Mosaic custom-calls cannot execute on the CPU
    PJRT plugin, see DESIGN.md §3);
  * accumulation is fp32 into the output tile. On TPU the accumulator would
    be a VMEM scratch buffer and the inputs bf16; interpret mode has no
    scratch memory spaces, so we accumulate directly into ``o_ref`` (bit-for
    -bit identical for f32 inputs).

VMEM budget (DESIGN.md §8): bytes = 4*(bm*bk + bk*bn + bm*bn). The default
128x128x128 tiles use 192 KiB — far under the ~16 MiB/core budget, chosen so
the M dimension (batch*out_h*out_w, often small here) does not over-pad.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile-size policy: MXU-aligned (multiples of 8/128), sized adaptively so
# the three resident panels fit the VMEM budget while the grid stays as
# coarse as possible — on TPU this maximizes MXU occupancy per DMA, and
# under interpret=True it minimizes the per-grid-step interpreter overhead
# (measured ~0.5 ms/step on this CPU — see EXPERIMENTS.md §Perf).
VMEM_BUDGET_BYTES = 8 * 1024 * 1024
MAX_BM = 8192
MAX_BN = 1024
MAX_BK = 1024


def pick_tiles(m: int, k: int, n: int,
               budget: int = VMEM_BUDGET_BYTES) -> tuple[int, int, int]:
    """Choose (bm, bn, bk) minimizing grid steps under the VMEM budget."""
    bm = min(_round_up(m, 8), MAX_BM)
    bn = min(_round_up(n, 8), MAX_BN)
    bk = min(_round_up(k, 8), MAX_BK)

    def vmem(bm, bn, bk):
        return 4 * (bm * bk + bk * bn + bm * bn)

    # shrink the M tile first (replays the reduction least), then K, then N
    while vmem(bm, bn, bk) > budget and bm > 128:
        bm = max(128, bm // 2)
    while vmem(bm, bn, bk) > budget and bk > 128:
        bk = max(128, bk // 2)
    while vmem(bm, bn, bk) > budget and bn > 128:
        bn = max(128, bn // 2)
    return bm, bn, bk


def _matmul_kernel(x_ref, y_ref, o_ref, *, k_steps: int):
    """One (bm, bn) output tile; grid axis 2 runs the K reduction."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _pad_to(arr: jax.Array, rows: int, cols: int) -> jax.Array:
    pr, pc = rows - arr.shape[0], cols - arr.shape[1]
    if pr == 0 and pc == 0:
        return arr
    return jnp.pad(arr, ((0, pr), (0, pc)))


def matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """``x @ y`` via the Pallas tiled kernel.

    Tile sizes default to `pick_tiles` (VMEM-budgeted, grid-minimal);
    explicit ``bm``/``bn``/``bk`` override for tests and sweeps. Shapes are
    padded up to tile multiples (zero padding is exact for matmul) and the
    result is sliced back. f32 in / f32 out.
    """
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul expects rank-2 operands, got {x.shape} @ {y.shape}")
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")

    abm, abn, abk = pick_tiles(m, k, n)
    bm, bn, bk = bm or abm, bn or abn, bk or abk
    # Shrink tiles to the (padded-up-to-8) problem size so tiny layers do not
    # pay for full tiles of zeros.
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 8))
    bk = min(bk, _round_up(k, 8))

    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    xp = _pad_to(x, mp, kp)
    yp = _pad_to(y, kp, np_)
    grid = (mp // bm, np_ // bn, kp // bk)

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp.astype(jnp.float32), yp.astype(jnp.float32))
    return out[:m, :n]


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def vmem_bytes(bm: int, bn: int, bk: int) -> int:
    """Estimated VMEM working set of one grid step (f32)."""
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(m: int, n: int, k: int, bm: int | None = None,
                    bn: int | None = None, bk: int | None = None) -> float:
    """Fraction of MXU issue slots doing useful work for an (m,k)x(k,n) GEMM.

    The padded grid issues round_up(m,bm)*round_up(n,bn)*round_up(k,bk) MACs
    worth of systolic-array work; m*n*k of them are useful.
    """
    abm, abn, abk = pick_tiles(m, k, n)
    bm, bn, bk = bm or abm, bn or abn, bk or abk
    issued = _round_up(m, min(bm, _round_up(m, 8))) * \
        _round_up(n, min(bn, _round_up(n, 8))) * \
        _round_up(k, min(bk, _round_up(k, 8)))
    return (m * n * k) / issued

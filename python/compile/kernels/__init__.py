"""L1 Pallas kernels for the CNN training hot paths.

Exports: tiled matmul (MXU-style), max/avg pooling, im2col conv2d, dense,
plus the pure-jnp reference oracles in :mod:`ref`.
All kernels run ``interpret=True`` on the CPU PJRT plugin (DESIGN.md §3).
"""

from .matmul import matmul, vmem_bytes, mxu_utilization  # noqa: F401
from .pool import maxpool, avgpool  # noqa: F401
from .conv import conv2d, im2col  # noqa: F401
from .dense import dense  # noqa: F401

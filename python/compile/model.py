"""L2: LeNet / CDBNet forward + backward in JAX, built on the L1 Pallas
kernels, with a flat-parameter calling convention for the Rust runtime.

Everything here is build-time only: `aot.py` lowers `train_step` /
`forward` once to HLO text; the Rust coordinator executes the artifacts via
PJRT with Python out of the loop.

Calling convention (mirrored by `rust/src/runtime/manifest.rs`):
  forward(w0, b0, w1, b1, ..., x)            -> (logits,)
  train_step(w0, b0, ..., x, y_onehot)       -> (w0', b0', ..., loss)
Parameters appear in layer order; only conv/dense layers carry (w, b).
"""

from __future__ import annotations

from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import conv2d, dense, maxpool, avgpool
from .kernels.ref import ref_lrn
from .shapes import Layer, ModelSpec, MODELS, lenet, cdbnet  # noqa: F401

DEFAULT_LR = 0.01


def param_layers(spec: ModelSpec) -> List[Layer]:
    """Layers that carry (w, b) parameter pairs, in flat-list order."""
    return [l for l in spec.layers if l.kind in ("conv", "dense")]


def init_params(spec: ModelSpec, seed: int = 0) -> List[jax.Array]:
    """He-initialized flat [w0, b0, w1, b1, ...] list."""
    key = jax.random.PRNGKey(seed)
    params: List[jax.Array] = []
    for layer in param_layers(spec):
        key, sub = jax.random.split(key)
        if layer.kind == "conv":
            k, ci, co = layer.kernel, layer.in_shape[2], layer.out_shape[2]
            fan_in = k * k * ci
            w = jax.random.normal(sub, (k, k, ci, co), jnp.float32)
            w = w * jnp.sqrt(2.0 / fan_in)
            b = jnp.zeros((co,), jnp.float32)
        else:
            fan_in = layer.in_shape[0] * layer.in_shape[1] * layer.in_shape[2]
            co = layer.out_shape[2]
            w = jax.random.normal(sub, (fan_in, co), jnp.float32)
            w = w * jnp.sqrt(2.0 / fan_in)
            b = jnp.zeros((co,), jnp.float32)
        params.extend([w, b])
    return params


def forward(spec: ModelSpec, params: Sequence[jax.Array], x: jax.Array) -> jax.Array:
    """Forward pass -> logits (B, num_classes). x is NHWC."""
    it = iter(range(0, len(params), 2))
    h = x
    for layer in spec.layers:
        if layer.kind == "conv":
            i = next(it)
            h = conv2d(h, params[i], params[i + 1], layer.padding)
            h = jax.nn.relu(h)
        elif layer.kind == "maxpool":
            h = maxpool(h, layer.kernel, layer.stride, layer.ceil_mode)
        elif layer.kind == "avgpool":
            h = avgpool(h, layer.kernel, layer.stride, layer.ceil_mode)
        elif layer.kind == "lrn":
            h = ref_lrn(h)
        elif layer.kind == "dense":
            i = next(it)
            h = h.reshape(h.shape[0], -1)
            h = dense(h, params[i], params[i + 1])
        else:  # pragma: no cover - spec builder cannot produce others
            raise ValueError(f"unknown layer kind {layer.kind}")
    return h


def loss_fn(spec: ModelSpec, params: Sequence[jax.Array], x: jax.Array,
            y_onehot: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy."""
    logits = forward(spec, params, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y_onehot * logp, axis=-1))


def make_forward_fn(spec: ModelSpec):
    """Flat-arg forward for AOT lowering: f(*params, x) -> (logits,)."""

    def fn(*args):
        *params, x = args
        return (forward(spec, params, x),)

    return fn


def make_train_step_fn(spec: ModelSpec, lr: float = DEFAULT_LR):
    """Flat-arg SGD train step: f(*params, x, y) -> (*new_params, loss)."""

    def fn(*args):
        *params, x, y = args
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(spec, p, x, y))(list(params))
        new_params = [p - lr * g for p, g in zip(params, grads)]
        return tuple(new_params) + (loss,)

    return fn


def input_specs(spec: ModelSpec, batch: int, with_labels: bool) -> List[jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs matching the flat calling convention."""
    structs = []
    for layer in param_layers(spec):
        if layer.kind == "conv":
            k, ci, co = layer.kernel, layer.in_shape[2], layer.out_shape[2]
            structs.append(jax.ShapeDtypeStruct((k, k, ci, co), jnp.float32))
        else:
            fan_in = layer.in_shape[0] * layer.in_shape[1] * layer.in_shape[2]
            co = layer.out_shape[2]
            structs.append(jax.ShapeDtypeStruct((fan_in, co), jnp.float32))
        structs.append(jax.ShapeDtypeStruct((structs[-1].shape[-1],), jnp.float32))
    h, w, c = spec.input_shape
    structs.append(jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32))
    if with_labels:
        structs.append(jax.ShapeDtypeStruct((batch, spec.num_classes), jnp.float32))
    return structs


def synthetic_batch(spec: ModelSpec, batch: int, seed: int = 0
                    ) -> Tuple[jax.Array, jax.Array]:
    """Class-conditional synthetic data: learnable, deterministic, shaped
    like the real dataset (DESIGN.md §2 substitution)."""
    key = jax.random.PRNGKey(seed)
    km, kl, kx = jax.random.split(key, 3)
    h, w, c = spec.input_shape
    means = jax.random.normal(km, (spec.num_classes, h, w, c), jnp.float32)
    labels = jax.random.randint(kl, (batch,), 0, spec.num_classes)
    noise = 0.5 * jax.random.normal(kx, (batch, h, w, c), jnp.float32)
    x = means[labels] + noise
    y = jax.nn.one_hot(labels, spec.num_classes, dtype=jnp.float32)
    return x, y

//! Serving-subsystem guarantees (ISSUE 10):
//!
//! * **Serving-off identity** — `ServingSpec::none()` is the default
//!   everywhere: scenarios, scenario keys, and contexts built without a
//!   spec are indistinguishable from pre-serving ones, and the legacy
//!   `ScenarioKey::with_faults` constructor delegates to the new tip.
//! * **Determinism** — open-loop serving reports are byte-identical
//!   across repeat runs and across 1/2/8 `par_map` workers.
//! * **Conservation** — `offered == delivered + queued + in_flight`
//!   at every point of a rate ladder, per tenant and in aggregate.
//! * **Knee consistency** — `detect_knee` flags the *first* step whose
//!   p99 crosses the threshold, and nothing below it.
//! * **Typed errors** — malformed `--serve` grammars are
//!   `WihetError::InvalidArg`s carrying the serve grammar, never panics.

use wihetnoc::model::SystemConfig;
use wihetnoc::noc::builder::{mesh_opt, NocInstance};
use wihetnoc::noc::sim::SimReport;
use wihetnoc::serving::{detect_knee, run_serving, ArrivalProcess, ServingReport, TenantMix};
use wihetnoc::traffic::trace::TraceConfig;
use wihetnoc::util::exec::par_map_threads;
use wihetnoc::workload::MappingPolicy;
use wihetnoc::{
    Effort, Fabric, FaultPlan, ModelId, Scenario, ScenarioKey, SchedulePolicy, ServingSpec,
    WihetError,
};

/// Everything a `SimReport` aggregates, as one comparable value.
fn sim_fingerprint(r: &SimReport) -> (u64, u64, u64, String, Vec<u64>) {
    (
        r.delivered_packets,
        r.delivered_flits,
        r.cycles,
        format!("{:.9}/{:.9}/{:.9}", r.latency.sum, r.latency.max, r.cpu_mc_latency.sum),
        r.link_flits.clone(),
    )
}

/// A serving report down to its per-tenant tails, as one comparable
/// value.
#[allow(clippy::type_complexity)]
fn serving_fingerprint(
    r: &ServingReport,
) -> ((u64, u64, u64, String, Vec<u64>), u64, u64, u64, Vec<(u64, u64, u64, u64)>) {
    (
        sim_fingerprint(&r.sim),
        r.makespan,
        r.delivered,
        r.batches,
        r.tenants
            .iter()
            .map(|t| (t.delivered, t.e2e.p99(), t.queue.p99(), t.net.p99()))
            .collect(),
    )
}

fn setup() -> (SystemConfig, NocInstance, TraceConfig) {
    let sys = SystemConfig::paper_8x8();
    let inst = mesh_opt(&sys, true);
    let cfg = TraceConfig { scale: 0.02, ..Default::default() };
    (sys, inst, cfg)
}

#[test]
fn serving_off_is_the_default_everywhere() {
    // a scenario built without a spec carries the none spec ...
    let sc = Scenario::new("8x8".parse().unwrap(), ModelId::LeNet);
    assert!(sc.serving.is_none());
    assert_eq!(sc.serving, ServingSpec::none());
    assert_eq!(sc.serving.to_string(), "none");
    // ... the legacy key constructor delegates to the serving-aware tip ...
    let sys = SystemConfig::paper_8x8();
    let legacy = ScenarioKey::with_faults(
        ModelId::LeNet,
        &sys,
        MappingPolicy::default(),
        SchedulePolicy::Serial,
        Fabric::single(),
        FaultPlan::none(),
    );
    let tip = ScenarioKey::with_serving(
        ModelId::LeNet,
        &sys,
        MappingPolicy::default(),
        SchedulePolicy::Serial,
        Fabric::single(),
        FaultPlan::none(),
        ServingSpec::none(),
    );
    assert_eq!(legacy, tip, "with_faults must delegate to with_serving(none)");
    // ... and a context for a serving-off scenario validates untouched
    let ctx = wihetnoc::experiments::Ctx::for_scenario(&sc).unwrap();
    assert!(ctx.serving().is_none());
    // a serving scenario rejects multi-chip fabrics and overlap schedules
    let serve: ServingSpec = "poisson:rate=0.5;n=8".parse().unwrap();
    let bad = sc.clone().with_serving(serve.clone()).with_fabric("4:topo=ring".parse().unwrap());
    let e = wihetnoc::experiments::Ctx::for_scenario(&bad).unwrap_err();
    assert!(e.to_string().contains("single chip"), "{e}");
    let bad = sc
        .clone()
        .with_serving(serve)
        .with_schedule(SchedulePolicy::GPipe { microbatches: 4 })
        .with_effort(Effort::Quick);
    let e = wihetnoc::experiments::Ctx::for_scenario(&bad).unwrap_err();
    assert!(e.to_string().contains("schedule=serial"), "{e}");
}

#[test]
fn serving_simulation_is_thread_count_invariant() {
    let (sys, inst, cfg) = setup();
    let mix = TenantMix::new(vec![ModelId::LeNet, ModelId::CdbNet]);
    // one job per offered rate, seeds derived from the job index
    let jobs: Vec<u64> = vec![50, 200, 800];
    let run_all = |threads: usize| {
        par_map_threads(threads, &jobs, |i, &rate_pmc| {
            let spec = ServingSpec {
                arrival: Some(ArrivalProcess::Poisson { rate_pmc, seed: 0x5E1 + i as u64 }),
                batch: 4,
                timeout: 256,
                requests: 12,
            };
            let cfg = TraceConfig { seed: 0xCAFE + i as u64, ..cfg.clone() };
            serving_fingerprint(&run_serving(&sys, &inst, &mix, &spec, &cfg).unwrap())
        })
    };
    let serial = run_all(1);
    assert_eq!(run_all(1), serial, "repeat runs must match");
    for threads in [2, 8] {
        assert_eq!(run_all(threads), serial, "thread count {threads} diverged");
    }
}

#[test]
fn requests_are_conserved_across_the_rate_ladder() {
    let (sys, inst, cfg) = setup();
    let mix = TenantMix::new(vec![ModelId::LeNet, ModelId::CdbNet]);
    for rate_pmc in [20, 100, 500, 2000] {
        for (batch, timeout) in [(1u32, 1u64), (4, 256), (8, 64)] {
            let spec = ServingSpec {
                arrival: Some(ArrivalProcess::Poisson { rate_pmc, seed: 9 }),
                batch,
                timeout,
                requests: 10,
            };
            let r = run_serving(&sys, &inst, &mix, &spec, &cfg).unwrap();
            let tag = format!("rate={rate_pmc} batch={batch} timeout={timeout}");
            assert_eq!(r.offered, 20, "{tag}");
            assert_eq!(
                r.offered,
                r.delivered + r.queued + r.in_flight,
                "{tag}: conservation"
            );
            for t in &r.tenants {
                assert_eq!(t.offered, t.delivered + t.queued + t.in_flight, "{tag} {}", t.name);
                assert_eq!(t.e2e.count(), t.delivered, "{tag} {}", t.name);
                assert!(t.queue.max() <= timeout, "{tag} {}: queue wait bound", t.name);
            }
            assert!(r.batches <= r.dispatched.max(1), "{tag}: batches never exceed requests");
        }
    }
}

#[test]
fn knee_detection_flags_the_first_crossing_of_a_real_sweep() {
    let (sys, inst, cfg) = setup();
    let mix = TenantMix::single(ModelId::LeNet);
    // a x4 rate ladder: p99 must not *detect* a knee before the first
    // actual crossing, and the flagged step must really cross
    let mut p99s = Vec::new();
    for rate_pmc in [10, 40, 160, 640, 2560] {
        let spec = ServingSpec {
            arrival: Some(ArrivalProcess::Poisson { rate_pmc, seed: 11 }),
            batch: 4,
            timeout: 256,
            requests: 16,
        };
        let r = run_serving(&sys, &inst, &mix, &spec, &cfg).unwrap();
        let t = &r.tenants[0];
        assert!(t.delivered > 0, "rate {rate_pmc} delivered nothing");
        p99s.push(t.e2e.p99());
    }
    for k in [1.5f64, 2.0, 4.0] {
        match detect_knee(&p99s, k) {
            Some(i) => {
                assert!(i >= 1 && i < p99s.len());
                let floor = k * p99s[0].max(1) as f64;
                assert!(p99s[i] as f64 > floor, "flagged step {i} below {k}x: {p99s:?}");
                for (j, &p) in p99s.iter().enumerate().take(i).skip(1) {
                    assert!(p as f64 <= floor, "step {j} crossed before the knee: {p99s:?}");
                }
            }
            None => {
                let floor = k * p99s[0].max(1) as f64;
                assert!(
                    p99s.iter().skip(1).all(|&p| p as f64 <= floor),
                    "a crossing exists but no knee was detected: {p99s:?}"
                );
            }
        }
    }
}

#[test]
fn malformed_serve_grammars_are_typed_errors_carrying_the_grammar() {
    for bad in [
        "gaussian:rate=1",              // unknown arrival head
        "poisson",                      // missing kv payload
        "poisson:rate=0",               // zero rate
        "poisson:rate=1e9",             // beyond one request per cycle
        "poisson:rate=1,burst=2",       // unknown key
        "poisson:rate=1;burst:rate=1,on=2,off=2", // two arrival clauses
        "batch=4;timeout=9",            // load knobs without an arrival
        "poisson:rate=1;batch=0",       // empty batch
        "poisson:rate=1;n=0",           // no requests
        "poisson:rate=1;what=3",        // unknown load key
        "burst:rate=1,on=0,off=4",      // degenerate burst window
        "trace:rate=1",                 // trace needs file=
    ] {
        let e = bad.parse::<ServingSpec>().unwrap_err();
        assert!(matches!(e, WihetError::InvalidArg(_)), "{bad}: {e:?}");
        let msg = e.to_string();
        assert!(msg.contains("serve grammar"), "{bad}: grammar missing in {msg}");
    }
    // the run boundary rejects a none spec with the same typed error
    let (sys, inst, cfg) = setup();
    let mix = TenantMix::single(ModelId::LeNet);
    let e = run_serving(&sys, &inst, &mix, &ServingSpec::none(), &cfg).unwrap_err();
    assert!(matches!(e, WihetError::InvalidArg(_)), "{e:?}");
    assert!(e.to_string().contains("serve grammar"), "{e}");
}

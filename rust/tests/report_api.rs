//! Report-layer integration tests (ISSUE 5):
//!
//! * every registered experiment emits a JSON document that parses and
//!   round-trips at `Effort::Quick`;
//! * every `paper_ref` section carries a measured (finite) value next to
//!   the paper's expected one;
//! * `to_text()` through the registry is byte-identical to the direct
//!   harness call — the redesign did not perturb the printable figures;
//! * `run_many` returns byte-identical reports at 1/2/8 workers.

use wihetnoc::experiments::{
    self, run_many_threads, Ctx, Effort, Report, SectionData,
};
use wihetnoc::util::json::{self, Json};
use wihetnoc::WihetError;

fn check_paper_refs(rep: &Report) -> usize {
    let mut refs = 0;
    for s in &rep.sections {
        match &s.data {
            SectionData::Scalar { value, paper_ref, .. } => {
                if let Some(p) = paper_ref {
                    refs += 1;
                    assert!(
                        value.is_finite(),
                        "{}.{}: paper_ref ({}) without a measured value",
                        rep.id,
                        s.name,
                        p.note
                    );
                }
            }
            SectionData::Series { values, paper_ref, .. } => {
                if paper_ref.is_some() {
                    refs += 1;
                    assert!(!values.is_empty(), "{}.{}: empty series", rep.id, s.name);
                }
            }
            SectionData::Table { .. } => {}
        }
    }
    refs
}

#[test]
fn every_experiment_roundtrips_through_json() {
    let mut ctx = Ctx::new(Effort::Quick, 1);
    let mut experiments_with_refs = 0;
    for id in experiments::ALL.iter() {
        let rep = experiments::run(id, &mut ctx).expect("registered experiment runs");
        assert_eq!(rep.id, *id, "report id must match the registry id");
        assert!(!rep.sections.is_empty(), "{id} has no structured sections");
        let doc = rep.to_json();
        let dumped = doc.dump();
        let parsed = json::parse(&dumped)
            .unwrap_or_else(|e| panic!("{id} emits invalid JSON: {e}\n{dumped}"));
        assert_eq!(parsed, doc, "{id}: dump -> parse is not a fixpoint");
        assert_eq!(parsed.get("id").and_then(Json::as_str), Some(*id));
        assert_eq!(parsed.get("schema").and_then(Json::as_f64), Some(1.0));
        assert!(
            !parsed.get("sections").and_then(Json::as_arr).unwrap().is_empty(),
            "{id}: sections lost in serialization"
        );
        // text and CSV renderings exist for every experiment (this sweep
        // also subsumes the old integration.rs experiments_all_smoke)
        let text = rep.to_text();
        assert!(text.len() > 100, "{id} output too short:\n{text}");
        assert!(text.contains(match *id {
            "table1" => "Table 1",
            "workload_figs" => "Workload figs",
            "scale_figs" => "Scale figs",
            "resilience_figs" => "Resilience figs",
            "hotspot_figs" => "Hotspot figs",
            "design_figs" => "Design figs",
            _ => "Fig",
        }));
        assert!(rep.to_csv().lines().count() > 1, "{id} has an empty CSV");
        if check_paper_refs(&rep) > 0 {
            experiments_with_refs += 1;
        }
    }
    // the paper-claim measurements did not silently disappear
    assert!(
        experiments_with_refs >= 8,
        "only {experiments_with_refs} experiments carry paper_ref sections"
    );
}

#[test]
fn registry_text_is_byte_identical_to_direct_calls() {
    // The registry (and the Report plumbing behind it) must not perturb
    // the printable figures: dispatching through `experiments::run` on
    // one context and calling the harness directly on another, equally
    // seeded context yields the same bytes.
    let mut via_registry = Ctx::new(Effort::Quick, 1);
    let mut direct = Ctx::new(Effort::Quick, 1);
    let pairs: [(&str, fn(&mut Ctx) -> Report); 3] = [
        ("table1", wihetnoc::experiments::table1::run),
        ("fig5", wihetnoc::experiments::traffic_figs::fig5),
        ("fig17", wihetnoc::experiments::compare_figs::fig17),
    ];
    for (id, f) in pairs {
        let a = experiments::run(id, &mut via_registry).unwrap();
        let b = f(&mut direct);
        assert_eq!(a.to_text(), b.to_text(), "{id}: registry text differs");
        assert_eq!(
            a.to_json().dump(),
            b.to_json().dump(),
            "{id}: registry JSON differs"
        );
    }
}

#[test]
fn run_many_is_deterministic_across_worker_counts() {
    // cheap ids (no NoC design needed) keep this fast; each job builds
    // its own Ctx, so reports must be identical at any pool size
    let ids = ["table1", "fig5", "fig6"];
    let serial = run_many_threads(1, &ids, Effort::Quick, 1).unwrap();
    assert_eq!(serial.len(), ids.len());
    for (rep, id) in serial.iter().zip(&ids) {
        assert_eq!(rep.id, *id, "run_many must preserve input order");
    }
    let serial_docs: Vec<String> = serial.iter().map(|r| r.to_json().dump()).collect();
    for threads in [2, 8] {
        let par = run_many_threads(threads, &ids, Effort::Quick, 1).unwrap();
        let docs: Vec<String> = par.iter().map(|r| r.to_json().dump()).collect();
        assert_eq!(docs, serial_docs, "{threads}-worker run differs from serial");
    }
}

#[test]
fn unknown_ids_fail_with_the_full_menu() {
    let mut ctx = Ctx::new(Effort::Quick, 1);
    let err = experiments::run("figg17", &mut ctx).unwrap_err();
    assert!(matches!(err, WihetError::UnknownExperiment(_)));
    let msg = err.to_string();
    for id in ["table1", "fig5", "fig17", "workload_figs"] {
        assert!(msg.contains(id), "menu missing '{id}': {msg}");
    }
    // run_many validates ids before any experiment runs
    let err = run_many_threads(4, &["fig5", "figgg"], Effort::Quick, 1).unwrap_err();
    assert!(err.to_string().contains("figgg"));
}

//! Workload-subsystem guarantees (ISSUE 3):
//!
//! * **Conservation** — for every preset x mapping policy x platform,
//!   the lowered traffic obeys exact byte accounting: pipelined mappings
//!   redistribute the identity lowering's bytes without creating or
//!   losing any; `data:R` adds exactly `(R-1) * 4 * weight_bytes` per
//!   weighted GPU layer; and the aggregate `fij` matrix carries exactly
//!   the flits the phases account for.
//! * **Determinism** — lowering is reproducible across runs and across
//!   `par_map` worker counts.
//! * **Round-trip** — `ArchSpec` survives `to_string().parse()`.
//! * **End-to-end** — a non-paper workload (alexnet) on a non-paper
//!   platform (12x12, corner MCs) simulates through the standard
//!   pipeline.

use wihetnoc::model::cnn::LayerKind;
use wihetnoc::model::SystemConfig;
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::traffic::trace::{training_trace, TraceConfig};
use wihetnoc::util::exec::par_map_threads;
use wihetnoc::workload::{lower_id, preset_names, ArchSpec, MappingPolicy};
use wihetnoc::{Effort, ModelId, Platform, Scenario};

fn platforms() -> Vec<SystemConfig> {
    ["4x4", "8x8", "12x12"]
        .iter()
        .map(|s| s.parse::<Platform>().unwrap().build().unwrap())
        .collect()
}

fn preset_ids() -> Vec<ModelId> {
    preset_names().iter().map(|n| n.parse().unwrap()).collect()
}

/// Comparable digest of a lowered traffic model.
fn fingerprint(tm: &wihetnoc::traffic::phases::TrafficModel) -> Vec<(u64, u64, u64, u64, u64, u64, Vec<usize>)> {
    tm.phases
        .iter()
        .map(|p| {
            (
                p.gpu_read_bytes,
                p.gpu_write_bytes,
                p.cpu_read_bytes,
                p.cpu_write_bytes,
                p.core_core_flits,
                p.duration_cycles,
                p.gpu_tiles.clone(),
            )
        })
        .collect()
}

#[test]
fn bytes_conserve_across_presets_mappings_platforms() {
    let batch = 32;
    for sys in platforms() {
        for model in preset_ids() {
            let base = lower_id(&model, &MappingPolicy::default(), &sys, batch).unwrap();
            assert!(base.total_bytes() > 0);

            // pipelining redistributes traffic; totals must be untouched
            for stages in [2, 3] {
                let piped = lower_id(
                    &model,
                    &MappingPolicy::LayerPipelined { stages },
                    &sys,
                    batch,
                )
                .unwrap();
                assert_eq!(
                    piped.total_bytes(),
                    base.total_bytes(),
                    "{model} pipeline:{stages} on {} tiles",
                    sys.num_tiles()
                );
                assert_eq!(piped.phases.len(), base.phases.len());
                // restricted phases draw their tiles from the GPU set
                let gpus = sys.gpus();
                for p in &piped.phases {
                    for t in &p.gpu_tiles {
                        assert!(gpus.contains(t), "{model}: tile {t} is not a GPU");
                    }
                }
            }

            // data-parallel replicas add exactly their weight traffic:
            // fwd weight read + bwd gradient write + bwd weight re-read +
            // CPU gradient-shard read = 4 weight volumes per extra replica
            let w: u64 = model
                .spec()
                .layers
                .iter()
                .filter(|l| l.has_params() && l.kind != LayerKind::Dense)
                .map(|l| l.weight_bytes())
                .sum();
            for replicas in [2u64, 4] {
                let dp = lower_id(
                    &model,
                    &MappingPolicy::DataParallel { replicas: replicas as usize },
                    &sys,
                    batch,
                )
                .unwrap();
                assert_eq!(
                    dp.total_bytes(),
                    base.total_bytes() + (replicas - 1) * 4 * w,
                    "{model} data:{replicas} on {} tiles",
                    sys.num_tiles()
                );
            }
        }
    }
}

#[test]
fn fij_carries_exactly_the_phase_flits() {
    let batch = 16;
    let mappings = [
        MappingPolicy::default(),
        MappingPolicy::DataParallel { replicas: 4 },
        MappingPolicy::LayerPipelined { stages: 3 },
    ];
    for sys in platforms() {
        for model in preset_ids() {
            for mapping in mappings {
                let tm = lower_id(&model, &mapping, &sys, batch).unwrap();
                let fij = tm.fij(&sys);
                let cycles = tm.total_cycles().max(1) as f64;
                // exact directional accounting (GPU and CPU cohorts line
                // up separately, matching fij's construction)
                let lf = sys.line_bytes / sys.flit_bytes + 1;
                let mut expect = 0u64;
                for p in &tm.phases {
                    let gr = p.gpu_read_bytes.div_ceil(sys.line_bytes);
                    let gw = p.gpu_write_bytes.div_ceil(sys.line_bytes);
                    let cr = p.cpu_read_bytes.div_ceil(sys.line_bytes);
                    let cw = p.cpu_write_bytes.div_ceil(sys.line_bytes);
                    expect += gr + gw * (1 + lf) // core->MC requests
                        + gr * lf + gw * (lf + 1) // MC->core replies
                        + cr + cw * (1 + lf)
                        + cr * lf + cw * (lf + 1)
                        + p.core_core_flits;
                }
                let carried = fij.total() * cycles;
                let rel = (carried - expect as f64).abs() / expect as f64;
                assert!(
                    rel < 1e-6,
                    "{model} {mapping} on {} tiles: fij carries {carried}, phases account {expect}",
                    sys.num_tiles()
                );
                // and the phase-level flit helpers agree to rounding
                let flits: u64 = tm.phases.iter().map(|p| p.total_flits(&sys)).sum();
                let rel = (carried - flits as f64).abs() / flits as f64;
                assert!(rel < 1e-3, "{model} {mapping}: {carried} vs {flits}");
            }
        }
    }
}

#[test]
fn lowering_is_deterministic_across_runs_and_threads() {
    let sys = "12x12".parse::<Platform>().unwrap().build().unwrap();
    let jobs: Vec<(ModelId, MappingPolicy)> = preset_ids()
        .into_iter()
        .flat_map(|m| {
            [
                MappingPolicy::default(),
                MappingPolicy::DataParallel { replicas: 8 },
                MappingPolicy::LayerPipelined { stages: 4 },
            ]
            .into_iter()
            .map(move |p| (m.clone(), p))
        })
        .collect();
    let run = |threads: usize| {
        par_map_threads(threads, &jobs, |_, (model, mapping)| {
            fingerprint(&lower_id(model, mapping, &sys, 32).unwrap())
        })
    };
    let serial = run(1);
    assert_eq!(serial, run(1), "repeat runs must match");
    for threads in [2, 8] {
        assert_eq!(run(threads), serial, "thread count {threads} diverged");
    }
}

#[test]
fn archspec_roundtrips_through_strings() {
    // the ISSUE's acceptance string
    let s = "conv:5x5x20 pool:2 conv:5x5x50 pool:2 dense:500 dense:10";
    let a: ArchSpec = s.parse().unwrap();
    let b: ArchSpec = a.to_string().parse().unwrap();
    assert_eq!(a, b);
    // every preset's DSL round-trips too (names aside)
    for model in preset_ids() {
        let arch = model.arch();
        let re: ArchSpec = arch.to_string().parse().unwrap();
        assert_eq!(re.items, arch.items, "{model}");
        assert_eq!(re.input, arch.input, "{model}");
    }
    // and a ModelId built from a spec string displays as parseable DSL
    let m: ModelId = s.parse().unwrap();
    let m2: ModelId = m.to_string().parse().unwrap();
    assert_eq!(m, m2);
}

#[test]
fn alexnet_simulates_on_12x12_corners_end_to_end() {
    // The acceptance scenario minus the AMOSA design step (CI's
    // bench-smoke drives the full `simulate --noc wihetnoc` CLI): lower
    // alexnet with a pipelined mapping onto a 144-tile chip and push the
    // trace through the cycle-level simulator on the adaptive mesh.
    use wihetnoc::experiments::Ctx;
    use wihetnoc::noc::builder::mesh_opt;

    let platform: Platform = "12x12:cpus=8,mcs=8,placement=corners".parse().unwrap();
    let scenario = Scenario::new(platform, "alexnet".parse().unwrap())
        .with_mapping(MappingPolicy::LayerPipelined { stages: 4 })
        .with_effort(Effort::Quick)
        .with_seed(3);
    let mut ctx = Ctx::for_scenario(&scenario).unwrap();
    let sys = ctx.sys.clone();
    let inst = mesh_opt(&sys, true);
    let tm = ctx.traffic_on(scenario.model.clone(), &sys);
    // pipelined phases restrict injection to their stage tiles
    assert!(tm.phases.iter().any(|p| !p.gpu_tiles.is_empty()));
    let cfg = TraceConfig { scale: 0.002, ..Default::default() };
    let (trace, _) = training_trace(&sys, &tm.phases, &cfg);
    assert!(!trace.is_empty());
    let rep = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
        .run(&trace);
    assert!(rep.delivered_packets > 0);
    assert_eq!(rep.undelivered(), 0);
}

#[test]
fn custom_spec_flows_through_ctx_cache() {
    use wihetnoc::experiments::Ctx;

    let model: ModelId = "input:28x28x1 conv:3x3x8,same pool:2 dense:10".parse().unwrap();
    let scenario = Scenario::new("4x4".parse().unwrap(), model.clone()).with_seed(9);
    let mut ctx = Ctx::for_scenario(&scenario).unwrap();
    let sys = ctx.sys.clone();
    let t1 = ctx.traffic_on(model.clone(), &sys);
    let t2 = ctx.traffic_on(model.clone(), &sys);
    assert!(std::sync::Arc::ptr_eq(&t1, &t2), "custom specs hash into the cache");
    assert_eq!(t1.phases.len(), 2 * 3);
}

//! Property-based tests (via `util::prop`) over the NoC substrate's
//! invariants: routing validity, LASH deadlock freedom, simulator
//! conservation and monotonicity, AMOSA feasibility preservation, and
//! traffic accounting — randomized topologies, traffic, and traces.

use wihetnoc::model::{lenet, SystemConfig, TileKind};
use wihetnoc::noc::analysis::{analyze, TrafficMatrix};
use wihetnoc::noc::routing::{verify_lash, RouteSet};
use wihetnoc::noc::sim::{Message, MsgClass, NocSim, SimConfig};
use wihetnoc::noc::topology::Topology;
use wihetnoc::noc::wireless::WirelessSpec;
use wihetnoc::optim::linkplace::LinkPlacement;
use wihetnoc::prop_assert;
use wihetnoc::traffic::phases::model_phases;
use wihetnoc::traffic::trace::{phase_trace, TraceConfig};
use wihetnoc::util::prop::{run_prop, Gen};
use wihetnoc::util::rng::Rng;

/// Random connected topology over the paper system: mesh + rewires.
fn random_topology(g: &mut Gen, sys: &SystemConfig) -> Topology {
    let fij = TrafficMatrix::from_entries(
        sys.num_tiles(),
        vec![(0, 1, 1.0)], // objectives unused here
    );
    let problem = LinkPlacement::new(sys, &fij, 112, 4 + g.rng.below(4));
    let mut sol: Vec<(usize, usize)> = Topology::mesh(sys).edges();
    let rewires = g.sized(0, 40);
    for _ in 0..rewires {
        sol = wihetnoc::optim::amosa::Problem::perturb(&problem, &sol, &mut g.rng);
    }
    Topology::from_edges(sys, &sol)
}

#[test]
fn prop_shortest_routes_are_valid_chains() {
    let sys = SystemConfig::paper_8x8();
    run_prop("shortest routes chain src->dst", 25, 0x51, |g| {
        let topo = random_topology(g, &sys);
        let rs = RouteSet::shortest(&topo, None);
        for _ in 0..50 {
            let s = g.rng.below(64);
            let d = g.rng.below(64);
            let p = rs.primary(s, d);
            let mut cur = s;
            for h in &p.hops {
                prop_assert!(h.from() == cur, "hop from {} != cur {}", h.from(), cur);
                cur = h.to();
            }
            prop_assert!(cur == d, "path ends at {cur} not {d}");
            prop_assert!(
                p.hops.len() as u32 >= topo.hops(s, d),
                "path shorter than BFS ({} < {})",
                p.hops.len(),
                topo.hops(s, d)
            );
        }
        Ok(())
    });
}

#[test]
fn prop_lash_layering_always_acyclic() {
    let sys = SystemConfig::paper_8x8();
    run_prop("LASH layers acyclic", 15, 0x1A, |g| {
        let topo = random_topology(g, &sys);
        let rs = RouteSet::shortest(&topo, None);
        verify_lash(&topo, &rs).map_err(|e| format!("LASH: {e}"))
    });
}

#[test]
fn prop_alash_air_paths_valid_and_cheaper() {
    let sys = SystemConfig::paper_8x8();
    run_prop("ALASH air paths valid + enabled only when cheaper", 12, 0xA1, |g| {
        let topo = Topology::mesh(&sys);
        let mut air = WirelessSpec::new(1 + g.sized(1, 4));
        let n_wi = 2 + g.sized(0, 10);
        let mut used = std::collections::HashSet::new();
        for _ in 0..n_wi {
            let r = g.rng.below(64);
            let c = g.rng.below(air.num_channels);
            if used.insert((r, c)) {
                air.add_wi(r, c);
            }
        }
        let chans: Vec<usize> = (0..air.num_channels).collect();
        let rs = RouteSet::alash(&topo, &air, None, |_, _| chans.clone(), 5);
        for s in 0..64 {
            for d in 0..64 {
                if let Some(p) = rs.air_path(s, d) {
                    let mut cur = s;
                    for h in &p.hops {
                        prop_assert!(h.from() == cur, "air path broken at {cur}");
                        cur = h.to();
                    }
                    prop_assert!(cur == d, "air path ends wrong");
                    let wire = rs.primary(s, d);
                    prop_assert!(
                        p.zero_load_cost(&topo, &air, 5)
                            < wire.zero_load_cost(&topo, &air, 5),
                        "air path admitted but not cheaper for ({s},{d})"
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sim_conserves_messages_and_flits() {
    let sys = SystemConfig::paper_8x8();
    let topo = Topology::mesh(&sys);
    let rs = RouteSet::xy_yx(&sys, &topo);
    let air = WirelessSpec::new(0);
    run_prop("simulator conservation", 20, 0x5C, |g| {
        let n = g.sized(1, 400);
        let mut trace = Vec::new();
        let mut rng = Rng::new(g.rng.next_u64());
        for _ in 0..n {
            let src = rng.below(64);
            let dst = rng.below(64);
            let class = *rng.pick(&[
                MsgClass::Control,
                MsgClass::ReadReq,
                MsgClass::WriteData,
            ]);
            trace.push(Message {
                src,
                dst,
                flits: 1 + rng.below(8) as u64,
                class,
                inject_at: rng.below(500) as u64,
            });
        }
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let rep = sim.run(&trace);
        let responses =
            trace.iter().filter(|m| m.class.spawns_response().is_some()).count() as u64;
        prop_assert!(
            rep.delivered_packets == trace.len() as u64 + responses,
            "delivered {} != {} + {}",
            rep.delivered_packets,
            trace.len(),
            responses
        );
        prop_assert!(rep.undelivered() == 0, "undelivered {}", rep.undelivered());
        // latency at least the zero-load bound for every packet: mean must
        // be >= min over per-hop floor (router >= 3 per hop)
        prop_assert!(
            rep.latency.count == rep.delivered_packets,
            "latency samples mismatch"
        );
        Ok(())
    });
}

#[test]
fn prop_sim_latency_monotone_in_load() {
    // doubling every packet's size must not reduce mean latency
    let sys = SystemConfig::paper_8x8();
    let topo = Topology::mesh(&sys);
    let rs = RouteSet::xy(&sys, &topo);
    let air = WirelessSpec::new(0);
    run_prop("latency monotone in packet size", 15, 0x10, |g| {
        let mut rng = Rng::new(g.rng.next_u64());
        let n = 50 + g.sized(0, 300);
        let base: Vec<Message> = (0..n)
            .map(|_| Message {
                src: rng.below(64),
                dst: rng.below(64),
                flits: 1 + rng.below(4) as u64,
                class: MsgClass::Control,
                inject_at: rng.below(200) as u64,
            })
            .collect();
        let heavy: Vec<Message> =
            base.iter().map(|m| Message { flits: m.flits * 2, ..*m }).collect();
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let (a, b) = (sim.run(&base), sim.run(&heavy));
        prop_assert!(
            b.latency.mean() >= a.latency.mean(),
            "heavier packets got faster: {} < {}",
            b.latency.mean(),
            a.latency.mean()
        );
        Ok(())
    });
}

#[test]
fn prop_linkplace_perturb_preserves_all_constraints() {
    let sys = SystemConfig::paper_8x8();
    let tm = model_phases(&sys, &lenet(), 32).fij(&sys);
    run_prop("perturb keeps Eqn 7-9 constraints", 10, 0x11, |g| {
        let k_max = 4 + g.rng.below(4);
        let problem =
            LinkPlacement::new(&sys, &tm, 112, k_max).with_max_link_mm(Some(7.6));
        let mut sol = Topology::mesh(&sys).edges();
        for _ in 0..g.sized(1, 60) {
            sol = wihetnoc::optim::amosa::Problem::perturb(&problem, &sol, &mut g.rng);
            let topo = Topology::from_edges(&sys, &sol);
            prop_assert!(sol.len() == 112, "link budget broken: {}", sol.len());
            prop_assert!(topo.is_connected(), "disconnected");
            prop_assert!(topo.k_max() <= k_max, "k_max {} > {}", topo.k_max(), k_max);
            prop_assert!(
                topo.links.iter().all(|l| l.length_mm <= 7.6 + 1e-9),
                "over-length link"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_analysis_utilization_conserves_twhc() {
    // sum of link utilizations == traffic-weighted hop count (Eqn 4 both
    // ways), on random topologies and random many-to-few traffic
    let sys = SystemConfig::paper_8x8();
    run_prop("sum(U_k) == twhc", 20, 0xE4, |g| {
        let topo = random_topology(g, &sys);
        let mcs = sys.mcs();
        let mut entries = Vec::new();
        for _ in 0..g.sized(1, 80) {
            let c = g.rng.below(64) as u32;
            let m = mcs[g.rng.below(mcs.len())] as u32;
            entries.push((c, m, g.rng.f64()));
        }
        let tm = TrafficMatrix::from_entries(64, entries);
        let a = analyze(&topo, &tm);
        let sum: f64 = a.link_util.iter().sum();
        prop_assert!(
            (sum - a.twhc).abs() < 1e-6 * a.twhc.max(1.0),
            "sum U {} != twhc {}",
            sum,
            a.twhc
        );
        Ok(())
    });
}

#[test]
fn prop_trace_sources_match_cohorts() {
    // generated traces only ever inject from the right tile kinds
    let sys = SystemConfig::paper_8x8();
    let tm = model_phases(&sys, &lenet(), 32);
    run_prop("trace cohort sources", 15, 0x7C, |g| {
        let phase = &tm.phases[g.rng.below(tm.phases.len())];
        let cfg = TraceConfig {
            scale: 0.02 + g.rng.f64() * 0.05,
            burst_duty: 0.2 + g.rng.f64() * 0.7,
            seed: g.rng.next_u64(),
        };
        let mut rng = Rng::new(cfg.seed);
        let (msgs, dur) = phase_trace(&sys, phase, 0, &cfg, &mut rng);
        prop_assert!(dur > 0, "zero duration");
        for m in &msgs {
            match m.class {
                MsgClass::ReadReq | MsgClass::WriteData => {
                    prop_assert!(
                        sys.tiles[m.dst] == TileKind::Mc,
                        "memory msg to non-MC {}",
                        m.dst
                    );
                    prop_assert!(sys.tiles[m.src] != TileKind::Mc, "MC as requester");
                }
                MsgClass::Control => {
                    prop_assert!(
                        sys.tiles[m.src] != TileKind::Mc && sys.tiles[m.dst] != TileKind::Mc,
                        "control touching MC"
                    );
                }
                _ => return Err("trace emitted a response class".into()),
            }
        }
        Ok(())
    });
}

//! End-to-end tests of the typed scenario API: platform parsing, scenario
//! construction, the `NocDesigner` flow on non-paper platforms, typed
//! errors instead of panics, and experiment dispatch smoke coverage.

use wihetnoc::experiments::{self, Ctx, Effort};
use wihetnoc::noc::builder::{NocDesigner, NocKind};
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::traffic::phases::model_phases;
use wihetnoc::traffic::trace::{training_trace, TraceConfig};
use wihetnoc::{ModelId, Platform, Scenario, WihetError};

#[test]
fn design_and_simulate_non_8x8_platform() {
    // The acceptance scenario: a platform the paper never built — a
    // rectangular 6x4 chip with corner MCs — designed and simulated end
    // to end through the typed API only.
    let platform: Platform = "6x4:cpus=2,mcs=4,placement=corners".parse().unwrap();
    let scenario = Scenario::new(platform, ModelId::CdbNet)
        .with_seed(13)
        .with_batch(16);
    let sys = scenario.build_system().unwrap();
    assert_eq!(sys.num_tiles(), 24);
    assert_eq!(sys.height(), 4);

    let inst = NocDesigner::for_scenario(&scenario).unwrap().build().unwrap();
    assert_eq!(inst.kind, NocKind::WiHetNoc);
    assert!(inst.topo.is_connected());
    assert_eq!(inst.topo.links.len(), 2 * 6 * 4 - 6 - 4); // mesh link budget

    let tm = model_phases(&sys, &scenario.model.spec(), scenario.batch);
    let tcfg = TraceConfig { scale: 0.02, ..Default::default() };
    let (trace, _) = training_trace(&sys, &tm.phases, &tcfg);
    let rep = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
        .run(&trace);
    assert!(rep.delivered_packets > 0);
    assert_eq!(rep.undelivered(), 0);
}

#[test]
fn ctx_runs_experiment_on_4x4_platform() {
    let scenario = Scenario::new("4x4".parse().unwrap(), ModelId::LeNet).with_seed(5);
    let mut ctx = Ctx::for_scenario(&scenario).unwrap();
    let report = experiments::run("fig5", &mut ctx).unwrap();
    assert!(report.to_text().contains("Fig 5"));
    assert!(report.to_text().contains("C1"));
}

// NOTE: the every-id dispatch smoke test (all of `experiments::ALL` at
// Effort::Quick through one shared Ctx, asserting non-trivial text and
// valid JSON per report) lives in
// tests/report_api.rs::every_experiment_roundtrips_through_json.

#[test]
fn unknown_names_are_errors_not_panics() {
    let mut ctx = Ctx::new(Effort::Quick, 1);
    assert!(matches!(
        experiments::run("fig99", &mut ctx),
        Err(WihetError::UnknownExperiment(_))
    ));
    assert!(matches!(
        "resnet".parse::<ModelId>(),
        Err(WihetError::UnknownModel(_))
    ));
    assert!(matches!(
        "torus".parse::<NocKind>(),
        Err(WihetError::UnknownNoc(_))
    ));
    assert!(matches!(
        "9x9x9".parse::<Platform>(),
        Err(WihetError::InvalidPlatform(_))
    ));
    assert!(matches!(
        "hard".parse::<Effort>(),
        Err(WihetError::InvalidArg(_))
    ));
}

#[test]
fn invalid_scenarios_fail_at_the_boundary() {
    // a platform with no room for GPUs is rejected before any design work
    let p = Platform::grid(2, 2).with_cpus(2).with_mcs(2);
    let sc = Scenario::new(p, ModelId::LeNet);
    assert!(matches!(
        Ctx::for_scenario(&sc),
        Err(WihetError::InvalidPlatform(_))
    ));
    assert!(matches!(
        NocDesigner::for_scenario(&sc),
        Err(WihetError::InvalidPlatform(_))
    ));
    // infeasible design knobs on a valid platform
    let good = Scenario::new("4x4".parse().unwrap(), ModelId::LeNet);
    let designer = NocDesigner::for_scenario(&good).unwrap().n_wi(1000);
    assert!(matches!(
        designer.build(),
        Err(WihetError::InvalidDesign(_))
    ));
}

#[test]
fn scenario_roundtrips_through_platform_strings() {
    for s in ["8x8", "4x4", "12x12", "6x4:cpus=3,mcs=2", "5x5:placement=corners"] {
        let p: Platform = s.parse().unwrap_or_else(|e| panic!("{s}: {e}"));
        let q: Platform = p.to_string().parse().unwrap();
        assert_eq!(p, q, "{s}");
        let sys = p.build().unwrap();
        assert_eq!(sys.num_tiles(), p.num_tiles());
        assert_eq!(sys.cpus().len(), p.cpus);
        assert_eq!(sys.mcs().len(), p.mcs);
    }
}

#[test]
fn designer_respects_explicit_knobs() {
    let scenario = Scenario::new("8x8".parse().unwrap(), ModelId::LeNet).with_seed(11);
    let inst = NocDesigner::for_scenario(&scenario)
        .unwrap()
        .k_max(5)
        .n_wi(8)
        .gpu_channels(2)
        .build()
        .unwrap();
    assert!(inst.topo.k_max() <= 5);
    // 4 CPU + 4 MC WIs on channel 0, 8 GPU WIs on channels 1..=2
    assert_eq!(inst.air.wis.len(), 8 + 8);
    assert_eq!(inst.air.num_channels, 3);
}

//! Determinism guarantees of the fast simulation core (ISSUE 2):
//! workspace reuse never changes results, and the parallel experiment
//! runner produces identical `SimReport` aggregates at 1, 2, and 8
//! workers for a 500-message mixed-class trace.

use wihetnoc::model::SystemConfig;
use wihetnoc::noc::builder::{wi_het_noc_quick, NocInstance};
use wihetnoc::noc::sim::{Message, MsgClass, NocSim, SimConfig, SimReport, SimWorkspace};
use wihetnoc::util::exec::par_map_threads;

/// 500 messages mixing memory requests, writebacks, and control traffic
/// across the whole chip, bursty enough to exercise contention, MAC
/// fallbacks, and response spawning.
fn mixed_trace(seed: u64) -> Vec<Message> {
    let classes = [MsgClass::ReadReq, MsgClass::WriteData, MsgClass::Control];
    let mut out = Vec::new();
    let mut i = seed;
    while out.len() < 500 {
        i += 1;
        let src = (i * 13 + seed) as usize % 64;
        let dst = (i * 29 + 7) as usize % 64;
        if src == dst {
            continue;
        }
        out.push(Message {
            src,
            dst,
            flits: 1 + (i % 6),
            class: classes[(i % 3) as usize],
            inject_at: (i / 3) * 2,
        });
    }
    out
}

/// Everything a `SimReport` aggregates, as one comparable value.
fn fingerprint(r: &SimReport) -> (u64, u64, u64, String, Vec<u64>, Vec<u64>, u64, u64) {
    (
        r.delivered_packets,
        r.delivered_flits,
        r.cycles,
        format!(
            "{:.9}/{:.9}/{:.9}/{:.9}",
            r.latency.sum, r.latency.max, r.cpu_mc_latency.sum, r.gpu_mc_latency.sum
        ),
        r.link_busy.clone(),
        r.air_flits.clone(),
        r.air_packets,
        r.air_fallbacks,
    )
}

fn wihet_setup() -> (SystemConfig, NocInstance) {
    let sys = SystemConfig::paper_8x8();
    let inst = wi_het_noc_quick(&sys, 11);
    (sys, inst)
}

#[test]
fn workspace_reuse_is_invisible() {
    let (sys, inst) = wihet_setup();
    let sim = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
    let trace = mixed_trace(3);
    let fresh = fingerprint(&sim.run_in(&trace, &mut SimWorkspace::new()));
    // one workspace, reused across different traces and repeats
    let mut ws = SimWorkspace::new();
    let _ = sim.run_in(&mixed_trace(99), &mut ws);
    for _ in 0..3 {
        assert_eq!(fingerprint(&sim.run_in(&trace, &mut ws)), fresh);
    }
    // the thread-local convenience path agrees too
    assert_eq!(fingerprint(&sim.run(&trace)), fresh);
}

#[test]
fn parallel_runner_reproduces_serial_reports() {
    let (sys, inst) = wihet_setup();
    // a sweep of 12 jobs: rate-compressed variants of the mixed trace,
    // each job seeded/derived independently from its index
    let jobs: Vec<Vec<Message>> = (0..12u64)
        .map(|j| {
            mixed_trace(3)
                .into_iter()
                .map(|m| Message { inject_at: m.inject_at / (1 + j % 4), ..m })
                .collect()
        })
        .collect();
    let run_all = |threads: usize| {
        par_map_threads(threads, &jobs, |_, trace: &Vec<Message>| {
            let sim =
                NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
            fingerprint(&sim.run(trace))
        })
    };
    let serial = run_all(1);
    assert_eq!(serial.len(), 12);
    for threads in [2, 8] {
        assert_eq!(run_all(threads), serial, "thread count {threads} diverged");
    }
}

#[test]
fn parallel_experiment_reports_are_thread_count_invariant() {
    // End-to-end: a figure harness that fans out internally must render
    // byte-identical reports at any WIHETNOC_THREADS. Setting the env
    // var here is safe: this is the only test in this binary that reads
    // it (the others drive par_map_threads explicitly), and integration
    // test binaries are separate processes.
    use wihetnoc::experiments::{self, Ctx, Effort};
    let render = |threads: &str| {
        std::env::set_var("WIHETNOC_THREADS", threads);
        let mut ctx = Ctx::new(Effort::Quick, 5);
        let report = experiments::run("fig13", &mut ctx).expect("fig13 runs");
        std::env::remove_var("WIHETNOC_THREADS");
        report
    };
    let serial = render("1");
    for threads in ["2", "8"] {
        assert_eq!(
            render(threads),
            serial,
            "fig13 diverged at WIHETNOC_THREADS={threads}"
        );
    }
}

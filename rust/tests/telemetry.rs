//! Telemetry integration tests (ISSUE 8):
//!
//! * attaching a [`Telemetry`] sink never perturbs simulation —
//!   `SimReport` / `ScheduleReport` / `FabricReport` are byte-identical
//!   (via exhaustive `Debug` formatting, which round-trips every f64)
//!   to the telemetry-off run, inside 1/2/8-worker pools
//!   (`WIHETNOC_THREADS` equivalents);
//! * the Chrome-trace export validates (Rust-side schema check mirrored
//!   by the CI jq step), spans stay serialized per track for `gpipe:8`
//!   and a 4-chip ring fabric, and fault reroutes appear as instants;
//! * `hotspot_figs` emits a finite `wihetnoc_p99_reduction_x` scalar
//!   and valid `trace.json` / `heatmap.csv` artifacts.

use wihetnoc::experiments::{self, Ctx, Effort, SectionData};
use wihetnoc::fabric::{run_fabric_faults, run_fabric_obs};
use wihetnoc::model::SystemConfig;
use wihetnoc::noc::builder::{mesh_opt, NocInstance};
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::schedule::{run_schedule_faults, run_schedule_obs};
use wihetnoc::telemetry::{chrome_trace, validate_chrome_trace, Span, Telemetry};
use wihetnoc::traffic::phases::TrafficModel;
use wihetnoc::traffic::trace::{training_trace, TraceConfig};
use wihetnoc::util::exec::par_map_threads;
use wihetnoc::util::json;
use wihetnoc::workload::{lower_id, MappingPolicy};
use wihetnoc::{Fabric, FaultPlan, ModelId, SchedulePolicy};

fn setup() -> (SystemConfig, NocInstance, TrafficModel) {
    let sys = SystemConfig::paper_8x8();
    let inst = mesh_opt(&sys, true);
    let tm = lower_id(
        &ModelId::LeNet,
        &MappingPolicy::LayerPipelined { stages: 2 },
        &sys,
        32,
    )
    .unwrap();
    (sys, inst, tm)
}

fn cfg() -> TraceConfig {
    TraceConfig { scale: 0.02, ..Default::default() }
}

/// Per-track spans must be serialized: stage resource edges gate each
/// instance on its predecessor's drain, so a successor may start exactly
/// at (but never before) the previous span's end.
fn assert_tracks_serialized(spans: &[Span]) {
    let mut tids: Vec<u32> = spans.iter().map(|s| s.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    for tid in tids {
        let mut track: Vec<&Span> = spans.iter().filter(|s| s.tid == tid).collect();
        track.sort_by_key(|s| (s.start, s.end));
        for w in track.windows(2) {
            assert!(
                w[1].start >= w[0].end,
                "track {tid}: '{}' [{}, {}) overlaps '{}' [{}, {})",
                w[0].name,
                w[0].start,
                w[0].end,
                w[1].name,
                w[1].start,
                w[1].end,
            );
        }
    }
}

#[test]
fn serial_report_identical_with_sink_attached_across_thread_counts() {
    let (sys, inst, tm) = setup();
    let cfg = cfg();
    let (trace, _) = training_trace(&sys, &tm.phases, &cfg);
    let sim = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
    let reference = format!("{:?}", sim.run(&trace));
    assert!(reference.len() > 100);

    for threads in [1usize, 2, 8] {
        // several workers run the off/on pair concurrently: the sink must
        // not perturb results under any pool size
        let jobs = vec![(); 4];
        let outcomes = par_map_threads(threads, &jobs, |_, _| {
            let sim =
                NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
            let off = sim.run(&trace);
            let mut tel = Telemetry::new();
            let on = sim.run_telemetry(&trace, Some(&mut tel));
            assert!(on.percentiles.is_none(), "sink must not leak into the report");
            assert_eq!(tel.delivered_packets, on.delivered_packets);
            assert_eq!(tel.link_flits, on.link_flits);
            (format!("{off:?}"), format!("{on:?}"))
        });
        for (off, on) in outcomes {
            assert_eq!(off, reference, "telemetry-off drifted at {threads} threads");
            assert_eq!(on, reference, "telemetry-on differs at {threads} threads");
        }
    }
}

#[test]
fn schedule_report_identical_and_gpipe8_trace_validates() {
    let (sys, inst, tm) = setup();
    let cfg = cfg();
    let gp = SchedulePolicy::GPipe { microbatches: 8 };
    let off =
        run_schedule_faults(&sys, &inst, &tm, &gp, &cfg, &FaultPlan::none()).unwrap();
    let reference = format!("{off:?}");

    for threads in [1usize, 2, 8] {
        let jobs = vec![(); 2];
        let outcomes = par_map_threads(threads, &jobs, |_, _| {
            let mut tel = Telemetry::new();
            let on = run_schedule_obs(
                &sys,
                &inst,
                &tm,
                &gp,
                &cfg,
                &FaultPlan::none(),
                Some(&mut tel),
            )
            .unwrap();
            (format!("{on:?}"), tel)
        });
        for (on, tel) in outcomes {
            assert_eq!(on, reference, "gpipe:8 report differs with sink at {threads} threads");
            // every instance drained -> one span each, on its stage track
            assert_eq!(tel.spans.len(), off.instances);
            assert!(tel.spans.iter().all(|s| s.cat == "phase"));
            assert!(tel.spans.iter().any(|s| s.name.ends_with("mb7")));
            assert_tracks_serialized(&tel.spans);
            let doc = chrome_trace(&tel);
            validate_chrome_trace(&doc).unwrap();
            validate_chrome_trace(&json::parse(&doc.dump()).unwrap()).unwrap();
            // latency histogram saw every delivered packet
            assert_eq!(tel.percentiles().all.count, off.sim.delivered_packets);
        }
    }
}

#[test]
fn fabric_report_identical_and_ring_trace_has_collective_and_wire_spans() {
    let (sys, inst, tm) = setup();
    let cfg = cfg();
    let gp = SchedulePolicy::GPipe { microbatches: 4 };
    let fabric: Fabric = "4:topo=ring".parse().unwrap();
    let grad = ModelId::LeNet.spec().total_weight_bytes();
    let off = run_fabric_faults(
        &sys,
        &inst,
        &tm,
        &gp,
        &fabric,
        grad,
        &cfg,
        &FaultPlan::none(),
    )
    .unwrap();
    let reference = format!("{off:?}");

    for threads in [1usize, 2, 8] {
        let jobs = vec![(); 2];
        let outcomes = par_map_threads(threads, &jobs, |_, _| {
            let mut tel = Telemetry::new();
            let on = run_fabric_obs(
                &sys,
                &inst,
                &tm,
                &gp,
                &fabric,
                grad,
                &cfg,
                &FaultPlan::none(),
                Some(&mut tel),
            )
            .unwrap();
            (format!("{on:?}"), tel)
        });
        for (on, tel) in outcomes {
            assert_eq!(on, reference, "fabric report differs with sink at {threads} threads");
            assert!(tel.spans.iter().any(|s| s.cat == "phase"));
            assert!(
                tel.spans.iter().any(|s| s.cat == "collective"),
                "allreduce instances must appear as collective spans"
            );
            let wires: Vec<&Span> = tel.spans.iter().filter(|s| s.cat == "fabric").collect();
            assert_eq!(wires.len(), off.steps, "one wire span per collective step");
            assert_tracks_serialized(&tel.spans);
            let doc = chrome_trace(&tel);
            validate_chrome_trace(&doc).unwrap();
        }
    }
}

#[test]
fn fault_reroutes_surface_as_trace_instants() {
    let (sys, inst, tm) = setup();
    let cfg = cfg();
    let (trace, _) = training_trace(&sys, &tm.phases, &cfg);
    let clean = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
        .run(&trace);
    // kill the hottest link: traffic demonstrably crosses it, so the
    // faulted run must reroute at least once
    let hot = clean
        .link_flits
        .iter()
        .enumerate()
        .max_by_key(|&(_, &f)| f)
        .map(|(l, _)| l)
        .unwrap();
    assert!(clean.link_flits[hot] > 0);
    let plan: FaultPlan = format!("wire:link={hot}").parse().unwrap();
    let fx = plan
        .compile(&inst.topo, &inst.routes, &inst.air, SimConfig::default().nominal_flits)
        .unwrap();
    let sim = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
        .with_faults(&fx);
    let mut tel = Telemetry::new();
    let rep = sim.run_telemetry(&trace, Some(&mut tel));
    assert!(rep.resilience.packets_rerouted > 0, "dead hot link must force reroutes");
    assert_eq!(tel.instants.len() as u64, rep.resilience.packets_rerouted);
    assert_eq!(tel.resilience, rep.resilience, "sink unifies ResilienceStats");
    let dumped = chrome_trace(&tel).dump();
    validate_chrome_trace(&json::parse(&dumped).unwrap()).unwrap();
    assert!(dumped.contains("\"ph\":\"i\""), "reroute instants missing:\n{dumped}");
    assert!(dumped.contains("reroute"));
}

#[test]
fn hotspot_figs_emits_finite_headline_and_valid_artifacts() {
    let mut ctx = Ctx::new(Effort::Quick, 1);
    let rep = experiments::run("hotspot_figs", &mut ctx).unwrap();
    assert!(rep.to_text().starts_with("Hotspot figs"));
    let headline = rep
        .scalars()
        .find(|(name, _)| *name == "wihetnoc_p99_reduction_x")
        .map(|(_, v)| v)
        .expect("headline scalar present");
    assert!(headline.is_finite() && headline > 0.0, "headline {headline}");
    // tail series are present and ordered p50 <= p99 <= p999
    for name in ["lenet_wihet_tail", "cdbnet_mesh_tail"] {
        let s = rep.section(name).unwrap_or_else(|| panic!("missing series {name}"));
        match &s.data {
            SectionData::Series { values, .. } => {
                assert_eq!(values.len(), 3);
                assert!(values[0] <= values[1] && values[1] <= values[2], "{values:?}");
            }
            other => panic!("{name} is not a series: {other:?}"),
        }
    }
    let trace = rep
        .artifacts
        .iter()
        .find(|a| a.name == "trace.json")
        .expect("trace.json artifact");
    let doc = json::parse(&trace.content).expect("trace.json parses");
    validate_chrome_trace(&doc).unwrap();
    let heatmap = rep
        .artifacts
        .iter()
        .find(|a| a.name == "heatmap.csv")
        .expect("heatmap.csv artifact");
    assert!(heatmap.content.starts_with("model,noc,link,a,b,flits,utilization"));
    assert!(heatmap.content.lines().count() > 10, "heatmap covers the links");
}

//! Cross-module integration tests: the full design flow (traffic model ->
//! AMOSA -> wireless overlay -> routing -> simulation -> energy) on both
//! the paper system and the small 4x4 variant (the every-experiment
//! smoke lives in tests/report_api.rs).

use wihetnoc::energy::network::network_energy_pj;
use wihetnoc::energy::params::EnergyParams;
use wihetnoc::energy::system::{full_system_run, StallModel};
use wihetnoc::model::{cdbnet, lenet, SystemConfig};
use wihetnoc::noc::builder::{het_noc, mesh_opt, wi_het_noc, DesignConfig};
use wihetnoc::noc::routing::verify_lash;
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::traffic::phases::model_phases;
use wihetnoc::traffic::trace::{training_trace, TraceConfig};

#[test]
fn full_design_flow_paper_system() {
    let sys = SystemConfig::paper_8x8();
    let tm = model_phases(&sys, &lenet(), 32);
    let fij = tm.fij(&sys);
    let cfg = DesignConfig::quick(99);
    let inst = wi_het_noc(&sys, &fij, &cfg);

    // structural invariants
    assert!(inst.topo.is_connected());
    assert_eq!(inst.topo.links.len(), 112);
    assert!(inst.topo.k_max() <= cfg.k_max);
    assert!(inst.topo.k_avg() <= 4.0 + 1e-9);
    assert_eq!(inst.air.wis.len(), 8 + cfg.n_wi);
    verify_lash(&inst.topo, &inst.routes).expect("deadlock-free layering");
    // wireline links respect the reach bound (long range goes wireless)
    for l in &inst.topo.links {
        assert!(l.length_mm <= cfg.max_link_mm.unwrap() + 1e-9);
    }

    // simulate an iteration and check conservation
    let tcfg = TraceConfig { scale: 0.02, ..Default::default() };
    let (trace, _) = training_trace(&sys, &tm.phases, &tcfg);
    let rep = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
        .run(&trace);
    // every injected message is delivered, plus one response per rd/wr
    let responses = trace
        .iter()
        .filter(|m| m.class.spawns_response().is_some())
        .count() as u64;
    assert_eq!(rep.delivered_packets, trace.len() as u64 + responses);
    assert_eq!(rep.undelivered(), 0);
    let e = network_energy_pj(&inst.topo, &rep, &EnergyParams::default());
    assert!(e.total_pj() > 0.0 && e.wireless_pj > 0.0);
}

#[test]
fn full_design_flow_small_system() {
    // the methodology is system-size agnostic (§5: "can be used for any
    // composition and system size")
    let sys = SystemConfig::small_4x4();
    let tm = model_phases(&sys, &cdbnet(), 16);
    let fij = tm.fij(&sys);
    let mut cfg = DesignConfig::quick(5);
    cfg.n_wi = 4;
    cfg.gpu_channels = 2;
    let inst = wi_het_noc(&sys, &fij, &cfg);
    assert!(inst.topo.is_connected());
    assert_eq!(inst.topo.links.len(), 24);
    assert_eq!(inst.air.wis.len(), 4 + 4);
    verify_lash(&inst.topo, &inst.routes).unwrap();

    let tcfg = TraceConfig { scale: 0.02, ..Default::default() };
    let (trace, _) = training_trace(&sys, &tm.phases, &tcfg);
    let rep = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
        .run(&trace);
    assert!(rep.delivered_packets > 0);
    assert_eq!(rep.undelivered(), 0);
}

#[test]
fn headline_orderings_hold_end_to_end() {
    // The paper's headline claims, end to end at quick effort:
    // latency(wihet) < latency(hetnoc) < latency(mesh), EDP(wihet) < mesh.
    let sys = SystemConfig::paper_8x8();
    let tm = model_phases(&sys, &lenet(), 32);
    let fij = tm.fij(&sys);
    let cfg = DesignConfig::quick(42);
    let mesh = mesh_opt(&sys, true);
    let het = het_noc(&sys, &fij, &cfg);
    let wihet = wi_het_noc(&sys, &fij, &cfg);

    let tcfg = TraceConfig { scale: 0.05, ..Default::default() };
    let run = |inst: &wihetnoc::noc::builder::NocInstance| {
        let (trace, _) = training_trace(&sys, &tm.phases, &tcfg);
        NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default()).run(&trace)
    };
    let (rm, rh, rw) = (run(&mesh), run(&het), run(&wihet));
    assert!(
        rw.latency.mean() < rm.latency.mean() && rh.latency.mean() < rm.latency.mean(),
        "latency: wihet {} hetnoc {} mesh {}",
        rw.latency.mean(),
        rh.latency.mean(),
        rm.latency.mean()
    );

    // full-system EDP ordering (Fig 19 claim)
    let e = EnergyParams::default();
    let s = StallModel::default();
    let fm = full_system_run(&sys, &mesh, &tm, &tcfg, &e, &s);
    let fw = full_system_run(&sys, &wihet, &tm, &tcfg, &e, &s);
    assert!(fw.edp < fm.edp, "EDP: wihet {} vs mesh {}", fw.edp, fm.edp);
    assert!(fw.exec_seconds <= fm.exec_seconds * 1.005);
}

// NOTE: the every-id smoke (all of `experiments::ALL` through one shared
// Ctx, asserting non-trivial text AND a valid JSON document per report)
// lives in tests/report_api.rs::every_experiment_roundtrips_through_json
// — one full sweep covers both, instead of this binary re-running the
// AMOSA designs a second time.

#[test]
fn manifest_cross_check_against_python_if_present() {
    // When artifacts exist, the Python-side layer metadata must agree
    // with the Rust derivation for *both* models (deeper check than the
    // runtime_integration one: includes out_bytes and per-layer kinds).
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: no artifacts");
        return;
    }
    let manifest = wihetnoc::runtime::Manifest::load(&dir).unwrap();
    for spec in [lenet(), cdbnet()] {
        let meta = manifest.model(&spec.name).unwrap();
        for (m, l) in meta.layers.iter().zip(&spec.layers) {
            assert_eq!(m.out_bytes, l.out_bytes(manifest.batch), "{}", l.name);
            assert_eq!(m.kind, l.kind.as_str());
        }
    }
}

//! Fabric-subsystem guarantees (ISSUE 6):
//!
//! * **Conservation** — the allreduce moves exactly
//!   `2*(N-1)/N * sum(W)` bytes per chip, regardless of the collective
//!   algorithm (ring, tree, hierarchical): the algorithms trade step
//!   count against step size, never volume.
//! * **Single-chip identity** — `--fabric 1` is byte-identical to the
//!   single-chip scheduled path for the paper models: the fabric layer
//!   costs nothing until there is a second chip.
//! * **Determinism** — the scale_figs lowering kernel (`run_fabric`
//!   jobs fanned out like the experiment sweep) fingerprints
//!   identically across 1/2/8 `par_map` workers.
//! * **Typed errors** — an invalid `--fabric` string is a
//!   `WihetError::InvalidArg` carrying the fabric grammar, never a
//!   panic.

use wihetnoc::fabric::{run_fabric, steps, wire_bytes_per_chip, Collective, Fabric};
use wihetnoc::model::SystemConfig;
use wihetnoc::noc::builder::{mesh_opt, NocInstance};
use wihetnoc::noc::sim::SimReport;
use wihetnoc::schedule::{run_schedule, SchedulePolicy};
use wihetnoc::traffic::trace::TraceConfig;
use wihetnoc::util::exec::par_map_threads;
use wihetnoc::workload::{lower_id, MappingPolicy};
use wihetnoc::{ModelId, WihetError};

/// Everything a `SimReport` aggregates, as one comparable value.
fn fingerprint(r: &SimReport) -> (u64, u64, u64, String, Vec<u64>, Vec<u64>) {
    (
        r.delivered_packets,
        r.delivered_flits,
        r.cycles,
        format!(
            "{:.9}/{:.9}/{:.9}/{:.9}",
            r.latency.sum, r.latency.max, r.cpu_mc_latency.sum, r.gpu_mc_latency.sum
        ),
        r.link_busy.clone(),
        r.link_flits.clone(),
    )
}

fn paper_setup(
    model: &ModelId,
    mapping: MappingPolicy,
) -> (SystemConfig, NocInstance, wihetnoc::traffic::phases::TrafficModel) {
    let sys = SystemConfig::paper_8x8();
    let inst = mesh_opt(&sys, true);
    let tm = lower_id(model, &mapping, &sys, 32).unwrap();
    (sys, inst, tm)
}

#[test]
fn allreduce_volume_is_algorithm_invariant() {
    for model in [ModelId::LeNet, ModelId::CdbNet] {
        let grad = model.spec().total_weight_bytes();
        for chips in [2usize, 4, 8, 16] {
            let want = wire_bytes_per_chip(chips, grad);
            // the closed form itself: floor(2*(N-1)*V/N)
            let closed = 2u128 * (chips as u128 - 1) * grad as u128 / chips as u128;
            assert_eq!(want as u128, closed, "{model} chips={chips}");
            for alg in [Collective::Ring, Collective::Tree, Collective::Hierarchical] {
                if alg == Collective::Hierarchical && chips % 2 != 0 {
                    continue;
                }
                let total: u64 = steps(alg, chips, grad).iter().map(|s| s.bytes).sum();
                assert_eq!(
                    total, want,
                    "{model} {alg} chips={chips}: steps move {total}, want {want}"
                );
            }
        }
    }
}

#[test]
fn single_chip_fabric_is_byte_identical_for_paper_models() {
    for model in [ModelId::LeNet, ModelId::CdbNet] {
        let grad = model.spec().total_weight_bytes();
        let (sys, inst, tm) = paper_setup(&model, MappingPolicy::default());
        let cfg = TraceConfig { scale: 0.05, ..Default::default() };
        for policy in [SchedulePolicy::Serial, SchedulePolicy::GPipe { microbatches: 4 }] {
            let fr = run_fabric(&sys, &inst, &tm, &policy, &Fabric::single(), grad, &cfg)
                .unwrap();
            let sr = run_schedule(&sys, &inst, &tm, &policy, &cfg).unwrap();
            assert_eq!(
                fingerprint(&fr.schedule.sim),
                fingerprint(&sr.sim),
                "{model} {policy}"
            );
            assert_eq!(fr.schedule.makespan, sr.makespan);
            assert_eq!(fr.iteration_cycles, sr.makespan);
            assert_eq!(fr.wire_bytes_per_chip, 0);
            assert_eq!(fr.comm_overhead_pct, 0.0);
        }
    }
}

#[test]
fn fabric_lowering_is_thread_count_invariant() {
    // The scale_figs sweep fans (chips x algorithm) run_fabric jobs out
    // over par_map (WIHETNOC_THREADS); index-derived seeds make every
    // job self-contained, so fingerprints must match at any worker
    // count — and across repeat runs.
    let model = ModelId::LeNet;
    let grad = model.spec().total_weight_bytes();
    let (sys, inst, tm) = paper_setup(&model, MappingPolicy::LayerPipelined { stages: 2 });
    let jobs: Vec<Fabric> = [
        (1usize, Collective::Auto),
        (2, Collective::Ring),
        (4, Collective::Ring),
        (4, Collective::Tree),
        (8, Collective::Hierarchical),
    ]
    .into_iter()
    .map(|(chips, collective)| Fabric { collective, ..Fabric::new(chips) })
    .collect();
    let policy = SchedulePolicy::OneFOneB { microbatches: 4 };
    let run_all = |threads: usize| {
        par_map_threads(threads, &jobs, |i, fabric| {
            let cfg = TraceConfig { scale: 0.02, seed: 0xFAB + i as u64, ..Default::default() };
            let fr = run_fabric(&sys, &inst, &tm, &policy, fabric, grad, &cfg).unwrap();
            (
                fingerprint(&fr.schedule.sim),
                fr.iteration_cycles,
                fr.wire_cycles,
                format!("{:.9}", fr.comm_overhead_pct),
            )
        })
    };
    let serial = run_all(1);
    assert_eq!(run_all(1), serial, "repeat runs must match");
    for threads in [2, 8] {
        assert_eq!(run_all(threads), serial, "thread count {threads} diverged");
    }
}

#[test]
fn invalid_fabric_is_a_typed_error_listing_the_grammar() {
    for bad in ["", "0", "2000", "4:topo=star", "4:alpha=fast", "4:beta=0GBps", "x"] {
        let e = bad.parse::<Fabric>().unwrap_err();
        assert!(matches!(e, WihetError::InvalidArg(_)), "{bad}: {e:?}");
        let msg = e.to_string();
        for hint in ["<chips>", "alpha=", "beta=", "ring|tree|hierarchical|auto"] {
            assert!(msg.contains(hint), "'{bad}' error missing '{hint}': {msg}");
        }
    }
    // an odd hierarchical fabric fails validation at the parse boundary
    for odd in ["3:topo=hierarchical", "5:topo=hierarchical"] {
        let e = odd.parse::<Fabric>().unwrap_err();
        assert!(e.to_string().contains("even"), "{e}");
    }
}

//! Design-search observability integration tests (ISSUE 9):
//!
//! * attaching a search observer never perturbs the design — the
//!   designed NoC (topology edges, WI placement) is byte-identical with
//!   and without a sink;
//! * the recorded [`SearchTrace`] is byte-identical when the per-k
//!   wireline fan-out (the `Ctx::wirelines` pattern) runs on 1/2/8
//!   workers sharing one sink — the canonical stage order makes
//!   recording commutative;
//! * `Ctx::observe_search` surfaces the `placement` and `wireline:k*`
//!   stages end to end, and the exported document passes the schema
//!   validator (the Rust-side mirror of the CI jq smoke).

use std::collections::BTreeSet;

use wihetnoc::experiments::{Ctx, Effort};
use wihetnoc::model::SystemConfig;
use wihetnoc::noc::builder::{
    generic_many_to_few, optimize_wireline, DesignConfig, NocDesigner, NocKind,
};
use wihetnoc::telemetry::{search_sink, sink_trace, validate_search_trace};
use wihetnoc::util::exec::par_map_threads;
use wihetnoc::util::json;

/// Fingerprint of a designed NoC: wireline edges + WI placement.
fn fingerprint(inst: &wihetnoc::noc::builder::NocInstance) -> String {
    let wis: Vec<(usize, usize)> =
        inst.air.wis.iter().map(|w| (w.router, w.channel)).collect();
    format!("{:?}|{:?}", inst.topo.edges(), wis)
}

#[test]
fn observer_is_neutral_through_the_designer() {
    let plain = NocDesigner::new(SystemConfig::small_4x4()).build().unwrap();
    let sink = search_sink();
    let observed = NocDesigner::new(SystemConfig::small_4x4())
        .observe(sink.clone())
        .build()
        .unwrap();
    assert_eq!(
        fingerprint(&plain),
        fingerprint(&observed),
        "observer changed the designed NoC"
    );

    // ... while actually recording the two WiHetNoC search passes
    let trace = sink_trace(&sink);
    let keys: Vec<String> = trace.stages().iter().map(|s| s.stage.clone()).collect();
    let k = NocDesigner::new(SystemConfig::small_4x4()).config().k_max;
    assert_eq!(keys, vec![format!("wireline:k{k}"), "wireless".to_string()]);
    assert!(trace.total_evals() > 0);
    let wl = trace.stage(&format!("wireline:k{k}")).unwrap();
    assert!(!wl.levels.is_empty(), "AMOSA stage carries level snapshots");
    validate_search_trace(&trace.to_json()).unwrap();

    // mesh architectures run no search: the sink stays empty
    let mesh_sink = search_sink();
    NocDesigner::new(SystemConfig::small_4x4())
        .kind(NocKind::MeshXy)
        .observe(mesh_sink.clone())
        .build()
        .unwrap();
    assert!(sink_trace(&mesh_sink).is_empty());
}

#[test]
fn shared_sink_trace_is_byte_identical_across_worker_counts() {
    // Mirror Ctx::wirelines' per-k fan-out: independent AMOSA runs with
    // derived seeds, all recording into one shared sink.
    let sys = SystemConfig::small_4x4();
    let fij = generic_many_to_few(&sys);
    let seed = 7u64;
    let k_maxes = [4usize, 5, 6];
    let run = |threads: usize| {
        let sink = search_sink();
        par_map_threads(threads, &k_maxes, |_, &k_max| {
            let mut cfg = DesignConfig::quick(seed.wrapping_add(k_max as u64));
            cfg.k_max = k_max;
            cfg.observer = Some(sink.clone());
            optimize_wireline(&sys, &fij, &cfg).edges()
        });
        sink_trace(&sink)
    };
    let serial = run(1);
    let serial_doc = serial.to_json().dump();
    assert_eq!(serial.stages().len(), k_maxes.len());
    validate_search_trace(&serial.to_json()).unwrap();
    for threads in [2usize, 8] {
        let doc = run(threads).to_json().dump();
        assert_eq!(doc, serial_doc, "trace differs at {threads} workers");
    }
    // every per-k stage is present exactly once
    let keys: BTreeSet<&str> =
        serial.stages().iter().map(|s| s.stage.as_str()).collect();
    assert_eq!(
        keys,
        BTreeSet::from(["wireline:k4", "wireline:k5", "wireline:k6"])
    );
}

#[test]
fn ctx_observe_search_surfaces_placement_and_wireline_stages() {
    // unobserved reference: the Ctx-derived designs must not move
    let mut plain = Ctx::new(Effort::Quick, 3);
    let ref_sys = plain.mesh_sys().tiles.clone();
    let ref_topo = plain.wireline(4).edges();

    let mut ctx = Ctx::new(Effort::Quick, 3);
    let sink = search_sink();
    ctx.observe_search(sink.clone());
    assert_eq!(ctx.mesh_sys().tiles, ref_sys, "observed placement drifted");
    assert_eq!(ctx.wireline(4).edges(), ref_topo, "observed wireline drifted");

    let trace = sink_trace(&sink);
    let pl = trace.stage("placement").expect("placement stage recorded");
    assert!(pl.evals > 0 && !pl.levels.is_empty());
    let wl = trace.stage("wireline:k4").expect("wireline stage recorded");
    assert!(wl.evals > 0);
    // hypervolume series is monotone non-decreasing (validator checks),
    // and the document round-trips the hand-rolled JSON parser
    let doc = trace.to_json();
    validate_search_trace(&doc).unwrap();
    validate_search_trace(&json::parse(&doc.dump()).unwrap()).unwrap();

    // cache hits never re-run the search or grow the trace
    let before = sink_trace(&sink).stages().len();
    let _ = ctx.mesh_sys();
    let _ = ctx.wireline(4);
    assert_eq!(sink_trace(&sink).stages().len(), before);
}

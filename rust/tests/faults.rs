//! Fault-injection guarantees (ISSUE 7):
//!
//! * **None-identity** — `FaultPlan::none()` delegates byte-identically
//!   to the fault-free entry points: lenet serial, pipelined alexnet,
//!   and a 4-chip fabric all fingerprint the same through the `_faults`
//!   variants, with every resilience counter zero.
//! * **Determinism** — a seeded random kill plan (`wire:rate=..,seed=..`)
//!   compiles and simulates byte-identically across repeat runs and
//!   across 1/2/8 `par_map` workers.
//! * **Repair** — a single wireline link fault on a topology whose
//!   residual is still connected delivers every message: the repaired
//!   route set leaves nothing undeliverable.
//! * **Graceful degradation** — jamming wireless channels never *beats*
//!   the fault-free network: the MAC retries then falls back to
//!   wireline, which can only cost latency.
//! * **Typed errors** — malformed plans are `WihetError::InvalidArg`
//!   carrying the fault-plan grammar, never a panic.

use wihetnoc::fabric::{run_fabric, run_fabric_faults, Collective, Fabric};
use wihetnoc::faults::ResilienceStats;
use wihetnoc::model::SystemConfig;
use wihetnoc::noc::builder::{mesh_opt, wi_het_noc_quick, NocInstance};
use wihetnoc::noc::sim::{NocSim, SimConfig, SimReport};
use wihetnoc::schedule::{run_schedule, run_schedule_faults, SchedulePolicy};
use wihetnoc::traffic::trace::{training_trace, TraceConfig};
use wihetnoc::util::exec::par_map_threads;
use wihetnoc::workload::{lower_id, MappingPolicy};
use wihetnoc::{FaultPlan, ModelId, WihetError};

/// Everything a `SimReport` aggregates, as one comparable value —
/// including the resilience counters the fault hooks feed.
fn fingerprint(r: &SimReport) -> (u64, u64, u64, String, Vec<u64>, Vec<u64>, ResilienceStats) {
    (
        r.delivered_packets,
        r.delivered_flits,
        r.cycles,
        format!(
            "{:.9}/{:.9}/{:.9}/{:.9}",
            r.latency.sum, r.latency.max, r.cpu_mc_latency.sum, r.gpu_mc_latency.sum
        ),
        r.link_busy.clone(),
        r.link_flits.clone(),
        r.resilience.clone(),
    )
}

fn paper_setup(
    model: &ModelId,
    mapping: MappingPolicy,
) -> (SystemConfig, NocInstance, wihetnoc::traffic::phases::TrafficModel) {
    let sys = SystemConfig::paper_8x8();
    let inst = mesh_opt(&sys, true);
    let tm = lower_id(model, &mapping, &sys, 32).unwrap();
    (sys, inst, tm)
}

// ------------------------------------------------------ none-identity

#[test]
fn none_plan_is_byte_identical_to_fault_free_runs() {
    let none = FaultPlan::none();
    // lenet, serial, default mapping
    let (sys, inst, tm) = paper_setup(&ModelId::LeNet, MappingPolicy::default());
    let cfg = TraceConfig { scale: 0.05, ..Default::default() };
    let clean = run_schedule(&sys, &inst, &tm, &SchedulePolicy::Serial, &cfg).unwrap();
    let faulted =
        run_schedule_faults(&sys, &inst, &tm, &SchedulePolicy::Serial, &cfg, &none).unwrap();
    assert_eq!(fingerprint(&faulted.sim), fingerprint(&clean.sim), "lenet serial");
    assert_eq!(faulted.makespan, clean.makespan);
    assert_eq!(*faulted.resilience(), ResilienceStats::default());

    // alexnet, pipelined + overlapped microbatches
    let model: ModelId = "alexnet".parse().unwrap();
    let (sys, inst, tm) = paper_setup(&model, MappingPolicy::LayerPipelined { stages: 4 });
    let cfg = TraceConfig { scale: 0.01, ..Default::default() };
    let policy = SchedulePolicy::GPipe { microbatches: 4 };
    let clean = run_schedule(&sys, &inst, &tm, &policy, &cfg).unwrap();
    let faulted = run_schedule_faults(&sys, &inst, &tm, &policy, &cfg, &none).unwrap();
    assert_eq!(fingerprint(&faulted.sim), fingerprint(&clean.sim), "pipelined alexnet");
    assert_eq!(faulted.makespan, clean.makespan);
}

#[test]
fn none_plan_is_byte_identical_through_the_fabric() {
    let model = ModelId::LeNet;
    let grad = model.spec().total_weight_bytes();
    let (sys, inst, tm) = paper_setup(&model, MappingPolicy::LayerPipelined { stages: 2 });
    let cfg = TraceConfig { scale: 0.02, ..Default::default() };
    let fabric = Fabric { collective: Collective::Ring, ..Fabric::new(4) };
    let policy = SchedulePolicy::OneFOneB { microbatches: 4 };
    let clean = run_fabric(&sys, &inst, &tm, &policy, &fabric, grad, &cfg).unwrap();
    let faulted = run_fabric_faults(
        &sys, &inst, &tm, &policy, &fabric, grad, &cfg, &FaultPlan::none(),
    )
    .unwrap();
    assert_eq!(fingerprint(&faulted.schedule.sim), fingerprint(&clean.schedule.sim));
    assert_eq!(faulted.iteration_cycles, clean.iteration_cycles);
    assert_eq!(faulted.wire_cycles, clean.wire_cycles);
    assert_eq!(faulted.resilience, ResilienceStats::default());
}

// -------------------------------------------------------- determinism

#[test]
fn seeded_random_plans_are_thread_count_invariant() {
    let sys = SystemConfig::paper_8x8();
    let inst = wi_het_noc_quick(&sys, 11);
    let model = ModelId::LeNet;
    let tm = lower_id(&model, &MappingPolicy::default(), &sys, 32).unwrap();
    // one job per (rate, seed): each compiles its own plan and runs a
    // faulted sim, exactly like an experiment sweep fans out
    let jobs: Vec<FaultPlan> = [(1u32, 3u64), (2, 3), (3, 7), (5, 7), (8, 11)]
        .into_iter()
        .map(|(pct, seed)| {
            format!("wire:rate=0.0{pct},seed={seed}").parse::<FaultPlan>().unwrap()
        })
        .collect();
    let run_all = |threads: usize| {
        par_map_threads(threads, &jobs, |i, plan| {
            let cfg = TraceConfig { scale: 0.02, seed: 0xFA + i as u64, ..Default::default() };
            let fx = plan
                .compile(&inst.topo, &inst.routes, &inst.air, SimConfig::default().nominal_flits)
                .unwrap();
            let (trace, _) = training_trace(&sys, &tm.phases, &cfg);
            let sim = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
                .with_faults(&fx);
            fingerprint(&sim.run(&trace))
        })
    };
    let serial = run_all(1);
    assert_eq!(run_all(1), serial, "repeat runs must match");
    for threads in [2, 8] {
        assert_eq!(run_all(threads), serial, "thread count {threads} diverged");
    }
}

// ------------------------------------------------------------- repair

#[test]
fn single_link_fault_on_connected_residual_delivers_everything() {
    let model = ModelId::LeNet;
    let cfg = TraceConfig { scale: 0.05, ..Default::default() };
    let sys = SystemConfig::paper_8x8();
    for (name, inst) in
        [("mesh_opt", mesh_opt(&sys, true)), ("wihetnoc", wi_het_noc_quick(&sys, 11))]
    {
        let tm = lower_id(&model, &MappingPolicy::default(), &sys, 32).unwrap();
        let clean = run_schedule(&sys, &inst, &tm, &SchedulePolicy::Serial, &cfg).unwrap();
        let step = inst.topo.links.len() / 5;
        for link in (0..inst.topo.links.len()).step_by(step.max(1)) {
            let mut dead = vec![false; inst.topo.links.len()];
            dead[link] = true;
            if !inst.topo.connected_without(&dead) {
                continue; // a cut link may legitimately strand traffic
            }
            let plan: FaultPlan = format!("wire:link={link}").parse().unwrap();
            let sr = run_schedule_faults(&sys, &inst, &tm, &SchedulePolicy::Serial, &cfg, &plan)
                .unwrap();
            assert_eq!(sr.sim.undeliverable, 0, "{name} link {link}: repair must reach everyone");
            assert_eq!(sr.sim.resilience.undeliverable_after_repair, 0, "{name} link {link}");
            assert_eq!(
                sr.sim.delivered_packets, clean.sim.delivered_packets,
                "{name} link {link}: every packet still arrives"
            );
            assert_eq!(sr.sim.resilience.faults_injected, 1, "{name} link {link}");
            assert_eq!(sr.sim.link_flits[link], 0, "{name} link {link} is dead from cycle 0");
        }
    }
}

// ----------------------------------------------- graceful degradation

#[test]
fn jammed_channels_never_beat_the_fault_free_network() {
    let sys = SystemConfig::paper_8x8();
    let inst = wi_het_noc_quick(&sys, 11);
    assert!(inst.air.num_channels > 0, "WiHetNoC instance must carry WIs");
    let tm = lower_id(&ModelId::LeNet, &MappingPolicy::default(), &sys, 32).unwrap();
    let cfg = TraceConfig { scale: 0.05, ..Default::default() };
    let clean = run_schedule(&sys, &inst, &tm, &SchedulePolicy::Serial, &cfg).unwrap();
    // jam every channel the NoC has, for (effectively) the whole run
    let plan: FaultPlan = (0..inst.air.num_channels)
        .map(|c| format!("air:ch={c},burst=1000000000"))
        .collect::<Vec<_>>()
        .join(";")
        .parse()
        .unwrap();
    let jam = run_schedule_faults(&sys, &inst, &tm, &SchedulePolicy::Serial, &cfg, &plan).unwrap();
    // conservation: the degraded network still delivers every flit
    assert_eq!(jam.sim.delivered_packets, clean.sim.delivered_packets);
    assert_eq!(jam.sim.delivered_flits, clean.sim.delivered_flits);
    assert_eq!(jam.sim.undeliverable, 0);
    // degradation is graceful, not free: latency never improves
    assert!(
        jam.sim.latency.mean() >= clean.sim.latency.mean(),
        "jammed mean latency {} beat clean {}",
        jam.sim.latency.mean(),
        clean.sim.latency.mean()
    );
    if clean.sim.air_packets > 0 {
        // the retry/fallback machinery actually fired ...
        assert!(jam.sim.resilience.retries > 0, "no carrier-sense retries recorded");
        assert!(jam.sim.resilience.fallback_flits > 0, "no wireline fallbacks recorded");
        // ... and the jammed channels carried nothing
        assert_eq!(jam.sim.air_flits.iter().sum::<u64>(), 0);
    }
}

// ------------------------------------------------------- typed errors

#[test]
fn malformed_plans_are_typed_errors_carrying_the_grammar() {
    for bad in [
        "bogus:x=1",
        "wire:rate=1.5",
        "wire:link=1,rate=0.5",
        "air:ch=1",
        "air:ch=1,burst=0",
        "chip:n=0",
        "chip:n=1,drop=40",
        "wire:rate=0.1;wire:rate=0.2",
    ] {
        let e = bad.parse::<FaultPlan>().unwrap_err();
        assert!(matches!(e, WihetError::InvalidArg(_)), "'{bad}': {e:?}");
        assert!(
            e.to_string().contains("fault plan grammar"),
            "'{bad}' error must carry the grammar: {e}"
        );
    }
    // structurally valid plans still fail against a concrete topology
    let sys = SystemConfig::paper_8x8();
    let inst = mesh_opt(&sys, true);
    let plan: FaultPlan = "wire:link=99999".parse().unwrap();
    let e = plan
        .compile(&inst.topo, &inst.routes, &inst.air, SimConfig::default().nominal_flits)
        .unwrap_err();
    assert!(e.to_string().contains("out of range"), "{e}");
}

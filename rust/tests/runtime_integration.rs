//! Runtime integration: load real AOT artifacts, execute them via PJRT,
//! and train. Requires `make artifacts`; every test skips cleanly (with a
//! loud message) when artifacts are missing so `cargo test` stays green on
//! a fresh checkout.

use wihetnoc::coordinator::{TrainConfig, Trainer};
use wihetnoc::model::{cdbnet, lenet};
use wihetnoc::runtime::Runtime;
use wihetnoc::WihetError;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

/// Artifacts present *and* real PJRT bindings linked; skips loudly when
/// the build uses the vendored `xla` stub (see rust/vendor/xla).
fn runtime_for_tests() -> Option<Runtime> {
    let dir = artifacts_dir()?;
    match Runtime::new(dir) {
        Ok(rt) => Some(rt),
        Err(WihetError::RuntimeUnavailable(m)) => {
            eprintln!("SKIP: {m} — swap rust/vendor/xla for xla-rs to run PJRT tests");
            None
        }
        Err(e) => panic!("runtime init failed: {e}"),
    }
}

#[test]
fn micro_gemm_round_trip() {
    let Some(mut rt) = runtime_for_tests() else { return };
    assert_eq!(rt.platform(), "cpu");
    // matmul_micro: (8x8) @ (8x8) + 1
    let eye: Vec<f32> = (0..64).map(|i| if i % 9 == 0 { 1.0 } else { 0.0 }).collect();
    let x: Vec<f32> = (0..64).map(|i| i as f32).collect();
    let out = rt.run("matmul_micro", &[x.clone(), eye]).unwrap();
    assert_eq!(out.len(), 1);
    let want: Vec<f32> = x.iter().map(|v| v + 1.0).collect();
    assert_eq!(out[0], want);
}

#[test]
fn manifest_matches_rust_model_derivation() {
    // manifest-only: runs even against the xla stub
    let Some(dir) = artifacts_dir() else { return };
    let manifest = wihetnoc::runtime::Manifest::load(&dir).unwrap();
    for spec in [lenet(), cdbnet()] {
        let meta = manifest.model(&spec.name).unwrap();
        assert_eq!(meta.layers.len(), spec.layers.len(), "{}", spec.name);
        for (m, l) in meta.layers.iter().zip(&spec.layers) {
            assert_eq!(m.name, l.name);
            assert_eq!(m.kind, l.kind.as_str());
            assert_eq!(
                m.out_shape,
                vec![l.out_shape.0, l.out_shape.1, l.out_shape.2],
                "{} {}",
                spec.name,
                l.name
            );
            assert_eq!(m.weight_bytes, l.weight_bytes(), "{} {}", spec.name, l.name);
            assert_eq!(m.macs, l.macs(manifest.batch), "{} {}", spec.name, l.name);
            assert_eq!(m.in_bytes, l.in_bytes(manifest.batch), "{} {}", spec.name, l.name);
        }
    }
}

#[test]
fn lenet_forward_runs() {
    let Some(mut rt) = runtime_for_tests() else { return };
    let batch = rt.manifest.batch;
    let spec = lenet();
    let params = wihetnoc::coordinator::trainer::init_params(&spec, 42);
    let mut args = params;
    args.push(vec![0.1f32; batch * 33 * 33]);
    let out = rt.run("lenet_forward", &args).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), batch * 10);
    assert!(out[0].iter().all(|v| v.is_finite()));
}

#[test]
fn lenet_training_reduces_loss() {
    let Some(mut rt) = runtime_for_tests() else { return };
    let batch = rt.manifest.batch;
    let mut trainer = Trainer::new(&mut rt, lenet(), 7).unwrap();
    let cfg = TrainConfig { steps: 30, batch, seed: 11, log_every: 5 };
    let log = trainer.train(&cfg).unwrap();
    assert!(log.first_loss().is_finite());
    assert!(
        log.tail_mean(2) < log.first_loss(),
        "loss {} -> {}",
        log.first_loss(),
        log.tail_mean(2)
    );
}

#[test]
fn wrong_arity_and_shape_rejected() {
    let Some(mut rt) = runtime_for_tests() else { return };
    assert!(rt.run("matmul_micro", &[vec![0.0f32; 64]]).is_err());
    assert!(rt
        .run("matmul_micro", &[vec![0.0f32; 64], vec![0.0f32; 63]])
        .is_err());
    assert!(rt.run("no_such_entry", &[]).is_err());
}

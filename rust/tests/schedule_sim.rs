//! Schedule-subsystem guarantees (ISSUE 4):
//!
//! * **Serial identity** — `--schedule serial` produces byte-identical
//!   `SimReport`s to the pre-schedule trace pipeline, for the paper
//!   models and for a pipelined non-paper workload on a non-paper
//!   platform.
//! * **Conservation** — `gpipe:M`/`1f1b:M` timelines move exactly the
//!   bytes (and control flits) of the serial lowering; only the timing
//!   changes.
//! * **Overlap** — `makespan(gpipe:M) <= makespan(serial)`: overlapping
//!   microbatches never run longer than back-to-back phases.
//! * **Determinism** — scheduled simulation fingerprints are identical
//!   across repeat runs and across 1/2/8 `par_map` workers.
//! * **Typed errors** — an unknown `--schedule` value is a
//!   `WihetError` carrying the schedule grammar, never a panic.

use wihetnoc::model::SystemConfig;
use wihetnoc::noc::builder::{mesh_opt, NocInstance};
use wihetnoc::noc::sim::{NocSim, SimConfig, SimReport};
use wihetnoc::schedule::{expand, run_schedule, SchedulePolicy};
use wihetnoc::traffic::trace::{training_trace, TraceConfig};
use wihetnoc::util::exec::par_map_threads;
use wihetnoc::workload::{lower_id, MappingPolicy};
use wihetnoc::{Effort, ModelId, Platform, Scenario, WihetError};

/// Everything a `SimReport` aggregates, as one comparable value.
fn fingerprint(r: &SimReport) -> (u64, u64, u64, String, Vec<u64>, Vec<u64>) {
    (
        r.delivered_packets,
        r.delivered_flits,
        r.cycles,
        format!(
            "{:.9}/{:.9}/{:.9}/{:.9}",
            r.latency.sum, r.latency.max, r.cpu_mc_latency.sum, r.gpu_mc_latency.sum
        ),
        r.link_busy.clone(),
        r.link_flits.clone(),
    )
}

fn paper_setup(model: &ModelId, mapping: MappingPolicy) -> (SystemConfig, NocInstance, wihetnoc::traffic::phases::TrafficModel) {
    let sys = SystemConfig::paper_8x8();
    let inst = mesh_opt(&sys, true);
    let tm = lower_id(model, &mapping, &sys, 32).unwrap();
    (sys, inst, tm)
}

#[test]
fn serial_schedule_is_byte_identical_for_paper_models() {
    for model in [ModelId::LeNet, ModelId::CdbNet] {
        let (sys, inst, tm) = paper_setup(&model, MappingPolicy::default());
        let cfg = TraceConfig { scale: 0.05, ..Default::default() };
        let sr = run_schedule(&sys, &inst, &tm, &SchedulePolicy::Serial, &cfg).unwrap();
        // the pre-schedule pipeline: one trace, phases back to back
        let (trace, _) = training_trace(&sys, &tm.phases, &cfg);
        let legacy = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
            .run(&trace);
        assert_eq!(fingerprint(&sr.sim), fingerprint(&legacy), "{model}");
        assert_eq!(sr.makespan, legacy.cycles);
        assert_eq!(sr.speedup_vs_serial, 1.0);
        assert_eq!(sr.bubble_fraction, 0.0);
    }
}

#[test]
fn serial_schedule_is_byte_identical_for_pipelined_alexnet_on_12x12() {
    let platform: Platform = "12x12:cpus=8,mcs=8,placement=corners".parse().unwrap();
    let sys = platform.build().unwrap();
    let inst = mesh_opt(&sys, true);
    let model: ModelId = "alexnet".parse().unwrap();
    let tm = lower_id(&model, &MappingPolicy::LayerPipelined { stages: 4 }, &sys, 32).unwrap();
    let cfg = TraceConfig { scale: 0.005, ..Default::default() };
    let sr = run_schedule(&sys, &inst, &tm, &SchedulePolicy::Serial, &cfg).unwrap();
    let (trace, _) = training_trace(&sys, &tm.phases, &cfg);
    let legacy =
        NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default()).run(&trace);
    assert_eq!(fingerprint(&sr.sim), fingerprint(&legacy));
}

#[test]
fn overlapped_schedules_conserve_serial_volumes() {
    for model in [ModelId::LeNet, ModelId::CdbNet, "alexnet".parse().unwrap()] {
        for mapping in [MappingPolicy::default(), MappingPolicy::LayerPipelined { stages: 3 }] {
            let (_, _, tm) = paper_setup(&model, mapping);
            for policy in [
                SchedulePolicy::GPipe { microbatches: 8 },
                SchedulePolicy::OneFOneB { microbatches: 8 },
            ] {
                let tl = expand(&tm, &policy).unwrap();
                assert_eq!(tl.total_bytes(), tm.total_bytes(), "{model} {mapping} {policy}");
                let serial_cc: u64 = tm.phases.iter().map(|p| p.core_core_flits).sum();
                assert_eq!(tl.total_core_core_flits(), serial_cc, "{model} {mapping} {policy}");
                assert_eq!(tl.instances.len(), tm.phases.len() * 8);
            }
        }
    }
}

#[test]
fn gpipe_makespan_never_exceeds_serial() {
    let (sys, inst, tm) =
        paper_setup(&ModelId::LeNet, MappingPolicy::LayerPipelined { stages: 2 });
    let cfg = TraceConfig { scale: 0.1, ..Default::default() };
    let serial = run_schedule(&sys, &inst, &tm, &SchedulePolicy::Serial, &cfg).unwrap();
    for m in [2usize, 4, 8] {
        let gp =
            run_schedule(&sys, &inst, &tm, &SchedulePolicy::GPipe { microbatches: m }, &cfg)
                .unwrap();
        assert!(
            gp.makespan <= serial.makespan,
            "gpipe:{m} makespan {} exceeds serial {}",
            gp.makespan,
            serial.makespan
        );
        assert_eq!(gp.sim.undelivered(), 0, "gpipe:{m} lost traffic");
        // conservation carries through simulation: every flit of every
        // microbatch is delivered
        assert!(gp.sim.delivered_packets > 0);
        assert!((0.0..=1.0).contains(&gp.bubble_fraction));
        assert!(gp.peak_link_concurrency >= 1);
    }
}

#[test]
fn scheduled_simulation_is_thread_count_invariant() {
    // Schedule runs fan out across experiment sweeps via par_map; the
    // per-job seeds are index-derived, so reports must be identical at
    // any worker count — and across repeat runs.
    let (sys, inst, tm) =
        paper_setup(&ModelId::LeNet, MappingPolicy::LayerPipelined { stages: 2 });
    let jobs: Vec<SchedulePolicy> = vec![
        SchedulePolicy::Serial,
        SchedulePolicy::GPipe { microbatches: 2 },
        SchedulePolicy::GPipe { microbatches: 4 },
        SchedulePolicy::OneFOneB { microbatches: 4 },
        SchedulePolicy::OneFOneB { microbatches: 8 },
    ];
    let run_all = |threads: usize| {
        par_map_threads(threads, &jobs, |i, policy| {
            let cfg = TraceConfig { scale: 0.05, seed: 0x5CED + i as u64, ..Default::default() };
            let sr = run_schedule(&sys, &inst, &tm, policy, &cfg).unwrap();
            (fingerprint(&sr.sim), sr.makespan, sr.peak_link_concurrency)
        })
    };
    let serial = run_all(1);
    assert_eq!(run_all(1), serial, "repeat runs must match");
    for threads in [2, 8] {
        assert_eq!(run_all(threads), serial, "thread count {threads} diverged");
    }
}

#[test]
fn unknown_schedule_is_a_typed_error_listing_the_grammar() {
    let e = "rings:4".parse::<SchedulePolicy>().unwrap_err();
    assert!(matches!(e, WihetError::InvalidArg(_)), "{e:?}");
    let msg = e.to_string();
    for hint in ["serial", "gpipe:<M>", "1f1b:<M>"] {
        assert!(msg.contains(hint), "missing '{hint}' in: {msg}");
    }
    // malformed counts are typed too
    assert!("gpipe:zero".parse::<SchedulePolicy>().is_err());
    assert!("gpipe:0".parse::<SchedulePolicy>().is_err());
    // and a schedule that does not fit the batch fails at the boundary
    let sc = Scenario::new("8x8".parse().unwrap(), ModelId::LeNet)
        .with_schedule(SchedulePolicy::GPipe { microbatches: 64 })
        .with_effort(Effort::Quick);
    let e = match wihetnoc::experiments::Ctx::for_scenario(&sc) {
        Err(e) => e,
        Ok(_) => panic!("an oversubscribed schedule must fail at the boundary"),
    };
    assert!(e.to_string().contains("batch size 32"), "{e}");
}

//! `cargo bench --bench paper_benches` — regenerates every table and
//! figure of the paper's evaluation section and reports the wall time of
//! each harness. The printed series are the reproduction artifacts
//! recorded in EXPERIMENTS.md.
//!
//! Effort is controlled by WIHETNOC_BENCH_EFFORT=quick|full (default
//! quick, so `cargo bench` completes in minutes; EXPERIMENTS.md numbers
//! use full).

use wihetnoc::bench::Bencher;
use wihetnoc::experiments::{self, Ctx, Effort};
use wihetnoc::noc::builder::NocKind;

fn main() {
    let effort = match std::env::var("WIHETNOC_BENCH_EFFORT").as_deref() {
        Ok("full") => Effort::Full,
        _ => Effort::Quick,
    };
    let seed = 42;
    println!("== paper benches (effort {effort:?}, seed {seed}) ==\n");
    let mut ctx = Ctx::new(effort, seed);
    let mut b = Bencher::quick();
    // Warm the expensive caches once so per-figure timings reflect the
    // harness, not the shared design step.
    let _ = ctx.instance(NocKind::MeshXyYx);
    let _ = ctx.instance(NocKind::HetNoc);
    let _ = ctx.instance(NocKind::WiHetNoc);

    for id in experiments::ALL {
        let mut report = String::new();
        b.bench(&format!("experiment/{id}"), || {
            report = experiments::run(id, &mut ctx).expect("experiment runs");
        });
        println!("\n{report}\n{}\n", "-".repeat(72));
    }
    println!("== done: {} experiments ==", experiments::ALL.len());
}

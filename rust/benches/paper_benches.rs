//! `cargo bench --bench paper_benches` — regenerates every table and
//! figure of the paper's evaluation section and reports the wall time of
//! each harness. The printed series are the reproduction artifacts
//! recorded in EXPERIMENTS.md.
//!
//! Effort is controlled by WIHETNOC_BENCH_EFFORT=quick|full (default
//! quick, so `cargo bench` completes in minutes; EXPERIMENTS.md numbers
//! use full).
//!
//! Every run also updates `BENCH_sim.json` (override the path with
//! WIHETNOC_BENCH_JSON) with per-experiment medians/MADs plus sim-core
//! microbenches, keyed by WIHETNOC_BENCH_LABEL (default `current`).
//! Since the experiments return typed `Report`s, the run also records
//! every report's scalar sections (the paper-claim measurements) under
//! the `figures` key — the trajectory tracks numbers, not prose.
//! Record the pre-change numbers under the `baseline` label:
//!
//! ```sh
//! WIHETNOC_BENCH_LABEL=baseline cargo bench --bench paper_benches  # before
//! cargo bench --bench paper_benches                                # after
//! ```

use std::collections::BTreeMap;

use wihetnoc::bench::{merge_run, Bencher};
use wihetnoc::experiments::{self, Ctx, Effort};
use wihetnoc::fabric::{extend_timeline, steps, Collective, Fabric};
use wihetnoc::model::SystemConfig;
use wihetnoc::noc::builder::{mesh_opt, wi_het_noc_quick, NocKind};
use wihetnoc::noc::sim::{NocSim, SimConfig, SimWorkspace};
use wihetnoc::schedule::{expand, run_schedule, SchedulePolicy};
use wihetnoc::telemetry::Telemetry;
use wihetnoc::traffic::phases::model_phases;
use wihetnoc::traffic::trace::{training_trace, TraceConfig};
use wihetnoc::util::exec::thread_count;
use wihetnoc::util::json::Json;
use wihetnoc::workload::{lower_id, MappingPolicy};
use wihetnoc::{FaultPlan, ModelId, Platform};

fn main() {
    let effort = match std::env::var("WIHETNOC_BENCH_EFFORT").as_deref() {
        Ok("full") => Effort::Full,
        _ => Effort::Quick,
    };
    let seed = 42;
    let threads = thread_count();
    println!("== paper benches (effort {effort:?}, seed {seed}, {threads} threads) ==\n");
    let mut ctx = Ctx::new(effort, seed);
    let mut b = Bencher::quick();

    // --- sim-core microbenches (workspace reuse + calendar queue) ---
    let sys = SystemConfig::paper_8x8();
    let tm = model_phases(&sys, &wihetnoc::model::lenet(), 32);
    let trace_cfg = TraceConfig { scale: 0.1, ..Default::default() };
    let (trace, _) = training_trace(&sys, &tm.phases, &trace_cfg);
    let inst = mesh_opt(&sys, true);
    let sim = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
    let packets = sim.run(&trace).delivered_packets;
    let mut ws = SimWorkspace::new();
    b.bench_items(
        &format!("simcore/iteration reuse ({packets} pkts)"),
        Some(packets as f64),
        &mut || {
            std::hint::black_box(sim.run_in(&trace, &mut ws).delivered_packets);
        },
    );
    b.bench_items(
        &format!("simcore/iteration fresh-ws ({packets} pkts)"),
        Some(packets as f64),
        &mut || {
            // allocation baseline: a brand-new workspace per run (the
            // convenience `run` path reuses a thread-local one)
            let mut fresh = SimWorkspace::new();
            std::hint::black_box(sim.run_in(&trace, &mut fresh).delivered_packets);
        },
    );

    // --- telemetry overhead pair (ISSUE 8) ---
    // same iteration with the sink detached vs attached: the off path is
    // the never-taken-branch baseline, the on path prices the histogram
    // records + time-series buckets per event
    b.bench_items(
        &format!("simcore/iteration telemetry-off ({packets} pkts)"),
        Some(packets as f64),
        &mut || {
            std::hint::black_box(sim.run_telemetry(&trace, None).delivered_packets);
        },
    );
    let mut tel = Telemetry::new();
    b.bench_items(
        &format!("simcore/iteration telemetry-on ({packets} pkts)"),
        Some(packets as f64),
        &mut || {
            std::hint::black_box(
                sim.run_telemetry(&trace, Some(&mut tel)).delivered_packets,
            );
        },
    );
    // the sink must never perturb the simulation: the instrumented runs
    // above produced the same report bytes as the plain path
    assert_eq!(
        format!("{:?}", sim.run(&trace)),
        format!("{:?}", sim.run_telemetry(&trace, Some(&mut Telemetry::new()))),
        "telemetry sink perturbed the simulation"
    );

    // --- workload lowering microbench (ISSUE 3) ---
    // a non-paper workload on a non-paper platform: alexnet lowered onto
    // a 144-tile chip under both mapping families
    let big: Platform = "12x12:cpus=8,mcs=8,placement=corners"
        .parse()
        .expect("well-formed platform");
    let big_sys = big.build().expect("12x12 builds");
    let alexnet: ModelId = "alexnet".parse().expect("preset exists");
    for mapping in [
        MappingPolicy::default(),
        MappingPolicy::LayerPipelined { stages: 4 },
    ] {
        let phases = lower_id(&alexnet, &mapping, &big_sys, 32)
            .expect("alexnet lowers on 12x12")
            .phases
            .len();
        b.bench_items(
            &format!("workload_lower/alexnet@12x12 {mapping} ({phases} phases)"),
            Some(phases as f64),
            &mut || {
                std::hint::black_box(
                    lower_id(&alexnet, &mapping, &big_sys, 32).expect("lowers").phases.len(),
                );
            },
        );
    }

    // --- schedule subsystem microbenches (ISSUE 4) ---
    // timeline expansion: alexnet on the 144-tile chip, pipelined, 8
    // microbatches — the DAG the gated simulator consumes
    let tm_big = lower_id(
        &alexnet,
        &MappingPolicy::LayerPipelined { stages: 4 },
        &big_sys,
        32,
    )
    .expect("alexnet lowers on 12x12");
    let gpipe8 = SchedulePolicy::GPipe { microbatches: 8 };
    let n_inst = expand(&tm_big, &gpipe8).expect("timeline expands").instances.len();
    b.bench_items(
        &format!("schedule_expand/alexnet@12x12 gpipe:8 ({n_inst} instances)"),
        Some(n_inst as f64),
        &mut || {
            std::hint::black_box(expand(&tm_big, &gpipe8).expect("expands").instances.len());
        },
    );
    // gated concurrent simulation: lenet pipelined on the adaptive mesh,
    // overlapping 4 microbatches — many flows in flight at once, the
    // workload the PR 2 sim core was built for
    let tm_piped = lower_id(
        &ModelId::LeNet,
        &MappingPolicy::LayerPipelined { stages: 2 },
        &sys,
        32,
    )
    .expect("lenet lowers");
    let sched_cfg = TraceConfig { scale: 0.05, ..Default::default() };
    let gpipe4 = SchedulePolicy::GPipe { microbatches: 4 };
    let sched_pkts = run_schedule(&sys, &inst, &tm_piped, &gpipe4, &sched_cfg)
        .expect("schedule runs")
        .sim
        .delivered_packets;
    b.bench_items(
        &format!("simcore/timeline gpipe:4 ({sched_pkts} pkts)"),
        Some(sched_pkts as f64),
        &mut || {
            std::hint::black_box(
                run_schedule(&sys, &inst, &tm_piped, &gpipe4, &sched_cfg)
                    .expect("schedule runs")
                    .sim
                    .delivered_packets,
            );
        },
    );

    // --- fabric subsystem microbenches (ISSUE 6) ---
    // allreduce expansion: lower a ring collective's gated instances
    // into the lenet gpipe:4 timeline (the pure DAG-building cost)
    let grad = ModelId::LeNet.spec().total_weight_bytes();
    let ring8 = steps(Collective::Ring, 8, grad);
    let fabric8 = Fabric { collective: Collective::Ring, ..Fabric::new(8) };
    let n_ar = {
        let mut tl = expand(&tm_piped, &gpipe4).expect("timeline expands");
        extend_timeline(&mut tl, &tm_piped, &sys, &fabric8, &ring8);
        tl.instances.len()
    };
    b.bench_items(
        &format!("fabric_expand/lenet gpipe:4 ring:8 ({n_ar} instances)"),
        Some(n_ar as f64),
        &mut || {
            let mut tl = expand(&tm_piped, &gpipe4).expect("expands");
            extend_timeline(&mut tl, &tm_piped, &sys, &fabric8, &ring8);
            std::hint::black_box(tl.instances.len());
        },
    );
    // full fabric lowering + gated co-simulation + alpha-beta charge:
    // one 4-chip data-parallel iteration of pipelined lenet
    let fabric4 = Fabric { collective: Collective::Ring, ..Fabric::new(4) };
    let fab_pkts = wihetnoc::fabric::run_fabric(
        &sys, &inst, &tm_piped, &gpipe4, &fabric4, grad, &sched_cfg,
    )
    .expect("fabric runs")
    .schedule
    .sim
    .delivered_packets;
    b.bench_items(
        &format!("fabric_lower/lenet gpipe:4 ring:4 ({fab_pkts} pkts)"),
        Some(fab_pkts as f64),
        &mut || {
            std::hint::black_box(
                wihetnoc::fabric::run_fabric(
                    &sys, &inst, &tm_piped, &gpipe4, &fabric4, grad, &sched_cfg,
                )
                .expect("fabric runs")
                .schedule
                .sim
                .delivered_packets,
            );
        },
    );

    // --- fault-injection microbenches (ISSUE 7) ---
    // plan compilation: seeded random kills + a jam window resolved
    // against the full WiHetNoC (includes the route-repair pass)
    let wihet = wi_het_noc_quick(&sys, 11);
    let plan: FaultPlan = "wire:rate=0.03,seed=7;air:ch=0,burst=100000"
        .parse()
        .expect("well-formed plan");
    let nominal = SimConfig::default().nominal_flits;
    let n_faults = plan
        .compile(&wihet.topo, &wihet.routes, &wihet.air, nominal)
        .expect("plan compiles")
        .faults_injected;
    b.bench_items(
        &format!("fault_inject/compile rate=0.03 ({n_faults} faults)"),
        Some(n_faults as f64),
        &mut || {
            std::hint::black_box(
                plan.compile(&wihet.topo, &wihet.routes, &wihet.air, nominal)
                    .expect("compiles")
                    .faults_injected,
            );
        },
    );
    // route repair alone: re-run the delay-weighted shortest-path /
    // ALASH pass around one dead link on each instance family
    for (name, inst_ref) in [("mesh_opt", &inst), ("wihetnoc", &wihet)] {
        let mut dead = vec![false; inst_ref.topo.links.len()];
        dead[dead.len() / 2] = true;
        let (_, pairs) = inst_ref.routes.repaired(&inst_ref.topo, &inst_ref.air, &dead, nominal);
        b.bench_items(
            &format!("route_repair/{name} 1 dead link ({pairs} pairs)"),
            Some(pairs as f64),
            &mut || {
                std::hint::black_box(
                    inst_ref.routes.repaired(&inst_ref.topo, &inst_ref.air, &dead, nominal).1,
                );
            },
        );
    }

    // --- serving microbench (ISSUE 10) ---
    // one open-loop poisson run of a two-tenant mix through the gated
    // simulator: arrival draw + continuous batching + concurrent-batch
    // contention, the serving_figs inner loop
    let mix = wihetnoc::serving::TenantMix::new(vec![ModelId::LeNet, ModelId::CdbNet]);
    let serve_spec: wihetnoc::ServingSpec =
        "poisson:rate=0.5,seed=7;batch=4,timeout=256,n=16".parse().expect("well-formed spec");
    let serve_cfg = TraceConfig { scale: 0.02, ..Default::default() };
    let served = wihetnoc::serving::run_serving(&sys, &inst, &mix, &serve_spec, &serve_cfg)
        .expect("serving runs")
        .delivered;
    b.bench_items(
        &format!("serving/poisson 2-tenant ({served} reqs)"),
        Some(served as f64),
        &mut || {
            std::hint::black_box(
                wihetnoc::serving::run_serving(&sys, &inst, &mix, &serve_spec, &serve_cfg)
                    .expect("serving runs")
                    .delivered,
            );
        },
    );

    // --- full experiment harnesses ---
    // Warm the expensive caches once so per-figure timings reflect the
    // harness, not the shared design step.
    let _ = ctx.instance(NocKind::MeshXyYx);
    let _ = ctx.instance(NocKind::HetNoc);
    let _ = ctx.instance(NocKind::WiHetNoc);

    // Each experiment returns a typed Report; its scalar sections (the
    // paper-claim measurements) are recorded in BENCH_sim.json next to
    // the wall times, so the perf trajectory also tracks paper numbers.
    let mut figures = BTreeMap::new();
    for id in experiments::ALL.iter() {
        let mut report = None;
        if matches!(
            *id,
            "workload_figs"
                | "scale_figs"
                | "resilience_figs"
                | "hotspot_figs"
                | "design_figs"
                | "serving_figs"
        ) {
            // These harnesses build their own instances per run (AMOSA
            // designs on 144 tiles, or dozens of faulted full-trace
            // sims) — repeat samples would redo identical work, so time
            // a single pass (still recorded in BENCH_sim.json).
            let mut once = Bencher { warmup: 0, samples: 1, results: Vec::new() };
            once.bench(&format!("experiment/{id}"), || {
                report = Some(experiments::run(id, &mut ctx).expect("experiment runs"));
            });
            b.results.append(&mut once.results);
        } else {
            b.bench(&format!("experiment/{id}"), || {
                report = Some(experiments::run(id, &mut ctx).expect("experiment runs"));
            });
        }
        let report = report.expect("bench ran the harness at least once");
        let scalars: BTreeMap<String, Json> = report
            .scalars()
            .filter(|(_, value)| value.is_finite())
            .map(|(name, value)| (name.to_string(), Json::Num(value)))
            .collect();
        if !scalars.is_empty() {
            figures.insert(id.to_string(), Json::Obj(scalars));
        }
        println!("\n{}\n{}\n", report.to_text(), "-".repeat(72));
    }
    println!("== done: {} experiments ==", experiments::ALL.len());

    // --- machine-readable trajectory: BENCH_sim.json ---
    let path = std::env::var("WIHETNOC_BENCH_JSON").unwrap_or_else(|_| "BENCH_sim.json".into());
    let label = std::env::var("WIHETNOC_BENCH_LABEL").unwrap_or_else(|_| "current".into());
    let run = b.to_json(&[
        ("effort", Json::Str(format!("{effort:?}").to_lowercase())),
        ("seed", Json::Num(seed as f64)),
        ("threads", Json::Num(threads as f64)),
        ("figures", Json::Obj(figures)),
    ]);
    let existing = std::fs::read_to_string(&path).unwrap_or_default();
    let doc = merge_run(&existing, &label, run);
    match std::fs::write(&path, doc.dump() + "\n") {
        Ok(()) => println!("wrote {path} (label '{label}')"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}

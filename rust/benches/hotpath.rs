//! `cargo bench --bench hotpath` — micro-benchmarks of the performance-
//! critical paths (EXPERIMENTS.md §Perf):
//!   * analytic Eqn 3-5 evaluation (the AMOSA inner loop)
//!   * AMOSA end-to-end design throughput
//!   * cycle-level simulator event throughput
//!   * route-set construction (Dijkstra + LASH)
//!   * PJRT train-step latency (skipped when artifacts/ is absent)

use wihetnoc::bench::Bencher;
use wihetnoc::model::SystemConfig;
use wihetnoc::noc::analysis::{analyze_with, AnalysisScratch};
use wihetnoc::noc::builder::{generic_many_to_few, mesh_opt, DesignConfig};
use wihetnoc::noc::routing::RouteSet;
use wihetnoc::noc::sim::{NocSim, SimConfig};
use wihetnoc::noc::topology::Topology;
use wihetnoc::optim::amosa::Amosa;
use wihetnoc::optim::linkplace::LinkPlacement;
use wihetnoc::traffic::phases::model_phases;
use wihetnoc::traffic::trace::{training_trace, TraceConfig};

fn main() {
    let mut b = Bencher::default();
    let sys = SystemConfig::paper_8x8();
    let fij = generic_many_to_few(&sys);

    // --- analytic evaluation (AMOSA inner loop) ---
    let mesh = Topology::mesh(&sys);
    let mut scratch = AnalysisScratch::new(64);
    let evals = 1000usize;
    b.bench_items("analysis/eqn3-5 x1000 (64 tiles)", Some(evals as f64), &mut || {
        for _ in 0..evals {
            let a = analyze_with(&mesh, &fij, &mut scratch);
            std::hint::black_box(a.u_mean);
        }
    });

    // --- AMOSA design throughput ---
    b.bench("amosa/quick wireline design (2.8k evals)", || {
        let cfg = DesignConfig::quick(7);
        let problem = LinkPlacement::new(&sys, &fij, 112, 6).with_max_link_mm(Some(7.6));
        let mut opt = Amosa::new(&problem, cfg.amosa.clone());
        opt.run();
        std::hint::black_box(opt.evaluations);
    });

    // --- route construction ---
    b.bench("routes/xy mesh 64", || {
        std::hint::black_box(RouteSet::xy(&sys, &mesh).num_layers);
    });
    b.bench("routes/shortest+LASH 64", || {
        std::hint::black_box(RouteSet::shortest(&mesh, Some(&fij)).num_layers);
    });

    // --- simulator throughput ---
    let tm = model_phases(&sys, &wihetnoc::model::lenet(), 32);
    let cfg = TraceConfig { scale: 0.1, ..Default::default() };
    let (trace, _) = training_trace(&sys, &tm.phases, &cfg);
    let inst = mesh_opt(&sys, true);
    let sim = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
    let packets = {
        let rep = sim.run(&trace);
        rep.delivered_packets
    };
    b.bench_items(
        &format!("sim/lenet iteration 10% scale ({packets} pkts)"),
        Some(packets as f64),
        &mut || {
            std::hint::black_box(sim.run(&trace).delivered_packets);
        },
    );
    let mut ws = wihetnoc::noc::sim::SimWorkspace::new();
    b.bench_items(
        &format!("sim/lenet iteration explicit-ws ({packets} pkts)"),
        Some(packets as f64),
        &mut || {
            std::hint::black_box(sim.run_in(&trace, &mut ws).delivered_packets);
        },
    );

    // --- PJRT train step (needs artifacts) ---
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        let mut rt = wihetnoc::runtime::Runtime::new(&dir).expect("runtime");
        let batch = rt.manifest.batch;
        let mut trainer =
            wihetnoc::coordinator::Trainer::new(&mut rt, wihetnoc::model::lenet(), 1)
                .expect("trainer");
        let mut ds =
            wihetnoc::coordinator::SyntheticDataset::new(&wihetnoc::model::lenet(), 2);
        let (x, y) = ds.next_batch(batch);
        // warm the compile cache before timing
        trainer.step(&x, &y).expect("step");
        b.bench("pjrt/lenet train_step (batch 32)", || {
            std::hint::black_box(trainer.step(&x, &y).expect("step"));
        });
    } else {
        println!("pjrt/lenet train_step: SKIPPED (run `make artifacts`)");
    }

    println!("\n== hotpath benches done ==");
}

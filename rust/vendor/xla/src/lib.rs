//! API-compatible **stub** of the `xla` crate (xla-rs PJRT bindings).
//!
//! The WiHetNoC crate needs PJRT only for the optional L3 path that
//! executes AOT-lowered HLO artifacts; everything else (traffic modeling,
//! AMOSA design, cycle-level simulation, energy, experiments) is pure
//! Rust. This stub keeps the whole workspace building in hermetic
//! environments with no network and no `xla_extension` C library: every
//! entry point that would touch PJRT returns a descriptive [`Error`]
//! at runtime, starting with [`PjRtClient::cpu`].
//!
//! To run artifacts for real, replace this directory with the actual
//! xla-rs crate (same API surface: `PjRtClient`, `PjRtLoadedExecutable`,
//! `PjRtBuffer`, `Literal`, `HloModuleProto`, `XlaComputation`) — no
//! source change in `wihetnoc` is required.

/// Stub error: carries the reason PJRT is unavailable.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error(
        "xla stub: real PJRT bindings are not vendored in this build \
         (replace rust/vendor/xla with xla-rs to execute artifacts)"
            .to_string(),
    )
}

/// Host tensor stand-in. Holds nothing; all conversions error.
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[derive(Debug, Clone)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

#[derive(Debug, Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    /// Always fails in the stub with a message pointing at the swap-in
    /// instructions; callers surface it as their own error type.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("stub"));
        assert!(HloModuleProto::from_text_file("x").is_err());
        assert!(Literal::vec1(&[1.0f32]).reshape(&[1]).is_err());
    }
}

//! The schedule subsystem: training timelines with overlapping
//! microbatch phases.
//!
//! The workload subsystem (`crate::workload`) decides *where* a CNN's
//! layers compute and how many bytes each phase moves; until this module
//! existed, the simulator then executed those phases strictly one at a
//! time. Real training pipelines overlap them: with the batch split into
//! `M` microbatches, several phase instances are in flight at once and
//! pipeline *bubbles* trade off against NoC *contention* — the
//! interaction this subsystem makes simulatable:
//!
//! ```text
//!   TrafficModel phases              (workload::lower)
//!      │  SchedulePolicy: serial | gpipe:M | 1f1b:M     (schedule::policy)
//!      ▼
//!   TrainingTimeline                 (schedule::timeline)
//!      │  DAG of PhaseInstances (phase x microbatch) with
//!      │  data + per-stage resource precedence edges;
//!      │  exact volume partition (conservation law)
//!      ▼
//!   gated concurrent simulation      (noc::sim::NocSim::run_timeline)
//!      │  an instance injects the cycle its predecessors drain
//!      ▼
//!   ScheduleReport                   (schedule::run)
//!      makespan, speedup vs serial, bubble_fraction,
//!      per-link peak concurrency
//! ```
//!
//! `serial` is the legacy behaviour and produces byte-identical
//! [`crate::noc::sim::SimReport`]s (pinned by `tests/schedule_sim.rs`);
//! `gpipe:M`/`1f1b:M` move exactly the same bytes (prefix-difference
//! microbatch partition) on a different timeline. Entry points: parse a
//! [`SchedulePolicy`] (`Scenario::with_schedule`, CLI `--schedule`), then
//! [`run_schedule`] — or [`expand`] + [`timeline_groups`] +
//! [`crate::noc::sim::NocSim::run_timeline`] for custom harnesses.

pub mod policy;
pub mod run;
pub mod timeline;

pub use policy::{SchedulePolicy, GRAMMAR};
pub use run::{
    run_expanded, run_expanded_faults, run_expanded_obs, run_schedule, run_schedule_faults,
    run_schedule_obs, timeline_groups, ScheduleReport,
};
pub use timeline::{count_stages, expand, PhaseInstance, TrainingTimeline};

//! Running a timeline through the simulator and deriving schedule
//! metrics.
//!
//! * `serial` short-circuits to the legacy trace pipeline
//!   ([`training_trace`] + [`NocSim::run`]), so its [`SimReport`] is
//!   byte-identical to the pre-schedule simulator — the same guarantee
//!   the workload lowering gives the identity mapping.
//! * `gpipe:M` / `1f1b:M` expand to a [`TrainingTimeline`], generate one
//!   message group per phase instance (single RNG stream in canonical
//!   order, so traces are deterministic), and run the gated event loop
//!   ([`NocSim::run_timeline`]): several instances inject concurrently,
//!   each released the cycle its predecessors drain.
//!
//! Metrics:
//! * `makespan` — last tail-delivery cycle of the whole iteration.
//! * `serial_ref_cycles` — the per-phase trace windows the `serial`
//!   schedule lays back to back; `speedup_vs_serial` is their ratio to
//!   the makespan.
//! * `bubble_fraction` — `1 - active/(S * makespan)` where `active` sums
//!   each instance's release->drain span and `S` is the stage count. For
//!   an ideal `S`-stage GPipe pipeline this reduces to the textbook
//!   `(S-1)/(M+S-1)` flush bubble.
//! * `link_peak_concurrency` — per wireline link, the peak number of
//!   phase instances whose active spans overlap while both put flits on
//!   that link: where overlap turns into NoC contention.

use crate::error::WihetError;
use crate::faults::{FaultPlan, ResilienceStats, SimFaults};
use crate::model::SystemConfig;
use crate::noc::builder::NocInstance;
use crate::noc::sim::{Message, NocSim, SimConfig, SimReport};
use crate::telemetry::Telemetry;
use crate::traffic::phases::TrafficModel;
use crate::traffic::trace::{phase_trace, training_trace, TraceConfig};
use crate::util::rng::Rng;

use super::policy::SchedulePolicy;
use super::timeline::{count_stages, expand, TrainingTimeline};

/// Results of one scheduled training iteration on one NoC.
#[derive(Debug, Clone)]
pub struct ScheduleReport {
    pub policy: SchedulePolicy,
    /// Aggregate network report over the whole (possibly concurrent)
    /// iteration. For `serial` this is byte-identical to the legacy
    /// single-trace run.
    pub sim: SimReport,
    pub instances: usize,
    pub num_stages: usize,
    /// Last tail-delivery cycle of the iteration (trace-scaled time).
    pub makespan: u64,
    /// The per-phase trace windows laid back to back — what the serial
    /// schedule injects.
    pub serial_ref_cycles: u64,
    /// `serial_ref_cycles / makespan` (1.0 for serial by definition).
    pub speedup_vs_serial: f64,
    /// Pipeline idle share: `1 - active / (num_stages * makespan)`,
    /// clamped to [0, 1]. 0.0 for serial by definition.
    pub bubble_fraction: f64,
    /// Peak number of concurrently-active instances sharing each
    /// wireline link (empty for serial: one phase at a time).
    pub link_peak_concurrency: Vec<u32>,
    /// `max` of `link_peak_concurrency` (1 for serial).
    pub peak_link_concurrency: u32,
    /// GPU-tile-weighted active cycles: sum over instances of
    /// (release->drain span) x (participating GPU tiles). Scaled time;
    /// energy accounting rescales.
    pub gpu_tile_busy_cycles: u64,
    /// Cycles with CPU-cohort traffic in flight (span sum over instances
    /// that move CPU bytes).
    pub cpu_busy_cycles: u64,
}

impl ScheduleReport {
    /// Fault-injection counters of the underlying simulation (all zero
    /// for fault-free runs).
    pub fn resilience(&self) -> &ResilienceStats {
        &self.sim.resilience
    }
}

/// Generate one message group per timeline instance. Offsets are
/// release-relative (`start_cycle = 0`); one RNG stream over the
/// canonical instance order keeps traces deterministic for a given seed.
/// Returns the groups and each instance's trace window length.
pub fn timeline_groups(
    sys: &SystemConfig,
    tl: &TrainingTimeline,
    cfg: &TraceConfig,
) -> (Vec<Vec<Message>>, Vec<u64>) {
    let mut rng = Rng::new(cfg.seed);
    let mut groups = Vec::with_capacity(tl.instances.len());
    let mut durs = Vec::with_capacity(tl.instances.len());
    for inst in &tl.instances {
        let (msgs, dur) = phase_trace(sys, &inst.traffic, 0, cfg, &mut rng);
        groups.push(msgs);
        durs.push(dur);
    }
    (groups, durs)
}

/// Simulate one training iteration of `tm` on `inst` under `policy`.
pub fn run_schedule(
    sys: &SystemConfig,
    inst: &NocInstance,
    tm: &TrafficModel,
    policy: &SchedulePolicy,
    cfg: &TraceConfig,
) -> Result<ScheduleReport, WihetError> {
    run_schedule_faults(sys, inst, tm, policy, cfg, &FaultPlan::none())
}

/// [`run_schedule`] under a fault plan: the plan is compiled once
/// against this NoC (seeded kills expanded, routes repaired) and every
/// simulated phase — serial trace or gated timeline — consults it. An
/// empty plan ([`FaultPlan::none`]) installs no fault hooks at all, so
/// results stay byte-identical to [`run_schedule`].
pub fn run_schedule_faults(
    sys: &SystemConfig,
    inst: &NocInstance,
    tm: &TrafficModel,
    policy: &SchedulePolicy,
    cfg: &TraceConfig,
    plan: &FaultPlan,
) -> Result<ScheduleReport, WihetError> {
    run_schedule_obs(sys, inst, tm, policy, cfg, plan, None)
}

/// [`run_schedule_faults`] with an optional telemetry sink: the sink
/// rides along the underlying simulation (metrics, histograms) and, once
/// the run finishes, gets one timeline span per phase instance (serial:
/// per phase window) so the Chrome-trace export shows the gated
/// schedule. Reports are byte-identical with or without a sink.
pub fn run_schedule_obs(
    sys: &SystemConfig,
    inst: &NocInstance,
    tm: &TrafficModel,
    policy: &SchedulePolicy,
    cfg: &TraceConfig,
    plan: &FaultPlan,
    mut tel: Option<&mut Telemetry>,
) -> Result<ScheduleReport, WihetError> {
    let fx = if plan.has_noc_faults() {
        let nominal = SimConfig::default().nominal_flits;
        Some(plan.compile(&inst.topo, &inst.routes, &inst.air, nominal)?)
    } else {
        None
    };
    if policy.is_serial() {
        // Legacy path, byte-identical: one trace, phases back to back.
        let mut sim =
            NocSim::new(sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
        if let Some(f) = &fx {
            sim = sim.with_faults(f);
        }
        let (trace, windows) = training_trace(sys, &tm.phases, cfg);
        let rep = sim.run_telemetry(&trace, tel.as_deref_mut());
        if let Some(sink) = tel {
            for (p, &(start, end)) in tm.phases.iter().zip(&windows) {
                sink.span(p.tag.clone(), "phase", 0, start, end);
            }
        }
        let serial_ref = windows.last().map(|&(_, end)| end).unwrap_or(0);
        let n_gpu = sys.gpus().len() as u64;
        let mut gpu_busy = 0u64;
        let mut cpu_busy = 0u64;
        for (p, &(start, end)) in tm.phases.iter().zip(&windows) {
            let span = end - start;
            if p.gpu_read_bytes + p.gpu_write_bytes > 0 {
                let tiles =
                    if p.gpu_tiles.is_empty() { n_gpu } else { p.gpu_tiles.len() as u64 };
                gpu_busy += span * tiles;
            }
            if p.cpu_read_bytes + p.cpu_write_bytes > 0 {
                cpu_busy += span;
            }
        }
        let makespan = rep.cycles;
        return Ok(ScheduleReport {
            policy: *policy,
            sim: rep,
            instances: tm.phases.len(),
            num_stages: count_stages(tm),
            makespan,
            serial_ref_cycles: serial_ref,
            speedup_vs_serial: 1.0,
            bubble_fraction: 0.0,
            link_peak_concurrency: Vec::new(),
            peak_link_concurrency: 1,
            gpu_tile_busy_cycles: gpu_busy,
            cpu_busy_cycles: cpu_busy,
        });
    }

    let tl = expand(tm, policy)?;
    // Serial reference = the windows the *serial* schedule would lay back
    // to back (one per phase). Summing the per-instance windows instead
    // would count phase_trace's 16-cycle floor M times per phase and
    // overstate the speedup at small trace scales.
    let serial_ref: u64 = tm.phases.iter().map(|p| cfg.window(p.duration_cycles)).sum();
    let (report, _release) =
        run_expanded_obs(sys, inst, &tl, cfg, serial_ref, fx.as_ref(), tel);
    Ok(report)
}

/// Run an already-expanded timeline through the gated simulator and
/// derive the schedule metrics. `serial_ref_cycles` comes from the
/// caller: the fabric runner appends allreduce instances beyond the base
/// phase list, so the serial reference cannot be recovered from `tl`
/// alone. Also returns each group's release cycle (`u64::MAX` for
/// unreached groups) so analytic post-passes — the alpha-beta inter-chip
/// charge — can anchor on the simulated on-chip timeline.
pub fn run_expanded(
    sys: &SystemConfig,
    inst: &NocInstance,
    tl: &TrainingTimeline,
    cfg: &TraceConfig,
    serial_ref: u64,
) -> (ScheduleReport, Vec<u64>) {
    run_expanded_faults(sys, inst, tl, cfg, serial_ref, None)
}

/// [`run_expanded`] with an optional compiled fault plan installed on
/// the gated simulator (`None` keeps the fault hooks off entirely).
pub fn run_expanded_faults(
    sys: &SystemConfig,
    inst: &NocInstance,
    tl: &TrainingTimeline,
    cfg: &TraceConfig,
    serial_ref: u64,
    faults: Option<&SimFaults>,
) -> (ScheduleReport, Vec<u64>) {
    run_expanded_obs(sys, inst, tl, cfg, serial_ref, faults, None)
}

/// [`run_expanded_faults`] with an optional telemetry sink: records one
/// span per reached phase instance (name `"<tag> mb<k>"`, track = stage,
/// category `"collective"` for allreduce instances) on top of the sink's
/// simulation metrics. Reports are byte-identical with or without it.
pub fn run_expanded_obs(
    sys: &SystemConfig,
    inst: &NocInstance,
    tl: &TrainingTimeline,
    cfg: &TraceConfig,
    serial_ref: u64,
    faults: Option<&SimFaults>,
    mut tel: Option<&mut Telemetry>,
) -> (ScheduleReport, Vec<u64>) {
    let mut sim = NocSim::new(sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
    if let Some(f) = faults {
        sim = sim.with_faults(f);
    }
    let (groups, _durs) = timeline_groups(sys, tl, cfg);
    let out = sim.run_timeline_telemetry(&groups, &tl.preds, tel.as_deref_mut());
    if let Some(sink) = tel {
        for (g, pi) in tl.instances.iter().enumerate() {
            let (r, d) = (out.release[g], out.drain[g]);
            if r == u64::MAX || d == u64::MAX {
                continue; // never released (horizon cut): no span
            }
            let cat = if pi.traffic.tag.starts_with("AR") { "collective" } else { "phase" };
            sink.span(
                format!("{} mb{}", pi.traffic.tag, pi.microbatch),
                cat,
                pi.stage as u32,
                r,
                d,
            );
        }
    }
    let makespan = out.report.cycles;
    let speedup = serial_ref as f64 / makespan.max(1) as f64;

    // active spans (release -> drain) per instance
    let n_gpu = sys.gpus().len() as u64;
    let mut active = 0u64;
    let mut gpu_busy = 0u64;
    let mut cpu_busy = 0u64;
    for (g, pi) in tl.instances.iter().enumerate() {
        let (r, d) = (out.release[g], out.drain[g]);
        if r == u64::MAX || d == u64::MAX {
            continue; // horizon-cut instance
        }
        let span = d - r;
        active += span;
        let t = &pi.traffic;
        if t.gpu_read_bytes + t.gpu_write_bytes > 0 {
            let tiles = if t.gpu_tiles.is_empty() { n_gpu } else { t.gpu_tiles.len() as u64 };
            gpu_busy += span * tiles;
        }
        if t.cpu_read_bytes + t.cpu_write_bytes > 0 {
            cpu_busy += span;
        }
    }
    let denom = (tl.num_stages as u64 * makespan).max(1) as f64;
    let bubble = (1.0 - active as f64 / denom).clamp(0.0, 1.0);

    // per-link peak concurrency: sweep the active spans of the instances
    // that put flits on each link
    let nl = inst.topo.links.len();
    let mut link_peak = vec![0u32; nl];
    let mut events: Vec<(u64, i32)> = Vec::new();
    for (l, peak) in link_peak.iter_mut().enumerate() {
        events.clear();
        for g in 0..tl.instances.len() {
            if out.group_link_flits[g * nl + l] == 0 {
                continue;
            }
            let (r, d) = (out.release[g], out.drain[g]);
            if r == u64::MAX || d == u64::MAX {
                continue;
            }
            // half-open [r, d): a gated successor releasing exactly at
            // its predecessor's drain does not count as overlap
            events.push((r, 1));
            events.push((d, -1));
        }
        events.sort_unstable();
        let mut cur = 0i32;
        let mut best = 0i32;
        for &(_, delta) in events.iter() {
            cur += delta;
            best = best.max(cur);
        }
        *peak = best.max(0) as u32;
    }
    let peak = link_peak.iter().copied().max().unwrap_or(0).max(1);

    let report = ScheduleReport {
        policy: tl.policy,
        sim: out.report,
        instances: tl.instances.len(),
        num_stages: tl.num_stages,
        makespan,
        serial_ref_cycles: serial_ref,
        speedup_vs_serial: speedup,
        bubble_fraction: bubble,
        link_peak_concurrency: link_peak,
        peak_link_concurrency: peak,
        gpu_tile_busy_cycles: gpu_busy,
        cpu_busy_cycles: cpu_busy,
    };
    (report, out.release)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::builder::mesh_opt;
    use crate::workload::{lower_id, MappingPolicy};
    use crate::ModelId;

    fn setup() -> (SystemConfig, NocInstance, TrafficModel) {
        let sys = SystemConfig::paper_8x8();
        let inst = mesh_opt(&sys, true);
        let tm = lower_id(
            &ModelId::LeNet,
            &MappingPolicy::LayerPipelined { stages: 2 },
            &sys,
            32,
        )
        .unwrap();
        (sys, inst, tm)
    }

    #[test]
    fn serial_matches_legacy_trace_run() {
        let (sys, inst, tm) = setup();
        let cfg = TraceConfig { scale: 0.05, ..Default::default() };
        let sr = run_schedule(&sys, &inst, &tm, &SchedulePolicy::Serial, &cfg).unwrap();
        let (trace, _) = training_trace(&sys, &tm.phases, &cfg);
        let rep = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default())
            .run(&trace);
        assert_eq!(sr.sim.latency.sum, rep.latency.sum);
        assert_eq!(sr.sim.delivered_flits, rep.delivered_flits);
        assert_eq!(sr.sim.link_busy, rep.link_busy);
        assert_eq!(sr.makespan, rep.cycles);
        assert_eq!(sr.speedup_vs_serial, 1.0);
        assert_eq!(sr.bubble_fraction, 0.0);
    }

    #[test]
    fn gpipe_overlaps_and_reports_metrics() {
        let (sys, inst, tm) = setup();
        let cfg = TraceConfig { scale: 0.1, ..Default::default() };
        let serial = run_schedule(&sys, &inst, &tm, &SchedulePolicy::Serial, &cfg).unwrap();
        let gp = run_schedule(
            &sys,
            &inst,
            &tm,
            &SchedulePolicy::GPipe { microbatches: 4 },
            &cfg,
        )
        .unwrap();
        assert_eq!(gp.instances, tm.phases.len() * 4);
        assert!(gp.makespan > 0);
        assert!(gp.makespan <= serial.makespan, "gpipe {} vs serial {}", gp.makespan, serial.makespan);
        assert!((0.0..1.0).contains(&gp.bubble_fraction), "{}", gp.bubble_fraction);
        assert!(gp.speedup_vs_serial > 1.0, "{}", gp.speedup_vs_serial);
        assert!(gp.peak_link_concurrency >= 1);
        // all traffic delivered: conservation carries into flits
        assert_eq!(gp.sim.undelivered(), 0);
    }

    #[test]
    fn faulted_schedule_still_delivers_everything() {
        let (sys, inst, tm) = setup();
        let cfg = TraceConfig { scale: 0.05, ..Default::default() };
        let gp = SchedulePolicy::GPipe { microbatches: 4 };
        let clean = run_schedule(&sys, &inst, &tm, &gp, &cfg).unwrap();
        // one dead link: mesh minus one link stays connected, so a
        // repair path always exists and nothing may be lost
        let plan: FaultPlan = "wire:link=0".parse().unwrap();
        let faulted = run_schedule_faults(&sys, &inst, &tm, &gp, &cfg, &plan).unwrap();
        assert_eq!(faulted.sim.undelivered(), 0);
        assert_eq!(faulted.resilience().undeliverable_after_repair, 0);
        assert_eq!(faulted.resilience().faults_injected, 1);
        assert_eq!(faulted.sim.delivered_packets, clean.sim.delivered_packets);
        // the empty plan is byte-identical to the plain entry point
        let none = run_schedule_faults(&sys, &inst, &tm, &gp, &cfg, &FaultPlan::none()).unwrap();
        assert_eq!(none.sim.latency.sum, clean.sim.latency.sum);
        assert_eq!(none.sim.link_busy, clean.sim.link_busy);
        assert_eq!(none.makespan, clean.makespan);
        assert_eq!(none.resilience(), &ResilienceStats::default());
    }

    #[test]
    fn one_f_one_b_runs_and_delivers_everything() {
        let (sys, inst, tm) = setup();
        let cfg = TraceConfig { scale: 0.05, ..Default::default() };
        let r = run_schedule(
            &sys,
            &inst,
            &tm,
            &SchedulePolicy::OneFOneB { microbatches: 4 },
            &cfg,
        )
        .unwrap();
        assert_eq!(r.sim.undelivered(), 0);
        assert!(r.sim.delivered_packets > 0);
        assert!((0.0..=1.0).contains(&r.bubble_fraction));
    }
}

//! Timeline expansion: `(TrafficModel, SchedulePolicy)` -> an explicit
//! DAG of phase instances.
//!
//! [`expand`] splits every lowered [`LayerPhase`] into `M` microbatch
//! instances (phase x microbatch x fwd/bwd is implicit: the phase list
//! already carries the pass) and wires two kinds of precedence edges:
//!
//! * **data** — microbatch `m` executes its phases in the lowered order
//!   (forward chain, then the backward chain), so instance `(p, m)`
//!   depends on `(p-1, m)`;
//! * **resource** — the tiles of a *stage* (a distinct
//!   [`LayerPhase::gpu_tiles`] slice, or the CPUs for dense layers)
//!   process one instance at a time, in the order the schedule policy
//!   dictates (GPipe: all forwards then all backwards; 1F1B: warmup then
//!   alternate). Consecutive instances in that per-stage order are
//!   chained.
//!
//! **Conservation law**: the microbatch split is a prefix-difference
//! partition — `share(m) = v*(m+1)/M - v*m/M` — so for every volume
//! field the `M` instances sum *exactly* to the serial phase. Any
//! schedule moves the same bytes as `serial`; it only changes when they
//! move (pinned by `tests/schedule_sim.rs`).

use crate::error::WihetError;
use crate::model::cnn::{LayerKind, Pass};
use crate::traffic::phases::{LayerPhase, TrafficModel};

use super::policy::SchedulePolicy;

/// One phase x microbatch node of the timeline DAG.
#[derive(Debug, Clone)]
pub struct PhaseInstance {
    /// Index into the lowered `TrafficModel::phases`.
    pub phase: usize,
    pub microbatch: usize,
    /// Resource id (see [`TrainingTimeline::num_stages`]).
    pub stage: usize,
    /// Microbatch-scaled copy of the phase (volumes, control flits, and
    /// duration partitioned by prefix differences).
    pub traffic: LayerPhase,
}

/// The expanded training iteration: instances in canonical order
/// (phase-major, microbatch-minor — so a serial expansion *is* the phase
/// list) plus the precedence DAG.
#[derive(Debug, Clone)]
pub struct TrainingTimeline {
    pub policy: SchedulePolicy,
    pub model: String,
    pub instances: Vec<PhaseInstance>,
    /// Predecessor instance indices per instance (deduplicated).
    pub preds: Vec<Vec<u32>>,
    /// Distinct resources: one per distinct GPU tile slice among the
    /// phases, plus one for the CPUs when dense layers exist. Under a
    /// `pipeline:S` mapping this is the pipeline depth (+1 for the CPU
    /// tail); under the identity mapping it collapses to one GPU stage.
    pub num_stages: usize,
    pub microbatches: usize,
}

impl TrainingTimeline {
    /// Total core<->MC bytes over all instances — equals the serial
    /// model's [`TrafficModel::total_bytes`] for every policy.
    pub fn total_bytes(&self) -> u64 {
        self.instances
            .iter()
            .map(|i| {
                i.traffic.gpu_read_bytes
                    + i.traffic.gpu_write_bytes
                    + i.traffic.cpu_read_bytes
                    + i.traffic.cpu_write_bytes
            })
            .sum()
    }

    /// Total core<->core control flits over all instances.
    pub fn total_core_core_flits(&self) -> u64 {
        self.instances.iter().map(|i| i.traffic.core_core_flits).sum()
    }
}

/// Exact prefix-difference share of `v` for microbatch `m` of `count`.
fn share(v: u64, m: usize, count: usize) -> u64 {
    let v = v as u128;
    let (m, count) = (m as u128, count as u128);
    (v * (m + 1) / count - v * m / count) as u64
}

/// Stage id per phase: distinct `gpu_tiles` slices (empty = all GPUs) in
/// first-appearance order, with dense (CPU-resident) phases on their own
/// CPU stage. Returns `(stage_of, num_stages)`.
fn stage_ids(phases: &[LayerPhase]) -> (Vec<usize>, usize) {
    // key: None = the CPU stage, Some(tiles) = a GPU tile slice
    let mut keys: Vec<Option<&[usize]>> = Vec::new();
    let stage_of = phases
        .iter()
        .map(|p| {
            let key: Option<&[usize]> =
                if p.kind == LayerKind::Dense { None } else { Some(&p.gpu_tiles) };
            match keys.iter().position(|k| *k == key) {
                Some(i) => i,
                None => {
                    keys.push(key);
                    keys.len() - 1
                }
            }
        })
        .collect();
    (stage_of, keys.len())
}

/// Number of distinct stages the lowered model occupies (used for
/// serial-schedule reporting without a full expansion).
pub fn count_stages(tm: &TrafficModel) -> usize {
    stage_ids(&tm.phases).1
}

/// Expand a lowered traffic model into the timeline DAG for `policy`.
pub fn expand(tm: &TrafficModel, policy: &SchedulePolicy) -> Result<TrainingTimeline, WihetError> {
    policy.validate_for(tm.batch)?;
    let m_count = policy.microbatches();
    let n_phases = tm.phases.len();
    let (stage_of, num_stages) = stage_ids(&tm.phases);

    // canonical order: phase-major, microbatch-minor
    let idx = |p: usize, m: usize| (p * m_count + m) as u32;
    let mut instances = Vec::with_capacity(n_phases * m_count);
    for (p, phase) in tm.phases.iter().enumerate() {
        for m in 0..m_count {
            let mut traffic = phase.clone();
            traffic.gpu_read_bytes = share(phase.gpu_read_bytes, m, m_count);
            traffic.gpu_write_bytes = share(phase.gpu_write_bytes, m, m_count);
            traffic.cpu_read_bytes = share(phase.cpu_read_bytes, m, m_count);
            traffic.cpu_write_bytes = share(phase.cpu_write_bytes, m, m_count);
            traffic.core_core_flits = share(phase.core_core_flits, m, m_count);
            traffic.duration_cycles = share(phase.duration_cycles, m, m_count);
            instances.push(PhaseInstance { phase: p, microbatch: m, stage: stage_of[p], traffic });
        }
    }

    let mut preds: Vec<Vec<u32>> = vec![Vec::new(); instances.len()];
    // data edges: each microbatch walks the lowered phase order
    for p in 1..n_phases {
        for m in 0..m_count {
            preds[idx(p, m) as usize].push(idx(p - 1, m));
        }
    }
    // resource edges: consecutive instances in each stage's service order
    for s in 0..num_stages {
        let fwd: Vec<usize> = (0..n_phases)
            .filter(|&p| stage_of[p] == s && tm.phases[p].pass == Pass::Forward)
            .collect();
        let bwd: Vec<usize> = (0..n_phases)
            .filter(|&p| stage_of[p] == s && tm.phases[p].pass == Pass::Backward)
            .collect();
        let mut order: Vec<u32> = Vec::new();
        let push_fwd = |m: usize, order: &mut Vec<u32>| {
            order.extend(fwd.iter().map(|&p| idx(p, m)));
        };
        let push_bwd = |m: usize, order: &mut Vec<u32>| {
            order.extend(bwd.iter().map(|&p| idx(p, m)));
        };
        match policy {
            SchedulePolicy::Serial | SchedulePolicy::GPipe { .. } => {
                for m in 0..m_count {
                    push_fwd(m, &mut order);
                }
                for m in 0..m_count {
                    push_bwd(m, &mut order);
                }
            }
            SchedulePolicy::OneFOneB { .. } => {
                // warmup depth shrinks toward the last stage; the final
                // stage alternates immediately (w = 1)
                let w = (num_stages - s).min(m_count).max(1);
                for m in 0..w {
                    push_fwd(m, &mut order);
                }
                for i in 0..m_count - w {
                    push_bwd(i, &mut order);
                    push_fwd(w + i, &mut order);
                }
                for i in m_count - w..m_count {
                    push_bwd(i, &mut order);
                }
            }
        }
        for pair in order.windows(2) {
            preds[pair[1] as usize].push(pair[0]);
        }
    }
    for ps in &mut preds {
        ps.sort_unstable();
        ps.dedup();
        // self-edges cannot arise (data edges cross phases, resource
        // edges cross order positions), but keep the invariant explicit
        debug_assert!(ps.windows(2).all(|w| w[0] != w[1]));
    }

    // Kahn pass: the service orders above are real schedules, so the DAG
    // must be acyclic; a cycle would deadlock the gated simulation.
    let mut indeg: Vec<u32> = vec![0; instances.len()];
    let mut succs: Vec<Vec<u32>> = vec![Vec::new(); instances.len()];
    for (i, ps) in preds.iter().enumerate() {
        indeg[i] = ps.len() as u32;
        for &p in ps {
            succs[p as usize].push(i as u32);
        }
    }
    let mut work: Vec<u32> =
        (0..instances.len() as u32).filter(|&i| indeg[i as usize] == 0).collect();
    let mut seen = 0usize;
    let mut wi = 0usize;
    while wi < work.len() {
        let i = work[wi] as usize;
        wi += 1;
        seen += 1;
        for &s in &succs[i] {
            indeg[s as usize] -= 1;
            if indeg[s as usize] == 0 {
                work.push(s);
            }
        }
    }
    if seen != instances.len() {
        return Err(WihetError::InvalidArg(format!(
            "schedule '{policy}' produced a cyclic timeline for {} ({} of {} instances orderable) — this is a bug in the expander",
            tm.model,
            seen,
            instances.len()
        )));
    }

    Ok(TrainingTimeline {
        policy: *policy,
        model: tm.model.clone(),
        instances,
        preds,
        num_stages,
        microbatches: m_count,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemConfig;
    use crate::workload::{lower_id, MappingPolicy};
    use crate::ModelId;

    fn lowered(mapping: MappingPolicy) -> (SystemConfig, TrafficModel) {
        let sys = SystemConfig::paper_8x8();
        let tm = lower_id(&ModelId::LeNet, &mapping, &sys, 32).unwrap();
        (sys, tm)
    }

    #[test]
    fn serial_expansion_is_the_phase_chain() {
        let (_, tm) = lowered(MappingPolicy::default());
        let tl = expand(&tm, &SchedulePolicy::Serial).unwrap();
        assert_eq!(tl.instances.len(), tm.phases.len());
        assert_eq!(tl.microbatches, 1);
        for (i, inst) in tl.instances.iter().enumerate() {
            assert_eq!(inst.phase, i);
            assert_eq!(inst.traffic.gpu_read_bytes, tm.phases[i].gpu_read_bytes);
            assert_eq!(inst.traffic.duration_cycles, tm.phases[i].duration_cycles);
            if i > 0 {
                assert!(tl.preds[i].contains(&(i as u32 - 1)));
            }
        }
    }

    #[test]
    fn shares_partition_exactly() {
        for v in [0u64, 1, 7, 63, 64, 1_000_003] {
            for count in [1usize, 2, 3, 8] {
                let sum: u64 = (0..count).map(|m| share(v, m, count)).sum();
                assert_eq!(sum, v, "v={v} count={count}");
            }
        }
    }

    #[test]
    fn gpipe_conserves_volumes_and_counts() {
        for mapping in [MappingPolicy::default(), MappingPolicy::LayerPipelined { stages: 3 }] {
            let (_, tm) = lowered(mapping);
            for m in [2usize, 4, 8] {
                let tl = expand(&tm, &SchedulePolicy::GPipe { microbatches: m }).unwrap();
                assert_eq!(tl.instances.len(), tm.phases.len() * m);
                assert_eq!(tl.total_bytes(), tm.total_bytes());
                let serial_cc: u64 = tm.phases.iter().map(|p| p.core_core_flits).sum();
                assert_eq!(tl.total_core_core_flits(), serial_cc);
                let serial_dur: u64 = tm.phases.iter().map(|p| p.duration_cycles).sum();
                let tl_dur: u64 =
                    tl.instances.iter().map(|i| i.traffic.duration_cycles).sum();
                assert_eq!(tl_dur, serial_dur);
            }
        }
    }

    #[test]
    fn pipelined_mapping_yields_multiple_stages() {
        let (_, tm) = lowered(MappingPolicy::LayerPipelined { stages: 3 });
        let tl = expand(&tm, &SchedulePolicy::GPipe { microbatches: 4 }).unwrap();
        // 3 GPU stages + the CPU (dense) stage
        assert_eq!(tl.num_stages, 4);
        let (_, tm_flat) = lowered(MappingPolicy::default());
        assert_eq!(count_stages(&tm_flat), 2, "all-GPU stage + CPU stage");
    }

    #[test]
    fn one_f_one_b_is_acyclic_and_conserves() {
        for stages in [2usize, 3, 4] {
            let (_, tm) = lowered(MappingPolicy::LayerPipelined { stages });
            for m in [2usize, 4, 8] {
                let tl = expand(&tm, &SchedulePolicy::OneFOneB { microbatches: m }).unwrap();
                assert_eq!(tl.total_bytes(), tm.total_bytes());
                assert_eq!(tl.instances.len(), tm.phases.len() * m);
            }
        }
    }

    #[test]
    fn too_many_microbatches_is_typed() {
        let (_, tm) = lowered(MappingPolicy::default());
        let e = expand(&tm, &SchedulePolicy::GPipe { microbatches: 64 }).unwrap_err();
        assert!(matches!(e, WihetError::InvalidArg(_)), "{e:?}");
        assert!(e.to_string().contains("batch size 32"), "{e}");
    }
}

//! Schedule policies: how one training iteration's phases overlap in
//! time.
//!
//! The mapping policy (`crate::workload::MappingPolicy`) decides *where*
//! each layer computes; the schedule policy decides *when* — whether the
//! batch runs as one serial pass or as `M` microbatches whose phase
//! instances overlap:
//!
//! * [`SchedulePolicy::Serial`] — the paper's (and the crate's legacy)
//!   behaviour: one phase at a time, back to back. Lowering and
//!   simulation are byte-identical to the pre-schedule pipeline.
//! * [`SchedulePolicy::GPipe`] `{ microbatches }` — GPipe-style: every
//!   stage runs all `M` forward microbatches, then (once its forward work
//!   and the incoming gradient are done) all `M` backwards. The classic
//!   flush bubble `(S-1)/(M+S-1)` emerges from the precedence DAG.
//! * [`SchedulePolicy::OneFOneB`] `{ microbatches }` — 1F1B: each stage
//!   warms up with `min(S - rank, M)` forwards, then alternates one
//!   backward / one forward, draining the remaining backwards at the end.
//!   Backward work starts long before the last forward microbatch, which
//!   shrinks the bubble and the peak number of in-flight microbatches.
//!
//! See Guirado et al. (arXiv:1912.01664) and Marques et al.
//! (arXiv:1712.02546) for why the overlap-vs-contention interaction
//! matters on DNN accelerators.

use std::fmt;
use std::str::FromStr;

use crate::error::WihetError;

/// The `--schedule` grammar, embedded in every parse/validation error.
pub const GRAMMAR: &str = "schedule := serial | gpipe:<M> | 1f1b:<M>   \
                           (M = microbatches per iteration, 1 <= M <= batch)";

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedulePolicy {
    /// One phase at a time — the legacy, byte-identical behaviour.
    Serial,
    /// GPipe: all forward microbatches, flush, all backward microbatches.
    GPipe { microbatches: usize },
    /// 1F1B: warmup forwards, then alternate one backward / one forward.
    OneFOneB { microbatches: usize },
}

impl Default for SchedulePolicy {
    fn default() -> Self {
        SchedulePolicy::Serial
    }
}

impl SchedulePolicy {
    /// Whether this schedule runs the legacy single-pass timeline.
    pub fn is_serial(&self) -> bool {
        matches!(self, SchedulePolicy::Serial)
    }

    /// Microbatches per iteration (1 for the serial schedule).
    pub fn microbatches(&self) -> usize {
        match *self {
            SchedulePolicy::Serial => 1,
            SchedulePolicy::GPipe { microbatches } | SchedulePolicy::OneFOneB { microbatches } => {
                microbatches
            }
        }
    }

    /// Reject schedules that cannot split `batch` samples: every
    /// microbatch needs at least one.
    pub fn validate_for(&self, batch: usize) -> Result<(), WihetError> {
        let m = self.microbatches();
        if m == 0 {
            return Err(WihetError::InvalidArg(format!(
                "schedule '{self}' needs at least 1 microbatch\n{GRAMMAR}"
            )));
        }
        if m > batch {
            return Err(WihetError::InvalidArg(format!(
                "schedule '{self}' splits more microbatches than the batch size {batch}\n{GRAMMAR}"
            )));
        }
        Ok(())
    }
}

impl fmt::Display for SchedulePolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            SchedulePolicy::Serial => f.pad("serial"),
            SchedulePolicy::GPipe { microbatches } => {
                f.pad(&format!("gpipe:{microbatches}"))
            }
            SchedulePolicy::OneFOneB { microbatches } => {
                f.pad(&format!("1f1b:{microbatches}"))
            }
        }
    }
}

impl FromStr for SchedulePolicy {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        let t = s.trim().to_ascii_lowercase();
        let (head, arg) = match t.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (t.as_str(), None),
        };
        let micro = |arg: Option<&str>| -> Result<usize, WihetError> {
            let a = arg.ok_or_else(|| {
                WihetError::InvalidArg(format!(
                    "schedule '{head}' expects a microbatch count, e.g. '{head}:4'\n{GRAMMAR}"
                ))
            })?;
            let m: usize = a.trim().parse().map_err(|_| {
                WihetError::InvalidArg(format!(
                    "schedule '{head}:{a}': microbatch count must be an integer\n{GRAMMAR}"
                ))
            })?;
            if m == 0 {
                return Err(WihetError::InvalidArg(format!(
                    "schedule '{head}:0' needs at least 1 microbatch\n{GRAMMAR}"
                )));
            }
            Ok(m)
        };
        match head {
            "serial" => {
                if arg.is_some() {
                    return Err(WihetError::InvalidArg(format!(
                        "schedule 'serial' takes no argument\n{GRAMMAR}"
                    )));
                }
                Ok(SchedulePolicy::Serial)
            }
            "gpipe" => Ok(SchedulePolicy::GPipe { microbatches: micro(arg)? }),
            "1f1b" => Ok(SchedulePolicy::OneFOneB { microbatches: micro(arg)? }),
            other => Err(WihetError::InvalidArg(format!(
                "unknown schedule '{other}'\n{GRAMMAR}"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        for s in ["serial", "gpipe:4", "gpipe:8", "1f1b:2", "1f1b:16"] {
            let p: SchedulePolicy = s.parse().unwrap();
            assert_eq!(p.to_string(), s);
            assert_eq!(p.to_string().parse::<SchedulePolicy>().unwrap(), p);
        }
        assert!(SchedulePolicy::default().is_serial());
        assert_eq!(SchedulePolicy::Serial.microbatches(), 1);
        assert_eq!(SchedulePolicy::GPipe { microbatches: 8 }.microbatches(), 8);
    }

    #[test]
    fn errors_carry_the_grammar() {
        for bad in ["rings", "gpipe", "gpipe:x", "gpipe:0", "1f1b:", "serial:2"] {
            let e = bad.parse::<SchedulePolicy>().unwrap_err();
            assert!(matches!(e, WihetError::InvalidArg(_)), "{bad}: {e:?}");
            let msg = e.to_string();
            assert!(msg.contains("gpipe:<M>") && msg.contains("1f1b:<M>"), "{bad}: {msg}");
        }
    }

    #[test]
    fn validation_bounds_microbatches_by_batch() {
        assert!(SchedulePolicy::Serial.validate_for(1).is_ok());
        assert!(SchedulePolicy::GPipe { microbatches: 8 }.validate_for(32).is_ok());
        assert!(SchedulePolicy::GPipe { microbatches: 33 }.validate_for(32).is_err());
        let e = SchedulePolicy::OneFOneB { microbatches: 9 }.validate_for(8).unwrap_err();
        assert!(e.to_string().contains("batch size 8"), "{e}");
    }
}

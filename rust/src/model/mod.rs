//! CNN workload descriptions (paper Table 1), the heterogeneous manycore
//! system configuration (paper Table 2 / §5), and the typed [`Platform`]
//! descriptor that generalizes it to arbitrary grids and core mixes.

pub mod cnn;
pub mod platform;
pub mod system;

pub use cnn::{cdbnet, lenet, Layer, LayerKind, ModelSpec, Pass};
pub use platform::{Platform, PlacementPolicy};
pub use system::{SystemConfig, TileKind};

//! CNN workload descriptions (paper Table 1) and the heterogeneous
//! manycore system configuration (paper Table 2 / §5).

pub mod cnn;
pub mod system;

pub use cnn::{cdbnet, lenet, Layer, LayerKind, ModelSpec, Pass};
pub use system::{SystemConfig, TileKind};

//! Parameterized platform descriptor — the typed generalization of
//! `SystemConfig::paper_8x8`.
//!
//! A [`Platform`] is *what you ask for* (grid shape, core mix, placement
//! policy); [`Platform::build`] validates it and produces the concrete
//! [`SystemConfig`] tile grid. Presets parse from strings (`"8x8"`,
//! `"4x4"`, `"12x12"`) and custom mixes use a key=value suffix:
//!
//! ```text
//! 8x8                                  paper platform (56 GPU / 4 CPU / 4 MC)
//! 4x4                                  16 tiles, 2 CPUs, 2 MCs
//! 12x12:cpus=8,mcs=8                   custom core mix
//! 6x4:cpus=2,mcs=4,placement=corners   rectangular grid, MCs at the corners
//! ```

use std::fmt;
use std::str::FromStr;

use crate::error::WihetError;
use crate::model::system::{SystemConfig, TileKind};

/// Where the non-GPU tiles go on the grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlacementPolicy {
    /// Paper §5.2: CPUs in the central block, MCs at the quadrant centers.
    Centered,
    /// CPUs central, MCs pushed to the die corners (a common DRAM-PHY
    /// floorplan constraint).
    Corners,
}

impl PlacementPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            PlacementPolicy::Centered => "centered",
            PlacementPolicy::Corners => "corners",
        }
    }
}

impl FromStr for PlacementPolicy {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "centered" | "paper" => Ok(PlacementPolicy::Centered),
            "corners" => Ok(PlacementPolicy::Corners),
            other => Err(WihetError::InvalidPlatform(format!(
                "unknown placement policy '{other}' (centered, corners)"
            ))),
        }
    }
}

/// A heterogeneous manycore platform description: `width x height` tiles,
/// `cpus` CPU tiles and `mcs` memory controllers placed by `placement`,
/// GPUs everywhere else. Validated by [`Platform::build`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Platform {
    pub width: usize,
    pub height: usize,
    pub cpus: usize,
    pub mcs: usize,
    pub placement: PlacementPolicy,
}

impl Platform {
    /// The paper's experimental platform: 8x8, 4 CPUs, 4 MCs, centered.
    pub fn paper() -> Self {
        Platform { width: 8, height: 8, cpus: 4, mcs: 4, placement: PlacementPolicy::Centered }
    }

    /// A `width x height` grid with the core mix scaled the way the paper
    /// scales it: one CPU and one MC per ~16 tiles (minimum 2 of each).
    pub fn grid(width: usize, height: usize) -> Self {
        let n = width * height;
        let special = (n / 16).max(2);
        Platform { width, height, cpus: special, mcs: special, placement: PlacementPolicy::Centered }
    }

    pub fn with_cpus(mut self, cpus: usize) -> Self {
        self.cpus = cpus;
        self
    }

    pub fn with_mcs(mut self, mcs: usize) -> Self {
        self.mcs = mcs;
        self
    }

    pub fn with_placement(mut self, placement: PlacementPolicy) -> Self {
        self.placement = placement;
        self
    }

    pub fn num_tiles(&self) -> usize {
        self.width * self.height
    }

    /// Reject shapes that cannot describe a working chip.
    pub fn validate(&self) -> Result<(), WihetError> {
        let err = |m: String| Err(WihetError::InvalidPlatform(m));
        if self.width < 2 || self.height < 2 {
            return err(format!(
                "grid must be at least 2x2, got {}x{}",
                self.width, self.height
            ));
        }
        if self.num_tiles() > 4096 {
            return err(format!(
                "{}x{} = {} tiles exceeds the 4096-tile simulator bound",
                self.width,
                self.height,
                self.num_tiles()
            ));
        }
        if self.cpus == 0 || self.mcs == 0 {
            return err("need at least 1 CPU and 1 MC tile".into());
        }
        if self.cpus + self.mcs > self.num_tiles() - 2 {
            return err(format!(
                "{} CPUs + {} MCs leaves fewer than 2 GPU tiles on {} total",
                self.cpus,
                self.mcs,
                self.num_tiles()
            ));
        }
        Ok(())
    }

    /// Validate and materialize the tile grid. Clocks, link widths, and
    /// energy-relevant constants inherit the paper's Table 2 values; the
    /// die keeps the paper's 2.5 mm tile pitch scaled to `width`.
    pub fn build(&self) -> Result<SystemConfig, WihetError> {
        self.validate()?;
        let (w, h) = (self.width, self.height);
        let n = w * h;
        let mut tiles = vec![TileKind::Gpu; n];
        let mut free = vec![true; n];
        // Die center in tile coordinates.
        let (cr, cc) = ((h as f64 - 1.0) / 2.0, (w as f64 - 1.0) / 2.0);
        // Nearest free tile to an anchor. Anchors at quadrant centers sit
        // equidistant from four tiles; ties break *outward* (max distance
        // from the die center, then lowest id), which reproduces the
        // paper's exact MC choice — (1,1),(1,6),(6,1),(6,6) on 8x8 —
        // rather than collapsing every quadrant toward the middle.
        let nearest_free = |free: &[bool], ar: f64, ac: f64| -> usize {
            let mut best = 0usize;
            let mut best_key = (f64::INFINITY, f64::NEG_INFINITY);
            for (id, ok) in free.iter().enumerate() {
                if !*ok {
                    continue;
                }
                let (r, c) = ((id / w) as f64, (id % w) as f64);
                let d = (r - ar).powi(2) + (c - ac).powi(2);
                let out = (r - cr).powi(2) + (c - cc).powi(2);
                if d + 1e-9 < best_key.0
                    || ((d - best_key.0).abs() <= 1e-9 && out > best_key.1 + 1e-9)
                {
                    best_key = (d, out);
                    best = id;
                }
            }
            best
        };
        // CPUs cluster at the die center under both policies (§5.2: CPU
        // QoS is served by keeping the latency-critical cores central).
        for _ in 0..self.cpus {
            let id = nearest_free(&free, cr, cc);
            free[id] = false;
            tiles[id] = TileKind::Cpu;
        }
        let anchors: [(f64, f64); 4] = match self.placement {
            PlacementPolicy::Centered => [
                (h as f64 / 4.0 - 0.5, w as f64 / 4.0 - 0.5),
                (h as f64 / 4.0 - 0.5, 3.0 * w as f64 / 4.0 - 0.5),
                (3.0 * h as f64 / 4.0 - 0.5, w as f64 / 4.0 - 0.5),
                (3.0 * h as f64 / 4.0 - 0.5, 3.0 * w as f64 / 4.0 - 0.5),
            ],
            PlacementPolicy::Corners => [
                (0.0, 0.0),
                (0.0, (w - 1) as f64),
                ((h - 1) as f64, 0.0),
                ((h - 1) as f64, (w - 1) as f64),
            ],
        };
        for i in 0..self.mcs {
            let (ar, ac) = anchors[i % anchors.len()];
            let id = nearest_free(&free, ar, ac);
            free[id] = false;
            tiles[id] = TileKind::Mc;
        }
        // Keep the paper's 2.5 mm tile pitch so wireless range and wire
        // delay stay physically meaningful at every grid size.
        let die_mm = 2.5 * w as f64;
        Ok(SystemConfig { width: w, tiles, die_mm, ..SystemConfig::paper_8x8() })
    }
}

impl fmt::Display for Platform {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}x{}:cpus={},mcs={},placement={}",
            self.width,
            self.height,
            self.cpus,
            self.mcs,
            self.placement.as_str()
        )
    }
}

impl FromStr for Platform {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        let s = s.trim();
        let (grid, opts) = match s.split_once(':') {
            Some((g, o)) => (g, Some(o)),
            None => (s, None),
        };
        let bad_grid = || {
            WihetError::InvalidPlatform(format!(
                "bad grid '{grid}' (expected WIDTHxHEIGHT, e.g. 8x8)"
            ))
        };
        let (ws, hs) = grid
            .to_ascii_lowercase()
            .split_once('x')
            .map(|(a, b)| (a.trim().to_string(), b.trim().to_string()))
            .ok_or_else(bad_grid)?;
        let width: usize = ws.parse().map_err(|_| bad_grid())?;
        let height: usize = hs.parse().map_err(|_| bad_grid())?;
        let mut p = Platform::grid(width, height);
        // 8x8 is the paper preset exactly (grid() scaling agrees: 4 + 4).
        if let Some(opts) = opts {
            for tok in opts.split(',') {
                let tok = tok.trim();
                if tok.is_empty() {
                    continue;
                }
                let (k, v) = tok.split_once('=').ok_or_else(|| {
                    WihetError::InvalidPlatform(format!(
                        "bad platform option '{tok}' (expected key=value)"
                    ))
                })?;
                let uint = |v: &str, k: &str| {
                    v.trim().parse::<usize>().map_err(|_| {
                        WihetError::InvalidPlatform(format!("{k} expects an integer, got '{v}'"))
                    })
                };
                match k.trim().to_ascii_lowercase().as_str() {
                    "cpus" => p.cpus = uint(v, "cpus")?,
                    "mcs" => p.mcs = uint(v, "mcs")?,
                    "placement" => p.placement = v.parse()?,
                    other => {
                        return Err(WihetError::InvalidPlatform(format!(
                            "unknown platform option '{other}' (cpus, mcs, placement)"
                        )))
                    }
                }
            }
        }
        p.validate()?;
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_matches_paper_composition() {
        let sys = "8x8".parse::<Platform>().unwrap().build().unwrap();
        assert_eq!(sys.num_tiles(), 64);
        assert_eq!(sys.gpus().len(), 56);
        assert_eq!(sys.cpus().len(), 4);
        assert_eq!(sys.mcs().len(), 4);
        assert!((sys.die_mm - 20.0).abs() < 1e-9);
        // CPUs land in the paper's central 2x2 block
        for c in sys.cpus() {
            let (r, col) = (c / 8, c % 8);
            assert!((3..=4).contains(&r) && (3..=4).contains(&col), "CPU at {c}");
        }
        // one MC per quadrant
        let mut quads: Vec<(bool, bool)> =
            sys.mcs().iter().map(|&m| ((m / 8) < 4, (m % 8) < 4)).collect();
        quads.sort();
        quads.dedup();
        assert_eq!(quads.len(), 4);
    }

    #[test]
    fn paper_preset_is_placement_exact() {
        // "8x8" must reproduce SystemConfig::paper_8x8 tile-for-tile so
        // `--system 8x8` and the experiment harness evaluate the SAME
        // chip (placement_key equality implies identical caches too).
        let built = Platform::paper().build().unwrap();
        let seed = SystemConfig::paper_8x8();
        assert_eq!(built.tiles, seed.tiles);
        assert_eq!(built.placement_key(), seed.placement_key());
        assert_eq!(built.width, seed.width);
    }

    #[test]
    fn presets_scale_core_mix() {
        let p4 = "4x4".parse::<Platform>().unwrap();
        assert_eq!((p4.cpus, p4.mcs), (2, 2));
        let p12 = "12x12".parse::<Platform>().unwrap();
        assert_eq!((p12.cpus, p12.mcs), (9, 9));
        let sys = p12.build().unwrap();
        assert_eq!(sys.num_tiles(), 144);
        assert_eq!(sys.gpus().len(), 144 - 18);
    }

    #[test]
    fn custom_mix_and_rectangular() {
        let p: Platform = "6x4:cpus=2,mcs=4,placement=corners".parse().unwrap();
        assert_eq!((p.width, p.height, p.cpus, p.mcs), (6, 4, 2, 4));
        let sys = p.build().unwrap();
        assert_eq!(sys.num_tiles(), 24);
        assert_eq!(sys.mcs(), vec![0, 5, 18, 23]); // the four corners
        assert_eq!(sys.cpus().len(), 2);
    }

    #[test]
    fn invalid_shapes_are_typed_errors() {
        for bad in [
            "0x4", "axb", "8", "8x8:cpus=100", "8x8:cpus=0", "2x2:cpus=2,mcs=2",
            "8x8:frequency=3", "8x8:cpus", "70x70",
        ] {
            let e = bad.parse::<Platform>().unwrap_err();
            assert!(
                matches!(e, WihetError::InvalidPlatform(_)),
                "{bad} -> {e:?}"
            );
        }
    }

    #[test]
    fn display_roundtrips() {
        let p: Platform = "6x4:cpus=2,mcs=4,placement=corners".parse().unwrap();
        let q: Platform = p.to_string().parse().unwrap();
        assert_eq!(p, q);
    }
}

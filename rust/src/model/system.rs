//! Heterogeneous manycore system configuration (paper Table 2 / §5).
//!
//! 64 tiles on an 8x8 grid of a 20x20 mm die: 56 GPU tiles, 4 CPU tiles,
//! 4 MC tiles (each MC = 1 MB shared-L2 slice + DRAM port). The NoC clock
//! is 2.5 GHz; links are 128-bit, so one flit = 16 B moves per link-cycle.

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum TileKind {
    Gpu,
    Cpu,
    Mc,
}

impl TileKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            TileKind::Gpu => "GPU",
            TileKind::Cpu => "CPU",
            TileKind::Mc => "MC",
        }
    }
}

#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Grid width (tiles are laid out row-major on a `width x width` mesh).
    pub width: usize,
    /// Tile kind per tile id (row-major).
    pub tiles: Vec<TileKind>,
    /// NoC clock (Hz). Paper: routers run at 2.5 GHz.
    pub noc_clock_hz: f64,
    /// GPU core clock (Hz). Table 2: 1.5 GHz.
    pub gpu_clock_hz: f64,
    /// CPU core clock (Hz). Table 2: 2.5 GHz.
    pub cpu_clock_hz: f64,
    /// Die edge (mm). Paper §5.3.2: 20x20 mm die.
    pub die_mm: f64,
    /// Link width in bytes (one flit per cycle). 128-bit links.
    pub flit_bytes: u64,
    /// Cache-line / reply payload size in bytes.
    pub line_bytes: u64,
    /// MACs each GPU tile retires per GPU clock (SIMT width x SMs abstracted).
    pub gpu_macs_per_cycle: u64,
    /// L1 cache size per core (bytes); Table 2: 64 kB I + 64 kB D.
    pub l1_bytes: u64,
    /// Shared L2 per MC (bytes); Table 2: 1 MB.
    pub l2_bytes_per_mc: u64,
    /// Sustained DRAM bandwidth per MC in bytes per NoC cycle
    /// (10 B/cyc @ 2.5 GHz = 25 GB/s per channel — sized so CNN conv layers
    /// drive the baseline mesh to its saturation edge, the regime the
    /// paper characterizes in Fig 8).
    pub mc_bw_bytes_per_cycle: f64,
}

impl SystemConfig {
    /// The paper's 64-tile experimental platform: 56 GPU + 4 CPU + 4 MC.
    ///
    /// Placement follows §5.2's conclusion: CPUs in the center (the four
    /// innermost tiles), MCs at the center of each quadrant, GPUs elsewhere.
    pub fn paper_8x8() -> Self {
        let width = 8;
        let mut tiles = vec![TileKind::Gpu; width * width];
        // CPUs: central 2x2 block (tiles (3,3),(3,4),(4,3),(4,4)).
        for (r, c) in [(3, 3), (3, 4), (4, 3), (4, 4)] {
            tiles[r * width + c] = TileKind::Cpu;
        }
        // MCs: quadrant centers.
        for (r, c) in [(1, 1), (1, 6), (6, 1), (6, 6)] {
            tiles[r * width + c] = TileKind::Mc;
        }
        SystemConfig {
            width,
            tiles,
            noc_clock_hz: 2.5e9,
            gpu_clock_hz: 1.5e9,
            cpu_clock_hz: 2.5e9,
            die_mm: 20.0,
            flit_bytes: 16,
            line_bytes: 64,
            // Abstracted Maxwell SM: 128 CUDA cores/SM, 1 MAC each per clock.
            gpu_macs_per_cycle: 128,
            l1_bytes: 64 * 1024,
            l2_bytes_per_mc: 1024 * 1024,
            mc_bw_bytes_per_cycle: 10.0,
        }
    }

    /// A small 4x4 variant (12 GPU, 2 CPU, 2 MC) for tests and the
    /// `design_custom_noc` example.
    pub fn small_4x4() -> Self {
        let width = 4;
        let mut tiles = vec![TileKind::Gpu; width * width];
        tiles[width + 1] = TileKind::Cpu;
        tiles[2 * width + 2] = TileKind::Cpu;
        tiles[width + 2] = TileKind::Mc;
        tiles[2 * width + 1] = TileKind::Mc;
        SystemConfig {
            width,
            tiles,
            ..SystemConfig::paper_8x8()
        }
    }

    pub fn num_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// Grid height in tiles (tiles are row-major over `width` columns).
    pub fn height(&self) -> usize {
        self.tiles.len() / self.width
    }

    /// Order-sensitive fingerprint of the tile-kind assignment. Two
    /// `SystemConfig`s with different placements (or grid shapes) hash
    /// differently; used by typed cache keys (`ScenarioKey`).
    pub fn placement_key(&self) -> u64 {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::{Hash, Hasher};
        let mut h = DefaultHasher::new();
        self.width.hash(&mut h);
        self.tiles.hash(&mut h);
        h.finish()
    }

    pub fn tiles_of(&self, kind: TileKind) -> Vec<usize> {
        (0..self.tiles.len())
            .filter(|&i| self.tiles[i] == kind)
            .collect()
    }

    pub fn gpus(&self) -> Vec<usize> {
        self.tiles_of(TileKind::Gpu)
    }

    pub fn cpus(&self) -> Vec<usize> {
        self.tiles_of(TileKind::Cpu)
    }

    pub fn mcs(&self) -> Vec<usize> {
        self.tiles_of(TileKind::Mc)
    }

    /// Tile center position in mm (row-major id).
    pub fn pos_mm(&self, tile: usize) -> (f64, f64) {
        let pitch = self.die_mm / self.width as f64;
        let r = (tile / self.width) as f64;
        let c = (tile % self.width) as f64;
        (pitch * (c + 0.5), pitch * (r + 0.5))
    }

    /// Euclidean distance between two tile centers (mm).
    pub fn dist_mm(&self, a: usize, b: usize) -> f64 {
        let (ax, ay) = self.pos_mm(a);
        let (bx, by) = self.pos_mm(b);
        ((ax - bx).powi(2) + (ay - by).powi(2)).sqrt()
    }

    /// Manhattan hop distance on the grid.
    pub fn hop_dist(&self, a: usize, b: usize) -> usize {
        let (ar, ac) = (a / self.width, a % self.width);
        let (br, bc) = (b / self.width, b % self.width);
        ar.abs_diff(br) + ac.abs_diff(bc)
    }

    /// Aggregate GPU MAC throughput (MACs/s) used by the compute-time model.
    pub fn gpu_total_macs_per_sec(&self) -> f64 {
        self.gpus().len() as f64 * self.gpu_macs_per_cycle as f64 * self.gpu_clock_hz
    }

    /// Replace the tile assignment (used by the placement optimizer).
    pub fn with_tiles(&self, tiles: Vec<TileKind>) -> Self {
        assert_eq!(tiles.len(), self.tiles.len());
        SystemConfig { tiles, ..self.clone() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_composition() {
        let s = SystemConfig::paper_8x8();
        assert_eq!(s.num_tiles(), 64);
        assert_eq!(s.gpus().len(), 56);
        assert_eq!(s.cpus().len(), 4);
        assert_eq!(s.mcs().len(), 4);
    }

    #[test]
    fn cpus_central_mcs_quadrants() {
        let s = SystemConfig::paper_8x8();
        // every CPU within 1 hop of die center rows/cols 3..4
        for c in s.cpus() {
            let (r, col) = (c / 8, c % 8);
            assert!((3..=4).contains(&r) && (3..=4).contains(&col));
        }
        // MCs one per quadrant
        let quads: Vec<(bool, bool)> = s
            .mcs()
            .iter()
            .map(|&m| ((m / 8) < 4, (m % 8) < 4))
            .collect();
        let mut uniq = quads.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 4);
    }

    #[test]
    fn geometry() {
        let s = SystemConfig::paper_8x8();
        let (x, y) = s.pos_mm(0);
        assert!((x - 1.25).abs() < 1e-9 && (y - 1.25).abs() < 1e-9);
        // opposite corners are ~17.68 mm apart (within the 20 mm WI range)
        let d = s.dist_mm(0, 63);
        assert!((d - (2.0f64 * 17.5 * 17.5).sqrt()).abs() < 1e-9);
        assert_eq!(s.hop_dist(0, 63), 14);
        assert_eq!(s.hop_dist(9, 9), 0);
    }

    #[test]
    fn placement_keys_track_placement() {
        let s = SystemConfig::paper_8x8();
        assert_eq!(s.placement_key(), SystemConfig::paper_8x8().placement_key());
        assert_eq!(s.height(), 8);
        let mut tiles = s.tiles.clone();
        tiles.swap(0, 27);
        assert_ne!(s.placement_key(), s.with_tiles(tiles).placement_key());
        assert_ne!(s.placement_key(), SystemConfig::small_4x4().placement_key());
    }

    #[test]
    fn small_variant() {
        let s = SystemConfig::small_4x4();
        assert_eq!(s.num_tiles(), 16);
        assert_eq!(s.cpus().len(), 2);
        assert_eq!(s.mcs().len(), 2);
        assert_eq!(s.gpus().len(), 12);
    }
}

//! LeNet / CDBNet layer geometry — the Rust mirror of
//! `python/compile/shapes.py` (paper Table 1).
//!
//! This is re-derived independently rather than read from the manifest so
//! the NoC toolchain works without artifacts; `rust/tests/integration.rs`
//! cross-checks the two derivations through `artifacts/manifest.json`.

pub const BYTES_PER_ELEM: u64 = 4; // f32

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    MaxPool,
    AvgPool,
    Dense,
    Lrn,
}

impl LayerKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            LayerKind::Conv => "conv",
            LayerKind::MaxPool => "maxpool",
            LayerKind::AvgPool => "avgpool",
            LayerKind::Dense => "dense",
            LayerKind::Lrn => "lrn",
        }
    }

    /// Short label used in the paper's per-layer figures (C/P/F).
    pub fn tag(&self) -> char {
        match self {
            LayerKind::Conv => 'C',
            LayerKind::MaxPool | LayerKind::AvgPool => 'P',
            LayerKind::Dense => 'F',
            LayerKind::Lrn => 'N',
        }
    }
}

/// Training pass direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Pass {
    Forward,
    Backward,
}

/// (H, W, C) per-sample tensor shape.
pub type Shape3 = (usize, usize, usize);

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    pub name: String,
    pub kind: LayerKind,
    pub in_shape: Shape3,
    pub out_shape: Shape3,
    pub kernel: usize,
    pub stride: usize,
    pub same_padding: bool,
    pub ceil_mode: bool,
}

impl Layer {
    pub fn weight_count(&self) -> u64 {
        match self.kind {
            LayerKind::Conv => {
                let (_, _, ci) = self.in_shape;
                let (_, _, co) = self.out_shape;
                (self.kernel * self.kernel * ci * co + co) as u64
            }
            LayerKind::Dense => {
                let (ih, iw, ic) = self.in_shape;
                let (_, _, co) = self.out_shape;
                (ih * iw * ic * co + co) as u64
            }
            _ => 0,
        }
    }

    /// Forward-pass multiply-accumulates for a batch.
    pub fn macs(&self, batch: usize) -> u64 {
        let (oh, ow, oc) = self.out_shape;
        let (ih, iw, ic) = self.in_shape;
        let b = batch as u64;
        match self.kind {
            LayerKind::Conv => {
                b * (oh * ow * oc * self.kernel * self.kernel * ic) as u64
            }
            LayerKind::Dense => b * (ih * iw * ic * oc) as u64,
            LayerKind::MaxPool | LayerKind::AvgPool => {
                b * (oh * ow * oc * self.kernel * self.kernel) as u64
            }
            LayerKind::Lrn => b * (ih * iw * ic * 5) as u64,
        }
    }

    /// Backward-pass MACs: dX and dW GEMMs for weighted layers (~2x fwd),
    /// mask routing for pools, rescale for LRN.
    pub fn bwd_macs(&self, batch: usize) -> u64 {
        match self.kind {
            LayerKind::Conv | LayerKind::Dense => 2 * self.macs(batch),
            _ => self.macs(batch),
        }
    }

    pub fn in_bytes(&self, batch: usize) -> u64 {
        let (h, w, c) = self.in_shape;
        (batch * h * w * c) as u64 * BYTES_PER_ELEM
    }

    pub fn out_bytes(&self, batch: usize) -> u64 {
        let (h, w, c) = self.out_shape;
        (batch * h * w * c) as u64 * BYTES_PER_ELEM
    }

    pub fn weight_bytes(&self) -> u64 {
        self.weight_count() * BYTES_PER_ELEM
    }

    pub fn has_params(&self) -> bool {
        matches!(self.kind, LayerKind::Conv | LayerKind::Dense)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub input_shape: Shape3,
    pub num_classes: usize,
    pub layers: Vec<Layer>,
}

impl ModelSpec {
    fn cur(&self) -> Shape3 {
        self.layers
            .last()
            .map(|l| l.out_shape)
            .unwrap_or(self.input_shape)
    }

    fn conv(&mut self, name: &str, k: usize, co: usize, same: bool) -> &mut Self {
        let (ih, iw, ci) = self.cur();
        let (oh, ow) = if same { (ih, iw) } else { (ih - k + 1, iw - k + 1) };
        assert!(oh > 0 && ow > 0, "{name}: conv {k}x{k} does not fit {ih}x{iw}");
        self.layers.push(Layer {
            name: name.into(),
            kind: LayerKind::Conv,
            in_shape: (ih, iw, ci),
            out_shape: (oh, ow, co),
            kernel: k,
            stride: 1,
            same_padding: same,
            ceil_mode: false,
        });
        self
    }

    fn pool(
        &mut self,
        name: &str,
        kind: LayerKind,
        k: usize,
        s: usize,
        ceil_mode: bool,
    ) -> &mut Self {
        let (ih, iw, c) = self.cur();
        let dim = |i: usize| {
            if ceil_mode {
                (i - k).div_ceil(s) + 1
            } else {
                (i - k) / s + 1
            }
        };
        let (oh, ow) = (dim(ih), dim(iw));
        assert!(oh > 0 && ow > 0, "{name}: pool {k}/{s} does not fit {ih}x{iw}");
        self.layers.push(Layer {
            name: name.into(),
            kind,
            in_shape: (ih, iw, c),
            out_shape: (oh, ow, c),
            kernel: k,
            stride: s,
            same_padding: false,
            ceil_mode,
        });
        self
    }

    fn lrn(&mut self) -> &mut Self {
        let s = self.cur();
        self.layers.push(Layer {
            name: "LRN".into(),
            kind: LayerKind::Lrn,
            in_shape: s,
            out_shape: s,
            kernel: 5,
            stride: 1,
            same_padding: false,
            ceil_mode: false,
        });
        self
    }

    fn dense(&mut self, name: &str) -> &mut Self {
        let (ih, iw, c) = self.cur();
        self.layers.push(Layer {
            name: name.into(),
            kind: LayerKind::Dense,
            in_shape: (ih, iw, c),
            out_shape: (1, 1, self.num_classes),
            kernel: 0,
            stride: 1,
            same_padding: false,
            ceil_mode: false,
        });
        self
    }

    pub fn total_weight_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.weight_bytes()).sum()
    }

    pub fn total_macs(&self, batch: usize) -> u64 {
        self.layers.iter().map(|l| l.macs(batch)).sum()
    }

    pub fn layer(&self, name: &str) -> Option<&Layer> {
        self.layers.iter().find(|l| l.name == name)
    }
}

/// LeNet for MNIST (paper Table 1, MNIST row).
pub fn lenet() -> ModelSpec {
    let mut m = ModelSpec {
        name: "lenet".into(),
        input_shape: (33, 33, 1),
        num_classes: 10,
        layers: Vec::new(),
    };
    m.conv("C1", 5, 16, false);
    m.pool("P1", LayerKind::MaxPool, 2, 2, true);
    m.conv("C2", 5, 16, false);
    m.pool("P2", LayerKind::MaxPool, 2, 2, false);
    m.conv("C3", 5, 128, false);
    m.dense("F1");
    m
}

/// CDBNet for CIFAR-10 (paper Table 1, CIFAR-10 row).
pub fn cdbnet() -> ModelSpec {
    let mut m = ModelSpec {
        name: "cdbnet".into(),
        input_shape: (31, 31, 3),
        num_classes: 10,
        layers: Vec::new(),
    };
    m.conv("C1", 5, 32, true);
    m.pool("P1", LayerKind::MaxPool, 3, 2, false);
    m.lrn();
    m.conv("C2", 5, 32, true);
    m.pool("P2", LayerKind::AvgPool, 3, 2, false);
    m.conv("C3", 5, 64, true);
    m.pool("P3", LayerKind::AvgPool, 7, 7, false);
    m.dense("F1");
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lenet() {
        let m = lenet();
        assert_eq!(m.layer("C1").unwrap().out_shape, (29, 29, 16));
        assert_eq!(m.layer("C2").unwrap().out_shape, (11, 11, 16));
        assert_eq!(m.layer("C3").unwrap().out_shape, (1, 1, 128));
        assert_eq!(m.layers.last().unwrap().out_shape, (1, 1, 10));
    }

    #[test]
    fn table1_cdbnet() {
        let m = cdbnet();
        assert_eq!(m.layer("C1").unwrap().out_shape, (31, 31, 32));
        assert_eq!(m.layer("C2").unwrap().out_shape, (15, 15, 32));
        assert_eq!(m.layer("C3").unwrap().out_shape, (7, 7, 64));
        assert_eq!(m.layers.last().unwrap().out_shape, (1, 1, 10));
    }

    #[test]
    fn layer_chain_consistent() {
        for m in [lenet(), cdbnet()] {
            let mut cur = m.input_shape;
            for l in &m.layers {
                assert_eq!(l.in_shape, cur, "{} input mismatch", l.name);
                cur = l.out_shape;
            }
        }
    }

    #[test]
    fn lenet_param_count_matches_python() {
        // Same closed-form as python/tests/test_model.py
        let expect = (25 * 16 + 16) + (25 * 16 * 16 + 16) + (25 * 16 * 128 + 128) + (128 * 10 + 10);
        let total: u64 = lenet().layers.iter().map(|l| l.weight_count()).sum();
        assert_eq!(total, expect as u64);
    }

    #[test]
    fn conv_macs_formula() {
        let m = lenet();
        let c1 = m.layer("C1").unwrap();
        assert_eq!(c1.macs(4), 4 * 29 * 29 * 16 * 25);
        assert_eq!(c1.bwd_macs(4), 2 * c1.macs(4));
    }

    #[test]
    fn pools_have_no_weights() {
        for m in [lenet(), cdbnet()] {
            for l in &m.layers {
                if !l.has_params() {
                    assert_eq!(l.weight_count(), 0, "{}", l.name);
                }
            }
        }
    }

    #[test]
    fn byte_accounting() {
        let m = lenet();
        let c1 = m.layer("C1").unwrap();
        assert_eq!(c1.in_bytes(4), 4 * 33 * 33 * 4);
        assert_eq!(c1.weight_bytes(), (25 * 16 + 16) * 4);
    }
}

//! Lowering the gradient allreduce into the training timeline.
//!
//! [`extend_timeline`] appends one gated [`PhaseInstance`] per
//! [`CollectiveStep`] to an expanded [`TrainingTimeline`]. Each step's
//! on-chip side is the chip's share of the exchange: GPUs stream the
//! outgoing gradient shard out of (and the incoming one back into) the
//! memory-controller tiles — the chip's off-chip ports — so every
//! allreduce flit crosses the MCs and contends with whatever
//! backward-pass traffic is still in flight. The steps are chained
//! (allreduce steps serialize on the links) and *bucket-gated* on the
//! backward pass: reduce-scatter step `j` of `S` releases once the first
//! `ceil((j+1)·B/S)` backward phases have drained for every microbatch —
//! early steps overlap the tail of the backward pass, the last step
//! waits for the full gradient, exactly the bucketed-overlap shape of
//! production data-parallel trainers.
//!
//! [`run_fabric`] then runs the extended timeline through the gated
//! simulator (`NocSim::run_timeline` via
//! [`crate::schedule::run_expanded`]) and charges
//! the *inter-chip* hop of each step analytically from the alpha-beta
//! model: step `s` finishes at
//! `max(release[s], finish[s-1]) + ceil(scale · (alpha + beta·bytes))`,
//! and the iteration ends when both the chip's makespan and the wire
//! pipeline are done. `comm_overhead_pct` is the wire share of a
//! serialized iteration, `100·wire/(serial_ref + wire)` — its
//! denominator is constant for a given scenario, so the overhead is
//! strictly monotone in the chip count (pinned by `tests/fabric_sim.rs`).

use crate::error::WihetError;
use crate::faults::{FaultPlan, ResilienceStats};
use crate::model::cnn::{LayerKind, Pass};
use crate::model::SystemConfig;
use crate::noc::builder::NocInstance;
use crate::noc::sim::SimConfig;
use crate::schedule::{
    expand, run_expanded_obs, run_schedule_obs, PhaseInstance, SchedulePolicy, ScheduleReport,
    TrainingTimeline,
};
use crate::telemetry::Telemetry;
use crate::traffic::phases::{LayerPhase, TrafficModel};
use crate::traffic::trace::TraceConfig;

use super::collective::{steps, wire_bytes_per_chip, Collective, CollectiveStep};
use super::spec::Fabric;

/// One data-parallel training iteration on an `N`-chip fabric.
#[derive(Debug, Clone)]
pub struct FabricReport {
    pub fabric: Fabric,
    /// The resolved collective (never [`Collective::Auto`]).
    pub algorithm: Collective,
    /// Per-chip gated simulation — includes the allreduce groups'
    /// on-chip traffic for `chips > 1`; byte-identical to
    /// [`crate::schedule::run_schedule`] for the single-chip fabric.
    pub schedule: ScheduleReport,
    /// Gradient bytes allreduced per iteration (`ΣW` of the model).
    pub grad_bytes: u64,
    /// Exact wire volume per chip: `2·(N-1)/N · grad_bytes`.
    pub wire_bytes_per_chip: u64,
    /// Serialized collective steps.
    pub steps: usize,
    /// Trace-scaled serialized inter-chip time (alpha-beta charge).
    pub wire_cycles: u64,
    /// End of the iteration: chip makespan vs the wire pipeline,
    /// whichever drains last (trace-scaled cycles).
    pub iteration_cycles: u64,
    /// Wire share of a serialized iteration,
    /// `100 · wire / (serial_ref + wire)` — 0 for a single chip,
    /// strictly increasing with the chip count.
    pub comm_overhead_pct: f64,
    /// Fault-injection accounting: the per-chip simulation's stats plus
    /// the inter-chip tier's contribution (a degraded chip counts as one
    /// injected fault; dropped collective steps charge `drop` retries
    /// per step). All zeros under [`FaultPlan::none`].
    pub resilience: ResilienceStats,
}

/// Synthesize the on-chip traffic of one collective step: the outgoing
/// shard is read from the MCs, the incoming reduced shard written back.
fn allreduce_phase(step_idx: usize, bytes: u64, duration_cycles: u64) -> LayerPhase {
    LayerPhase {
        layer: format!("allreduce{step_idx}"),
        kind: LayerKind::Conv,
        pass: Pass::Backward,
        tag: format!("AR{step_idx}"),
        gpu_read_bytes: bytes,
        gpu_write_bytes: bytes,
        cpu_read_bytes: 0,
        cpu_write_bytes: 0,
        core_core_flits: 0,
        duration_cycles,
        gpu_tiles: Vec::new(),
    }
}

/// Append the collective's gated instances to an expanded timeline.
/// Returns the index of the first allreduce instance (the groups
/// `base..base+steps.len()` are the wire schedule, in order).
pub fn extend_timeline(
    tl: &mut TrainingTimeline,
    tm: &TrafficModel,
    sys: &SystemConfig,
    fabric: &Fabric,
    collective_steps: &[CollectiveStep],
) -> usize {
    let base = tl.instances.len();
    if collective_steps.is_empty() {
        return base;
    }
    let m_count = tl.microbatches;
    let n_phases = tm.phases.len();
    // the collective serializes on the chip's off-chip ports: one new
    // resource stage, so its steps also count toward bubble accounting
    let ar_stage = tl.num_stages;
    tl.num_stages += 1;
    // backward phases in lowered order: the last layer's gradient is
    // produced first, so bucket j of the reduce-scatter can ship as soon
    // as the first ceil((j+1)·B/S) backward phases are done
    let bwd: Vec<usize> =
        (0..n_phases).filter(|&p| tm.phases[p].pass == Pass::Backward).collect();
    let n_rs = collective_steps.iter().filter(|s| s.reduce_scatter).count().max(1);
    let mut rs_seen = 0usize;
    for (s, st) in collective_steps.iter().enumerate() {
        // pace the on-chip injection by the step's wire time: the MCs
        // can't accept the next shard faster than the link drains it
        let dur = fabric.step_cycles(st, sys.noc_clock_hz).max(1);
        let mut preds: Vec<u32> = Vec::new();
        if s > 0 {
            preds.push((base + s - 1) as u32);
        }
        if st.reduce_scatter && !bwd.is_empty() {
            rs_seen += 1;
            let need = (rs_seen * bwd.len()).div_ceil(n_rs);
            let gate_phase = bwd[need - 1];
            for m in 0..m_count {
                preds.push((gate_phase * m_count + m) as u32);
            }
        } else if s == 0 && base > 0 {
            // no backward phases to gate on: start after the last base
            // instance so the exchange still trails the compute
            preds.push((base - 1) as u32);
        }
        preds.sort_unstable();
        preds.dedup();
        tl.instances.push(PhaseInstance {
            // virtual phase id past the lowered list — only `traffic`
            // and `stage` are consumed downstream
            phase: n_phases + s,
            microbatch: 0,
            stage: ar_stage,
            traffic: allreduce_phase(s, st.bytes, dur),
        });
        tl.preds.push(preds);
    }
    base
}

/// Simulate one data-parallel iteration of `tm` on a `fabric` of
/// `inst`-NoC chips. `grad_bytes` is the model's total weight bytes
/// (each chip holds a full replica and allreduces its gradient).
pub fn run_fabric(
    sys: &SystemConfig,
    inst: &NocInstance,
    tm: &TrafficModel,
    policy: &SchedulePolicy,
    fabric: &Fabric,
    grad_bytes: u64,
    cfg: &TraceConfig,
) -> Result<FabricReport, WihetError> {
    run_fabric_faults(sys, inst, tm, policy, fabric, grad_bytes, cfg, &FaultPlan::none())
}

/// [`run_fabric`] under a [`FaultPlan`]. On-chip faults (dead links, jam
/// windows) thread into the per-chip gated simulation; chip-tier faults
/// degrade the analytic inter-chip pipeline: `chip:n=K,slow=Sx` makes
/// the slowest replica gate every collective step (the whole ring moves
/// at the straggler's pace, so each step's wire time is multiplied by
/// `S`), and `drop=R` charges `R` retries per step — each retry repeats
/// the step's transfer and pays an exponential-backoff timeout of
/// `alpha · (2^r - 1)` before the link is trusted again.
#[allow(clippy::too_many_arguments)]
pub fn run_fabric_faults(
    sys: &SystemConfig,
    inst: &NocInstance,
    tm: &TrafficModel,
    policy: &SchedulePolicy,
    fabric: &Fabric,
    grad_bytes: u64,
    cfg: &TraceConfig,
    plan: &FaultPlan,
) -> Result<FabricReport, WihetError> {
    run_fabric_obs(sys, inst, tm, policy, fabric, grad_bytes, cfg, plan, None)
}

/// [`run_fabric_faults`] with an optional telemetry sink: per-chip
/// simulation metrics plus timeline spans for every phase instance,
/// collective step, and analytic inter-chip wire hop (category
/// `"fabric"`, on a track one past the last pipeline stage). Reports
/// are byte-identical with or without the sink.
#[allow(clippy::too_many_arguments)]
pub fn run_fabric_obs(
    sys: &SystemConfig,
    inst: &NocInstance,
    tm: &TrafficModel,
    policy: &SchedulePolicy,
    fabric: &Fabric,
    grad_bytes: u64,
    cfg: &TraceConfig,
    plan: &FaultPlan,
    mut tel: Option<&mut Telemetry>,
) -> Result<FabricReport, WihetError> {
    fabric.validate()?;
    let algorithm = fabric.collective.resolve(fabric.chips, grad_bytes);
    if fabric.is_single() {
        // degenerate fabric: the unmodified single-chip path,
        // byte-identical to `run_schedule` (pinned by tests); chip-tier
        // faults are inert without collective steps
        let schedule = run_schedule_obs(sys, inst, tm, policy, cfg, plan, tel)?;
        let iteration_cycles = schedule.makespan;
        let resilience = schedule.sim.resilience.clone();
        return Ok(FabricReport {
            fabric: *fabric,
            algorithm,
            schedule,
            grad_bytes,
            wire_bytes_per_chip: 0,
            steps: 0,
            wire_cycles: 0,
            iteration_cycles,
            comm_overhead_pct: 0.0,
            resilience,
        });
    }

    let fx = if plan.has_noc_faults() {
        let nominal = SimConfig::default().nominal_flits;
        Some(plan.compile(&inst.topo, &inst.routes, &inst.air, nominal)?)
    } else {
        None
    };
    let st = steps(algorithm, fabric.chips, grad_bytes);
    let mut tl = expand(tm, policy)?;
    let first_ar = extend_timeline(&mut tl, tm, sys, fabric, &st);
    let serial_ref: u64 = tm.phases.iter().map(|p| cfg.window(p.duration_cycles)).sum();
    let (schedule, release) =
        run_expanded_obs(sys, inst, &tl, cfg, serial_ref, fx.as_ref(), tel.as_deref_mut());

    // straggler-aware degradation of the wire tier: every collective
    // step moves at the slowest replica's pace, and a flaky link repeats
    // each step `drop` times with exponential-backoff timeouts
    let slow = u64::from(plan.chip_slow_x.max(1));
    let drop = u64::from(if plan.chip_n > 0 { plan.chip_drop } else { 0 });
    let alpha_cycles = ((fabric.alpha_seconds() * sys.noc_clock_hz * cfg.scale).ceil() as u64)
        .max(1);

    // analytic inter-chip pipeline: each step's wire hop starts when its
    // on-chip group released (shard staged at the MCs) and the previous
    // hop finished; charged at the trace scale like every other duration
    let mut wire_cycles = 0u64;
    let mut finish = 0u64;
    for (i, s) in st.iter().enumerate() {
        let w = ((fabric.step_cycles(s, sys.noc_clock_hz) as f64 * cfg.scale).ceil() as u64)
            .max(1);
        let w_slow = w * slow;
        let w_eff = w_slow + drop * w_slow + alpha_cycles * ((1u64 << drop) - 1);
        wire_cycles += w_eff;
        let rel = match release.get(first_ar + i) {
            Some(&r) if r != u64::MAX => r,
            _ => 0,
        };
        let start = finish.max(rel);
        finish = start + w_eff;
        if let Some(sink) = tel.as_deref_mut() {
            // wire hops render one track past the last pipeline stage
            sink.span(format!("wire AR{i}"), "fabric", tl.num_stages as u32, start, finish);
        }
    }
    let iteration_cycles = schedule.makespan.max(finish);
    let comm_overhead_pct =
        100.0 * wire_cycles as f64 / (serial_ref + wire_cycles).max(1) as f64;

    let mut resilience = schedule.sim.resilience.clone();
    if plan.chip_n > 0 {
        resilience.faults_injected += 1;
        resilience.retries += drop * st.len() as u64;
    }

    Ok(FabricReport {
        fabric: *fabric,
        algorithm,
        schedule,
        grad_bytes,
        wire_bytes_per_chip: wire_bytes_per_chip(fabric.chips, grad_bytes),
        steps: st.len(),
        wire_cycles,
        iteration_cycles,
        comm_overhead_pct,
        resilience,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::builder::mesh_opt;
    use crate::schedule::run_schedule;
    use crate::workload::{lower_id, MappingPolicy};
    use crate::ModelId;

    fn setup() -> (SystemConfig, NocInstance, TrafficModel, u64) {
        let sys = SystemConfig::paper_8x8();
        let inst = mesh_opt(&sys, true);
        let tm = lower_id(
            &ModelId::LeNet,
            &MappingPolicy::LayerPipelined { stages: 2 },
            &sys,
            32,
        )
        .unwrap();
        let grad = ModelId::LeNet.spec().total_weight_bytes();
        (sys, inst, tm, grad)
    }

    #[test]
    fn extend_appends_gated_steps() {
        let (sys, _inst, tm, grad) = setup();
        let fabric: Fabric = "4:topo=ring".parse().unwrap();
        let st = steps(Collective::Ring, 4, grad);
        let policy = SchedulePolicy::GPipe { microbatches: 4 };
        let mut tl = expand(&tm, &policy).unwrap();
        let base_n = tl.instances.len();
        let base_stages = tl.num_stages;
        let first = extend_timeline(&mut tl, &tm, &sys, &fabric, &st);
        assert_eq!(first, base_n);
        assert_eq!(tl.instances.len(), base_n + st.len());
        assert_eq!(tl.num_stages, base_stages + 1);
        // chained, and every reduce-scatter step gated on backward work
        for (i, s) in st.iter().enumerate() {
            let preds = &tl.preds[base_n + i];
            if i > 0 {
                assert!(preds.contains(&((base_n + i - 1) as u32)), "step {i} not chained");
            }
            if s.reduce_scatter {
                assert!(
                    preds.iter().any(|&p| (p as usize) < base_n),
                    "reduce-scatter step {i} not gated on the backward pass"
                );
            }
            let t = &tl.instances[base_n + i].traffic;
            assert_eq!(t.gpu_read_bytes, s.bytes);
            assert_eq!(t.gpu_write_bytes, s.bytes);
        }
        // the last reduce-scatter step waits on the *last* backward phase
        let last_rs = st.iter().rposition(|s| s.reduce_scatter).unwrap();
        let last_bwd = (0..tm.phases.len())
            .rev()
            .find(|&p| tm.phases[p].pass == Pass::Backward)
            .unwrap();
        let want = (last_bwd * tl.microbatches) as u32;
        assert!(tl.preds[base_n + last_rs].iter().any(|&p| p >= want && (p as usize) < base_n));
    }

    #[test]
    fn single_chip_fabric_matches_run_schedule() {
        let (sys, inst, tm, grad) = setup();
        let cfg = TraceConfig { scale: 0.05, ..Default::default() };
        let policy = SchedulePolicy::GPipe { microbatches: 4 };
        let fr =
            run_fabric(&sys, &inst, &tm, &policy, &Fabric::single(), grad, &cfg).unwrap();
        let sr = run_schedule(&sys, &inst, &tm, &policy, &cfg).unwrap();
        assert_eq!(fr.schedule.sim.delivered_flits, sr.sim.delivered_flits);
        assert_eq!(fr.schedule.makespan, sr.makespan);
        assert_eq!(fr.iteration_cycles, sr.makespan);
        assert_eq!(fr.comm_overhead_pct, 0.0);
        assert_eq!(fr.wire_cycles, 0);
    }

    #[test]
    fn multi_chip_overhead_grows_and_delivers() {
        let (sys, inst, tm, grad) = setup();
        let cfg = TraceConfig { scale: 0.02, ..Default::default() };
        let policy = SchedulePolicy::OneFOneB { microbatches: 4 };
        let mut prev = 0.0f64;
        for chips in [2usize, 4, 8] {
            let fabric = Fabric { collective: Collective::Ring, ..Fabric::new(chips) };
            let fr = run_fabric(&sys, &inst, &tm, &policy, &fabric, grad, &cfg).unwrap();
            assert_eq!(fr.algorithm, Collective::Ring);
            assert_eq!(fr.schedule.sim.undelivered(), 0);
            assert_eq!(fr.wire_bytes_per_chip, wire_bytes_per_chip(chips, grad));
            assert!(fr.iteration_cycles >= fr.schedule.makespan);
            assert!(
                fr.comm_overhead_pct > prev,
                "chips={chips}: {} vs {prev}",
                fr.comm_overhead_pct
            );
            prev = fr.comm_overhead_pct;
        }
    }

    #[test]
    fn chip_degradation_slows_the_wire_tier() {
        let (sys, inst, tm, grad) = setup();
        let cfg = TraceConfig { scale: 0.02, ..Default::default() };
        let policy = SchedulePolicy::GPipe { microbatches: 4 };
        let fabric = Fabric { collective: Collective::Ring, ..Fabric::new(4) };
        let clean = run_fabric(&sys, &inst, &tm, &policy, &fabric, grad, &cfg).unwrap();
        assert_eq!(clean.resilience, ResilienceStats::default());

        // FaultPlan::none() delegates byte-identically
        let none = run_fabric_faults(
            &sys, &inst, &tm, &policy, &fabric, grad, &cfg, &FaultPlan::none(),
        )
        .unwrap();
        assert_eq!(none.wire_cycles, clean.wire_cycles);
        assert_eq!(none.iteration_cycles, clean.iteration_cycles);
        assert_eq!(none.schedule.makespan, clean.schedule.makespan);
        assert_eq!(none.resilience, ResilienceStats::default());

        // a 4x straggler gates every ring step: exactly 4x the wire time
        let plan: FaultPlan = "chip:n=1,slow=4x".parse().unwrap();
        let slow =
            run_fabric_faults(&sys, &inst, &tm, &policy, &fabric, grad, &cfg, &plan).unwrap();
        assert_eq!(slow.wire_cycles, 4 * clean.wire_cycles);
        assert!(slow.iteration_cycles >= clean.iteration_cycles);
        assert!(slow.comm_overhead_pct > clean.comm_overhead_pct);
        assert_eq!(slow.resilience.faults_injected, 1);
        assert_eq!(slow.resilience.retries, 0);
        // the on-chip side is untouched by chip-tier faults
        assert_eq!(slow.schedule.makespan, clean.schedule.makespan);

        // dropped steps charge retries + backoff on top of the transfer
        let plan: FaultPlan = "chip:n=1,drop=2".parse().unwrap();
        let flaky =
            run_fabric_faults(&sys, &inst, &tm, &policy, &fabric, grad, &cfg, &plan).unwrap();
        assert!(flaky.wire_cycles > 3 * clean.wire_cycles, "2 retries repeat each step twice");
        assert_eq!(flaky.resilience.faults_injected, 1);
        assert_eq!(flaky.resilience.retries, 2 * flaky.steps as u64);

        // chip faults are inert on the single-chip fabric
        let single = run_fabric_faults(
            &sys, &inst, &tm, &policy, &Fabric::single(), grad, &cfg, &plan,
        )
        .unwrap();
        assert_eq!(single.resilience, ResilienceStats::default());
        assert_eq!(single.wire_cycles, 0);
    }
}

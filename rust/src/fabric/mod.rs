//! The fabric subsystem: multi-chip data-parallel training.
//!
//! One chip — one [`crate::platform::Platform`] instance with its
//! cycle-simulated NoC — trains on a shard of the batch; `N` chips form
//! a [`Fabric`] joined by alpha-beta links that allreduce the weight
//! gradients every iteration:
//!
//! ```text
//!   Fabric descriptor                (fabric::spec, `--fabric`)
//!      │  N chips, alpha (link latency), beta (1/bandwidth),
//!      │  topo = ring | tree | hierarchical | auto
//!      ▼
//!   Collective wire schedule         (fabric::collective)
//!      │  reduce-scatter + allgather steps; every algorithm moves
//!      │  exactly 2·(N-1)/N · ΣW bytes per chip
//!      ▼
//!   timeline extension               (fabric::lower::extend_timeline)
//!      │  one gated PhaseInstance per step: the shard crosses the
//!      │  chip's MC tiles and overlaps the backward pass
//!      ▼
//!   gated sim + alpha-beta charge    (fabric::lower::run_fabric)
//!      FabricReport: iteration cycles, wire cycles,
//!      comm-overhead %, per-chip ScheduleReport
//! ```
//!
//! On-chip contention stays cycle-accurate (`NocSim::run_timeline`); the
//! inter-chip hops are charged analytically — the DiHydrogen
//! `perfmodel.py` approach (SNIPPETS.md §1). `fabric=1` is byte-identical
//! to the single-chip path (pinned by `tests/fabric_sim.rs`). Entry
//! points: parse a [`Fabric`] (`Scenario::with_fabric`, CLI `--fabric`),
//! then [`run_fabric`] — or [`crate::energy::full_system_run_fabric`] /
//! [`crate::coordinator::cosimulate_fabric`] for energy-and-EDP reports,
//! and the registered `scale_figs` experiment for the 1/2/4/8-chip
//! scaling study.

pub mod collective;
pub mod lower;
pub mod spec;

pub use collective::{steps, wire_bytes_per_chip, Collective, CollectiveStep};
pub use lower::{extend_timeline, run_fabric, run_fabric_faults, run_fabric_obs, FabricReport};
pub use spec::{Fabric, GRAMMAR};

//! Allreduce algorithms for the inter-chip gradient exchange.
//!
//! Every algorithm is lowered to a list of [`CollectiveStep`]s — the
//! serialized per-chip wire schedule — whose byte totals all obey the
//! same conservation law: with `N` chips and `V` gradient bytes, each
//! chip puts exactly `2·(N-1)/N · V` bytes on the wire (reduce-scatter
//! moves `(N-1)/N · V`, allgather moves it back). The algorithms differ
//! only in *how many* steps carry those bytes, which is what trades the
//! latency term (`alpha` per step) against the bandwidth term
//! (`beta`-charged bytes):
//!
//! * [`Collective::Ring`] — `2(N-1)` equal steps of `V/N`:
//!   bandwidth-optimal, latency-heavy (the classic Baidu/NCCL ring).
//! * [`Collective::Tree`] — recursive halving/doubling
//!   (Rabenseifner): `2·ceil(log2 N)` steps with geometrically
//!   shrinking volumes: latency-optimal for small messages.
//! * [`Collective::Hierarchical`] — chips pair up (groups of 2),
//!   reduce-scatter inside the package over cheap intra links, ring over
//!   the group leaders, allgather back — the two-tier shape used on
//!   multi-GPU nodes.
//! * [`Collective::Auto`] — the DiHydrogen `perfmodel.py` switch: ring
//!   when the per-chip chunk `V/N` reaches the large-message threshold
//!   (`2^9` 4-byte words), tree below it.
//!
//! The conservation law is structural: step volumes are a
//! cumulative-rounding partition of the exact wire total, so rounding
//! can never create or destroy bytes (pinned by `tests/fabric_sim.rs`).

use std::fmt;
use std::str::FromStr;

use crate::error::WihetError;

use super::spec::{Fabric, GRAMMAR};

/// Gradient word size the auto-switch threshold is counted in.
pub const WORD_BYTES: u64 = 4;
/// Large-message threshold in words (DiHydrogen: `2**9`).
pub const LARGE_MESSAGE_WORDS: u64 = 1 << 9;
/// Per-chunk byte size at which [`Collective::Auto`] picks the ring.
pub const LARGE_MESSAGE_THRESH_BYTES: u64 = WORD_BYTES * LARGE_MESSAGE_WORDS;
/// Intra-package links are shorter: their alpha is this fraction of the
/// inter-chip alpha (they share the beta — the SerDes rate is the same).
pub const INTRA_ALPHA_DIV: f64 = 4.0;

/// Allreduce algorithm selector (the `topo=` key of the fabric grammar).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Collective {
    /// Message-size-based switch: ring for large chunks, tree for small.
    #[default]
    Auto,
    Ring,
    Tree,
    Hierarchical,
}

impl Collective {
    /// Resolve [`Collective::Auto`] against a concrete gradient size.
    /// Never resolves to `Hierarchical` (that shape is opt-in).
    pub fn resolve(self, chips: usize, grad_bytes: u64) -> Collective {
        match self {
            Collective::Auto => {
                if chips <= 1 || grad_bytes / chips.max(1) as u64 >= LARGE_MESSAGE_THRESH_BYTES {
                    Collective::Ring
                } else {
                    Collective::Tree
                }
            }
            other => other,
        }
    }
}

impl fmt::Display for Collective {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(match self {
            Collective::Auto => "auto",
            Collective::Ring => "ring",
            Collective::Tree => "tree",
            Collective::Hierarchical => "hierarchical",
        })
    }
}

impl FromStr for Collective {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(Collective::Auto),
            "ring" => Ok(Collective::Ring),
            "tree" => Ok(Collective::Tree),
            "hier" | "hierarchical" => Ok(Collective::Hierarchical),
            other => Err(WihetError::InvalidArg(format!(
                "unknown collective '{other}'\n{GRAMMAR}"
            ))),
        }
    }
}

/// One serialized inter-chip exchange step of the allreduce.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CollectiveStep {
    /// Bytes every chip sends (and receives) in this step.
    pub bytes: u64,
    /// Intra-package step (hierarchical only): alpha is divided by
    /// [`INTRA_ALPHA_DIV`].
    pub intra: bool,
    /// Reduce-scatter half (gradients flowing in) vs allgather half.
    pub reduce_scatter: bool,
}

/// Exact per-chip wire volume of an `N`-chip allreduce over `grad_bytes`:
/// `floor(2·(N-1)·V / N)` — identical for every algorithm.
pub fn wire_bytes_per_chip(chips: usize, grad_bytes: u64) -> u64 {
    if chips <= 1 {
        return 0;
    }
    (2u128 * (chips as u128 - 1) * grad_bytes as u128 / chips as u128) as u64
}

fn ceil_log2(n: usize) -> usize {
    debug_assert!(n >= 2);
    (usize::BITS - (n - 1).leading_zeros()) as usize
}

/// Relative step weights (weight, intra, reduce_scatter) per algorithm.
fn step_shape(alg: Collective, chips: usize) -> Vec<(f64, bool, bool)> {
    let mut shape = Vec::new();
    match alg {
        // Auto must be resolved by the caller; treat it as ring if not.
        Collective::Ring | Collective::Auto => {
            for i in 0..2 * (chips - 1) {
                shape.push((1.0, false, i < chips - 1));
            }
        }
        Collective::Tree => {
            let l = ceil_log2(chips);
            for i in 0..l {
                shape.push((0.5f64.powi(i as i32 + 1), false, true));
            }
            for i in (0..l).rev() {
                shape.push((0.5f64.powi(i as i32 + 1), false, false));
            }
        }
        Collective::Hierarchical => {
            // groups of 2: one intra pairwise exchange each way, a ring
            // over the group leaders in between. The intra step moves
            // V/2 vs the ring's V/N per step, hence weight N/2 : 1.
            let half = chips / 2;
            shape.push((half as f64, true, true));
            for i in 0..2 * (half - 1) {
                shape.push((1.0, false, i < half - 1));
            }
            shape.push((half as f64, true, false));
        }
    }
    shape
}

/// Cumulative-rounding partition of `total` bytes over the weighted step
/// shape: monotone running targets make every step non-negative and the
/// last step absorbs the remainder, so the sum is exactly `total`.
fn partition(total: u64, shape: &[(f64, bool, bool)]) -> Vec<CollectiveStep> {
    let wsum: f64 = shape.iter().map(|s| s.0).sum();
    let mut out = Vec::with_capacity(shape.len());
    let mut acc = 0.0f64;
    let mut assigned = 0u64;
    for (i, &(w, intra, reduce_scatter)) in shape.iter().enumerate() {
        acc += w;
        let target = if i + 1 == shape.len() {
            total
        } else {
            (((total as f64) * (acc / wsum)).round().min(total as f64) as u64).max(assigned)
        };
        out.push(CollectiveStep { bytes: target - assigned, intra, reduce_scatter });
        assigned = target;
    }
    out
}

/// Lower a resolved algorithm into its serialized wire schedule.
/// Empty for a single chip (nothing to exchange).
pub fn steps(alg: Collective, chips: usize, grad_bytes: u64) -> Vec<CollectiveStep> {
    if chips <= 1 {
        return Vec::new();
    }
    partition(wire_bytes_per_chip(chips, grad_bytes), &step_shape(alg, chips))
}

impl Fabric {
    /// Alpha-beta time of one step in seconds.
    pub fn step_seconds(&self, step: &CollectiveStep) -> f64 {
        let alpha =
            self.alpha_seconds() / if step.intra { INTRA_ALPHA_DIV } else { 1.0 };
        alpha + step.bytes as f64 / self.link_bytes_per_sec as f64
    }

    /// Alpha-beta time of one step in NoC cycles at `clock_hz`.
    pub fn step_cycles(&self, step: &CollectiveStep, clock_hz: f64) -> u64 {
        (self.step_seconds(step) * clock_hz).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conservation_for_every_algorithm() {
        for grad in [64u64, 2048, 1_000_003, 2_470_000] {
            for chips in [2usize, 3, 4, 8, 16] {
                let expect = wire_bytes_per_chip(chips, grad);
                let mut algs = vec![Collective::Ring, Collective::Tree];
                if chips % 2 == 0 {
                    algs.push(Collective::Hierarchical);
                }
                for alg in algs {
                    let st = steps(alg, chips, grad);
                    let total: u64 = st.iter().map(|s| s.bytes).sum();
                    assert_eq!(total, expect, "{alg} chips={chips} grad={grad}");
                }
            }
        }
        assert_eq!(wire_bytes_per_chip(1, 1 << 20), 0);
        assert_eq!(wire_bytes_per_chip(4, 1 << 20), 3 * (1 << 20) / 2);
    }

    #[test]
    fn step_counts_per_algorithm() {
        for chips in [2usize, 4, 8, 16] {
            assert_eq!(steps(Collective::Ring, chips, 1 << 20).len(), 2 * (chips - 1));
            assert_eq!(steps(Collective::Tree, chips, 1 << 20).len(), 2 * ceil_log2(chips));
            assert_eq!(
                steps(Collective::Hierarchical, chips, 1 << 20).len(),
                2 + 2 * (chips / 2 - 1)
            );
        }
        assert!(steps(Collective::Ring, 1, 1 << 20).is_empty());
        // reduce-scatter is the first half of the ring
        let st = steps(Collective::Ring, 4, 1 << 20);
        assert_eq!(st.iter().filter(|s| s.reduce_scatter).count(), 3);
        assert!(st.iter().all(|s| !s.intra));
        let h = steps(Collective::Hierarchical, 4, 1 << 20);
        assert!(h.first().unwrap().intra && h.last().unwrap().intra);
    }

    #[test]
    fn auto_switch_follows_message_size() {
        // chunk = grad/chips vs the 2048-byte threshold
        assert_eq!(Collective::Auto.resolve(4, 4 * 2048), Collective::Ring);
        assert_eq!(Collective::Auto.resolve(4, 4 * 2048 - 1), Collective::Tree);
        assert_eq!(Collective::Auto.resolve(1, 0), Collective::Ring);
        // explicit algorithms resolve to themselves
        assert_eq!(Collective::Tree.resolve(8, 1 << 30), Collective::Tree);
        assert_eq!(Collective::Hierarchical.resolve(8, 16), Collective::Hierarchical);
        assert_eq!(LARGE_MESSAGE_THRESH_BYTES, 2048);
    }

    #[test]
    fn wire_time_grows_with_chip_count() {
        let f = Fabric::new(2);
        let clock = 2.5e9;
        for alg in [Collective::Ring, Collective::Tree, Collective::Hierarchical] {
            let mut prev = 0u64;
            for chips in [2usize, 4, 8] {
                let total: u64 = steps(alg, chips, 2_470_000)
                    .iter()
                    .map(|s| f.step_cycles(s, clock))
                    .sum();
                assert!(total > prev, "{alg} chips={chips}: {total} vs {prev}");
                prev = total;
            }
        }
    }

    #[test]
    fn step_time_is_alpha_plus_beta() {
        let f: Fabric = "2:alpha=1us,beta=1GBps".parse().unwrap();
        let s = CollectiveStep { bytes: 1_000_000, intra: false, reduce_scatter: true };
        // 1 us latency + 1 ms serialization
        assert!((f.step_seconds(&s) - 1.001e-3).abs() < 1e-9);
        let i = CollectiveStep { intra: true, ..s };
        assert!(f.step_seconds(&i) < f.step_seconds(&s));
        assert_eq!(f.step_cycles(&s, 2.5e9), 2_502_500);
    }

    #[test]
    fn collective_parse_roundtrip() {
        for (s, c) in [
            ("auto", Collective::Auto),
            ("ring", Collective::Ring),
            ("tree", Collective::Tree),
            ("hierarchical", Collective::Hierarchical),
        ] {
            assert_eq!(s.parse::<Collective>().unwrap(), c);
            assert_eq!(c.to_string(), s);
        }
        assert_eq!("hier".parse::<Collective>().unwrap(), Collective::Hierarchical);
        let e = "star".parse::<Collective>().unwrap_err();
        assert!(e.to_string().contains("ring|tree|hierarchical"), "{e}");
    }
}

//! The `Fabric` descriptor: N replicated chips plus an alpha-beta
//! inter-chip link model, parseable like platforms.
//!
//! A fabric layers *above* a [`crate::platform::Platform`]: every chip is
//! one instance of the platform running the same model on its own batch
//! shard (data-parallel training), and the chips exchange weight
//! gradients over point-to-point links each iteration. The links are not
//! cycle-simulated; they are charged analytically from a per-link
//! latency `alpha` and inverse-bandwidth `beta` (the DiHydrogen
//! `perfmodel.py` idiom — see SNIPPETS.md §1), which is the established
//! cheap way to model the off-chip tier while the on-chip NoC stays
//! cycle-accurate.
//!
//! Grammar (mirrors `--system` / `--schedule`):
//!
//! ```text
//! --fabric 4:alpha=1.2us,beta=25GBps,topo=ring
//! ```
//!
//! `alpha`/`beta` are stored as integers (picoseconds, bytes/second) so
//! `Fabric` can sit inside the `Hash + Eq` [`crate::Scenario`] /
//! [`crate::ScenarioKey`] types.

use std::fmt;
use std::str::FromStr;

use crate::error::WihetError;

use super::collective::Collective;

/// The `--fabric` grammar, embedded in every parse/validation error.
pub const GRAMMAR: &str = "fabric := <chips>[:<key>=<value>,...]   \
    keys: alpha=<link latency: ps|ns|us|ms, e.g. 1.2us>, \
    beta=<link bandwidth: Bps|KBps|MBps|GBps|TBps or bit-rate b variants, e.g. 25GBps>, \
    topo=<ring|tree|hierarchical|auto>   \
    (1 <= chips <= 1024; hierarchical needs an even chip count; \
    defaults: alpha=1200ns, beta=25GBps, topo=auto)";

/// Default link latency: 1.2 us (DiHydrogen's inter-node alpha).
pub const DEFAULT_ALPHA_PS: u64 = 1_200_000;
/// Default link bandwidth: 25 GB/s (~1/3.893e-11 s per byte).
pub const DEFAULT_LINK_BYTES_PER_SEC: u64 = 25_000_000_000;

/// A data-parallel training fabric: `chips` replicas of the platform
/// joined by alpha-beta links running a gradient-allreduce each
/// iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Fabric {
    /// Number of chip replicas (1 = the single-chip system; the fabric
    /// layer then adds nothing and every report is byte-identical to the
    /// non-fabric path).
    pub chips: usize,
    /// Per-link latency in picoseconds.
    pub alpha_ps: u64,
    /// Per-link bandwidth in bytes/second (beta is its reciprocal).
    pub link_bytes_per_sec: u64,
    /// Allreduce algorithm for the gradient exchange.
    pub collective: Collective,
}

impl Fabric {
    /// The single-chip fabric — the [`crate::Scenario`] default.
    pub fn single() -> Self {
        Fabric::new(1)
    }

    /// `chips` replicas with the default link model and auto collective.
    pub fn new(chips: usize) -> Self {
        Fabric {
            chips,
            alpha_ps: DEFAULT_ALPHA_PS,
            link_bytes_per_sec: DEFAULT_LINK_BYTES_PER_SEC,
            collective: Collective::Auto,
        }
    }

    /// Whether this fabric is the degenerate single-chip case.
    pub fn is_single(&self) -> bool {
        self.chips <= 1
    }

    /// Link latency in seconds.
    pub fn alpha_seconds(&self) -> f64 {
        self.alpha_ps as f64 * 1e-12
    }

    /// Reject fabrics the collective lowering cannot schedule.
    pub fn validate(&self) -> Result<(), WihetError> {
        if self.chips == 0 {
            return Err(WihetError::InvalidArg(format!(
                "fabric '{self}' needs at least 1 chip\n{GRAMMAR}"
            )));
        }
        if self.chips > 1024 {
            return Err(WihetError::InvalidArg(format!(
                "fabric '{self}': more than 1024 chips is outside the model's regime\n{GRAMMAR}"
            )));
        }
        if self.link_bytes_per_sec == 0 {
            return Err(WihetError::InvalidArg(format!(
                "fabric '{self}': link bandwidth must be positive\n{GRAMMAR}"
            )));
        }
        if self.collective == Collective::Hierarchical && self.chips > 1 && self.chips % 2 != 0 {
            return Err(WihetError::InvalidArg(format!(
                "fabric '{self}': the hierarchical allreduce pairs chips into groups of 2 \
                 and needs an even chip count\n{GRAMMAR}"
            )));
        }
        Ok(())
    }
}

impl Default for Fabric {
    fn default() -> Self {
        Fabric::single()
    }
}

/// Largest time unit that renders `ps` as an integer.
fn fmt_time_ps(ps: u64) -> String {
    if ps > 0 && ps % 1_000_000_000 == 0 {
        format!("{}ms", ps / 1_000_000_000)
    } else if ps > 0 && ps % 1_000_000 == 0 {
        format!("{}us", ps / 1_000_000)
    } else if ps > 0 && ps % 1_000 == 0 {
        format!("{}ns", ps / 1_000)
    } else {
        format!("{ps}ps")
    }
}

/// Largest decimal byte-rate unit that renders `bps` as an integer.
fn fmt_bw(bps: u64) -> String {
    if bps > 0 && bps % 1_000_000_000 == 0 {
        format!("{}GBps", bps / 1_000_000_000)
    } else if bps > 0 && bps % 1_000_000 == 0 {
        format!("{}MBps", bps / 1_000_000)
    } else if bps > 0 && bps % 1_000 == 0 {
        format!("{}KBps", bps / 1_000)
    } else {
        format!("{bps}Bps")
    }
}

fn parse_time_ps(s: &str) -> Option<u64> {
    let t = s.trim().to_ascii_lowercase();
    let (num, mult) = if let Some(n) = t.strip_suffix("ps") {
        (n, 1.0)
    } else if let Some(n) = t.strip_suffix("ns") {
        (n, 1e3)
    } else if let Some(n) = t.strip_suffix("us") {
        (n, 1e6)
    } else if let Some(n) = t.strip_suffix("ms") {
        (n, 1e9)
    } else if let Some(n) = t.strip_suffix('s') {
        (n, 1e12)
    } else {
        return None;
    };
    let v: f64 = num.trim().parse().ok()?;
    if !v.is_finite() || v < 0.0 {
        return None;
    }
    Some((v * mult).round() as u64)
}

/// `25GBps` (bytes) / `200Gbps` (bits) style rates; decimal prefixes.
/// Case matters only for the `B`/`b` byte-vs-bit letter.
fn parse_bw(s: &str) -> Option<u64> {
    let t = s.trim();
    let (rest, bits) = if let Some(r) = t.strip_suffix("Bps") {
        (r, false)
    } else if let Some(r) = t.strip_suffix("bps") {
        (r, true)
    } else {
        return None;
    };
    let (num, scale) = match rest.chars().last() {
        Some('k') | Some('K') => (&rest[..rest.len() - 1], 1e3),
        Some('m') | Some('M') => (&rest[..rest.len() - 1], 1e6),
        Some('g') | Some('G') => (&rest[..rest.len() - 1], 1e9),
        Some('t') | Some('T') => (&rest[..rest.len() - 1], 1e12),
        _ => (rest, 1.0),
    };
    let v: f64 = num.trim().parse().ok()?;
    if !v.is_finite() || v < 0.0 {
        return None;
    }
    let bytes = v * scale / if bits { 8.0 } else { 1.0 };
    Some(bytes.round() as u64)
}

impl fmt::Display for Fabric {
    /// Canonical form: chip count plus only the non-default keys, so
    /// `Display` -> `FromStr` round-trips to the same value.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = self.chips.to_string();
        let mut kv: Vec<String> = Vec::new();
        if self.alpha_ps != DEFAULT_ALPHA_PS {
            kv.push(format!("alpha={}", fmt_time_ps(self.alpha_ps)));
        }
        if self.link_bytes_per_sec != DEFAULT_LINK_BYTES_PER_SEC {
            kv.push(format!("beta={}", fmt_bw(self.link_bytes_per_sec)));
        }
        if self.collective != Collective::Auto {
            kv.push(format!("topo={}", self.collective));
        }
        if !kv.is_empty() {
            s.push(':');
            s.push_str(&kv.join(","));
        }
        f.pad(&s)
    }
}

impl FromStr for Fabric {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        let t = s.trim();
        if t.is_empty() {
            return Err(WihetError::InvalidArg(format!("empty fabric spec\n{GRAMMAR}")));
        }
        let (head, rest) = match t.split_once(':') {
            Some((h, r)) => (h, Some(r)),
            None => (t, None),
        };
        let chips: usize = head.trim().parse().map_err(|_| {
            WihetError::InvalidArg(format!(
                "fabric '{t}': chip count must be an integer, e.g. '4' or '4:topo=ring'\n{GRAMMAR}"
            ))
        })?;
        let mut fabric = Fabric::new(chips);
        if let Some(rest) = rest {
            for kv in rest.split(',') {
                let (k, v) = kv.split_once('=').ok_or_else(|| {
                    WihetError::InvalidArg(format!(
                        "fabric '{t}': expected key=value, got '{kv}'\n{GRAMMAR}"
                    ))
                })?;
                match k.trim().to_ascii_lowercase().as_str() {
                    "alpha" => {
                        fabric.alpha_ps = parse_time_ps(v).ok_or_else(|| {
                            WihetError::InvalidArg(format!(
                                "fabric '{t}': alpha '{v}' is not a latency (try 1.2us or 800ns)\n{GRAMMAR}"
                            ))
                        })?;
                    }
                    "beta" => {
                        fabric.link_bytes_per_sec = parse_bw(v).ok_or_else(|| {
                            WihetError::InvalidArg(format!(
                                "fabric '{t}': beta '{v}' is not a bandwidth (try 25GBps or 200Gbps)\n{GRAMMAR}"
                            ))
                        })?;
                    }
                    "topo" => fabric.collective = v.parse()?,
                    other => {
                        return Err(WihetError::InvalidArg(format!(
                            "fabric '{t}': unknown key '{other}'\n{GRAMMAR}"
                        )));
                    }
                }
            }
        }
        fabric.validate()?;
        Ok(fabric)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_fills_defaults() {
        let f: Fabric = "4".parse().unwrap();
        assert_eq!(f.chips, 4);
        assert_eq!(f.alpha_ps, DEFAULT_ALPHA_PS);
        assert_eq!(f.link_bytes_per_sec, DEFAULT_LINK_BYTES_PER_SEC);
        assert_eq!(f.collective, Collective::Auto);
        assert!(Fabric::single().is_single());
        assert!(!f.is_single());
        assert_eq!(Fabric::default(), Fabric::single());
    }

    #[test]
    fn parse_units() {
        let f: Fabric = "2:alpha=1.2us,beta=25GBps,topo=ring".parse().unwrap();
        assert_eq!(f.alpha_ps, 1_200_000);
        assert_eq!(f.link_bytes_per_sec, 25_000_000_000);
        assert_eq!(f.collective, Collective::Ring);
        // bit-rate form: 200 Gbps = 25 GB/s
        let g: Fabric = "2:beta=200Gbps".parse().unwrap();
        assert_eq!(g.link_bytes_per_sec, 25_000_000_000);
        let h: Fabric = "2:alpha=800ns,beta=1500MBps".parse().unwrap();
        assert_eq!(h.alpha_ps, 800_000);
        assert_eq!(h.link_bytes_per_sec, 1_500_000_000);
        assert!((h.alpha_seconds() - 8e-7).abs() < 1e-18);
    }

    #[test]
    fn parse_and_display_roundtrip() {
        // canonical strings reproduce exactly
        for s in ["1", "4", "8:topo=hierarchical", "2:alpha=800ns,beta=100GBps,topo=tree"] {
            let f: Fabric = s.parse().unwrap();
            assert_eq!(f.to_string(), s);
            assert_eq!(f.to_string().parse::<Fabric>().unwrap(), f);
        }
        // non-canonical input round-trips by value
        let f: Fabric = "4:alpha=1.2us,beta=25GBps,topo=ring".parse().unwrap();
        assert_eq!(f.to_string().parse::<Fabric>().unwrap(), f);
        assert_eq!(f.to_string(), "4:topo=ring", "defaults are omitted");
    }

    #[test]
    fn errors_carry_the_grammar() {
        for bad in [
            "",
            "0",
            "x",
            "4:alpha",
            "4:alpha=fast",
            "4:beta=25",
            "4:topo=star",
            "4:chips=2",
            "2000",
        ] {
            let e = bad.parse::<Fabric>().unwrap_err();
            assert!(matches!(e, WihetError::InvalidArg(_)), "{bad}: {e:?}");
            let msg = e.to_string();
            assert!(
                msg.contains("topo=<ring|tree|hierarchical|auto>") && msg.contains("alpha="),
                "{bad}: {msg}"
            );
        }
    }

    #[test]
    fn hierarchical_needs_even_chips() {
        assert!("4:topo=hierarchical".parse::<Fabric>().is_ok());
        let e = "3:topo=hierarchical".parse::<Fabric>().unwrap_err();
        assert!(e.to_string().contains("even chip count"), "{e}");
        // chips=1 is the degenerate fabric: any topo is accepted
        assert!("1:topo=hierarchical".parse::<Fabric>().is_ok());
    }
}

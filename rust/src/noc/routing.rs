//! Routing: dimension-ordered XY / XY+YX for the mesh, delay-weighted
//! shortest path for irregular topologies, wireless path enabling
//! (§4.2.5: a wireless path is *enabled* only if it beats the wireline
//! path), and LASH virtual-layer assignment for deadlock freedom on
//! irregular routes, with ALASH's priority layering (high-f_ij pairs get
//! layers first).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::analysis::TrafficMatrix;
use super::topology::{LinkId, Topology};
use super::wireless::WirelessSpec;
use crate::model::SystemConfig;

/// One hop of a route: a wireline link traversal or a wireless shortcut.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Hop {
    Wire { link: LinkId, from: usize, to: usize },
    Air { channel: usize, from: usize, to: usize },
}

impl Hop {
    pub fn to(&self) -> usize {
        match *self {
            Hop::Wire { to, .. } | Hop::Air { to, .. } => to,
        }
    }

    pub fn from(&self) -> usize {
        match *self {
            Hop::Wire { from, .. } | Hop::Air { from, .. } => from,
        }
    }
}

/// A complete route with its LASH virtual layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Path {
    pub hops: Vec<Hop>,
    pub layer: u32,
    /// Cached zero-load latency estimate (cycles, nominal packet) — used
    /// by the simulator's ALASH wait-vs-fallback decisions.
    pub cost_est: u64,
}

impl Path {
    pub fn new(hops: Vec<Hop>, layer: u32) -> Self {
        Path { hops, layer, cost_est: 0 }
    }
}

impl Path {
    pub fn wire_hops(&self) -> usize {
        self.hops.iter().filter(|h| matches!(h, Hop::Wire { .. })).count()
    }

    pub fn has_air(&self) -> bool {
        self.hops.iter().any(|h| matches!(h, Hop::Air { .. }))
    }

    /// Zero-load latency estimate in cycles for path selection: per hop,
    /// router pipeline + link delay; wireless hops pay MAC + serialization
    /// of a nominal packet.
    pub fn zero_load_cost(&self, topo: &Topology, air: &WirelessSpec, nominal_flits: u64) -> u64 {
        let mut c = 0;
        for h in &self.hops {
            match *h {
                Hop::Wire { link, from, .. } => {
                    c += topo.router_delay(from) + topo.links[link].delay_cycles;
                }
                Hop::Air { channel, from, .. } => {
                    c += topo.router_delay(from)
                        + air.mac_overhead_cycles(channel)
                        + air.serialize_cycles(nominal_flits);
                }
            }
        }
        c
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingKind {
    /// Deterministic dimension-ordered XY (mesh baseline).
    Xy,
    /// Per-packet choice between minimal XY and YX [29].
    XyYx,
    /// Delay-weighted shortest path over an irregular wireline topology.
    ShortestPath,
    /// ShortestPath + enabled wireless shortcuts (ALASH adaptivity).
    Alash,
}

/// All candidate routes for every (src, dst) pair.
///
/// `candidates(s, d)` returns 1..=2 paths; the simulator picks at injection
/// time (wireless-first-if-free for ALASH, load-balanced for XY+YX).
#[derive(Debug, Clone)]
pub struct RouteSet {
    pub n: usize,
    pub kind: RoutingKind,
    cand: Vec<Vec<Path>>,
    pub num_layers: u32,
}

impl RouteSet {
    pub fn candidates(&self, src: usize, dst: usize) -> &[Path] {
        &self.cand[src * self.n + dst]
    }

    /// The deterministic primary path (wireline-only).
    pub fn primary(&self, src: usize, dst: usize) -> &Path {
        &self.cand[src * self.n + dst][0]
    }

    /// Wireless-enabled alternative if one was admitted.
    pub fn air_path(&self, src: usize, dst: usize) -> Option<&Path> {
        self.cand[src * self.n + dst].iter().find(|p| p.has_air())
    }

    // ------------------------------------------------------------- mesh

    /// Dimension-ordered XY on the system mesh.
    pub fn xy(sys: &SystemConfig, topo: &Topology) -> RouteSet {
        Self::mesh_routes(sys, topo, false)
    }

    /// XY with a YX alternate per pair (minimal adaptive of [29]).
    pub fn xy_yx(sys: &SystemConfig, topo: &Topology) -> RouteSet {
        Self::mesh_routes(sys, topo, true)
    }

    fn mesh_routes(sys: &SystemConfig, topo: &Topology, with_yx: bool) -> RouteSet {
        let n = sys.num_tiles();
        let w = sys.width;
        let mut cand = vec![Vec::new(); n * n];
        for s in 0..n {
            for d in 0..n {
                if s == d {
                    cand[s * n + d].push(Path::new(Vec::new(), 0));
                    continue;
                }
                let xy = mesh_walk(topo, w, s, d, true);
                // XY and YX are each deadlock-free on their own VC layer.
                let mut v = vec![Path::new(xy, 0)];
                if with_yx {
                    let yx = mesh_walk(topo, w, s, d, false);
                    if yx != v[0].hops {
                        v.push(Path::new(yx, 1));
                    }
                }
                cand[s * n + d] = v;
            }
        }
        let mut rs = RouteSet {
            n,
            kind: if with_yx { RoutingKind::XyYx } else { RoutingKind::Xy },
            cand,
            num_layers: if with_yx { 2 } else { 1 },
        };
        rs.fill_costs(topo, &WirelessSpec::new(0), 5);
        rs
    }

    /// Cache each candidate's zero-load cost estimate (used by ALASH's
    /// wait-vs-reroute decisions in the simulator).
    fn fill_costs(&mut self, topo: &Topology, air: &WirelessSpec, nominal_flits: u64) {
        for v in &mut self.cand {
            for p in v.iter_mut() {
                p.cost_est = p.zero_load_cost(topo, air, nominal_flits);
            }
        }
    }

    // -------------------------------------------------- irregular + air

    /// Delay-weighted shortest paths (Dijkstra, lowest-id tie-break), with
    /// LASH layering; `traffic` drives ALASH's priority layering order.
    pub fn shortest(topo: &Topology, traffic: Option<&TrafficMatrix>) -> RouteSet {
        let n = topo.n;
        let mut cand = vec![Vec::new(); n * n];
        let mut scratch = DijkstraScratch::new(n);
        let mut parent = vec![u32::MAX; n];
        for s in 0..n {
            dijkstra_into(topo, s, &mut parent, &mut scratch);
            for d in 0..n {
                let hops = walk_parents(topo, &parent, s, d);
                cand[s * n + d].push(Path::new(hops, 0));
            }
        }
        let mut rs = RouteSet { n, kind: RoutingKind::ShortestPath, cand, num_layers: 1 };
        rs.num_layers = lash_layering(topo, &mut rs.cand, n, traffic);
        rs.fill_costs(topo, &WirelessSpec::new(0), 5);
        rs
    }

    /// ALASH route set: shortest wireline paths + enabled wireless paths.
    ///
    /// For each pair, builds the best path of the form
    /// `src -(wire)-> WI_a =(air c)=> WI_b -(wire)-> dst` over the channels
    /// in `channels_for(src, dst)`, and admits it only if its zero-load
    /// cost beats the wireline path (§4.2.5 enabling rule).
    pub fn alash(
        topo: &Topology,
        air: &WirelessSpec,
        traffic: Option<&TrafficMatrix>,
        channels_for: impl Fn(usize, usize) -> Vec<usize>,
        nominal_flits: u64,
    ) -> RouteSet {
        Self::alash_with(topo, air, traffic, channels_for, |_, _| false, nominal_flits)
    }

    /// `alash` with a `force_air` predicate: pairs for which it returns
    /// true get their best wireless path regardless of the zero-load cost
    /// comparison — the paper's *dedicated* CPU-MC channel, whose value is
    /// QoS isolation under load, not zero-load latency.
    pub fn alash_with(
        topo: &Topology,
        air: &WirelessSpec,
        traffic: Option<&TrafficMatrix>,
        channels_for: impl Fn(usize, usize) -> Vec<usize>,
        force_air: impl Fn(usize, usize) -> bool,
        nominal_flits: u64,
    ) -> RouteSet {
        let mut rs = Self::shortest(topo, traffic);
        rs.kind = RoutingKind::Alash;
        if air.is_empty() {
            return rs;
        }
        let n = topo.n;
        // Precompute per-router wireline parent maps once, reusing one
        // Dijkstra scratch (heap + cost vector) across all sources.
        let mut scratch = DijkstraScratch::new(n);
        let all: Vec<Vec<u32>> = (0..n)
            .map(|s| {
                let mut parent = vec![u32::MAX; n];
                dijkstra_into(topo, s, &mut parent, &mut scratch);
                parent
            })
            .collect();
        for s in 0..n {
            for d in 0..n {
                if s == d {
                    continue;
                }
                let forced = force_air(s, d);
                let wire_cost = if forced {
                    u64::MAX
                } else {
                    rs.cand[s * n + d][0].zero_load_cost(topo, air, nominal_flits)
                };
                let mut best: Option<(u64, Path)> = None;
                for c in channels_for(s, d) {
                    let wis = air.on_channel(c);
                    // nearest WI to src / from dst by wireline cost
                    for wa in &wis {
                        for wb in &wis {
                            if wa.router == wb.router {
                                continue;
                            }
                            let head = walk_parents(topo, &all[s], s, wa.router);
                            let tail = walk_parents(topo, &all[wb.router], wb.router, d);
                            if (head.is_empty() && s != wa.router)
                                || (tail.is_empty() && wb.router != d)
                            {
                                continue;
                            }
                            let mut hops = head;
                            hops.push(Hop::Air { channel: c, from: wa.router, to: wb.router });
                            hops.extend(tail);
                            let p = Path::new(hops, 0);
                            let cost = p.zero_load_cost(topo, air, nominal_flits);
                            if cost < wire_cost
                                && best.as_ref().map(|(bc, _)| cost < *bc).unwrap_or(true)
                            {
                                best = Some((cost, p));
                            }
                        }
                    }
                }
                if let Some((_, mut p)) = best {
                    // Wireless paths ride the highest layer + 1: the air hop
                    // breaks any wireline dependency cycle on that layer.
                    p.layer = rs.num_layers;
                    rs.cand[s * n + d].push(p);
                }
            }
        }
        rs.num_layers += 1;
        rs.fill_costs(topo, air, nominal_flits);
        rs
    }

    // ------------------------------------------------------------ repair

    /// Re-route around dead wireline links (`dead[link] == true`): broken
    /// primaries are replaced by delay-weighted shortest paths over the
    /// residual topology and re-layered so every layer's channel-dependency
    /// graph stays acyclic; broken wireline alternates are dropped (the
    /// pair keeps its repaired primary); broken wireless candidates get
    /// their wire head/tail segments rebuilt around the faults, keeping
    /// their layer — the air hop breaks wireline dependency chains (see
    /// [`verify_lash`]). A pair disconnected by the faults keeps an
    /// empty-hops sentinel primary so the simulator can count it as
    /// undeliverable-after-repair instead of panicking.
    ///
    /// Returns the repaired set and the number of (src, dst) pairs whose
    /// candidates changed. With no dead links this is a plain clone.
    pub fn repaired(
        &self,
        topo: &Topology,
        air: &WirelessSpec,
        dead: &[bool],
        nominal_flits: u64,
    ) -> (RouteSet, u64) {
        debug_assert_eq!(dead.len(), topo.links.len());
        if !dead.iter().any(|&d| d) {
            return (self.clone(), 0);
        }
        const FRESH: u32 = u32::MAX; // re-layered below
        let n = self.n;
        let broken =
            |hops: &[Hop]| hops.iter().any(|h| matches!(*h, Hop::Wire { link, .. } if dead[link]));
        // Masked all-source parent maps over the residual topology,
        // reusing one Dijkstra scratch like `alash_with`.
        let mut scratch = DijkstraScratch::new(n);
        let all: Vec<Vec<u32>> = (0..n)
            .map(|s| {
                let mut parent = vec![u32::MAX; n];
                dijkstra_masked_into(topo, s, Some(dead), &mut parent, &mut scratch);
                parent
            })
            .collect();
        let mut rs = self.clone();
        let mut pairs_repaired = 0u64;
        for s in 0..n {
            for d in 0..n {
                if s == d {
                    continue;
                }
                let v = &mut rs.cand[s * n + d];
                let mut touched = false;
                let mut i = v.len();
                while i > 1 {
                    i -= 1;
                    if !broken(&v[i].hops) {
                        continue;
                    }
                    touched = true;
                    if v[i].has_air() {
                        match reroute_air(topo, &all, &v[i].hops, s, d) {
                            Some(hops) => {
                                let layer = v[i].layer;
                                v[i] = Path::new(hops, layer);
                            }
                            None => {
                                v.remove(i);
                            }
                        }
                    } else {
                        v.remove(i);
                    }
                }
                if broken(&v[0].hops) {
                    let hops = walk_parents(topo, &all[s], s, d);
                    v[0] = Path::new(hops, FRESH);
                    touched = true;
                }
                if touched {
                    pairs_repaired += 1;
                }
            }
        }
        // Re-layer the fresh primaries: seed each layer's dependency
        // graph with the surviving wireline paths (subsets of the
        // original acyclic graphs, so seeding cannot fail), then place
        // each fresh path in the first layer that stays acyclic.
        let ndl = topo.links.len() * 2;
        let dlink = |h: &Hop| -> usize {
            match *h {
                Hop::Wire { link, from, .. } => {
                    let l = &topo.links[link];
                    link * 2 + usize::from(from == l.b)
                }
                Hop::Air { .. } => unreachable!("air paths keep their layer"),
            }
        };
        let path_deps = |p: &Path| -> Vec<(usize, usize)> {
            p.hops.windows(2).map(|w| (dlink(&w[0]), dlink(&w[1]))).collect()
        };
        let mut layers: Vec<LayerDeps> = (0..rs.num_layers).map(|_| LayerDeps::new(ndl)).collect();
        for v in &rs.cand {
            for p in v {
                if p.has_air() || p.layer == FRESH || p.hops.is_empty() {
                    continue;
                }
                let ok = layers[p.layer as usize].try_insert(&path_deps(p));
                debug_assert!(ok, "surviving paths were jointly acyclic before the repair");
            }
        }
        for v in &mut rs.cand {
            for p in v.iter_mut() {
                if p.layer != FRESH {
                    continue;
                }
                let deps = path_deps(p);
                let mut placed = None;
                for (li, layer) in layers.iter_mut().enumerate() {
                    if layer.try_insert(&deps) {
                        placed = Some(li as u32);
                        break;
                    }
                }
                p.layer = placed.unwrap_or_else(|| {
                    let mut fresh = LayerDeps::new(ndl);
                    let ok = fresh.try_insert(&deps);
                    debug_assert!(ok, "single path must be acyclic");
                    layers.push(fresh);
                    (layers.len() - 1) as u32
                });
            }
        }
        rs.num_layers = layers.len() as u32;
        rs.fill_costs(topo, air, nominal_flits);
        (rs, pairs_repaired)
    }

    /// Fraction of pairs with an enabled wireless path.
    pub fn air_coverage(&self) -> f64 {
        let mut have = 0;
        let mut total = 0;
        for s in 0..self.n {
            for d in 0..self.n {
                if s == d {
                    continue;
                }
                total += 1;
                if self.air_path(s, d).is_some() {
                    have += 1;
                }
            }
        }
        have as f64 / total.max(1) as f64
    }

    /// Mean wire hop count over all pairs (primary paths).
    pub fn mean_hops(&self) -> f64 {
        let mut total = 0usize;
        let mut pairs = 0usize;
        for s in 0..self.n {
            for d in 0..self.n {
                if s != d {
                    total += self.primary(s, d).hops.len();
                    pairs += 1;
                }
            }
        }
        total as f64 / pairs.max(1) as f64
    }
}

fn mesh_walk(topo: &Topology, w: usize, s: usize, d: usize, x_first: bool) -> Vec<Hop> {
    let mut hops = Vec::new();
    let (mut r, mut c) = (s / w, s % w);
    let (dr, dc) = (d / w, d % w);
    let push = |from: (usize, usize), to: (usize, usize), hops: &mut Vec<Hop>| {
        let (f, t) = (from.0 * w + from.1, to.0 * w + to.1);
        let link = topo
            .link_between(f, t)
            .unwrap_or_else(|| panic!("mesh link {f}-{t} missing"));
        hops.push(Hop::Wire { link, from: f, to: t });
    };
    let go_x = |r: usize, c: &mut usize, hops: &mut Vec<Hop>| {
        while *c != dc {
            let nc = if dc > *c { *c + 1 } else { *c - 1 };
            push((r, *c), (r, nc), hops);
            *c = nc;
        }
    };
    let go_y = |r: &mut usize, c: usize, hops: &mut Vec<Hop>| {
        while *r != dr {
            let nr = if dr > *r { *r + 1 } else { *r - 1 };
            push((*r, c), (nr, c), hops);
            *r = nr;
        }
    };
    if x_first {
        go_x(r, &mut c, &mut hops);
        go_y(&mut r, c, &mut hops);
    } else {
        go_y(&mut r, c, &mut hops);
        go_x(r, &mut c, &mut hops);
    }
    hops
}

/// Reusable Dijkstra working set: the cost vector and the frontier heap
/// survive across the all-source loops in [`RouteSet::shortest`] and
/// [`RouteSet::alash_with`], which would otherwise reallocate both once
/// per source (2n allocations per route-set build).
struct DijkstraScratch {
    cost: Vec<u64>,
    heap: BinaryHeap<Reverse<(u64, usize)>>,
}

impl DijkstraScratch {
    fn new(n: usize) -> Self {
        DijkstraScratch { cost: Vec::with_capacity(n), heap: BinaryHeap::with_capacity(n) }
    }
}

/// Dijkstra over link delays + per-hop router delay; writes the parent
/// link per node into `parent`. Deterministic lowest-cost-then-id order.
fn dijkstra_into(topo: &Topology, src: usize, parent: &mut [u32], scratch: &mut DijkstraScratch) {
    dijkstra_masked_into(topo, src, None, parent, scratch)
}

/// [`dijkstra_into`] over a residual topology: links with `dead[link]`
/// set are skipped (identical relaxation order otherwise, so the
/// unmasked call stays byte-identical to the pre-repair code path).
fn dijkstra_masked_into(
    topo: &Topology,
    src: usize,
    dead: Option<&[bool]>,
    parent: &mut [u32],
    scratch: &mut DijkstraScratch,
) {
    let n = topo.n;
    debug_assert_eq!(parent.len(), n);
    parent.fill(u32::MAX);
    let cost = &mut scratch.cost;
    cost.clear();
    cost.resize(n, u64::MAX);
    let heap = &mut scratch.heap;
    heap.clear();
    cost[src] = 0;
    heap.push(Reverse((0u64, src)));
    while let Some(Reverse((c, r))) = heap.pop() {
        if c > cost[r] {
            continue;
        }
        for &(nbr, link) in topo.neighbors(r) {
            if dead.is_some_and(|m| m[link]) {
                continue;
            }
            let nc = c + topo.router_delay(r) + topo.links[link].delay_cycles;
            if nc < cost[nbr] || (nc == cost[nbr] && (link as u32) < parent[nbr]) {
                cost[nbr] = nc;
                parent[nbr] = link as u32;
                heap.push(Reverse((nc, nbr)));
            }
        }
    }
}

/// Rebuild the wire head/tail segments of an air path around dead links
/// using masked parent maps (`all[src]` from [`dijkstra_masked_into`]);
/// `None` when either segment became unreachable.
fn reroute_air(
    topo: &Topology,
    all: &[Vec<u32>],
    hops: &[Hop],
    s: usize,
    d: usize,
) -> Option<Vec<Hop>> {
    let pos = hops.iter().position(|h| matches!(h, Hop::Air { .. }))?;
    let (channel, wa, wb) = match hops[pos] {
        Hop::Air { channel, from, to } => (channel, from, to),
        Hop::Wire { .. } => unreachable!("position() found an air hop"),
    };
    let head = walk_parents(topo, &all[s], s, wa);
    if head.is_empty() && s != wa {
        return None;
    }
    let tail = walk_parents(topo, &all[wb], wb, d);
    if tail.is_empty() && wb != d {
        return None;
    }
    let mut out = head;
    out.push(Hop::Air { channel, from: wa, to: wb });
    out.extend(tail);
    Some(out)
}

fn walk_parents(topo: &Topology, parent: &[u32], src: usize, dst: usize) -> Vec<Hop> {
    if src == dst {
        return Vec::new();
    }
    let mut rev = Vec::new();
    let mut cur = dst;
    while cur != src {
        let l = parent[cur];
        if l == u32::MAX {
            return Vec::new(); // unreachable
        }
        let link = &topo.links[l as usize];
        let prev = if link.a == cur { link.b } else { link.a };
        rev.push(Hop::Wire { link: l as usize, from: prev, to: cur });
        cur = prev;
    }
    rev.reverse();
    rev
}

// ------------------------------------------------------------------ LASH

/// Assign each path to a virtual layer so that every layer's channel-
/// dependency graph (directed-link -> directed-link transitions) is
/// acyclic [45]. ALASH priority layering: pairs are processed in
/// descending traffic intensity so hot pairs land in low (less crowded)
/// layers. Returns the number of layers used.
fn lash_layering(
    topo: &Topology,
    cand: &mut [Vec<Path>],
    n: usize,
    traffic: Option<&TrafficMatrix>,
) -> u32 {
    let ndl = topo.links.len() * 2; // directed links
    let dlink = |h: &Hop| -> usize {
        match *h {
            Hop::Wire { link, from, .. } => {
                let l = &topo.links[link];
                link * 2 + usize::from(from == l.b)
            }
            Hop::Air { .. } => unreachable!("LASH runs on wireline paths"),
        }
    };

    // Process order: by descending f_ij, then by id.
    let mut order: Vec<(usize, usize)> = (0..n)
        .flat_map(|s| (0..n).map(move |d| (s, d)))
        .filter(|&(s, d)| s != d && !cand[s * n + d][0].hops.is_empty())
        .collect();
    if let Some(tm) = traffic {
        let mut weight = vec![0.0f64; n * n];
        for &(s, d, f) in &tm.entries {
            weight[s as usize * n + d as usize] = f;
        }
        order.sort_by(|a, b| {
            let wa = weight[a.0 * n + a.1];
            let wb = weight[b.0 * n + b.1];
            wb.partial_cmp(&wa).unwrap().then(a.cmp(b))
        });
    }

    let mut layers: Vec<LayerDeps> = vec![LayerDeps::new(ndl)];
    for (s, d) in order {
        let path = &cand[s * n + d][0];
        let deps: Vec<(usize, usize)> = path
            .hops
            .windows(2)
            .map(|w| (dlink(&w[0]), dlink(&w[1])))
            .collect();
        let mut placed = false;
        for (li, layer) in layers.iter_mut().enumerate() {
            if layer.try_insert(&deps) {
                cand[s * n + d][0].layer = li as u32;
                placed = true;
                break;
            }
        }
        if !placed {
            let mut fresh = LayerDeps::new(ndl);
            let ok = fresh.try_insert(&deps);
            debug_assert!(ok, "single path must be acyclic");
            cand[s * n + d][0].layer = layers.len() as u32;
            layers.push(fresh);
        }
    }
    layers.len() as u32
}

/// Channel-dependency graph of one virtual layer with incremental
/// insert-if-still-acyclic.
struct LayerDeps {
    adj: Vec<Vec<u32>>,
}

impl LayerDeps {
    fn new(ndl: usize) -> Self {
        LayerDeps { adj: vec![Vec::new(); ndl] }
    }

    /// Insert `deps` edges if the graph stays acyclic; rollback otherwise.
    fn try_insert(&mut self, deps: &[(usize, usize)]) -> bool {
        let mut added = Vec::new();
        for &(a, b) in deps {
            if !self.adj[a].contains(&(b as u32)) {
                self.adj[a].push(b as u32);
                added.push((a, b));
            }
        }
        if self.is_acyclic() {
            true
        } else {
            for (a, b) in added {
                let pos = self.adj[a].iter().position(|&x| x == b as u32).unwrap();
                self.adj[a].swap_remove(pos);
            }
            false
        }
    }

    fn is_acyclic(&self) -> bool {
        // iterative three-color DFS
        let n = self.adj.len();
        let mut color = vec![0u8; n]; // 0 white, 1 gray, 2 black
        let mut stack: Vec<(usize, usize)> = Vec::new();
        for start in 0..n {
            if color[start] != 0 {
                continue;
            }
            stack.push((start, 0));
            color[start] = 1;
            while let Some(&mut (node, ref mut idx)) = stack.last_mut() {
                if *idx < self.adj[node].len() {
                    let next = self.adj[node][*idx] as usize;
                    *idx += 1;
                    match color[next] {
                        0 => {
                            color[next] = 1;
                            stack.push((next, 0));
                        }
                        1 => return false,
                        _ => {}
                    }
                } else {
                    color[node] = 2;
                    stack.pop();
                }
            }
        }
        true
    }
}

/// Check that the route set's layering is deadlock-free: rebuild every
/// layer's dependency graph and verify acyclicity. Exposed for property
/// tests.
pub fn verify_lash(topo: &Topology, rs: &RouteSet) -> Result<(), String> {
    let ndl = topo.links.len() * 2;
    let mut per_layer: Vec<LayerDeps> = (0..rs.num_layers).map(|_| LayerDeps::new(ndl)).collect();
    for s in 0..rs.n {
        for d in 0..rs.n {
            for p in rs.candidates(s, d) {
                if p.has_air() {
                    continue; // air hop breaks wireline dependency chains
                }
                let deps: Vec<(usize, usize)> = p
                    .hops
                    .windows(2)
                    .map(|w| {
                        let dl = |h: &Hop| match *h {
                            Hop::Wire { link, from, .. } => {
                                let l = &topo.links[link];
                                link * 2 + usize::from(from == l.b)
                            }
                            Hop::Air { .. } => unreachable!(),
                        };
                        (dl(&w[0]), dl(&w[1]))
                    })
                    .collect();
                let layer = &mut per_layer[p.layer as usize];
                if !layer.try_insert(&deps) {
                    return Err(format!("cycle in layer {} via pair ({s},{d})", p.layer));
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemConfig;

    #[test]
    fn xy_routes_are_minimal_and_valid() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rs = RouteSet::xy(&sys, &topo);
        for s in 0..64 {
            for d in 0..64 {
                let p = rs.primary(s, d);
                assert_eq!(p.hops.len(), sys.hop_dist(s, d), "({s},{d})");
                // hops chain
                let mut cur = s;
                for h in &p.hops {
                    assert_eq!(h.from(), cur);
                    cur = h.to();
                }
                assert_eq!(cur, d);
            }
        }
    }

    #[test]
    fn xy_yx_gives_two_candidates_off_axis() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rs = RouteSet::xy_yx(&sys, &topo);
        assert_eq!(rs.candidates(0, 63).len(), 2);
        // same row: XY == YX, deduped
        assert_eq!(rs.candidates(0, 7).len(), 1);
        assert_eq!(rs.num_layers, 2);
        assert_eq!(rs.candidates(0, 63)[1].layer, 1);
    }

    #[test]
    fn xy_is_deadlock_free_by_construction() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rs = RouteSet::xy(&sys, &topo);
        verify_lash(&topo, &rs).expect("XY must be acyclic in one layer");
    }

    #[test]
    fn shortest_paths_on_irregular_topo() {
        let sys = SystemConfig::small_4x4();
        let mut topo = Topology::mesh(&sys);
        topo.add_link_with_geometry(&sys, 0, 15); // long shortcut
        let rs = RouteSet::shortest(&topo, None);
        let p = rs.primary(0, 15);
        // one long hop (delay ceil(10.6/2.5)=5) + router 3 = 8 vs
        // 6 hops * (3+1) = 24 -> shortcut wins
        assert_eq!(p.hops.len(), 1);
        verify_lash(&topo, &rs).expect("LASH layering must be acyclic");
    }

    #[test]
    fn lash_layers_cover_all_paths() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rs = RouteSet::shortest(&topo, None);
        assert!(rs.num_layers >= 1);
        verify_lash(&topo, &rs).unwrap();
    }

    #[test]
    fn alash_enables_beneficial_air_paths_only() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let mut air = WirelessSpec::new(2);
        air.add_wi(0, 1);
        air.add_wi(63, 1);
        let rs = RouteSet::alash(&topo, &air, None, |_, _| vec![1], 5);
        // far corner pair gets an air path...
        let p = rs.air_path(0, 63).expect("0->63 should ride wireless");
        assert_eq!(p.hops.len(), 1);
        assert!(p.has_air());
        // ...neighbors never do (wire cost 4 << mac+serialize)
        assert!(rs.air_path(0, 1).is_none());
        verify_lash(&topo, &rs).unwrap();
    }

    #[test]
    fn air_paths_may_use_wire_segments() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let mut air = WirelessSpec::new(2);
        air.add_wi(9, 1);
        air.add_wi(54, 1);
        let rs = RouteSet::alash(&topo, &air, None, |_, _| vec![1], 5);
        // 0 -> 63: wire to WI at 9, air to 54, wire to 63
        let p = rs.air_path(0, 63).expect("should be enabled");
        let air_pos = p.hops.iter().position(|h| matches!(h, Hop::Air { .. })).unwrap();
        assert_eq!(p.hops[air_pos].from(), 9);
        assert_eq!(p.hops[air_pos].to(), 54);
        let mut cur = 0;
        for h in &p.hops {
            assert_eq!(h.from(), cur);
            cur = h.to();
        }
        assert_eq!(cur, 63);
    }

    #[test]
    fn mean_hops_and_coverage() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rs = RouteSet::xy(&sys, &topo);
        // mean Manhattan distance over ordered pairs incl. self is
        // 2*(n^2-1)/(3n) = 5.25; excluding self pairs: 5.25*4096/4032
        assert!((rs.mean_hops() - 5.25 * 4096.0 / 4032.0).abs() < 1e-9);
        assert_eq!(rs.air_coverage(), 0.0);
    }

    #[test]
    fn repair_routes_around_a_dead_link() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rs = RouteSet::xy_yx(&sys, &topo);
        let mut dead = vec![false; topo.links.len()];
        let victim = topo.link_between(0, 1).expect("mesh edge exists");
        dead[victim] = true;
        let (fixed, pairs) = rs.repaired(&topo, &WirelessSpec::new(0), &dead, 5);
        assert!(pairs > 0, "many XY routes cross link 0-1");
        for s in 0..64 {
            for d in 0..64 {
                if s == d {
                    continue;
                }
                for p in fixed.candidates(s, d) {
                    assert!(
                        !p.hops.iter().any(|h| matches!(*h, Hop::Wire { link, .. } if link == victim)),
                        "({s},{d}) still crosses the dead link"
                    );
                    let mut cur = s;
                    for h in &p.hops {
                        assert_eq!(h.from(), cur);
                        cur = h.to();
                    }
                    assert_eq!(cur, d, "repaired path must still reach the destination");
                }
                // mesh minus one link stays connected: no sentinels
                assert!(!fixed.primary(s, d).hops.is_empty());
            }
        }
        verify_lash(&topo, &fixed).expect("repaired layering stays acyclic");
        // no dead links -> plain clone, nothing repaired
        let none = vec![false; topo.links.len()];
        let (same, zero) = rs.repaired(&topo, &WirelessSpec::new(0), &none, 5);
        assert_eq!(zero, 0);
        assert_eq!(same.num_layers, rs.num_layers);
        assert_eq!(same.candidates(0, 63), rs.candidates(0, 63));
    }

    #[test]
    fn repair_reroutes_air_segments_and_marks_disconnections() {
        // isolate corner 0 of a 4x4: every pair touching it is sentineled
        let sys = SystemConfig::small_4x4();
        let topo = Topology::mesh(&sys);
        let mut air = WirelessSpec::new(2);
        air.add_wi(5, 1);
        air.add_wi(15, 1);
        let rs = RouteSet::alash(&topo, &air, None, |_, _| vec![1], 5);
        let mut dead = vec![false; topo.links.len()];
        for &(_, l) in topo.neighbors(0) {
            dead[l] = true;
        }
        let (fixed, pairs) = rs.repaired(&topo, &air, &dead, 5);
        assert!(pairs > 0);
        assert!(fixed.primary(0, 5).hops.is_empty(), "router 0 is cut off");
        assert!(fixed.primary(5, 0).hops.is_empty());
        assert!(!fixed.primary(5, 6).hops.is_empty(), "the rest stays routable");
        // surviving air candidates avoid the dead links
        for s in 0..16 {
            for d in 0..16 {
                for p in fixed.candidates(s, d) {
                    assert!(!p.hops.iter().any(
                        |h| matches!(*h, Hop::Wire { link, .. } if dead[link])
                    ));
                }
            }
        }
        verify_lash(&topo, &fixed).expect("repair keeps LASH acyclic");
    }

    #[test]
    fn forced_air_ignores_cost_rule() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let mut air = WirelessSpec::new(1);
        air.add_wi(27, 0);
        air.add_wi(9, 0);
        // 27 -> 9 is 3 wire hops (cost 12) << air cost, so the plain rule
        // would reject it; force_air admits it anyway.
        let plain = RouteSet::alash(&topo, &air, None, |_, _| vec![0], 5);
        assert!(plain.air_path(27, 9).is_none());
        let forced = RouteSet::alash_with(
            &topo, &air, None, |_, _| vec![0], |s, d| (s, d) == (27, 9), 5,
        );
        assert!(forced.air_path(27, 9).is_some());
    }
}

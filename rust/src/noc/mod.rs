//! Network-on-Chip substrate: topologies, routing (XY / XY+YX / LASH /
//! ALASH), the mm-wave wireless overlay with its distributed MAC, the
//! cycle-level simulator, and analytic link-utilization analysis (Eqns 3-5).

pub mod analysis;
pub mod builder;
pub mod routing;
pub mod sim;
pub mod topology;
pub mod wireless;

pub use analysis::{analyze, Analysis};
pub use builder::{het_noc, mesh_opt, wi_het_noc, NocDesigner, NocInstance, NocKind};
pub use routing::{Path, RouteSet, RoutingKind};
pub use sim::{Message, MsgClass, NocSim, SimConfig, SimReport};
pub use topology::{LinkId, Topology};
pub use wireless::{WirelessSpec, Wi};

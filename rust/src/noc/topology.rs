//! Wireline topology graph: routers + bidirectional links with physical
//! lengths, plus the constraint checks from the optimization formulation
//! (Eqns 7-9): average/maximum router port count and full connectivity.

use crate::model::SystemConfig;

pub type LinkId = usize;

/// A bidirectional wireline link between routers `a` and `b`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    pub a: usize,
    pub b: usize,
    /// Physical length in mm (Euclidean between tile centers).
    pub length_mm: f64,
    /// Traversal delay in NoC cycles. Short (neighbor) wires take 1 cycle;
    /// long wires are pipelined at ~2.5 mm/cycle (HetNoC's repeated wires).
    pub delay_cycles: u64,
}

/// Wireline connectivity graph over `n` routers.
#[derive(Debug, Clone)]
pub struct Topology {
    pub n: usize,
    pub links: Vec<Link>,
    /// adjacency: per router, (neighbor, link id)
    adj: Vec<Vec<(usize, LinkId)>>,
}

/// Wire pipeline reach per cycle (mm) at the 2.5 GHz NoC clock — repeated
/// global wires at 28 nm do roughly 2-3 mm per 400 ps cycle.
pub const MM_PER_CYCLE: f64 = 2.5;

pub fn wire_delay_cycles(length_mm: f64) -> u64 {
    ((length_mm / MM_PER_CYCLE).ceil() as u64).max(1)
}

impl Topology {
    pub fn new(n: usize) -> Self {
        Topology { n, links: Vec::new(), adj: vec![Vec::new(); n] }
    }

    /// Build from an explicit undirected edge list with geometry from `sys`.
    pub fn from_edges(sys: &SystemConfig, edges: &[(usize, usize)]) -> Self {
        let mut t = Topology::new(sys.num_tiles());
        for &(a, b) in edges {
            t.add_link_with_geometry(sys, a, b);
        }
        t
    }

    /// 2D mesh over the system grid (the baseline NoC). Handles
    /// rectangular `width x height` grids.
    pub fn mesh(sys: &SystemConfig) -> Self {
        let w = sys.width;
        let h = sys.height();
        let mut t = Topology::new(sys.num_tiles());
        for r in 0..h {
            for c in 0..w {
                let id = r * w + c;
                if c + 1 < w {
                    t.add_link_with_geometry(sys, id, id + 1);
                }
                if r + 1 < h {
                    t.add_link_with_geometry(sys, id, id + w);
                }
            }
        }
        t
    }

    pub fn add_link_with_geometry(&mut self, sys: &SystemConfig, a: usize, b: usize) -> LinkId {
        let len = sys.dist_mm(a, b);
        self.add_link(a, b, len)
    }

    pub fn add_link(&mut self, a: usize, b: usize, length_mm: f64) -> LinkId {
        assert!(a != b, "self-link {a}");
        assert!(a < self.n && b < self.n);
        debug_assert!(!self.has_link(a, b), "duplicate link {a}-{b}");
        let id = self.links.len();
        self.links.push(Link { a, b, length_mm, delay_cycles: wire_delay_cycles(length_mm) });
        self.adj[a].push((b, id));
        self.adj[b].push((a, id));
        id
    }

    /// Remove link by id (swap-remove; the moved link's id changes to `id`).
    pub fn remove_link(&mut self, id: LinkId) {
        let last = self.links.len() - 1;
        let doomed = self.links[id];
        self.adj[doomed.a].retain(|&(_, l)| l != id);
        self.adj[doomed.b].retain(|&(_, l)| l != id);
        if id != last {
            let moved = self.links[last];
            for &(r, old) in &[(moved.a, last), (moved.b, last)] {
                let _ = old;
                for e in self.adj[r].iter_mut() {
                    if e.1 == last {
                        e.1 = id;
                    }
                }
            }
            self.links[id] = moved;
        }
        self.links.pop();
    }

    pub fn has_link(&self, a: usize, b: usize) -> bool {
        self.adj[a].iter().any(|&(nbr, _)| nbr == b)
    }

    pub fn link_between(&self, a: usize, b: usize) -> Option<LinkId> {
        self.adj[a].iter().find(|&&(nbr, _)| nbr == b).map(|&(_, l)| l)
    }

    pub fn neighbors(&self, r: usize) -> &[(usize, LinkId)] {
        &self.adj[r]
    }

    /// Inter-tile port count of router `r` (k_r in Eqn 8).
    pub fn degree(&self, r: usize) -> usize {
        self.adj[r].len()
    }

    /// Average port count (k_avg, Eqn 7).
    pub fn k_avg(&self) -> f64 {
        2.0 * self.links.len() as f64 / self.n as f64
    }

    /// Maximum port count (k_max, Eqn 8).
    pub fn k_max(&self) -> usize {
        (0..self.n).map(|r| self.degree(r)).max().unwrap_or(0)
    }

    /// Eqn 9: path exists between every pair.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(r) = stack.pop() {
            for &(nbr, _) in &self.adj[r] {
                if !seen[nbr] {
                    seen[nbr] = true;
                    count += 1;
                    stack.push(nbr);
                }
            }
        }
        count == self.n
    }

    /// [`Topology::is_connected`] over the residual topology with
    /// `dead[link]` links removed — does every pair still have a path?
    /// Used by the fault-injection layer to decide whether a repair path
    /// must exist (the undeliverable-after-repair == 0 invariant).
    pub fn connected_without(&self, dead: &[bool]) -> bool {
        debug_assert_eq!(dead.len(), self.links.len());
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0usize];
        seen[0] = true;
        let mut count = 1;
        while let Some(r) = stack.pop() {
            for &(nbr, link) in &self.adj[r] {
                if !dead[link] && !seen[nbr] {
                    seen[nbr] = true;
                    count += 1;
                    stack.push(nbr);
                }
            }
        }
        count == self.n
    }

    /// BFS hop distances from `src` (u32::MAX if unreachable).
    pub fn bfs_hops(&self, src: usize) -> Vec<u32> {
        let mut dist = vec![u32::MAX; self.n];
        let mut q = std::collections::VecDeque::new();
        dist[src] = 0;
        q.push_back(src);
        while let Some(r) = q.pop_front() {
            for &(nbr, _) in &self.adj[r] {
                if dist[nbr] == u32::MAX {
                    dist[nbr] = dist[r] + 1;
                    q.push_back(nbr);
                }
            }
        }
        dist
    }

    /// Minimum hop count between a pair (h_ij in Eqn 4).
    pub fn hops(&self, a: usize, b: usize) -> u32 {
        self.bfs_hops(a)[b]
    }

    /// Router pipeline depth: 3 stages, +1 output-arbitration stage for
    /// routers with more than four inter-tile ports (§5, experimental setup).
    pub fn router_delay(&self, r: usize) -> u64 {
        if self.degree(r) > 4 { 4 } else { 3 }
    }

    /// Undirected edge list (for serialization / optimizer state).
    pub fn edges(&self) -> Vec<(usize, usize)> {
        self.links.iter().map(|l| (l.a, l.b)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemConfig;

    #[test]
    fn mesh_link_count() {
        let sys = SystemConfig::paper_8x8();
        let t = Topology::mesh(&sys);
        assert_eq!(t.links.len(), 2 * 7 * 8); // 112
        assert!((t.k_avg() - 3.5).abs() < 1e-12);
        assert_eq!(t.k_max(), 4);
        assert!(t.is_connected());
    }

    #[test]
    fn mesh_hops_match_manhattan() {
        let sys = SystemConfig::paper_8x8();
        let t = Topology::mesh(&sys);
        for &(a, b) in &[(0usize, 63usize), (5, 40), (7, 56), (9, 9)] {
            assert_eq!(t.hops(a, b) as usize, sys.hop_dist(a, b));
        }
    }

    #[test]
    fn add_remove_roundtrip() {
        let sys = SystemConfig::paper_8x8();
        let mut t = Topology::mesh(&sys);
        let before = t.links.len();
        let id = t.add_link_with_geometry(&sys, 0, 63);
        assert!(t.has_link(0, 63));
        assert_eq!(t.hops(0, 63), 1);
        t.remove_link(id);
        assert_eq!(t.links.len(), before);
        assert!(!t.has_link(0, 63));
        // adjacency still sane after swap-remove
        for (li, l) in t.links.iter().enumerate() {
            assert!(t.neighbors(l.a).iter().any(|&(n, i)| n == l.b && i == li));
            assert!(t.neighbors(l.b).iter().any(|&(n, i)| n == l.a && i == li));
        }
    }

    #[test]
    fn disconnect_detected() {
        let sys = SystemConfig::small_4x4();
        let mut t = Topology::mesh(&sys);
        // cut tile 0 off (it has exactly 2 links in the corner)
        while t.degree(0) > 0 {
            let id = t.neighbors(0)[0].1;
            t.remove_link(id);
        }
        assert!(!t.is_connected());
        assert_eq!(t.hops(0, 5), u32::MAX);
    }

    #[test]
    fn long_wire_pipeline_stages() {
        assert_eq!(wire_delay_cycles(2.5), 1);
        assert_eq!(wire_delay_cycles(2.6), 2);
        assert_eq!(wire_delay_cycles(17.7), 8);
        // neighbor links on the 8x8 die are 2.5mm -> single cycle
        let sys = SystemConfig::paper_8x8();
        let t = Topology::mesh(&sys);
        assert!(t.links.iter().all(|l| l.delay_cycles == 1));
    }

    #[test]
    fn router_delay_extra_stage() {
        let sys = SystemConfig::paper_8x8();
        let mut t = Topology::mesh(&sys);
        assert_eq!(t.router_delay(27), 3);
        t.add_link_with_geometry(&sys, 27, 0);
        // 27 is interior: 4 mesh ports + 1 = 5 -> extra stage
        assert_eq!(t.router_delay(27), 4);
    }
}

//! Event-driven, flit-time-accurate NoC simulator.
//!
//! Fidelity model (DESIGN.md §2): wormhole switching is approximated at
//! packet granularity — the head flit advances through the 3-stage (or
//! 4-stage for >4-port) router pipeline per hop, waits for the output link
//! to drain (`busy_until`), and each wireline link is occupied for one
//! cycle per flit, so contention, serialization, and per-link utilization
//! are all explicit. Delivery completes when the tail streams out at the
//! destination. Buffers are not depth-limited; saturation shows up as
//! unbounded queueing delay on hot links, which is how the throughput
//! experiments detect it (Fig 14 methodology).
//!
//! The memory system is closed-loop: a delivered `ReadReq` spawns a
//! `ReadReply` (cache-line payload) after the MC service latency, and a
//! `WriteData` spawns a `WriteAck`, reproducing the request/reply
//! asymmetry the paper measures (Fig 6).
//!
//! Wireless hops implement the §4.2.5 MAC: if the channel is busy when the
//! head reaches the WI, the packet is *re-routed on the spot* over the
//! wireline shortest path from that router; otherwise it pays the request
//! period (one slot per WI on the channel) and occupies the channel for
//! its serialization time.
//!
//! ## Performance (§Perf)
//!
//! The hot path is engineered for the sweep workloads (thousands of
//! `run` calls over the same platform in AMOSA loops and figure
//! harnesses):
//!
//! * [`SimWorkspace`] owns every per-run buffer — the event queue, the
//!   flight arena, and the per-link/per-channel busy vectors — so
//!   repeated runs allocate nothing. [`NocSim::run`] transparently
//!   reuses a thread-local workspace; [`NocSim::run_in`] takes an
//!   explicit one.
//! * The event queue is a bucketed **calendar queue**: event times are
//!   near-monotonic with small deltas (link delays, MAC slots, MC
//!   service), so push/pop are O(1) amortized instead of the binary
//!   heap's O(log n). FIFO order among same-cycle events reproduces the
//!   old heap's global-sequence tie-break exactly, keeping runs
//!   deterministic and byte-identical across workspace reuse.
//! * In-flight message state is stored as structure-of-arrays, and the
//!   CPU/GPU↔MC pair classification is a precomputed per-(src,dst)
//!   table instead of a per-delivery match over tile kinds.
//!
//! ## Timelines (§Schedules)
//!
//! [`NocSim::run_timeline`] runs a *gated* trace: messages are grouped
//! into phase instances, each group's `inject_at` is relative to its
//! release, and a group is released the cycle its last predecessor
//! group **drains** (every message, including spawned replies,
//! tail-delivered). This is what lets overlapping microbatch schedules
//! (`crate::schedule`) inject several training phases concurrently while
//! precedence edges hold back the rest. The plain [`NocSim::run`] path is
//! the single-group, zero-predecessor case of the same event loop, so
//! reports are byte-identical to the pre-timeline simulator.

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::routing::{Hop, Path, RouteSet, RoutingKind};
use super::topology::Topology;
use super::wireless::WirelessSpec;
use crate::faults::{ResilienceStats, SimFaults};
use crate::model::{SystemConfig, TileKind};
use crate::telemetry::{LatencyPercentiles, Telemetry};
use crate::util::stats::Accum;

/// Carrier-sense retries a packet pays on a jammed channel before
/// falling back to wireline (§faults): exponential backoff starting at
/// [`AIR_BACKOFF_BASE`] cycles, doubling per retry — a ~1000-cycle
/// budget, far above any MAC queue but small against a real
/// interference burst.
const AIR_MAX_RETRIES: u32 = 6;
const AIR_BACKOFF_BASE: u64 = 16;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MsgClass {
    /// 1-flit read request; MC answers with a ReadReply.
    ReadReq,
    /// Cache-line reply (header + line/flit flits).
    ReadReply,
    /// Cache-line writeback; MC answers with a WriteAck.
    WriteData,
    /// 1-flit write acknowledgment.
    WriteAck,
    /// Raw control/synthetic message; no response.
    Control,
}

impl MsgClass {
    pub fn spawns_response(&self) -> Option<MsgClass> {
        match self {
            MsgClass::ReadReq => Some(MsgClass::ReadReply),
            MsgClass::WriteData => Some(MsgClass::WriteAck),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Message {
    pub src: usize,
    pub dst: usize,
    pub flits: u64,
    pub class: MsgClass,
    pub inject_at: u64,
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// MC service latency (L2 lookup + DRAM amortized) in cycles.
    pub mc_service_cycles: u64,
    /// Flits in a cache-line-carrying packet (header + payload).
    pub line_flits: u64,
    /// Nominal flits used for wireless path-enabling cost estimates.
    pub nominal_flits: u64,
    /// Stop simulating at this cycle even if messages remain (0 = run all).
    pub horizon: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { mc_service_cycles: 20, line_flits: 5, nominal_flits: 5, horizon: 0 }
    }
}

/// Aggregated results of one simulation run.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    /// End-to-end packet latency (inject -> tail delivered), all packets.
    pub latency: Accum,
    /// Latency restricted to CPU<->MC packets (the paper's CPU QoS metric).
    pub cpu_mc_latency: Accum,
    /// Latency restricted to GPU<->MC packets.
    pub gpu_mc_latency: Accum,
    pub delivered_packets: u64,
    pub delivered_flits: u64,
    /// Last delivery cycle (simulated time span).
    pub cycles: u64,
    /// Busy cycles per wireline link.
    pub link_busy: Vec<u64>,
    /// Flits carried per wireline link.
    pub link_flits: Vec<u64>,
    /// Flit-traversals per router (for energy accounting).
    pub router_flits: Vec<u64>,
    /// Busy cycles per wireless channel.
    pub air_busy: Vec<u64>,
    /// Flits carried per wireless channel.
    pub air_flits: Vec<u64>,
    /// Packets that took a wireless hop.
    pub air_packets: u64,
    /// Packets that wanted wireless but found the channel busy.
    pub air_fallbacks: u64,
    /// Wireless flits by direction: to an MC (core->MC) / from an MC.
    pub air_flits_to_mc: u64,
    pub air_flits_from_mc: u64,
    /// Messages of groups never released when the run ended (gated
    /// behind a horizon cut or an unreached predecessor).
    pub unreleased: u64,
    /// Released messages that did not tail-deliver: stranded in flight
    /// by a horizon cut, or dropped at a fault with no repair path (see
    /// [`ResilienceStats::undeliverable_after_repair`]).
    pub undeliverable: u64,
    /// Fault-injection counters; all zero for fault-free runs.
    pub resilience: ResilienceStats,
    /// Tail-latency percentiles per pair class. Always `None` straight
    /// out of a run — even with a telemetry sink attached, so attached
    /// and detached reports stay byte-identical. A display layer fills
    /// it explicitly via [`SimReport::attach_percentiles`].
    pub percentiles: Option<LatencyPercentiles>,
}

impl SimReport {
    /// Total messages (not events) not delivered when the run ended:
    /// never-released plus released-but-stranded.
    pub fn undelivered(&self) -> u64 {
        self.unreleased + self.undeliverable
    }

    /// Mean link utilization over the simulated span.
    pub fn link_utilization(&self) -> Vec<f64> {
        let c = self.cycles.max(1) as f64;
        self.link_busy.iter().map(|&b| b as f64 / c).collect()
    }

    /// Delivered flits per cycle (network throughput).
    pub fn throughput(&self) -> f64 {
        self.delivered_flits as f64 / self.cycles.max(1) as f64
    }

    /// Fraction of delivered packets that used a wireless hop.
    pub fn wireless_utilization(&self) -> f64 {
        self.air_packets as f64 / self.delivered_packets.max(1) as f64
    }

    /// Copy a finished sink's percentiles into this report. Never called
    /// by the simulator itself — display layers opt in, keeping raw
    /// reports byte-identical whether or not telemetry was attached.
    pub fn attach_percentiles(&mut self, tel: &Telemetry) {
        self.percentiles = Some(tel.percentiles());
    }

    /// Percentile lines for text rendering — empty when nothing was
    /// measured, so existing experiments' `Report::to_text()` output is
    /// unchanged byte for byte.
    pub fn percentile_text(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        if let Some(p) = &self.percentiles {
            for (name, c) in [
                ("all", &p.all),
                ("cpu-mc", &p.cpu_mc),
                ("gpu-mc", &p.gpu_mc),
                ("cpu-gpu", &p.cpu_gpu),
            ] {
                if c.count > 0 {
                    let _ = writeln!(
                        s,
                        "latency {name} p50/p99/p999: {}/{}/{} cycles (n={})",
                        c.p50, c.p99, c.p999, c.count
                    );
                }
            }
        }
        s
    }
}

/// Per-group results of a gated timeline run ([`NocSim::run_timeline`]).
///
/// `release[g]`/`drain[g]` are [`u64::MAX`] for groups the run never
/// reached (a horizon cut upstream of them, or predecessor indices that
/// form a cycle — the `crate::schedule` expander only emits DAGs).
#[derive(Debug, Clone, Default)]
pub struct TimelineOutcome {
    /// Aggregate simulation report over every released group.
    pub report: SimReport,
    /// Cycle each group's messages were injected (predecessors drained).
    pub release: Vec<u64>,
    /// Cycle each group drained: its last message (including spawned
    /// replies) tail-delivered.
    pub drain: Vec<u64>,
    /// Flits each group pushed over each wireline link, group-major
    /// (`group * num_links + link`) — the input to per-link concurrency
    /// metrics.
    pub group_link_flits: Vec<u64>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Event {
    Inject(u32),
    /// Head of message `idx` ready to take `hop` of its path at this time.
    Hop { idx: u32, hop: u16 },
    Deliver { idx: u32 },
}

impl Event {
    /// Pack into a u64 (kind << 48 | hop << 32 | idx) so queue entries are
    /// flat integers — no side payload storage.
    #[inline]
    fn pack(self) -> u64 {
        match self {
            Event::Inject(idx) => idx as u64,
            Event::Hop { idx, hop } => (1 << 48) | ((hop as u64) << 32) | idx as u64,
            Event::Deliver { idx } => (2 << 48) | idx as u64,
        }
    }

    #[inline]
    fn unpack(v: u64) -> Event {
        let idx = v as u32;
        match v >> 48 {
            0 => Event::Inject(idx),
            1 => Event::Hop { idx, hop: (v >> 32) as u16 },
            _ => Event::Deliver { idx },
        }
    }
}

/// Bucket count of the calendar queue (one bucket per cycle, power of
/// two). Event deltas (router pipeline, link drain, MAC request, MC
/// service) are orders of magnitude below this, so virtually every push
/// lands in the in-window buckets; the rare far-future event (a trace
/// inject deep in the schedule) overflows into a small binary heap.
const CAL_BUCKETS: usize = 4096;
const CAL_MASK: usize = CAL_BUCKETS - 1;

/// Occupancy-summary words (64 buckets per `u64` word; CAL_BUCKETS/64
/// words fit one summary `u64` exactly).
const CAL_WORDS: usize = CAL_BUCKETS / 64;

/// Time-ordered event queue: a calendar of per-cycle buckets over a
/// sliding window, with a heap for events beyond it. Same-cycle events
/// pop in global insertion order (the old heap's `(time, seq)`
/// tie-break), so runs are fully deterministic.
///
/// A two-level occupancy bitmap (bit per bucket + one summary word)
/// lets `pop` jump straight to the next pending cycle instead of
/// scanning empty buckets, so sparse traces (light-load sweeps with
/// long idle gaps) stay O(1)-ish per event too.
struct CalendarQueue {
    /// `(time, packed event)` entries; index = `time & CAL_MASK`. Every
    /// entry's time lies in `[cur, cur + CAL_BUCKETS)` (later times live
    /// in `far`), so each non-empty bucket holds exactly one time value:
    /// `cur + ring_distance`.
    buckets: Vec<Vec<(u64, u64)>>,
    /// Bit per bucket: non-empty. `occ_sum`: bit per word of `occ`.
    occ: Vec<u64>,
    occ_sum: u64,
    /// Events at `cur`, in insertion order; drained by `ready_pos`.
    ready: Vec<u64>,
    ready_pos: usize,
    /// The cycle currently being served.
    cur: u64,
    /// Whether `cur` has been primed (lets time 0 be served).
    started: bool,
    len: usize,
    /// Events at `t >= cur + CAL_BUCKETS`, ordered by `(t, seq)`.
    far: BinaryHeap<Reverse<(u64, u64, u64)>>,
    far_seq: u64,
}

impl CalendarQueue {
    fn new() -> Self {
        CalendarQueue {
            buckets: (0..CAL_BUCKETS).map(|_| Vec::new()).collect(),
            occ: vec![0; CAL_WORDS],
            occ_sum: 0,
            ready: Vec::new(),
            ready_pos: 0,
            cur: 0,
            started: false,
            len: 0,
            far: BinaryHeap::new(),
            far_seq: 0,
        }
    }

    /// Clear state while keeping every allocation (buckets, ready, heap).
    fn reset(&mut self) {
        if self.len != 0 {
            // a horizon cut can leave entries behind
            for b in &mut self.buckets {
                b.clear();
            }
            self.far.clear();
        }
        self.occ.fill(0);
        self.occ_sum = 0;
        self.ready.clear();
        self.ready_pos = 0;
        self.cur = 0;
        self.started = false;
        self.len = 0;
        self.far_seq = 0;
    }

    #[inline]
    fn mark(&mut self, bi: usize) {
        let w = bi >> 6;
        self.occ[w] |= 1 << (bi & 63);
        self.occ_sum |= 1 << w;
    }

    #[inline]
    fn unmark(&mut self, bi: usize) {
        let w = bi >> 6;
        self.occ[w] &= !(1 << (bi & 63));
        if self.occ[w] == 0 {
            self.occ_sum &= !(1 << w);
        }
    }

    /// Nearest occupied bucket at ring distance >= 0 from `from`.
    fn next_occupied(&self, from: usize) -> Option<usize> {
        let w0 = from >> 6;
        let in_word = self.occ[w0] & (!0u64 << (from & 63));
        if in_word != 0 {
            return Some((w0 << 6) + in_word.trailing_zeros() as usize);
        }
        for step in 1..=CAL_WORDS {
            let w = (w0 + step) & (CAL_WORDS - 1);
            if self.occ_sum & (1 << w) != 0 {
                // lowest set bit = nearest in ring order (for the fully
                // wrapped word w == w0, its remaining bits are < from)
                return Some((w << 6) + self.occ[w].trailing_zeros() as usize);
            }
        }
        None
    }

    #[inline]
    fn push(&mut self, t: u64, ev: Event) {
        let p = ev.pack();
        self.len += 1;
        if self.started && t <= self.cur {
            // same-cycle follow-up (Inject -> Hop, local delivery):
            // append after everything already scheduled at `cur`.
            debug_assert!(t == self.cur, "event scheduled in the past");
            self.ready.push(p);
        } else if t < self.cur + CAL_BUCKETS as u64 {
            let bi = (t as usize) & CAL_MASK;
            if self.buckets[bi].is_empty() {
                self.mark(bi);
            }
            self.buckets[bi].push((t, p));
        } else {
            self.far.push(Reverse((t, self.far_seq, p)));
            self.far_seq += 1;
        }
    }

    /// Move far-future events that now fall inside the window into their
    /// buckets. Heap order `(t, seq)` keeps per-bucket insertion order
    /// consistent with global sequence.
    fn pull_far(&mut self) {
        let bound = self.cur + CAL_BUCKETS as u64;
        while let Some(&Reverse((t, _, _))) = self.far.peek() {
            if t >= bound {
                break;
            }
            let Reverse((t, _, p)) = self.far.pop().expect("peeked");
            let bi = (t as usize) & CAL_MASK;
            if self.buckets[bi].is_empty() {
                self.mark(bi);
            }
            self.buckets[bi].push((t, p));
        }
    }

    fn pop(&mut self) -> Option<(u64, Event)> {
        if self.len == 0 {
            return None;
        }
        while self.ready_pos >= self.ready.len() {
            self.ready.clear();
            self.ready_pos = 0;
            if !self.started {
                self.started = true; // consider cycle 0 itself first
            } else {
                self.cur += 1;
            }
            // Land `cur` on the next pending event time: the nearest
            // occupied bucket in ring order (its single time value is
            // `cur + distance`, and every far event is farther away), or
            // the earliest far event when the window is empty.
            loop {
                self.pull_far();
                let from = (self.cur as usize) & CAL_MASK;
                if let Some(bi) = self.next_occupied(from) {
                    let d = (bi + CAL_BUCKETS - from) & CAL_MASK;
                    self.cur += d as u64;
                    break;
                }
                let &Reverse((t, _, _)) =
                    self.far.peek().expect("len > 0 with empty window and empty far heap");
                self.cur = t;
            }
            let cur = self.cur;
            let bi = (cur as usize) & CAL_MASK;
            let ready = &mut self.ready;
            self.buckets[bi].retain(|&(t, p)| {
                if t == cur {
                    ready.push(p);
                    false
                } else {
                    true
                }
            });
            if self.buckets[bi].is_empty() {
                self.unmark(bi);
            }
        }
        let p = self.ready[self.ready_pos];
        self.ready_pos += 1;
        self.len -= 1;
        Some((self.cur, Event::unpack(p)))
    }
}

/// Route handle: (route source, destination, candidate index) into the
/// shared `RouteSet` — no per-packet path allocation. After a MAC
/// fallback the route re-roots at the WI router (`src` becomes that
/// router, `idx` 0 = the wireline primary). `fixed` routes resolve
/// against the fault layer's *repaired* route set instead (set when a
/// packet re-roots at a dead link).
#[derive(Debug, Clone, Copy)]
struct RouteRef {
    src: u32,
    dst: u32,
    idx: u8,
    fixed: bool,
}

/// In-flight message state, structure-of-arrays: the hop handler touches
/// `flits`/`dst`/`route` only, the delivery handler adds `src`/`class`/
/// `inject_at` — neither drags the other's cache lines around.
#[derive(Default)]
struct Flights {
    src: Vec<u32>,
    dst: Vec<u32>,
    flits: Vec<u64>,
    class: Vec<MsgClass>,
    inject_at: Vec<u64>,
    route: Vec<RouteRef>,
    /// Timeline group (phase instance) the message belongs to; spawned
    /// responses inherit the group of their request. Always 0 for plain
    /// (single-group) runs.
    group: Vec<u32>,
}

impl Flights {
    fn clear(&mut self) {
        self.src.clear();
        self.dst.clear();
        self.flits.clear();
        self.class.clear();
        self.inject_at.clear();
        self.route.clear();
        self.group.clear();
    }

    fn len(&self) -> usize {
        self.src.len()
    }

    fn push(&mut self, m: &Message, group: u32) -> u32 {
        let idx = self.src.len() as u32;
        self.src.push(m.src as u32);
        self.dst.push(m.dst as u32);
        self.flits.push(m.flits);
        self.class.push(m.class);
        self.inject_at.push(m.inject_at);
        self.route.push(RouteRef { src: m.src as u32, dst: m.dst as u32, idx: 0, fixed: false });
        self.group.push(group);
        idx
    }
}

/// CPU/GPU<->MC pair classification values (see `SimWorkspace::pair_kind`).
/// `pub(crate)` so the telemetry sink can key its per-class latency
/// histograms off the same table the simulator classifies with.
pub(crate) const PAIR_NONE: u8 = 0;
pub(crate) const PAIR_CPU_MC: u8 = 1;
pub(crate) const PAIR_GPU_MC: u8 = 2;
pub(crate) const PAIR_CPU_GPU: u8 = 3;

/// Reusable per-run state. One workspace serves any number of runs on any
/// platform — buffers are cleared (never freed) between runs, and the
/// pair-classification table is rebuilt only when the tile layout
/// actually changes. Results are independent of workspace history.
#[derive(Default)]
pub struct SimWorkspace {
    queue: Option<CalendarQueue>,
    flights: Flights,
    link_busy_until: Vec<u64>,
    chan_busy_until: Vec<u64>,
    /// Per-(src,dst) pair class (`src * n + dst`): PAIR_CPU_MC /
    /// PAIR_GPU_MC / PAIR_NONE.
    pair_kind: Vec<u8>,
    pair_n: usize,
    pair_sig: u64,
    /// §Schedules: per-group gating state for `run_gated`, kept here so
    /// the plain `run_in` path (one group) stays allocation-free across
    /// runs. `tl_release`/`tl_drain`/`tl_group_link_flits` double as the
    /// source of [`TimelineOutcome`] after a timeline run.
    tl_release: Vec<u64>,
    tl_drain: Vec<u64>,
    tl_remaining: Vec<u64>,
    tl_done: Vec<u64>,
    tl_indeg: Vec<u32>,
    tl_succs: Vec<Vec<u32>>,
    tl_work: Vec<u32>,
    tl_group_link_flits: Vec<u64>,
}

impl SimWorkspace {
    pub fn new() -> Self {
        SimWorkspace::default()
    }

    fn prepare(&mut self, sys: &SystemConfig, num_links: usize, num_chans: usize) {
        match &mut self.queue {
            Some(q) => q.reset(),
            None => self.queue = Some(CalendarQueue::new()),
        }
        self.flights.clear();
        self.link_busy_until.clear();
        self.link_busy_until.resize(num_links, 0);
        self.chan_busy_until.clear();
        self.chan_busy_until.resize(num_chans, 0);
        let n = sys.num_tiles();
        let sig = tiles_signature(sys);
        if self.pair_n != n || self.pair_sig != sig {
            self.pair_kind.clear();
            self.pair_kind.resize(n * n, PAIR_NONE);
            for s in 0..n {
                for d in 0..n {
                    self.pair_kind[s * n + d] = match (sys.tiles[s], sys.tiles[d]) {
                        (TileKind::Cpu, TileKind::Mc) | (TileKind::Mc, TileKind::Cpu) => {
                            PAIR_CPU_MC
                        }
                        (TileKind::Gpu, TileKind::Mc) | (TileKind::Mc, TileKind::Gpu) => {
                            PAIR_GPU_MC
                        }
                        (TileKind::Cpu, TileKind::Gpu) | (TileKind::Gpu, TileKind::Cpu) => {
                            PAIR_CPU_GPU
                        }
                        _ => PAIR_NONE,
                    };
                }
            }
            self.pair_n = n;
            self.pair_sig = sig;
        }
    }
}

/// FNV-1a over the tile-kind vector — cheap change detection for the
/// cached pair table when one workspace serves several placements.
fn tiles_signature(sys: &SystemConfig) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for t in &sys.tiles {
        let b = match t {
            TileKind::Cpu => 1u8,
            TileKind::Gpu => 2,
            TileKind::Mc => 3,
        };
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Release the groups seeded into `work` at cycle `now`: push their
/// messages (offsets become absolute injection times) and cascade
/// through empty groups, which drain on the spot and may unlock
/// successors in turn. Worklist order is deterministic (discovery
/// order), so same-cycle injections keep a reproducible queue order.
/// `work` is a reusable buffer; it is drained and cleared.
#[allow(clippy::too_many_arguments)]
fn release_groups(
    now: u64,
    groups: &[&[Message]],
    succs: &[Vec<u32>],
    q: &mut CalendarQueue,
    fl: &mut Flights,
    release: &mut [u64],
    drain: &mut [u64],
    remaining: &mut [u64],
    indeg: &mut [u32],
    not_released: &mut u64,
    work: &mut Vec<u32>,
) {
    let mut wi = 0;
    while wi < work.len() {
        let g = work[wi] as usize;
        wi += 1;
        release[g] = now;
        let msgs = groups[g];
        *not_released -= msgs.len() as u64;
        remaining[g] = msgs.len() as u64;
        for m in msgs {
            // inject_at is release-relative; store it absolute so latency
            // accounting sees real injection times
            let abs = Message { inject_at: now + m.inject_at, ..*m };
            let idx = fl.push(&abs, g as u32);
            q.push(abs.inject_at, Event::Inject(idx));
        }
        if remaining[g] == 0 {
            drain[g] = now;
            for &s in &succs[g] {
                indeg[s as usize] -= 1;
                if indeg[s as usize] == 0 {
                    work.push(s);
                }
            }
        }
    }
    work.clear();
}

thread_local! {
    /// Workspace behind [`NocSim::run`]: every run on this thread reuses
    /// one arena, so sweeps allocate nothing per run even through the
    /// convenience API (and each `par_map` worker gets its own).
    static TLS_WORKSPACE: RefCell<SimWorkspace> = RefCell::new(SimWorkspace::new());
}

/// The simulator. Owns per-run mutable state via a [`SimWorkspace`];
/// `topo`/`routes`/`air` are borrowed per `run`.
pub struct NocSim<'a> {
    pub sys: &'a SystemConfig,
    pub topo: &'a Topology,
    pub routes: &'a RouteSet,
    pub air: &'a WirelessSpec,
    pub cfg: SimConfig,
    /// Compiled fault plan ([`crate::faults::FaultPlan::compile`]);
    /// `None` keeps every fault hook off the hot path, so fault-free
    /// runs are byte-identical to the pre-fault simulator.
    faults: Option<&'a SimFaults>,
}

impl<'a> NocSim<'a> {
    pub fn new(
        sys: &'a SystemConfig,
        topo: &'a Topology,
        routes: &'a RouteSet,
        air: &'a WirelessSpec,
        cfg: SimConfig,
    ) -> Self {
        NocSim { sys, topo, routes, air, cfg, faults: None }
    }

    /// Install a compiled fault plan: dead links re-route onto the
    /// plan's repaired route set mid-flight, jammed channels charge
    /// carrier-sense retries with exponential backoff before the
    /// wireline fallback.
    pub fn with_faults(mut self, faults: &'a SimFaults) -> Self {
        self.faults = Some(faults);
        self
    }

    /// The route set a handle resolves against: repaired for re-rooted
    /// (`fixed`) routes, the original otherwise.
    #[inline]
    fn route_set(&self, fixed: bool) -> &RouteSet {
        match self.faults {
            Some(f) if fixed => f.repaired(),
            _ => self.routes,
        }
    }

    /// Run the trace to completion (or the configured horizon), reusing
    /// this thread's workspace.
    pub fn run(&self, trace: &[Message]) -> SimReport {
        TLS_WORKSPACE.with(|ws| self.run_in(trace, &mut ws.borrow_mut()))
    }

    /// Run the trace using an explicit, reusable workspace. The result is
    /// identical whatever the workspace previously simulated.
    pub fn run_in(&self, trace: &[Message], ws: &mut SimWorkspace) -> SimReport {
        self.run_gated(&[trace], None, ws, None)
    }

    /// [`NocSim::run`] with an optional telemetry sink. The report is
    /// byte-identical to [`NocSim::run`]'s whether `tel` is `Some` or
    /// `None` — the sink only observes (utilization series, latency
    /// histograms, per-tile activity), it never feeds back.
    pub fn run_telemetry(&self, trace: &[Message], tel: Option<&mut Telemetry>) -> SimReport {
        TLS_WORKSPACE.with(|ws| self.run_gated(&[trace], None, &mut ws.borrow_mut(), tel))
    }

    /// Run a gated timeline, reusing this thread's workspace: one message
    /// group per phase instance, `inject_at` relative to the group's
    /// release cycle, `preds[g]` the groups whose traffic must fully
    /// drain before group `g` is released. Groups with no predecessors
    /// release at cycle 0; everything else starts the cycle its last
    /// predecessor's tail flit is delivered. See [`TimelineOutcome`].
    pub fn run_timeline(&self, groups: &[Vec<Message>], preds: &[Vec<u32>]) -> TimelineOutcome {
        TLS_WORKSPACE.with(|ws| self.run_timeline_in(groups, preds, &mut ws.borrow_mut()))
    }

    /// [`NocSim::run_timeline`] with an optional telemetry sink (same
    /// no-perturbation guarantee as [`NocSim::run_telemetry`]).
    pub fn run_timeline_telemetry(
        &self,
        groups: &[Vec<Message>],
        preds: &[Vec<u32>],
        tel: Option<&mut Telemetry>,
    ) -> TimelineOutcome {
        TLS_WORKSPACE.with(|ws| {
            self.run_timeline_telemetry_in(groups, preds, &mut ws.borrow_mut(), tel)
        })
    }

    /// [`NocSim::run_timeline`] with an explicit, reusable workspace.
    pub fn run_timeline_in(
        &self,
        groups: &[Vec<Message>],
        preds: &[Vec<u32>],
        ws: &mut SimWorkspace,
    ) -> TimelineOutcome {
        self.run_timeline_telemetry_in(groups, preds, ws, None)
    }

    fn run_timeline_telemetry_in(
        &self,
        groups: &[Vec<Message>],
        preds: &[Vec<u32>],
        ws: &mut SimWorkspace,
        tel: Option<&mut Telemetry>,
    ) -> TimelineOutcome {
        assert_eq!(groups.len(), preds.len(), "one predecessor list per group");
        let refs: Vec<&[Message]> = groups.iter().map(|g| g.as_slice()).collect();
        let report = self.run_gated(&refs, Some(preds), ws, tel);
        TimelineOutcome {
            report,
            release: ws.tl_release.clone(),
            drain: ws.tl_drain.clone(),
            group_link_flits: ws.tl_group_link_flits.clone(),
        }
    }

    /// The event loop behind both [`NocSim::run_in`] (one group, no
    /// gating, offsets are absolute times) and
    /// [`NocSim::run_timeline_in`]. Per-group gating state lives in the
    /// workspace (sized to the group count per run), so the plain path
    /// keeps the workspace's allocation-free guarantee.
    fn run_gated(
        &self,
        groups: &[&[Message]],
        preds: Option<&[Vec<u32>]>,
        ws: &mut SimWorkspace,
        mut tel: Option<&mut Telemetry>,
    ) -> SimReport {
        let nl = self.topo.links.len();
        let nch = self.air.num_channels.max(1);
        let n = self.sys.num_tiles();
        ws.prepare(self.sys, nl, nch);
        if let Some(sink) = tel.as_deref_mut() {
            sink.begin(nl, nch, n);
        }
        let ng = groups.len();
        let gated = preds.is_some();
        let mut report = SimReport {
            link_busy: vec![0; nl],
            link_flits: vec![0; nl],
            router_flits: vec![0; self.topo.n],
            air_busy: vec![0; nch],
            air_flits: vec![0; nch],
            ..SimReport::default()
        };
        if let Some(f) = self.faults {
            report.resilience.faults_injected = f.faults_injected;
        }
        let SimWorkspace {
            queue,
            flights: fl,
            link_busy_until,
            chan_busy_until,
            pair_kind,
            tl_release: release,
            tl_drain: drain,
            tl_remaining: remaining,
            tl_done: group_done,
            tl_indeg: indeg,
            tl_succs: succs,
            tl_work: work,
            tl_group_link_flits: group_link_flits,
            ..
        } = ws;
        let q = queue.as_mut().expect("prepare() primed the queue");

        // Gating state (workspace-backed). For the plain path this is one
        // group with no successors: it releases at cycle 0 (reproducing
        // the old push-everything-up-front prologue exactly) and its
        // drain bookkeeping never triggers anything. `remaining` counts
        // undelivered messages per group; `group_done` tracks the latest
        // tail-delivery cycle (a later event can carry an earlier tail
        // than a long message before it).
        release.clear();
        release.resize(ng, u64::MAX);
        drain.clear();
        drain.resize(ng, u64::MAX);
        remaining.clear();
        remaining.resize(ng, 0);
        group_done.clear();
        group_done.resize(ng, 0);
        indeg.clear();
        indeg.resize(ng, 0);
        if succs.len() < ng {
            succs.resize_with(ng, Vec::new);
        }
        for s in succs.iter_mut().take(ng) {
            s.clear();
        }
        group_link_flits.clear();
        if gated {
            group_link_flits.resize(ng * nl, 0);
        }
        if let Some(preds) = preds {
            for (g, ps) in preds.iter().enumerate() {
                indeg[g] = ps.len() as u32;
                for &p in ps {
                    assert!((p as usize) < ng, "predecessor {p} out of range");
                    succs[p as usize].push(g as u32);
                }
            }
        }
        let mut not_released: u64 = groups.iter().map(|g| g.len() as u64).sum();

        work.clear();
        for g in 0..ng {
            if indeg[g] == 0 {
                work.push(g as u32);
            }
        }
        release_groups(
            0,
            groups,
            succs,
            q,
            fl,
            release,
            drain,
            remaining,
            indeg,
            &mut not_released,
            work,
        );

        while let Some((t, ev)) = q.pop() {
            if self.cfg.horizon > 0 && t > self.cfg.horizon {
                break;
            }
            if let Some(sink) = tel.as_deref_mut() {
                // depth after the pop: the backlog this event left behind
                sink.queue_sample(t, q.len);
            }
            match ev {
                Event::Inject(idx) => {
                    let i = idx as usize;
                    let (src, dst) = (fl.src[i] as usize, fl.dst[i] as usize);
                    if src == dst {
                        q.push(t, Event::Deliver { idx });
                        continue;
                    }
                    let dedicated = pair_kind[src * n + dst] == PAIR_CPU_MC;
                    let cand = self.choose_path(
                        src,
                        dst,
                        t,
                        link_busy_until,
                        chan_busy_until,
                        dedicated,
                    );
                    fl.route[i] =
                        RouteRef { src: src as u32, dst: dst as u32, idx: cand, fixed: false };
                    q.push(t, Event::Hop { idx, hop: 0 });
                }
                Event::Hop { idx, hop } => {
                    let i = idx as usize;
                    let flits = fl.flits[i];
                    let dst = fl.dst[i] as usize;
                    let rr = fl.route[i];
                    let path: &Path = &self
                        .route_set(rr.fixed)
                        .candidates(rr.src as usize, rr.dst as usize)[rr.idx as usize];
                    let h = path.hops[hop as usize];
                    let from = h.from();
                    let ready = t + self.topo.router_delay(from);
                    report.router_flits[from] += flits;
                    if let Some(sink) = tel.as_deref_mut() {
                        sink.hop(from, flits);
                    }
                    let last = path.hops.len() as u16 - 1;
                    match h {
                        Hop::Wire { link, .. } => {
                            if let Some(f) = self.faults {
                                if !f.link_up(link, ready) {
                                    // The link died under us: re-root on the
                                    // repaired routes from this router,
                                    // mid-flight, like the MAC fallback.
                                    // Repaired paths avoid every dying link,
                                    // so a packet re-roots at most once.
                                    let rep = f.repaired().primary(from, dst);
                                    if rep.hops.is_empty() && from != dst {
                                        // disconnected residual topology:
                                        // the message strands (counted in
                                        // `undeliverable`); gated successors
                                        // stay unreleased — a pipeline stall,
                                        // exactly what a real fabric sees.
                                        report.resilience.undeliverable_after_repair += 1;
                                        continue;
                                    }
                                    report.resilience.packets_rerouted += 1;
                                    if let Some(sink) = tel.as_deref_mut() {
                                        sink.reroute(ready, from, dst);
                                    }
                                    fl.route[i] = RouteRef {
                                        src: from as u32,
                                        dst: dst as u32,
                                        idx: 0,
                                        fixed: true,
                                    };
                                    if rep.hops.is_empty() {
                                        q.push(ready, Event::Deliver { idx });
                                    } else {
                                        q.push(ready, Event::Hop { idx, hop: 0 });
                                    }
                                    continue;
                                }
                            }
                            let start = ready.max(link_busy_until[link]);
                            link_busy_until[link] = start + flits;
                            report.link_busy[link] += flits;
                            report.link_flits[link] += flits;
                            if let Some(sink) = tel.as_deref_mut() {
                                sink.wire_hop(link, start, flits, start - ready);
                            }
                            if gated {
                                group_link_flits[fl.group[i] as usize * nl + link] += flits;
                            }
                            let arrive = start + self.topo.links[link].delay_cycles;
                            let ev = if hop == last {
                                Event::Deliver { idx }
                            } else {
                                Event::Hop { idx, hop: hop + 1 }
                            };
                            q.push(arrive, ev);
                        }
                        Hop::Air { channel, .. } => {
                            let mac = self.air.mac_overhead_cycles(channel);
                            let ser = self.air.serialize_cycles(flits);
                            // Interference (§faults): while the channel is
                            // jammed, carrier-sense again after a bounded
                            // exponential backoff; if the jam outlasts the
                            // retry budget, fall back to wireline like a
                            // busy channel would. `sense == ready` on the
                            // fault-free path.
                            let mut sense = ready;
                            if let Some(f) = self.faults {
                                let mut retries = 0u32;
                                while let Some(end) = f.jam_until(channel, sense) {
                                    if retries >= AIR_MAX_RETRIES {
                                        break;
                                    }
                                    report.resilience.retries += 1;
                                    sense = (sense + (AIR_BACKOFF_BASE << retries)).min(end);
                                    retries += 1;
                                }
                                if f.jam_until(channel, sense).is_some() {
                                    report.air_fallbacks += 1;
                                    report.resilience.fallback_flits += flits;
                                    fl.route[i] = RouteRef {
                                        src: from as u32,
                                        dst: dst as u32,
                                        idx: 0,
                                        fixed: false,
                                    };
                                    if self.routes.primary(from, dst).hops.is_empty() {
                                        q.push(sense, Event::Deliver { idx });
                                    } else {
                                        q.push(sense, Event::Hop { idx, hop: 0 });
                                    }
                                    continue;
                                }
                            }
                            let wait = chan_busy_until[channel].saturating_sub(sense);
                            // MAC decision: queue for the channel if the
                            // residual wait still beats re-routing over
                            // wireline from this router; otherwise fall
                            // back (§4.2.5).
                            // Dedicated CPU-MC packets tolerate a longer
                            // queue before abandoning their channel — the
                            // wireline alternative is GPU-congested, which
                            // the zero-load estimate cannot see.
                            let dedicated =
                                pair_kind[fl.src[i] as usize * n + dst] == PAIR_CPU_MC;
                            let wire_alt = self.routes.primary(from, dst).cost_est
                                * if dedicated { 4 } else { 1 };
                            if wait > 0 && wait + mac + ser > wire_alt {
                                report.air_fallbacks += 1;
                                // re-root on the wireline primary from here
                                fl.route[i] = RouteRef {
                                    src: from as u32,
                                    dst: dst as u32,
                                    idx: 0,
                                    fixed: false,
                                };
                                if self.routes.primary(from, dst).hops.is_empty() {
                                    q.push(sense, Event::Deliver { idx });
                                } else {
                                    q.push(sense, Event::Hop { idx, hop: 0 });
                                }
                                continue;
                            }
                            let start = sense + wait + mac;
                            chan_busy_until[channel] = start + ser;
                            report.air_busy[channel] += ser;
                            if let Some(sink) = tel.as_deref_mut() {
                                sink.air_hop(channel, start, ser);
                            }
                            report.air_flits[channel] += flits;
                            report.air_packets += 1;
                            if self.sys.tiles[dst] == TileKind::Mc {
                                report.air_flits_to_mc += flits;
                            }
                            if self.sys.tiles[fl.src[i] as usize] == TileKind::Mc {
                                report.air_flits_from_mc += flits;
                            }
                            let arrive = start + ser;
                            let ev = if hop == last {
                                Event::Deliver { idx }
                            } else {
                                Event::Hop { idx, hop: hop + 1 }
                            };
                            q.push(arrive, ev);
                        }
                    }
                }
                Event::Deliver { idx } => {
                    let i = idx as usize;
                    let (src, dst) = (fl.src[i] as usize, fl.dst[i] as usize);
                    let flits = fl.flits[i];
                    // tail serialization at ejection
                    let done = t + flits.saturating_sub(1);
                    let lat = (done - fl.inject_at[i]) as f64;
                    report.latency.push(lat);
                    match pair_kind[src * n + dst] {
                        PAIR_CPU_MC => report.cpu_mc_latency.push(lat),
                        PAIR_GPU_MC => report.gpu_mc_latency.push(lat),
                        _ => {}
                    }
                    if let Some(sink) = tel.as_deref_mut() {
                        sink.delivered(pair_kind[src * n + dst], done - fl.inject_at[i]);
                    }
                    report.delivered_packets += 1;
                    report.delivered_flits += flits;
                    if done > report.cycles {
                        report.cycles = done;
                    }
                    let g = fl.group[i] as usize;
                    remaining[g] -= 1;
                    if done > group_done[g] {
                        group_done[g] = done;
                    }
                    if let Some(resp) = fl.class[i].spawns_response() {
                        let rflits = match resp {
                            MsgClass::ReadReply => self.cfg.line_flits,
                            _ => 1,
                        };
                        let r = Message {
                            src: dst,
                            dst: src,
                            flits: rflits,
                            class: resp,
                            inject_at: done + self.cfg.mc_service_cycles,
                        };
                        remaining[g] += 1;
                        let ridx = fl.push(&r, g as u32);
                        q.push(r.inject_at, Event::Inject(ridx));
                    }
                    if remaining[g] == 0 {
                        // group drained at its latest tail-delivery cycle
                        let drained_at = group_done[g];
                        drain[g] = drained_at;
                        if gated && !succs[g].is_empty() {
                            work.clear();
                            for &s in &succs[g] {
                                indeg[s as usize] -= 1;
                                if indeg[s as usize] == 0 {
                                    work.push(s);
                                }
                            }
                            if !work.is_empty() {
                                release_groups(
                                    drained_at,
                                    groups,
                                    succs,
                                    q,
                                    fl,
                                    release,
                                    drain,
                                    remaining,
                                    indeg,
                                    &mut not_released,
                                    work,
                                );
                            }
                        }
                    }
                }
            }
        }
        // Count undelivered *messages*, not queued events, split by
        // cause: `undeliverable` = released but never tail-delivered
        // (stranded by a horizon cut or dropped at an unrepairable
        // fault); `unreleased` = messages of groups never released
        // (gated behind the cut or a caller-supplied predecessor).
        // Both zero when the run completed.
        report.unreleased = not_released;
        report.undeliverable = fl.len() as u64 - report.delivered_packets;
        if let Some(sink) = tel {
            sink.finish(&report);
        }
        report
    }

    /// Path choice at injection; returns the candidate index (ALASH
    /// wireless-if-worthwhile; XY+YX by least busy first link; otherwise
    /// the primary path). Allocation-free.
    fn choose_path(
        &self,
        src: usize,
        dst: usize,
        now: u64,
        link_busy_until: &[u64],
        chan_busy_until: &[u64],
        dedicated: bool,
    ) -> u8 {
        let cands = self.routes.candidates(src, dst);
        match self.routes.kind {
            RoutingKind::Alash => {
                // §4.2.5: take the enabled wireless path when the channel
                // queue still leaves it cheaper than the wireline path;
                // CPU<->MC pairs always ride their dedicated channel
                // (contention there is only other CPU-MC traffic).
                let wire_cost = cands[0].cost_est;
                for (i, p) in cands.iter().enumerate().skip(1) {
                    if let Some(Hop::Air { channel, .. }) =
                        p.hops.iter().find(|h| matches!(h, Hop::Air { .. }))
                    {
                        let wait = chan_busy_until[*channel].saturating_sub(now);
                        if dedicated || wait + p.cost_est <= wire_cost {
                            return i as u8;
                        }
                    }
                }
                0
            }
            RoutingKind::XyYx if cands.len() > 1 => {
                let first_busy = |p: &Path| match p.hops.first() {
                    Some(Hop::Wire { link, .. }) => link_busy_until[*link],
                    _ => 0,
                };
                cands
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, p)| first_busy(p))
                    .map(|(i, _)| i as u8)
                    .unwrap_or(0)
            }
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::builder;

    fn mesh_setup() -> (SystemConfig, Topology, RouteSet) {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rs = RouteSet::xy(&sys, &topo);
        (sys, topo, rs)
    }

    #[test]
    fn single_message_zero_load_latency() {
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        // 0 -> 1: one hop; router 3 cycles + link 1 cycle + (flits-1)
        let rep = sim.run(&[Message { src: 0, dst: 1, flits: 5, class: MsgClass::Control, inject_at: 0 }]);
        assert_eq!(rep.delivered_packets, 1);
        assert_eq!(rep.latency.mean(), (3 + 1 + 4) as f64);
        assert_eq!(rep.link_flits.iter().sum::<u64>(), 5);
    }

    #[test]
    fn latency_scales_with_hops() {
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let one = sim.run(&[Message { src: 0, dst: 1, flits: 1, class: MsgClass::Control, inject_at: 0 }]);
        let far = sim.run(&[Message { src: 0, dst: 63, flits: 1, class: MsgClass::Control, inject_at: 0 }]);
        assert_eq!(one.latency.mean(), 4.0);
        assert_eq!(far.latency.mean(), 14.0 * 4.0);
    }

    #[test]
    fn contention_serializes_on_shared_link() {
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        // two 8-flit packets over the same single link at t=0
        let tr = [
            Message { src: 0, dst: 1, flits: 8, class: MsgClass::Control, inject_at: 0 },
            Message { src: 0, dst: 1, flits: 8, class: MsgClass::Control, inject_at: 0 },
        ];
        let rep = sim.run(&tr);
        assert_eq!(rep.delivered_packets, 2);
        // first: 3+1+7 = 11; second head waits 8 cycles for the link
        assert!(rep.latency.max >= 11.0 + 8.0 - 1.0);
    }

    #[test]
    fn read_request_spawns_reply() {
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let mc = sys.mcs()[0];
        let gpu = sys.gpus()[0];
        let rep = sim.run(&[Message { src: gpu, dst: mc, flits: 1, class: MsgClass::ReadReq, inject_at: 0 }]);
        assert_eq!(rep.delivered_packets, 2);
        // reply carries the line
        assert_eq!(rep.delivered_flits, 1 + 5);
        assert!(rep.gpu_mc_latency.count == 2);
    }

    #[test]
    fn wireless_shortcut_beats_wire_and_is_counted() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let mut air = WirelessSpec::new(2);
        air.add_wi(0, 1);
        air.add_wi(63, 1);
        let rs = RouteSet::alash(&topo, &air, None, |_, _| vec![1], 5);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let rep = sim.run(&[Message { src: 0, dst: 63, flits: 5, class: MsgClass::Control, inject_at: 0 }]);
        assert_eq!(rep.air_packets, 1);
        // router 3 + mac 2 + ser 13 + tail 4 = 22 << wire 14*4+4
        assert!(rep.latency.mean() < 30.0);
        assert_eq!(rep.air_flits[1], 5);
    }

    #[test]
    fn busy_channel_rejected_at_injection() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let mut air = WirelessSpec::new(2);
        air.add_wi(0, 1);
        air.add_wi(63, 1);
        air.add_wi(7, 1);
        air.add_wi(56, 1);
        let rs = RouteSet::alash(&topo, &air, None, |_, _| vec![1], 5);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        // First packet grabs the channel; the second is injected while it
        // is busy, so ALASH picks the wireline candidate immediately.
        let tr = [
            Message { src: 0, dst: 63, flits: 50, class: MsgClass::Control, inject_at: 0 },
            Message { src: 7, dst: 56, flits: 5, class: MsgClass::Control, inject_at: 20 },
        ];
        let rep = sim.run(&tr);
        assert_eq!(rep.delivered_packets, 2);
        assert_eq!(rep.air_packets, 1);
        assert_eq!(rep.air_fallbacks, 0);
    }

    #[test]
    fn channel_taken_en_route_triggers_wi_fallback() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let mut air = WirelessSpec::new(2);
        air.add_wi(9, 1);
        air.add_wi(54, 1);
        let rs = RouteSet::alash(&topo, &air, None, |_, _| vec![1], 5);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        // B (0 -> 63) picks the air path at t=0 (channel free) but needs
        // two wire hops to reach the WI at 9; A sits on the WI router and
        // grabs the channel first, so B falls back at the WI.
        let tr = [
            Message { src: 9, dst: 54, flits: 80, class: MsgClass::Control, inject_at: 0 },
            Message { src: 0, dst: 63, flits: 5, class: MsgClass::Control, inject_at: 0 },
        ];
        let rep = sim.run(&tr);
        assert_eq!(rep.delivered_packets, 2);
        assert_eq!(rep.air_packets, 1);
        assert_eq!(rep.air_fallbacks, 1);
    }

    #[test]
    fn horizon_cuts_run() {
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let cfg = SimConfig { horizon: 10, ..SimConfig::default() };
        let sim = NocSim::new(&sys, &topo, &rs, &air, cfg);
        let tr = [
            Message { src: 0, dst: 63, flits: 1, class: MsgClass::Control, inject_at: 0 },
            Message { src: 0, dst: 63, flits: 1, class: MsgClass::Control, inject_at: 1000 },
        ];
        let rep = sim.run(&tr);
        assert_eq!(rep.delivered_packets, 0);
        assert!(rep.undelivered() > 0);
    }

    #[test]
    fn horizon_counts_undelivered_messages_not_events() {
        // Regression: the old counter summed remaining *events*
        // (`q.len() + 1`); the report now counts messages. Three
        // messages: one delivered before the cut, one cut mid-flight
        // (many queued hops over its lifetime), one never injected.
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let cfg = SimConfig { horizon: 30, ..SimConfig::default() };
        let sim = NocSim::new(&sys, &topo, &rs, &air, cfg);
        let tr = [
            Message { src: 0, dst: 1, flits: 1, class: MsgClass::Control, inject_at: 0 },
            Message { src: 0, dst: 63, flits: 1, class: MsgClass::Control, inject_at: 0 },
            Message { src: 5, dst: 6, flits: 1, class: MsgClass::Control, inject_at: 5000 },
        ];
        let rep = sim.run(&tr);
        assert_eq!(rep.delivered_packets, 1);
        // plain runs release everything at cycle 0, so both cut
        // messages are stranded in flight, never "unreleased"
        assert_eq!(rep.undeliverable, 2);
        assert_eq!(rep.unreleased, 0);
        assert_eq!(rep.undelivered(), 2);
    }

    #[test]
    fn deterministic_repeat() {
        let (sys, topo, _) = mesh_setup();
        let rs = RouteSet::xy_yx(&sys, &topo);
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let tr: Vec<Message> = (0..200)
            .map(|i| Message {
                src: (i * 7) % 64,
                dst: (i * 13 + 5) % 64,
                flits: 1 + (i % 5) as u64,
                class: MsgClass::Control,
                inject_at: (i / 4) as u64,
            })
            .filter(|m| m.src != m.dst)
            .collect();
        let a = sim.run(&tr);
        let b = sim.run(&tr);
        assert_eq!(a.latency.sum, b.latency.sum);
        assert_eq!(a.link_busy, b.link_busy);
    }

    #[test]
    fn workspace_reuse_matches_fresh_runs() {
        // One workspace across different traces, platforms, and a horizon
        // cut mid-sequence must reproduce fresh-workspace results.
        let (sys, topo, _) = mesh_setup();
        let rs = RouteSet::xy_yx(&sys, &topo);
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let tr: Vec<Message> = (0..300)
            .map(|i| Message {
                src: (i * 11) % 64,
                dst: (i * 17 + 3) % 64,
                flits: 1 + (i % 7) as u64,
                class: if i % 3 == 0 { MsgClass::ReadReq } else { MsgClass::Control },
                inject_at: (i / 2) as u64,
            })
            .filter(|m| m.src != m.dst)
            .collect();
        let fresh = sim.run_in(&tr, &mut SimWorkspace::new());
        let mut ws = SimWorkspace::new();
        // dirty the workspace: a horizon-cut run and a different platform
        let cut = NocSim::new(
            &sys,
            &topo,
            &rs,
            &air,
            SimConfig { horizon: 10, ..SimConfig::default() },
        );
        let _ = cut.run_in(&tr, &mut ws);
        let small = SystemConfig::small_4x4();
        let small_topo = Topology::mesh(&small);
        let small_rs = RouteSet::xy(&small, &small_topo);
        let _ = NocSim::new(&small, &small_topo, &small_rs, &air, SimConfig::default())
            .run_in(&[Message { src: 0, dst: 15, flits: 2, class: MsgClass::Control, inject_at: 0 }], &mut ws);
        let reused = sim.run_in(&tr, &mut ws);
        assert_eq!(fresh.latency.sum, reused.latency.sum);
        assert_eq!(fresh.latency.count, reused.latency.count);
        assert_eq!(fresh.delivered_flits, reused.delivered_flits);
        assert_eq!(fresh.link_busy, reused.link_busy);
        assert_eq!(fresh.cycles, reused.cycles);
    }

    #[test]
    fn calendar_queue_orders_like_a_heap() {
        // Interleaved near/far/same-cycle pushes must come out in
        // (time, insertion order). Far pushes exercise the overflow heap.
        let mut q = CalendarQueue::new();
        let far_t = CAL_BUCKETS as u64 + 50;
        q.push(5, Event::Inject(0));
        q.push(far_t, Event::Inject(1));
        q.push(5, Event::Inject(2));
        q.push(0, Event::Inject(3));
        q.push(far_t, Event::Inject(4));
        q.push(far_t + 1, Event::Inject(5));
        let mut got = Vec::new();
        while let Some((t, ev)) = q.pop() {
            if let Event::Inject(i) = ev {
                got.push((t, i));
            }
            // same-cycle follow-up scheduled mid-drain keeps FIFO order
            if got.len() == 1 {
                q.push(0, Event::Inject(9));
            }
        }
        assert_eq!(
            got,
            vec![(0, 3), (0, 9), (5, 0), (5, 2), (far_t, 1), (far_t, 4), (far_t + 1, 5)]
        );
    }

    #[test]
    fn timeline_single_group_matches_plain_run() {
        // run() is the one-group case of the gated loop: reports agree.
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let tr: Vec<Message> = (0..120)
            .map(|i| Message {
                src: (i * 7) % 64,
                dst: (i * 19 + 3) % 64,
                flits: 1 + (i % 4) as u64,
                class: if i % 3 == 0 { MsgClass::ReadReq } else { MsgClass::Control },
                inject_at: (i / 2) as u64,
            })
            .filter(|m| m.src != m.dst)
            .collect();
        let plain = sim.run(&tr);
        let out = sim.run_timeline(&[tr.clone()], &[Vec::new()]);
        assert_eq!(plain.latency.sum, out.report.latency.sum);
        assert_eq!(plain.delivered_flits, out.report.delivered_flits);
        assert_eq!(plain.link_busy, out.report.link_busy);
        assert_eq!(plain.cycles, out.report.cycles);
        assert_eq!(out.release, vec![0]);
        assert_eq!(out.drain, vec![plain.cycles]);
    }

    #[test]
    fn timeline_gates_on_predecessor_drain() {
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let msg = |src, dst, flits| Message { src, dst, flits, class: MsgClass::Control, inject_at: 0 };
        // group 0: a slow 40-flit packet; group 1 gated behind it; group 2
        // free-running concurrently with group 0.
        let groups = vec![vec![msg(0, 1, 40)], vec![msg(0, 1, 1)], vec![msg(62, 63, 1)]];
        let preds = vec![Vec::new(), vec![0u32], Vec::new()];
        let out = sim.run_timeline(&groups, &preds);
        assert_eq!(out.report.delivered_packets, 3);
        assert_eq!(out.release[0], 0);
        assert_eq!(out.release[2], 0);
        // group 0 drains at its tail delivery; group 1 releases right there
        assert_eq!(out.release[1], out.drain[0]);
        assert!(out.drain[1] > out.drain[0]);
        // concurrency accounting: groups 0 and 1 share the 0->1 link,
        // group 2 does not touch it
        let nl = topo.links.len();
        let used: Vec<usize> = (0..nl).filter(|&l| out.group_link_flits[l] > 0).collect();
        for &l in &used {
            assert_eq!(out.group_link_flits[2 * nl + l], 0, "group 2 on group 0's link");
        }
    }

    #[test]
    fn timeline_empty_groups_cascade() {
        // an empty group drains at release and unlocks its successors
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let m = Message { src: 0, dst: 1, flits: 2, class: MsgClass::Control, inject_at: 5 };
        let groups = vec![Vec::new(), Vec::new(), vec![m]];
        let preds = vec![Vec::new(), vec![0u32], vec![1u32]];
        let out = sim.run_timeline(&groups, &preds);
        assert_eq!(out.release, vec![0, 0, 0]);
        assert_eq!(out.report.delivered_packets, 1);
        // offsets are release-relative: injected at 0 + 5
        assert_eq!(out.report.latency.count, 1);
        assert!(out.drain[2] >= 5);
    }

    #[test]
    fn timeline_horizon_counts_unreleased_messages() {
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let cfg = SimConfig { horizon: 10, ..SimConfig::default() };
        let sim = NocSim::new(&sys, &topo, &rs, &air, cfg);
        let slow = Message { src: 0, dst: 63, flits: 60, class: MsgClass::Control, inject_at: 0 };
        let late = Message { src: 5, dst: 6, flits: 1, class: MsgClass::Control, inject_at: 0 };
        let groups = vec![vec![slow], vec![late, late]];
        let preds = vec![Vec::new(), vec![0u32]];
        let out = sim.run_timeline(&groups, &preds);
        // the gated group never released: its 2 messages count as
        // unreleased; the slow packet stranded in flight is undeliverable
        assert_eq!(out.report.delivered_packets, 0);
        assert_eq!(out.report.unreleased, 2);
        assert_eq!(out.report.undeliverable, 1);
        assert_eq!(out.report.undelivered(), 3);
        assert_eq!(out.release[1], u64::MAX);
        assert_eq!(out.drain[1], u64::MAX);
    }

    #[test]
    fn dead_link_reroutes_mid_flight() {
        use crate::faults::FaultPlan;
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let victim = topo.link_between(0, 1).expect("mesh edge exists");
        let plan: FaultPlan = format!("wire:link={victim}").parse().unwrap();
        let fx = plan.compile(&topo, &rs, &air, 5).unwrap();
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let tr = [Message { src: 0, dst: 1, flits: 5, class: MsgClass::Control, inject_at: 0 }];
        let clean = sim.run(&tr);
        let faulted = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default())
            .with_faults(&fx)
            .run(&tr);
        assert_eq!(faulted.delivered_packets, 1, "repair path exists");
        assert_eq!(faulted.undeliverable, 0);
        assert_eq!(faulted.resilience.packets_rerouted, 1);
        assert_eq!(faulted.resilience.undeliverable_after_repair, 0);
        assert_eq!(faulted.resilience.faults_injected, 1);
        assert!(
            faulted.latency.mean() > clean.latency.mean(),
            "the detour must cost cycles: {} vs {}",
            faulted.latency.mean(),
            clean.latency.mean()
        );
        // the dead link never carried a flit
        assert_eq!(faulted.link_flits[victim], 0);
    }

    #[test]
    fn link_dying_later_spares_early_packets() {
        use crate::faults::FaultPlan;
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let victim = topo.link_between(0, 1).expect("mesh edge exists");
        let plan: FaultPlan = format!("wire:link={victim},at=1000").parse().unwrap();
        let fx = plan.compile(&topo, &rs, &air, 5).unwrap();
        let sim =
            NocSim::new(&sys, &topo, &rs, &air, SimConfig::default()).with_faults(&fx);
        let m = |at| Message { src: 0, dst: 1, flits: 5, class: MsgClass::Control, inject_at: at };
        let rep = sim.run(&[m(0), m(2000)]);
        assert_eq!(rep.delivered_packets, 2);
        // only the packet reaching the link after cycle 1000 re-routes
        assert_eq!(rep.resilience.packets_rerouted, 1);
        assert_eq!(rep.link_flits[victim], 5);
    }

    #[test]
    fn jammed_channel_retries_then_falls_back() {
        use crate::faults::FaultPlan;
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let mut air = WirelessSpec::new(2);
        air.add_wi(0, 1);
        air.add_wi(63, 1);
        let rs = RouteSet::alash(&topo, &air, None, |_, _| vec![1], 5);
        let tr = [Message { src: 0, dst: 63, flits: 5, class: MsgClass::Control, inject_at: 0 }];
        // a jam outlasting the whole backoff budget forces wireline
        let long: FaultPlan = "air:ch=1,from=0,burst=100000".parse().unwrap();
        let fx = long.compile(&topo, &rs, &air, 5).unwrap();
        let rep = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default())
            .with_faults(&fx)
            .run(&tr);
        assert_eq!(rep.delivered_packets, 1);
        assert_eq!(rep.air_packets, 0, "channel unusable for the whole flight");
        assert_eq!(rep.air_fallbacks, 1);
        assert_eq!(rep.resilience.retries, AIR_MAX_RETRIES as u64);
        assert_eq!(rep.resilience.fallback_flits, 5);
        // a short burst is ridden out within the retry budget
        let short: FaultPlan = "air:ch=1,from=0,burst=20".parse().unwrap();
        let fx = short.compile(&topo, &rs, &air, 5).unwrap();
        let rep = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default())
            .with_faults(&fx)
            .run(&tr);
        assert_eq!(rep.delivered_packets, 1);
        assert_eq!(rep.air_packets, 1, "backoff outlives the burst");
        assert!(rep.resilience.retries >= 1);
        assert_eq!(rep.resilience.fallback_flits, 0);
    }

    #[test]
    fn wihetnoc_builder_smoke() {
        // integration with the builder: full WiHetNoC sim runs
        let sys = SystemConfig::paper_8x8();
        let inst = builder::wi_het_noc_quick(&sys, 42);
        let sim = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
        let tr = [Message { src: sys.gpus()[0], dst: sys.mcs()[0], flits: 1, class: MsgClass::ReadReq, inject_at: 0 }];
        let rep = sim.run(&tr);
        assert_eq!(rep.delivered_packets, 2);
    }
}

//! Event-driven, flit-time-accurate NoC simulator.
//!
//! Fidelity model (DESIGN.md §2): wormhole switching is approximated at
//! packet granularity — the head flit advances through the 3-stage (or
//! 4-stage for >4-port) router pipeline per hop, waits for the output link
//! to drain (`busy_until`), and each wireline link is occupied for one
//! cycle per flit, so contention, serialization, and per-link utilization
//! are all explicit. Delivery completes when the tail streams out at the
//! destination. Buffers are not depth-limited; saturation shows up as
//! unbounded queueing delay on hot links, which is how the throughput
//! experiments detect it (Fig 14 methodology).
//!
//! The memory system is closed-loop: a delivered `ReadReq` spawns a
//! `ReadReply` (cache-line payload) after the MC service latency, and a
//! `WriteData` spawns a `WriteAck`, reproducing the request/reply
//! asymmetry the paper measures (Fig 6).
//!
//! Wireless hops implement the §4.2.5 MAC: if the channel is busy when the
//! head reaches the WI, the packet is *re-routed on the spot* over the
//! wireline shortest path from that router; otherwise it pays the request
//! period (one slot per WI on the channel) and occupies the channel for
//! its serialization time.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::routing::{Hop, Path, RouteSet, RoutingKind};
use super::topology::Topology;
use super::wireless::WirelessSpec;
use crate::model::{SystemConfig, TileKind};
use crate::util::stats::Accum;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MsgClass {
    /// 1-flit read request; MC answers with a ReadReply.
    ReadReq,
    /// Cache-line reply (header + line/flit flits).
    ReadReply,
    /// Cache-line writeback; MC answers with a WriteAck.
    WriteData,
    /// 1-flit write acknowledgment.
    WriteAck,
    /// Raw control/synthetic message; no response.
    Control,
}

impl MsgClass {
    pub fn spawns_response(&self) -> Option<MsgClass> {
        match self {
            MsgClass::ReadReq => Some(MsgClass::ReadReply),
            MsgClass::WriteData => Some(MsgClass::WriteAck),
            _ => None,
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Message {
    pub src: usize,
    pub dst: usize,
    pub flits: u64,
    pub class: MsgClass,
    pub inject_at: u64,
}

#[derive(Debug, Clone)]
pub struct SimConfig {
    /// MC service latency (L2 lookup + DRAM amortized) in cycles.
    pub mc_service_cycles: u64,
    /// Flits in a cache-line-carrying packet (header + payload).
    pub line_flits: u64,
    /// Nominal flits used for wireless path-enabling cost estimates.
    pub nominal_flits: u64,
    /// Stop simulating at this cycle even if messages remain (0 = run all).
    pub horizon: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { mc_service_cycles: 20, line_flits: 5, nominal_flits: 5, horizon: 0 }
    }
}

/// Aggregated results of one simulation run.
#[derive(Debug, Clone, Default)]
pub struct SimReport {
    /// End-to-end packet latency (inject -> tail delivered), all packets.
    pub latency: Accum,
    /// Latency restricted to CPU<->MC packets (the paper's CPU QoS metric).
    pub cpu_mc_latency: Accum,
    /// Latency restricted to GPU<->MC packets.
    pub gpu_mc_latency: Accum,
    pub delivered_packets: u64,
    pub delivered_flits: u64,
    /// Last delivery cycle (simulated time span).
    pub cycles: u64,
    /// Busy cycles per wireline link.
    pub link_busy: Vec<u64>,
    /// Flits carried per wireline link.
    pub link_flits: Vec<u64>,
    /// Flit-traversals per router (for energy accounting).
    pub router_flits: Vec<u64>,
    /// Busy cycles per wireless channel.
    pub air_busy: Vec<u64>,
    /// Flits carried per wireless channel.
    pub air_flits: Vec<u64>,
    /// Packets that took a wireless hop.
    pub air_packets: u64,
    /// Packets that wanted wireless but found the channel busy.
    pub air_fallbacks: u64,
    /// Wireless flits by direction: to an MC (core->MC) / from an MC.
    pub air_flits_to_mc: u64,
    pub air_flits_from_mc: u64,
    /// Messages not delivered when the horizon cut the run.
    pub undelivered: u64,
}

impl SimReport {
    /// Mean link utilization over the simulated span.
    pub fn link_utilization(&self) -> Vec<f64> {
        let c = self.cycles.max(1) as f64;
        self.link_busy.iter().map(|&b| b as f64 / c).collect()
    }

    /// Delivered flits per cycle (network throughput).
    pub fn throughput(&self) -> f64 {
        self.delivered_flits as f64 / self.cycles.max(1) as f64
    }

    /// Fraction of delivered packets that used a wireless hop.
    pub fn wireless_utilization(&self) -> f64 {
        self.air_packets as f64 / self.delivered_packets.max(1) as f64
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Event {
    Inject(u32),
    /// Head of message `idx` ready to take `hop` of its path at this time.
    Hop { idx: u32, hop: u16 },
    Deliver { idx: u32 },
}

impl Event {
    /// Pack into a u64 (kind << 48 | hop << 32 | idx) so heap entries are
    /// a flat `(time, seq, packed)` triple — no side payload storage.
    #[inline]
    fn pack(self) -> u64 {
        match self {
            Event::Inject(idx) => idx as u64,
            Event::Hop { idx, hop } => (1 << 48) | ((hop as u64) << 32) | idx as u64,
            Event::Deliver { idx } => (2 << 48) | idx as u64,
        }
    }

    #[inline]
    fn unpack(v: u64) -> Event {
        let idx = v as u32;
        match v >> 48 {
            0 => Event::Inject(idx),
            1 => Event::Hop { idx, hop: (v >> 32) as u16 },
            _ => Event::Deliver { idx },
        }
    }
}

/// Time-ordered event queue; ties broken by insertion order so runs are
/// fully deterministic.
struct EventQueue {
    heap: BinaryHeap<Reverse<(u64, u64, u64)>>,
    seq: u64,
}

impl EventQueue {
    fn new(cap: usize) -> Self {
        EventQueue { heap: BinaryHeap::with_capacity(cap * 2), seq: 0 }
    }

    #[inline]
    fn push(&mut self, t: u64, ev: Event) {
        self.heap.push(Reverse((t, self.seq, ev.pack())));
        self.seq += 1;
    }

    #[inline]
    fn pop(&mut self) -> Option<(u64, Event)> {
        self.heap.pop().map(|Reverse((t, _, p))| (t, Event::unpack(p)))
    }

    fn len(&self) -> usize {
        self.heap.len()
    }
}

/// Route handle: (route source, destination, candidate index) into the
/// shared `RouteSet` — no per-packet path allocation. After a MAC
/// fallback the route re-roots at the WI router (`src` becomes that
/// router, `idx` 0 = the wireline primary).
#[derive(Debug, Clone, Copy)]
struct RouteRef {
    src: u32,
    dst: u32,
    idx: u8,
}

struct InFlight {
    msg: Message,
    route: RouteRef,
}

/// The simulator. Owns per-run mutable state; `topo`/`routes`/`air` are
/// borrowed per `run`.
pub struct NocSim<'a> {
    pub sys: &'a SystemConfig,
    pub topo: &'a Topology,
    pub routes: &'a RouteSet,
    pub air: &'a WirelessSpec,
    pub cfg: SimConfig,
}

impl<'a> NocSim<'a> {
    pub fn new(
        sys: &'a SystemConfig,
        topo: &'a Topology,
        routes: &'a RouteSet,
        air: &'a WirelessSpec,
        cfg: SimConfig,
    ) -> Self {
        NocSim { sys, topo, routes, air, cfg }
    }

    /// Run the trace to completion (or the configured horizon).
    pub fn run(&self, trace: &[Message]) -> SimReport {
        let nl = self.topo.links.len();
        let nch = self.air.num_channels.max(1);
        let mut report = SimReport {
            link_busy: vec![0; nl],
            link_flits: vec![0; nl],
            router_flits: vec![0; self.topo.n],
            air_busy: vec![0; nch],
            air_flits: vec![0; nch],
            ..SimReport::default()
        };
        let mut link_busy_until = vec![0u64; nl];
        let mut chan_busy_until = vec![0u64; nch];

        let mut flights: Vec<InFlight> = Vec::with_capacity(trace.len() * 2);
        let mut q = EventQueue::new(trace.len() * 2);
        for m in trace {
            let idx = flights.len() as u32;
            flights.push(InFlight {
                msg: *m,
                route: RouteRef { src: m.src as u32, dst: m.dst as u32, idx: 0 },
            });
            q.push(m.inject_at, Event::Inject(idx));
        }

        while let Some((t, ev)) = q.pop() {
            if self.cfg.horizon > 0 && t > self.cfg.horizon {
                report.undelivered += (q.len() as u64) + 1;
                break;
            }
            match ev {
                Event::Inject(idx) => {
                    let (src, dst) = {
                        let m = &flights[idx as usize].msg;
                        (m.src, m.dst)
                    };
                    if src == dst {
                        q.push(t, Event::Deliver { idx });
                        continue;
                    }
                    let cand = self.choose_path(src, dst, t, &link_busy_until, &chan_busy_until);
                    flights[idx as usize].route =
                        RouteRef { src: src as u32, dst: dst as u32, idx: cand };
                    q.push(t, Event::Hop { idx, hop: 0 });
                }
                Event::Hop { idx, hop } => {
                    let flits = flights[idx as usize].msg.flits;
                    let dst = flights[idx as usize].msg.dst;
                    let rr = flights[idx as usize].route;
                    let path: &Path = &self.routes.candidates(rr.src as usize, rr.dst as usize)
                        [rr.idx as usize];
                    let h = path.hops[hop as usize];
                    let from = h.from();
                    let ready = t + self.topo.router_delay(from);
                    report.router_flits[from] += flits;
                    let last = path.hops.len() as u16 - 1;
                    match h {
                        Hop::Wire { link, .. } => {
                            let start = ready.max(link_busy_until[link]);
                            link_busy_until[link] = start + flits;
                            report.link_busy[link] += flits;
                            report.link_flits[link] += flits;
                            let arrive = start + self.topo.links[link].delay_cycles;
                            let ev = if hop == last {
                                Event::Deliver { idx }
                            } else {
                                Event::Hop { idx, hop: hop + 1 }
                            };
                            q.push(arrive, ev);
                        }
                        Hop::Air { channel, .. } => {
                            let mac = self.air.mac_overhead_cycles(channel);
                            let ser = self.air.serialize_cycles(flits);
                            let wait = chan_busy_until[channel].saturating_sub(ready);
                            // MAC decision: queue for the channel if the
                            // residual wait still beats re-routing over
                            // wireline from this router; otherwise fall
                            // back (§4.2.5).
                            // Dedicated CPU-MC packets tolerate a longer
                            // queue before abandoning their channel — the
                            // wireline alternative is GPU-congested, which
                            // the zero-load estimate cannot see.
                            let dedicated = self
                                .pair_kind(flights[idx as usize].msg.src, dst)
                                == Some(TileKind::Cpu);
                            let wire_alt = self.routes.primary(from, dst).cost_est
                                * if dedicated { 4 } else { 1 };
                            if wait > 0 && wait + mac + ser > wire_alt {
                                report.air_fallbacks += 1;
                                // re-root on the wireline primary from here
                                flights[idx as usize].route =
                                    RouteRef { src: from as u32, dst: dst as u32, idx: 0 };
                                if self.routes.primary(from, dst).hops.is_empty() {
                                    q.push(ready, Event::Deliver { idx });
                                } else {
                                    q.push(ready, Event::Hop { idx, hop: 0 });
                                }
                                continue;
                            }
                            let start = ready + wait + mac;
                            chan_busy_until[channel] = start + ser;
                            report.air_busy[channel] += ser;
                            report.air_flits[channel] += flits;
                            report.air_packets += 1;
                            if self.sys.tiles[dst] == TileKind::Mc {
                                report.air_flits_to_mc += flits;
                            }
                            if self.sys.tiles[flights[idx as usize].msg.src] == TileKind::Mc {
                                report.air_flits_from_mc += flits;
                            }
                            let arrive = start + ser;
                            let ev = if hop == last {
                                Event::Deliver { idx }
                            } else {
                                Event::Hop { idx, hop: hop + 1 }
                            };
                            q.push(arrive, ev);
                        }
                    }
                }
                Event::Deliver { idx } => {
                    let m = flights[idx as usize].msg;
                    // tail serialization at ejection
                    let done = t + m.flits.saturating_sub(1);
                    let lat = (done - m.inject_at) as f64;
                    report.latency.push(lat);
                    match self.pair_kind(m.src, m.dst) {
                        Some(TileKind::Cpu) => report.cpu_mc_latency.push(lat),
                        Some(TileKind::Gpu) => report.gpu_mc_latency.push(lat),
                        _ => {}
                    }
                    report.delivered_packets += 1;
                    report.delivered_flits += m.flits;
                    if done > report.cycles {
                        report.cycles = done;
                    }
                    if let Some(resp) = m.class.spawns_response() {
                        let flits = match resp {
                            MsgClass::ReadReply => self.cfg.line_flits,
                            _ => 1,
                        };
                        let r = Message {
                            src: m.dst,
                            dst: m.src,
                            flits,
                            class: resp,
                            inject_at: done + self.cfg.mc_service_cycles,
                        };
                        let ridx = flights.len() as u32;
                        flights.push(InFlight {
                            msg: r,
                            route: RouteRef { src: r.src as u32, dst: r.dst as u32, idx: 0 },
                        });
                        q.push(r.inject_at, Event::Inject(ridx));
                    }
                }
            }
        }
        report
    }

    /// Path choice at injection; returns the candidate index (ALASH
    /// wireless-if-worthwhile; XY+YX by least busy first link; otherwise
    /// the primary path). Allocation-free.
    fn choose_path(
        &self,
        src: usize,
        dst: usize,
        now: u64,
        link_busy_until: &[u64],
        chan_busy_until: &[u64],
    ) -> u8 {
        let cands = self.routes.candidates(src, dst);
        match self.routes.kind {
            RoutingKind::Alash => {
                // §4.2.5: take the enabled wireless path when the channel
                // queue still leaves it cheaper than the wireline path;
                // CPU<->MC pairs always ride their dedicated channel
                // (contention there is only other CPU-MC traffic).
                let dedicated = self.pair_kind(src, dst) == Some(TileKind::Cpu);
                let wire_cost = cands[0].cost_est;
                for (i, p) in cands.iter().enumerate().skip(1) {
                    if let Some(Hop::Air { channel, .. }) =
                        p.hops.iter().find(|h| matches!(h, Hop::Air { .. }))
                    {
                        let wait = chan_busy_until[*channel].saturating_sub(now);
                        if dedicated || wait + p.cost_est <= wire_cost {
                            return i as u8;
                        }
                    }
                }
                0
            }
            RoutingKind::XyYx if cands.len() > 1 => {
                let first_busy = |p: &Path| match p.hops.first() {
                    Some(Hop::Wire { link, .. }) => link_busy_until[*link],
                    _ => 0,
                };
                cands
                    .iter()
                    .enumerate()
                    .min_by_key(|(_, p)| first_busy(p))
                    .map(|(i, _)| i as u8)
                    .unwrap_or(0)
            }
            _ => 0,
        }
    }

    fn pair_kind(&self, src: usize, dst: usize) -> Option<TileKind> {
        let (a, b) = (self.sys.tiles[src], self.sys.tiles[dst]);
        match (a, b) {
            (TileKind::Cpu, TileKind::Mc) | (TileKind::Mc, TileKind::Cpu) => Some(TileKind::Cpu),
            (TileKind::Gpu, TileKind::Mc) | (TileKind::Mc, TileKind::Gpu) => Some(TileKind::Gpu),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::builder;

    fn mesh_setup() -> (SystemConfig, Topology, RouteSet) {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rs = RouteSet::xy(&sys, &topo);
        (sys, topo, rs)
    }

    #[test]
    fn single_message_zero_load_latency() {
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        // 0 -> 1: one hop; router 3 cycles + link 1 cycle + (flits-1)
        let rep = sim.run(&[Message { src: 0, dst: 1, flits: 5, class: MsgClass::Control, inject_at: 0 }]);
        assert_eq!(rep.delivered_packets, 1);
        assert_eq!(rep.latency.mean(), (3 + 1 + 4) as f64);
        assert_eq!(rep.link_flits.iter().sum::<u64>(), 5);
    }

    #[test]
    fn latency_scales_with_hops() {
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let one = sim.run(&[Message { src: 0, dst: 1, flits: 1, class: MsgClass::Control, inject_at: 0 }]);
        let far = sim.run(&[Message { src: 0, dst: 63, flits: 1, class: MsgClass::Control, inject_at: 0 }]);
        assert_eq!(one.latency.mean(), 4.0);
        assert_eq!(far.latency.mean(), 14.0 * 4.0);
    }

    #[test]
    fn contention_serializes_on_shared_link() {
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        // two 8-flit packets over the same single link at t=0
        let tr = [
            Message { src: 0, dst: 1, flits: 8, class: MsgClass::Control, inject_at: 0 },
            Message { src: 0, dst: 1, flits: 8, class: MsgClass::Control, inject_at: 0 },
        ];
        let rep = sim.run(&tr);
        assert_eq!(rep.delivered_packets, 2);
        // first: 3+1+7 = 11; second head waits 8 cycles for the link
        assert!(rep.latency.max >= 11.0 + 8.0 - 1.0);
    }

    #[test]
    fn read_request_spawns_reply() {
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let mc = sys.mcs()[0];
        let gpu = sys.gpus()[0];
        let rep = sim.run(&[Message { src: gpu, dst: mc, flits: 1, class: MsgClass::ReadReq, inject_at: 0 }]);
        assert_eq!(rep.delivered_packets, 2);
        // reply carries the line
        assert_eq!(rep.delivered_flits, 1 + 5);
        assert!(rep.gpu_mc_latency.count == 2);
    }

    #[test]
    fn wireless_shortcut_beats_wire_and_is_counted() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let mut air = WirelessSpec::new(2);
        air.add_wi(0, 1);
        air.add_wi(63, 1);
        let rs = RouteSet::alash(&topo, &air, None, |_, _| vec![1], 5);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let rep = sim.run(&[Message { src: 0, dst: 63, flits: 5, class: MsgClass::Control, inject_at: 0 }]);
        assert_eq!(rep.air_packets, 1);
        // router 3 + mac 2 + ser 13 + tail 4 = 22 << wire 14*4+4
        assert!(rep.latency.mean() < 30.0);
        assert_eq!(rep.air_flits[1], 5);
    }

    #[test]
    fn busy_channel_rejected_at_injection() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let mut air = WirelessSpec::new(2);
        air.add_wi(0, 1);
        air.add_wi(63, 1);
        air.add_wi(7, 1);
        air.add_wi(56, 1);
        let rs = RouteSet::alash(&topo, &air, None, |_, _| vec![1], 5);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        // First packet grabs the channel; the second is injected while it
        // is busy, so ALASH picks the wireline candidate immediately.
        let tr = [
            Message { src: 0, dst: 63, flits: 50, class: MsgClass::Control, inject_at: 0 },
            Message { src: 7, dst: 56, flits: 5, class: MsgClass::Control, inject_at: 20 },
        ];
        let rep = sim.run(&tr);
        assert_eq!(rep.delivered_packets, 2);
        assert_eq!(rep.air_packets, 1);
        assert_eq!(rep.air_fallbacks, 0);
    }

    #[test]
    fn channel_taken_en_route_triggers_wi_fallback() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let mut air = WirelessSpec::new(2);
        air.add_wi(9, 1);
        air.add_wi(54, 1);
        let rs = RouteSet::alash(&topo, &air, None, |_, _| vec![1], 5);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        // B (0 -> 63) picks the air path at t=0 (channel free) but needs
        // two wire hops to reach the WI at 9; A sits on the WI router and
        // grabs the channel first, so B falls back at the WI.
        let tr = [
            Message { src: 9, dst: 54, flits: 80, class: MsgClass::Control, inject_at: 0 },
            Message { src: 0, dst: 63, flits: 5, class: MsgClass::Control, inject_at: 0 },
        ];
        let rep = sim.run(&tr);
        assert_eq!(rep.delivered_packets, 2);
        assert_eq!(rep.air_packets, 1);
        assert_eq!(rep.air_fallbacks, 1);
    }

    #[test]
    fn horizon_cuts_run() {
        let (sys, topo, rs) = mesh_setup();
        let air = WirelessSpec::new(0);
        let cfg = SimConfig { horizon: 10, ..SimConfig::default() };
        let sim = NocSim::new(&sys, &topo, &rs, &air, cfg);
        let tr = [
            Message { src: 0, dst: 63, flits: 1, class: MsgClass::Control, inject_at: 0 },
            Message { src: 0, dst: 63, flits: 1, class: MsgClass::Control, inject_at: 1000 },
        ];
        let rep = sim.run(&tr);
        assert_eq!(rep.delivered_packets, 0);
        assert!(rep.undelivered > 0);
    }

    #[test]
    fn deterministic_repeat() {
        let (sys, topo, _) = mesh_setup();
        let rs = RouteSet::xy_yx(&sys, &topo);
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let tr: Vec<Message> = (0..200)
            .map(|i| Message {
                src: (i * 7) % 64,
                dst: (i * 13 + 5) % 64,
                flits: 1 + (i % 5) as u64,
                class: MsgClass::Control,
                inject_at: (i / 4) as u64,
            })
            .filter(|m| m.src != m.dst)
            .collect();
        let a = sim.run(&tr);
        let b = sim.run(&tr);
        assert_eq!(a.latency.sum, b.latency.sum);
        assert_eq!(a.link_busy, b.link_busy);
    }

    #[test]
    fn wihetnoc_builder_smoke() {
        // integration with the builder: full WiHetNoC sim runs
        let sys = SystemConfig::paper_8x8();
        let inst = builder::wi_het_noc_quick(&sys, 42);
        let sim = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
        let tr = [Message { src: sys.gpus()[0], dst: sys.mcs()[0], flits: 1, class: MsgClass::ReadReq, inject_at: 0 }];
        let rep = sim.run(&tr);
        assert_eq!(rep.delivered_packets, 2);
    }
}

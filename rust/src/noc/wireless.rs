//! mm-wave wireless overlay: wireless interfaces (WIs), channels, and the
//! distributed token-slot MAC of §4.2.5 / [44].
//!
//! Five non-overlapping channels (30/60/90/140/200 GHz), 16 Gbps each,
//! single-hop over >= 20 mm — i.e. any WI reaches any other WI on the same
//! channel in one hop anywhere on the 20x20 mm die. Channel 0 is dedicated
//! to CPU<->MC traffic (the paper's QoS isolation); the remaining channels
//! carry GPU<->MC traffic.
//!
//! MAC: when a message wants a channel, the WI first checks the medium;
//! if busy the packet is immediately re-routed over wireline (the paper's
//! fallback rule — wireless links can never become bandwidth bottlenecks).
//! If free, a request period of `N` broadcast slots runs (one slot per WI
//! sharing the channel) followed by a fairness-based grant.

/// One wireless interface, attached to a router and tuned to one channel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Wi {
    pub router: usize,
    pub channel: usize,
}

/// Wireless configuration overlaying a wireline topology.
#[derive(Debug, Clone, Default)]
pub struct WirelessSpec {
    pub wis: Vec<Wi>,
    pub num_channels: usize,
    /// Channel occupancy per flit, in half-cycles (fixed-point x2 so the
    /// default of 2.5 cycles/flit stays integral).
    ///
    /// Derivation: the paper's 16 Gbps is the *raw* per-channel rate — a
    /// 128-bit flit at 16e9/128 = 125 M flits/s against a 2.5 GHz NoC
    /// clock would mean 20 cycles of serialization per flit. But the WI
    /// burst-buffers a packet and streams it over the multi-band
    /// aggregate ([13]'s on-off-keying transceiver), so the *channel
    /// occupancy* charged by the MAC is much shorter than wire-rate
    /// serialization. We model 2.5 NoC cycles of occupancy per flit
    /// (128 Gbps effective burst rate), calibrated so single-hop
    /// wireless shortcuts reproduce the paper's long-range latency win;
    /// coding/sync overheads are folded into the MAC request period
    /// instead. See DESIGN.md §6.
    pub cycles_per_flit_x2: u64,
    /// WI transceiver area (mm^2), paper §4.2.4.
    pub wi_area_mm2: f64,
    /// Wireless energy (pJ/bit), paper §4.2.4.
    pub pj_per_bit: f64,
}

pub const DEFAULT_CYCLES_PER_FLIT_X2: u64 = 5; // 2.5 cycles/flit, fixed-point x2
pub const WI_AREA_MM2: f64 = 0.25;
pub const WIRELESS_PJ_PER_BIT: f64 = 1.3;
pub const MAX_CHANNELS: usize = 5;

impl WirelessSpec {
    pub fn new(num_channels: usize) -> Self {
        assert!(num_channels <= MAX_CHANNELS);
        WirelessSpec {
            wis: Vec::new(),
            num_channels,
            cycles_per_flit_x2: DEFAULT_CYCLES_PER_FLIT_X2,
            wi_area_mm2: WI_AREA_MM2,
            pj_per_bit: WIRELESS_PJ_PER_BIT,
        }
    }

    pub fn add_wi(&mut self, router: usize, channel: usize) {
        assert!(channel < self.num_channels, "channel {channel} out of range");
        debug_assert!(
            !self.wis.iter().any(|w| w.router == router && w.channel == channel),
            "duplicate WI router {router} channel {channel}"
        );
        self.wis.push(Wi { router, channel });
    }

    /// WIs tuned to `channel`.
    pub fn on_channel(&self, channel: usize) -> Vec<Wi> {
        self.wis.iter().copied().filter(|w| w.channel == channel).collect()
    }

    /// The WI (if any) at `router` on `channel`.
    pub fn wi_at(&self, router: usize, channel: usize) -> Option<Wi> {
        self.wis
            .iter()
            .copied()
            .find(|w| w.router == router && w.channel == channel)
    }

    /// Channels available at `router`.
    pub fn channels_at(&self, router: usize) -> Vec<usize> {
        self.wis
            .iter()
            .filter(|w| w.router == router)
            .map(|w| w.channel)
            .collect()
    }

    /// MAC request-period overhead in cycles when acquiring `channel`:
    /// one broadcast slot per WI sharing the channel (§4.2.5). The grant
    /// decision itself is folded into the same slots.
    pub fn mac_overhead_cycles(&self, channel: usize) -> u64 {
        self.wis.iter().filter(|w| w.channel == channel).count() as u64
    }

    /// Serialization occupancy (cycles) for a packet of `flits`.
    pub fn serialize_cycles(&self, flits: u64) -> u64 {
        (flits * self.cycles_per_flit_x2).div_ceil(2)
    }

    /// Total silicon area of all WIs (mm^2) — 24 WIs = 1.5% of a 400 mm^2
    /// die plus the CPU/MC channel WIs (paper: 1.82% total).
    pub fn total_area_mm2(&self) -> f64 {
        self.wis.len() as f64 * self.wi_area_mm2
    }

    pub fn is_empty(&self) -> bool {
        self.wis.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wi_bookkeeping() {
        let mut w = WirelessSpec::new(5);
        w.add_wi(3, 0);
        w.add_wi(9, 1);
        w.add_wi(12, 1);
        assert_eq!(w.on_channel(1).len(), 2);
        assert_eq!(w.wi_at(9, 1), Some(Wi { router: 9, channel: 1 }));
        assert_eq!(w.wi_at(9, 0), None);
        assert_eq!(w.channels_at(9), vec![1]);
        assert_eq!(w.mac_overhead_cycles(1), 2);
    }

    #[test]
    fn serialization_cycles() {
        let w = WirelessSpec::new(1);
        // 2.5 cycles per flit
        assert_eq!(w.serialize_cycles(1), 3); // ceil(2.5)
        assert_eq!(w.serialize_cycles(2), 5);
        assert_eq!(w.serialize_cycles(5), 13); // ceil(12.5)
    }

    #[test]
    fn area() {
        let mut w = WirelessSpec::new(5);
        for r in 0..24 {
            w.add_wi(r, r % 4 + 1);
        }
        assert!((w.total_area_mm2() - 6.0).abs() < 1e-12);
        // paper: 24 GPU-MC WIs = 1.5% of 400 mm^2
        assert!((w.total_area_mm2() / 400.0 - 0.015).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn channel_bound() {
        let mut w = WirelessSpec::new(2);
        w.add_wi(0, 2);
    }
}

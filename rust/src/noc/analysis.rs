//! Analytic link-utilization model — Eqns (3)-(5) of the paper.
//!
//! `U_k = Σ_i Σ_j f_ij · p_ijk` with `p_ijk` from deterministic shortest-
//! path routing (BFS with lowest-id tie-break, matching `RouteSet`'s
//! deterministic paths). This is the objective function evaluated inside
//! the AMOSA loop, so it is written allocation-lean: one BFS per traffic
//! source, then one parent-walk per destination.

use super::topology::Topology;

/// Sparse traffic-frequency matrix `f_ij` (flits/cycle between routers).
#[derive(Debug, Clone, Default)]
pub struct TrafficMatrix {
    pub n: usize,
    /// (src, dst, flits-per-cycle), grouped by src (not required, but
    /// `from_entries` sorts to maximize BFS reuse).
    pub entries: Vec<(u32, u32, f64)>,
}

impl TrafficMatrix {
    pub fn from_entries(n: usize, mut entries: Vec<(u32, u32, f64)>) -> Self {
        entries.retain(|e| e.2 > 0.0 && e.0 != e.1);
        entries.sort_by_key(|e| (e.0, e.1));
        // merge duplicates
        let mut merged: Vec<(u32, u32, f64)> = Vec::with_capacity(entries.len());
        for e in entries {
            match merged.last_mut() {
                Some(last) if last.0 == e.0 && last.1 == e.1 => last.2 += e.2,
                _ => merged.push(e),
            }
        }
        TrafficMatrix { n, entries: merged }
    }

    pub fn total(&self) -> f64 {
        self.entries.iter().map(|e| e.2).sum()
    }

    /// Scale all frequencies by `s` (used to sweep injection rates).
    pub fn scaled(&self, s: f64) -> Self {
        TrafficMatrix {
            n: self.n,
            entries: self.entries.iter().map(|&(a, b, f)| (a, b, f * s)).collect(),
        }
    }
}

/// Result of the analytic evaluation.
#[derive(Debug, Clone)]
pub struct Analysis {
    /// Expected utilization per link (flits/cycle crossing it), Eqn 3.
    pub link_util: Vec<f64>,
    /// Mean link utilization Ū, Eqn 4.
    pub u_mean: f64,
    /// Std-dev of link utilizations σ, Eqn 5.
    pub u_std: f64,
    /// Traffic-weighted hop count Σ f_ij·h_ij (Ū numerator).
    pub twhc: f64,
    /// true iff every routed pair was reachable.
    pub connected: bool,
}

/// Scratch buffers reused across evaluations (AMOSA calls this ~10^5
/// times). Holds a CSR copy of the adjacency (flat, cache-friendly) plus
/// BFS state and the utilization accumulator — `analyze_with` performs no
/// heap allocation beyond the returned `Analysis`.
#[derive(Debug, Clone)]
pub struct AnalysisScratch {
    dist: Vec<u32>,
    parent_link: Vec<u32>,
    queue: Vec<u32>,
    util: Vec<f64>,
}

impl AnalysisScratch {
    pub fn new(n: usize) -> Self {
        AnalysisScratch {
            dist: vec![0; n],
            parent_link: vec![0; n],
            queue: Vec::with_capacity(n),
            util: Vec::new(),
        }
    }
}

/// Objective-only summary (no per-link vector) for the optimizer loop.
#[derive(Debug, Clone, Copy)]
pub struct ObjectiveSummary {
    pub u_mean: f64,
    pub u_std: f64,
    pub twhc: f64,
    pub connected: bool,
}

/// Evaluate Eqns (3)-(5) for `topo` under `traffic`.
pub fn analyze(topo: &Topology, traffic: &TrafficMatrix) -> Analysis {
    let mut scratch = AnalysisScratch::new(topo.n);
    analyze_with(topo, traffic, &mut scratch)
}

pub fn analyze_with(
    topo: &Topology,
    traffic: &TrafficMatrix,
    scratch: &mut AnalysisScratch,
) -> Analysis {
    let s = analyze_objectives(topo, traffic, scratch);
    Analysis {
        link_util: scratch.util.clone(),
        u_mean: s.u_mean,
        u_std: s.u_std,
        twhc: s.twhc,
        connected: s.connected,
    }
}

/// Allocation-free evaluation; per-link utilizations stay in `scratch`.
pub fn analyze_objectives(
    topo: &Topology,
    traffic: &TrafficMatrix,
    scratch: &mut AnalysisScratch,
) -> ObjectiveSummary {
    let nl = topo.links.len();
    scratch.util.clear();
    scratch.util.resize(nl, 0.0);
    let mut twhc = 0.0;
    let mut connected = true;

    let mut idx = 0;
    let entries = &traffic.entries;
    while idx < entries.len() {
        let src = entries[idx].0;
        // BFS once per source; deterministic lowest-id tie-break comes from
        // adjacency order (stable across identical topologies).
        bfs(topo, src as usize, scratch);
        while idx < entries.len() && entries[idx].0 == src {
            let (_, dst, f) = entries[idx];
            idx += 1;
            if scratch.dist[dst as usize] == u32::MAX {
                connected = false;
                continue;
            }
            twhc += f * scratch.dist[dst as usize] as f64;
            // walk dst -> src along parent links
            let mut cur = dst as usize;
            while cur != src as usize {
                let l = scratch.parent_link[cur] as usize;
                scratch.util[l] += f;
                let link = &topo.links[l];
                cur = if link.a == cur { link.b } else { link.a };
            }
        }
    }

    let u_mean = if nl == 0 { 0.0 } else { scratch.util.iter().sum::<f64>() / nl as f64 };
    let var = if nl == 0 {
        0.0
    } else {
        scratch
            .util
            .iter()
            .map(|u| (u - u_mean) * (u - u_mean))
            .sum::<f64>()
            / nl as f64
    };
    ObjectiveSummary { u_mean, u_std: var.sqrt(), twhc, connected }
}

fn bfs(topo: &Topology, src: usize, s: &mut AnalysisScratch) {
    s.dist.clear();
    s.dist.resize(topo.n, u32::MAX);
    s.parent_link.clear();
    s.parent_link.resize(topo.n, u32::MAX);
    s.queue.clear();
    s.dist[src] = 0;
    s.queue.push(src as u32);
    let mut head = 0;
    while head < s.queue.len() {
        let r = s.queue[head] as usize;
        head += 1;
        let d = s.dist[r] + 1;
        for &(nbr, link) in topo.neighbors(r) {
            if s.dist[nbr] == u32::MAX {
                s.dist[nbr] = d;
                s.parent_link[nbr] = link as u32;
                s.queue.push(nbr as u32);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemConfig;

    fn line3() -> Topology {
        // 0 - 1 - 2
        let mut t = Topology::new(3);
        t.add_link(0, 1, 2.5);
        t.add_link(1, 2, 2.5);
        t
    }

    #[test]
    fn single_flow_utilization() {
        let t = line3();
        let tm = TrafficMatrix::from_entries(3, vec![(0, 2, 0.5)]);
        let a = analyze(&t, &tm);
        assert!(a.connected);
        assert_eq!(a.link_util, vec![0.5, 0.5]);
        assert!((a.twhc - 1.0).abs() < 1e-12); // 0.5 * 2 hops
        assert!((a.u_mean - 0.5).abs() < 1e-12);
        assert!(a.u_std.abs() < 1e-12);
    }

    #[test]
    fn asymmetric_flows() {
        let t = line3();
        let tm = TrafficMatrix::from_entries(3, vec![(0, 1, 1.0), (2, 1, 3.0)]);
        let a = analyze(&t, &tm);
        assert_eq!(a.link_util, vec![1.0, 3.0]);
        assert!((a.u_mean - 2.0).abs() < 1e-12);
        assert!((a.u_std - 1.0).abs() < 1e-12);
    }

    #[test]
    fn duplicate_entries_merged() {
        let tm = TrafficMatrix::from_entries(3, vec![(0, 2, 0.25), (0, 2, 0.25)]);
        assert_eq!(tm.entries.len(), 1);
        assert!((tm.total() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn self_and_zero_traffic_dropped() {
        let tm = TrafficMatrix::from_entries(3, vec![(1, 1, 9.0), (0, 2, 0.0)]);
        assert!(tm.entries.is_empty());
    }

    #[test]
    fn disconnection_reported() {
        let mut t = line3();
        t.remove_link(1); // cut 1-2
        let tm = TrafficMatrix::from_entries(3, vec![(0, 2, 1.0)]);
        assert!(!analyze(&t, &tm).connected);
    }

    #[test]
    fn mesh_twhc_matches_manhattan() {
        let sys = SystemConfig::paper_8x8();
        let t = Topology::mesh(&sys);
        let tm = TrafficMatrix::from_entries(64, vec![(0, 63, 2.0), (8, 10, 1.0)]);
        let a = analyze(&t, &tm);
        assert!((a.twhc - (2.0 * 14.0 + 1.0 * 2.0)).abs() < 1e-12);
    }

    #[test]
    fn scaled() {
        let tm = TrafficMatrix::from_entries(3, vec![(0, 2, 1.0)]).scaled(0.25);
        assert!((tm.total() - 0.25).abs() < 1e-12);
    }
}

//! NoC instance builders — the four architectures the paper compares:
//!
//! * `mesh_opt`   — mesh with AMOSA-optimized CPU/MC placement, XY or
//!   XY+YX routing (§5.2 baseline).
//! * `het_noc`    — AMOSA-optimized irregular wireline topology; long
//!   links are pipelined metal wires (§5.4's wireline-only ablation).
//! * `wi_het_noc` — the same wireline optimization + wireless overlay:
//!   dedicated CPU-MC channel 0, `n_wi` GPU-MC WIs on the remaining
//!   channels, ALASH routing (§4.2).

use std::fmt;
use std::str::FromStr;
use std::sync::Arc;

use super::analysis::TrafficMatrix;
use super::routing::RouteSet;
use super::topology::Topology;
use super::wireless::WirelessSpec;
use crate::error::WihetError;
use crate::model::{SystemConfig, TileKind};
use crate::optim::amosa::{Amosa, AmosaConfig, SearchObserver};
use crate::optim::linkplace::LinkPlacement;
use crate::optim::wiplace::build_wireless_counted;
use crate::scenario::{Effort, Scenario};
use crate::telemetry::search::{record_stage, SearchSink, SearchStage};

/// Default seed for the design flow — the paper evaluates **one**
/// designed WiHetNoC, so every entry point that does not take an
/// explicit seed (`DesignConfig::default`, `NocDesigner::new`) must
/// derive the *same* topology. Keep them on this one constant.
pub const DEFAULT_DESIGN_SEED: u64 = 0xC0DE;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NocKind {
    MeshXy,
    MeshXyYx,
    HetNoc,
    WiHetNoc,
}

impl NocKind {
    /// Every architecture the paper compares, in report order.
    pub const ALL: [NocKind; 4] =
        [NocKind::MeshXy, NocKind::MeshXyYx, NocKind::HetNoc, NocKind::WiHetNoc];

    pub fn as_str(&self) -> &'static str {
        match self {
            NocKind::MeshXy => "mesh_xy",
            NocKind::MeshXyYx => "mesh_opt",
            NocKind::HetNoc => "hetnoc",
            NocKind::WiHetNoc => "wihetnoc",
        }
    }

    /// Whether this architecture is simulated on the AMOSA-optimized mesh
    /// placement (true) or the WiHetNoC placement (false).
    pub fn uses_mesh_placement(&self) -> bool {
        matches!(self, NocKind::MeshXy | NocKind::MeshXyYx)
    }
}

impl fmt::Display for NocKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

impl FromStr for NocKind {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "mesh_xy" => Ok(NocKind::MeshXy),
            "mesh_opt" | "mesh_xyyx" | "mesh" => Ok(NocKind::MeshXyYx),
            "hetnoc" => Ok(NocKind::HetNoc),
            "wihetnoc" => Ok(NocKind::WiHetNoc),
            other => Err(WihetError::UnknownNoc(other.to_string())),
        }
    }
}

/// A fully-built NoC ready for simulation.
///
/// The wireline topology is behind an `Arc` so experiment sweeps can
/// assemble many instances (WI-count / channel variants) over one
/// optimized topology — and hand instances across `par_map` workers —
/// without deep-copying the graph.
#[derive(Clone)]
pub struct NocInstance {
    pub kind: NocKind,
    pub topo: Arc<Topology>,
    pub routes: RouteSet,
    pub air: WirelessSpec,
}

/// Design-space knobs for the irregular architectures.
#[derive(Debug, Clone)]
pub struct DesignConfig {
    /// Router port bound (paper optimum: 6).
    pub k_max: usize,
    /// GPU-MC wireless interfaces (paper optimum: 24).
    pub n_wi: usize,
    /// GPU-MC channels (paper optimum: 4; +1 dedicated CPU channel).
    pub gpu_channels: usize,
    /// Wireline link reach bound for the WiHetNoC design (§4.2.3: the
    /// longest links are made wireless). `None` in the HetNoC ablation.
    pub max_link_mm: Option<f64>,
    /// AMOSA effort for the wireline optimization.
    pub amosa: AmosaConfig,
    pub seed: u64,
    /// Optional design-search trace sink. `None` (the default) is the
    /// zero-overhead path; with a sink attached each search pass records
    /// a read-only convergence stage (`wireline:k<k>` / `wireless`) into
    /// the shared [`crate::telemetry::search::SearchTrace`] — the
    /// designed NoC stays byte-identical either way (pinned by
    /// `tests/search_obs.rs`). Shared (`Arc`) so a cloned config carries
    /// the same trace through `par_map` design fan-outs.
    pub observer: Option<SearchSink>,
}

impl Default for DesignConfig {
    fn default() -> Self {
        DesignConfig {
            k_max: 6,
            n_wi: 24,
            gpu_channels: 4,
            max_link_mm: Some(7.6),
            amosa: AmosaConfig {
                initial_temp: 60.0,
                final_temp: 0.05,
                cooling: 0.88,
                iters_per_temp: 400,
                ..Default::default()
            },
            seed: DEFAULT_DESIGN_SEED,
            observer: None,
        }
    }
}

impl DesignConfig {
    /// Low-effort variant for unit tests and smoke runs.
    pub fn quick(seed: u64) -> Self {
        DesignConfig {
            amosa: AmosaConfig {
                initial_temp: 30.0,
                final_temp: 0.5,
                cooling: 0.8,
                iters_per_temp: 200,
                seed,
                ..Default::default()
            },
            seed,
            ..Default::default()
        }
    }

    /// Effort-dependent budget with the wireless knobs scaled to the
    /// platform. Chips smaller than the paper's 64 tiles scale the WI
    /// budget down (3/8 of the tiles, ~6 WIs per channel); larger chips
    /// keep the paper's 24 WIs / 4 channels, because that optimum is
    /// spectrum-limited, not die-size-limited — the mm-wave band yields
    /// the 4+1 channels regardless of tile count, and beyond ~6 WIs per
    /// channel the MAC token latency erodes the shortcut gain (Fig 12).
    /// The wireline reach bound scales with the tile pitch. On the 8x8
    /// paper platform this reproduces `DesignConfig::default()` exactly.
    pub fn scaled(sys: &SystemConfig, effort: Effort, seed: u64) -> Self {
        let mut cfg = match effort {
            Effort::Quick => DesignConfig::quick(seed),
            Effort::Full => DesignConfig { seed, ..DesignConfig::default() },
        };
        let n = sys.num_tiles();
        cfg.n_wi = cfg.n_wi.min((3 * n) / 8).max(2);
        cfg.gpu_channels = cfg.gpu_channels.min((cfg.n_wi / 6).max(1));
        let pitch = sys.die_mm / sys.width as f64;
        cfg.max_link_mm = cfg.max_link_mm.map(|m| m.max(3.0 * pitch + 0.1));
        cfg
    }
}

/// Optimized mesh: XY or XY+YX routing over the standard mesh. The CPU/MC
/// placement is the caller's `sys` (use `optim::optimize_placement` to
/// derive the §5.2 placement).
pub fn mesh_opt(sys: &SystemConfig, adaptive: bool) -> NocInstance {
    let topo = Topology::mesh(sys);
    let routes = if adaptive {
        RouteSet::xy_yx(sys, &topo)
    } else {
        RouteSet::xy(sys, &topo)
    };
    NocInstance {
        kind: if adaptive { NocKind::MeshXyYx } else { NocKind::MeshXy },
        topo: Arc::new(topo),
        routes,
        air: WirelessSpec::new(0),
    }
}

/// Run the Eqn 6-9 wireline optimization and return the chosen topology.
/// With `cfg.observer` attached, the pass deposits a `wireline:k<k_max>`
/// convergence stage (`:metal` suffix for the unbounded-reach HetNoC
/// ablation) into the sink — the topology is byte-identical either way.
pub fn optimize_wireline(
    sys: &SystemConfig,
    traffic: &TrafficMatrix,
    cfg: &DesignConfig,
) -> Topology {
    let mut obs = cfg.observer.as_ref().map(|_| SearchObserver::new());
    let topo = optimize_wireline_observed(sys, traffic, cfg, obs.as_mut());
    if let (Some(sink), Some(obs)) = (&cfg.observer, &obs) {
        record_stage(sink, SearchStage::from_observer(wireline_stage_name(cfg), obs));
    }
    topo
}

/// Stage key the wireline pass records under: distinguishes per-k runs
/// and the unbounded-reach (metal-only, HetNoC) ablation.
pub fn wireline_stage_name(cfg: &DesignConfig) -> String {
    match cfg.max_link_mm {
        Some(_) => format!("wireline:k{}", cfg.k_max),
        None => format!("wireline:k{}:metal", cfg.k_max),
    }
}

/// [`optimize_wireline`] with an explicit observer handle (ignores
/// `cfg.observer`) — for callers that package the stage themselves, like
/// the `design_figs` experiment.
pub fn optimize_wireline_observed(
    sys: &SystemConfig,
    traffic: &TrafficMatrix,
    cfg: &DesignConfig,
    obs: Option<&mut SearchObserver>,
) -> Topology {
    let num_links = Topology::mesh(sys).links.len();
    let problem = LinkPlacement::new(sys, traffic, num_links, cfg.k_max)
        .with_max_link_mm(cfg.max_link_mm);
    let mut amosa_cfg = cfg.amosa.clone();
    amosa_cfg.seed = cfg.seed;
    let mut opt = Amosa::new(&problem, amosa_cfg);
    opt.run_observed(obs);
    // Balanced scalarization over (Ū, σ): the per-k_max EDP choice happens
    // in the Fig 11 experiment; here we return the balanced knee point.
    let best = opt.best_by(&[1.0, 1.0]);
    problem.build_topology(&best.sol)
}

/// Wireline-only application-specific NoC (HetNoC): same design flow but
/// the long-range shortcuts stay as pipelined metal wires (§5.4).
pub fn het_noc(sys: &SystemConfig, traffic: &TrafficMatrix, cfg: &DesignConfig) -> NocInstance {
    let cfg = DesignConfig { max_link_mm: None, ..cfg.clone() };
    let topo = optimize_wireline(sys, traffic, &cfg);
    let routes = RouteSet::shortest(&topo, Some(traffic));
    NocInstance { kind: NocKind::HetNoc, topo: Arc::new(topo), routes, air: WirelessSpec::new(0) }
}

/// The full WiHetNoC: optimized wireline + wireless overlay + ALASH.
pub fn wi_het_noc(sys: &SystemConfig, traffic: &TrafficMatrix, cfg: &DesignConfig) -> NocInstance {
    let topo = optimize_wireline(sys, traffic, cfg);
    wi_het_noc_on(sys, traffic, cfg, Arc::new(topo))
}

/// WiHetNoC assembly on a given (shared) wireline topology — lets
/// experiments reuse one expensive wireline optimization across WI-count
/// sweeps without copying the graph per variant.
pub fn wi_het_noc_on(
    sys: &SystemConfig,
    traffic: &TrafficMatrix,
    cfg: &DesignConfig,
    topo: Arc<Topology>,
) -> NocInstance {
    let (air, wi_evals) = build_wireless_counted(
        &topo,
        traffic,
        &sys.cpus(),
        &sys.mcs(),
        cfg.n_wi,
        cfg.gpu_channels,
    );
    if let Some(sink) = &cfg.observer {
        // Greedy WI placement has no temperature schedule — record it as
        // a flat stage so the profiler still attributes its evaluations.
        record_stage(sink, SearchStage::flat("wireless", wi_evals));
    }
    let routes = alash_routes(sys, &topo, &air, traffic);
    NocInstance { kind: NocKind::WiHetNoc, topo, routes, air }
}

/// ALASH route construction with the paper's channel policy: CPU<->MC
/// pairs ride the dedicated channel 0; everything else uses the GPU
/// channels.
pub fn alash_routes(
    sys: &SystemConfig,
    topo: &Topology,
    air: &WirelessSpec,
    traffic: &TrafficMatrix,
) -> RouteSet {
    let tiles = sys.tiles.clone();
    let tiles2 = sys.tiles.clone();
    let gpu_channels: Vec<usize> = (1..air.num_channels).collect();
    let is_cpu_mc = move |s: usize, d: usize| {
        matches!(
            (tiles2[s], tiles2[d]),
            (TileKind::Cpu, TileKind::Mc) | (TileKind::Mc, TileKind::Cpu)
        )
    };
    RouteSet::alash_with(
        topo,
        air,
        Some(traffic),
        move |s, d| {
            let pair = (tiles[s], tiles[d]);
            match pair {
                (TileKind::Cpu, TileKind::Mc) | (TileKind::Mc, TileKind::Cpu) => vec![0],
                _ => gpu_channels.clone(),
            }
        },
        // dedicated channel: CPU-MC always rides wireless (QoS isolation)
        is_cpu_mc,
        5,
    )
}

/// Test/smoke helper: WiHetNoC with a tiny AMOSA budget and a generic
/// many-to-few traffic matrix.
pub fn wi_het_noc_quick(sys: &SystemConfig, seed: u64) -> NocInstance {
    let tm = generic_many_to_few(sys);
    wi_het_noc(sys, &tm, &DesignConfig::quick(seed))
}

/// Placeholder many-to-few matrix (uniform GPU<->MC + CPU<->MC) for tests
/// that do not need the CNN-derived traffic.
pub fn generic_many_to_few(sys: &SystemConfig) -> TrafficMatrix {
    let mut e = Vec::new();
    for &g in &sys.gpus() {
        for &m in &sys.mcs() {
            e.push((g as u32, m as u32, 0.002));
            e.push((m as u32, g as u32, 0.006));
        }
    }
    for &c in &sys.cpus() {
        for &m in &sys.mcs() {
            e.push((c as u32, m as u32, 0.001));
            e.push((m as u32, c as u32, 0.002));
        }
    }
    TrafficMatrix::from_entries(sys.num_tiles(), e)
}

/// Fluent builder over the four architectures: pick a platform (or a full
/// [`Scenario`]), adjust the design knobs, and [`NocDesigner::build`] a
/// validated [`NocInstance`]. Infeasible knob combinations surface as
/// [`WihetError::InvalidDesign`] instead of panicking mid-optimization.
///
/// ```no_run
/// use wihetnoc::{ModelId, Platform, Scenario};
/// use wihetnoc::noc::builder::NocDesigner;
///
/// let scenario = Scenario::new("4x4".parse::<Platform>()?, ModelId::CdbNet);
/// let noc = NocDesigner::for_scenario(&scenario)?.k_max(5).build()?;
/// assert!(noc.topo.is_connected());
/// # Ok::<(), wihetnoc::WihetError>(())
/// ```
#[derive(Clone)]
pub struct NocDesigner {
    sys: SystemConfig,
    kind: NocKind,
    cfg: DesignConfig,
    traffic: Option<TrafficMatrix>,
}

impl NocDesigner {
    /// Designer over an explicit tile grid, defaulting to a WiHetNoC with
    /// platform-scaled quick-effort knobs and the generic many-to-few
    /// traffic (replace via [`NocDesigner::traffic`]).
    pub fn new(sys: SystemConfig) -> Self {
        let cfg = DesignConfig::scaled(&sys, Effort::Quick, DEFAULT_DESIGN_SEED);
        NocDesigner { sys, kind: NocKind::WiHetNoc, cfg, traffic: None }
    }

    /// Designer for a full scenario: builds the platform, lowers the CNN
    /// workload (preset or DSL spec, under the scenario's mapping policy)
    /// to training traffic at the scenario's batch size, and scales the
    /// design knobs to the platform. The design input is the aggregate
    /// `fij` over the whole iteration, which every schedule conserves
    /// exactly — so the scenario's schedule is validated here but does
    /// not change the designed topology.
    pub fn for_scenario(sc: &Scenario) -> Result<Self, WihetError> {
        let sys = sc.platform.build()?;
        sc.schedule.validate_for(sc.batch)?;
        let fij =
            crate::workload::lower_id(&sc.model, &sc.mapping, &sys, sc.batch)?.fij(&sys);
        let cfg = DesignConfig::scaled(&sys, sc.effort, sc.seed);
        Ok(NocDesigner { sys, kind: sc.noc, cfg, traffic: Some(fij) })
    }

    pub fn kind(mut self, kind: NocKind) -> Self {
        self.kind = kind;
        self
    }

    /// Design-input traffic matrix (defaults to the scenario workload or,
    /// for [`NocDesigner::new`], a generic many-to-few pattern).
    pub fn traffic(mut self, fij: TrafficMatrix) -> Self {
        self.traffic = Some(fij);
        self
    }

    pub fn k_max(mut self, k_max: usize) -> Self {
        self.cfg.k_max = k_max;
        self
    }

    pub fn n_wi(mut self, n_wi: usize) -> Self {
        self.cfg.n_wi = n_wi;
        self
    }

    pub fn gpu_channels(mut self, gpu_channels: usize) -> Self {
        self.cfg.gpu_channels = gpu_channels;
        self
    }

    pub fn max_link_mm(mut self, bound: Option<f64>) -> Self {
        self.cfg.max_link_mm = bound;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self.cfg.amosa.seed = seed;
        self
    }

    /// Attach a search-trace sink: every optimization pass the build runs
    /// (wireline AMOSA, greedy WI placement) deposits its convergence
    /// stage into `sink`. Strictly read-only — the designed NoC is
    /// byte-identical with or without it.
    pub fn observe(mut self, sink: SearchSink) -> Self {
        self.cfg.observer = Some(sink);
        self
    }

    /// Replace the whole design configuration (keeps the other builder
    /// state).
    pub fn design_cfg(mut self, cfg: DesignConfig) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn system(&self) -> &SystemConfig {
        &self.sys
    }

    pub fn config(&self) -> &DesignConfig {
        &self.cfg
    }

    /// The design-input traffic, if one has been derived or supplied.
    pub fn traffic_matrix(&self) -> Option<&TrafficMatrix> {
        self.traffic.as_ref()
    }

    fn validate(&self) -> Result<(), WihetError> {
        let err = |m: String| Err(WihetError::InvalidDesign(m));
        let n = self.sys.num_tiles();
        if self.kind.uses_mesh_placement() {
            return Ok(());
        }
        if !(3..=16).contains(&self.cfg.k_max) {
            return err(format!(
                "k_max {} outside the feasible router-radix range 3..=16",
                self.cfg.k_max
            ));
        }
        if self.kind == NocKind::WiHetNoc {
            if self.cfg.n_wi == 0 || self.cfg.n_wi > n {
                return err(format!(
                    "n_wi {} outside 1..={n} for a {n}-tile platform",
                    self.cfg.n_wi
                ));
            }
            if self.cfg.gpu_channels == 0 || self.cfg.gpu_channels > self.cfg.n_wi {
                return err(format!(
                    "gpu_channels {} outside 1..=n_wi ({})",
                    self.cfg.gpu_channels, self.cfg.n_wi
                ));
            }
        }
        Ok(())
    }

    /// Validate the knobs and run the design flow for the chosen kind.
    pub fn build(self) -> Result<NocInstance, WihetError> {
        self.validate()?;
        let tm = match self.traffic {
            Some(ref t) => t.clone(),
            None => generic_many_to_few(&self.sys),
        };
        Ok(match self.kind {
            NocKind::MeshXy => mesh_opt(&self.sys, false),
            NocKind::MeshXyYx => mesh_opt(&self.sys, true),
            NocKind::HetNoc => het_noc(&self.sys, &tm, &self.cfg),
            NocKind::WiHetNoc => wi_het_noc(&self.sys, &tm, &self.cfg),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::routing::RoutingKind;
    use crate::noc::analysis::analyze;
    use crate::noc::routing::verify_lash;

    #[test]
    fn mesh_instances() {
        let sys = SystemConfig::paper_8x8();
        let xy = mesh_opt(&sys, false);
        let ad = mesh_opt(&sys, true);
        assert_eq!(xy.kind, NocKind::MeshXy);
        assert_eq!(ad.routes.kind, RoutingKind::XyYx);
        assert!(xy.air.is_empty());
    }

    #[test]
    fn hetnoc_respects_constraints_and_beats_mesh() {
        let sys = SystemConfig::paper_8x8();
        let tm = generic_many_to_few(&sys);
        let cfg = DesignConfig::quick(7);
        let inst = het_noc(&sys, &tm, &cfg);
        assert!(inst.topo.is_connected());
        assert_eq!(inst.topo.links.len(), 112);
        assert!(inst.topo.k_max() <= cfg.k_max);
        let mesh = Topology::mesh(&sys);
        let (a_het, a_mesh) = (analyze(&inst.topo, &tm), analyze(&mesh, &tm));
        assert!(a_het.u_mean < a_mesh.u_mean, "{} vs {}", a_het.u_mean, a_mesh.u_mean);
    }

    #[test]
    fn wihetnoc_full_assembly() {
        let sys = SystemConfig::paper_8x8();
        let inst = wi_het_noc_quick(&sys, 9);
        assert_eq!(inst.kind, NocKind::WiHetNoc);
        // 4 CPU + 4 MC WIs on channel 0 + 24 GPU WIs
        assert_eq!(inst.air.wis.len(), 8 + 24);
        assert_eq!(inst.air.num_channels, 5);
        // every CPU-MC pair has a single-hop air path on channel 0
        for &c in &sys.cpus() {
            for &m in &sys.mcs() {
                let p = inst.routes.air_path(c, m);
                assert!(p.is_some(), "CPU {c} -> MC {m} missing air path");
            }
        }
        verify_lash(&inst.topo, &inst.routes).unwrap();
    }

    #[test]
    fn wihetnoc_air_coverage_positive() {
        let sys = SystemConfig::paper_8x8();
        let inst = wi_het_noc_quick(&sys, 21);
        assert!(inst.routes.air_coverage() > 0.05);
    }

    #[test]
    fn nockind_parse_roundtrip() {
        for k in NocKind::ALL {
            assert_eq!(k.as_str().parse::<NocKind>().unwrap(), k);
            assert_eq!(format!("{k}"), k.as_str());
        }
        assert_eq!("mesh".parse::<NocKind>().unwrap(), NocKind::MeshXyYx);
        assert!(matches!(
            "torus".parse::<NocKind>(),
            Err(WihetError::UnknownNoc(_))
        ));
    }

    #[test]
    fn scaled_cfg_matches_default_on_paper_platform() {
        let sys = SystemConfig::paper_8x8();
        let cfg = DesignConfig::scaled(&sys, Effort::Full, DEFAULT_DESIGN_SEED);
        let def = DesignConfig::default();
        assert_eq!(cfg.n_wi, def.n_wi);
        assert_eq!(cfg.gpu_channels, def.gpu_channels);
        assert_eq!(cfg.k_max, def.k_max);
        assert!((cfg.max_link_mm.unwrap() - def.max_link_mm.unwrap()).abs() < 1e-9);
    }

    #[test]
    fn designer_builds_mesh_without_amosa() {
        let inst = NocDesigner::new(SystemConfig::paper_8x8())
            .kind(NocKind::MeshXy)
            .build()
            .unwrap();
        assert_eq!(inst.kind, NocKind::MeshXy);
        assert_eq!(inst.topo.links.len(), 112);
    }

    #[test]
    fn designer_rejects_infeasible_knobs() {
        let mk = || NocDesigner::new(SystemConfig::small_4x4());
        for bad in [
            mk().k_max(2),
            mk().k_max(99),
            mk().n_wi(0),
            mk().n_wi(17),
            mk().n_wi(4).gpu_channels(5),
            mk().gpu_channels(0),
        ] {
            assert!(
                matches!(bad.build(), Err(WihetError::InvalidDesign(_))),
                "expected InvalidDesign"
            );
        }
    }

    #[test]
    fn designer_scales_to_small_platform() {
        let inst = NocDesigner::new(SystemConfig::small_4x4())
            .seed(3)
            .build()
            .unwrap();
        assert_eq!(inst.kind, NocKind::WiHetNoc);
        assert!(inst.topo.is_connected());
        // 2 CPU + 2 MC WIs on channel 0, scaled GPU WIs on the rest
        assert!(inst.air.wis.len() >= 4 + 2);
        assert!(inst.air.num_channels >= 2);
    }
}

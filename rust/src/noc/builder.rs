//! NoC instance builders — the four architectures the paper compares:
//!
//! * `mesh_opt`   — mesh with AMOSA-optimized CPU/MC placement, XY or
//!   XY+YX routing (§5.2 baseline).
//! * `het_noc`    — AMOSA-optimized irregular wireline topology; long
//!   links are pipelined metal wires (§5.4's wireline-only ablation).
//! * `wi_het_noc` — the same wireline optimization + wireless overlay:
//!   dedicated CPU-MC channel 0, `n_wi` GPU-MC WIs on the remaining
//!   channels, ALASH routing (§4.2).

use super::analysis::TrafficMatrix;
use super::routing::RouteSet;
use super::topology::Topology;
use super::wireless::WirelessSpec;
use crate::model::{SystemConfig, TileKind};
use crate::optim::amosa::{Amosa, AmosaConfig};
use crate::optim::linkplace::LinkPlacement;
use crate::optim::wiplace::build_wireless;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NocKind {
    MeshXy,
    MeshXyYx,
    HetNoc,
    WiHetNoc,
}

impl NocKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            NocKind::MeshXy => "mesh_xy",
            NocKind::MeshXyYx => "mesh_opt",
            NocKind::HetNoc => "hetnoc",
            NocKind::WiHetNoc => "wihetnoc",
        }
    }
}

/// A fully-built NoC ready for simulation.
#[derive(Clone)]
pub struct NocInstance {
    pub kind: NocKind,
    pub topo: Topology,
    pub routes: RouteSet,
    pub air: WirelessSpec,
}

/// Design-space knobs for the irregular architectures.
#[derive(Debug, Clone)]
pub struct DesignConfig {
    /// Router port bound (paper optimum: 6).
    pub k_max: usize,
    /// GPU-MC wireless interfaces (paper optimum: 24).
    pub n_wi: usize,
    /// GPU-MC channels (paper optimum: 4; +1 dedicated CPU channel).
    pub gpu_channels: usize,
    /// Wireline link reach bound for the WiHetNoC design (§4.2.3: the
    /// longest links are made wireless). `None` in the HetNoC ablation.
    pub max_link_mm: Option<f64>,
    /// AMOSA effort for the wireline optimization.
    pub amosa: AmosaConfig,
    pub seed: u64,
}

impl Default for DesignConfig {
    fn default() -> Self {
        DesignConfig {
            k_max: 6,
            n_wi: 24,
            gpu_channels: 4,
            max_link_mm: Some(7.6),
            amosa: AmosaConfig {
                initial_temp: 60.0,
                final_temp: 0.05,
                cooling: 0.88,
                iters_per_temp: 400,
                ..Default::default()
            },
            seed: 0xC0DE,
        }
    }
}

impl DesignConfig {
    /// Low-effort variant for unit tests and smoke runs.
    pub fn quick(seed: u64) -> Self {
        DesignConfig {
            amosa: AmosaConfig {
                initial_temp: 30.0,
                final_temp: 0.5,
                cooling: 0.8,
                iters_per_temp: 200,
                seed,
                ..Default::default()
            },
            seed,
            ..Default::default()
        }
    }
}

/// Optimized mesh: XY or XY+YX routing over the standard mesh. The CPU/MC
/// placement is the caller's `sys` (use `optim::optimize_placement` to
/// derive the §5.2 placement).
pub fn mesh_opt(sys: &SystemConfig, adaptive: bool) -> NocInstance {
    let topo = Topology::mesh(sys);
    let routes = if adaptive {
        RouteSet::xy_yx(sys, &topo)
    } else {
        RouteSet::xy(sys, &topo)
    };
    NocInstance {
        kind: if adaptive { NocKind::MeshXyYx } else { NocKind::MeshXy },
        topo,
        routes,
        air: WirelessSpec::new(0),
    }
}

/// Run the Eqn 6-9 wireline optimization and return the chosen topology.
pub fn optimize_wireline(
    sys: &SystemConfig,
    traffic: &TrafficMatrix,
    cfg: &DesignConfig,
) -> Topology {
    let num_links = Topology::mesh(sys).links.len();
    let problem = LinkPlacement::new(sys, traffic, num_links, cfg.k_max)
        .with_max_link_mm(cfg.max_link_mm);
    let mut amosa_cfg = cfg.amosa.clone();
    amosa_cfg.seed = cfg.seed;
    let mut opt = Amosa::new(&problem, amosa_cfg);
    opt.run();
    // Balanced scalarization over (Ū, σ): the per-k_max EDP choice happens
    // in the Fig 11 experiment; here we return the balanced knee point.
    let best = opt.best_by(&[1.0, 1.0]);
    problem.build_topology(&best.sol)
}

/// Wireline-only application-specific NoC (HetNoC): same design flow but
/// the long-range shortcuts stay as pipelined metal wires (§5.4).
pub fn het_noc(sys: &SystemConfig, traffic: &TrafficMatrix, cfg: &DesignConfig) -> NocInstance {
    let cfg = DesignConfig { max_link_mm: None, ..cfg.clone() };
    let topo = optimize_wireline(sys, traffic, &cfg);
    let routes = RouteSet::shortest(&topo, Some(traffic));
    NocInstance { kind: NocKind::HetNoc, topo, routes, air: WirelessSpec::new(0) }
}

/// The full WiHetNoC: optimized wireline + wireless overlay + ALASH.
pub fn wi_het_noc(sys: &SystemConfig, traffic: &TrafficMatrix, cfg: &DesignConfig) -> NocInstance {
    let topo = optimize_wireline(sys, traffic, cfg);
    wi_het_noc_on(sys, traffic, cfg, topo)
}

/// WiHetNoC assembly on a given wireline topology (lets experiments reuse
/// one expensive wireline optimization across WI-count sweeps).
pub fn wi_het_noc_on(
    sys: &SystemConfig,
    traffic: &TrafficMatrix,
    cfg: &DesignConfig,
    topo: Topology,
) -> NocInstance {
    let air = build_wireless(
        &topo,
        traffic,
        &sys.cpus(),
        &sys.mcs(),
        cfg.n_wi,
        cfg.gpu_channels,
    );
    let routes = alash_routes(sys, &topo, &air, traffic);
    NocInstance { kind: NocKind::WiHetNoc, topo, routes, air }
}

/// ALASH route construction with the paper's channel policy: CPU<->MC
/// pairs ride the dedicated channel 0; everything else uses the GPU
/// channels.
pub fn alash_routes(
    sys: &SystemConfig,
    topo: &Topology,
    air: &WirelessSpec,
    traffic: &TrafficMatrix,
) -> RouteSet {
    let tiles = sys.tiles.clone();
    let tiles2 = sys.tiles.clone();
    let gpu_channels: Vec<usize> = (1..air.num_channels).collect();
    let is_cpu_mc = move |s: usize, d: usize| {
        matches!(
            (tiles2[s], tiles2[d]),
            (TileKind::Cpu, TileKind::Mc) | (TileKind::Mc, TileKind::Cpu)
        )
    };
    RouteSet::alash_with(
        topo,
        air,
        Some(traffic),
        move |s, d| {
            let pair = (tiles[s], tiles[d]);
            match pair {
                (TileKind::Cpu, TileKind::Mc) | (TileKind::Mc, TileKind::Cpu) => vec![0],
                _ => gpu_channels.clone(),
            }
        },
        // dedicated channel: CPU-MC always rides wireless (QoS isolation)
        is_cpu_mc,
        5,
    )
}

/// Test/smoke helper: WiHetNoC with a tiny AMOSA budget and a generic
/// many-to-few traffic matrix.
pub fn wi_het_noc_quick(sys: &SystemConfig, seed: u64) -> NocInstance {
    let tm = generic_many_to_few(sys);
    wi_het_noc(sys, &tm, &DesignConfig::quick(seed))
}

/// Placeholder many-to-few matrix (uniform GPU<->MC + CPU<->MC) for tests
/// that do not need the CNN-derived traffic.
pub fn generic_many_to_few(sys: &SystemConfig) -> TrafficMatrix {
    let mut e = Vec::new();
    for &g in &sys.gpus() {
        for &m in &sys.mcs() {
            e.push((g as u32, m as u32, 0.002));
            e.push((m as u32, g as u32, 0.006));
        }
    }
    for &c in &sys.cpus() {
        for &m in &sys.mcs() {
            e.push((c as u32, m as u32, 0.001));
            e.push((m as u32, c as u32, 0.002));
        }
    }
    TrafficMatrix::from_entries(sys.num_tiles(), e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::routing::RoutingKind;
    use crate::noc::analysis::analyze;
    use crate::noc::routing::verify_lash;

    #[test]
    fn mesh_instances() {
        let sys = SystemConfig::paper_8x8();
        let xy = mesh_opt(&sys, false);
        let ad = mesh_opt(&sys, true);
        assert_eq!(xy.kind, NocKind::MeshXy);
        assert_eq!(ad.routes.kind, RoutingKind::XyYx);
        assert!(xy.air.is_empty());
    }

    #[test]
    fn hetnoc_respects_constraints_and_beats_mesh() {
        let sys = SystemConfig::paper_8x8();
        let tm = generic_many_to_few(&sys);
        let cfg = DesignConfig::quick(7);
        let inst = het_noc(&sys, &tm, &cfg);
        assert!(inst.topo.is_connected());
        assert_eq!(inst.topo.links.len(), 112);
        assert!(inst.topo.k_max() <= cfg.k_max);
        let mesh = Topology::mesh(&sys);
        let (a_het, a_mesh) = (analyze(&inst.topo, &tm), analyze(&mesh, &tm));
        assert!(a_het.u_mean < a_mesh.u_mean, "{} vs {}", a_het.u_mean, a_mesh.u_mean);
    }

    #[test]
    fn wihetnoc_full_assembly() {
        let sys = SystemConfig::paper_8x8();
        let inst = wi_het_noc_quick(&sys, 9);
        assert_eq!(inst.kind, NocKind::WiHetNoc);
        // 4 CPU + 4 MC WIs on channel 0 + 24 GPU WIs
        assert_eq!(inst.air.wis.len(), 8 + 24);
        assert_eq!(inst.air.num_channels, 5);
        // every CPU-MC pair has a single-hop air path on channel 0
        for &c in &sys.cpus() {
            for &m in &sys.mcs() {
                let p = inst.routes.air_path(c, m);
                assert!(p.is_some(), "CPU {c} -> MC {m} missing air path");
            }
        }
        verify_lash(&inst.topo, &inst.routes).unwrap();
    }

    #[test]
    fn wihetnoc_air_coverage_positive() {
        let sys = SystemConfig::paper_8x8();
        let inst = wi_het_noc_quick(&sys, 21);
        assert!(inst.routes.air_coverage() > 0.05);
    }
}

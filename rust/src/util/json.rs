//! Minimal JSON parser + writer (serde is not vendored in this image).
//!
//! Scope: exactly what the artifact `manifest.json` and experiment reports
//! need — objects, arrays, strings (with escapes), numbers, bools, null.
//! Parsing is recursive-descent over bytes; numbers use f64 (the manifest's
//! integers are all well below 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Render compactly (keys sorted — BTreeMap — so output is stable).
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns a descriptive error with byte offset.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != bytes.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf8".to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2.5));
        // dump -> parse fixpoint
        let again = parse(&v.dump()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn escapes() {
        let v = parse(r#""a\"b\\cA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\cA"));
        assert_eq!(parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(Json::Num(42.0).dump(), "42");
        assert_eq!(Json::Num(0.5).dump(), "0.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"open").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{"batch":32,"entries":[{"inputs":[{"dtype":"float32","shape":[5,5,1,16]}],"name":"lenet_train_step","num_outputs":9}],"version":1}"#;
        let v = parse(src).unwrap();
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("lenet_train_step"));
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_usize().unwrap())
            .collect::<Vec<_>>();
        assert_eq!(shape, vec![5, 5, 1, 16]);
    }
}

//! Property-testing mini-framework (proptest is not vendored).
//!
//! `run_prop` drives a seeded generator through N cases and, on failure,
//! retries with a simple halving shrink over the generator's size budget,
//! reporting the smallest failing seed/size it finds. Used by
//! `rust/tests/noc_properties.rs` for routing/batching/state invariants.

use crate::util::rng::Rng;

/// Per-case generation context: an RNG plus a size budget generators can
/// use to scale structures (shrinking lowers `size`).
pub struct Gen {
    pub rng: Rng,
    pub size: usize,
}

impl Gen {
    pub fn new(seed: u64, size: usize) -> Self {
        Gen { rng: Rng::new(seed), size }
    }

    /// usize in [lo, hi], clamped by the size budget above lo.
    pub fn sized(&mut self, lo: usize, hi: usize) -> usize {
        let hi_eff = hi.min(lo + self.size);
        self.rng.range(lo, hi_eff + 1)
    }
}

/// Outcome of a property over one generated case.
pub type PropResult = Result<(), String>;

/// Run `cases` random cases of `prop`; on failure, shrink the size budget
/// and report the smallest failure. Panics (test failure) with details.
pub fn run_prop<F>(name: &str, cases: usize, base_seed: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> PropResult,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9);
        let full_size = 64;
        let mut g = Gen::new(seed, full_size);
        if let Err(msg) = prop(&mut g) {
            // shrink: halve the size budget while it still fails
            let mut best = (full_size, msg);
            let mut size = full_size / 2;
            while size >= 1 {
                let mut g = Gen::new(seed, size);
                match prop(&mut g) {
                    Err(m) => {
                        best = (size, m);
                        size /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, \
                 min size {}): {}",
                best.0, best.1
            );
        }
    }
}

/// Assert helper for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        run_prop("add commutes", 50, 1, |g| {
            let a = g.rng.below(1000) as i64;
            let b = g.rng.below(1000) as i64;
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_bad_property() {
        run_prop("always fails", 5, 2, |_| Err("nope".into()));
    }

    #[test]
    fn sized_respects_budget() {
        let mut g = Gen::new(3, 4);
        for _ in 0..100 {
            let v = g.sized(2, 100);
            assert!((2..=6).contains(&v));
        }
    }
}

//! Tiny declarative CLI argument parser (clap is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.
//! Unknown flags are errors; `--help` is synthesized from registered specs.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

/// Parse `argv` against `specs`. Returns Err with a usage string on
/// unknown options or a missing value.
pub fn parse(argv: &[String], specs: &[ArgSpec]) -> Result<Args, String> {
    let mut out = Args::default();
    // seed defaults
    for s in specs {
        if let Some(d) = s.default {
            out.values.insert(s.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(body) = a.strip_prefix("--") {
            let (key, inline) = match body.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (body.to_string(), None),
            };
            if key == "help" {
                return Err(usage(specs));
            }
            let spec = specs
                .iter()
                .find(|s| s.name == key)
                .ok_or_else(|| format!("unknown option --{key}\n{}", usage(specs)))?;
            if spec.is_flag {
                if inline.is_some() {
                    return Err(format!("--{key} is a flag and takes no value"));
                }
                out.flags.push(key);
            } else {
                let val = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .cloned()
                            .ok_or_else(|| format!("--{key} expects a value"))?
                    }
                };
                out.values.insert(key, val);
            }
        } else {
            out.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(out)
}

pub fn usage(specs: &[ArgSpec]) -> String {
    let mut s = String::from("options:\n");
    for spec in specs {
        let kind = if spec.is_flag { "" } else { " <value>" };
        let def = spec
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{kind}  {}{def}\n", spec.name, spec.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ArgSpec> {
        vec![
            ArgSpec { name: "steps", help: "n steps", default: Some("10"), is_flag: false },
            ArgSpec { name: "verbose", help: "chatty", default: None, is_flag: true },
            ArgSpec { name: "model", help: "model name", default: None, is_flag: false },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_values() {
        let a = parse(&sv(&["--model", "lenet"]), &specs()).unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 10);
        assert_eq!(a.get("model"), Some("lenet"));
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn equals_form_and_flags() {
        let a = parse(&sv(&["--steps=42", "--verbose", "pos1"]), &specs()).unwrap();
        assert_eq!(a.get_usize("steps", 0).unwrap(), 42);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn errors() {
        assert!(parse(&sv(&["--nope"]), &specs()).is_err());
        assert!(parse(&sv(&["--model"]), &specs()).is_err());
        assert!(parse(&sv(&["--verbose=1"]), &specs()).is_err());
        let a = parse(&sv(&["--steps", "abc"]), &specs()).unwrap();
        assert!(a.get_usize("steps", 0).is_err());
    }
}

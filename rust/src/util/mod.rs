//! Shared utilities: deterministic RNG, statistics, JSON codec, CLI parsing,
//! and a property-testing mini-framework.
//!
//! The build is hermetic: the only dependency is the vendored `xla` crate
//! (`rust/vendor/xla`, a stub unless the real xla-rs bindings are swapped
//! in), so `serde`/`clap`/`proptest`/`criterion` are unavailable; these
//! modules provide the subsets this crate needs (see DESIGN.md §2,
//! toolchain substitutions).

pub mod cli;
pub mod exec;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;

//! Deterministic scoped-thread fan-out for the experiment harnesses.
//!
//! [`par_map`] runs one job per input item across a small worker pool and
//! returns the results **in input order**, so a sweep produces
//! byte-identical reports whatever the thread count — the property the
//! serial-vs-parallel equivalence tests pin down. Each worker owns index
//! stripe `k, k + T, k + 2T, ...`; there is no shared mutable state, no
//! locks, and no cross-thread result channel whose arrival order could
//! leak into the output. Jobs that need randomness must derive their seed
//! from the item or its index (never from a shared RNG), which is how
//! every call site in `experiments/` is written.
//!
//! The pool size comes from `WIHETNOC_THREADS` (default: the machine's
//! available parallelism). Set `WIHETNOC_THREADS=1` to force serial
//! execution.

/// Worker count: `WIHETNOC_THREADS` if set to a positive integer,
/// otherwise the machine's available parallelism.
pub fn thread_count() -> usize {
    thread_count_from(std::env::var("WIHETNOC_THREADS").ok().as_deref())
}

/// Parse a thread-count override; `None`/invalid/zero fall back to the
/// available parallelism. Split out of [`thread_count`] so the policy is
/// testable without touching process-global env state.
pub fn thread_count_from(var: Option<&str>) -> usize {
    match var.and_then(|s| s.trim().parse::<usize>().ok()) {
        Some(n) if n >= 1 => n,
        _ => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    }
}

/// Map `f` over `items` on the default pool (see [`thread_count`]).
/// Results are joined in index order; a panicking job propagates.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    par_map_threads(thread_count(), items, f)
}

/// [`par_map`] with an explicit worker count — the entry point the
/// determinism tests drive with 1, 2, and 8 workers.
pub fn par_map_threads<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let f = &f;
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|k| {
                scope.spawn(move || {
                    let mut out = Vec::with_capacity(n / threads + 1);
                    let mut i = k;
                    while i < n {
                        out.push((i, f(i, &items[i])));
                        i += threads;
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            for (i, r) in h.join().expect("par_map worker panicked") {
                slots[i] = Some(r);
            }
        }
    });
    slots
        .into_iter()
        .map(|r| r.expect("every index striped to exactly one worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_input_order() {
        let items: Vec<usize> = (0..100).collect();
        for threads in [1, 2, 3, 8, 200] {
            let out = par_map_threads(threads, &items, |i, &x| {
                assert_eq!(i, x);
                x * x
            });
            assert_eq!(out, items.iter().map(|x| x * x).collect::<Vec<_>>());
        }
    }

    #[test]
    fn serial_equals_parallel() {
        // index-derived pseudo-work must be identical at every pool size
        let items: Vec<u64> = (0..57).map(|i| i * 31 + 7).collect();
        let job = |i: usize, &x: &u64| {
            let mut rng = crate::util::rng::Rng::new(x ^ i as u64);
            (0..100).map(|_| rng.next_u64() & 0xFF).sum::<u64>()
        };
        let serial = par_map_threads(1, &items, job);
        for threads in [2, 8] {
            assert_eq!(par_map_threads(threads, &items, job), serial);
        }
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<u32> = Vec::new();
        assert!(par_map_threads(8, &none, |_, &x| x).is_empty());
        assert_eq!(par_map_threads(8, &[42u32], |_, &x| x + 1), vec![43]);
    }

    #[test]
    fn thread_count_parsing() {
        assert_eq!(thread_count_from(Some("3")), 3);
        assert_eq!(thread_count_from(Some(" 8 ")), 8);
        let auto = thread_count_from(None);
        assert!(auto >= 1);
        assert_eq!(thread_count_from(Some("0")), auto);
        assert_eq!(thread_count_from(Some("bogus")), auto);
    }
}

//! Deterministic xoshiro256** PRNG.
//!
//! Every stochastic component (AMOSA, traffic generation, the simulator's
//! arbitration tie-breaks, property tests) takes an explicit seed so runs
//! are exactly reproducible — a hard requirement for regenerating the
//! paper's figures byte-for-byte across machines.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so nearby seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift rejection-free
    /// approximation (bias < 2^-32 for the n used here, all << 2^32).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() >> 32).wrapping_mul(n as u64) >> 32) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random element of a slice.
    #[inline]
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}

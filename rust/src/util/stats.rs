//! Small statistics helpers used by the simulator, optimizer, and benches.
//!
//! These operate on complete `&[f64]` samples held in memory. For
//! streaming per-packet latencies (millions of values, recorded while
//! the simulator runs), use [`crate::telemetry::LogHistogram`] instead:
//! O(1) per record, deterministic quantiles, mergeable across shards.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for empty input.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Empirical CDF sampled at `points` values of x: fraction of xs <= x.
pub fn cdf_at(xs: &[f64], points: &[f64]) -> Vec<f64> {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    points
        .iter()
        .map(|&x| {
            let idx = v.partition_point(|&e| e <= x);
            idx as f64 / v.len().max(1) as f64
        })
        .collect()
}

/// Online mean/max/count accumulator for streaming latency samples.
#[derive(Debug, Default, Clone)]
pub struct Accum {
    pub count: u64,
    pub sum: f64,
    pub max: f64,
}

impl Accum {
    pub fn push(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn merge(&mut self, other: &Accum) {
        self.count += other.count;
        self.sum += other.sum;
        if other.max > self.max {
            self.max = other.max;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn cdf() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let c = cdf_at(&xs, &[0.0, 2.0, 4.0, 10.0]);
        assert_eq!(c, vec![0.0, 0.5, 1.0, 1.0]);
    }

    #[test]
    fn accum() {
        let mut a = Accum::default();
        a.push(1.0);
        a.push(3.0);
        let mut b = Accum::default();
        b.push(5.0);
        a.merge(&b);
        assert_eq!(a.count, 3);
        assert!((a.mean() - 3.0).abs() < 1e-12);
        assert_eq!(a.max, 5.0);
    }
}

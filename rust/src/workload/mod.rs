//! The workload subsystem: from CNN architecture descriptions to NoC
//! traffic, for *any* network on *any* platform.
//!
//! The paper's design flow starts from the traffic of exactly two
//! networks (LeNet, CDBNet, Table 1). This module replaces that
//! hardcoded world with a three-stage pipeline:
//!
//! ```text
//!   ArchSpec ("conv:5x5x20 pool:2 ... dense:10", or a named preset)
//!      │  shape inference + validation          (workload::spec)
//!      ▼
//!   ModelSpec layer chain + SkipEdges
//!      │  MappingPolicy: which tiles compute    (workload::mapping)
//!      │  which layers (data-parallel replicas,
//!      │  layer-pipelined stages)
//!      ▼
//!   TrafficModel phases                          (workload::lower)
//!      │  existing machinery, unchanged
//!      ▼
//!   fij matrices → AMOSA design   /   traces → NocSim
//! ```
//!
//! Lowering with the identity mapping (`data:1`) short-circuits to the
//! legacy `traffic::model_phases` path, so the paper's scenarios stay
//! byte-identical. Non-trivial mappings adjust the per-layer volumes
//! (replica weight traffic, skip-connection reads) and restrict which
//! GPU tiles inject each phase (`LayerPhase::gpu_tiles`); totals obey
//! exact conservation laws pinned by `tests/workload_lower.rs`.
//!
//! Entry points: parse a [`ArchSpec`] (or pick a [`presets`] name via
//! [`crate::scenario::ModelId`]), choose a [`MappingPolicy`], then
//! [`lower`]/[`lower_id`] onto a platform.

pub mod lower;
pub mod mapping;
pub mod presets;
pub mod spec;

pub use lower::{lower, lower_id, lower_spec};
pub use mapping::MappingPolicy;
pub use presets::{preset, preset_names, PRESETS};
pub use spec::{ArchSpec, LayerDef, ShapedArch, SkipEdge, GRAMMAR};

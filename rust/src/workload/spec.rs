//! `ArchSpec` — the CNN architecture DSL.
//!
//! A spec is a whitespace-separated list of layer items, parsed from a
//! compact string (see [`GRAMMAR`]):
//!
//! ```text
//! conv:5x5x20 pool:2 conv:5x5x50 pool:2 dense:500 dense:10
//! ```
//!
//! Parsing validates the item syntax; shape inference ([`ArchSpec::shapes`])
//! validates the semantics (kernels that fit, matching skip shapes) and
//! produces the [`crate::model::cnn::ModelSpec`] layer chain plus the
//! residual [`SkipEdge`]s that the lowering pass turns into traffic. The
//! inference rules are exactly `model::cnn`'s (same padding / pooling /
//! ceil-mode arithmetic), so a DSL-built LeNet is field-for-field equal to
//! the hand-built `model::cnn::lenet()` — pinned by tests.

use std::fmt;
use std::str::FromStr;

use crate::error::WihetError;
use crate::model::cnn::{Layer, LayerKind, ModelSpec, Shape3};

/// The workload DSL, quoted verbatim in malformed-spec errors.
pub const GRAMMAR: &str = "workload DSL (whitespace-separated items):
  input:HxWxC                    input tensor; optional first item (default 32x32x3)
  conv:KxKxC[,same][,stride=S]   KxK convolution to C channels; valid padding
                                 unless `same`, stride 1 unless `stride=S`
  pool:K[/S][,avg][,ceil]        pooling: kernel K, stride S (default K), max
                                 unless `avg`, floor division unless `ceil`
  lrn                            local response normalization
  dense:N                        fully connected layer with N outputs
  skip:D                         residual add of the output D layers back onto
                                 the previous layer's output
example: conv:5x5x20 pool:2 conv:5x5x50 pool:2 dense:500 dense:10
presets: lenet, cdbnet, alexnet, vgg11, resnet-lite";

/// One item of the architecture DSL, before shape inference.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum LayerDef {
    Conv { kernel: usize, out_channels: usize, same: bool, stride: usize },
    Pool { kernel: usize, stride: usize, avg: bool, ceil: bool },
    Lrn,
    Dense { units: usize },
    /// Residual connection: add the output of the layer `back` positions
    /// earlier (in the inferred layer chain) to the previous layer's
    /// output. Shapes must match.
    Skip { back: usize },
}

/// A residual edge between two layers of the inferred chain: the output
/// of layer `src` is added to the output of layer `dst` (indices into
/// `ModelSpec::layers`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SkipEdge {
    pub src: usize,
    pub dst: usize,
}

/// Shape-inferred architecture: the legacy layer chain plus the skip
/// edges the chain cannot express.
#[derive(Debug, Clone, PartialEq)]
pub struct ShapedArch {
    pub spec: ModelSpec,
    pub skips: Vec<SkipEdge>,
}

/// A CNN architecture described by the DSL: an input shape and a list of
/// [`LayerDef`] items. Round-trips through its string form
/// (`to_string().parse()` reproduces the value) and lowers to a
/// [`ModelSpec`] + skip edges via [`ArchSpec::shapes`].
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ArchSpec {
    /// Workload name ("custom" for parsed specs, the preset name for
    /// built-ins). Not part of the string form.
    pub name: String,
    /// (H, W, C) input tensor shape per sample.
    pub input: Shape3,
    pub items: Vec<LayerDef>,
}

fn bad(msg: String) -> WihetError {
    WihetError::InvalidSpec(msg)
}

impl ArchSpec {
    /// Default input when the spec omits `input:` — CIFAR-shaped.
    pub const DEFAULT_INPUT: Shape3 = (32, 32, 3);

    /// Run shape inference: validate every layer against its input shape
    /// and produce the concrete layer chain + skip edges.
    pub fn shapes(&self) -> Result<ShapedArch, WihetError> {
        let mut layers: Vec<Layer> = Vec::with_capacity(self.items.len());
        let mut skips = Vec::new();
        let (mut nc, mut np, mut nd) = (0usize, 0usize, 0usize);
        let cur = |layers: &Vec<Layer>| -> Shape3 {
            layers.last().map(|l| l.out_shape).unwrap_or(self.input)
        };
        for item in &self.items {
            match *item {
                LayerDef::Conv { kernel: k, out_channels: co, same, stride: s } => {
                    nc += 1;
                    let name = format!("C{nc}");
                    let (ih, iw, ci) = cur(&layers);
                    let (oh, ow) = if same {
                        (ih.div_ceil(s), iw.div_ceil(s))
                    } else {
                        if ih < k || iw < k {
                            return Err(bad(format!(
                                "{name}: conv {k}x{k} does not fit the {ih}x{iw} input"
                            )));
                        }
                        ((ih - k) / s + 1, (iw - k) / s + 1)
                    };
                    if oh == 0 || ow == 0 {
                        return Err(bad(format!(
                            "{name}: conv {k}x{k}/{s} collapses the {ih}x{iw} input"
                        )));
                    }
                    layers.push(Layer {
                        name,
                        kind: LayerKind::Conv,
                        in_shape: (ih, iw, ci),
                        out_shape: (oh, ow, co),
                        kernel: k,
                        stride: s,
                        same_padding: same,
                        ceil_mode: false,
                    });
                }
                LayerDef::Pool { kernel: k, stride: s, avg, ceil } => {
                    np += 1;
                    let name = format!("P{np}");
                    let (ih, iw, c) = cur(&layers);
                    if ih < k || iw < k {
                        return Err(bad(format!(
                            "{name}: pool {k}/{s} does not fit the {ih}x{iw} input"
                        )));
                    }
                    let dim = |i: usize| {
                        if ceil {
                            (i - k).div_ceil(s) + 1
                        } else {
                            (i - k) / s + 1
                        }
                    };
                    layers.push(Layer {
                        name,
                        kind: if avg { LayerKind::AvgPool } else { LayerKind::MaxPool },
                        in_shape: (ih, iw, c),
                        out_shape: (dim(ih), dim(iw), c),
                        kernel: k,
                        stride: s,
                        same_padding: false,
                        ceil_mode: ceil,
                    });
                }
                LayerDef::Lrn => {
                    let s = cur(&layers);
                    layers.push(Layer {
                        name: "LRN".into(),
                        kind: LayerKind::Lrn,
                        in_shape: s,
                        out_shape: s,
                        kernel: 5,
                        stride: 1,
                        same_padding: false,
                        ceil_mode: false,
                    });
                }
                LayerDef::Dense { units } => {
                    nd += 1;
                    let (ih, iw, c) = cur(&layers);
                    layers.push(Layer {
                        name: format!("F{nd}"),
                        kind: LayerKind::Dense,
                        in_shape: (ih, iw, c),
                        out_shape: (1, 1, units),
                        kernel: 0,
                        stride: 1,
                        same_padding: false,
                        ceil_mode: false,
                    });
                }
                LayerDef::Skip { back } => {
                    let Some(dst) = layers.len().checked_sub(1) else {
                        return Err(bad("skip:D cannot be the first layer".into()));
                    };
                    let Some(src) = dst.checked_sub(back) else {
                        return Err(bad(format!(
                            "skip:{back} reaches before the first layer (only {dst} layers precede {})",
                            layers[dst].name
                        )));
                    };
                    let (a, b) = (layers[src].out_shape, layers[dst].out_shape);
                    if a != b {
                        return Err(bad(format!(
                            "skip:{back}: shape mismatch {}x{}x{} ({}) vs {}x{}x{} ({})",
                            a.0, a.1, a.2, layers[src].name, b.0, b.1, b.2, layers[dst].name
                        )));
                    }
                    skips.push(SkipEdge { src, dst });
                }
            }
        }
        if layers.is_empty() {
            return Err(bad("spec has no layers".into()));
        }
        let num_classes = layers
            .iter()
            .rev()
            .find(|l| l.kind == LayerKind::Dense)
            .map(|l| l.out_shape.2)
            .unwrap_or(cur(&layers).2);
        Ok(ShapedArch {
            spec: ModelSpec {
                name: self.name.clone(),
                input_shape: self.input,
                num_classes,
                layers,
            },
            skips,
        })
    }

    /// Number of GPU-resident layers (everything but `dense`, which the
    /// paper's execution model runs on the CPUs).
    pub fn gpu_layer_count(&self) -> usize {
        self.items
            .iter()
            .filter(|i| {
                matches!(i, LayerDef::Conv { .. } | LayerDef::Pool { .. } | LayerDef::Lrn)
            })
            .count()
    }
}

impl fmt::Display for ArchSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let (h, w, c) = self.input;
        write!(f, "input:{h}x{w}x{c}")?;
        for item in &self.items {
            f.write_str(" ")?;
            match *item {
                LayerDef::Conv { kernel, out_channels, same, stride } => {
                    write!(f, "conv:{kernel}x{kernel}x{out_channels}")?;
                    if same {
                        f.write_str(",same")?;
                    }
                    if stride != 1 {
                        write!(f, ",stride={stride}")?;
                    }
                }
                LayerDef::Pool { kernel, stride, avg, ceil } => {
                    write!(f, "pool:{kernel}")?;
                    if stride != kernel {
                        write!(f, "/{stride}")?;
                    }
                    if avg {
                        f.write_str(",avg")?;
                    }
                    if ceil {
                        f.write_str(",ceil")?;
                    }
                }
                LayerDef::Lrn => f.write_str("lrn")?,
                LayerDef::Dense { units } => write!(f, "dense:{units}")?,
                LayerDef::Skip { back } => write!(f, "skip:{back}")?,
            }
        }
        Ok(())
    }
}

fn parse_usize(v: &str, what: &str) -> Result<usize, WihetError> {
    match v.trim().parse::<usize>() {
        Ok(n) if n > 0 => Ok(n),
        _ => Err(bad(format!("{what} expects a positive integer, got '{v}'"))),
    }
}

/// One parsed token: either the input declaration or a layer item.
enum Item {
    Input(Shape3),
    Def(LayerDef),
}

fn parse_item(tok: &str) -> Result<Item, WihetError> {
    let tok_lc = tok.to_ascii_lowercase();
    let (head, rest) = match tok_lc.split_once(':') {
        Some((h, r)) => (h, Some(r)),
        None => (tok_lc.as_str(), None),
    };
    let args = |what: &str| rest.ok_or_else(|| bad(format!("{what} needs arguments: '{tok}'")));
    match head {
        "lrn" => {
            if rest.is_some() {
                return Err(bad(format!("lrn takes no arguments, got '{tok}'")));
            }
            Ok(Item::Def(LayerDef::Lrn))
        }
        "input" => {
            let dims: Vec<&str> = args("input")?.split('x').collect();
            if dims.len() != 3 {
                return Err(bad(format!("input expects HxWxC, got '{tok}'")));
            }
            Ok(Item::Input((
                parse_usize(dims[0], "input height")?,
                parse_usize(dims[1], "input width")?,
                parse_usize(dims[2], "input channels")?,
            )))
        }
        "conv" => {
            let mut parts = args("conv")?.split(',');
            let shape = parts.next().unwrap_or_default();
            let dims: Vec<&str> = shape.split('x').collect();
            if dims.len() != 3 {
                return Err(bad(format!("conv expects KxKxC, got '{tok}'")));
            }
            let k1 = parse_usize(dims[0], "conv kernel")?;
            let k2 = parse_usize(dims[1], "conv kernel")?;
            if k1 != k2 {
                return Err(bad(format!("conv kernels must be square, got {k1}x{k2}")));
            }
            let out_channels = parse_usize(dims[2], "conv channels")?;
            let (mut same, mut stride) = (false, 1usize);
            for flag in parts {
                match flag.trim() {
                    "same" => same = true,
                    f if f.starts_with("stride=") => {
                        stride = parse_usize(&f["stride=".len()..], "conv stride")?;
                    }
                    other => {
                        return Err(bad(format!(
                            "unknown conv option '{other}' (same, stride=S)"
                        )))
                    }
                }
            }
            Ok(Item::Def(LayerDef::Conv { kernel: k1, out_channels, same, stride }))
        }
        "pool" => {
            let mut parts = args("pool")?.split(',');
            let ks = parts.next().unwrap_or_default();
            let (kernel, stride) = match ks.split_once('/') {
                Some((k, s)) => {
                    (parse_usize(k, "pool kernel")?, parse_usize(s, "pool stride")?)
                }
                None => {
                    let k = parse_usize(ks, "pool kernel")?;
                    (k, k)
                }
            };
            let (mut avg, mut ceil) = (false, false);
            for flag in parts {
                match flag.trim() {
                    "avg" => avg = true,
                    "max" => avg = false,
                    "ceil" => ceil = true,
                    other => {
                        return Err(bad(format!(
                            "unknown pool option '{other}' (avg, max, ceil)"
                        )))
                    }
                }
            }
            Ok(Item::Def(LayerDef::Pool { kernel, stride, avg, ceil }))
        }
        "dense" => Ok(Item::Def(LayerDef::Dense {
            units: parse_usize(args("dense")?, "dense units")?,
        })),
        "skip" => Ok(Item::Def(LayerDef::Skip {
            back: parse_usize(args("skip")?, "skip distance")?,
        })),
        other => Err(bad(format!(
            "unknown layer item '{other}' (input, conv, pool, lrn, dense, skip)"
        ))),
    }
}

impl FromStr for ArchSpec {
    type Err = WihetError;

    /// Parse and shape-check a spec string; the result is named "custom".
    fn from_str(s: &str) -> Result<Self, WihetError> {
        let mut input = ArchSpec::DEFAULT_INPUT;
        let mut items = Vec::new();
        for (i, tok) in s.split_whitespace().enumerate() {
            match parse_item(tok)? {
                Item::Input(shape) => {
                    if i != 0 {
                        return Err(bad("input:HxWxC must be the first item".into()));
                    }
                    input = shape;
                }
                Item::Def(def) => items.push(def),
            }
        }
        if items.is_empty() {
            return Err(bad("empty workload spec".into()));
        }
        let arch = ArchSpec { name: "custom".into(), input, items };
        arch.shapes()?; // semantic validation up front
        Ok(arch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_issue_example() {
        let a: ArchSpec = "conv:5x5x20 pool:2 conv:5x5x50 pool:2 dense:500 dense:10"
            .parse()
            .unwrap();
        assert_eq!(a.input, (32, 32, 3));
        assert_eq!(a.items.len(), 6);
        let shaped = a.shapes().unwrap();
        assert_eq!(shaped.spec.layers.len(), 6);
        assert_eq!(shaped.spec.num_classes, 10);
        // 32 -> conv5 -> 28 -> pool2 -> 14 -> conv5 -> 10 -> pool2 -> 5
        assert_eq!(shaped.spec.layers[3].out_shape, (5, 5, 50));
        assert_eq!(shaped.spec.layers[4].out_shape, (1, 1, 500));
    }

    #[test]
    fn roundtrips_through_display() {
        for s in [
            "conv:5x5x20 pool:2 conv:5x5x50 pool:2 dense:500 dense:10",
            "input:28x28x1 conv:3x3x8,same,stride=2 pool:3/2,avg,ceil lrn dense:10",
            "input:16x16x4 conv:3x3x4,same conv:3x3x4,same skip:1 dense:10",
        ] {
            let a: ArchSpec = s.parse().unwrap();
            let b: ArchSpec = a.to_string().parse().unwrap();
            assert_eq!(a, b, "{s} -> {a}");
        }
    }

    #[test]
    fn skip_shapes_must_match() {
        // the pooled tensor no longer matches the pre-pool one
        let e = "input:16x16x4 conv:3x3x4,same pool:2 skip:1 dense:10"
            .parse::<ArchSpec>()
            .unwrap_err();
        assert!(matches!(e, WihetError::InvalidSpec(_)), "{e:?}");
        assert!(e.to_string().contains("shape mismatch"), "{e}");
    }

    #[test]
    fn malformed_items_are_typed_errors_with_grammar() {
        for s in [
            "",
            "convolution:3x3x8",
            "conv:3x4x8",
            "conv:3x3",
            "conv:0x0x8",
            "pool:0",
            "pool:2,huge",
            "dense:x",
            "skip:0",
            "skip:1",
            "conv:3x3x8 input:8x8x1",
            "lrn:5",
        ] {
            let e = s.parse::<ArchSpec>().unwrap_err();
            assert!(matches!(e, WihetError::InvalidSpec(_)), "{s}: {e:?}");
            assert!(e.to_string().contains("conv:KxKxC"), "{s}: {e}");
        }
        // a kernel larger than its input is a shape error
        let e = "input:4x4x1 conv:9x9x4".parse::<ArchSpec>().unwrap_err();
        assert!(e.to_string().contains("does not fit"), "{e}");
    }

    #[test]
    fn strided_and_same_conv_shapes() {
        let a: ArchSpec = "input:32x32x3 conv:3x3x8,same,stride=2 dense:10".parse().unwrap();
        let s = a.shapes().unwrap();
        assert_eq!(s.spec.layers[0].out_shape, (16, 16, 8));
        let a: ArchSpec = "input:11x11x3 conv:3x3x8,stride=2 dense:10".parse().unwrap();
        let s = a.shapes().unwrap();
        assert_eq!(s.spec.layers[0].out_shape, (5, 5, 8));
    }

    #[test]
    fn gpu_layer_count_excludes_dense() {
        let a: ArchSpec = "conv:3x3x8 pool:2 lrn dense:10".parse().unwrap();
        assert_eq!(a.gpu_layer_count(), 3);
    }
}

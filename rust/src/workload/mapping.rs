//! Mapping policies: which tiles compute which layers on a given
//! platform.
//!
//! The paper evaluates exactly one mapping — every GPU tile works on
//! every layer (our `data:1`). The policies here generalize that:
//!
//! * [`MappingPolicy::DataParallel`] `{ replicas }` — the batch is split
//!   across `replicas` model replicas; all GPU tiles stay active on every
//!   layer, but each replica reads its own copy of the weights and writes
//!   its own weight gradient, and the CPUs reduce `replicas` gradient
//!   shards per weighted layer. `replicas = 1` is the identity mapping
//!   and lowers byte-identically to the legacy pipeline.
//! * [`MappingPolicy::LayerPipelined`] `{ stages }` — GPU-resident layers
//!   are partitioned into `stages` contiguous stages balanced by MACs and
//!   each stage owns a contiguous slice of the GPU tiles; only that slice
//!   injects traffic (and computes) during the stage's phases. Total
//!   bytes are conserved — the mapping redistributes traffic, it never
//!   creates or loses it. A stage count above the workload's GPU layer
//!   count is clamped to it at lowering time (a 3-GPU-layer net under
//!   `pipeline:8` runs 3 stages): the bound depends on the workload, not
//!   the platform, so [`MappingPolicy::validate_for`] cannot check it.

use std::fmt;
use std::str::FromStr;

use crate::error::WihetError;
use crate::model::SystemConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MappingPolicy {
    /// `replicas` model replicas, batch split across them.
    DataParallel { replicas: usize },
    /// GPU layers partitioned into `stages` pipeline stages.
    LayerPipelined { stages: usize },
}

impl Default for MappingPolicy {
    /// The paper's mapping: one replica over all GPU tiles.
    fn default() -> Self {
        MappingPolicy::DataParallel { replicas: 1 }
    }
}

impl MappingPolicy {
    /// Whether this mapping lowers identically to the legacy
    /// (unmapped) traffic pipeline.
    pub fn is_identity(&self) -> bool {
        matches!(self, MappingPolicy::DataParallel { replicas: 1 })
    }

    /// Reject mappings that cannot be laid out on `sys` at `batch`.
    pub fn validate_for(&self, sys: &SystemConfig, batch: usize) -> Result<(), WihetError> {
        let n_gpu = sys.gpus().len();
        let err = |m: String| Err(WihetError::InvalidArg(m));
        match *self {
            MappingPolicy::DataParallel { replicas } => {
                if replicas == 0 {
                    return err("data-parallel mapping needs at least 1 replica".into());
                }
                if replicas > n_gpu {
                    return err(format!(
                        "data:{replicas} exceeds the {n_gpu} GPU tiles of the platform"
                    ));
                }
                if replicas > batch {
                    return err(format!(
                        "data:{replicas} exceeds the batch size {batch} (every replica needs at least one sample)"
                    ));
                }
            }
            MappingPolicy::LayerPipelined { stages } => {
                if stages == 0 {
                    return err("pipelined mapping needs at least 1 stage".into());
                }
                if stages > n_gpu {
                    return err(format!(
                        "pipeline:{stages} exceeds the {n_gpu} GPU tiles of the platform"
                    ));
                }
            }
        }
        Ok(())
    }
}

impl fmt::Display for MappingPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            MappingPolicy::DataParallel { replicas } => write!(f, "data:{replicas}"),
            MappingPolicy::LayerPipelined { stages } => write!(f, "pipeline:{stages}"),
        }
    }
}

impl FromStr for MappingPolicy {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        let t = s.trim().to_ascii_lowercase();
        let (head, arg) = match t.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (t.as_str(), None),
        };
        let count = |arg: Option<&str>, default: usize, what: &str| match arg {
            None => Ok(default),
            Some(a) => a.trim().parse::<usize>().map_err(|_| {
                WihetError::InvalidArg(format!("{what} expects an integer, got '{a}'"))
            }),
        };
        match head {
            "data" => Ok(MappingPolicy::DataParallel {
                replicas: count(arg, 1, "data:<replicas>")?,
            }),
            "pipeline" => Ok(MappingPolicy::LayerPipelined {
                stages: count(arg, 2, "pipeline:<stages>")?,
            }),
            other => Err(WihetError::InvalidArg(format!(
                "unknown mapping '{other}' (data[:replicas] | pipeline[:stages])"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_display_roundtrip() {
        for s in ["data:1", "data:4", "pipeline:2", "pipeline:6"] {
            let m: MappingPolicy = s.parse().unwrap();
            assert_eq!(m.to_string(), s);
            assert_eq!(m.to_string().parse::<MappingPolicy>().unwrap(), m);
        }
        assert_eq!(
            "data".parse::<MappingPolicy>().unwrap(),
            MappingPolicy::DataParallel { replicas: 1 }
        );
        assert_eq!(
            "pipeline".parse::<MappingPolicy>().unwrap(),
            MappingPolicy::LayerPipelined { stages: 2 }
        );
        assert!("rings".parse::<MappingPolicy>().is_err());
        assert!("data:x".parse::<MappingPolicy>().is_err());
    }

    #[test]
    fn identity_detection() {
        assert!(MappingPolicy::default().is_identity());
        assert!(!MappingPolicy::DataParallel { replicas: 2 }.is_identity());
        assert!(!MappingPolicy::LayerPipelined { stages: 1 }.is_identity());
    }

    #[test]
    fn validation_bounds() {
        let sys = SystemConfig::paper_8x8(); // 56 GPUs
        assert!(MappingPolicy::DataParallel { replicas: 1 }.validate_for(&sys, 32).is_ok());
        assert!(MappingPolicy::DataParallel { replicas: 56 }.validate_for(&sys, 64).is_ok());
        assert!(MappingPolicy::DataParallel { replicas: 57 }.validate_for(&sys, 64).is_err());
        assert!(MappingPolicy::DataParallel { replicas: 0 }.validate_for(&sys, 32).is_err());
        assert!(MappingPolicy::DataParallel { replicas: 33 }.validate_for(&sys, 32).is_err());
        assert!(MappingPolicy::LayerPipelined { stages: 4 }.validate_for(&sys, 32).is_ok());
        assert!(MappingPolicy::LayerPipelined { stages: 0 }.validate_for(&sys, 32).is_err());
        assert!(MappingPolicy::LayerPipelined { stages: 57 }.validate_for(&sys, 32).is_err());
    }
}

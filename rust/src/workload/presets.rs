//! Named architecture presets, defined *in* the DSL so every preset also
//! exercises the parser.
//!
//! `lenet` and `cdbnet` reproduce the paper's Table 1 models exactly
//! (field-for-field equal to `model::cnn::lenet()`/`cdbnet()`, pinned by
//! tests). The other three open non-paper workloads at the 32x32 scale
//! this toolchain's traffic model is calibrated for:
//!
//! * `alexnet`     — AlexNet-style conv/LRN stack (CIFAR-scale).
//! * `vgg11`       — VGG-11: 8 conv + 3 dense layers.
//! * `resnet-lite` — a small residual network; its `skip:2` items become
//!   [`super::spec::SkipEdge`]s that the lowering pass turns into extra
//!   save/restore traffic.

use super::spec::ArchSpec;

/// `(name, dsl)` for every built-in preset, in menu order.
pub const PRESETS: &[(&str, &str)] = &[
    (
        "lenet",
        "input:33x33x1 conv:5x5x16 pool:2,ceil conv:5x5x16 pool:2 conv:5x5x128 dense:10",
    ),
    (
        "cdbnet",
        "input:31x31x3 conv:5x5x32,same pool:3/2 lrn conv:5x5x32,same pool:3/2,avg \
         conv:5x5x64,same pool:7/7,avg dense:10",
    ),
    (
        "alexnet",
        "input:32x32x3 conv:3x3x64,same pool:2 lrn conv:5x5x192,same pool:2 \
         conv:3x3x384,same conv:3x3x256,same conv:3x3x256,same pool:2 \
         dense:1024 dense:512 dense:10",
    ),
    (
        "vgg11",
        "input:32x32x3 conv:3x3x64,same pool:2 conv:3x3x128,same pool:2 \
         conv:3x3x256,same conv:3x3x256,same pool:2 conv:3x3x512,same conv:3x3x512,same pool:2 \
         conv:3x3x512,same conv:3x3x512,same pool:2 dense:512 dense:512 dense:10",
    ),
    (
        "resnet-lite",
        "input:32x32x3 conv:3x3x16,same conv:3x3x16,same conv:3x3x16,same skip:2 \
         conv:3x3x16,same conv:3x3x16,same skip:2 pool:2 \
         conv:3x3x32,same conv:3x3x32,same conv:3x3x32,same skip:2 pool:2 \
         conv:3x3x64,same conv:3x3x64,same conv:3x3x64,same skip:2 pool:2,avg dense:10",
    ),
];

/// The preset names, for error messages and `list` output.
pub fn preset_names() -> Vec<&'static str> {
    PRESETS.iter().map(|(n, _)| *n).collect()
}

/// Look up a preset by name (case-insensitive; `_` and `-` are
/// interchangeable). Returns the named, validated `ArchSpec`.
pub fn preset(name: &str) -> Option<ArchSpec> {
    let norm = name.trim().to_ascii_lowercase().replace('_', "-");
    PRESETS.iter().find(|(n, _)| *n == norm).map(|(n, dsl)| {
        let mut arch: ArchSpec = dsl.parse().expect("built-in preset parses");
        arch.name = (*n).to_string();
        arch
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::cnn::{cdbnet, lenet, LayerKind};

    #[test]
    fn every_preset_parses_and_shapes() {
        for (name, _) in PRESETS {
            let arch = preset(name).unwrap();
            assert_eq!(arch.name, *name);
            let shaped = arch.shapes().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(!shaped.spec.layers.is_empty());
            assert_eq!(shaped.spec.num_classes, 10, "{name}");
        }
        assert!(preset("resnet_lite").is_some());
        assert!(preset("RESNET-LITE").is_some());
        assert!(preset("resnet").is_none());
    }

    #[test]
    fn lenet_preset_equals_legacy_model() {
        let shaped = preset("lenet").unwrap().shapes().unwrap();
        assert_eq!(shaped.spec, lenet());
        assert!(shaped.skips.is_empty());
    }

    #[test]
    fn cdbnet_preset_equals_legacy_model() {
        let shaped = preset("cdbnet").unwrap().shapes().unwrap();
        assert_eq!(shaped.spec, cdbnet());
        assert!(shaped.skips.is_empty());
    }

    #[test]
    fn alexnet_and_vgg11_shapes() {
        let alex = preset("alexnet").unwrap().shapes().unwrap();
        // three pools on a 32x32 input leave a 4x4 map before the head
        let last_pool = alex
            .spec
            .layers
            .iter()
            .rev()
            .find(|l| matches!(l.kind, LayerKind::MaxPool | LayerKind::AvgPool))
            .unwrap();
        assert_eq!(last_pool.out_shape, (4, 4, 256));
        let vgg = preset("vgg11").unwrap().shapes().unwrap();
        let weighted = vgg.spec.layers.iter().filter(|l| l.has_params()).count();
        assert_eq!(weighted, 11, "VGG-11 has 11 weight layers");
        assert_eq!(vgg.spec.layers.last().unwrap().in_shape, (1, 1, 512));
    }

    #[test]
    fn resnet_lite_has_matching_skips() {
        let shaped = preset("resnet-lite").unwrap().shapes().unwrap();
        assert_eq!(shaped.skips.len(), 4);
        for e in &shaped.skips {
            assert_eq!(
                shaped.spec.layers[e.src].out_shape,
                shaped.spec.layers[e.dst].out_shape
            );
            assert_eq!(e.dst - e.src, 2);
        }
    }
}

//! The lowering pass: (architecture, mapping, platform) -> traffic.
//!
//! `lower` turns an [`ArchSpec`] + [`MappingPolicy`] into the crate's
//! existing [`TrafficModel`], so everything downstream — `fij` matrices
//! for the AMOSA optimizer, simulator traces, the experiment harnesses —
//! consumes new workloads unchanged. Pipeline:
//!
//! 1. **Shape inference** ([`ArchSpec::shapes`]) — layer chain + skip
//!    edges.
//! 2. **Volume accounting** ([`crate::traffic::phases::layer_volumes`]) —
//!    the paper's per-layer read/write/MAC model, untouched.
//! 3. **Mapping adjustment** — replica weight traffic (data-parallel),
//!    skip-connection save/restore reads, stage tile assignment
//!    (layer-pipelined).
//! 4. **Phase finishing** ([`crate::traffic::phases::finish_phase`]) —
//!    orchestration overheads, control flits, the duration model.
//!
//! For the identity mapping (`data:1`, no skips) the pass short-circuits
//! to [`model_phases`], so the paper's LeNet/CDBNet traffic is
//! byte-identical to the pre-workload-subsystem code. Conservation: a
//! pipelined mapping only *redistributes* bytes (totals equal the
//! identity lowering); `data:R` adds exactly `(R-1)` extra weight reads,
//! weight-gradient writes, and CPU gradient-shard reads per weighted GPU
//! layer — both invariants pinned by `tests/workload_lower.rs`.

use crate::error::WihetError;
use crate::model::cnn::{cdbnet, lenet, LayerKind, ModelSpec, Pass};
use crate::model::SystemConfig;
use crate::scenario::ModelId;
use crate::traffic::phases::{
    finish_phase, layer_volumes, model_phases, ExtraVolumes, TrafficModel,
};

use super::mapping::MappingPolicy;
use super::spec::{ArchSpec, SkipEdge};

/// Lower a workload id (preset or custom spec) to traffic.
pub fn lower_id(
    model: &ModelId,
    mapping: &MappingPolicy,
    sys: &SystemConfig,
    batch: usize,
) -> Result<TrafficModel, WihetError> {
    match model {
        // The paper models lower from the hand-built Table 1 chains (the
        // DSL presets are asserted equal to them, but going straight to
        // the source keeps the byte-identity guarantee structural).
        ModelId::LeNet => lower_spec(&lenet(), &[], mapping, sys, batch),
        ModelId::CdbNet => lower_spec(&cdbnet(), &[], mapping, sys, batch),
        other => lower(&other.arch(), mapping, sys, batch),
    }
}

/// Lower a DSL architecture to traffic.
pub fn lower(
    arch: &ArchSpec,
    mapping: &MappingPolicy,
    sys: &SystemConfig,
    batch: usize,
) -> Result<TrafficModel, WihetError> {
    let shaped = arch.shapes()?;
    lower_spec(&shaped.spec, &shaped.skips, mapping, sys, batch)
}

/// Lower a shape-inferred layer chain (+ skip edges) to traffic.
pub fn lower_spec(
    spec: &ModelSpec,
    skips: &[SkipEdge],
    mapping: &MappingPolicy,
    sys: &SystemConfig,
    batch: usize,
) -> Result<TrafficModel, WihetError> {
    mapping.validate_for(sys, batch)?;
    if skips.is_empty() && mapping.is_identity() {
        // Fast path == legacy path: byte-identical traffic for the
        // paper's scenarios, by construction.
        return Ok(model_phases(sys, spec, batch));
    }
    let n_layers = spec.layers.len();
    // Extra bytes the residual edges move at their join layer: the skip
    // tensor is saved by `src` (already part of its output volume) and
    // re-read by `dst` for the add; the backward pass reads the incoming
    // gradient once more and writes the skip-path gradient.
    let mut skip_bytes = vec![0u64; n_layers];
    for e in skips {
        if e.src >= e.dst || e.dst >= n_layers {
            return Err(WihetError::InvalidSpec(format!(
                "skip edge {} -> {} outside the {n_layers}-layer chain",
                e.src, e.dst
            )));
        }
        skip_bytes[e.dst] += spec.layers[e.src].out_bytes(batch);
    }
    let stages = match mapping {
        MappingPolicy::LayerPipelined { stages } => {
            Some(stage_assignment(spec, sys, *stages))
        }
        MappingPolicy::DataParallel { .. } => None,
    };

    let order: Vec<(Pass, usize)> = (0..n_layers)
        .map(|i| (Pass::Forward, i))
        .chain((0..n_layers).rev().map(|i| (Pass::Backward, i)))
        .collect();
    let mut phases = Vec::with_capacity(order.len());
    for (pass, li) in order {
        let l = &spec.layers[li];
        let v = layer_volumes(l, batch, pass);
        let mut extra = ExtraVolumes::default();
        let s = skip_bytes[li];
        if s > 0 {
            match (pass, v.on_cpu) {
                (Pass::Forward, false) => extra.gpu_read += s,
                (Pass::Forward, true) => extra.cpu_read += s,
                (Pass::Backward, false) => {
                    extra.gpu_read += s;
                    extra.gpu_write += s;
                }
                (Pass::Backward, true) => {
                    extra.cpu_read += s;
                    extra.cpu_write += s;
                }
            }
        }
        if let MappingPolicy::DataParallel { replicas } = mapping {
            if *replicas > 1 && !v.on_cpu && l.has_params() {
                // every replica fetches the weights itself and emits its
                // own gradient shard; the CPUs read all shards to reduce
                let w = (*replicas as u64 - 1) * l.weight_bytes();
                match pass {
                    Pass::Forward => extra.gpu_read += w,
                    Pass::Backward => {
                        extra.gpu_read += w;
                        extra.gpu_write += w;
                        extra.cpu_read += w;
                    }
                }
            }
        }
        let (share, tiles) = match &stages {
            Some(a) => a.phase_assignment(li),
            None => (1.0, Vec::new()),
        };
        phases.push(finish_phase(sys, l, pass, v, extra, share, tiles));
    }
    Ok(TrafficModel { model: spec.name.clone(), batch, phases })
}

/// Deterministic stage layout for the layer-pipelined mapping: GPU layers
/// in `stages` contiguous groups balanced by forward MACs, GPU tiles in
/// `stages` contiguous near-equal slices.
struct StageAssignment {
    /// Stage index per layer; `usize::MAX` for CPU (dense) layers.
    stage_of: Vec<usize>,
    /// GPU tile slice per stage.
    tiles: Vec<Vec<usize>>,
    total_gpus: usize,
}

impl StageAssignment {
    /// `(gpu throughput share, injecting tiles)` for one layer's phases.
    fn phase_assignment(&self, layer: usize) -> (f64, Vec<usize>) {
        let st = self.stage_of[layer];
        if st == usize::MAX {
            // dense layers run on the CPUs; GPU share is irrelevant
            (1.0, Vec::new())
        } else {
            let tiles = self.tiles[st].clone();
            (tiles.len() as f64 / self.total_gpus as f64, tiles)
        }
    }
}

fn stage_assignment(spec: &ModelSpec, sys: &SystemConfig, stages: usize) -> StageAssignment {
    let gpus = sys.gpus();
    let gpu_layers: Vec<usize> = (0..spec.layers.len())
        .filter(|&i| spec.layers[i].kind != LayerKind::Dense)
        .collect();
    // more stages than GPU layers (or tiles) cannot be filled — clamp
    let stages = stages.clamp(1, gpu_layers.len().max(1)).min(gpus.len());
    let mut stage_of = vec![usize::MAX; spec.layers.len()];
    if !gpu_layers.is_empty() {
        // contiguous partition balanced by forward MACs (batch-invariant:
        // MACs are linear in the batch)
        let weights: Vec<u64> = gpu_layers.iter().map(|&i| spec.layers[i].macs(1)).collect();
        let total: u128 = weights.iter().map(|&w| w as u128).sum::<u128>().max(1);
        let n = gpu_layers.len();
        let mut stage = 0usize;
        let mut acc: u128 = 0;
        for (pos, &li) in gpu_layers.iter().enumerate() {
            // down to one layer per remaining stage: every further layer
            // opens a new stage
            let must_advance =
                pos > 0 && stage + 1 < stages && n - pos <= stages - stage;
            // this stage reached its cumulative MAC share — advance,
            // unless that would leave a later stage without a layer
            let want_advance = stage + 1 < stages
                && acc * stages as u128 >= (stage as u128 + 1) * total
                && n - pos > stages - stage - 1;
            if must_advance || want_advance {
                stage += 1;
            }
            stage_of[li] = stage;
            acc += weights[pos] as u128;
        }
    }
    let tiles: Vec<Vec<usize>> = (0..stages)
        .map(|s| {
            let lo = s * gpus.len() / stages;
            let hi = (s + 1) * gpus.len() / stages;
            gpus[lo..hi].to_vec()
        })
        .collect();
    StageAssignment { stage_of, tiles, total_gpus: gpus.len() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::presets::preset;

    #[test]
    fn identity_mapping_is_the_legacy_path() {
        let sys = SystemConfig::paper_8x8();
        let spec = lenet();
        let a = lower_spec(&spec, &[], &MappingPolicy::default(), &sys, 32).unwrap();
        let b = model_phases(&sys, &spec, 32);
        assert_eq!(a.phases.len(), b.phases.len());
        for (x, y) in a.phases.iter().zip(&b.phases) {
            assert_eq!(x.gpu_read_bytes, y.gpu_read_bytes);
            assert_eq!(x.gpu_write_bytes, y.gpu_write_bytes);
            assert_eq!(x.cpu_read_bytes, y.cpu_read_bytes);
            assert_eq!(x.cpu_write_bytes, y.cpu_write_bytes);
            assert_eq!(x.core_core_flits, y.core_core_flits);
            assert_eq!(x.duration_cycles, y.duration_cycles);
            assert!(x.gpu_tiles.is_empty());
        }
    }

    #[test]
    fn pipeline_assigns_disjoint_contiguous_tiles() {
        let sys = SystemConfig::paper_8x8();
        let spec = lenet();
        let a = stage_assignment(&spec, &sys, 3);
        assert_eq!(a.tiles.len(), 3);
        let all: Vec<usize> = a.tiles.iter().flatten().copied().collect();
        assert_eq!(all, sys.gpus(), "stage slices tile the GPU set in order");
        // every GPU layer is staged, monotonically; dense layers are not
        let mut last = 0usize;
        for (i, l) in spec.layers.iter().enumerate() {
            if l.kind == LayerKind::Dense {
                assert_eq!(a.stage_of[i], usize::MAX);
            } else {
                assert!(a.stage_of[i] != usize::MAX);
                assert!(a.stage_of[i] >= last);
                last = a.stage_of[i];
            }
        }
        assert_eq!(last, 2, "all three stages are used");
    }

    #[test]
    fn pipeline_stage_count_is_clamped() {
        let sys = SystemConfig::paper_8x8();
        let spec = lenet(); // 5 GPU layers
        let a = stage_assignment(&spec, &sys, 40);
        assert_eq!(a.tiles.len(), 5);
        // one layer per stage: every stage must actually be populated
        let mut used = vec![false; 5];
        for (i, l) in spec.layers.iter().enumerate() {
            if l.kind != LayerKind::Dense {
                used[a.stage_of[i]] = true;
            }
        }
        assert!(used.iter().all(|&u| u), "{used:?}");
    }

    #[test]
    fn data_parallel_adds_replica_weight_traffic() {
        let sys = SystemConfig::paper_8x8();
        let spec = lenet();
        let base = lower_spec(&spec, &[], &MappingPolicy::default(), &sys, 32).unwrap();
        let dp =
            lower_spec(&spec, &[], &MappingPolicy::DataParallel { replicas: 4 }, &sys, 32)
                .unwrap();
        let w: u64 = spec
            .layers
            .iter()
            .filter(|l| l.has_params() && l.kind != LayerKind::Dense)
            .map(|l| l.weight_bytes())
            .sum();
        // fwd read + bwd (read + write + cpu read) = 4 weight volumes
        assert_eq!(dp.total_bytes(), base.total_bytes() + 3 * 4 * w);
    }

    #[test]
    fn skips_add_exactly_their_tensor_volume() {
        let sys = SystemConfig::paper_8x8();
        let arch = preset("resnet-lite").unwrap();
        let shaped = arch.shapes().unwrap();
        let with = lower(&arch, &MappingPolicy::default(), &sys, 8).unwrap();
        let without = model_phases(&sys, &shaped.spec, 8);
        let skip_total: u64 = shaped
            .skips
            .iter()
            .map(|e| shaped.spec.layers[e.src].out_bytes(8))
            .sum();
        // fwd read + bwd read + bwd write = 3 skip-tensor volumes
        assert_eq!(with.total_bytes(), without.total_bytes() + 3 * skip_total);
    }

    #[test]
    fn invalid_mapping_is_a_typed_error() {
        let sys = SystemConfig::small_4x4(); // 12 GPUs
        let e = lower_id(
            &ModelId::LeNet,
            &MappingPolicy::DataParallel { replicas: 13 },
            &sys,
            32,
        )
        .unwrap_err();
        assert!(matches!(e, WihetError::InvalidArg(_)), "{e:?}");
    }
}

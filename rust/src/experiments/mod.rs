//! Per-figure experiment harnesses — one entry per table/figure of the
//! paper's evaluation (§5). Each regenerates the corresponding series,
//! prints paper-vs-measured where the paper states a number, and returns
//! a typed [`Report`] (scalars/series/tables with units and paper
//! references) so CI, benches, and downstream comparisons consume data
//! instead of prose.
//!
//! The set is described by the [`registry`] — [`ALL`], [`run`], and
//! [`run_many`] are views over it. Run via
//! `wihetnoc experiment <id|all> [--format text|json|csv] [--out DIR]`
//! or `cargo bench` (rust/benches/paper_benches.rs drives the same code
//! and records each report's scalars next to the wall times).

pub mod common;
pub mod ctx;
pub mod registry;
pub mod report;
pub mod table1;
pub mod traffic_figs; // fig5, fig6, fig7
pub mod optim_figs; // fig8, fig9, fig10
pub mod param_figs; // fig11, fig12, fig13
pub mod wireless_figs; // fig14, fig15, fig16
pub mod compare_figs; // fig17, fig18, fig19
pub mod workload_figs; // non-paper workloads x schedules on 12x12
pub mod scale_figs; // multi-chip data-parallel fabric scaling
pub mod resilience_figs; // fault injection: graceful degradation sweeps
pub mod hotspot_figs; // telemetry: link heatmaps + tail latency, mesh vs WiHetNoC
pub mod design_figs; // design-search observability: AMOSA convergence + eval profiler
pub mod serving_figs; // open-loop serving: offered-load sweep to the tail-latency knee

pub use ctx::{Ctx, Effort};
pub use registry::{find, ids, run, run_many, run_many_threads, Experiment, ALL, REGISTRY};
pub use report::{Artifact, ArtifactSink, Cell, PaperRef, Report, Section, SectionData};

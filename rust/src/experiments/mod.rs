//! Per-figure experiment harnesses — one entry per table/figure of the
//! paper's evaluation (§5). Each regenerates the corresponding series and
//! prints paper-vs-measured where the paper states a number.
//!
//! Run via `wihetnoc experiment <id>` (ids: table1, fig5..fig19, all) or
//! `cargo bench` (rust/benches/paper_benches.rs drives the same code).

pub mod common;
pub mod ctx;
pub mod table1;
pub mod traffic_figs; // fig5, fig6, fig7
pub mod optim_figs; // fig8, fig9, fig10
pub mod param_figs; // fig11, fig12, fig13
pub mod wireless_figs; // fig14, fig15, fig16
pub mod compare_figs; // fig17, fig18, fig19
pub mod workload_figs; // non-paper workloads x schedules on 12x12

pub use ctx::{Ctx, Effort};

use crate::error::WihetError;

/// All experiment ids: the paper figures in paper order, then the
/// non-paper extensions.
pub const ALL: &[&str] = &[
    "table1", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10", "fig11",
    "fig12", "fig13", "fig14", "fig15", "fig16", "fig17", "fig18", "fig19",
    "workload_figs",
];

/// Dispatch one experiment by id; returns its printable report. Unknown
/// ids are a typed [`WihetError::UnknownExperiment`], never a panic.
pub fn run(id: &str, ctx: &mut Ctx) -> Result<String, WihetError> {
    match id {
        "table1" => Ok(table1::run(ctx)),
        "fig5" => Ok(traffic_figs::fig5(ctx)),
        "fig6" => Ok(traffic_figs::fig6(ctx)),
        "fig7" => Ok(traffic_figs::fig7(ctx)),
        "fig8" => Ok(optim_figs::fig8(ctx)),
        "fig9" => Ok(optim_figs::fig9(ctx)),
        "fig10" => Ok(optim_figs::fig10(ctx)),
        "fig11" => Ok(param_figs::fig11(ctx)),
        "fig12" => Ok(param_figs::fig12(ctx)),
        "fig13" => Ok(param_figs::fig13(ctx)),
        "fig14" => Ok(wireless_figs::fig14(ctx)),
        "fig15" => Ok(wireless_figs::fig15(ctx)),
        "fig16" => Ok(wireless_figs::fig16(ctx)),
        "fig17" => Ok(compare_figs::fig17(ctx)),
        "fig18" => Ok(compare_figs::fig18(ctx)),
        "fig19" => Ok(compare_figs::fig19(ctx)),
        "workload_figs" => Ok(workload_figs::workload_figs(ctx)),
        other => Err(WihetError::UnknownExperiment(other.to_string())),
    }
}

//! Figs 5-7: on-chip traffic characterization of CNN training.

use super::common::normalize_to_max;
use super::ctx::Ctx;
use crate::model::cnn::Pass;
use crate::model::TileKind;
use crate::noc::builder::NocKind;
use crate::noc::sim::{NocSim, SimConfig};
use crate::scenario::ModelId;
use crate::traffic::trace::phase_trace;
use crate::util::rng::Rng;

/// Fig 5: per-layer message injection rate, forward + backward, both CNNs,
/// normalized to the hottest layer. Paper shape: conv > pool > FC.
pub fn fig5(ctx: &mut Ctx) -> String {
    let mut out = String::from(
        "Fig 5 — normalized injection rate per layer (paper: conv > pool > FC)\n",
    );
    let sys = ctx.sys.clone();
    for model in ModelId::ALL {
        let tm = ctx.traffic(model.clone());
        for pass in [Pass::Forward, Pass::Backward] {
            let phases = tm.pass_phases(pass);
            let rates: Vec<f64> = phases.iter().map(|p| p.injection_rate(&sys)).collect();
            let norm = normalize_to_max(&rates);
            out.push_str(&format!("\n{model} {pass:?}:\n"));
            for (p, r) in phases.iter().zip(&norm) {
                out.push_str(&format!("  {:<5} {:>6.3} {}\n", p.tag, r, bar(*r)));
            }
        }
    }
    out
}

/// Fig 6: per-layer traffic breakdown — core->MC vs MC->core shares and
/// the many-to-few fraction (paper: 93% LeNet / 89% CDBNet).
pub fn fig6(ctx: &mut Ctx) -> String {
    let mut out = String::from("Fig 6 — traffic breakdown per layer (flit shares)\n");
    let sys = ctx.sys.clone();
    for model in ModelId::ALL {
        let tm = ctx.traffic(model.clone());
        out.push_str(&format!(
            "\n{model}: many-to-few = {:.1}% (paper: {}%)\n",
            100.0 * tm.many_to_few_fraction(&sys),
            if model == ModelId::LeNet { 93 } else { 89 },
        ));
        out.push_str("  layer(pass)   core->MC  MC->core  core-core  MC->core/core->MC\n");
        for p in &tm.phases {
            let c2m = p.core_to_mc_flits(&sys) as f64;
            let m2c = p.mc_to_core_flits(&sys) as f64;
            let cc = p.core_core_flits as f64;
            let tot = c2m + m2c + cc;
            out.push_str(&format!(
                "  {:<5}({:<3})   {:>6.1}%   {:>6.1}%    {:>5.1}%       {:>5.2}x\n",
                p.tag,
                pass_tag(p.pass),
                100.0 * c2m / tot,
                100.0 * m2c / tot,
                100.0 * cc / tot,
                p.asymmetry(&sys),
            ));
        }
    }
    out
}

/// Fig 7: temporal locality raster of MC accesses during LeNet's forward
/// conv (C1) and pool (P1) layers: which tiles talk to MCs in which time
/// bin. The paper's observation: many GPUs transmit simultaneously
/// (waves), demonstrating the need for dedicated CPU-MC links.
pub fn fig7(ctx: &mut Ctx) -> String {
    let sys = ctx.sys.clone();
    let tm = ctx.traffic(ModelId::LeNet);
    let mut out = String::from(
        "Fig 7 — temporal locality of MC accesses (LeNet fwd; '#' = tile sent/received in bin)\n",
    );
    for want in ["C1", "P1"] {
        let phase = tm
            .phases
            .iter()
            .find(|p| p.tag == want && p.pass == Pass::Forward)
            .expect("phase exists");
        let mut rng = Rng::new(ctx.seed);
        let cfg = ctx.trace_cfg();
        let (msgs, dur) = phase_trace(&sys, phase, 0, &cfg, &mut rng);
        // raster: 64 time bins x tiles (sample: all 4 CPUs + 12 GPUs)
        let bins = 64usize;
        let mut tiles: Vec<usize> = sys.cpus();
        tiles.extend(sys.gpus().into_iter().step_by(5).take(12));
        let mut grid = vec![vec![false; bins]; tiles.len()];
        for m in &msgs {
            if let Some(row) = tiles.iter().position(|&t| t == m.src) {
                let b = ((m.inject_at.min(dur - 1)) as usize * bins) / dur as usize;
                grid[row][b] = true;
            }
        }
        out.push_str(&format!("\n{} (duration {} cycles, {} msgs):\n", want, dur, msgs.len()));
        for (row, &tile) in tiles.iter().enumerate() {
            let kind = match sys.tiles[tile] {
                TileKind::Cpu => "CPU",
                TileKind::Gpu => "GPU",
                TileKind::Mc => "MC ",
            };
            let line: String = grid[row]
                .iter()
                .map(|&b| if b { '#' } else { '.' })
                .collect();
            out.push_str(&format!("  {kind}{tile:<3} {line}\n"));
        }
    }
    out.push_str("\n(observe: GPU rows form staggered waves; CPU rows are sparse but overlap GPU bursts — motivating the dedicated CPU-MC wireless channel)\n");
    out
}

fn pass_tag(p: Pass) -> &'static str {
    match p {
        Pass::Forward => "fwd",
        Pass::Backward => "bwd",
    }
}

fn bar(v: f64) -> String {
    "#".repeat((v * 40.0).round() as usize)
}

/// Simulated (not just modeled) injection ordering — used by tests to tie
/// the Fig 5 model to actual simulator behavior.
pub fn simulated_phase_latency(ctx: &mut Ctx, model: ModelId, tag: &str, pass: Pass) -> f64 {
    let sys = ctx.sys.clone();
    let tm = ctx.traffic(model);
    let phase = tm
        .phases
        .iter()
        .find(|p| p.tag == tag && p.pass == pass)
        .expect("phase");
    let mut rng = Rng::new(ctx.seed);
    let cfg = ctx.trace_cfg();
    let (msgs, _) = phase_trace(&sys, phase, 0, &cfg, &mut rng);
    let inst = ctx.instance(NocKind::MeshXy);
    let sim = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
    sim.run(&msgs).latency.mean()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ctx::Effort;

    #[test]
    fn fig5_reports_all_layers() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let s = fig5(&mut ctx);
        for tag in ["C1", "P1", "C2", "P2", "C3", "F1"] {
            assert!(s.contains(tag), "missing {tag}\n{s}");
        }
        assert!(s.contains("cdbnet Backward"));
    }

    #[test]
    fn fig6_many_to_few_near_paper() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let s = fig6(&mut ctx);
        assert!(s.contains("many-to-few"));
        // extract lenet fraction
        let frac = s
            .lines()
            .find(|l| l.contains("lenet: many-to-few"))
            .and_then(|l| l.split('=').nth(1))
            .and_then(|x| x.trim().trim_end_matches(|c: char| !c.is_ascii_digit() && c != '.').split('%').next())
            .and_then(|x| x.trim().parse::<f64>().ok())
            .unwrap();
        assert!((85.0..=99.0).contains(&frac), "lenet m2f {frac}");
    }

    #[test]
    fn fig7_raster_has_waves() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let s = fig7(&mut ctx);
        assert!(s.contains("C1"));
        assert!(s.contains('#'));
        assert!(s.lines().filter(|l| l.contains("GPU")).count() >= 10);
    }
}

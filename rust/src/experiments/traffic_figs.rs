//! Figs 5-7: on-chip traffic characterization of CNN training.

use super::common::normalize_to_max;
use super::ctx::Ctx;
use super::report::{Cell, Report};
use crate::model::cnn::Pass;
use crate::model::TileKind;
use crate::noc::builder::NocKind;
use crate::noc::sim::{NocSim, SimConfig};
use crate::scenario::ModelId;
use crate::traffic::trace::phase_trace;
use crate::util::rng::Rng;

/// Fig 5: per-layer message injection rate, forward + backward, both CNNs,
/// normalized to the hottest layer. Paper shape: conv > pool > FC.
pub fn fig5(ctx: &mut Ctx) -> Report {
    let mut rep =
        Report::new("fig5", "normalized injection rate per layer").with_paper("Fig. 5");
    let mut out = String::from(
        "Fig 5 — normalized injection rate per layer (paper: conv > pool > FC)\n",
    );
    let sys = ctx.sys.clone();
    for model in ModelId::ALL {
        let tm = ctx.traffic(model.clone());
        for pass in [Pass::Forward, Pass::Backward] {
            let phases = tm.pass_phases(pass);
            let rates: Vec<f64> = phases.iter().map(|p| p.injection_rate(&sys)).collect();
            let norm = normalize_to_max(&rates);
            out.push_str(&format!("\n{model} {pass:?}:\n"));
            for (p, r) in phases.iter().zip(&norm) {
                out.push_str(&format!("  {:<5} {:>6.3} {}\n", p.tag, r, bar(*r)));
            }
            rep.series(
                format!("{model}.{}", pass_tag(pass)),
                "injection rate / max layer",
                phases.iter().map(|p| p.tag.clone()).collect(),
                norm,
            );
        }
    }
    rep.set_text(out);
    rep
}

/// Fig 6: per-layer traffic breakdown — core->MC vs MC->core shares and
/// the many-to-few fraction (paper: 93% LeNet / 89% CDBNet).
pub fn fig6(ctx: &mut Ctx) -> Report {
    let mut rep = Report::new("fig6", "traffic breakdown per layer (flit shares)")
        .with_paper("Fig. 6");
    let mut out = String::from("Fig 6 — traffic breakdown per layer (flit shares)\n");
    let sys = ctx.sys.clone();
    for model in ModelId::ALL {
        let tm = ctx.traffic(model.clone());
        let m2f_pct = 100.0 * tm.many_to_few_fraction(&sys);
        let paper_pct = if model == ModelId::LeNet { 93 } else { 89 };
        out.push_str(&format!(
            "\n{model}: many-to-few = {m2f_pct:.1}% (paper: {paper_pct}%)\n",
        ));
        rep.scalar_vs_paper(
            format!("{model}.many_to_few_pct"),
            m2f_pct,
            "%",
            paper_pct as f64,
            format!("paper: {paper_pct}% of traffic is many-to-few"),
        );
        out.push_str("  layer(pass)   core->MC  MC->core  core-core  MC->core/core->MC\n");
        let mut rows = Vec::new();
        for p in &tm.phases {
            let c2m = p.core_to_mc_flits(&sys) as f64;
            let m2c = p.mc_to_core_flits(&sys) as f64;
            let cc = p.core_core_flits as f64;
            let tot = c2m + m2c + cc;
            out.push_str(&format!(
                "  {:<5}({:<3})   {:>6.1}%   {:>6.1}%    {:>5.1}%       {:>5.2}x\n",
                p.tag,
                pass_tag(p.pass),
                100.0 * c2m / tot,
                100.0 * m2c / tot,
                100.0 * cc / tot,
                p.asymmetry(&sys),
            ));
            rows.push(vec![
                Cell::str(p.tag.as_str()),
                Cell::str(pass_tag(p.pass)),
                Cell::num(100.0 * c2m / tot),
                Cell::num(100.0 * m2c / tot),
                Cell::num(100.0 * cc / tot),
                Cell::num(p.asymmetry(&sys)),
            ]);
        }
        rep.table(
            format!("{model}.breakdown"),
            &["layer", "pass", "core_to_mc_pct", "mc_to_core_pct", "core_core_pct", "asymmetry"],
            rows,
        );
    }
    rep.set_text(out);
    rep
}

/// Fig 7: temporal locality raster of MC accesses during LeNet's forward
/// conv (C1) and pool (P1) layers: which tiles talk to MCs in which time
/// bin. The paper's observation: many GPUs transmit simultaneously
/// (waves), demonstrating the need for dedicated CPU-MC links.
pub fn fig7(ctx: &mut Ctx) -> Report {
    let mut rep =
        Report::new("fig7", "temporal locality of MC accesses").with_paper("Fig. 7");
    let sys = ctx.sys.clone();
    let tm = ctx.traffic(ModelId::LeNet);
    let mut out = String::from(
        "Fig 7 — temporal locality of MC accesses (LeNet fwd; '#' = tile sent/received in bin)\n",
    );
    for want in ["C1", "P1"] {
        let phase = tm
            .phases
            .iter()
            .find(|p| p.tag == want && p.pass == Pass::Forward)
            .expect("phase exists");
        let mut rng = Rng::new(ctx.seed);
        let cfg = ctx.trace_cfg();
        let (msgs, dur) = phase_trace(&sys, phase, 0, &cfg, &mut rng);
        // raster: 64 time bins x tiles (sample: all 4 CPUs + 12 GPUs)
        let bins = 64usize;
        let mut tiles: Vec<usize> = sys.cpus();
        tiles.extend(sys.gpus().into_iter().step_by(5).take(12));
        let mut grid = vec![vec![false; bins]; tiles.len()];
        for m in &msgs {
            if let Some(row) = tiles.iter().position(|&t| t == m.src) {
                let b = ((m.inject_at.min(dur - 1)) as usize * bins) / dur as usize;
                grid[row][b] = true;
            }
        }
        out.push_str(&format!("\n{} (duration {} cycles, {} msgs):\n", want, dur, msgs.len()));
        rep.scalar(format!("{want}.duration_cycles"), dur as f64, "cyc");
        rep.scalar(format!("{want}.messages"), msgs.len() as f64, "msgs");
        let mut active_bins = 0usize;
        for (row, &tile) in tiles.iter().enumerate() {
            let kind = match sys.tiles[tile] {
                TileKind::Cpu => "CPU",
                TileKind::Gpu => "GPU",
                TileKind::Mc => "MC ",
            };
            let line: String = grid[row]
                .iter()
                .map(|&b| if b { '#' } else { '.' })
                .collect();
            active_bins += grid[row].iter().filter(|&&b| b).count();
            out.push_str(&format!("  {kind}{tile:<3} {line}\n"));
        }
        rep.scalar(
            format!("{want}.active_bin_fraction"),
            active_bins as f64 / (bins * tiles.len()) as f64,
            "active (tile, bin) cells / all",
        );
    }
    out.push_str("\n(observe: GPU rows form staggered waves; CPU rows are sparse but overlap GPU bursts — motivating the dedicated CPU-MC wireless channel)\n");
    rep.set_text(out);
    rep
}

fn pass_tag(p: Pass) -> &'static str {
    match p {
        Pass::Forward => "fwd",
        Pass::Backward => "bwd",
    }
}

fn bar(v: f64) -> String {
    "#".repeat((v * 40.0).round() as usize)
}

/// Simulated (not just modeled) injection ordering — used by tests to tie
/// the Fig 5 model to actual simulator behavior.
pub fn simulated_phase_latency(ctx: &mut Ctx, model: ModelId, tag: &str, pass: Pass) -> f64 {
    let sys = ctx.sys.clone();
    let tm = ctx.traffic(model);
    let phase = tm
        .phases
        .iter()
        .find(|p| p.tag == tag && p.pass == pass)
        .expect("phase");
    let mut rng = Rng::new(ctx.seed);
    let cfg = ctx.trace_cfg();
    let (msgs, _) = phase_trace(&sys, phase, 0, &cfg, &mut rng);
    let inst = ctx.instance(NocKind::MeshXy);
    let sim = NocSim::new(&sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
    sim.run(&msgs).latency.mean()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ctx::Effort;
    use crate::experiments::report::SectionData;

    #[test]
    fn fig5_reports_all_layers() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let rep = fig5(&mut ctx);
        let s = rep.to_text();
        for tag in ["C1", "P1", "C2", "P2", "C3", "F1"] {
            assert!(s.contains(tag), "missing {tag}\n{s}");
        }
        assert!(s.contains("cdbnet Backward"));
        // structured: one series per (model, pass), normalized to 1.0 max
        assert_eq!(rep.sections.len(), 4);
        for name in ["lenet.fwd", "lenet.bwd", "cdbnet.fwd", "cdbnet.bwd"] {
            let sec = rep.section(name).unwrap_or_else(|| panic!("missing {name}"));
            let SectionData::Series { values, labels, .. } = &sec.data else {
                panic!("{name} is not a series");
            };
            assert_eq!(values.len(), labels.len());
            let max = values.iter().copied().fold(f64::NEG_INFINITY, f64::max);
            assert!((max - 1.0).abs() < 1e-9, "{name} max {max}");
        }
    }

    #[test]
    fn fig6_many_to_few_near_paper() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let rep = fig6(&mut ctx);
        assert!(rep.to_text().contains("many-to-few"));
        // the measured fraction now travels as a typed scalar
        let frac = rep
            .scalars()
            .find(|(n, _)| *n == "lenet.many_to_few_pct")
            .map(|(_, v)| v)
            .unwrap();
        assert!((85.0..=99.0).contains(&frac), "lenet m2f {frac}");
    }

    #[test]
    fn fig7_raster_has_waves() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let rep = fig7(&mut ctx);
        let s = rep.to_text();
        assert!(s.contains("C1"));
        assert!(s.contains('#'));
        assert!(s.lines().filter(|l| l.contains("GPU")).count() >= 10);
        let active = rep
            .scalars()
            .find(|(n, _)| *n == "C1.active_bin_fraction")
            .map(|(_, v)| v)
            .unwrap();
        assert!(active > 0.0);
    }
}

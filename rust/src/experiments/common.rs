//! Shared helpers for the experiment harnesses (normalization, printing).

/// Normalize a series to its first element (the paper plots most results
/// relative to the optimized mesh).
pub fn normalize_to_first(xs: &[f64]) -> Vec<f64> {
    let base = xs.first().copied().unwrap_or(1.0);
    xs.iter().map(|x| x / base.max(1e-30)).collect()
}

/// Normalize to the max element (Fig 5 style).
pub fn normalize_to_max(xs: &[f64]) -> Vec<f64> {
    let m = xs.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    xs.iter().map(|x| x / m.max(1e-30)).collect()
}

/// Render a fixed-width table row.
pub fn row(cells: &[String], widths: &[usize]) -> String {
    cells
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}", w = w))
        .collect::<Vec<_>>()
        .join("  ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalizations() {
        assert_eq!(normalize_to_first(&[2.0, 4.0]), vec![1.0, 2.0]);
        assert_eq!(normalize_to_max(&[2.0, 4.0]), vec![0.5, 1.0]);
        assert!(normalize_to_first(&[]).is_empty());
    }

    #[test]
    fn rows() {
        let r = row(&["a".into(), "bb".into()], &[3, 4]);
        assert_eq!(r, "  a    bb");
    }
}

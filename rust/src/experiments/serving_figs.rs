//! Open-loop serving figures: offered load vs tail latency, mesh vs
//! WiHetNoC (non-paper extension; ROADMAP item 2's serving workload).
//!
//! A two-tenant mix (LeNet + CDBNet) shares one 8x8 chip. Requests
//! arrive on a Poisson clock and are continuously batched
//! (`batch=4;timeout=256`); each dispatched batch runs its model's
//! forward phases through the gated simulator, so consecutive batches
//! and the two tenants *contend* for the same links and MCs. The
//! harness sweeps the offered rate up a x2 ladder and records, per NoC
//! and per step, the delivered throughput and the end-to-end latency
//! tail (with its queueing / network split).
//!
//! **Knee**: the first ladder step whose aggregate e2e p99 exceeds
//! [`KNEE_K`] x the unloaded (step-0) p99 — the classic open-loop
//! saturation signature. The step before it is the last sustainable
//! operating point, and its delivered rate is the NoC's knee
//! throughput.
//!
//! Headline scalars (both guarded, always finite):
//! * `wihetnoc_knee_throughput_x` — WiHetNoC knee throughput over the
//!   optimized mesh's.
//! * `wihetnoc_p99_at_0p7_load_reduction_x` — mesh p99 over WiHetNoC
//!   p99 at the ladder step closest to 70% of the mesh's knee load
//!   (both NoCs see the identical arrival streams there).
//!
//! The full sweep is attached as a `rows.csv` artifact.

use super::ctx::Ctx;
use super::report::{Cell, Report};
use crate::error::WihetError;
use crate::noc::builder::NocKind;
use crate::scenario::ModelId;
use crate::serving::{detect_knee, run_serving, ArrivalProcess, ServingSpec, TenantMix};
use crate::telemetry::LogHistogram;
use crate::traffic::phases::Pass;
use crate::workload::{lower_id, MappingPolicy};

/// Offered-load ladder: multipliers over the base (well under-loaded)
/// rate. x2 steps reach 128x, far past single-chip saturation.
const LOAD_STEPS: [u64; 8] = [1, 2, 4, 8, 16, 32, 64, 128];
/// Knee threshold: first step whose p99 exceeds `KNEE_K` x the unloaded
/// p99.
const KNEE_K: f64 = 2.0;
/// Continuous-batching policy for every step of the sweep.
const BATCH: u32 = 4;
const TIMEOUT: u64 = 256;
/// Requests per tenant per step — 6 batches of 4 when full, enough
/// concurrent batches at the top of the ladder to saturate the chip.
const REQUESTS: u32 = 24;

/// `a / b`, guarded so headline scalars stay finite: a zero or missing
/// denominator yields parity (1.0), never inf/NaN.
fn guarded_ratio(a: f64, b: f64) -> f64 {
    if b <= 0.0 || !b.is_finite() {
        1.0
    } else {
        a / b
    }
}

/// Per-step measurements for one NoC.
struct StepRow {
    multiplier: u64,
    offered_rate_pmc: f64,
    delivered_rate_pmc: f64,
    delivered: u64,
    offered: u64,
    p50: u64,
    p99: u64,
    p999: u64,
    queue_p99: u64,
    net_p99: u64,
}

/// The serving saturation sweep, mesh vs WiHetNoC.
pub fn serving_figs(ctx: &mut Ctx) -> Result<Report, WihetError> {
    let mut rep = Report::new(
        "serving_figs",
        "open-loop serving: offered-load sweep to the tail-latency knee, mesh vs WiHetNoC",
    );
    let mesh = ctx.instance_arc(NocKind::MeshXyYx);
    let wihet = ctx.instance_arc(NocKind::WiHetNoc);
    let mesh_sys = ctx.sys_for(NocKind::MeshXyYx);
    let wihet_sys = ctx.sys_for(NocKind::WiHetNoc);
    let mut cfg = ctx.trace_cfg();
    // 2 NoCs x 8 ladder steps, each a multi-batch concurrent simulation
    cfg.scale = cfg.scale.min(0.02);
    let seed = ctx.seed;

    let mix = TenantMix::new(vec![ModelId::LeNet, ModelId::CdbNet]);
    // Base rate from the mean forward-service window of the mix at the
    // dispatch batch size: mean inter-arrival gap = 4x that window, so
    // step 1x is comfortably under-loaded and the ladder's top is ~32x
    // past back-to-back service. Both platforms are the paper 8x8 chip,
    // so one ladder serves both NoCs and every step compares them under
    // byte-identical arrival streams.
    let mut service_sum = 0u64;
    for t in &mix.tenants {
        let tm = lower_id(&t.model, &MappingPolicy::default(), &mesh_sys, BATCH as usize)?;
        service_sum += tm
            .pass_phases(Pass::Forward)
            .iter()
            .map(|p| cfg.window(p.duration_cycles))
            .sum::<u64>();
    }
    let service = (service_sum / mix.len() as u64).max(1);
    let base_gap = 4 * service;

    let mut out = format!(
        "Serving figs — open-loop saturation sweep on the 8x8 chip (trace scale {:.3})\n\
         (tenants: lenet + cdbnet, poisson arrivals, batch={BATCH} timeout={TIMEOUT} \
         n={REQUESTS}/tenant/step;\n  base mean gap {base_gap} cyc = 4x the mean forward \
         window; knee = first step with p99 > {KNEE_K}x unloaded)\n",
        cfg.scale
    );
    let mut csv = String::from(
        "noc,step,multiplier,offered_rate_pmc,delivered_rate_pmc,p50,p99,p999,queue_p99,net_p99,knee\n",
    );
    let mut table_rows = Vec::new();
    // per-NoC results for the headline scalars
    let mut knee_tp = [0.0f64; 2];
    let mut p99_series = [Vec::new(), Vec::new()];
    let mut offered_series = [Vec::new(), Vec::new()];
    let mut knee_steps = [None, None];

    for (ni, (noc_name, inst, sys)) in
        [("mesh", &mesh, &mesh_sys), ("wihet", &wihet, &wihet_sys)].into_iter().enumerate()
    {
        let mut rows = Vec::with_capacity(LOAD_STEPS.len());
        for &m in &LOAD_STEPS {
            let gap = (base_gap / m).max(1);
            let rate_pmc = (1_000_000 / gap).clamp(1, 1_000_000);
            let spec = ServingSpec {
                arrival: Some(ArrivalProcess::Poisson { rate_pmc, seed }),
                batch: BATCH,
                timeout: TIMEOUT,
                requests: REQUESTS,
            };
            let r = run_serving(sys, inst, &mix, &spec, &cfg)?;
            let mut e2e = LogHistogram::new();
            let mut queue = LogHistogram::new();
            let mut net = LogHistogram::new();
            for t in &r.tenants {
                e2e.merge(&t.e2e);
                queue.merge(&t.queue);
                net.merge(&t.net);
            }
            rows.push(StepRow {
                multiplier: m,
                offered_rate_pmc: (mix.len() as u64 * rate_pmc) as f64,
                delivered_rate_pmc: r.delivered_rate_pmc(),
                delivered: r.delivered,
                offered: r.offered,
                p50: e2e.p50(),
                p99: e2e.p99(),
                p999: e2e.p999(),
                queue_p99: queue.p99(),
                net_p99: net.p99(),
            });
        }

        let p99s: Vec<u64> = rows.iter().map(|r| r.p99).collect();
        let knee = detect_knee(&p99s, KNEE_K);
        knee_steps[ni] = knee;
        // knee throughput = delivered rate at the last sustainable step
        let tp_step = knee.map(|k| k - 1).unwrap_or(rows.len() - 1);
        knee_tp[ni] = rows[tp_step].delivered_rate_pmc;
        p99_series[ni] = rows.iter().map(|r| r.p99 as f64).collect();
        offered_series[ni] = rows.iter().map(|r| r.offered_rate_pmc).collect();

        out.push_str(&format!(
            "\n  {noc_name}: knee {} (sustains {:.3} req/Mcyc at step {})\n  \
             step   x   offered  delivered     p50     p99    p999  q_p99  net_p99\n",
            match knee {
                Some(k) => format!("at step {k} ({}x)", rows[k].multiplier),
                None => "not reached".to_string(),
            },
            knee_tp[ni],
            tp_step,
        ));
        for (si, row) in rows.iter().enumerate() {
            let at_knee = knee == Some(si);
            out.push_str(&format!(
                "  {si:>4} {:>3}  {:>8.3}  {:>9.3}  {:>6}  {:>6}  {:>6}  {:>5}  {:>7}{}\n",
                row.multiplier,
                row.offered_rate_pmc,
                row.delivered_rate_pmc,
                row.p50,
                row.p99,
                row.p999,
                row.queue_p99,
                row.net_p99,
                if at_knee { "  <- knee" } else { "" },
            ));
            csv.push_str(&format!(
                "{noc_name},{si},{},{:.6},{:.6},{},{},{},{},{},{}\n",
                row.multiplier,
                row.offered_rate_pmc,
                row.delivered_rate_pmc,
                row.p50,
                row.p99,
                row.p999,
                row.queue_p99,
                row.net_p99,
                at_knee as u8,
            ));
            table_rows.push(vec![
                Cell::str(noc_name),
                Cell::num(si as f64),
                Cell::num(row.multiplier as f64),
                Cell::num(row.offered_rate_pmc),
                Cell::num(row.delivered_rate_pmc),
                Cell::num(row.p99 as f64),
                Cell::num(row.queue_p99 as f64),
                Cell::num(row.net_p99 as f64),
                Cell::num(at_knee as u8 as f64),
            ]);
        }
        let labels: Vec<String> = rows.iter().map(|r| format!("{}x", r.multiplier)).collect();
        rep.series(format!("{noc_name}_p99_vs_load"), "cycles", labels.clone(), p99_series[ni].clone());
        rep.series(
            format!("{noc_name}_delivered_vs_load"),
            "req/Mcyc",
            labels,
            rows.iter().map(|r| r.delivered_rate_pmc).collect(),
        );
        rep.scalar(
            format!("{noc_name}_knee_step"),
            knee.map(|k| k as f64).unwrap_or(-1.0),
            "step",
        );
        rep.scalar(format!("{noc_name}_knee_throughput_pmc"), knee_tp[ni], "req/Mcyc");
        let last = rows.last().expect("ladder is non-empty");
        rep.scalar(
            format!("{noc_name}_delivered_share_at_peak_pct"),
            100.0 * last.delivered as f64 / last.offered.max(1) as f64,
            "%",
        );
    }

    // headline 1: knee throughput, WiHetNoC over mesh
    let knee_x = guarded_ratio(knee_tp[1], knee_tp[0]);
    rep.scalar("wihetnoc_knee_throughput_x", knee_x, "x");
    // headline 2: p99 at ~70% of the mesh's knee load, mesh over WiHetNoC
    // (same ladder => same offered rate at the chosen step for both NoCs)
    let mesh_tp_step = knee_steps[0].map(|k| k - 1).unwrap_or(LOAD_STEPS.len() - 1);
    let target = 0.7 * offered_series[0][mesh_tp_step];
    let ref_step = (0..LOAD_STEPS.len())
        .min_by(|&a, &b| {
            let da = (offered_series[0][a] - target).abs();
            let db = (offered_series[0][b] - target).abs();
            da.partial_cmp(&db).expect("rates are finite")
        })
        .expect("ladder is non-empty");
    let p99_x = guarded_ratio(p99_series[0][ref_step], p99_series[1][ref_step]);
    rep.scalar("wihetnoc_p99_at_0p7_load_reduction_x", p99_x, "x");

    rep.table(
        "load_sweep",
        &[
            "noc", "step", "multiplier", "offered_pmc", "delivered_pmc", "p99", "queue_p99",
            "net_p99", "knee",
        ],
        table_rows,
    );
    rep.artifact("rows.csv", csv);
    out.push_str(&format!(
        "\n  WiHetNoC sustains {knee_x:.2}x the mesh's knee throughput and cuts e2e p99\n  \
         {p99_x:.2}x at step {ref_step} (~70% of the mesh knee load); full sweep in rows.csv\n"
    ));
    rep.set_text(out);
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ctx::Effort;

    #[test]
    fn guarded_ratio_is_always_finite() {
        assert_eq!(guarded_ratio(3.0, 0.0), 1.0);
        assert_eq!(guarded_ratio(0.0, 0.0), 1.0);
        assert_eq!(guarded_ratio(3.0, f64::NAN), 1.0);
        assert_eq!(guarded_ratio(6.0, 3.0), 2.0);
    }

    /// The full harness at Quick effort: finite headline scalars, a
    /// detected knee on both NoCs, and a complete csv artifact.
    #[test]
    fn sweep_detects_a_knee_on_both_nocs() {
        let mut ctx = Ctx::new(Effort::Quick, 7);
        let rep = serving_figs(&mut ctx).unwrap();
        let get = |name: &str| -> f64 {
            rep.scalars()
                .find(|(n, _)| *n == name)
                .unwrap_or_else(|| panic!("scalar '{name}' missing"))
                .1
        };
        let knee_x = get("wihetnoc_knee_throughput_x");
        let p99_x = get("wihetnoc_p99_at_0p7_load_reduction_x");
        assert!(knee_x.is_finite() && knee_x > 0.0, "knee_x={knee_x}");
        assert!(p99_x.is_finite() && p99_x > 0.0, "p99_x={p99_x}");
        for noc in ["mesh", "wihet"] {
            let step = get(&format!("{noc}_knee_step"));
            assert!(step >= 1.0, "{noc} never crossed the knee (step={step})");
            let tp = get(&format!("{noc}_knee_throughput_pmc"));
            assert!(tp > 0.0, "{noc} knee throughput {tp}");
        }
        // the csv artifact carries the whole sweep
        let csv = &rep
            .artifacts
            .iter()
            .find(|a| a.name == "rows.csv")
            .expect("rows.csv attached")
            .content;
        assert_eq!(csv.lines().count(), 1 + 2 * LOAD_STEPS.len());
        assert!(csv.lines().next().unwrap().starts_with("noc,step,multiplier"));
    }
}

//! Structured experiment reports — the machine-readable artifact every
//! registered [`crate::experiments::registry::Experiment`] returns.
//!
//! A [`Report`] is metadata (id, title, paper anchor) plus typed
//! [`Section`]s — [`SectionData::Scalar`], [`SectionData::Series`], and
//! [`SectionData::Table`] — each carrying units and, where the paper
//! states a number, a [`PaperRef`] with the expected value, so CI and
//! benches can regression-gate paper claims instead of grepping prose.
//! Auxiliary files (e.g. the `workload_figs` comparison CSV) ride along
//! as [`Artifact`] attachments instead of env-var side channels.
//!
//! Three renderers:
//! * [`Report::to_text`] — the human-readable figure, byte-identical to
//!   the pre-registry `String` output (pinned by `tests/report_api.rs`).
//! * [`Report::to_csv`] — one row per data point
//!   (`kind,section,column,row,value,unit`).
//! * [`Report::to_json`] — the full document via [`crate::util::json`]
//!   (schema documented in README §Experiments).
//!
//! [`ArtifactSink`] writes a report to `<out_dir>/<id>.{json,csv,txt}`
//! plus `<id>.<name>` per attachment — the `--out` backend of
//! `wihetnoc experiment`.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

use crate::error::WihetError;
use crate::util::json::Json;

/// A value the paper states for this measurement, kept next to the
/// measured one so downstream tooling can diff reproduction vs claim.
#[derive(Debug, Clone, PartialEq)]
pub struct PaperRef {
    /// The paper's number (for ranges, the midpoint — see `note`).
    pub expected: f64,
    /// The claim verbatim, e.g. "~1.8x latency reduction".
    pub note: String,
}

/// One table cell: a number (JSON number) or a label (JSON string).
#[derive(Debug, Clone, PartialEq)]
pub enum Cell {
    Num(f64),
    Str(String),
}

impl Cell {
    pub fn num(v: f64) -> Cell {
        Cell::Num(v)
    }

    pub fn str(s: impl Into<String>) -> Cell {
        Cell::Str(s.into())
    }

    fn to_json(&self) -> Json {
        match self {
            Cell::Num(v) => num(*v),
            Cell::Str(s) => Json::Str(s.clone()),
        }
    }

    fn to_csv_field(&self) -> String {
        match self {
            Cell::Num(v) => fmt_num(*v),
            Cell::Str(s) => csv_escape(s),
        }
    }
}

/// The payload of one named report section.
#[derive(Debug, Clone, PartialEq)]
pub enum SectionData {
    /// A single measured value.
    Scalar { value: f64, unit: String, paper_ref: Option<PaperRef> },
    /// A labeled 1-D series (one value per x label).
    Series {
        unit: String,
        labels: Vec<String>,
        values: Vec<f64>,
        paper_ref: Option<PaperRef>,
    },
    /// A rectangular table with named columns.
    Table { columns: Vec<String>, rows: Vec<Vec<Cell>> },
}

/// A named piece of a report.
#[derive(Debug, Clone, PartialEq)]
pub struct Section {
    pub name: String,
    pub data: SectionData,
}

/// An auxiliary file carried by the report. `name` is a filename suffix
/// — [`ArtifactSink`] writes it as `<report id>.<name>` and rejects
/// names that would shadow the `.json`/`.csv`/`.txt` renderings or
/// escape the sink directory.
#[derive(Debug, Clone, PartialEq)]
pub struct Artifact {
    pub name: String,
    pub content: String,
}

/// A typed, serializable experiment result.
#[derive(Debug, Clone, PartialEq)]
pub struct Report {
    /// Registry id (`table1`, `fig5`, ... `workload_figs`).
    pub id: String,
    /// One-line human title.
    pub title: String,
    /// Paper anchor (`"Fig. 17"`, `"Table 1"`); empty for non-paper
    /// extensions.
    pub paper: String,
    pub sections: Vec<Section>,
    pub artifacts: Vec<Artifact>,
    /// The preformatted human rendering (what the harness printed before
    /// the registry existed) — returned verbatim by [`Report::to_text`].
    text: String,
}

impl Report {
    pub fn new(id: impl Into<String>, title: impl Into<String>) -> Report {
        Report {
            id: id.into(),
            title: title.into(),
            paper: String::new(),
            sections: Vec::new(),
            artifacts: Vec::new(),
            text: String::new(),
        }
    }

    /// Set the paper anchor (builder-style).
    pub fn with_paper(mut self, paper: impl Into<String>) -> Report {
        self.paper = paper.into();
        self
    }

    /// Attach the human-readable rendering.
    pub fn set_text(&mut self, text: String) {
        self.text = text;
    }

    pub fn scalar(&mut self, name: impl Into<String>, value: f64, unit: impl Into<String>) {
        self.sections.push(Section {
            name: name.into(),
            data: SectionData::Scalar { value, unit: unit.into(), paper_ref: None },
        });
    }

    /// A scalar the paper states a number for.
    pub fn scalar_vs_paper(
        &mut self,
        name: impl Into<String>,
        value: f64,
        unit: impl Into<String>,
        expected: f64,
        note: impl Into<String>,
    ) {
        self.sections.push(Section {
            name: name.into(),
            data: SectionData::Scalar {
                value,
                unit: unit.into(),
                paper_ref: Some(PaperRef { expected, note: note.into() }),
            },
        });
    }

    pub fn series(
        &mut self,
        name: impl Into<String>,
        unit: impl Into<String>,
        labels: Vec<String>,
        values: Vec<f64>,
    ) {
        debug_assert_eq!(labels.len(), values.len(), "series labels/values must align");
        self.sections.push(Section {
            name: name.into(),
            data: SectionData::Series {
                unit: unit.into(),
                labels,
                values,
                paper_ref: None,
            },
        });
    }

    pub fn table(
        &mut self,
        name: impl Into<String>,
        columns: &[&str],
        rows: Vec<Vec<Cell>>,
    ) {
        debug_assert!(
            rows.iter().all(|r| r.len() == columns.len()),
            "table rows must match the column count"
        );
        self.sections.push(Section {
            name: name.into(),
            data: SectionData::Table {
                columns: columns.iter().map(|c| c.to_string()).collect(),
                rows,
            },
        });
    }

    pub fn artifact(&mut self, name: impl Into<String>, content: impl Into<String>) {
        self.artifacts.push(Artifact { name: name.into(), content: content.into() });
    }

    /// Look up a section by name.
    pub fn section(&self, name: &str) -> Option<&Section> {
        self.sections.iter().find(|s| s.name == name)
    }

    /// Every scalar section as `(name, value)` — what the bench
    /// trajectory records next to the wall times.
    pub fn scalars(&self) -> impl Iterator<Item = (&str, f64)> {
        self.sections.iter().filter_map(|s| match &s.data {
            SectionData::Scalar { value, .. } => Some((s.name.as_str(), *value)),
            _ => None,
        })
    }

    /// The human-readable figure — byte-identical to the pre-registry
    /// `String` the harness returned.
    pub fn to_text(&self) -> &str {
        &self.text
    }

    /// One CSV row per data point: `id,kind,section,column,row,value,unit`.
    /// The leading report id keeps rows attributable when several
    /// reports are concatenated (`experiment all --format csv`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("id,kind,section,column,row,value,unit\n");
        let id = csv_escape(&self.id);
        for s in &self.sections {
            let name = csv_escape(&s.name);
            match &s.data {
                SectionData::Scalar { value, unit, .. } => {
                    let _ =
                        writeln!(out, "{id},scalar,{name},,,{},{}", fmt_num(*value), csv_escape(unit));
                }
                SectionData::Series { unit, labels, values, .. } => {
                    for (i, (l, v)) in labels.iter().zip(values).enumerate() {
                        let _ = writeln!(
                            out,
                            "{id},series,{name},{},{i},{},{}",
                            csv_escape(l),
                            fmt_num(*v),
                            csv_escape(unit)
                        );
                    }
                }
                SectionData::Table { columns, rows } => {
                    for (ri, row) in rows.iter().enumerate() {
                        for (col, cell) in columns.iter().zip(row) {
                            let _ = writeln!(
                                out,
                                "{id},table,{name},{},{ri},{},",
                                csv_escape(col),
                                cell.to_csv_field()
                            );
                        }
                    }
                }
            }
        }
        out
    }

    /// The full document (schema 1; see README §Experiments).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("schema".into(), Json::Num(1.0));
        m.insert("id".into(), Json::Str(self.id.clone()));
        m.insert("title".into(), Json::Str(self.title.clone()));
        m.insert(
            "paper".into(),
            if self.paper.is_empty() { Json::Null } else { Json::Str(self.paper.clone()) },
        );
        m.insert(
            "sections".into(),
            Json::Arr(self.sections.iter().map(section_json).collect()),
        );
        m.insert(
            "artifacts".into(),
            Json::Arr(
                self.artifacts
                    .iter()
                    .map(|a| {
                        let mut am = BTreeMap::new();
                        am.insert("name".into(), Json::Str(a.name.clone()));
                        am.insert("content".into(), Json::Str(a.content.clone()));
                        Json::Obj(am)
                    })
                    .collect(),
            ),
        );
        m.insert("text".into(), Json::Str(self.text.clone()));
        Json::Obj(m)
    }
}

fn section_json(s: &Section) -> Json {
    let mut m = BTreeMap::new();
    m.insert("name".into(), Json::Str(s.name.clone()));
    match &s.data {
        SectionData::Scalar { value, unit, paper_ref } => {
            m.insert("kind".into(), Json::Str("scalar".into()));
            m.insert("value".into(), num(*value));
            m.insert("unit".into(), Json::Str(unit.clone()));
            m.insert("paper_ref".into(), paper_ref_json(paper_ref));
        }
        SectionData::Series { unit, labels, values, paper_ref } => {
            m.insert("kind".into(), Json::Str("series".into()));
            m.insert("unit".into(), Json::Str(unit.clone()));
            m.insert(
                "labels".into(),
                Json::Arr(labels.iter().map(|l| Json::Str(l.clone())).collect()),
            );
            m.insert("values".into(), Json::Arr(values.iter().map(|v| num(*v)).collect()));
            m.insert("paper_ref".into(), paper_ref_json(paper_ref));
        }
        SectionData::Table { columns, rows } => {
            m.insert("kind".into(), Json::Str("table".into()));
            m.insert(
                "columns".into(),
                Json::Arr(columns.iter().map(|c| Json::Str(c.clone())).collect()),
            );
            m.insert(
                "rows".into(),
                Json::Arr(
                    rows.iter()
                        .map(|r| Json::Arr(r.iter().map(Cell::to_json).collect()))
                        .collect(),
                ),
            );
        }
    }
    Json::Obj(m)
}

fn paper_ref_json(p: &Option<PaperRef>) -> Json {
    match p {
        None => Json::Null,
        Some(p) => {
            let mut m = BTreeMap::new();
            m.insert("expected".into(), num(p.expected));
            m.insert("note".into(), Json::Str(p.note.clone()));
            Json::Obj(m)
        }
    }
}

/// Non-finite values (a degenerate normalization) serialize as `null`,
/// never as invalid JSON.
fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

fn fmt_num(v: f64) -> String {
    if v.is_finite() {
        Json::Num(v).dump()
    } else {
        String::new()
    }
}

fn csv_escape(s: &str) -> String {
    if s.contains([',', '"', '\n', '\r']) {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Writes reports (and their attachments) under one output directory:
/// `<dir>/<id>.json`, `<dir>/<id>.csv`, `<dir>/<id>.txt`, and
/// `<dir>/<id>.<artifact name>` per attachment.
pub struct ArtifactSink {
    dir: PathBuf,
}

impl ArtifactSink {
    /// Create the sink (and the directory, if missing).
    pub fn new(dir: impl AsRef<Path>) -> Result<ArtifactSink, WihetError> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)?;
        Ok(ArtifactSink { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Write all renderings + attachments; returns the paths written.
    ///
    /// Artifact names are validated first: a name that would shadow a
    /// rendering (`json`/`csv`/`txt`) or escape the sink directory
    /// (path separators, `..`) is a typed error — the "attachments can
    /// never collide" invariant is enforced, not just documented.
    pub fn write(&self, rep: &Report) -> Result<Vec<PathBuf>, WihetError> {
        for a in &rep.artifacts {
            if matches!(a.name.as_str(), "json" | "csv" | "txt")
                || a.name.contains(['/', '\\'])
                || a.name.contains("..")
                || a.name.is_empty()
            {
                return Err(WihetError::InvalidArg(format!(
                    "artifact name '{}' in report '{}' would shadow a rendering or \
                     escape the output directory",
                    a.name, rep.id
                )));
            }
        }
        let mut paths = Vec::new();
        let mut emit = |suffix: &str, content: &str| -> Result<(), WihetError> {
            let path = self.dir.join(format!("{}.{suffix}", rep.id));
            std::fs::write(&path, content)?;
            paths.push(path);
            Ok(())
        };
        emit("json", &(rep.to_json().dump() + "\n"))?;
        emit("csv", &rep.to_csv())?;
        emit("txt", rep.to_text())?;
        for a in &rep.artifacts {
            emit(&a.name, &a.content)?;
        }
        Ok(paths)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn sample() -> Report {
        let mut r = Report::new("figx", "a sample figure").with_paper("Fig. X");
        r.scalar("plain", 2.5, "cyc");
        r.scalar_vs_paper("claimed", 1.76, "x", 1.8, "~1.8x reduction");
        r.series(
            "lat",
            "cyc",
            vec!["C1".into(), "P1".into()],
            vec![10.0, 4.5],
        );
        r.table(
            "rows",
            &["layer", "ratio"],
            vec![
                vec![Cell::str("C1"), Cell::num(0.5)],
                vec![Cell::str("P1, odd\"name"), Cell::num(1.0)],
            ],
        );
        r.artifact("rows.csv", "a,b\n1,2\n");
        r.set_text("the preformatted figure\n".into());
        r
    }

    #[test]
    fn json_document_roundtrips() {
        let r = sample();
        let doc = r.to_json();
        let parsed = json::parse(&doc.dump()).expect("valid json");
        assert_eq!(parsed, doc);
        assert_eq!(parsed.get("id").and_then(Json::as_str), Some("figx"));
        assert_eq!(parsed.get("paper").and_then(Json::as_str), Some("Fig. X"));
        let sections = parsed.get("sections").and_then(Json::as_arr).unwrap();
        assert_eq!(sections.len(), 4);
        let claimed = &sections[1];
        assert_eq!(claimed.get("kind").and_then(Json::as_str), Some("scalar"));
        assert_eq!(
            claimed.get("paper_ref").unwrap().get("expected").and_then(Json::as_f64),
            Some(1.8)
        );
        assert_eq!(
            parsed.get("artifacts").and_then(Json::as_arr).unwrap()[0]
                .get("name")
                .and_then(Json::as_str),
            Some("rows.csv")
        );
    }

    #[test]
    fn text_is_verbatim() {
        assert_eq!(sample().to_text(), "the preformatted figure\n");
    }

    #[test]
    fn csv_rows_cover_every_point() {
        let csv = sample().to_csv();
        let mut lines = csv.lines();
        assert_eq!(lines.next(), Some("id,kind,section,column,row,value,unit"));
        // 2 scalars + 2 series points + 4 table cells
        assert_eq!(csv.lines().count(), 1 + 2 + 2 + 4);
        // every data row is attributable to its report after concatenation
        assert!(csv.lines().skip(1).all(|l| l.starts_with("figx,")));
        assert!(csv.contains("figx,scalar,claimed,,,1.76,x"));
        assert!(csv.contains("figx,series,lat,P1,1,4.5,cyc"));
        // csv-escaped cell
        assert!(csv.contains("\"P1, odd\"\"name\""));
    }

    #[test]
    fn non_finite_values_stay_valid_json() {
        let mut r = Report::new("nan", "degenerate");
        r.scalar("bad", f64::NAN, "");
        r.series("s", "", vec!["a".into()], vec![f64::INFINITY]);
        let doc = r.to_json().dump();
        assert!(json::parse(&doc).is_ok(), "{doc}");
        assert!(doc.contains("null"));
    }

    #[test]
    fn scalars_iterator_and_lookup() {
        let r = sample();
        let all: Vec<(&str, f64)> = r.scalars().collect();
        assert_eq!(all, vec![("plain", 2.5), ("claimed", 1.76)]);
        assert!(r.section("lat").is_some());
        assert!(r.section("missing").is_none());
    }

    #[test]
    fn sink_writes_renderings_and_artifacts() {
        let dir = std::env::temp_dir().join(format!("wihet_sink_{}", std::process::id()));
        let sink = ArtifactSink::new(&dir).unwrap();
        let paths = sink.write(&sample()).unwrap();
        let names: Vec<String> = paths
            .iter()
            .map(|p| p.file_name().unwrap().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["figx.json", "figx.csv", "figx.txt", "figx.rows.csv"]);
        for p in &paths {
            assert!(std::fs::metadata(p).unwrap().len() > 0, "{p:?} is empty");
        }
        let json_text = std::fs::read_to_string(&paths[0]).unwrap();
        assert!(json::parse(json_text.trim()).is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sink_rejects_shadowing_and_escaping_artifact_names() {
        let dir = std::env::temp_dir().join(format!("wihet_sink_bad_{}", std::process::id()));
        let sink = ArtifactSink::new(&dir).unwrap();
        for bad in ["csv", "json", "txt", "sub/rows.csv", "..", "../rows.csv", ""] {
            let mut r = Report::new("figx", "bad artifact");
            r.artifact(bad, "x");
            let err = sink.write(&r).unwrap_err();
            assert!(
                matches!(err, WihetError::InvalidArg(_)),
                "'{bad}' was not rejected"
            );
        }
        // nothing was written for rejected reports
        assert!(std::fs::read_dir(&dir).unwrap().next().is_none());
        std::fs::remove_dir_all(&dir).ok();
    }
}

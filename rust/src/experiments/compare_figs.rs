//! Figs 17-19: head-to-head evaluation — mesh vs HetNoC vs WiHetNoC,
//! per-layer network metrics and full-system execution/EDP.
//!
//! §Perf: trace generation stays serial (it shares one RNG stream per
//! NoC, which pins the report bytes), and the (NoC x layer) simulation
//! matrix fans out over [`par_map`] workers.

use std::sync::Arc;

use super::ctx::Ctx;
use super::report::{Cell, Report};
use crate::coordinator::cosim::cosimulate;
use crate::energy::network::message_edp;
use crate::energy::params::EnergyParams;
use crate::model::cnn::Pass;
use crate::model::SystemConfig;
use crate::noc::builder::{NocInstance, NocKind};
use crate::noc::sim::{Message, NocSim, SimConfig};
use crate::scenario::ModelId;
use crate::traffic::trace::phase_trace;
use crate::util::exec::par_map;
use crate::util::rng::Rng;

struct PerLayer {
    tags: Vec<String>,
    /// Flits per phase (weights for the aggregate means).
    flits: Vec<f64>,
    /// [noc][layer] metric
    latency: Vec<Vec<f64>>,
    edp: Vec<Vec<f64>>,
}

/// Mesh-normalized per-layer ratios + the aggregate means — the
/// structured form of one Fig 17/18 panel.
struct NormPanel {
    het: Vec<f64>,
    wihet: Vec<f64>,
    het_wmean: f64,
    wihet_wmean: f64,
}

/// One (NoC, layer) simulation job, prepared serially and run on any
/// worker.
struct LayerJob {
    inst: Arc<NocInstance>,
    sys: Arc<SystemConfig>,
    msgs: Vec<Message>,
}

/// Simulate every phase of `model` on the three NoCs; returns per-layer
/// latency and message EDP (mesh placement used for the mesh).
fn per_layer(ctx: &mut Ctx, model: ModelId) -> PerLayer {
    let energy = EnergyParams::default();
    let kinds = [NocKind::MeshXyYx, NocKind::HetNoc, NocKind::WiHetNoc];
    let mut tags = Vec::new();
    let mut flits = Vec::new();
    let mut jobs: Vec<LayerJob> = Vec::new();
    let mut layers_per_kind = 0usize;
    for (ni, kind) in kinds.iter().enumerate() {
        let inst = ctx.instance_arc(*kind);
        let sys = ctx.sys_for(*kind);
        let tm = ctx.traffic_on(model.clone(), &sys);
        let cfg = ctx.trace_cfg();
        let mut rng = Rng::new(ctx.seed ^ 17);
        for p in &tm.phases {
            let (msgs, _) = phase_trace(&sys, p, 0, &cfg, &mut rng);
            if ni == 0 {
                tags.push(format!(
                    "{}{}",
                    p.tag,
                    if p.pass == Pass::Forward { "f" } else { "b" }
                ));
                flits.push(p.total_flits(&sys) as f64);
            }
            jobs.push(LayerJob { inst: inst.clone(), sys: sys.clone(), msgs });
        }
        if ni == 0 {
            layers_per_kind = jobs.len();
        }
    }
    let results = par_map(&jobs, |_, j| {
        let rep = NocSim::new(&j.sys, &j.inst.topo, &j.inst.routes, &j.inst.air, SimConfig::default())
            .run(&j.msgs);
        (rep.latency.mean(), message_edp(&j.inst.topo, &rep, &energy))
    });
    let mut latency = vec![Vec::new(); kinds.len()];
    let mut edp = vec![Vec::new(); kinds.len()];
    for (i, (lat, e)) in results.into_iter().enumerate() {
        let ni = i / layers_per_kind.max(1);
        latency[ni].push(lat);
        edp[ni].push(e);
    }
    PerLayer { tags, flits, latency, edp }
}

fn render_per_layer(
    title: &str,
    paper_note: &str,
    pl: &PerLayer,
    metric: impl Fn(&PerLayer, usize, usize) -> f64,
) -> (String, NormPanel) {
    let mut out = format!("{title}\n{paper_note}\n\n  layer    HetNoC/mesh   WiHetNoC/mesh\n");
    let n = pl.tags.len();
    let mut het_sum = 0.0;
    let mut wihet_sum = 0.0;
    let mut het_wsum = 0.0;
    let mut wihet_wsum = 0.0;
    let wtotal: f64 = pl.flits.iter().sum();
    let mut het_norm = Vec::with_capacity(n);
    let mut wihet_norm = Vec::with_capacity(n);
    for li in 0..n {
        let base = metric(pl, 0, li).max(1e-30);
        let het = metric(pl, 1, li) / base;
        let wih = metric(pl, 2, li) / base;
        het_sum += het;
        wihet_sum += wih;
        het_wsum += het * pl.flits[li];
        wihet_wsum += wih * pl.flits[li];
        het_norm.push(het);
        wihet_norm.push(wih);
        out.push_str(&format!("  {:<7}  {:>9.3}     {:>9.3}\n", pl.tags[li], het, wih));
    }
    out.push_str(&format!(
        "  mean     {:>9.3}     {:>9.3}   (unweighted)\n",
        het_sum / n as f64,
        wihet_sum / n as f64
    ));
    out.push_str(&format!(
        "  mean     {:>9.3}     {:>9.3}   (traffic-weighted — the paper's aggregate)\n",
        het_wsum / wtotal,
        wihet_wsum / wtotal
    ));
    let panel = NormPanel {
        het: het_norm,
        wihet: wihet_norm,
        het_wmean: het_wsum / wtotal,
        wihet_wmean: wihet_wsum / wtotal,
    };
    (out, panel)
}

/// Fig 17/18 share everything except the metric and the paper numbers.
fn compare_fig(
    ctx: &mut Ctx,
    id: &str,
    title: &str,
    fig_no: u32,
    metric_name: &str,
    paper_note: &str,
    metric: impl Fn(&PerLayer, usize, usize) -> f64,
    paper_het: f64,
    paper_wihet: f64,
) -> Report {
    let mut rep = Report::new(id, title).with_paper(format!("Fig. {fig_no}"));
    let mut out = String::new();
    let mut wihet_wmeans = Vec::new();
    for model in ModelId::ALL {
        let pl = per_layer(ctx, model.clone());
        let (text, panel) = render_per_layer(
            &format!("Fig {fig_no} ({model}) — normalized network {metric_name} vs mesh"),
            paper_note,
            &pl,
            &metric,
        );
        out.push_str(&text);
        out.push('\n');
        rep.series(
            format!("{model}.hetnoc_over_mesh"),
            format!("{metric_name} / optimized mesh"),
            pl.tags.clone(),
            panel.het,
        );
        rep.series(
            format!("{model}.wihetnoc_over_mesh"),
            format!("{metric_name} / optimized mesh"),
            pl.tags.clone(),
            panel.wihet,
        );
        rep.scalar_vs_paper(
            format!("{model}.hetnoc_mean_weighted"),
            panel.het_wmean,
            format!("{metric_name} / mesh (traffic-weighted)"),
            paper_het,
            format!("paper mean: HetNoC ~{paper_het}"),
        );
        rep.scalar_vs_paper(
            format!("{model}.wihetnoc_mean_weighted"),
            panel.wihet_wmean,
            format!("{metric_name} / mesh (traffic-weighted)"),
            paper_wihet,
            format!("paper mean: WiHetNoC ~{paper_wihet}"),
        );
        wihet_wmeans.push(panel.wihet_wmean);
    }
    // the headline claim: average WiHetNoC reduction over both CNNs
    let avg = wihet_wmeans.iter().sum::<f64>() / wihet_wmeans.len() as f64;
    rep.scalar_vs_paper(
        format!("wihetnoc_{}_reduction_pct", metric_name.to_lowercase()),
        100.0 * (1.0 - avg),
        "% vs optimized mesh",
        100.0 * (1.0 - paper_wihet),
        format!("paper: ~{:.0}% lower {metric_name} than the optimized mesh", 100.0 * (1.0 - paper_wihet)),
    );
    rep.set_text(out);
    rep
}

/// Fig 17: per-layer network latency normalized to the optimized mesh.
/// Paper: HetNoC ~23% lower, WiHetNoC ~42% lower on average.
pub fn fig17(ctx: &mut Ctx) -> Report {
    compare_fig(
        ctx,
        "fig17",
        "per-layer network latency vs the optimized mesh",
        17,
        "latency",
        "paper means: HetNoC ~0.77-0.78, WiHetNoC ~0.58",
        |p, ni, li| p.latency[ni][li],
        0.775,
        0.58,
    )
}

/// Fig 18: per-layer network (message) EDP normalized to the optimized
/// mesh. Paper: HetNoC ~0.56-0.58, WiHetNoC ~0.40-0.42.
pub fn fig18(ctx: &mut Ctx) -> Report {
    compare_fig(
        ctx,
        "fig18",
        "per-layer network EDP vs the optimized mesh",
        18,
        "EDP",
        "paper means: HetNoC ~0.56-0.58, WiHetNoC ~0.40-0.42",
        |p, ni, li| p.edp[ni][li],
        0.57,
        0.41,
    )
}

/// Fig 19: full-system execution time and EDP normalized to the mesh.
/// Paper: HetNoC ~8% faster; WiHetNoC ~13% faster, 25% lower EDP.
pub fn fig19(ctx: &mut Ctx) -> Report {
    let mut rep =
        Report::new("fig19", "full-system execution time & EDP vs the optimized mesh")
            .with_paper("Fig. 19");
    let mut out = String::from(
        "Fig 19 — full-system execution time & EDP (normalized to optimized mesh)\n\n",
    );
    out.push_str("  model    noc        exec    EDP     paper exec / EDP\n");
    let cfg = ctx.trace_cfg();
    let mut rows = Vec::new();
    for model in ModelId::ALL {
        // NOTE: the mesh is evaluated on its own optimized placement, the
        // irregular NoCs on the WiHetNoC placement, exactly as designed.
        // Traffic comes from the Ctx's lowering (mapping- and
        // skip-aware), the same pipeline every other figure consumes.
        let mesh = ctx.instance_arc(NocKind::MeshXyYx);
        let het = ctx.instance_arc(NocKind::HetNoc);
        let wihet = ctx.instance_arc(NocKind::WiHetNoc);
        let mesh_sys = ctx.sys_for(NocKind::MeshXyYx);
        let sys = ctx.sys.clone();
        let mesh_tm = ctx.traffic_on(model.clone(), &mesh_sys);
        let tm = ctx.traffic_on(model.clone(), &sys);
        let mesh_rep = cosimulate(&mesh_sys, &mesh_tm, &[&mesh], &cfg)
            .expect("cosimulate is infallible on in-memory inputs");
        let irr = cosimulate(&sys, &tm, &[&het, &wihet], &cfg)
            .expect("cosimulate is infallible on in-memory inputs");
        let base = &mesh_rep.per_noc[0];
        for (i, name, paper, paper_exec, paper_edp) in [
            (0usize, "HetNoC", "0.92 / 0.85", 0.92, 0.85),
            (1, "WiHetNoC", "0.87 / 0.75", 0.87, 0.75),
        ] {
            let r = &irr.per_noc[i];
            let exec_ratio = r.exec_seconds / base.exec_seconds;
            let edp_ratio = r.edp / base.edp;
            out.push_str(&format!(
                "  {:<8} {:<9} {:>6.3}  {:>6.3}   {}\n",
                model,
                name,
                exec_ratio,
                edp_ratio,
                paper,
            ));
            rows.push(vec![
                Cell::str(model.as_str()),
                Cell::str(name),
                Cell::num(exec_ratio),
                Cell::num(edp_ratio),
                Cell::num(paper_exec),
                Cell::num(paper_edp),
            ]);
            if name == "WiHetNoC" {
                rep.scalar_vs_paper(
                    format!("{model}.wihetnoc_exec_over_mesh"),
                    exec_ratio,
                    "execution time / mesh",
                    paper_exec,
                    "paper: WiHetNoC trains ~13% faster than the optimized mesh",
                );
                rep.scalar_vs_paper(
                    format!("{model}.wihetnoc_edp_over_mesh"),
                    edp_ratio,
                    "full-system EDP / mesh",
                    paper_edp,
                    "paper: WiHetNoC lowers full-system EDP by ~25%",
                );
            }
        }
    }
    rep.table(
        "normalized",
        &["model", "noc", "exec_over_mesh", "edp_over_mesh", "paper_exec", "paper_edp"],
        rows,
    );
    out.push_str("\n(exec < 1 and EDP < 1 with WiHetNoC < HetNoC reproduces the paper's ordering; see EXPERIMENTS.md for the recorded run)\n");
    rep.set_text(out);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ctx::Effort;
    use crate::experiments::report::SectionData;

    #[test]
    fn fig17_18_ordering_wihetnoc_best() {
        // Traffic-weighted aggregates (the paper's means): WiHetNoC must
        // beat the mesh on both latency and message EDP.
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let pl = per_layer(&mut ctx, ModelId::LeNet);
        let wmean = |v: &Vec<f64>| {
            let wt: f64 = pl.flits.iter().sum();
            v.iter().zip(&pl.flits).map(|(x, w)| x * w).sum::<f64>() / wt
        };
        let mesh_lat = wmean(&pl.latency[0]);
        let het_lat = wmean(&pl.latency[1]);
        let wihet_lat = wmean(&pl.latency[2]);
        assert!(wihet_lat < mesh_lat, "wihet {wihet_lat} vs mesh {mesh_lat}");
        assert!(het_lat < mesh_lat, "het {het_lat} vs mesh {mesh_lat}");
        let mesh_edp = wmean(&pl.edp[0]);
        let wihet_edp = wmean(&pl.edp[2]);
        assert!(wihet_edp < mesh_edp, "edp wihet {wihet_edp} vs mesh {mesh_edp}");
    }

    #[test]
    fn per_layer_matrix_is_complete() {
        // every NoC row carries one entry per (layer, pass) phase
        let mut ctx = Ctx::new(Effort::Quick, 2);
        let pl = per_layer(&mut ctx, ModelId::LeNet);
        assert!(!pl.tags.is_empty());
        for row in pl.latency.iter().chain(pl.edp.iter()) {
            assert_eq!(row.len(), pl.tags.len());
        }
    }

    #[test]
    fn fig17_carries_the_latency_series_and_headline_scalar() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let rep = fig17(&mut ctx);
        // acceptance: the mesh-vs-WiHetNoC latency series is structured
        for name in ["lenet.wihetnoc_over_mesh", "cdbnet.wihetnoc_over_mesh"] {
            let sec = rep.section(name).unwrap_or_else(|| panic!("missing {name}"));
            let SectionData::Series { values, labels, .. } = &sec.data else {
                panic!("{name} is not a series");
            };
            assert!(!values.is_empty());
            assert_eq!(values.len(), labels.len());
            assert!(values.iter().all(|v| v.is_finite() && *v > 0.0));
        }
        let (_, reduction) = rep
            .scalars()
            .find(|(n, _)| *n == "wihetnoc_latency_reduction_pct")
            .expect("headline scalar");
        assert!((0.0..100.0).contains(&reduction), "reduction {reduction}%");
    }
}

//! The experiment registry — one [`Experiment`] descriptor per table /
//! figure of the paper's evaluation (plus the non-paper extensions),
//! replacing the old hardcoded id slice and string-returning dispatch.
//!
//! [`ALL`] and [`run`] are views over [`REGISTRY`]: adding an experiment
//! means adding one descriptor, and the CLI menu, the unknown-id error
//! message, the benches, and CI all pick it up. [`run_many`] fans
//! independent experiments out over [`crate::util::exec::par_map`] with
//! results joined in input order — every harness is deterministic given
//! (effort, seed), so reports are byte-identical at any thread count
//! (pinned by `tests/report_api.rs`).

use std::sync::LazyLock;

use super::ctx::{Ctx, Effort};
use super::report::Report;
use super::{
    compare_figs, design_figs, hotspot_figs, optim_figs, param_figs, resilience_figs, scale_figs,
    serving_figs, table1, traffic_figs, wireless_figs, workload_figs,
};
use crate::error::WihetError;
use crate::util::exec::{par_map_threads, thread_count};

/// A registered experiment: identity, provenance, and its harness.
pub struct Experiment {
    /// CLI id (`table1`, `fig5`, ... `workload_figs`).
    pub id: &'static str,
    /// One-line human title (shown by `wihetnoc list`).
    pub title: &'static str,
    /// Paper anchor (`"Fig. 17"`); empty for non-paper extensions.
    pub paper: &'static str,
    /// The lightest [`Effort`] at which the harness produces a
    /// meaningful report (all current harnesses are CI-runnable at
    /// `Quick`; heavier future experiments can demand `Full`).
    pub min_effort: Effort,
    /// The harness itself.
    pub run: fn(&mut Ctx) -> Result<Report, WihetError>,
}

impl Experiment {
    /// Whether `effort` meets this experiment's floor ([`run`] and
    /// [`run_many`] reject dispatches below it).
    pub fn runnable_at(&self, effort: Effort) -> bool {
        !(self.min_effort == Effort::Full && effort == Effort::Quick)
    }
}

/// Every experiment, in paper order, then the non-paper extensions.
pub const REGISTRY: &[Experiment] = &[
    Experiment {
        id: "table1",
        title: "layer configurations of LeNet and CDBNet",
        paper: "Table 1",
        min_effort: Effort::Quick,
        run: |ctx| Ok(table1::run(ctx)),
    },
    Experiment {
        id: "fig5",
        title: "normalized injection rate per layer",
        paper: "Fig. 5",
        min_effort: Effort::Quick,
        run: |ctx| Ok(traffic_figs::fig5(ctx)),
    },
    Experiment {
        id: "fig6",
        title: "traffic breakdown per layer (many-to-few shares)",
        paper: "Fig. 6",
        min_effort: Effort::Quick,
        run: |ctx| Ok(traffic_figs::fig6(ctx)),
    },
    Experiment {
        id: "fig7",
        title: "temporal locality of MC accesses",
        paper: "Fig. 7",
        min_effort: Effort::Quick,
        run: |ctx| Ok(traffic_figs::fig7(ctx)),
    },
    Experiment {
        id: "fig8",
        title: "optimized mesh link-utilization bottlenecks",
        paper: "Fig. 8",
        min_effort: Effort::Quick,
        run: |ctx| Ok(optim_figs::fig8(ctx)),
    },
    Experiment {
        id: "fig9",
        title: "hop count & link-utilization spread, mesh vs WiHetNoC",
        paper: "Fig. 9",
        min_effort: Effort::Quick,
        run: |ctx| Ok(optim_figs::fig9(ctx)),
    },
    Experiment {
        id: "fig10",
        title: "AMOSA candidate fronts per k_max",
        paper: "Fig. 10",
        min_effort: Effort::Quick,
        run: |ctx| Ok(optim_figs::fig10(ctx)),
    },
    Experiment {
        id: "fig11",
        title: "network EDP vs router port bound k_max",
        paper: "Fig. 11",
        min_effort: Effort::Quick,
        run: |ctx| Ok(param_figs::fig11(ctx)),
    },
    Experiment {
        id: "fig12",
        title: "EDP & wireless utilization vs WI count",
        paper: "Fig. 12",
        min_effort: Effort::Quick,
        run: |ctx| Ok(param_figs::fig12(ctx)),
    },
    Experiment {
        id: "fig13",
        title: "EDP & wireless utilization vs channel count",
        paper: "Fig. 13",
        min_effort: Effort::Quick,
        run: |ctx| Ok(param_figs::fig13(ctx)),
    },
    Experiment {
        id: "fig14",
        title: "CPU-MC latency & saturation throughput, mesh vs WiHetNoC",
        paper: "Fig. 14",
        min_effort: Effort::Quick,
        run: |ctx| Ok(wireless_figs::fig14(ctx)),
    },
    Experiment {
        id: "fig15",
        title: "CDF of link utilizations, mesh vs WiHetNoC",
        paper: "Fig. 15",
        min_effort: Effort::Quick,
        run: |ctx| Ok(wireless_figs::fig15(ctx)),
    },
    Experiment {
        id: "fig16",
        title: "WI utilization asymmetry per layer",
        paper: "Fig. 16",
        min_effort: Effort::Quick,
        run: |ctx| Ok(wireless_figs::fig16(ctx)),
    },
    Experiment {
        id: "fig17",
        title: "per-layer network latency vs the optimized mesh",
        paper: "Fig. 17",
        min_effort: Effort::Quick,
        run: |ctx| Ok(compare_figs::fig17(ctx)),
    },
    Experiment {
        id: "fig18",
        title: "per-layer network EDP vs the optimized mesh",
        paper: "Fig. 18",
        min_effort: Effort::Quick,
        run: |ctx| Ok(compare_figs::fig18(ctx)),
    },
    Experiment {
        id: "fig19",
        title: "full-system execution time & EDP vs the optimized mesh",
        paper: "Fig. 19",
        min_effort: Effort::Quick,
        run: |ctx| Ok(compare_figs::fig19(ctx)),
    },
    Experiment {
        id: "workload_figs",
        title: "mesh vs WiHetNoC on non-paper workloads x schedules",
        paper: "",
        min_effort: Effort::Quick,
        run: |ctx| Ok(workload_figs::workload_figs(ctx)),
    },
    Experiment {
        id: "scale_figs",
        title: "multi-chip data-parallel scaling: speedup & comm overhead vs chips",
        paper: "",
        min_effort: Effort::Quick,
        run: |ctx| Ok(scale_figs::scale_figs(ctx)),
    },
    Experiment {
        id: "resilience_figs",
        title: "graceful degradation under link faults & jammed channels, mesh vs WiHetNoC",
        paper: "",
        min_effort: Effort::Quick,
        run: |ctx| Ok(resilience_figs::resilience_figs(ctx)),
    },
    Experiment {
        id: "hotspot_figs",
        title: "link-utilization heatmap & tail latency (p50/p99/p999), mesh vs WiHetNoC",
        paper: "Sec. 3",
        min_effort: Effort::Quick,
        run: |ctx| Ok(hotspot_figs::hotspot_figs(ctx)),
    },
    Experiment {
        id: "design_figs",
        title: "AMOSA convergence, Pareto snapshots & design-search eval attribution",
        paper: "",
        min_effort: Effort::Quick,
        run: |ctx| Ok(design_figs::design_figs(ctx)),
    },
    Experiment {
        id: "serving_figs",
        title: "open-loop serving: offered-load sweep to the tail-latency knee, mesh vs WiHetNoC",
        paper: "",
        min_effort: Effort::Quick,
        run: serving_figs::serving_figs,
    },
];

/// All experiment ids, in registry order — a view over [`REGISTRY`].
pub static ALL: LazyLock<Vec<&'static str>> =
    LazyLock::new(|| REGISTRY.iter().map(|e| e.id).collect());

/// All experiment ids as a slice (registry order).
pub fn ids() -> &'static [&'static str] {
    ALL.as_slice()
}

/// Look up a registered experiment by id.
pub fn find(id: &str) -> Option<&'static Experiment> {
    REGISTRY.iter().find(|e| e.id == id)
}

/// Dispatch one experiment by id. Unknown ids are a typed
/// [`WihetError::UnknownExperiment`] (whose message lists every
/// registered id), never a panic; an effort below the experiment's
/// [`Experiment::min_effort`] floor is an [`WihetError::InvalidArg`].
pub fn run(id: &str, ctx: &mut Ctx) -> Result<Report, WihetError> {
    match find(id) {
        Some(e) if !e.runnable_at(ctx.effort) => Err(WihetError::InvalidArg(format!(
            "experiment '{}' requires --effort {} or higher (got {})",
            e.id, e.min_effort, ctx.effort
        ))),
        Some(e) => (e.run)(ctx),
        None => Err(WihetError::UnknownExperiment(id.to_string())),
    }
}

/// Run several experiments, fanning out over the default worker pool
/// (`WIHETNOC_THREADS`). Reports come back in input order.
///
/// Unknown ids fail up front, before any experiment runs. Each job gets
/// its own [`Ctx`] built from `(effort, seed)` — experiments never share
/// mutable state across workers, and every harness is deterministic
/// given its context, so the reports are byte-identical to a serial run.
pub fn run_many(ids: &[&str], effort: Effort, seed: u64) -> Result<Vec<Report>, WihetError> {
    run_many_threads(thread_count(), ids, effort, seed)
}

/// [`run_many`] with an explicit worker count — the entry point the
/// determinism tests drive with 1, 2, and 8 workers.
pub fn run_many_threads(
    threads: usize,
    ids: &[&str],
    effort: Effort,
    seed: u64,
) -> Result<Vec<Report>, WihetError> {
    let exps: Vec<&'static Experiment> = ids
        .iter()
        .map(|id| {
            let e = find(id).ok_or_else(|| WihetError::UnknownExperiment(id.to_string()))?;
            if !e.runnable_at(effort) {
                return Err(WihetError::InvalidArg(format!(
                    "experiment '{}' requires --effort {} or higher (got {effort})",
                    e.id, e.min_effort
                )));
            }
            Ok(e)
        })
        .collect::<Result<_, _>>()?;
    par_map_threads(threads, &exps, |_, e| {
        let mut ctx = Ctx::new(effort, seed);
        (e.run)(&mut ctx)
    })
    .into_iter()
    .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_is_a_view_over_the_registry() {
        assert_eq!(ALL.len(), REGISTRY.len());
        assert_eq!(ALL.len(), 22);
        for (id, e) in ALL.iter().zip(REGISTRY) {
            assert_eq!(*id, e.id);
        }
        // ids are unique
        let mut sorted = ALL.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), ALL.len());
    }

    #[test]
    fn min_effort_floor_is_enforced() {
        // every current experiment is CI-runnable at Quick ...
        for e in REGISTRY {
            assert!(e.runnable_at(Effort::Quick), "{} not runnable at quick", e.id);
            assert!(e.runnable_at(Effort::Full));
        }
        // ... and a Full-floor experiment would be rejected at Quick
        let heavy = Experiment {
            id: "heavy",
            title: "synthetic",
            paper: "",
            min_effort: Effort::Full,
            run: |_| unreachable!("never dispatched below its floor"),
        };
        assert!(!heavy.runnable_at(Effort::Quick));
        assert!(heavy.runnable_at(Effort::Full));
    }

    #[test]
    fn paper_anchors_and_titles_present() {
        for e in REGISTRY {
            assert!(!e.title.is_empty(), "{} has no title", e.id);
            if e.id.starts_with("fig") || e.id.starts_with("table") {
                assert!(!e.paper.is_empty(), "{} has no paper anchor", e.id);
            }
        }
        assert_eq!(find("workload_figs").unwrap().paper, "");
    }

    #[test]
    fn unknown_id_is_typed_and_lists_the_menu() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let err = run("figg17", &mut ctx).unwrap_err();
        assert!(matches!(err, WihetError::UnknownExperiment(_)));
        let msg = err.to_string();
        // satellite: the message enumerates every registered id
        for id in ids() {
            assert!(msg.contains(id), "error does not list '{id}': {msg}");
        }
        // run_many validates before doing any work
        let err = run_many(&["table1", "nope"], Effort::Quick, 1).unwrap_err();
        assert!(err.to_string().contains("nope"));
    }
}

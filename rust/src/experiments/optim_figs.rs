//! Figs 8-10: mesh link-utilization characterization and the WiHetNoC
//! wireline design-space exploration.

use super::ctx::Ctx;
use super::report::{Cell, Report};
use crate::noc::analysis::analyze;
use crate::noc::routing::RouteSet;
use crate::noc::topology::Topology;
use crate::optim::amosa::{Amosa, AmosaConfig};
use crate::optim::linkplace::LinkPlacement;

/// Fig 8: link utilizations of the optimized mesh under the scenario's
/// design workload (paper: LeNet), normalized to the mean. Paper:
/// MC-adjacent links reach ~6-7x mean.
pub fn fig8(ctx: &mut Ctx) -> Report {
    let mut rep = Report::new("fig8", "optimized mesh link-utilization bottlenecks")
        .with_paper("Fig. 8");
    let model = ctx.model();
    let sys = ctx.mesh_sys();
    let tm = ctx.traffic_on(model.clone(), &sys);
    let fij = tm.fij(&sys);
    let topo = Topology::mesh(&sys);
    let a = analyze(&topo, &fij);
    let mean = a.u_mean.max(1e-30);

    let mut out = format!(
        "Fig 8 — optimized mesh link utilization / mean ({model}). Paper: MC links 6-7x mean\n\n",
    );
    // per-tile kind map + hottest links
    let w = sys.width;
    let h = sys.height();
    out.push_str("  tile map (C=CPU, M=MC, .=GPU):\n");
    for r in 0..h {
        out.push_str("    ");
        for c in 0..w {
            let ch = match sys.tiles[r * w + c] {
                crate::model::TileKind::Cpu => 'C',
                crate::model::TileKind::Mc => 'M',
                crate::model::TileKind::Gpu => '.',
            };
            out.push(ch);
        }
        out.push('\n');
    }
    let mut hot: Vec<(usize, f64)> = a
        .link_util
        .iter()
        .enumerate()
        .map(|(i, &u)| (i, u / mean))
        .collect();
    hot.sort_by(|x, y| y.1.partial_cmp(&x.1).unwrap());
    out.push_str("\n  hottest links (utilization / mean):\n");
    let mcs = sys.mcs();
    let mut rows = Vec::new();
    for &(li, ratio) in hot.iter().take(10) {
        let l = &topo.links[li];
        let touches_mc = mcs.contains(&l.a) || mcs.contains(&l.b);
        out.push_str(&format!(
            "    {:>2}-{:<2}  {:>5.2}x {}\n",
            l.a,
            l.b,
            ratio,
            if touches_mc { "(MC link)" } else { "" }
        ));
        rows.push(vec![
            Cell::str(format!("{}-{}", l.a, l.b)),
            Cell::num(ratio),
            Cell::str(if touches_mc { "mc" } else { "core" }),
        ]);
    }
    rep.table("hottest_links", &["link", "util_over_mean", "kind"], rows);
    let max_mc_ratio = hot
        .iter()
        .filter(|&&(li, _)| {
            let l = &topo.links[li];
            mcs.contains(&l.a) || mcs.contains(&l.b)
        })
        .map(|&(_, r)| r)
        .fold(0.0, f64::max);
    let bottlenecks = hot.iter().filter(|&&(_, r)| r >= 2.0).count();
    out.push_str(&format!(
        "\n  max MC-adjacent link = {:.1}x mean (paper: up to 6-7x); bottlenecks >2x: {}/{} links\n",
        max_mc_ratio,
        bottlenecks,
        topo.links.len()
    ));
    rep.scalar_vs_paper(
        "max_mc_link_over_mean",
        max_mc_ratio,
        "x mean utilization",
        6.5,
        "paper: MC-adjacent links reach ~6-7x the mean",
    );
    rep.scalar("bottleneck_links_over_2x", bottlenecks as f64, "links");
    rep.scalar("total_links", topo.links.len() as f64, "links");
    rep.set_text(out);
    rep
}

/// Fig 9: traffic-weighted hop count and σ(link util) for the optimized
/// mesh (XY, XY+YX) vs WiHetNoC wireline candidates (k_max 4..7).
/// Paper: mesh is >= 2x worse on both.
pub fn fig9(ctx: &mut Ctx) -> Report {
    let mut rep = Report::new("fig9", "hop count & link-utilization spread, mesh vs WiHetNoC")
        .with_paper("Fig. 9");
    let model = ctx.model();
    let mesh_sys = ctx.mesh_sys();
    let mesh_tm = ctx.traffic_on(model.clone(), &mesh_sys);
    let mesh_fij = mesh_tm.fij(&mesh_sys);
    let mesh = Topology::mesh(&mesh_sys);
    let a_mesh = analyze(&mesh, &mesh_fij);

    // XY+YX splits each pair's flow across both minimal routes; model as
    // the average of XY-tree and YX-tree utilizations (same twhc).
    let sigma_xyyx = {
        let a = analyze(&mesh, &mesh_fij);
        // approximation: balancing halves the deviation of the skewed
        // component; measured via simulation in fig15
        a.u_std * 0.85
    };

    let fij = ctx.fij(model);
    let mut out = String::from(
        "Fig 9 — traffic-weighted hop count & σ(U): mesh vs WiHetNoC candidates\n\n",
    );
    out.push_str("  config          twhc (flits*hops/cyc)   sigma(U)\n");
    out.push_str(&format!(
        "  mesh XY         {:>10.3}              {:>8.4}\n",
        a_mesh.twhc, a_mesh.u_std
    ));
    out.push_str(&format!(
        "  mesh XY+YX      {:>10.3}              {:>8.4}\n",
        a_mesh.twhc, sigma_xyyx
    ));
    let mut rows = vec![
        vec![Cell::str("mesh_xy"), Cell::num(a_mesh.twhc), Cell::num(a_mesh.u_std)],
        vec![Cell::str("mesh_xy_yx"), Cell::num(a_mesh.twhc), Cell::num(sigma_xyyx)],
    ];
    let mut best_ratio = f64::INFINITY;
    // the four per-k_max AMOSA candidates are independent — design any
    // missing ones in parallel before walking the (now cached) set
    ctx.wirelines(&[4, 5, 6, 7]);
    for k_max in 4..=7 {
        let topo = ctx.wireline(k_max);
        let a = analyze(&topo, &fij);
        best_ratio = best_ratio.min(a.twhc / a_mesh.twhc);
        out.push_str(&format!(
            "  WiHetNoC k_max={k_max} {:>9.3}              {:>8.4}\n",
            a.twhc, a.u_std
        ));
        rows.push(vec![
            Cell::str(format!("wihetnoc_kmax{k_max}")),
            Cell::num(a.twhc),
            Cell::num(a.u_std),
        ]);
    }
    rep.table("objectives", &["config", "twhc", "sigma_u"], rows);
    out.push_str(&format!(
        "\n  mesh/WiHetNoC twhc ratio >= {:.2}x (paper: >= 2x)\n",
        1.0 / best_ratio
    ));
    rep.scalar_vs_paper(
        "mesh_over_wihetnoc_twhc",
        1.0 / best_ratio,
        "x",
        2.0,
        "paper: the mesh is >= 2x worse on traffic-weighted hop count",
    );
    rep.set_text(out);
    rep
}

/// Fig 10: the AMOSA candidate fronts (Ū, σ) per k_max, normalized to the
/// final WiHetNoC configuration. Paper: both objectives fall as k_max
/// grows, with diminishing returns by 7.
pub fn fig10(ctx: &mut Ctx) -> Report {
    let mut rep =
        Report::new("fig10", "AMOSA candidate fronts per k_max").with_paper("Fig. 10");
    let model = ctx.model();
    let fij = ctx.fij(model);
    let sys = ctx.sys.clone();
    let num_links = Topology::mesh(&sys).links.len();
    let mut out = String::from(
        "Fig 10 — AMOSA candidate fronts per k_max (normalized to k_max=6 knee)\n\n",
    );
    // reference: the k_max=6 balanced knee
    let ref_topo = ctx.wireline(6);
    let ref_a = analyze(&ref_topo, &fij);

    let mut rows = Vec::new();
    let mut cfg = ctx.design_cfg();
    for k_max in 4..=7 {
        cfg.seed = ctx.seed.wrapping_add(100 + k_max as u64);
        let problem = LinkPlacement::new(&sys, &fij, num_links, k_max);
        let mut amosa_cfg: AmosaConfig = cfg.amosa.clone();
        amosa_cfg.seed = cfg.seed;
        let mut opt = Amosa::new(&problem, amosa_cfg);
        opt.run();
        out.push_str(&format!("  k_max={k_max} front ({} candidates):\n", opt.archive.len()));
        let mut pts: Vec<(f64, f64)> = opt
            .archive
            .iter()
            .map(|m| (m.obj[0] / ref_a.u_mean, m.obj[1] / ref_a.u_std.max(1e-30)))
            .collect();
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        for (u, s) in pts.iter().take(6) {
            out.push_str(&format!("    U={u:.3}  sigma={s:.3}\n"));
            rows.push(vec![Cell::num(k_max as f64), Cell::num(*u), Cell::num(*s)]);
        }
    }
    rep.table("fronts", &["k_max", "u_norm", "sigma_norm"], rows);
    out.push_str("\n(expect: fronts shift toward the origin as k_max grows 4 -> 6, small gain 6 -> 7)\n");
    rep.set_text(out);
    rep
}

/// Analytic helper shared with tests: (twhc, σ) of an instance's wireline
/// topology under the LeNet fij.
pub fn wireline_objectives(ctx: &mut Ctx, k_max: usize) -> (f64, f64) {
    let model = ctx.model();
    let fij = ctx.fij(model);
    let topo = ctx.wireline(k_max);
    let a = analyze(&topo, &fij);
    (a.twhc, a.u_std)
}

/// Mesh XY objectives on the mesh placement (baseline for ratios).
pub fn mesh_objectives(ctx: &mut Ctx) -> (f64, f64) {
    let model = ctx.model();
    let sys = ctx.mesh_sys();
    let tm = ctx.traffic_on(model, &sys);
    let fij = tm.fij(&sys);
    let a = analyze(&Topology::mesh(&sys), &fij);
    (a.twhc, a.u_std)
}

/// Routes for the mesh instance (referenced by property tests).
pub fn mesh_routes(ctx: &mut Ctx) -> RouteSet {
    let sys = ctx.mesh_sys();
    RouteSet::xy(&sys, &Topology::mesh(&sys))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ctx::Effort;

    #[test]
    fn fig8_finds_mc_bottlenecks() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let rep = fig8(&mut ctx);
        assert!(rep.to_text().contains("MC link"), "{}", rep.to_text());
        // the max MC ratio travels as a typed scalar with the paper claim
        let ratio = rep
            .scalars()
            .find(|(n, _)| *n == "max_mc_link_over_mean")
            .map(|(_, v)| v)
            .unwrap();
        assert!(ratio > 2.0, "MC links only {ratio}x mean");
    }

    #[test]
    fn fig9_wihetnoc_beats_mesh_twhc() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let (mesh_twhc, mesh_sigma) = mesh_objectives(&mut ctx);
        let (w_twhc, w_sigma) = wireline_objectives(&mut ctx, 6);
        assert!(w_twhc < mesh_twhc, "twhc {w_twhc} vs mesh {mesh_twhc}");
        assert!(w_sigma < mesh_sigma, "sigma {w_sigma} vs mesh {mesh_sigma}");
    }
}

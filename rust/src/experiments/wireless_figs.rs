//! Figs 14-16: WiHetNoC network characteristics vs the optimized mesh.
//!
//! §Perf: the Fig 14 saturation ladder evaluates its injection-rate
//! points in thread-count-sized chunks through [`par_map`] — the chunk
//! boundary preserves the serial early-exit semantics (the reported
//! saturation point is the last stable rate before the first unstable
//! one), so results are identical at any `WIHETNOC_THREADS`.

use std::sync::Arc;

use super::ctx::Ctx;
use super::param_figs::sim_iteration;
use super::report::{Cell, Report};
use crate::model::cnn::Pass;
use crate::model::SystemConfig;
use crate::noc::builder::{NocInstance, NocKind};
use crate::noc::sim::{Message, NocSim, SimConfig, SimReport};
use crate::scenario::ModelId;
use crate::traffic::trace::{phase_trace, training_trace};
use crate::util::exec::{par_map, thread_count};
use crate::util::rng::Rng;
use crate::util::stats;

/// Simulate one design-workload iteration on a cached instance, using
/// the placement that instance was designed for.
fn sim_kind(ctx: &mut Ctx, kind: NocKind) -> SimReport {
    let (inst, sys, trace) = kind_setup(ctx, kind);
    run_on(&sys, &inst, &trace)
}

/// Cached instance + its placement + the design-iteration trace.
fn kind_setup(ctx: &mut Ctx, kind: NocKind) -> (Arc<NocInstance>, Arc<SystemConfig>, Vec<Message>) {
    let model = ctx.model();
    let inst = ctx.instance_arc(kind);
    let sys = ctx.sys_for(kind);
    let tm = ctx.traffic_on(model, &sys);
    let cfg = ctx.trace_cfg();
    let (trace, _) = training_trace(&sys, &tm.phases, &cfg);
    (inst, sys, trace)
}

fn run_on(sys: &SystemConfig, inst: &NocInstance, trace: &[Message]) -> SimReport {
    NocSim::new(sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default()).run(trace)
}

/// `trace` with injection times compressed by `rate`.
fn compress(trace: &[Message], rate: f64) -> Vec<Message> {
    trace
        .iter()
        .map(|m| Message { inject_at: (m.inject_at as f64 / rate) as u64, ..*m })
        .collect()
}

/// Saturation throughput (Fig 14 methodology): compress the trace's
/// injection window by increasing rate multipliers until mean latency
/// exceeds `LAT_BOUND`; the network throughput is the delivered flits/
/// cycle of the last stable point.
pub fn saturation_throughput(ctx: &mut Ctx, kind: NocKind) -> (f64, f64) {
    const LAT_BOUND: f64 = 300.0;
    let (inst, sys, trace) = kind_setup(ctx, kind);
    let rates: Vec<f64> = (1..=32).map(|step| 0.25 * step as f64).collect();
    let mut best = (0.0f64, 0.0f64); // (throughput, rate)
    for chunk in rates.chunks(thread_count().max(1)) {
        let reps = par_map(chunk, |_, &rate| run_on(&sys, &inst, &compress(&trace, rate)));
        let mut saturated = false;
        for (&rate, rep) in chunk.iter().zip(&reps) {
            if rep.latency.mean() > LAT_BOUND {
                saturated = true;
                break;
            }
            best = (rep.throughput(), rate);
        }
        if saturated {
            break;
        }
    }
    best
}

/// Simulate one design-workload iteration with injection times
/// compressed by `rate`.
pub fn sim_at_rate(ctx: &mut Ctx, kind: NocKind, rate: f64) -> SimReport {
    let (inst, sys, trace) = kind_setup(ctx, kind);
    run_on(&sys, &inst, &compress(&trace, rate))
}

/// Fig 14: CPU-MC latency and overall throughput, optimized mesh vs
/// WiHetNoC. Paper: ~1.8x latency reduction, ~2.2x throughput.
pub fn fig14(ctx: &mut Ctx) -> Report {
    let mut rep =
        Report::new("fig14", "CPU-MC latency & saturation throughput, mesh vs WiHetNoC")
            .with_paper("Fig. 14");
    let (mesh_thr, mesh_rate) = saturation_throughput(ctx, NocKind::MeshXyYx);
    let (wihet_thr, wihet_rate) = saturation_throughput(ctx, NocKind::WiHetNoc);
    // Two operating points: the workload's nominal rate (x1 — where the
    // CNN actually drives the chip, and where the mesh sits at its
    // saturation edge), and 75% of the common sustainable load (finite-
    // queue regime comparable to the paper's reported latencies).
    let nominal = 1.0;
    let light = (mesh_rate.min(wihet_rate) * 0.75).max(0.25);
    // the four operating-point sims are independent: fan them out
    let points = [
        (NocKind::MeshXyYx, nominal),
        (NocKind::WiHetNoc, nominal),
        (NocKind::MeshXyYx, light),
        (NocKind::WiHetNoc, light),
    ];
    let setups: Vec<_> = points
        .iter()
        .map(|&(kind, rate)| {
            let (inst, sys, trace) = kind_setup(ctx, kind);
            (inst, sys, trace, rate)
        })
        .collect();
    let mut reps = par_map(&setups, |_, (inst, sys, trace, rate)| {
        run_on(sys, inst, &compress(trace, *rate))
    })
    .into_iter();
    let mesh_nom = reps.next().expect("four operating points");
    let wihet_nom = reps.next().expect("four operating points");
    let mesh_lt = reps.next().expect("four operating points");
    let wihet_lt = reps.next().expect("four operating points");

    let thr_ratio = wihet_thr / mesh_thr.max(1e-9);
    let r = |a: f64, b: f64| a / b.max(1e-9);
    let text = format!(
        "Fig 14 — CPU-MC latency & throughput: optimized mesh vs WiHetNoC\n\n\
         \x20 metric                          mesh      WiHetNoC   ratio    paper\n\
         \x20 at nominal CNN load (x1.00):\n\
         \x20   CPU-MC latency (cyc)      {:>8.2}  {:>10.2}   {:>5.2}x   lower\n\
         \x20   overall latency (cyc)     {:>8.2}  {:>10.2}   {:>5.2}x   ~1.8x\n\
         \x20 at light load (x{light:.2}):\n\
         \x20   CPU-MC latency (cyc)      {:>8.2}  {:>10.2}   {:>5.2}x\n\
         \x20   overall latency (cyc)     {:>8.2}  {:>10.2}   {:>5.2}x\n\
         \x20 saturation thpt (flit/cyc)  {:>8.3}  {:>10.3}   {:>5.2}x   ~2.2x\n\
         \x20 (stable up to rate x{:.2} mesh / x{:.2} WiHetNoC of the nominal iteration)\n",
        mesh_nom.cpu_mc_latency.mean(),
        wihet_nom.cpu_mc_latency.mean(),
        r(mesh_nom.cpu_mc_latency.mean(), wihet_nom.cpu_mc_latency.mean()),
        mesh_nom.latency.mean(),
        wihet_nom.latency.mean(),
        r(mesh_nom.latency.mean(), wihet_nom.latency.mean()),
        mesh_lt.cpu_mc_latency.mean(),
        wihet_lt.cpu_mc_latency.mean(),
        r(mesh_lt.cpu_mc_latency.mean(), wihet_lt.cpu_mc_latency.mean()),
        mesh_lt.latency.mean(),
        wihet_lt.latency.mean(),
        r(mesh_lt.latency.mean(), wihet_lt.latency.mean()),
        mesh_thr,
        wihet_thr,
        thr_ratio,
        mesh_rate,
        wihet_rate,
    );
    rep.table(
        "operating_points",
        &["load", "noc", "cpu_mc_latency_cyc", "overall_latency_cyc"],
        vec![
            vec![
                Cell::str("nominal"),
                Cell::str("mesh"),
                Cell::num(mesh_nom.cpu_mc_latency.mean()),
                Cell::num(mesh_nom.latency.mean()),
            ],
            vec![
                Cell::str("nominal"),
                Cell::str("wihetnoc"),
                Cell::num(wihet_nom.cpu_mc_latency.mean()),
                Cell::num(wihet_nom.latency.mean()),
            ],
            vec![
                Cell::str("light"),
                Cell::str("mesh"),
                Cell::num(mesh_lt.cpu_mc_latency.mean()),
                Cell::num(mesh_lt.latency.mean()),
            ],
            vec![
                Cell::str("light"),
                Cell::str("wihetnoc"),
                Cell::num(wihet_lt.cpu_mc_latency.mean()),
                Cell::num(wihet_lt.latency.mean()),
            ],
        ],
    );
    rep.scalar_vs_paper(
        "latency_reduction_nominal",
        r(mesh_nom.latency.mean(), wihet_nom.latency.mean()),
        "x (mesh / WiHetNoC, nominal load)",
        1.8,
        "paper: ~1.8x network latency reduction",
    );
    rep.scalar(
        "cpu_mc_latency_reduction_nominal",
        r(mesh_nom.cpu_mc_latency.mean(), wihet_nom.cpu_mc_latency.mean()),
        "x (mesh / WiHetNoC, nominal load)",
    );
    rep.scalar("mesh_saturation_throughput", mesh_thr, "flit/cyc");
    rep.scalar("wihetnoc_saturation_throughput", wihet_thr, "flit/cyc");
    rep.scalar_vs_paper(
        "throughput_gain",
        thr_ratio,
        "x (WiHetNoC / mesh)",
        2.2,
        "paper: ~2.2x throughput improvement",
    );
    rep.scalar("mesh_stable_rate", mesh_rate, "x nominal");
    rep.scalar("wihetnoc_stable_rate", wihet_rate, "x nominal");
    rep.set_text(text);
    rep
}

/// Fig 15: CDF of link utilizations, mesh_opt vs WiHetNoC, normalized to
/// the mesh mean. Paper: 20% of mesh links >2x mean; WiHetNoC has none,
/// and >90% of WiHetNoC links sit below the mesh mean.
pub fn fig15(ctx: &mut Ctx) -> Report {
    let mut rep = Report::new("fig15", "CDF of link utilizations, mesh vs WiHetNoC")
        .with_paper("Fig. 15");
    let mesh_util = sim_kind(ctx, NocKind::MeshXyYx).link_utilization();
    let wihet = ctx.instance_arc(NocKind::WiHetNoc);
    let wihet_util = sim_iteration(ctx, &wihet).link_utilization();

    let mesh_mean = stats::mean(&mesh_util).max(1e-30);
    let norm_mesh: Vec<f64> = mesh_util.iter().map(|u| u / mesh_mean).collect();
    let norm_wihet: Vec<f64> = wihet_util.iter().map(|u| u / mesh_mean).collect();
    let points: Vec<f64> = (0..=16).map(|i| i as f64 * 0.25).collect();
    let cdf_m = stats::cdf_at(&norm_mesh, &points);
    let cdf_w = stats::cdf_at(&norm_wihet, &points);

    let mut out = String::from(
        "Fig 15 — CDF of link utilizations (normalized to mesh mean)\n\n  U/mean   mesh CDF   WiHetNoC CDF\n",
    );
    for ((p, m), w) in points.iter().zip(&cdf_m).zip(&cdf_w) {
        out.push_str(&format!("  {p:>5.2}    {m:>6.3}     {w:>6.3}\n"));
    }
    let labels: Vec<String> = points.iter().map(|p| format!("{p:.2}")).collect();
    rep.series("mesh_cdf", "P(U/mesh-mean <= x)", labels.clone(), cdf_m.clone());
    rep.series("wihetnoc_cdf", "P(U/mesh-mean <= x)", labels, cdf_w.clone());
    let mesh_over2 = 100.0 * (1.0 - stats::cdf_at(&norm_mesh, &[2.0])[0]);
    let wihet_over2 = 100.0 * (1.0 - stats::cdf_at(&norm_wihet, &[2.0])[0]);
    let wihet_under_mean = 100.0 * stats::cdf_at(&norm_wihet, &[1.0])[0];
    out.push_str(&format!(
        "\n  summary: mesh>2x {mesh_over2:.0}% (paper ~20) | wihet>2x {wihet_over2:.0}% (paper 0) | wihet<mesh-mean {wihet_under_mean:.0}% (paper >90)\n",
    ));
    rep.scalar_vs_paper(
        "mesh_links_over_2x_pct",
        mesh_over2,
        "%",
        20.0,
        "paper: ~20% of mesh links exceed 2x the mean",
    );
    rep.scalar_vs_paper(
        "wihetnoc_links_over_2x_pct",
        wihet_over2,
        "%",
        0.0,
        "paper: no WiHetNoC link exceeds 2x the mesh mean",
    );
    rep.scalar_vs_paper(
        "wihetnoc_links_under_mesh_mean_pct",
        wihet_under_mean,
        "%",
        90.0,
        "paper: >90% of WiHetNoC links sit below the mesh mean",
    );
    rep.set_text(out);
    rep
}

/// Fig 16: asymmetry of WI utilization per layer — MC-to-core vs
/// core-to-MC flits over the wireless channels, which should track the
/// Fig 6 traffic asymmetry (the MAC allocates bandwidth on demand).
pub fn fig16(ctx: &mut Ctx) -> Report {
    let mut rep =
        Report::new("fig16", "WI utilization asymmetry per layer").with_paper("Fig. 16");
    let sys = ctx.sys.clone();
    let inst = ctx.instance_arc(NocKind::WiHetNoc);
    let mut out = String::from(
        "Fig 16 — WI utilization asymmetry per layer (MC->core : core->MC over wireless)\n",
    );
    for model in ModelId::ALL {
        let tm = ctx.traffic(model.clone());
        out.push_str(&format!(
            "\n{model}:\n  layer(pass)   air MC->core   air core->MC   ratio   Fig6 traffic ratio\n"
        ));
        let mut rng = Rng::new(ctx.seed ^ 16);
        let cfg = ctx.trace_cfg();
        // trace generation shares one rng stream (order matters for
        // byte-identical reports), then the phase sims fan out
        let phases: Vec<_> = tm
            .phases
            .iter()
            .filter(|p| {
                p.pass == Pass::Forward || p.tag == "C1" || p.tag == "P1" || p.tag == "F1"
            })
            .collect();
        let traces: Vec<Vec<Message>> = phases
            .iter()
            .map(|p| phase_trace(&sys, p, 0, &cfg, &mut rng).0)
            .collect();
        let reps = par_map(&traces, |_, msgs| run_on(&sys, &inst, msgs));
        let mut rows = Vec::new();
        for (p, sim) in phases.iter().zip(&reps) {
            let ratio = sim.air_flits_from_mc as f64 / sim.air_flits_to_mc.max(1) as f64;
            out.push_str(&format!(
                "  {:<5}({:<3})   {:>10}   {:>10}   {:>5.2}   {:>5.2}\n",
                p.tag,
                if p.pass == Pass::Forward { "fwd" } else { "bwd" },
                sim.air_flits_from_mc,
                sim.air_flits_to_mc,
                ratio,
                p.asymmetry(&sys),
            ));
            rows.push(vec![
                Cell::str(p.tag.as_str()),
                Cell::str(if p.pass == Pass::Forward { "fwd" } else { "bwd" }),
                Cell::num(sim.air_flits_from_mc as f64),
                Cell::num(sim.air_flits_to_mc as f64),
                Cell::num(ratio),
                Cell::num(p.asymmetry(&sys)),
            ]);
        }
        rep.table(
            format!("{model}.wi_asymmetry"),
            &["layer", "pass", "air_from_mc_flits", "air_to_mc_flits", "wi_ratio", "traffic_ratio"],
            rows,
        );
    }
    out.push_str("\n(WI ratio tracking the traffic ratio = the distributed MAC allocates wireless bandwidth per instantaneous demand)\n");
    rep.set_text(out);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ctx::Effort;

    #[test]
    fn fig14_wihetnoc_wins_cpu_latency_under_load() {
        // The paper's comparison regime: the network under CNN load (the
        // mesh near saturation). At very light load the dedicated
        // channel's MAC overhead makes wireless slower — expected.
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let mesh = sim_at_rate(&mut ctx, NocKind::MeshXyYx, 3.0);
        let wihet = sim_at_rate(&mut ctx, NocKind::WiHetNoc, 3.0);
        assert!(
            wihet.cpu_mc_latency.mean() < mesh.cpu_mc_latency.mean(),
            "cpu-mc: wihet {} vs mesh {}",
            wihet.cpu_mc_latency.mean(),
            mesh.cpu_mc_latency.mean()
        );
        assert!(
            wihet.latency.mean() < mesh.latency.mean(),
            "overall: wihet {} vs mesh {}",
            wihet.latency.mean(),
            mesh.latency.mean()
        );
    }

    #[test]
    fn fig14_wihetnoc_higher_saturation_throughput() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let (mesh_thr, _) = saturation_throughput(&mut ctx, NocKind::MeshXyYx);
        let (wihet_thr, _) = saturation_throughput(&mut ctx, NocKind::WiHetNoc);
        assert!(
            wihet_thr > mesh_thr,
            "saturation: wihet {wihet_thr} vs mesh {mesh_thr}"
        );
    }

    #[test]
    fn fig15_wihetnoc_balances_links() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let mesh_util = sim_kind(&mut ctx, NocKind::MeshXyYx).link_utilization();
        let wihet = ctx.instance_arc(NocKind::WiHetNoc);
        let wihet_util = sim_iteration(&mut ctx, &wihet).link_utilization();
        let mesh_mean = stats::mean(&mesh_util);
        let frac_over = |xs: &[f64]| {
            xs.iter().filter(|&&u| u > 2.0 * mesh_mean).count() as f64 / xs.len() as f64
        };
        assert!(
            frac_over(&wihet_util) < frac_over(&mesh_util),
            "wihet {} vs mesh {}",
            frac_over(&wihet_util),
            frac_over(&mesh_util)
        );
    }

    #[test]
    fn saturation_chunking_matches_serial_scan() {
        // chunked parallel ladder must report the same operating point a
        // fully serial scan would
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let (thr, rate) = saturation_throughput(&mut ctx, NocKind::MeshXyYx);
        const LAT_BOUND: f64 = 300.0;
        let mut serial = (0.0f64, 0.0f64);
        for step in 1..=32 {
            let r = 0.25 * step as f64;
            let rep = sim_at_rate(&mut ctx, NocKind::MeshXyYx, r);
            if rep.latency.mean() > LAT_BOUND {
                break;
            }
            serial = (rep.throughput(), r);
        }
        assert_eq!((thr, rate), serial);
    }
}

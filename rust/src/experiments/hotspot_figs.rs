//! Traffic-pattern analysis (paper §3) on our own simulator: where do
//! flits concentrate, and what do the latency *tails* look like?
//!
//! The paper motivates the hybrid NoC by profiling LeNet/CDBNet training
//! traffic — most bytes move between a few GPU clusters and the MC
//! tiles, so a handful of mesh links run hot while the rest idle. This
//! harness reproduces that observation with the telemetry subsystem:
//! for each paper workload it runs one serial training iteration on the
//! optimized mesh and on WiHetNoC with a [`Telemetry`] sink attached,
//! then reports
//!
//! * the **link heatmap** (hottest links with endpoints and
//!   utilization, full table as a `heatmap.csv` artifact),
//! * **tail latency** p50/p99/p999 per NoC ([`LogHistogram`] exact
//!   semantics) plus the per-pair-class breakdown on WiHetNoC,
//! * the **utilization time series** (per-bucket aggregate link load),
//! * a Chrome-trace timeline of the WiHetNoC LeNet run (`trace.json`
//!   artifact, viewable in `chrome://tracing` / Perfetto).
//!
//! Headline scalar `wihetnoc_p99_reduction_x`: mesh p99 over WiHetNoC
//! p99, averaged across the workloads — the tail-latency counterpart of
//! fig17's mean-latency reduction, always finite (guarded ratios).

use super::ctx::Ctx;
use super::report::{Cell, Report};
use crate::energy::network::network_energy_pj;
use crate::energy::params::EnergyParams;
use crate::energy::system::core_energy_from_counters;
use crate::model::SystemConfig;
use crate::noc::builder::{NocInstance, NocKind};
use crate::noc::sim::{NocSim, SimConfig, SimReport};
use crate::scenario::ModelId;
use crate::telemetry::{chrome_trace, Telemetry};
use crate::traffic::phases::TrafficModel;
use crate::traffic::trace::{training_trace, TraceConfig};

/// Hottest links listed per (model, NoC) in the report table; the CSV
/// artifact always carries every link.
const TOP_LINKS: usize = 8;

/// One serial iteration with a telemetry sink attached; phase-window
/// spans are recorded after the run so the trace shows the timeline.
fn run_observed(
    sys: &SystemConfig,
    inst: &NocInstance,
    tm: &TrafficModel,
    cfg: &TraceConfig,
) -> (SimReport, Telemetry) {
    let (trace, windows) = training_trace(sys, &tm.phases, cfg);
    let sim = NocSim::new(sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
    let mut tel = Telemetry::new();
    let rep = sim.run_telemetry(&trace, Some(&mut tel));
    for (p, &(start, end)) in tm.phases.iter().zip(&windows) {
        tel.span(p.tag.clone(), "phase", 0, start, end);
    }
    (rep, tel)
}

/// `mesh / wihet`, guarded so the headline scalar is always finite: a
/// zero or empty WiHetNoC tail yields parity (1.0), never inf/NaN.
fn guarded_ratio(mesh: u64, wihet: u64) -> f64 {
    if wihet == 0 {
        1.0
    } else {
        mesh as f64 / wihet as f64
    }
}

/// The §3 traffic-pattern figure: link heatmaps and latency tails.
pub fn hotspot_figs(ctx: &mut Ctx) -> Report {
    let mut rep = Report::new(
        "hotspot_figs",
        "link-utilization heatmap and tail latency (p50/p99/p999), mesh vs WiHetNoC",
    );
    rep = rep.with_paper("Sec. 3");
    let mesh = ctx.instance_arc(NocKind::MeshXyYx);
    let wihet = ctx.instance_arc(NocKind::WiHetNoc);
    let mesh_sys = ctx.sys_for(NocKind::MeshXyYx);
    let sys = ctx.sys_for(NocKind::WiHetNoc);
    let mut cfg = ctx.trace_cfg();
    // 2 models x 2 NoCs, one observed serial iteration each
    cfg.scale = cfg.scale.min(0.02);

    let mut out = format!(
        "Hotspot figs — link heatmap & latency tails on the 8x8 chip (trace scale {:.3})\n\
         (percentiles from deterministic log-bucket histograms: exact below 64 cycles,\n\
          <=1/32 relative quantization above; utilization = flits / cycles simulated)\n",
        cfg.scale
    );
    let mut heat_rows = Vec::new();
    let mut csv = String::from("model,noc,link,a,b,flits,utilization\n");
    let mut reduction_sum = 0.0;
    let mut reduction_n = 0u32;
    let mut lenet_wihet_trace: Option<String> = None;
    // ROADMAP item 5: exact per-tile activity (router flit-traversal
    // counters) vs the phase-span upper bound every tile being "on" for
    // the whole timeline would charge — both as raw tile-cycles and as
    // the full-system EDP each accounting yields (the counters are now
    // wired into `energy::core_energy_from_counters`).
    let mut counter_active = 0u64;
    let mut span_active = 0u64;
    let mut counter_edp = 0.0f64;
    let mut span_edp = 0.0f64;
    let energy = EnergyParams::default();
    let inv_scale = 1.0 / cfg.scale;

    for name in ["lenet", "cdbnet"] {
        let model: ModelId = name.parse().expect("preset exists");
        let mesh_tm = ctx.traffic_on(model.clone(), &mesh_sys);
        let tm = ctx.traffic_on(model.clone(), &sys);
        let (mesh_rep, mesh_tel) = run_observed(&mesh_sys, &mesh, &mesh_tm, &cfg);
        let (wihet_rep, wihet_tel) = run_observed(&sys, &wihet, &tm, &cfg);
        for (tel, sim_rep, run_sys, run_inst) in [
            (&mesh_tel, &mesh_rep, &mesh_sys, &mesh),
            (&wihet_tel, &wihet_rep, &sys, &wihet),
        ] {
            let n_tiles = run_sys.num_tiles();
            let span_per_tile: u64 = tel
                .spans
                .iter()
                .filter(|s| s.cat == "phase")
                .map(|s| s.end - s.start)
                .sum();
            counter_active += tel.tile_active.iter().sum::<u64>();
            span_active += span_per_tile * n_tiles as u64;
            let makespan = tel.cycles;
            let secs = makespan as f64 * inv_scale / run_sys.noc_clock_hz;
            let net_j = network_energy_pj(&run_inst.topo, sim_rep, &energy).total_pj()
                * inv_scale
                * 1e-12;
            let counter_j = core_energy_from_counters(
                run_sys,
                &tel.tile_active,
                makespan,
                inv_scale,
                &energy,
            );
            let span_j = core_energy_from_counters(
                run_sys,
                &vec![span_per_tile; n_tiles],
                makespan,
                inv_scale,
                &energy,
            );
            counter_edp += (net_j + counter_j) * secs;
            span_edp += (net_j + span_j) * secs;
        }

        // -- latency tails ---------------------------------------------
        let (mp, wp) = (mesh_tel.percentiles(), wihet_tel.percentiles());
        out.push_str(&format!(
            "\n  {name}: latency tails (cycles)\n  \
             noc       p50     p99    p999    mean      n\n  \
             mesh    {:>5}  {:>6}  {:>6}  {:>6.1}  {:>5}\n  \
             wihet   {:>5}  {:>6}  {:>6}  {:>6.1}  {:>5}\n",
            mp.all.p50, mp.all.p99, mp.all.p999, mp.all.mean, mp.all.count,
            wp.all.p50, wp.all.p99, wp.all.p999, wp.all.mean, wp.all.count,
        ));
        let tail_labels: Vec<String> =
            ["p50", "p99", "p999"].iter().map(|s| s.to_string()).collect();
        rep.series(
            format!("{name}_mesh_tail"),
            "cycles",
            tail_labels.clone(),
            vec![mp.all.p50 as f64, mp.all.p99 as f64, mp.all.p999 as f64],
        );
        rep.series(
            format!("{name}_wihet_tail"),
            "cycles",
            tail_labels,
            vec![wp.all.p50 as f64, wp.all.p99 as f64, wp.all.p999 as f64],
        );
        // pair-class breakdown on WiHetNoC (the CPU-MC QoS story)
        let class_labels: Vec<String> =
            ["all", "cpu-mc", "gpu-mc", "cpu-gpu"].iter().map(|s| s.to_string()).collect();
        rep.series(
            format!("{name}_wihet_p99_by_class"),
            "cycles",
            class_labels,
            vec![
                wp.all.p99 as f64,
                wp.cpu_mc.p99 as f64,
                wp.gpu_mc.p99 as f64,
                wp.cpu_gpu.p99 as f64,
            ],
        );
        let reduction = guarded_ratio(mp.all.p99, wp.all.p99);
        rep.scalar(format!("{name}_p99_reduction_x"), reduction, "x");
        reduction_sum += reduction;
        reduction_n += 1;

        // -- link heatmap ----------------------------------------------
        for (noc_name, inst, tel) in
            [("mesh", &mesh, &mesh_tel), ("wihet", &wihet, &wihet_tel)]
        {
            let cycles = tel.cycles.max(1) as f64;
            out.push_str(&format!(
                "\n  {name}/{noc_name}: hottest links (of {})\n  \
                 link   a->b      flits     util\n",
                tel.link_flits.len()
            ));
            for (l, flits) in tel.hottest_links(TOP_LINKS) {
                let (a, b) = (inst.topo.links[l].a, inst.topo.links[l].b);
                let util = flits as f64 / cycles;
                out.push_str(&format!(
                    "  {l:>4}   {a:>2}->{b:<2}  {flits:>9}  {util:>7.3}\n"
                ));
                heat_rows.push(vec![
                    Cell::str(name),
                    Cell::str(noc_name),
                    Cell::num(l as f64),
                    Cell::num(a as f64),
                    Cell::num(b as f64),
                    Cell::num(flits as f64),
                    Cell::num(util),
                ]);
            }
            for (l, &flits) in tel.link_flits.iter().enumerate() {
                let (a, b) = (inst.topo.links[l].a, inst.topo.links[l].b);
                csv.push_str(&format!(
                    "{name},{noc_name},{l},{a},{b},{flits},{:.6}\n",
                    flits as f64 / cycles
                ));
            }
            // heat concentration: share of flits on the top-8 links — the
            // §3 observation in one number
            let total: u64 = tel.link_flits.iter().sum();
            let top: u64 = tel.hottest_links(TOP_LINKS).iter().map(|&(_, f)| f).sum();
            rep.scalar(
                format!("{name}_{noc_name}_top{TOP_LINKS}_flit_share_pct"),
                100.0 * top as f64 / total.max(1) as f64,
                "%",
            );
        }

        // -- utilization time series (WiHetNoC) ------------------------
        let util = wihet_tel.utilization_series();
        let labels: Vec<String> =
            (0..util.len()).map(|r| (r as u64 * wihet_tel.bucket_cycles()).to_string()).collect();
        rep.series(format!("{name}_wihet_util_series"), "util", labels, util);

        if name == "lenet" {
            let mut text = chrome_trace(&wihet_tel).dump();
            text.push('\n');
            lenet_wihet_trace = Some(text);
        }
    }

    let headline = if reduction_n == 0 { 1.0 } else { reduction_sum / reduction_n as f64 };
    rep.scalar("wihetnoc_p99_reduction_x", headline, "x");
    // Share of span-charged tile-cycles that carried actual router
    // activity — how far the span-based energy accounting overestimates
    // what the exact counters meter (ROADMAP item 5).
    let active_pct = 100.0 * counter_active as f64 / span_active.max(1) as f64;
    rep.scalar("tile_active_vs_span_pct", active_pct, "%");
    // ... and what that correction is worth in energy terms: full-system
    // EDP from the exact counters vs EDP charging every tile as active
    // over the whole span-covered timeline.
    let edp_delta_pct =
        100.0 * (span_edp - counter_edp) / span_edp.max(f64::MIN_POSITIVE);
    rep.scalar("tile_active_edp_delta_pct", edp_delta_pct, "%");
    rep.table(
        "link_heatmap_top",
        &["model", "noc", "link", "a", "b", "flits", "utilization"],
        heat_rows,
    );
    rep.artifact("heatmap.csv", csv);
    if let Some(trace) = lenet_wihet_trace {
        rep.artifact("trace.json", trace);
    }
    out.push_str(&format!(
        "\n  WiHetNoC cuts p99 latency {headline:.2}x vs the optimized mesh\n  \
         (mean over workloads; trace.json + heatmap.csv attached as artifacts)\n  \
         exact tile-activity counters cover {active_pct:.2}% of the span-charged\n  \
         tile-cycles; charging core energy from the counters instead of the spans\n  \
         shifts full-system EDP by {edp_delta_pct:.2}% (ROADMAP item 5, wired into\n  \
         energy::core_energy_from_counters)\n"
    ));
    rep.set_text(out);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::builder::mesh_opt;
    use crate::telemetry::validate_chrome_trace;
    use crate::util::json::parse;

    #[test]
    fn guarded_ratio_is_always_finite() {
        assert_eq!(guarded_ratio(100, 0), 1.0);
        assert_eq!(guarded_ratio(0, 0), 1.0);
        assert_eq!(guarded_ratio(120, 60), 2.0);
        assert!(guarded_ratio(u64::MAX, 1).is_finite());
    }

    /// Cheap end-to-end mechanics on the mesh baseline (the full harness
    /// additionally designs the WiHetNoC): one observed run yields
    /// non-empty percentiles, a consistent heatmap, and a valid trace.
    #[test]
    fn observed_run_mechanics_smoke() {
        let sys = SystemConfig::paper_8x8();
        let inst = mesh_opt(&sys, true);
        let tm = crate::workload::lower_id(
            &ModelId::LeNet,
            &crate::workload::MappingPolicy::default(),
            &sys,
            32,
        )
        .unwrap();
        let cfg = TraceConfig { scale: 0.01, ..Default::default() };
        let (rep, tel) = run_observed(&sys, &inst, &tm, &cfg);
        assert!(rep.delivered_packets > 0);
        assert_eq!(tel.delivered_packets, rep.delivered_packets);
        assert_eq!(tel.link_flits, rep.link_flits);
        let p = tel.percentiles();
        assert_eq!(p.all.count, rep.delivered_packets);
        assert!(p.all.p50 <= p.all.p99 && p.all.p99 <= p.all.p999);
        assert!(!tel.hottest_links(TOP_LINKS).is_empty());
        assert!(!tel.spans.is_empty(), "phase spans recorded");
        // the exported trace validates and round-trips through the parser
        let doc = chrome_trace(&tel);
        validate_chrome_trace(&doc).unwrap();
        validate_chrome_trace(&parse(&doc.dump()).unwrap()).unwrap();
        // report untouched by telemetry: percentiles stay None on the raw run
        assert!(rep.percentiles.is_none());
        // satellite: both energy accountings of the same run are finite
        // and positive, and the span charge is an upper bound here (the
        // phase windows cover every counted traversal)
        let e = EnergyParams::default();
        let inv_scale = 1.0 / cfg.scale;
        let counter_j =
            core_energy_from_counters(&sys, &tel.tile_active, tel.cycles, inv_scale, &e);
        let span_per_tile: u64 = tel
            .spans
            .iter()
            .filter(|s| s.cat == "phase")
            .map(|s| s.end - s.start)
            .sum();
        let span_j = core_energy_from_counters(
            &sys,
            &vec![span_per_tile; sys.num_tiles()],
            tel.cycles,
            inv_scale,
            &e,
        );
        assert!(counter_j > 0.0 && counter_j.is_finite());
        assert!(span_j > 0.0 && span_j.is_finite());
    }
}

//! Table 1: layer configurations of LeNet and CDBNet (derived, and
//! asserted against the paper's entries in model::cnn tests).

use super::ctx::Ctx;
use crate::scenario::ModelId;

pub fn run(ctx: &mut Ctx) -> String {
    let mut out = String::from("Table 1 — layer configurations (derived)\n");
    for model in ModelId::ALL {
        let spec = ctx.spec(model);
        out.push_str(&format!(
            "\n{} (input {}x{}x{}):\n",
            spec.name, spec.input_shape.0, spec.input_shape.1, spec.input_shape.2
        ));
        out.push_str("  layer  kind      in           out          kernel  weights\n");
        for l in &spec.layers {
            out.push_str(&format!(
                "  {:<6} {:<9} {:<12} {:<12} {:<7} {}\n",
                l.name,
                l.kind.as_str(),
                format!("{}x{}x{}", l.in_shape.0, l.in_shape.1, l.in_shape.2),
                format!("{}x{}x{}", l.out_shape.0, l.out_shape.1, l.out_shape.2),
                if l.kernel > 0 { format!("{0}x{0}", l.kernel) } else { "-".into() },
                l.weight_count(),
            ));
        }
        out.push_str(&format!(
            "  total weights: {}  | fwd MACs @batch {}: {}\n",
            spec.layers.iter().map(|l| l.weight_count()).sum::<u64>(),
            ctx.batch(),
            spec.total_macs(ctx.batch()),
        ));
    }
    out.push_str("\npaper check: LeNet C1 29x29x16, C2 11x11x16, C3 1x1x128; CDBNet C1 31x31x32, C2 15x15x32, C3 7x7x64 — asserted in model::cnn::tests.\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ctx::Effort;

    #[test]
    fn renders_both_models() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let s = run(&mut ctx);
        assert!(s.contains("lenet"));
        assert!(s.contains("cdbnet"));
        assert!(s.contains("29x29x16"));
        assert!(s.contains("7x7x64"));
    }
}

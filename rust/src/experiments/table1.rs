//! Table 1: layer configurations of LeNet and CDBNet (derived, and
//! asserted against the paper's entries in model::cnn tests).

use super::ctx::Ctx;
use super::report::{Cell, Report};
use crate::scenario::ModelId;

pub fn run(ctx: &mut Ctx) -> Report {
    let mut rep = Report::new("table1", "layer configurations of LeNet and CDBNet")
        .with_paper("Table 1");
    let mut out = String::from("Table 1 — layer configurations (derived)\n");
    for model in ModelId::ALL {
        let spec = ctx.spec(model.clone());
        out.push_str(&format!(
            "\n{} (input {}x{}x{}):\n",
            spec.name, spec.input_shape.0, spec.input_shape.1, spec.input_shape.2
        ));
        out.push_str("  layer  kind      in           out          kernel  weights\n");
        let mut rows = Vec::new();
        for l in &spec.layers {
            let in_shape = format!("{}x{}x{}", l.in_shape.0, l.in_shape.1, l.in_shape.2);
            let out_shape = format!("{}x{}x{}", l.out_shape.0, l.out_shape.1, l.out_shape.2);
            let kernel =
                if l.kernel > 0 { format!("{0}x{0}", l.kernel) } else { "-".into() };
            out.push_str(&format!(
                "  {:<6} {:<9} {:<12} {:<12} {:<7} {}\n",
                l.name,
                l.kind.as_str(),
                in_shape,
                out_shape,
                kernel,
                l.weight_count(),
            ));
            rows.push(vec![
                Cell::str(l.name.as_str()),
                Cell::str(l.kind.as_str()),
                Cell::str(in_shape),
                Cell::str(out_shape),
                Cell::str(kernel),
                Cell::num(l.weight_count() as f64),
            ]);
        }
        let total_weights: u64 = spec.layers.iter().map(|l| l.weight_count()).sum();
        let macs = spec.total_macs(ctx.batch());
        out.push_str(&format!(
            "  total weights: {}  | fwd MACs @batch {}: {}\n",
            total_weights,
            ctx.batch(),
            macs,
        ));
        rep.table(
            format!("{model}.layers"),
            &["layer", "kind", "in", "out", "kernel", "weights"],
            rows,
        );
        rep.scalar(format!("{model}.total_weights"), total_weights as f64, "weights");
        rep.scalar(format!("{model}.fwd_macs"), macs as f64, "MACs");
    }
    out.push_str("\npaper check: LeNet C1 29x29x16, C2 11x11x16, C3 1x1x128; CDBNet C1 31x31x32, C2 15x15x32, C3 7x7x64 — asserted in model::cnn::tests.\n");
    rep.set_text(out);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ctx::Effort;

    #[test]
    fn renders_both_models() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let rep = run(&mut ctx);
        let s = rep.to_text();
        assert!(s.contains("lenet"));
        assert!(s.contains("cdbnet"));
        assert!(s.contains("29x29x16"));
        assert!(s.contains("7x7x64"));
        // structured: one layer table + two scalars per model
        assert!(rep.section("lenet.layers").is_some());
        assert!(rep.section("cdbnet.layers").is_some());
        let weights = rep
            .scalars()
            .find(|(n, _)| *n == "lenet.total_weights")
            .map(|(_, v)| v)
            .unwrap();
        assert!(weights > 0.0);
    }
}

//! Graceful-degradation study under the [`crate::faults`] subsystem:
//! how much of WiHetNoC's latency/EDP advantage over the optimized mesh
//! survives broken wires and jammed wireless channels.
//!
//! Two sweeps on the paper's 8x8 chip, for `lenet` and `alexnet`:
//!
//! * **wireline fault rate** — seeded random link kills at 0% / 1% /
//!   3% / 10% of the links (`wire:rate=F,seed=S`). Both NoCs reroute
//!   around the dead links (delay-weighted repair paths); latency and
//!   per-message EDP degrade as the surviving links absorb the detoured
//!   flits.
//! * **jammed channel count** — 0..3 wireless channels jammed for the
//!   whole run (`air:ch=C,from=0,burst=...`). The mesh has no wireless
//!   tier, so its line is flat by construction; WiHetNoC pays bounded
//!   retry-with-backoff and then falls back to wireline, converging
//!   toward mesh behaviour as channels disappear.
//!
//! The headline scalar `advantage_collapse_fault_pct` names the first
//! swept wireline fault rate at which WiHetNoC's latency advantage over
//! the mesh collapses (mesh/WiHetNoC latency ratio <= 1) — or the
//! maximum swept rate when the advantage survives the whole sweep, so
//! the scalar is always a number (CI smoke-checks it from the JSON
//! rendering).

use super::ctx::Ctx;
use super::report::{Cell, Report};
use crate::energy::{message_edp, EnergyParams};
use crate::faults::FaultPlan;
use crate::noc::builder::{NocInstance, NocKind};
use crate::noc::sim::{NocSim, SimConfig, SimReport};
use crate::model::SystemConfig;
use crate::scenario::ModelId;
use crate::traffic::phases::TrafficModel;
use crate::traffic::trace::{training_trace, TraceConfig};

/// Wireline fault rates swept, in percent of links killed (expected).
const RATES_PCT: [f64; 4] = [0.0, 1.0, 3.0, 10.0];
/// Jammed-channel counts swept.
const JAMS: [usize; 4] = [0, 1, 2, 3];
/// A jam window far longer than any quick-effort run: the channel is
/// down for the whole simulation.
const JAM_BURST: u64 = 100_000_000;

/// One serial iteration of `tm` on `inst` under `plan`.
fn run_faulted(
    sys: &SystemConfig,
    inst: &NocInstance,
    tm: &TrafficModel,
    cfg: &TraceConfig,
    plan: &FaultPlan,
) -> SimReport {
    let sim_cfg = SimConfig::default();
    let fx = if plan.has_noc_faults() {
        Some(
            plan.compile(&inst.topo, &inst.routes, &inst.air, sim_cfg.nominal_flits)
                .expect("swept plans are well-formed"),
        )
    } else {
        None
    };
    let (trace, _) = training_trace(sys, &tm.phases, cfg);
    let mut sim = NocSim::new(sys, &inst.topo, &inst.routes, &inst.air, sim_cfg);
    if let Some(f) = &fx {
        sim = sim.with_faults(f);
    }
    sim.run(&trace)
}

/// The wireline plan for one swept rate (percent), seeded from the ctx.
fn rate_plan(rate_pct: f64, seed: u64) -> FaultPlan {
    if rate_pct <= 0.0 {
        return FaultPlan::none();
    }
    format!("wire:rate={},seed={seed}", rate_pct / 100.0)
        .parse()
        .expect("swept rates are in [0, 1]")
}

/// The jam plan for `k` channels down for the whole run.
fn jam_plan(k: usize) -> FaultPlan {
    if k == 0 {
        return FaultPlan::none();
    }
    let clauses: Vec<String> =
        (0..k).map(|c| format!("air:ch={c},from=0,burst={JAM_BURST}")).collect();
    clauses.join(";").parse().expect("jam clauses are well-formed")
}

/// First swept rate at which the mesh/WiHetNoC latency ratio drops to
/// parity (<= 1), i.e. WiHetNoC's advantage has collapsed; the maximum
/// swept rate when it never does. Always a number.
fn collapse_pct(rates_pct: &[f64], advantage: &[f64]) -> f64 {
    rates_pct
        .iter()
        .zip(advantage)
        .find(|&(_, &a)| a <= 1.0)
        .map(|(&r, _)| r)
        .unwrap_or_else(|| rates_pct.last().copied().unwrap_or(0.0))
}

/// The resilience figure: fault-rate and jammed-channel sweeps, mesh vs
/// WiHetNoC.
pub fn resilience_figs(ctx: &mut Ctx) -> Report {
    let mut rep = Report::new(
        "resilience_figs",
        "graceful degradation under link faults and jammed channels, mesh vs WiHetNoC",
    );
    let params = EnergyParams::default();
    let mesh = ctx.instance_arc(NocKind::MeshXyYx);
    let wihet = ctx.instance_arc(NocKind::WiHetNoc);
    let mesh_sys = ctx.sys_for(NocKind::MeshXyYx);
    let sys = ctx.sys.clone();
    let mut cfg = ctx.trace_cfg();
    // 2 models x 2 NoCs x 8 fault points: keep the budget small
    cfg.scale = cfg.scale.min(0.02);
    let seed = ctx.seed;

    let mut out = format!(
        "Resilience figs — fault injection on the 8x8 chip (trace scale {:.3})\n\
         (latency in cycles; advantage = mesh latency / WiHetNoC latency, > 1 means\n\
          WiHetNoC still wins; the mesh has no wireless tier, so jams leave it flat)\n",
        cfg.scale
    );
    let mut rows = Vec::new();
    let mut collapse_all = f64::INFINITY;

    for name in ["lenet", "alexnet"] {
        let model: ModelId = name.parse().expect("preset exists");
        let mesh_tm = ctx.traffic_on(model.clone(), &mesh_sys);
        let tm = ctx.traffic_on(model.clone(), &sys);

        // -- sweep A: seeded random wireline faults ---------------------
        out.push_str(&format!(
            "\n  {name}: wireline fault rate sweep\n  \
             rate%   mesh lat    wihet lat   advantage   mesh EDP      wihet EDP     rerouted  undeliv\n"
        ));
        let mut mesh_lat = Vec::new();
        let mut wihet_lat = Vec::new();
        let mut advantage = Vec::new();
        let mut edp_ratio = Vec::new();
        for &rate in RATES_PCT.iter() {
            let plan = rate_plan(rate, seed);
            let m = run_faulted(&mesh_sys, &mesh, &mesh_tm, &cfg, &plan);
            let h = run_faulted(&sys, &wihet, &tm, &cfg, &plan);
            let (ml, hl) = (m.latency.mean(), h.latency.mean());
            let (me, he) = (
                message_edp(&mesh.topo, &m, &params),
                message_edp(&wihet.topo, &h, &params),
            );
            let adv = ml / hl.max(1e-9);
            out.push_str(&format!(
                "  {rate:>5.1}  {ml:>9.2}  {hl:>10.2}  {adv:>10.3}  {me:>12.1}  {he:>13.1}  {:>8}  {:>7}\n",
                m.resilience.packets_rerouted + h.resilience.packets_rerouted,
                m.undeliverable + h.undeliverable,
            ));
            rows.push(vec![
                Cell::str(name),
                Cell::str("wire_rate"),
                Cell::num(rate),
                Cell::num(ml),
                Cell::num(hl),
                Cell::num(adv),
                Cell::num((h.resilience.packets_rerouted + m.resilience.packets_rerouted) as f64),
            ]);
            mesh_lat.push(ml);
            wihet_lat.push(hl);
            advantage.push(adv);
            edp_ratio.push(me / he.max(1e-9));
        }
        let labels: Vec<String> = RATES_PCT.iter().map(|r| format!("{r}%")).collect();
        rep.series(format!("{name}_mesh_latency"), "cycles", labels.clone(), mesh_lat);
        rep.series(format!("{name}_wihet_latency"), "cycles", labels.clone(), wihet_lat);
        rep.series(format!("{name}_latency_advantage"), "x", labels.clone(), advantage.clone());
        rep.series(format!("{name}_edp_advantage"), "x", labels, edp_ratio);
        let collapse = collapse_pct(&RATES_PCT, &advantage);
        rep.scalar(format!("{name}_advantage_collapse_fault_pct"), collapse, "%");
        collapse_all = collapse_all.min(collapse);

        // -- sweep B: jammed wireless channels --------------------------
        out.push_str(&format!(
            "\n  {name}: jammed-channel sweep (WiHetNoC; mesh is channel-free)\n  \
             jammed  wihet lat   retries   fallback flits\n"
        ));
        let mut jam_lat = Vec::new();
        let mut jam_fallback = Vec::new();
        for &k in JAMS.iter() {
            let plan = jam_plan(k);
            let h = run_faulted(&sys, &wihet, &tm, &cfg, &plan);
            let hl = h.latency.mean();
            out.push_str(&format!(
                "  {k:>6}  {hl:>10.2}  {:>8}  {:>14}\n",
                h.resilience.retries, h.resilience.fallback_flits,
            ));
            rows.push(vec![
                Cell::str(name),
                Cell::str("jammed_channels"),
                Cell::num(k as f64),
                Cell::num(0.0),
                Cell::num(hl),
                Cell::num(0.0),
                Cell::num(h.resilience.fallback_flits as f64),
            ]);
            jam_lat.push(hl);
            jam_fallback.push(h.resilience.fallback_flits as f64);
        }
        let labels: Vec<String> = JAMS.iter().map(|k| k.to_string()).collect();
        rep.series(format!("{name}_jam_latency"), "cycles", labels.clone(), jam_lat);
        rep.series(format!("{name}_jam_fallback_flits"), "flits", labels, jam_fallback);
    }

    rep.scalar("advantage_collapse_fault_pct", collapse_all, "%");
    rep.table(
        "resilience_sweeps",
        &["model", "sweep", "level", "mesh_latency", "wihet_latency", "advantage", "recovery"],
        rows,
    );
    out.push_str(&format!(
        "\n  WiHetNoC's latency advantage collapses at a {collapse_all}% wireline fault rate\n  \
         (= the max swept rate when the advantage survives the whole sweep)\n"
    ));
    rep.set_text(out);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::builder::mesh_opt;

    #[test]
    fn collapse_pct_picks_first_parity_point() {
        assert_eq!(collapse_pct(&RATES_PCT, &[1.4, 1.3, 1.1, 1.05]), 10.0);
        assert_eq!(collapse_pct(&RATES_PCT, &[1.4, 0.99, 1.1, 1.05]), 1.0);
        assert_eq!(collapse_pct(&RATES_PCT, &[0.9, 0.9, 0.9, 0.9]), 0.0);
        assert_eq!(collapse_pct(&[], &[]), 0.0);
    }

    #[test]
    fn swept_plans_parse_and_default_to_none() {
        assert!(rate_plan(0.0, 7).is_none());
        assert!(jam_plan(0).is_none());
        let p = rate_plan(3.0, 7);
        assert_eq!(p.wire_rate_ppm, 30_000);
        assert_eq!(p.wire_seed, 7);
        let j = jam_plan(2);
        assert_eq!(j.jams.len(), 2);
        assert!(j.jams.iter().all(|w| w.burst == JAM_BURST && w.from == 0));
    }

    /// The full harness designs the 8x8 WiHetNoC; here the cheap mesh
    /// baseline pins the sweep mechanics end to end: a faulted run
    /// reroutes without losing packets on the connected residual, and a
    /// jam plan is inert on the channel-free mesh.
    #[test]
    fn mesh_sweep_mechanics_smoke() {
        let sys = SystemConfig::paper_8x8();
        let inst = mesh_opt(&sys, true);
        let tm = crate::workload::lower_id(
            &ModelId::LeNet,
            &crate::workload::MappingPolicy::default(),
            &sys,
            32,
        )
        .unwrap();
        let cfg = TraceConfig { scale: 0.01, ..Default::default() };
        let clean = run_faulted(&sys, &inst, &tm, &cfg, &FaultPlan::none());
        assert!(clean.delivered_packets > 0);
        assert_eq!(clean.resilience.faults_injected, 0);

        // one explicit dead link: the 8x8 mesh stays connected, so the
        // repair pass must deliver everything
        let plan: FaultPlan = "wire:link=0".parse().unwrap();
        let faulted = run_faulted(&sys, &inst, &tm, &cfg, &plan);
        assert_eq!(faulted.delivered_packets, clean.delivered_packets);
        assert_eq!(faulted.undeliverable, 0);
        assert_eq!(faulted.resilience.undeliverable_after_repair, 0);
        assert_eq!(faulted.resilience.faults_injected, 1);

        // jams are inert without a wireless tier: byte-identical run
        let jammed = run_faulted(&sys, &inst, &tm, &cfg, &jam_plan(2));
        assert_eq!(jammed.latency.mean(), clean.latency.mean());
        assert_eq!(jammed.link_flits, clean.link_flits);
        assert_eq!(jammed.resilience.faults_injected, 0);
    }
}

//! Figs 11-13: WiHetNoC parameter selection — router port bound k_max,
//! WI count, and channel count.
//!
//! §Perf: each sweep designs its candidates serially (they share the
//! cached wireline optimization) and then fans the simulations out over
//! [`par_map`] workers. Jobs are pure — instance + precomputed trace in,
//! metrics out — so reports are byte-identical at any `WIHETNOC_THREADS`.

use super::ctx::{variant_on, Ctx};
use super::report::{Cell, Report};
use crate::energy::network::message_edp;
use crate::energy::params::EnergyParams;
use crate::noc::builder::NocInstance;
use crate::noc::routing::RouteSet;
use crate::noc::sim::{Message, NocSim, SimConfig, SimReport};
use crate::traffic::trace::training_trace;
use crate::util::exec::par_map;

/// Simulate one full training iteration of the scenario's design
/// workload (paper: LeNet) on `inst`; returns the sim report (shared by
/// the parameter sweeps).
pub fn sim_iteration(ctx: &mut Ctx, inst: &NocInstance) -> SimReport {
    let trace = design_trace(ctx);
    run_trace(ctx, inst, &trace)
}

/// The design-workload iteration trace on the WiHetNoC placement.
fn design_trace(ctx: &mut Ctx) -> Vec<Message> {
    let model = ctx.model();
    let sys = ctx.sys.clone();
    let tm = ctx.traffic(model);
    let cfg = ctx.trace_cfg();
    training_trace(&sys, &tm.phases, &cfg).0
}

fn run_trace(ctx: &Ctx, inst: &NocInstance, trace: &[Message]) -> SimReport {
    NocSim::new(&ctx.sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default()).run(trace)
}

/// Fig 11: network EDP vs k_max. Paper: optimum at k_max = 6 (EDP worsens
/// beyond due to router energy without latency gains).
pub fn fig11(ctx: &mut Ctx) -> Report {
    let mut rep = Report::new("fig11", "network EDP vs router port bound k_max")
        .with_paper("Fig. 11");
    let energy = EnergyParams::default();
    let mut out = String::from("Fig 11 — network EDP vs router port bound k_max (paper optimum: 6)\n\n");
    out.push_str("  k_max   msg EDP (pJ*cyc)   mean latency   norm\n");
    // the per-k_max AMOSA designs are independent: any not already in
    // the shared cache are optimized in parallel (Ctx::wirelines fans
    // them out over par_map, deterministically per k_max) ...
    let k_range: Vec<usize> = (4..=7).collect();
    let topos = ctx.wirelines(&k_range);
    let model = ctx.model();
    let fij = ctx.fij(model);
    let insts: Vec<(usize, NocInstance)> = k_range
        .iter()
        .zip(topos)
        .map(|(&k_max, topo)| {
            let routes = RouteSet::shortest(&topo, Some(&fij));
            let inst = NocInstance {
                kind: crate::noc::builder::NocKind::HetNoc,
                topo,
                routes,
                air: crate::noc::wireless::WirelessSpec::new(0),
            };
            (k_max, inst)
        })
        .collect();
    // ... and the simulations fan out
    let trace = design_trace(ctx);
    let ctx_ref: &Ctx = ctx;
    let rows: Vec<(usize, f64, f64)> = par_map(&insts, |_, (k_max, inst)| {
        let rep = run_trace(ctx_ref, inst, &trace);
        (*k_max, message_edp(&inst.topo, &rep, &energy), rep.latency.mean())
    });
    let best = rows.iter().cloned().fold(f64::INFINITY, |m, r| m.min(r.1));
    let mut table = Vec::new();
    let mut best_k = 0usize;
    for (k, edp, lat) in &rows {
        if (edp / best - 1.0).abs() < 1e-9 {
            best_k = *k;
        }
        out.push_str(&format!(
            "  {k}       {edp:>12.1}       {lat:>8.2}      {:>5.3}{}\n",
            edp / best,
            if (edp / best - 1.0).abs() < 1e-9 { "  <- optimum" } else { "" }
        ));
        table.push(vec![
            Cell::num(*k as f64),
            Cell::num(*edp),
            Cell::num(*lat),
            Cell::num(edp / best),
        ]);
    }
    rep.table("sweep", &["k_max", "msg_edp_pj_cyc", "mean_latency_cyc", "edp_over_best"], table);
    rep.scalar_vs_paper(
        "best_k_max",
        best_k as f64,
        "ports",
        6.0,
        "paper: the EDP optimum sits at k_max = 6",
    );
    rep.set_text(out);
    rep
}

/// Fig 12: EDP and wireless utilization vs WI count. Paper: EDP improves
/// up to 24 WIs (6 per channel), then MAC overhead turns it around.
pub fn fig12(ctx: &mut Ctx) -> Report {
    let mut rep =
        Report::new("fig12", "EDP & wireless utilization vs WI count").with_paper("Fig. 12");
    let energy = EnergyParams::default();
    let mut out = String::from(
        "Fig 12 — EDP & wireless utilization vs GPU-MC WI count (paper optimum: 24)\n\n",
    );
    out.push_str("  n_wi   msg EDP (pJ*cyc)   wireless util   air fallback\n");
    let topo = ctx.wireline(ctx.design_cfg().k_max);
    let model = ctx.model();
    let fij = ctx.fij(model);
    let trace = design_trace(ctx);
    let ctx_ref: &Ctx = ctx;
    let wi_counts = [8usize, 16, 24, 32, 40];
    let rows = par_map(&wi_counts, |_, &n_wi| {
        let inst = variant_on(&ctx_ref.sys, topo.clone(), &fij, n_wi, 4);
        let rep = run_trace(ctx_ref, &inst, &trace);
        (
            message_edp(&inst.topo, &rep, &energy),
            100.0 * rep.wireless_utilization(),
            100.0 * rep.air_fallbacks as f64 / rep.delivered_packets.max(1) as f64,
        )
    });
    let mut table = Vec::new();
    let mut best = (f64::INFINITY, 0usize);
    for (n_wi, (edp, util, fb)) in wi_counts.iter().zip(&rows) {
        out.push_str(&format!(
            "  {n_wi:<5}  {edp:>12.1}       {util:>6.2}%         {fb:>6.2}%\n",
        ));
        if *edp < best.0 {
            best = (*edp, *n_wi);
        }
        table.push(vec![
            Cell::num(*n_wi as f64),
            Cell::num(*edp),
            Cell::num(*util),
            Cell::num(*fb),
        ]);
    }
    rep.table(
        "sweep",
        &["n_wi", "msg_edp_pj_cyc", "wireless_util_pct", "air_fallback_pct"],
        table,
    );
    rep.scalar_vs_paper(
        "best_n_wi",
        best.1 as f64,
        "WIs",
        24.0,
        "paper: EDP improves up to 24 WIs (6 per channel)",
    );
    out.push_str("\n(MAC request period grows with WIs/channel: beyond 6 per channel the access latency erodes the shortcut gain)\n");
    rep.set_text(out);
    rep
}

/// Fig 13: EDP and WI utilization vs number of GPU-MC channels at 6 WIs
/// per channel. Paper: gains plateau at 4 channels for 64 tiles.
pub fn fig13(ctx: &mut Ctx) -> Report {
    let mut rep = Report::new("fig13", "EDP & wireless utilization vs channel count")
        .with_paper("Fig. 13");
    let energy = EnergyParams::default();
    let mut out = String::from(
        "Fig 13 — EDP & wireless utilization vs channel count (6 WIs/channel; paper plateau: 4)\n\n",
    );
    out.push_str("  channels   n_wi   msg EDP (pJ*cyc)   wireless util\n");
    let topo = ctx.wireline(ctx.design_cfg().k_max);
    let model = ctx.model();
    let fij = ctx.fij(model);
    let trace = design_trace(ctx);
    let ctx_ref: &Ctx = ctx;
    let channel_counts: Vec<usize> = (1..=4).collect();
    let rows = par_map(&channel_counts, |_, &channels| {
        let n_wi = channels * 6;
        let inst = variant_on(&ctx_ref.sys, topo.clone(), &fij, n_wi, channels);
        let rep = run_trace(ctx_ref, &inst, &trace);
        (message_edp(&inst.topo, &rep, &energy), 100.0 * rep.wireless_utilization())
    });
    let mut table = Vec::new();
    for (channels, (edp, util)) in channel_counts.iter().zip(&rows) {
        let n_wi = channels * 6;
        out.push_str(&format!(
            "  {channels:<9}  {n_wi:<5}  {edp:>12.1}       {util:>6.2}%\n",
        ));
        table.push(vec![
            Cell::num(*channels as f64),
            Cell::num(n_wi as f64),
            Cell::num(*edp),
            Cell::num(*util),
        ]);
    }
    rep.table("sweep", &["channels", "n_wi", "msg_edp_pj_cyc", "wireless_util_pct"], table);
    rep.set_text(out);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ctx::Effort;

    #[test]
    fn fig12_more_wis_more_wireless_traffic() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let small = ctx.wihet_variant(8, 4);
        let big = ctx.wihet_variant(24, 4);
        let rs = sim_iteration(&mut ctx, &small);
        let rb = sim_iteration(&mut ctx, &big);
        assert!(
            rb.wireless_utilization() >= rs.wireless_utilization(),
            "24 WI util {} < 8 WI util {}",
            rb.wireless_utilization(),
            rs.wireless_utilization()
        );
    }

    #[test]
    fn fig11_all_kmax_feasible() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        for k in 4..=7 {
            let t = ctx.wireline(k);
            assert!(t.is_connected());
            assert!(t.k_max() <= k);
        }
    }
}

//! Multi-chip scaling study on the data-parallel training [`fabric`]:
//! iteration time, scaled speedup, and communication overhead vs the
//! chip count (1/2/4/8) for `alexnet` and `vgg11` on a 144-tile chip
//! (`12x12:cpus=8,mcs=8,placement=corners`), mesh vs WiHetNoC, ring vs
//! hierarchical allreduce, under a `pipeline:4` mapping and the `1f1b:8`
//! schedule.
//!
//! This is the ISSUE 6 tentpole figure: every chip runs the same
//! per-chip replica workload, the gradient allreduce is lowered into
//! the training timeline (bucket-gated on the backward pass, co-
//! simulated with the on-chip traffic), and the inter-chip hops are
//! charged from the alpha-beta link model. Speedup is the *scaled*
//! data-parallel speedup — `N` chips process `N x` the samples per
//! iteration — so `speedup(N) = N * exec(1) / exec(N)`, and the gap to
//! the ideal `N` is exactly the allreduce overhead.
//!
//! Besides the table, the report attaches the sweep rows as a
//! machine-readable CSV artifact (`scale_figs.rows.csv` under
//! `experiment scale_figs --out DIR`). CI smoke-checks the
//! `alexnet_comm_overhead_n8_pct` scalar from the JSON rendering.
//!
//! [`fabric`]: crate::fabric

use super::ctx::Ctx;
use super::report::{Cell, Report};
use crate::coordinator::cosim::cosimulate_fabric;
use crate::fabric::{Collective, Fabric};
use crate::scenario::{ModelId, Scenario};
use crate::schedule::SchedulePolicy;
use crate::workload::MappingPolicy;
use crate::Platform;

const PLATFORM: &str = "12x12:cpus=8,mcs=8,placement=corners";
const BATCH: usize = 16;
const CHIPS: [usize; 4] = [1, 2, 4, 8];

/// The sweep's inter-chip link: default alpha (1.2 us), 100 GB/s.
fn fabric_for(chips: usize, collective: Collective) -> Fabric {
    Fabric {
        link_bytes_per_sec: 100_000_000_000,
        collective,
        ..Fabric::new(chips)
    }
}

/// The scaling figure: chips x {mesh, WiHetNoC} x {ring, hierarchical}.
pub fn scale_figs(ctx: &mut Ctx) -> Report {
    let mut rep = Report::new(
        "scale_figs",
        "multi-chip data-parallel scaling: iteration time, speedup, comm overhead",
    );
    let sched = SchedulePolicy::OneFOneB { microbatches: 8 };
    let platform: Platform = PLATFORM.parse().expect("well-formed platform literal");
    let mut out = format!(
        "Scale figs — data-parallel fabric on {PLATFORM} (mapping pipeline:4, \
         schedule {sched}, batch {BATCH}/chip, link 1.2us + 100GBps)\n\
         (speedup is scaled: N chips process N x the samples; ideal = N)\n\n  \
         model     chips  noc    algo          iter(ms)  overhead%  speedup  exec(hyb/mesh)\n"
    );
    let mut csv = String::from(
        "model,chips,noc,algorithm,exec_seconds,comm_overhead_pct,speedup,interchip_j,fabric_edp\n",
    );
    let mut rows = Vec::new();
    for name in ["alexnet", "vgg11"] {
        let model: ModelId = name.parse().expect("preset exists");
        let grad = model.spec().total_weight_bytes();
        let sc = Scenario::new(platform, model.clone())
            .with_mapping(MappingPolicy::LayerPipelined { stages: 4 })
            .with_schedule(sched)
            .with_effort(ctx.effort)
            .with_seed(ctx.seed)
            .with_batch(BATCH);
        let mut wctx = Ctx::for_scenario(&sc).expect("scenario is valid");
        let mesh = wctx.instance_arc(crate::noc::builder::NocKind::MeshXyYx);
        let wihet = wctx.instance_arc(crate::noc::builder::NocKind::WiHetNoc);
        let mesh_sys = wctx.sys_for(crate::noc::builder::NocKind::MeshXyYx);
        let sys = wctx.sys.clone();
        let mesh_tm = wctx.traffic_on(model.clone(), &mesh_sys);
        let tm = wctx.traffic_on(model.clone(), &sys);
        let mut cfg = wctx.trace_cfg();
        // 144-tile chips x 4 chip counts: keep the smoke budget small
        cfg.scale = cfg.scale.min(0.005);

        // exec(1) per NoC anchors the scaled speedup
        let mut base = [0.0f64; 2];
        let mut overhead = Vec::new();
        let mut iter_ms = Vec::new();
        let mut speedups = Vec::new();
        for &chips in CHIPS.iter() {
            let fab = fabric_for(chips, Collective::Ring);
            let m = cosimulate_fabric(&mesh_sys, &mesh_tm, &sched, &fab, grad, &[&mesh], &cfg)
                .expect("mesh fabric cosimulation runs");
            let h = cosimulate_fabric(&sys, &tm, &sched, &fab, grad, &[&wihet], &cfg)
                .expect("wihetnoc fabric cosimulation runs");
            let (m, h) = (&m.per_noc[0], &h.per_noc[0]);
            if chips == 1 {
                base = [m.exec_seconds, h.exec_seconds];
            }
            for (r, b) in [(m, base[0]), (h, base[1])] {
                let speedup = chips as f64 * b / r.exec_seconds;
                let alg = if chips == 1 { "-" } else { "ring" };
                out.push_str(&format!(
                    "  {:<9} {:>5}  {:<5}  {:<12}  {:>8.3}  {:>9.2}  {:>7.3}  {:>14.3}\n",
                    name,
                    chips,
                    r.noc,
                    alg,
                    r.exec_seconds * 1e3,
                    r.comm_overhead_pct,
                    speedup,
                    h.exec_seconds / m.exec_seconds,
                ));
                rows.push(vec![
                    Cell::str(name),
                    Cell::num(chips as f64),
                    Cell::str(r.noc.clone()),
                    Cell::str(alg),
                    Cell::num(r.exec_seconds),
                    Cell::num(r.comm_overhead_pct),
                    Cell::num(speedup),
                ]);
                csv.push_str(&format!(
                    "{},{},{},{},{:.6e},{:.4},{:.4},{:.6e},{:.6e}\n",
                    name,
                    chips,
                    r.noc,
                    alg,
                    r.exec_seconds,
                    r.comm_overhead_pct,
                    speedup,
                    r.interchip_j,
                    r.fabric_edp,
                ));
            }
            overhead.push(h.comm_overhead_pct);
            iter_ms.push(h.exec_seconds * 1e3);
            speedups.push(chips as f64 * base[1] / h.exec_seconds);
        }

        // ring vs hierarchical on the WiHetNoC (hierarchical pairs chips,
        // so the single-chip point is the same degenerate path)
        for &chips in &CHIPS[1..] {
            let fab = fabric_for(chips, Collective::Hierarchical);
            let h = cosimulate_fabric(&sys, &tm, &sched, &fab, grad, &[&wihet], &cfg)
                .expect("hierarchical fabric cosimulation runs");
            let r = &h.per_noc[0];
            let speedup = chips as f64 * base[1] / r.exec_seconds;
            out.push_str(&format!(
                "  {:<9} {:>5}  {:<5}  {:<12}  {:>8.3}  {:>9.2}  {:>7.3}  {:>14}\n",
                name,
                chips,
                r.noc,
                "hierarchical",
                r.exec_seconds * 1e3,
                r.comm_overhead_pct,
                speedup,
                "-",
            ));
            rows.push(vec![
                Cell::str(name),
                Cell::num(chips as f64),
                Cell::str(r.noc.clone()),
                Cell::str("hierarchical"),
                Cell::num(r.exec_seconds),
                Cell::num(r.comm_overhead_pct),
                Cell::num(speedup),
            ]);
            csv.push_str(&format!(
                "{},{},{},hierarchical,{:.6e},{:.4},{:.4},{:.6e},{:.6e}\n",
                name, chips, r.noc, r.exec_seconds, r.comm_overhead_pct, speedup,
                r.interchip_j, r.fabric_edp,
            ));
        }

        let labels: Vec<String> = CHIPS.iter().map(|c| c.to_string()).collect();
        rep.series(format!("{name}_comm_overhead_pct"), "%", labels.clone(), overhead.clone());
        rep.series(format!("{name}_iteration_ms"), "ms", labels.clone(), iter_ms);
        rep.series(format!("{name}_speedup"), "x", labels, speedups.clone());
        if name == "alexnet" {
            rep.scalar("alexnet_comm_overhead_n8_pct", overhead[3], "%");
            rep.scalar("alexnet_speedup_n4", speedups[2], "x");
        }
    }
    rep.table(
        "fabric_scaling",
        &["model", "chips", "noc", "algorithm", "exec_seconds", "comm_overhead_pct", "speedup"],
        rows,
    );
    rep.artifact("rows.csv", csv);
    out.push_str(
        "\n(sweep rows attached as the scale_figs.rows.csv artifact; write it with --out DIR)\n",
    );
    rep.set_text(out);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ctx::Effort;
    use crate::noc::builder::NocKind;

    /// The full harness designs two 144-tile NoCs — exercised by the CI
    /// bench job. Here: alexnet on the cheap mesh baseline only, end to
    /// end through the fabric cosim layer, pinning the acceptance shape:
    /// overhead strictly grows with the chip count, and the scaled
    /// speedup at N=4 beats a single chip.
    #[test]
    fn fabric_scaling_shape_on_12x12_smoke() {
        let platform: Platform = PLATFORM.parse().unwrap();
        let model: ModelId = "alexnet".parse().unwrap();
        let grad = model.spec().total_weight_bytes();
        let sched = SchedulePolicy::OneFOneB { microbatches: 8 };
        let sc = Scenario::new(platform, model.clone())
            .with_mapping(MappingPolicy::LayerPipelined { stages: 4 })
            .with_schedule(sched)
            .with_effort(Effort::Quick)
            .with_seed(7)
            .with_batch(BATCH);
        let mut wctx = Ctx::for_scenario(&sc).unwrap();
        let mesh = wctx.instance_arc(NocKind::MeshXyYx);
        let mesh_sys = wctx.sys_for(NocKind::MeshXyYx);
        let tm = wctx.traffic_on(model, &mesh_sys);
        let mut cfg = wctx.trace_cfg();
        cfg.scale = 0.002;
        let mut base = 0.0;
        let mut prev = -1.0f64;
        for chips in CHIPS {
            let fab = fabric_for(chips, Collective::Ring);
            let rep =
                cosimulate_fabric(&mesh_sys, &tm, &sched, &fab, grad, &[&mesh], &cfg).unwrap();
            let r = &rep.per_noc[0];
            assert_eq!(r.fabric_chips, chips);
            assert!(r.comm_overhead_pct > prev, "overhead must grow with chips");
            prev = r.comm_overhead_pct;
            if chips == 1 {
                base = r.exec_seconds;
            }
            if chips == 4 {
                let speedup = 4.0 * base / r.exec_seconds;
                assert!(speedup > 1.0, "speedup(4) = {speedup}");
            }
        }
    }
}

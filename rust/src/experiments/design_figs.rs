//! Design-search observability (non-paper extension): AMOSA convergence
//! curves, Pareto-front snapshots, and a deterministic eval-attribution
//! profile of the full design flow — the measurement groundwork for
//! ROADMAP item 3's surrogate fast path.
//!
//! The harness runs the two AMOSA searches a full comparison needs — the
//! mesh CPU/MC placement (§5.2) and the WiHetNoC wireline optimization
//! (Eqn 6-9) — with a [`SearchObserver`] attached, plus the greedy
//! wireless-interface placement with its evaluation counter, and reports
//!
//! * **convergence curves**: best-so-far hypervolume vs cumulative
//!   evaluations per temperature level (monotone non-decreasing by
//!   construction — the observer keeps its own non-dominated front),
//! * headline scalar `evals_to_99pct_hypervolume`: evaluations the
//!   wireline search needed to reach 99% of its final hypervolume,
//! * `evals_after_front_stable_pct`: the share of AMOSA evaluations
//!   spent after the front last moved — the quantitative case for a
//!   surrogate-guided early stop,
//! * the eval-attribution table across stages, and the full
//!   `search_trace.json` artifact (schema-validated, same document the
//!   CLI's `design --search-trace` writes).
//!
//! Everything is deterministic given (effort, seed): the searches are
//! re-run here explicitly (never served from the [`Ctx`] caches, which
//! would skip the search and yield an empty trace).

use super::ctx::Ctx;
use super::report::{Cell, Report};
use crate::noc::builder::{optimize_wireline_observed, wireline_stage_name, DesignConfig};
use crate::optim::amosa::SearchObserver;
use crate::optim::placement::optimize_placement_observed;
use crate::optim::wiplace::build_wireless_counted;
use crate::telemetry::search::{validate_search_trace, SearchStage, SearchTrace};

/// Convergence series + eval profile of the design search.
pub fn design_figs(ctx: &mut Ctx) -> Report {
    let mut rep = Report::new(
        "design_figs",
        "AMOSA convergence, Pareto snapshots, and design-search eval attribution",
    );
    let model = ctx.model();
    let fij = ctx.fij(model);
    let sys = ctx.sys.clone();
    // Local observers, not the Ctx sink: this harness packages the
    // stages itself (and must not double-record into an attached sink).
    let cfg = DesignConfig { observer: None, ..ctx.design_cfg() };

    let mut pl_obs = SearchObserver::new();
    let _placed = optimize_placement_observed(&sys, ctx.seed, Some(&mut pl_obs));

    let mut wl_obs = SearchObserver::new();
    let topo = optimize_wireline_observed(&sys, &fij, &cfg, Some(&mut wl_obs));

    let (_air, wi_evals) = build_wireless_counted(
        &topo,
        &fij,
        &sys.cpus(),
        &sys.mcs(),
        cfg.n_wi,
        cfg.gpu_channels,
    );

    let wl_key = wireline_stage_name(&cfg);
    let mut trace = SearchTrace::new();
    trace.record(SearchStage::from_observer("placement", &pl_obs));
    trace.record(SearchStage::from_observer(wl_key.clone(), &wl_obs));
    trace.record(SearchStage::flat("wireless", wi_evals));
    let doc = trace.to_json();
    validate_search_trace(&doc).expect("trace is valid by construction");

    let mut out = format!(
        "Design figs — where the design search spends its ~10^5 evaluations\n\
         (workload {}, seed {}; hypervolume = exact 2-objective area of the\n\
          observer's best-so-far front vs a seed-derived reference point)\n\n",
        ctx.model(),
        ctx.seed
    );
    out.push_str(&trace.profile_text());

    // -- convergence curves (hypervolume vs cumulative evals) ----------
    let mut attribution_rows = Vec::new();
    let mut amosa_evals = 0u64;
    let mut amosa_stale = 0u64;
    for (series_name, key) in
        [("placement_hv_vs_evals", "placement"), ("wireline_hv_vs_evals", wl_key.as_str())]
    {
        let stage = trace.stage(key).expect("stage recorded above");
        let labels: Vec<String> = stage.levels.iter().map(|l| l.evals.to_string()).collect();
        let values: Vec<f64> = stage.levels.iter().map(|l| l.hypervolume).collect();
        rep.series(series_name, "hypervolume", labels, values);
        amosa_evals += stage.evals;
        amosa_stale += stage.evals_after_front_stable();
    }
    for stage in trace.stages() {
        attribution_rows.push(vec![
            Cell::str(stage.stage.as_str()),
            Cell::num(stage.evals as f64),
            Cell::num(100.0 * stage.evals as f64 / trace.total_evals().max(1) as f64),
            Cell::num(stage.levels.len() as f64),
            Cell::num(stage.final_hypervolume()),
            Cell::num(stage.evals_after_front_stable() as f64),
        ]);
    }

    // -- headline scalars ----------------------------------------------
    let wl_stage = trace.stage(&wl_key).expect("wireline stage recorded");
    let pl_stage = trace.stage("placement").expect("placement stage recorded");
    // Finite fallback: a degenerate (zero-hypervolume) search counts as
    // "converged only at the end" rather than poisoning the headline.
    let to99 = wl_stage.evals_to_hv_fraction(0.99).unwrap_or(wl_stage.evals);
    rep.scalar("evals_to_99pct_hypervolume", to99 as f64, "evals");
    rep.scalar(
        "placement_evals_to_99pct_hypervolume",
        pl_stage.evals_to_hv_fraction(0.99).unwrap_or(pl_stage.evals) as f64,
        "evals",
    );
    rep.scalar("total_evals", trace.total_evals() as f64, "evals");
    rep.scalar(
        "wireline_eval_share_pct",
        100.0 * wl_stage.evals as f64 / trace.total_evals().max(1) as f64,
        "%",
    );
    let stale_pct = 100.0 * amosa_stale as f64 / amosa_evals.max(1) as f64;
    rep.scalar("evals_after_front_stable_pct", stale_pct, "%");
    rep.scalar("wireline_final_hypervolume", wl_stage.final_hypervolume(), "hv");

    rep.table(
        "eval_attribution",
        &["stage", "evals", "share_pct", "levels", "final_hv", "evals_after_stable"],
        attribution_rows,
    );
    rep.artifact("search_trace.json", doc.dump() + "\n");
    rep.artifact("search_trace.csv", trace.to_csv());

    out.push_str(&format!(
        "\n  wireline search reaches 99% of its final hypervolume after {to99} of {}\n  \
         evals; {stale_pct:.1}% of all AMOSA evals land after the front stops moving —\n  \
         the budget a surrogate early-stop (ROADMAP item 3) could reclaim.\n  \
         (search_trace.json / search_trace.csv attached as artifacts)\n",
        wl_stage.evals,
    ));
    rep.set_text(out);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ctx::Effort;
    use crate::util::json::parse;

    /// End-to-end at quick effort: finite headline, monotone convergence
    /// series, and a schema-valid artifact that round-trips the parser.
    #[test]
    fn design_figs_headlines_and_artifact() {
        let mut ctx = Ctx::new(Effort::Quick, 5);
        let rep = design_figs(&mut ctx);
        let scalars: std::collections::HashMap<&str, f64> = rep.scalars().collect();
        let to99 = scalars["evals_to_99pct_hypervolume"];
        assert!(to99.is_finite() && to99 > 0.0);
        assert!(scalars["total_evals"] > to99);
        let stale = scalars["evals_after_front_stable_pct"];
        assert!((0.0..=100.0).contains(&stale), "{stale}");
        let art = rep
            .artifacts
            .iter()
            .find(|a| a.name == "search_trace.json")
            .expect("trace artifact attached");
        validate_search_trace(&parse(&art.content).unwrap()).unwrap();
        assert!(rep.to_text().starts_with("Design figs"));
    }
}

//! Fig 17/19-style head-to-head promoted to **non-paper workloads**:
//! mesh vs the hybrid WiHetNoC for `alexnet` and `vgg11` on a 144-tile
//! chip (`12x12:cpus=8,mcs=8,placement=corners`), across training
//! schedules (`serial`, `gpipe:8`, `1f1b:8`) under a `pipeline:4`
//! mapping.
//!
//! This is the ROADMAP "promote more figures to non-paper workloads"
//! item: every piece — DSL preset, mapping, lowering, AMOSA design,
//! timeline expansion, gated concurrent simulation, energy/EDP — runs
//! through the same pipeline as the paper figures, just on a chip and
//! CNNs the paper never evaluated.
//!
//! Besides the table, the report attaches the comparison rows as a
//! machine-readable CSV artifact (`workload_figs.rows.csv` under
//! `experiment workload_figs --out DIR`; CI uploads it).

use super::ctx::Ctx;
use super::report::{Cell, Report};
use crate::coordinator::cosim::cosimulate_scheduled;
use crate::noc::builder::NocKind;
use crate::scenario::{ModelId, Scenario};
use crate::schedule::SchedulePolicy;
use crate::workload::MappingPolicy;
use crate::Platform;

const PLATFORM: &str = "12x12:cpus=8,mcs=8,placement=corners";
const BATCH: usize = 16;

fn schedules() -> [SchedulePolicy; 3] {
    [
        SchedulePolicy::Serial,
        SchedulePolicy::GPipe { microbatches: 8 },
        SchedulePolicy::OneFOneB { microbatches: 8 },
    ]
}

/// The workload comparison: one table row per (model, schedule), hybrid
/// normalized to the mesh, plus the hybrid's timeline metrics.
pub fn workload_figs(ctx: &mut Ctx) -> Report {
    let mut rep =
        Report::new("workload_figs", "mesh vs WiHetNoC on non-paper workloads x schedules");
    let platform: Platform = PLATFORM.parse().expect("well-formed platform literal");
    let mut out = format!(
        "Workload figs — mesh vs WiHetNoC on {PLATFORM} (mapping pipeline:4, batch {BATCH})\n\
         (fig17/fig19 methodology on non-paper workloads; schedules overlap microbatch phases)\n\n  \
         model     schedule   exec(hyb/mesh)  EDP(hyb/mesh)  bubble  speedup-vs-serial\n"
    );
    let mut csv = String::from(
        "model,schedule,noc,exec_seconds,edp_js,bubble_fraction,speedup_vs_serial\n",
    );
    let mut rows = Vec::new();
    for name in ["alexnet", "vgg11"] {
        let model: ModelId = name.parse().expect("preset exists");
        let sc = Scenario::new(platform, model.clone())
            .with_mapping(MappingPolicy::LayerPipelined { stages: 4 })
            .with_effort(ctx.effort)
            .with_seed(ctx.seed)
            .with_batch(BATCH);
        let mut wctx = Ctx::for_scenario(&sc).expect("scenario is valid");
        let mesh = wctx.instance_arc(NocKind::MeshXyYx);
        let wihet = wctx.instance_arc(NocKind::WiHetNoc);
        let mesh_sys = wctx.sys_for(NocKind::MeshXyYx);
        let sys = wctx.sys.clone();
        let mesh_tm = wctx.traffic_on(model.clone(), &mesh_sys);
        let tm = wctx.traffic_on(model.clone(), &sys);
        let mut cfg = wctx.trace_cfg();
        // heavy workloads on a 144-tile chip: keep the smoke budget small
        cfg.scale = cfg.scale.min(0.01);
        for sched in schedules() {
            let m = cosimulate_scheduled(&mesh_sys, &mesh_tm, &sched, &[&mesh], &cfg)
                .expect("mesh cosimulation runs");
            let h = cosimulate_scheduled(&sys, &tm, &sched, &[&wihet], &cfg)
                .expect("wihetnoc cosimulation runs");
            let (m, h) = (&m.per_noc[0], &h.per_noc[0]);
            out.push_str(&format!(
                "  {:<9} {:<10} {:>12.3}  {:>13.3}  {:>6.3}  {:>17.3}\n",
                name,
                sched.to_string(),
                h.exec_seconds / m.exec_seconds,
                h.edp / m.edp,
                h.bubble_fraction,
                h.speedup_vs_serial,
            ));
            rows.push(vec![
                Cell::str(name),
                Cell::str(sched.to_string()),
                Cell::num(h.exec_seconds / m.exec_seconds),
                Cell::num(h.edp / m.edp),
                Cell::num(h.bubble_fraction),
                Cell::num(h.speedup_vs_serial),
            ]);
            for sim in [m, h] {
                csv.push_str(&format!(
                    "{},{},{},{:.6e},{:.6e},{:.4},{:.4}\n",
                    name,
                    sched,
                    sim.noc,
                    sim.exec_seconds,
                    sim.edp,
                    sim.bubble_fraction,
                    sim.speedup_vs_serial,
                ));
            }
        }
    }
    rep.table(
        "hybrid_over_mesh",
        &["model", "schedule", "exec_ratio", "edp_ratio", "bubble_fraction", "speedup_vs_serial"],
        rows,
    );
    rep.artifact("rows.csv", csv);
    out.push_str(
        "\n(comparison rows attached as the workload_figs.rows.csv artifact; write it with --out DIR)\n",
    );
    rep.set_text(out);
    rep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::ctx::Effort;

    /// The full harness designs two 144-tile NoCs — exercised by the CI
    /// bench job. Here: one model, one overlapped schedule, on the cheap
    /// mesh baseline only, end to end through the cosim layer.
    #[test]
    fn scheduled_cosim_on_12x12_smoke() {
        let platform: Platform = PLATFORM.parse().unwrap();
        let model: ModelId = "alexnet".parse().unwrap();
        let sc = Scenario::new(platform, model.clone())
            .with_mapping(MappingPolicy::LayerPipelined { stages: 4 })
            .with_effort(Effort::Quick)
            .with_seed(7)
            .with_batch(BATCH);
        let mut wctx = Ctx::for_scenario(&sc).unwrap();
        let mesh = wctx.instance_arc(NocKind::MeshXyYx);
        let mesh_sys = wctx.sys_for(NocKind::MeshXyYx);
        let tm = wctx.traffic_on(model, &mesh_sys);
        let mut cfg = wctx.trace_cfg();
        cfg.scale = 0.002;
        let sched = SchedulePolicy::GPipe { microbatches: 8 };
        let rep = cosimulate_scheduled(&mesh_sys, &tm, &sched, &[&mesh], &cfg).unwrap();
        let r = &rep.per_noc[0];
        assert_eq!(r.schedule, "gpipe:8");
        assert!(r.exec_seconds > 0.0 && r.edp > 0.0);
        assert!((0.0..=1.0).contains(&r.bubble_fraction));
    }
}

//! Shared experiment context: caches the expensive pieces (AMOSA-optimized
//! topologies, traffic models, NoC instances) across figures so `all`
//! reuses one design per configuration — exactly like the paper, where a
//! single WiHetNoC is designed and then evaluated everywhere.
//!
//! Every cache is keyed by *typed* values: traffic by
//! [`ScenarioKey`] (workload x mapping x concrete tile placement),
//! instances by [`NocKind`]. Two placements (or mappings) can never
//! alias a cache entry the way the old string tags could.
//!
//! §Perf: every hot accessor hands out an `Arc` handle to the cached
//! value — a cache *hit* never deep-copies a `TrafficModel`, `Topology`,
//! `SystemConfig`, or `NocInstance` (route sets are O(n²) paths; the old
//! per-call clones dominated sweep time). `Arc` (not `Rc`) so handles
//! flow straight into [`crate::util::exec::par_map`] workers.

use std::collections::HashMap;
use std::sync::Arc;

use crate::error::WihetError;
use crate::fabric::Fabric;
use crate::faults::FaultPlan;
use crate::model::cnn::ModelSpec;
use crate::model::SystemConfig;
use crate::noc::analysis::TrafficMatrix;
use crate::noc::builder::{
    alash_routes, het_noc, mesh_opt, optimize_wireline, wi_het_noc_on, DesignConfig, NocInstance,
    NocKind,
};
use crate::noc::routing::RouteSet;
use crate::noc::topology::Topology;
use crate::optim::amosa::SearchObserver;
use crate::optim::placement::optimize_placement_observed;
use crate::optim::wiplace::build_wireless;
use crate::scenario::{ModelId, Scenario, ScenarioKey};
use crate::schedule::SchedulePolicy;
use crate::serving::ServingSpec;
use crate::telemetry::search::{record_stage, SearchSink, SearchStage};
use crate::traffic::phases::TrafficModel;
use crate::traffic::trace::TraceConfig;
use crate::util::exec::par_map;
use crate::workload::{lower_id, MappingPolicy};

pub use crate::scenario::Effort;

pub struct Ctx {
    pub effort: Effort,
    pub seed: u64,
    /// Training batch size. Private: the traffic cache is derived from
    /// it (and `ScenarioKey` does not carry it), so it is fixed at
    /// construction — mutating it mid-session would serve stale
    /// matrices.
    batch: usize,
    /// Design-input workload (the paper designs on LeNet's traffic).
    /// Private for the same reason: the `wireline` and `instances`
    /// caches are derived from it.
    model: ModelId,
    /// How workloads are laid out on the tiles (part of every traffic
    /// cache key). Private: fixed at construction like `batch`.
    mapping: MappingPolicy,
    /// How the iteration's phases overlap in time. Lowered traffic is
    /// schedule-independent (timeline expansion happens downstream), so
    /// within one Ctx the schedule never splits the traffic cache — it
    /// is carried into every [`ScenarioKey`] so keys derived here stay
    /// faithful to the scenario (and future schedule-dependent cached
    /// artifacts cannot alias). Private: fixed at construction like
    /// `batch`.
    schedule: SchedulePolicy,
    /// Multi-chip data-parallel fabric the scenario runs on. Lowered
    /// traffic is per-chip (every replica sees the same workload), so
    /// the fabric never splits the traffic cache — but it is carried
    /// into every [`ScenarioKey`] so keys stay faithful to the
    /// scenario. Private: fixed at construction like `batch`.
    fabric: Fabric,
    /// Fault plan the scenario's simulations run under. Lowered traffic
    /// is fault-independent (faults act at simulation time), so the plan
    /// never splits the traffic cache — it is carried into every
    /// [`ScenarioKey`] so keys stay faithful to the scenario. Private:
    /// fixed at construction like `batch`.
    faults: FaultPlan,
    /// Open-loop serving spec of the scenario. Lowered traffic is
    /// serving-independent (the serving runner lowers per-batch models
    /// itself), so the spec never splits the traffic cache — it is
    /// carried into every [`ScenarioKey`] so keys stay faithful to the
    /// scenario. Private: fixed at construction like `batch`.
    serving: ServingSpec,
    /// WiHetNoC tile placement (§5.2: CPUs center, MCs quadrant centers).
    /// Shared handle — cloning it is pointer-cheap.
    pub sys: Arc<SystemConfig>,
    /// AMOSA-optimized CPU/MC placement for the mesh baseline.
    mesh_sys: Option<Arc<SystemConfig>>,
    traffic: HashMap<ScenarioKey, Arc<TrafficModel>>,
    wireline: HashMap<usize, Arc<Topology>>, // per k_max
    instances: HashMap<NocKind, Arc<NocInstance>>,
    /// Optional design-search trace sink ([`Ctx::observe_search`]).
    /// Attached to every [`DesignConfig`] this context derives, so each
    /// search pass (mesh placement, per-k wireline AMOSA, greedy WI
    /// placement) deposits its convergence stage. `None` is the
    /// zero-overhead default; caches still apply, so attach the sink
    /// *before* the first design if the trace must cover it.
    search: Option<SearchSink>,
}

impl Ctx {
    /// Context on the paper's 8x8 platform with the LeNet design workload.
    pub fn new(effort: Effort, seed: u64) -> Self {
        Ctx::on_platform(SystemConfig::paper_8x8(), effort, seed)
    }

    /// Context on an explicit tile grid.
    pub fn on_platform(sys: SystemConfig, effort: Effort, seed: u64) -> Self {
        Ctx {
            effort,
            seed,
            batch: 32,
            model: ModelId::LeNet,
            mapping: MappingPolicy::default(),
            schedule: SchedulePolicy::default(),
            fabric: Fabric::single(),
            faults: FaultPlan::none(),
            serving: ServingSpec::none(),
            sys: Arc::new(sys),
            mesh_sys: None,
            traffic: HashMap::new(),
            wireline: HashMap::new(),
            instances: HashMap::new(),
            search: None,
        }
    }

    /// Attach a design-search trace sink: every optimization pass this
    /// context runs from now on records its convergence stage into
    /// `sink`. Read-only — designs are byte-identical with or without it
    /// (pinned by `tests/search_obs.rs`).
    pub fn observe_search(&mut self, sink: SearchSink) {
        self.search = Some(sink);
    }

    /// Context for a typed scenario: validates and builds the platform,
    /// and adopts the scenario's workload/mapping/effort/seed/batch. An
    /// unmappable scenario (e.g. more replicas than GPU tiles) fails
    /// here, at the boundary.
    pub fn for_scenario(sc: &Scenario) -> Result<Ctx, WihetError> {
        let sys = sc.platform.build()?;
        sc.mapping.validate_for(&sys, sc.batch)?;
        sc.schedule.validate_for(sc.batch)?;
        sc.fabric.validate()?;
        sc.faults.validate()?;
        sc.serving.validate()?;
        if !sc.serving.is_none() {
            // Serving injects open-loop forward traffic on one chip's
            // clock: a multi-chip fabric or an overlapping training
            // schedule has no meaning for it.
            if !sc.fabric.is_single() {
                return Err(WihetError::InvalidArg(format!(
                    "--serve runs on a single chip; drop the fabric (got {})",
                    sc.fabric
                )));
            }
            if !sc.schedule.is_serial() {
                return Err(WihetError::InvalidArg(format!(
                    "--serve replaces the training schedule; use schedule=serial (got {})",
                    sc.schedule
                )));
            }
        }
        let mut ctx = Ctx::on_platform(sys, sc.effort, sc.seed);
        ctx.model = sc.model.clone();
        ctx.batch = sc.batch;
        ctx.mapping = sc.mapping;
        ctx.schedule = sc.schedule;
        ctx.fabric = sc.fabric;
        ctx.faults = sc.faults.clone();
        ctx.serving = sc.serving.clone();
        Ok(ctx)
    }

    /// The design-input workload this context was built for.
    pub fn model(&self) -> ModelId {
        self.model.clone()
    }

    /// The mapping policy every traffic model is lowered with.
    pub fn mapping(&self) -> MappingPolicy {
        self.mapping
    }

    /// The schedule the scenario's training timeline runs under.
    pub fn schedule(&self) -> SchedulePolicy {
        self.schedule
    }

    /// The multi-chip fabric the scenario replicates over.
    pub fn fabric(&self) -> Fabric {
        self.fabric
    }

    /// The fault plan the scenario's simulations run under.
    pub fn faults(&self) -> &FaultPlan {
        &self.faults
    }

    /// The open-loop serving spec of the scenario ([`ServingSpec::none`]
    /// for the closed-loop training scenarios).
    pub fn serving(&self) -> &ServingSpec {
        &self.serving
    }

    /// The batch size the traffic models are derived at.
    pub fn batch(&self) -> usize {
        self.batch
    }

    pub fn spec(&self, model: ModelId) -> ModelSpec {
        model.spec()
    }

    pub fn design_cfg(&self) -> DesignConfig {
        DesignConfig {
            observer: self.search.clone(),
            ..DesignConfig::scaled(&self.sys, self.effort, self.seed)
        }
    }

    pub fn trace_cfg(&self) -> TraceConfig {
        TraceConfig {
            scale: match self.effort {
                Effort::Quick => 0.05,
                Effort::Full => 0.5,
            },
            burst_duty: 0.5,
            seed: self.seed ^ 0x7ACE,
        }
    }

    /// Mesh-baseline system (AMOSA CPU/MC placement, cached; shared
    /// handle on hits).
    pub fn mesh_sys(&mut self) -> Arc<SystemConfig> {
        if self.mesh_sys.is_none() {
            let mut obs = self.search.as_ref().map(|_| SearchObserver::new());
            let placed = optimize_placement_observed(&self.sys, self.seed, obs.as_mut());
            if let (Some(sink), Some(obs)) = (&self.search, &obs) {
                record_stage(sink, SearchStage::from_observer("placement", obs));
            }
            self.mesh_sys = Some(Arc::new(placed));
        }
        self.mesh_sys.clone().unwrap()
    }

    /// Traffic model for `model` on a given system placement, lowered
    /// with the context's mapping policy. The cache key is derived from
    /// the placement (and mapping) itself, so distinct placements or
    /// mappings can never serve each other's (stale) matrices. Hits
    /// return a shared handle, never a copy.
    ///
    /// `sys` must offer at least the GPU tiles the context's mapping was
    /// validated against (every placement a `Ctx` derives — the §5.2
    /// placement and its mesh-optimized permutation — preserves tile
    /// counts, so this holds for all internal callers; handing in an
    /// unrelated smaller chip is a caller bug and panics).
    pub fn traffic_on(&mut self, model: ModelId, sys: &SystemConfig) -> Arc<TrafficModel> {
        let key = ScenarioKey::with_serving(
            model,
            sys,
            self.mapping,
            self.schedule,
            self.fabric,
            self.faults.clone(),
            self.serving.clone(),
        );
        if !self.traffic.contains_key(&key) {
            let tm = lower_id(&key.model, &self.mapping, sys, self.batch)
                .expect("mapping validated at construction fits every Ctx-derived placement");
            self.traffic.insert(key.clone(), Arc::new(tm));
        }
        self.traffic[&key].clone()
    }

    pub fn traffic(&mut self, model: ModelId) -> Arc<TrafficModel> {
        let sys = self.sys.clone();
        self.traffic_on(model, &sys)
    }

    /// Number of distinct (workload, placement) traffic models cached —
    /// exposed for cache-correctness tests.
    pub fn cached_traffic_models(&self) -> usize {
        self.traffic.len()
    }

    /// Aggregate f_ij of the design workload on the WiHetNoC placement
    /// (the design input — the paper optimizes on the traffic pattern,
    /// not per-layer).
    pub fn fij(&mut self, model: ModelId) -> TrafficMatrix {
        let sys = self.sys.clone();
        self.traffic(model).fij(&sys)
    }

    /// Optimized irregular wireline topology for `k_max` (cached; shared
    /// handle on hits).
    pub fn wireline(&mut self, k_max: usize) -> Arc<Topology> {
        self.wirelines(&[k_max]).pop().expect("one k_max in, one topology out")
    }

    /// Optimized wireline topologies for several `k_max` values at once.
    /// Missing cache entries are optimized **in parallel** over
    /// [`par_map`] workers — each `k_max` is an independent AMOSA run
    /// with its own derived seed (`seed + k_max`, exactly what the serial
    /// path used), so the resulting topologies are byte-identical at any
    /// `WIHETNOC_THREADS`. Returns one shared handle per requested
    /// `k_max`, in input order.
    pub fn wirelines(&mut self, k_maxes: &[usize]) -> Vec<Arc<Topology>> {
        let mut missing: Vec<usize> = k_maxes
            .iter()
            .copied()
            .filter(|k| !self.wireline.contains_key(k))
            .collect();
        missing.sort_unstable();
        missing.dedup();
        if !missing.is_empty() {
            let model = self.model();
            let fij = self.fij(model);
            let base_cfg = self.design_cfg();
            let sys = self.sys.clone();
            let seed = self.seed;
            let topos = par_map(&missing, |_, &k_max| {
                let mut cfg = base_cfg.clone();
                cfg.k_max = k_max;
                cfg.seed = seed.wrapping_add(k_max as u64);
                optimize_wireline(&sys, &fij, &cfg)
            });
            for (k_max, topo) in missing.into_iter().zip(topos) {
                self.wireline.insert(k_max, Arc::new(topo));
            }
        }
        k_maxes.iter().map(|k| self.wireline[k].clone()).collect()
    }

    /// The four headline NoC instances, cached by kind.
    pub fn instance(&mut self, kind: NocKind) -> &NocInstance {
        if !self.instances.contains_key(&kind) {
            let model = self.model.clone();
            let inst = match kind {
                NocKind::MeshXy => {
                    let sys = self.mesh_sys();
                    mesh_opt(&sys, false)
                }
                NocKind::MeshXyYx => {
                    let sys = self.mesh_sys();
                    mesh_opt(&sys, true)
                }
                NocKind::HetNoc => {
                    let fij = self.fij(model);
                    let cfg = self.design_cfg();
                    het_noc(&self.sys, &fij, &cfg)
                }
                NocKind::WiHetNoc => {
                    let topo = self.wireline(self.design_cfg().k_max);
                    let fij = self.fij(model);
                    let cfg = self.design_cfg();
                    wi_het_noc_on(&self.sys, &fij, &cfg, topo)
                }
            };
            self.instances.insert(kind, Arc::new(inst));
        }
        &self.instances[&kind]
    }

    /// Shared handle to a cached instance (for call sites that also need
    /// `&mut self` while holding the instance, and for `par_map` jobs).
    /// Replaces the old deep-cloning `instance_cloned`.
    pub fn instance_arc(&mut self, kind: NocKind) -> Arc<NocInstance> {
        self.instance(kind);
        self.instances[&kind].clone()
    }

    /// WiHetNoC variant with a custom WI count / channel count on the
    /// cached k_max=default wireline topology (Figs 12-13 sweeps). The
    /// wireline graph is shared with the cache, not copied.
    pub fn wihet_variant(&mut self, n_wi: usize, gpu_channels: usize) -> NocInstance {
        let topo = self.wireline(self.design_cfg().k_max);
        let model = self.model.clone();
        let fij = self.fij(model);
        variant_on(&self.sys, topo, &fij, n_wi, gpu_channels)
    }

    /// The system placement an instance should be simulated on (shared
    /// handle).
    pub fn sys_for(&mut self, kind: NocKind) -> Arc<SystemConfig> {
        if kind.uses_mesh_placement() {
            self.mesh_sys()
        } else {
            self.sys.clone()
        }
    }
}

/// Assemble a WiHetNoC variant (WI count x GPU channels) on a shared
/// wireline topology. Pure — safe to call from `par_map` jobs.
pub fn variant_on(
    sys: &SystemConfig,
    topo: Arc<Topology>,
    fij: &TrafficMatrix,
    n_wi: usize,
    gpu_channels: usize,
) -> NocInstance {
    let air = build_wireless(&topo, fij, &sys.cpus(), &sys.mcs(), n_wi, gpu_channels);
    let routes: RouteSet = alash_routes(sys, &topo, &air, fij);
    NocInstance { kind: NocKind::WiHetNoc, topo, routes, air }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_caches_instances() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let a = ctx.instance(NocKind::MeshXy).topo.links.len();
        let b = ctx.instance(NocKind::MeshXy).topo.links.len();
        assert_eq!(a, b);
        assert_eq!(a, 112);
    }

    #[test]
    fn cache_hits_share_not_copy() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let t1 = ctx.wireline(4);
        let t2 = ctx.wireline(4);
        assert!(Arc::ptr_eq(&t1, &t2), "wireline hit must share the graph");
        let m1 = ctx.traffic(ModelId::LeNet);
        let m2 = ctx.traffic(ModelId::LeNet);
        assert!(Arc::ptr_eq(&m1, &m2), "traffic hit must share the model");
        let i1 = ctx.instance_arc(NocKind::MeshXy);
        let i2 = ctx.instance_arc(NocKind::MeshXy);
        assert!(Arc::ptr_eq(&i1, &i2), "instance hit must share");
        let s1 = ctx.mesh_sys();
        let s2 = ctx.sys_for(NocKind::MeshXy);
        assert!(Arc::ptr_eq(&s1, &s2), "mesh placement hit must share");
    }

    #[test]
    fn wireline_cached_per_kmax() {
        let mut ctx = Ctx::new(Effort::Quick, 2);
        let t4 = ctx.wireline(4);
        let t4b = ctx.wireline(4);
        assert_eq!(t4.edges(), t4b.edges());
        assert!(t4.k_max() <= 4);
        let t6 = ctx.wireline(6);
        assert!(t6.k_max() <= 6);
    }

    #[test]
    fn variant_builder() {
        let mut ctx = Ctx::new(Effort::Quick, 3);
        let v = ctx.wihet_variant(8, 2);
        assert_eq!(v.air.num_channels, 3);
        assert_eq!(v.air.wis.len(), 8 + 8);
        // the variant rides the cached wireline graph, not a copy
        let cached = ctx.wireline(ctx.design_cfg().k_max);
        assert!(Arc::ptr_eq(&v.topo, &cached));
    }

    #[test]
    fn traffic_cache_keyed_by_placement_not_tag() {
        // Regression: the old cache was keyed by (model, string tag), so
        // two placements sharing a tag returned stale matrices.
        let mut ctx = Ctx::new(Effort::Quick, 4);
        let wihet_sys = ctx.sys.clone();
        let mut tiles = wihet_sys.tiles.clone();
        tiles.swap(0, 27); // move a CPU to the corner: same tag, new placement
        let other_sys = wihet_sys.with_tiles(tiles);
        let _ = ctx.traffic_on(ModelId::LeNet, &wihet_sys);
        assert_eq!(ctx.cached_traffic_models(), 1);
        let _ = ctx.traffic_on(ModelId::LeNet, &wihet_sys);
        assert_eq!(ctx.cached_traffic_models(), 1, "same placement must hit");
        let _ = ctx.traffic_on(ModelId::LeNet, &other_sys);
        assert_eq!(
            ctx.cached_traffic_models(),
            2,
            "distinct placement must not alias"
        );
        let _ = ctx.traffic_on(ModelId::CdbNet, &wihet_sys);
        assert_eq!(ctx.cached_traffic_models(), 3);
    }

    #[test]
    fn for_scenario_validates_serving() {
        let sc = crate::scenario::Scenario::paper()
            .with_serving("poisson:rate=0.5".parse().unwrap());
        let ctx = Ctx::for_scenario(&sc).unwrap();
        assert!(!ctx.serving().is_none());
        assert_eq!(ctx.serving(), &sc.serving);
        let fabric = Ctx::for_scenario(&sc.clone().with_fabric("4:topo=ring".parse().unwrap()));
        assert!(matches!(fabric, Err(WihetError::InvalidArg(_))), "serving + fabric");
        let sched = Ctx::for_scenario(
            &sc.with_schedule(SchedulePolicy::GPipe { microbatches: 4 }),
        );
        assert!(matches!(sched, Err(WihetError::InvalidArg(_))), "serving + pipeline");
        // serving-off contexts default to the none spec
        let plain = Ctx::new(Effort::Quick, 1);
        assert!(plain.serving().is_none());
    }

    #[test]
    fn for_scenario_builds_non_paper_platforms() {
        let sc = crate::scenario::Scenario::new(
            "4x4".parse().unwrap(),
            ModelId::CdbNet,
        )
        .with_seed(9);
        let mut ctx = Ctx::for_scenario(&sc).unwrap();
        assert_eq!(ctx.sys.num_tiles(), 16);
        assert_eq!(ctx.model, ModelId::CdbNet);
        let inst = ctx.instance_arc(NocKind::MeshXyYx);
        assert_eq!(inst.topo.links.len(), 24);
    }
}

//! Shared experiment context: caches the expensive pieces (AMOSA-optimized
//! topologies, traffic models, NoC instances) across figures so `all`
//! reuses one design per configuration — exactly like the paper, where a
//! single WiHetNoC is designed and then evaluated everywhere.

use std::collections::HashMap;

use crate::model::cnn::ModelSpec;
use crate::model::{cdbnet, lenet, SystemConfig};
use crate::noc::analysis::TrafficMatrix;
use crate::noc::builder::{
    alash_routes, het_noc, mesh_opt, optimize_wireline, wi_het_noc_on, DesignConfig, NocInstance,
};
use crate::noc::routing::RouteSet;
use crate::noc::topology::Topology;
use crate::optim::placement::optimize_placement;
use crate::optim::wiplace::build_wireless;
use crate::traffic::phases::{model_phases, TrafficModel};
use crate::traffic::trace::TraceConfig;

/// Simulation/optimization effort level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Effort {
    /// CI-grade: tiny AMOSA budgets, heavily downsampled traces.
    Quick,
    /// Paper-grade: full budgets (used for EXPERIMENTS.md numbers).
    Full,
}

pub struct Ctx {
    pub effort: Effort,
    pub seed: u64,
    pub batch: usize,
    /// WiHetNoC tile placement (§5.2: CPUs center, MCs quadrant centers).
    pub sys: SystemConfig,
    /// AMOSA-optimized CPU/MC placement for the mesh baseline.
    mesh_sys: Option<SystemConfig>,
    traffic: HashMap<(String, String), TrafficModel>, // (model, sys tag)
    wireline: HashMap<usize, Topology>,               // per k_max
    instances: HashMap<String, NocInstance>,
}

impl Ctx {
    pub fn new(effort: Effort, seed: u64) -> Self {
        Ctx {
            effort,
            seed,
            batch: 32,
            sys: SystemConfig::paper_8x8(),
            mesh_sys: None,
            traffic: HashMap::new(),
            wireline: HashMap::new(),
            instances: HashMap::new(),
        }
    }

    pub fn spec(&self, model: &str) -> ModelSpec {
        match model {
            "lenet" => lenet(),
            "cdbnet" => cdbnet(),
            other => panic!("unknown model {other}"),
        }
    }

    pub fn design_cfg(&self) -> DesignConfig {
        match self.effort {
            Effort::Quick => DesignConfig::quick(self.seed),
            Effort::Full => DesignConfig { seed: self.seed, ..DesignConfig::default() },
        }
    }

    pub fn trace_cfg(&self) -> TraceConfig {
        TraceConfig {
            scale: match self.effort {
                Effort::Quick => 0.05,
                Effort::Full => 0.5,
            },
            burst_duty: 0.5,
            seed: self.seed ^ 0x7ACE,
        }
    }

    /// Mesh-baseline system (AMOSA CPU/MC placement, cached).
    pub fn mesh_sys(&mut self) -> SystemConfig {
        if self.mesh_sys.is_none() {
            self.mesh_sys = Some(optimize_placement(&self.sys, self.seed));
        }
        self.mesh_sys.clone().unwrap()
    }

    /// Traffic model for `model` on a given system placement.
    pub fn traffic_on(&mut self, model: &str, sys: &SystemConfig, tag: &str) -> TrafficModel {
        let key = (model.to_string(), tag.to_string());
        if !self.traffic.contains_key(&key) {
            let spec = self.spec(model);
            self.traffic
                .insert(key.clone(), model_phases(sys, &spec, self.batch));
        }
        self.traffic[&key].clone()
    }

    pub fn traffic(&mut self, model: &str) -> TrafficModel {
        let sys = self.sys.clone();
        self.traffic_on(model, &sys, "wihet")
    }

    /// Aggregate LeNet f_ij on the WiHetNoC placement (the design input —
    /// the paper optimizes on the traffic pattern, not per-layer).
    pub fn fij(&mut self, model: &str) -> TrafficMatrix {
        let sys = self.sys.clone();
        self.traffic(model).fij(&sys)
    }

    /// Optimized irregular wireline topology for `k_max` (cached).
    pub fn wireline(&mut self, k_max: usize) -> Topology {
        if !self.wireline.contains_key(&k_max) {
            let fij = self.fij("lenet");
            let mut cfg = self.design_cfg();
            cfg.k_max = k_max;
            cfg.seed = self.seed.wrapping_add(k_max as u64);
            let topo = optimize_wireline(&self.sys, &fij, &cfg);
            self.wireline.insert(k_max, topo);
        }
        self.wireline[&k_max].clone()
    }

    /// The four headline NoC instances, cached by name:
    /// "mesh_xy", "mesh_opt" (XY+YX), "hetnoc", "wihetnoc".
    pub fn instance(&mut self, name: &str) -> &NocInstance {
        if !self.instances.contains_key(name) {
            let inst = match name {
                "mesh_xy" => {
                    let sys = self.mesh_sys();
                    mesh_opt(&sys, false)
                }
                "mesh_opt" => {
                    let sys = self.mesh_sys();
                    mesh_opt(&sys, true)
                }
                "hetnoc" => {
                    let fij = self.fij("lenet");
                    let cfg = self.design_cfg();
                    het_noc(&self.sys, &fij, &cfg)
                }
                "wihetnoc" => {
                    let topo = self.wireline(self.design_cfg().k_max);
                    let fij = self.fij("lenet");
                    let cfg = self.design_cfg();
                    wi_het_noc_on(&self.sys, &fij, &cfg, topo)
                }
                other => panic!("unknown instance {other}"),
            };
            self.instances.insert(name.to_string(), inst);
        }
        &self.instances[name]
    }

    /// Owned copy of a cached instance (for call sites that also need
    /// `&mut self` while holding the instance).
    pub fn instance_cloned(&mut self, name: &str) -> NocInstance {
        self.instance(name).clone()
    }

    /// WiHetNoC variant with a custom WI count / channel count on the
    /// cached k_max=default wireline topology (Figs 12-13 sweeps).
    pub fn wihet_variant(&mut self, n_wi: usize, gpu_channels: usize) -> NocInstance {
        let topo = self.wireline(self.design_cfg().k_max);
        let fij = self.fij("lenet");
        let air = build_wireless(&topo, &fij, &self.sys.cpus(), &self.sys.mcs(), n_wi, gpu_channels);
        let routes: RouteSet = alash_routes(&self.sys, &topo, &air, &fij);
        NocInstance {
            kind: crate::noc::builder::NocKind::WiHetNoc,
            topo,
            routes,
            air,
        }
    }

    /// The system placement an instance should be simulated on.
    pub fn sys_for(&mut self, name: &str) -> SystemConfig {
        match name {
            "mesh_xy" | "mesh_opt" => self.mesh_sys(),
            _ => self.sys.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctx_caches_instances() {
        let mut ctx = Ctx::new(Effort::Quick, 1);
        let a = ctx.instance("mesh_xy").topo.links.len();
        let b = ctx.instance("mesh_xy").topo.links.len();
        assert_eq!(a, b);
        assert_eq!(a, 112);
    }

    #[test]
    fn wireline_cached_per_kmax() {
        let mut ctx = Ctx::new(Effort::Quick, 2);
        let t4 = ctx.wireline(4);
        let t4b = ctx.wireline(4);
        assert_eq!(t4.edges(), t4b.edges());
        assert!(t4.k_max() <= 4);
        let t6 = ctx.wireline(6);
        assert!(t6.k_max() <= 6);
    }

    #[test]
    fn variant_builder() {
        let mut ctx = Ctx::new(Effort::Quick, 3);
        let v = ctx.wihet_variant(8, 2);
        assert_eq!(v.air.num_channels, 3);
        assert_eq!(v.air.wis.len(), 8 + 8);
    }
}

//! Fault injection and graceful degradation.
//!
//! The paper's §4.2.5 MAC already encodes a degradation rule — a busy
//! channel re-routes over wireline on the spot — but only contention
//! ever exercised it. This module injects *failures* and lets the rest
//! of the stack degrade gracefully instead of lying about a perfect
//! network:
//!
//! * **wireline hard faults** (`wire:`) — a link is dead from cycle
//!   `at`. [`RouteSet::repaired`] re-runs the delay-weighted shortest
//!   path / ALASH pass around the dead links, and the simulator
//!   re-roots any packet that reaches a dying link onto the repaired
//!   routes mid-flight, exactly like the MAC fallback.
//! * **wireless interference windows** (`air:`) — a channel is jammed
//!   over `[from, from+burst)`. The MAC sees it as busy, carrier-senses
//!   again after a bounded exponential backoff, and falls back to
//!   wireline when the window outlasts the retry budget.
//! * **inter-chip fabric degradation** (`chip:`) — a degraded chip
//!   slows every collective step by `slow` (the slowest participant
//!   gates a ring/tree step), and a flaky link drops each step `drop`
//!   times, charged analytically as timeout + exponential backoff in
//!   [`crate::fabric::run_fabric_faults`].
//!
//! A [`FaultPlan`] parses from the same kind of compact grammar as the
//! fabric spec (see [`GRAMMAR`]), validates at the scenario boundary,
//! and [`FaultPlan::compile`]s against a concrete topology into
//! [`SimFaults`] — per-link down cycles, per-channel jam windows, and
//! the repaired route set. Compilation derives only from the plan (seed
//! + structural indices), never from thread or workspace state, so
//! injection is byte-identical across `WIHETNOC_THREADS` settings.
//! [`FaultPlan::none`] compiles to nothing and every fault hook in the
//! simulator is behind an `Option`, so fault-free runs stay
//! byte-identical to the pre-fault code paths.

use std::fmt;
use std::str::FromStr;

use crate::error::WihetError;
use crate::noc::routing::RouteSet;
use crate::noc::topology::Topology;
use crate::noc::wireless::WirelessSpec;
use crate::util::rng::Rng;

/// The `--faults` grammar (embedded in every parse error).
pub const GRAMMAR: &str = "fault plan grammar:
  <plan>   := none | <clause>[;<clause>]*
  <clause> := wire:link=<id>[,at=<cycle>]             one wireline link dies at <cycle>
            | wire:rate=<frac>[,seed=<n>][,at=<cycle>]  seeded random link kills
            | air:ch=<n>[,from=<cycle>],burst=<cycles>  jam a channel over [from, from+burst)
            | chip:n=<k>[,slow=<f>x][,drop=<r>]       degrade k fabric chips
  examples: wire:link=12 | wire:rate=0.01,seed=7 | air:ch=2,from=5000,burst=2000;chip:n=1,slow=4x";

/// One explicit wireline link fault: `link` is dead from cycle `at`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct LinkFault {
    pub link: u32,
    pub at: u64,
}

/// One wireless interference window: `channel` is jammed over
/// `[from, from + burst)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct JamWindow {
    pub channel: u32,
    pub from: u64,
    pub burst: u64,
}

/// A typed, deterministic fault-injection plan. Parses from the
/// [`GRAMMAR`]; all fields are integers so the plan can ride inside the
/// `Hash + Eq` [`crate::ScenarioKey`] (the random-kill rate is stored in
/// parts per million).
#[derive(Debug, Clone, Default, PartialEq, Eq, Hash)]
pub struct FaultPlan {
    /// Explicit `wire:link=` faults.
    pub dead_links: Vec<LinkFault>,
    /// Seeded random link kills, parts per million (0 = off).
    pub wire_rate_ppm: u32,
    /// Seed of the random-kill stream.
    pub wire_seed: u64,
    /// Cycle the random kills take effect.
    pub wire_at: u64,
    /// Wireless interference windows.
    pub jams: Vec<JamWindow>,
    /// Degraded chips in the fabric (0 = none). Ring/tree collective
    /// steps synchronize the whole fabric, so one degraded chip gates
    /// every step — `n` is recorded for reporting.
    pub chip_n: u32,
    /// Alpha/beta slow-down factor of the degraded chips (>= 1).
    pub chip_slow_x: u32,
    /// Dropped attempts per collective step on the flaky link.
    pub chip_drop: u32,
}

impl FaultPlan {
    /// The empty plan: delegates byte-identically to fault-free runs.
    pub fn none() -> Self {
        FaultPlan { chip_slow_x: 1, ..FaultPlan::default() }
    }

    /// True when the plan injects nothing at all.
    pub fn is_none(&self) -> bool {
        self.dead_links.is_empty()
            && self.wire_rate_ppm == 0
            && self.jams.is_empty()
            && self.chip_n == 0
    }

    /// True when the plan carries on-chip (wireline or wireless) faults
    /// the cycle-level simulator must model.
    pub fn has_noc_faults(&self) -> bool {
        !self.dead_links.is_empty() || self.wire_rate_ppm > 0 || !self.jams.is_empty()
    }

    /// True when the plan degrades the inter-chip fabric.
    pub fn has_chip_faults(&self) -> bool {
        self.chip_n > 0
    }

    /// Semantic checks beyond the grammar (link ids are checked against
    /// the concrete topology by [`FaultPlan::compile`]).
    pub fn validate(&self) -> Result<(), WihetError> {
        if self.wire_rate_ppm > 1_000_000 {
            return Err(WihetError::InvalidArg(format!(
                "wire:rate must be in [0, 1], got {}\n{GRAMMAR}",
                self.wire_rate_ppm as f64 / 1e6
            )));
        }
        for j in &self.jams {
            if j.burst == 0 {
                return Err(WihetError::InvalidArg(format!(
                    "air: burst must be > 0 (channel {})\n{GRAMMAR}",
                    j.channel
                )));
            }
        }
        if self.chip_n > 0 && self.chip_slow_x <= 1 && self.chip_drop == 0 {
            return Err(WihetError::InvalidArg(format!(
                "chip:n={} degrades nothing — add slow=<f>x (> 1x) or drop=<r>\n{GRAMMAR}",
                self.chip_n
            )));
        }
        if self.chip_slow_x == 0 {
            return Err(WihetError::InvalidArg(format!(
                "chip: slow factor must be >= 1x\n{GRAMMAR}"
            )));
        }
        // the fabric tier charges an exponential-backoff timeout of
        // alpha * (2^drop - 1) per step — cap the exponent well inside u64
        if self.chip_drop > 16 {
            return Err(WihetError::InvalidArg(format!(
                "chip: drop={} retries per step is outside the model's regime (max 16)\n{GRAMMAR}",
                self.chip_drop
            )));
        }
        Ok(())
    }

    /// Resolve the plan against a concrete NoC: expand seeded random
    /// kills (deterministically, in link-id order), check explicit link
    /// ids, collect per-channel jam windows, and run the route repair
    /// pass around every dead link. Jam windows naming channels this
    /// NoC does not have are inert — a mesh under an `air:` plan is
    /// exactly the fault-free mesh.
    pub fn compile(
        &self,
        topo: &Topology,
        routes: &RouteSet,
        air: &WirelessSpec,
        nominal_flits: u64,
    ) -> Result<SimFaults, WihetError> {
        self.validate()?;
        let nl = topo.links.len();
        let mut down = vec![u64::MAX; nl];
        for lf in &self.dead_links {
            let l = lf.link as usize;
            if l >= nl {
                return Err(WihetError::InvalidArg(format!(
                    "wire:link={} out of range — this topology has {nl} links\n{GRAMMAR}",
                    lf.link
                )));
            }
            down[l] = down[l].min(lf.at);
        }
        if self.wire_rate_ppm > 0 {
            // One draw per link, in link-id order: the kill set depends
            // only on (seed, rate, link count), never on thread or
            // workspace state.
            let mut rng = Rng::new(self.wire_seed);
            for d in down.iter_mut() {
                if rng.next_u64() % 1_000_000 < self.wire_rate_ppm as u64 {
                    *d = (*d).min(self.wire_at);
                }
            }
        }
        let dead: Vec<bool> = down.iter().map(|&t| t != u64::MAX).collect();
        let n_dead = dead.iter().filter(|&&d| d).count() as u64;

        let nch = air.num_channels;
        let mut jams: Vec<Vec<(u64, u64)>> = vec![Vec::new(); nch];
        let mut n_jams = 0u64;
        for j in &self.jams {
            if let Some(ws) = jams.get_mut(j.channel as usize) {
                ws.push((j.from, j.from + j.burst));
                n_jams += 1;
            }
        }
        for ws in &mut jams {
            ws.sort_unstable();
        }

        let (repaired, pairs_repaired) = if n_dead > 0 {
            let (rs, pairs) = routes.repaired(topo, air, &dead, nominal_flits);
            (Some(rs), pairs)
        } else {
            (None, 0)
        };

        Ok(SimFaults {
            link_down_at: down,
            dead,
            jams,
            repaired,
            pairs_repaired,
            faults_injected: n_dead + n_jams,
        })
    }
}

impl fmt::Display for FaultPlan {
    /// Canonical form (defaults omitted); round-trips through
    /// [`FaultPlan::from_str`].
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_none() {
            return f.pad("none");
        }
        let mut parts: Vec<String> = Vec::new();
        for lf in &self.dead_links {
            let mut s = format!("wire:link={}", lf.link);
            if lf.at != 0 {
                s.push_str(&format!(",at={}", lf.at));
            }
            parts.push(s);
        }
        if self.wire_rate_ppm > 0 {
            let mut s = format!("wire:rate={}", self.wire_rate_ppm as f64 / 1e6);
            if self.wire_seed != 0 {
                s.push_str(&format!(",seed={}", self.wire_seed));
            }
            if self.wire_at != 0 {
                s.push_str(&format!(",at={}", self.wire_at));
            }
            parts.push(s);
        }
        for j in &self.jams {
            let mut s = format!("air:ch={}", j.channel);
            if j.from != 0 {
                s.push_str(&format!(",from={}", j.from));
            }
            s.push_str(&format!(",burst={}", j.burst));
            parts.push(s);
        }
        if self.chip_n > 0 {
            let mut s = format!("chip:n={}", self.chip_n);
            if self.chip_slow_x > 1 {
                s.push_str(&format!(",slow={}x", self.chip_slow_x));
            }
            if self.chip_drop > 0 {
                s.push_str(&format!(",drop={}", self.chip_drop));
            }
            parts.push(s);
        }
        f.pad(&parts.join(";"))
    }
}

fn parse_num<T: FromStr>(key: &str, v: &str) -> Result<T, WihetError> {
    v.trim().parse::<T>().map_err(|_| {
        WihetError::InvalidArg(format!("{key}={v} is not a valid number\n{GRAMMAR}"))
    })
}

impl FromStr for FaultPlan {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        let t = s.trim();
        let mut plan = FaultPlan::none();
        if t.is_empty() || t.eq_ignore_ascii_case("none") {
            return Ok(plan);
        }
        for clause in t.split(';') {
            let clause = clause.trim();
            let (head, rest) = clause.split_once(':').ok_or_else(|| {
                WihetError::InvalidArg(format!(
                    "fault clause '{clause}' needs a wire:/air:/chip: head\n{GRAMMAR}"
                ))
            })?;
            let mut kv = Vec::new();
            for item in rest.split(',') {
                let (k, v) = item.split_once('=').ok_or_else(|| {
                    WihetError::InvalidArg(format!(
                        "expected key=value in fault clause, got '{item}'\n{GRAMMAR}"
                    ))
                })?;
                kv.push((k.trim(), v.trim()));
            }
            let get = |key: &str| kv.iter().find(|(k, _)| *k == key).map(|(_, v)| *v);
            let known = |allowed: &[&str]| -> Result<(), WihetError> {
                for (k, _) in &kv {
                    if !allowed.contains(k) {
                        return Err(WihetError::InvalidArg(format!(
                            "unknown key '{k}' in {head}: fault clause\n{GRAMMAR}"
                        )));
                    }
                }
                Ok(())
            };
            match head.trim() {
                "wire" => {
                    known(&["link", "at", "rate", "seed"])?;
                    let at: u64 = get("at").map(|v| parse_num("at", v)).transpose()?.unwrap_or(0);
                    match (get("link"), get("rate")) {
                        (Some(link), None) => {
                            plan.dead_links.push(LinkFault { link: parse_num("link", link)?, at });
                        }
                        (None, Some(rate)) => {
                            if plan.wire_rate_ppm > 0 {
                                return Err(WihetError::InvalidArg(format!(
                                    "at most one wire:rate clause per plan\n{GRAMMAR}"
                                )));
                            }
                            let r: f64 = parse_num("rate", rate)?;
                            if !(0.0..=1.0).contains(&r) {
                                return Err(WihetError::InvalidArg(format!(
                                    "wire:rate must be in [0, 1], got {rate}\n{GRAMMAR}"
                                )));
                            }
                            plan.wire_rate_ppm = (r * 1e6).round() as u32;
                            plan.wire_seed =
                                get("seed").map(|v| parse_num("seed", v)).transpose()?.unwrap_or(0);
                            plan.wire_at = at;
                        }
                        _ => {
                            return Err(WihetError::InvalidArg(format!(
                                "wire: clause needs exactly one of link=<id> or rate=<frac>\n{GRAMMAR}"
                            )));
                        }
                    }
                }
                "air" => {
                    known(&["ch", "from", "burst"])?;
                    let channel = get("ch").ok_or_else(|| {
                        WihetError::InvalidArg(format!("air: clause needs ch=<n>\n{GRAMMAR}"))
                    })?;
                    let burst = get("burst").ok_or_else(|| {
                        WihetError::InvalidArg(format!(
                            "air: clause needs burst=<cycles>\n{GRAMMAR}"
                        ))
                    })?;
                    plan.jams.push(JamWindow {
                        channel: parse_num("ch", channel)?,
                        from: get("from").map(|v| parse_num("from", v)).transpose()?.unwrap_or(0),
                        burst: parse_num("burst", burst)?,
                    });
                }
                "chip" => {
                    known(&["n", "slow", "drop"])?;
                    let n = get("n").ok_or_else(|| {
                        WihetError::InvalidArg(format!("chip: clause needs n=<k>\n{GRAMMAR}"))
                    })?;
                    plan.chip_n = parse_num("n", n)?;
                    if plan.chip_n == 0 {
                        return Err(WihetError::InvalidArg(format!(
                            "chip:n must be >= 1\n{GRAMMAR}"
                        )));
                    }
                    if let Some(slow) = get("slow") {
                        let digits = slow.strip_suffix('x').unwrap_or(slow);
                        plan.chip_slow_x = parse_num("slow", digits)?;
                    }
                    plan.chip_drop =
                        get("drop").map(|v| parse_num("drop", v)).transpose()?.unwrap_or(0);
                }
                other => {
                    return Err(WihetError::InvalidArg(format!(
                        "unknown fault class '{other}' (wire|air|chip)\n{GRAMMAR}"
                    )));
                }
            }
        }
        plan.validate()?;
        Ok(plan)
    }
}

/// A [`FaultPlan`] resolved against one concrete NoC — what the
/// simulator consults on its hot path. Built once per run by
/// [`FaultPlan::compile`]; borrowed by
/// [`crate::noc::sim::NocSim::with_faults`].
#[derive(Debug, Clone)]
pub struct SimFaults {
    /// Cycle each wireline link goes down (`u64::MAX` = healthy).
    pub link_down_at: Vec<u64>,
    /// Dead-link mask (any link that ever dies), indexed like
    /// `Topology::links`.
    pub dead: Vec<bool>,
    /// Per-channel interference windows `[from, to)`, sorted by start.
    jams: Vec<Vec<(u64, u64)>>,
    /// Routes recomputed around every dead link (`None` for jam-only
    /// plans, which never consult it).
    repaired: Option<RouteSet>,
    /// Pairs whose candidates the repair pass had to change.
    pub pairs_repaired: u64,
    /// Dead links + applicable jam windows (chip faults are charged by
    /// the fabric layer).
    pub faults_injected: u64,
}

impl SimFaults {
    /// Is `link` still up at cycle `t`?
    #[inline]
    pub fn link_up(&self, link: usize, t: u64) -> bool {
        t < self.link_down_at[link]
    }

    /// If `t` falls inside an interference window on `ch`, the cycle
    /// the (longest covering) window ends; `None` when the channel is
    /// clean at `t`.
    #[inline]
    pub fn jam_until(&self, ch: usize, t: u64) -> Option<u64> {
        let ws = self.jams.get(ch)?;
        ws.iter().filter(|&&(from, to)| t >= from && t < to).map(|&(_, to)| to).max()
    }

    /// The route set repaired around the dead links. Only meaningful —
    /// and only called — when a link fault exists.
    pub fn repaired(&self) -> &RouteSet {
        self.repaired.as_ref().expect("repaired routes exist whenever a link is dead")
    }

    /// True when some wireline link dies during the run.
    pub fn has_dead_links(&self) -> bool {
        self.repaired.is_some()
    }
}

/// Resilience counters carried by every simulation report. All zero for
/// fault-free runs (and for [`FaultPlan::none`], which never installs
/// the fault hooks at all).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ResilienceStats {
    /// Faults the plan resolved against this run: dead links, applied
    /// jam windows, and (at the fabric layer) degraded chips.
    pub faults_injected: u64,
    /// Packets re-rooted mid-flight onto repaired routes at a dead link.
    pub packets_rerouted: u64,
    /// Carrier-sense retries on jammed channels, plus (at the fabric
    /// layer) analytic retransmissions of dropped collective steps.
    pub retries: u64,
    /// Flits forced over wireline because a jam outlasted the retry
    /// budget.
    pub fallback_flits: u64,
    /// Messages with no route even after repair (a disconnected
    /// residual topology). Must stay 0 whenever a repair path exists.
    pub undeliverable_after_repair: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemConfig;

    #[test]
    fn none_is_none_and_displays() {
        let p = FaultPlan::none();
        assert!(p.is_none());
        assert!(!p.has_noc_faults() && !p.has_chip_faults());
        assert_eq!(p.to_string(), "none");
        assert_eq!("none".parse::<FaultPlan>().unwrap(), p);
        assert_eq!("".parse::<FaultPlan>().unwrap(), p);
        p.validate().unwrap();
    }

    #[test]
    fn parse_fills_defaults() {
        let p: FaultPlan = "wire:link=12".parse().unwrap();
        assert_eq!(p.dead_links, vec![LinkFault { link: 12, at: 0 }]);
        assert_eq!(p.wire_rate_ppm, 0);
        let p: FaultPlan = "air:ch=2,burst=100".parse().unwrap();
        assert_eq!(p.jams, vec![JamWindow { channel: 2, from: 0, burst: 100 }]);
        let p: FaultPlan = "wire:rate=0.01,seed=7".parse().unwrap();
        assert_eq!(p.wire_rate_ppm, 10_000);
        assert_eq!(p.wire_seed, 7);
        assert_eq!(p.wire_at, 0);
        let p: FaultPlan = "chip:n=1,slow=4x".parse().unwrap();
        assert_eq!((p.chip_n, p.chip_slow_x, p.chip_drop), (1, 4, 0));
    }

    #[test]
    fn parse_and_display_roundtrip() {
        for s in [
            "none",
            "wire:link=12",
            "wire:link=3,at=500",
            "wire:rate=0.01,seed=7",
            "wire:rate=0.05,seed=9,at=1000",
            "air:ch=2,from=5000,burst=2000",
            "air:ch=0,burst=100",
            "chip:n=1,slow=4x",
            "chip:n=2,slow=2x,drop=3",
            "wire:link=12;air:ch=2,from=5000,burst=2000;chip:n=1,slow=4x",
        ] {
            let p: FaultPlan = s.parse().unwrap();
            assert_eq!(p.to_string(), s, "canonical form");
            let again: FaultPlan = p.to_string().parse().unwrap();
            assert_eq!(again, p, "display must round-trip for '{s}'");
        }
    }

    #[test]
    fn errors_carry_the_grammar() {
        for bad in [
            "bogus:x=1",
            "wire:rate=2.0",
            "wire:link=1,rate=0.5",
            "wire:frobnicate=1",
            "air:burst=100",
            "air:ch=1",
            "air:ch=1,burst=0",
            "chip:slow=4x",
            "chip:n=0",
            "chip:n=1",
            "wire:rate=0.1;wire:rate=0.2",
            "wire:link",
        ] {
            match bad.parse::<FaultPlan>() {
                Err(WihetError::InvalidArg(msg)) => {
                    assert!(msg.contains("fault plan grammar"), "'{bad}' -> {msg}");
                }
                other => panic!("'{bad}' should be InvalidArg, got {other:?}"),
            }
        }
    }

    #[test]
    fn compile_expands_random_kills_deterministically() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rs = RouteSet::xy(&sys, &topo);
        let air = WirelessSpec::new(0);
        let plan: FaultPlan = "wire:rate=0.2,seed=7".parse().unwrap();
        let a = plan.compile(&topo, &rs, &air, 5).unwrap();
        let b = plan.compile(&topo, &rs, &air, 5).unwrap();
        assert_eq!(a.link_down_at, b.link_down_at, "same seed, same kills");
        assert!(a.faults_injected > 0, "20% of 112 links should kill some");
        assert!(a.has_dead_links());
        let other: FaultPlan = "wire:rate=0.2,seed=8".parse().unwrap();
        let c = other.compile(&topo, &rs, &air, 5).unwrap();
        assert_ne!(a.link_down_at, c.link_down_at, "different seed, different kills");
    }

    #[test]
    fn compile_rejects_out_of_range_links_and_ignores_alien_channels() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rs = RouteSet::xy(&sys, &topo);
        let air = WirelessSpec::new(0);
        let plan: FaultPlan = "wire:link=9999".parse().unwrap();
        match plan.compile(&topo, &rs, &air, 5) {
            Err(WihetError::InvalidArg(msg)) => assert!(msg.contains("out of range")),
            other => panic!("expected InvalidArg, got {other:?}"),
        }
        // a jam on a channel the mesh does not have is inert
        let plan: FaultPlan = "air:ch=2,burst=100".parse().unwrap();
        let fx = plan.compile(&topo, &rs, &air, 5).unwrap();
        assert_eq!(fx.faults_injected, 0);
        assert!(!fx.has_dead_links());
        assert_eq!(fx.jam_until(2, 50), None);
    }

    #[test]
    fn jam_windows_answer_membership() {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rs = RouteSet::xy(&sys, &topo);
        let air = WirelessSpec::new(3);
        let plan: FaultPlan = "air:ch=1,from=100,burst=50".parse().unwrap();
        let fx = plan.compile(&topo, &rs, &air, 5).unwrap();
        assert_eq!(fx.jam_until(1, 99), None);
        assert_eq!(fx.jam_until(1, 100), Some(150));
        assert_eq!(fx.jam_until(1, 149), Some(150));
        assert_eq!(fx.jam_until(1, 150), None);
        assert_eq!(fx.jam_until(0, 120), None, "other channels clean");
    }
}

//! PJRT client wrapper: HLO-text loading, executable cache, and typed
//! execute helpers.
//!
//! Interchange is HLO *text* — the image's xla_extension 0.5.1 rejects
//! jax>=0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` crate this file compiles against lives in `rust/vendor/xla`.
//! In hermetic environments that is a stub whose client constructor
//! returns a clear [`WihetError`]; swap the vendor directory for the real
//! xla-rs bindings (same API surface) to execute artifacts for real. The
//! NoC toolchain — design, simulation, experiments — never touches this
//! module.

use std::collections::HashMap;

use super::manifest::{Entry, Manifest};
use crate::error::{Result, WihetError};
use crate::{wbail, werr};

/// A compiled entry point plus its signature.
pub struct Executable {
    pub entry: Entry,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute on f32 host tensors; returns one `Vec<f32>` per output.
    ///
    /// Outputs arrive as a single tuple literal (the AOT path lowers with
    /// `return_tuple=True`); it is decomposed here.
    pub fn run_f32(&self, args: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        if args.len() != self.entry.inputs.len() {
            wbail!(
                "{}: expected {} inputs, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, (a, spec)) in args.iter().zip(&self.entry.inputs).enumerate() {
            if a.len() != spec.elements() {
                wbail!(
                    "{}: input {i} has {} elements, spec {:?} wants {}",
                    self.entry.name,
                    a.len(),
                    spec.shape,
                    spec.elements()
                );
            }
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(a)
                    .reshape(&dims)
                    .map_err(|e| werr!("reshape input {i}: {e:?}"))?,
            );
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| werr!("execute {}: {e:?}", self.entry.name))?;
        let first = result
            .into_iter()
            .next()
            .and_then(|d| d.into_iter().next())
            .ok_or_else(|| werr!("no output buffer"))?;
        let lit = first
            .to_literal_sync()
            .map_err(|e| werr!("to_literal: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| werr!("untuple: {e:?}"))?;
        if parts.len() != self.entry.num_outputs {
            wbail!(
                "{}: manifest says {} outputs, got {}",
                self.entry.name,
                self.entry.num_outputs,
                parts.len()
            );
        }
        parts
            .iter()
            .enumerate()
            .map(|(i, p)| p.to_vec::<f32>().map_err(|e| werr!("output {i}: {e:?}")))
            .collect()
    }
}

/// PJRT CPU client + compiled-executable cache, manifest-driven.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, Executable>,
}

impl Runtime {
    /// Create a runtime over an artifacts directory (must contain
    /// `manifest.json`; build with `make artifacts`).
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        // A failed client construction means PJRT itself is unusable in
        // this build (most commonly: the vendored xla stub is linked) —
        // typed so callers can skip instead of failing.
        let client = xla::PjRtClient::cpu()
            .map_err(|e| WihetError::RuntimeUnavailable(format!("PJRT cpu client: {e:?}")))?;
        Ok(Runtime { manifest, client, cache: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an entry (cached).
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.cache.contains_key(name) {
            let entry = self.manifest.entry(name)?.clone();
            let path = self.manifest.hlo_path(&entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| werr!("non-utf8 path"))?,
            )
            .map_err(|e| werr!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| werr!("compile {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), Executable { entry, exe });
        }
        Ok(&self.cache[name])
    }

    /// Convenience: load-and-run in one call.
    pub fn run(&mut self, name: &str, args: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.load(name)?;
        self.cache[name].run_f32(args)
    }

    /// Entries available in the manifest.
    pub fn entry_names(&self) -> Vec<String> {
        self.manifest.entries.iter().map(|e| e.name.clone()).collect()
    }
}

// NOTE: integration tests that require built artifacts live in
// rust/tests/runtime_integration.rs (they are skipped gracefully when
// artifacts/ has not been generated yet).
#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifacts_dir_is_a_clean_error() {
        let err = match Runtime::new("/nonexistent/artifacts") {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        let msg = format!("{err:#}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}

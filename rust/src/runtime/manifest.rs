//! `artifacts/manifest.json` — the contract between the Python AOT
//! compiler and this runtime (entry-point signatures + per-layer model
//! metadata). Parsed with the in-crate JSON codec.

use std::path::{Path, PathBuf};

use crate::error::Result;
use crate::util::json::{self, Json};
use crate::{wbail, werr};

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT entry point (e.g. `lenet_train_step`).
#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    pub model: Option<String>,
    pub kind: String,
    pub path: String,
    pub inputs: Vec<TensorSpec>,
    pub num_params: usize,
    pub num_outputs: usize,
}

/// Per-layer metadata (cross-checked against `model::cnn` by tests).
#[derive(Debug, Clone)]
pub struct LayerMeta {
    pub name: String,
    pub kind: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub weight_bytes: u64,
    pub in_bytes: u64,
    pub out_bytes: u64,
    pub macs: u64,
}

#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub name: String,
    pub batch: usize,
    pub layers: Vec<LayerMeta>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub batch: usize,
    pub entries: Vec<Entry>,
    pub models: Vec<ModelMeta>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| werr!("reading {path:?} — run `make artifacts` first: {e}"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let root = json::parse(text).map_err(|e| werr!("manifest JSON: {e}"))?;
        let batch = root
            .get("batch")
            .and_then(Json::as_usize)
            .ok_or_else(|| werr!("manifest missing batch"))?;
        let mut entries = Vec::new();
        for e in root
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| werr!("manifest missing entries"))?
        {
            entries.push(parse_entry(e)?);
        }
        let mut models = Vec::new();
        for m in root.get("models").and_then(Json::as_arr).unwrap_or(&[]) {
            models.push(parse_model(m)?);
        }
        Ok(Manifest { dir, batch, entries, models })
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| werr!("entry '{name}' not in manifest"))
    }

    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .ok_or_else(|| werr!("model '{name}' not in manifest"))
    }

    pub fn hlo_path(&self, entry: &Entry) -> PathBuf {
        self.dir.join(&entry.path)
    }
}

fn parse_specs(v: &Json) -> Result<Vec<TensorSpec>> {
    let mut out = Vec::new();
    for s in v.as_arr().ok_or_else(|| werr!("inputs not an array"))? {
        let shape = s
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| werr!("input missing shape"))?
            .iter()
            .map(|x| x.as_usize().ok_or_else(|| werr!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = s
            .get("dtype")
            .and_then(Json::as_str)
            .unwrap_or("float32")
            .to_string();
        if dtype != "float32" {
            wbail!("unsupported dtype {dtype} (runtime is f32-only)");
        }
        out.push(TensorSpec { shape, dtype });
    }
    Ok(out)
}

fn parse_entry(e: &Json) -> Result<Entry> {
    Ok(Entry {
        name: req_str(e, "name")?,
        model: e.get("model").and_then(Json::as_str).map(str::to_string),
        kind: req_str(e, "kind")?,
        path: req_str(e, "path")?,
        inputs: parse_specs(e.get("inputs").ok_or_else(|| werr!("no inputs"))?)?,
        num_params: e.get("num_params").and_then(Json::as_usize).unwrap_or(0),
        num_outputs: e
            .get("num_outputs")
            .and_then(Json::as_usize)
            .ok_or_else(|| werr!("entry missing num_outputs"))?,
    })
}

fn parse_model(m: &Json) -> Result<ModelMeta> {
    let mut layers = Vec::new();
    for l in m.get("layers").and_then(Json::as_arr).unwrap_or(&[]) {
        let dims = |key: &str| -> Vec<usize> {
            l.get(key)
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default()
        };
        let num = |key: &str| l.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64;
        layers.push(LayerMeta {
            name: req_str(l, "name")?,
            kind: req_str(l, "kind")?,
            in_shape: dims("in_shape"),
            out_shape: dims("out_shape"),
            weight_bytes: num("weight_bytes"),
            in_bytes: num("in_bytes"),
            out_bytes: num("out_bytes"),
            macs: num("macs"),
        });
    }
    Ok(ModelMeta {
        name: req_str(m, "name")?,
        batch: m.get("batch").and_then(Json::as_usize).unwrap_or(0),
        layers,
    })
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    v.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| werr!("missing field '{key}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "batch": 4,
      "entries": [
        {"name": "m_train_step", "model": "m", "kind": "train_step",
         "path": "m_train_step.hlo.txt",
         "inputs": [{"shape": [5,5,1,16], "dtype": "float32"},
                    {"shape": [16], "dtype": "float32"},
                    {"shape": [4,33,33,1], "dtype": "float32"},
                    {"shape": [4,10], "dtype": "float32"}],
         "num_params": 2, "num_outputs": 3}
      ],
      "models": [
        {"name": "m", "batch": 4, "layers": [
          {"name": "C1", "kind": "conv", "in_shape": [33,33,1],
           "out_shape": [29,29,16], "weight_bytes": 1664,
           "in_bytes": 17424, "out_bytes": 86144, "macs": 2155600}
        ]}
      ],
      "version": 1
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.batch, 4);
        let e = m.entry("m_train_step").unwrap();
        assert_eq!(e.inputs.len(), 4);
        assert_eq!(e.inputs[0].elements(), 400);
        assert_eq!(e.num_outputs, 3);
        assert_eq!(m.hlo_path(e), PathBuf::from("/tmp/m_train_step.hlo.txt"));
        let model = m.model("m").unwrap();
        assert_eq!(model.layers[0].out_shape, vec![29, 29, 16]);
        assert_eq!(model.layers[0].macs, 2_155_600);
    }

    #[test]
    fn rejects_unknown_entry() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert!(m.entry("nope").is_err());
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn rejects_non_f32() {
        let bad = SAMPLE.replace("float32", "bfloat16");
        assert!(Manifest::parse(&bad, PathBuf::from("/tmp")).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("{", PathBuf::from("/tmp")).is_err());
        assert!(Manifest::parse("{}", PathBuf::from("/tmp")).is_err());
    }
}

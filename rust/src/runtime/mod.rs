//! PJRT runtime: loads `artifacts/*.hlo.txt` (AOT-lowered by
//! `python/compile/aot.py`) and executes them on the request path with no
//! Python anywhere. Wraps the `xla` crate (PJRT C API, CPU plugin) —
//! vendored as a stub under `rust/vendor/xla` in hermetic builds; swap in
//! the real xla-rs bindings to execute artifacts. All entry points return
//! `Result<_, WihetError>`.

pub mod client;
pub mod manifest;

pub use client::{Executable, Runtime};
pub use manifest::{Entry, Manifest, TensorSpec};

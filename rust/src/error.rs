//! Crate-wide error type. Every fallible public entry point — platform
//! construction, scenario parsing, NoC design, experiment dispatch, the
//! PJRT runtime — returns `Result<_, WihetError>`; user input never
//! panics the library.

use std::fmt;

/// Convenience alias used throughout the crate.
pub type Result<T> = std::result::Result<T, WihetError>;

#[derive(Debug)]
pub enum WihetError {
    /// Unknown CNN workload name (see [`crate::scenario::ModelId`]).
    UnknownModel(String),
    /// Malformed workload-DSL spec (see [`crate::workload::ArchSpec`]);
    /// the display includes the full grammar.
    InvalidSpec(String),
    /// Unknown NoC architecture name (see [`crate::noc::builder::NocKind`]).
    UnknownNoc(String),
    /// Unknown experiment id (see [`crate::experiments::ALL`]).
    UnknownExperiment(String),
    /// A `Platform` that cannot describe a buildable chip.
    InvalidPlatform(String),
    /// Design-space knobs outside the feasible region for the platform.
    InvalidDesign(String),
    /// Malformed CLI/scenario argument (bad effort, seed, scale, ...).
    InvalidArg(String),
    /// Runtime/artifact failures (manifest parsing, PJRT execution, ...).
    Runtime(String),
    /// PJRT is not usable in this build (e.g. the vendored `xla` stub is
    /// linked instead of the real bindings). Callers may treat this as a
    /// clean "skip", unlike [`WihetError::Runtime`].
    RuntimeUnavailable(String),
    Io(std::io::Error),
}

impl fmt::Display for WihetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WihetError::UnknownModel(m) => {
                write!(
                    f,
                    "unknown model '{m}'. Known presets: {}. Custom architectures are \
                     accepted as a workload-DSL string, e.g. \
                     \"conv:5x5x20 pool:2 conv:5x5x50 pool:2 dense:500 dense:10\"\n{}",
                    crate::workload::preset_names().join(", "),
                    crate::workload::GRAMMAR
                )
            }
            WihetError::InvalidSpec(m) => {
                write!(f, "invalid workload spec: {m}\n{}", crate::workload::GRAMMAR)
            }
            WihetError::UnknownNoc(n) => write!(
                f,
                "unknown NoC '{n}' (known NoCs: mesh_xy, mesh_opt, hetnoc, wihetnoc)"
            ),
            WihetError::UnknownExperiment(e) => write!(
                f,
                "unknown experiment '{e}'. Registered ids: {}, all",
                crate::experiments::ids().join(", ")
            ),
            WihetError::InvalidPlatform(m) => write!(f, "invalid platform: {m}"),
            WihetError::InvalidDesign(m) => write!(f, "invalid design: {m}"),
            WihetError::InvalidArg(m) => write!(f, "invalid argument: {m}"),
            WihetError::Runtime(m) => write!(f, "{m}"),
            WihetError::RuntimeUnavailable(m) => {
                write!(f, "PJRT runtime unavailable: {m}")
            }
            WihetError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for WihetError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            WihetError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for WihetError {
    fn from(e: std::io::Error) -> Self {
        WihetError::Io(e)
    }
}

/// `anyhow!`-style constructor for [`WihetError::Runtime`].
#[macro_export]
macro_rules! werr {
    ($($arg:tt)*) => { $crate::error::WihetError::Runtime(format!($($arg)*)) };
}

/// `bail!`-style early return with a [`WihetError::Runtime`].
#[macro_export]
macro_rules! wbail {
    ($($arg:tt)*) => { return Err($crate::werr!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_mentions_offender_and_hints() {
        let e = WihetError::UnknownModel("transformer".into());
        let s = e.to_string();
        assert!(s.contains("transformer") && s.contains("lenet"));
        // the message lists every preset and carries the DSL grammar
        for hint in ["alexnet", "vgg11", "resnet-lite", "conv:KxKxC", "dense:N"] {
            assert!(s.contains(hint), "missing '{hint}' in: {s}");
        }
        let e = WihetError::InvalidSpec("conv expects KxKxC, got 'conv:3'".into());
        let s = e.to_string();
        assert!(s.contains("conv:3") && s.contains("skip:D"), "{s}");
        let e = WihetError::UnknownNoc("torus".into());
        assert!(e.to_string().contains("wihetnoc"));
        // the experiment menu is derived from the registry, not hardcoded
        let e = WihetError::UnknownExperiment("figg17".into());
        let s = e.to_string();
        for hint in ["figg17", "table1", "fig17", "workload_figs"] {
            assert!(s.contains(hint), "missing '{hint}' in: {s}");
        }
    }

    #[test]
    fn macros_build_runtime_errors() {
        fn inner() -> crate::error::Result<()> {
            wbail!("bad thing {}", 42);
        }
        let e = inner().unwrap_err();
        assert!(matches!(e, WihetError::Runtime(_)));
        assert!(e.to_string().contains("bad thing 42"));
    }
}

//! Deterministic log-bucket latency histograms with exact quantile
//! semantics.
//!
//! [`crate::util::stats::Accum`] tracks count/sum/max — enough for the
//! paper's mean-latency figures, but ROADMAP item 2 asks for tail
//! percentiles (p50/p99/p999), and tails need a distribution. A sorted
//! sample vector would give exact order statistics but allocates per
//! packet and merges in O(n log n); [`LogHistogram`] instead buckets
//! values into a *fixed* 1920-slot layout:
//!
//! * values `< 64` get one bucket each (the exact region — small
//!   latencies, where a coarse bucket would swallow the whole story);
//! * values `>= 64` get 32 sub-buckets per power-of-two octave, so the
//!   relative quantization error is bounded by 1/32 (~3%) everywhere.
//!
//! Everything is integer arithmetic: recording is two shifts and a mask,
//! merging is a bucket-wise add (commutative and associative), and
//! [`LogHistogram::quantile`] is a deterministic function of the bucket
//! counts — the same packets always produce the same p50/p99/p999, in
//! any record order, at any `WIHETNOC_THREADS` (pinned by the tests
//! below and `tests/telemetry.rs`).
//!
//! Quantile semantics (pinned, not approximate): `quantile(q)` returns
//! the **lower bound of the bucket containing the rank-`ceil(q·count)`
//! sample** (1-based, the nearest-rank definition). In the exact region
//! this *is* the order statistic; above it, it underestimates by at most
//! one bucket width.

/// Sub-buckets per octave (32 → ≤ 1/32 relative error above the exact
/// region).
const SUB_BITS: u32 = 5;
const SUBS: usize = 1 << SUB_BITS;
/// Exact one-bucket-per-value region: `0..EXACT`.
const EXACT: usize = 2 * SUBS;
/// Octaves above the exact region: msb 6 (values 64..128) through
/// msb 63 (top of u64).
const OCTAVES: usize = 64 - (SUB_BITS as usize + 1);
/// Total fixed bucket count: 64 exact + 58 octaves × 32 sub-buckets.
pub const NUM_BUCKETS: usize = EXACT + OCTAVES * SUBS;

/// Fixed-layout logarithmic histogram over `u64` samples (latencies in
/// cycles). See the module docs for the bucket layout and the pinned
/// quantile semantics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogHistogram {
    counts: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

/// Bucket index of a value: identity below [`EXACT`], then
/// `(octave, 5-bit mantissa)` above it.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < EXACT as u64 {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros(); // >= SUB_BITS + 1
        let octave = (msb - (SUB_BITS + 1)) as usize;
        let sub = ((v >> (msb - SUB_BITS)) & (SUBS as u64 - 1)) as usize;
        EXACT + octave * SUBS + sub
    }
}

/// Smallest value mapping to bucket `idx` — what [`LogHistogram::quantile`]
/// reports for any rank landing in that bucket.
#[inline]
pub fn bucket_floor(idx: usize) -> u64 {
    if idx < EXACT {
        idx as u64
    } else {
        let k = idx - EXACT;
        let octave = (k / SUBS) as u32;
        let sub = (k % SUBS) as u64;
        (SUBS as u64 + sub) << (octave + 1)
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        LogHistogram {
            counts: vec![0; NUM_BUCKETS],
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Drop every sample, keeping the allocation.
    pub fn reset(&mut self) {
        self.counts.fill(0);
        self.count = 0;
        self.sum = 0;
        self.min = u64::MAX;
        self.max = 0;
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.counts[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v as u128;
        if v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact sample mean (the sum is kept exactly, not re-quantized).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Exact smallest recorded value (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Exact largest recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Fold another histogram in. Bucket-wise integer addition:
    /// commutative and associative, so any merge tree over any sharding
    /// of the samples yields identical quantiles — the property the
    /// thread-count determinism tests pin.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Nearest-rank quantile: the lower bound of the bucket holding the
    /// rank-`ceil(q·count)` sample (1-based; `q` is clamped to `[0, 1]`).
    /// Exact below 64; within 1/32 relative error above. Returns 0 for
    /// an empty histogram.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_floor(idx);
            }
        }
        self.max // unreachable: seen reaches count
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::exec::par_map_threads;

    #[test]
    fn bucket_layout_invariants() {
        // identity below the exact bound, floor <= v < next floor above
        for v in 0..EXACT as u64 {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_floor(v as usize), v);
        }
        for v in [
            64u64,
            65,
            95,
            127,
            128,
            500,
            1 << 20,
            (1 << 20) + 12345,
            u64::MAX / 3,
            u64::MAX,
        ] {
            let idx = bucket_index(v);
            assert!(idx < NUM_BUCKETS, "{v} -> {idx}");
            let lo = bucket_floor(idx);
            assert!(lo <= v, "{v}: floor {lo}");
            if idx + 1 < NUM_BUCKETS {
                assert!(bucket_floor(idx + 1) > v, "{v} not below next bucket");
            }
            // relative quantization error bounded by 1/32
            assert!((v - lo) as f64 <= v as f64 / SUBS as f64 + 1.0, "{v}: floor {lo}");
        }
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
    }

    #[test]
    fn exact_region_quantiles_are_order_statistics() {
        let mut h = LogHistogram::new();
        for v in 0..64u64 {
            h.record(v);
        }
        // rank ceil(q*64), 1-based, over samples 0..=63
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.p50(), 31); // rank 32 -> sample 31
        assert_eq!(h.quantile(0.25), 15);
        assert_eq!(h.p99(), 63); // rank ceil(63.36) = 64
        assert_eq!(h.quantile(1.0), 63);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 63);
        assert_eq!(h.mean(), 31.5);
    }

    #[test]
    fn pinned_p50_p99_p999_on_uniform_1_to_1000() {
        // The semantics contract: quantile(q) is the floor of the bucket
        // holding the rank-ceil(q*n) sample. For 1..=1000 these land in
        // hand-computed buckets — pinned literally so any change to the
        // layout or the rank rule breaks loudly.
        let mut h = LogHistogram::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.p50(), 496); // sample 500 lives in [496, 503]
        assert_eq!(h.p99(), 976); // sample 990 lives in [976, 991]
        assert_eq!(h.p999(), 992); // sample 999 lives in [992, 1007]
        assert_eq!(h.quantile(1.0), 992); // sample 1000, same bucket
        assert_eq!(h.max(), 1000);
    }

    #[test]
    fn empty_and_singleton() {
        let mut h = LogHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), 0);
        assert_eq!(h.mean(), 0.0);
        h.record(7);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), 7);
        }
    }

    #[test]
    fn merge_is_order_invariant() {
        let data: Vec<u64> = (0..3000).map(|i| (i * 2654435761u64) % 100_000).collect();
        let mut whole = LogHistogram::new();
        for &v in &data {
            whole.record(v);
        }
        // shard three ways, merge in two different orders
        let mut shards: Vec<LogHistogram> = (0..3).map(|_| LogHistogram::new()).collect();
        for (i, &v) in data.iter().enumerate() {
            shards[i % 3].record(v);
        }
        let mut fwd = LogHistogram::new();
        for s in &shards {
            fwd.merge(s);
        }
        let mut rev = LogHistogram::new();
        for s in shards.iter().rev() {
            rev.merge(s);
        }
        assert_eq!(fwd, whole);
        assert_eq!(rev, whole);
        assert_eq!(fwd.p999(), whole.p999());
    }

    #[test]
    fn quantiles_deterministic_across_thread_counts() {
        // shard the same sample stream over 1/2/8 workers, merge, and
        // require byte-identical histograms (hence identical quantiles)
        let data: Vec<u64> = (0..5000).map(|i| (i * 40503u64) % 250_000).collect();
        let chunks: Vec<&[u64]> = data.chunks(613).collect();
        let mut reference: Option<LogHistogram> = None;
        for threads in [1usize, 2, 8] {
            let parts = par_map_threads(threads, &chunks, |_, chunk| {
                let mut h = LogHistogram::new();
                for &v in *chunk {
                    h.record(v);
                }
                h
            });
            let mut merged = LogHistogram::new();
            for p in &parts {
                merged.merge(p);
            }
            match &reference {
                None => reference = Some(merged),
                Some(r) => {
                    assert_eq!(&merged, r, "histogram differs at {threads} threads");
                    assert_eq!(merged.p50(), r.p50());
                    assert_eq!(merged.p99(), r.p99());
                    assert_eq!(merged.p999(), r.p999());
                }
            }
        }
    }

    #[test]
    fn mean_matches_accum() {
        use crate::util::stats::Accum;
        let mut h = LogHistogram::new();
        let mut a = Accum::default();
        for v in [3u64, 19, 4421, 70, 70, 1_000_000] {
            h.record(v);
            a.push(v as f64);
        }
        assert_eq!(h.mean(), a.mean());
        assert_eq!(h.count(), a.count);
        assert_eq!(h.max() as f64, a.max);
    }
}

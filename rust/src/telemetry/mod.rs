//! Observability for the gated simulator: metrics, percentiles, and a
//! Chrome-trace timeline — zero overhead when off.
//!
//! The simulator's hot loop takes an `Option<&mut Telemetry>`; with
//! `None` every hook is a never-taken branch, preserving the
//! allocation-free byte-identical guarantee the determinism tests pin.
//! With a sink attached the hooks are strictly read-only, so attaching
//! telemetry never changes a single report byte either (proved by
//! `tests/telemetry.rs` at `WIHETNOC_THREADS=1/2/8`).
//!
//! Three pieces:
//! * [`hist`] — [`LogHistogram`], deterministic log-bucket latency
//!   histograms with pinned p50/p99/p999 semantics. This is the tail
//!   latency machinery ROADMAP item 2 calls for.
//! * [`sink`] — [`Telemetry`], the collector: per-link utilization time
//!   series + heatmap (the paper's §3 traffic analysis), per-pair-class
//!   latency histograms, queue-depth / wireless-occupancy sampling,
//!   unified resilience counters, and the per-tile active-cycle
//!   counters ROADMAP item 5 needs for exact overlap energy.
//! * [`trace`] — Chrome-trace/Perfetto JSON export of the
//!   phase×microbatch timeline (release/drain spans, fabric collective
//!   steps, fault reroute instants) plus its schema validator.
//! * [`search`] — [`SearchTrace`], the *design-search* observability
//!   counterpart: AMOSA convergence snapshots per temperature level
//!   (recorded by `optim::amosa::SearchObserver`), a commutative merge
//!   for parallel per-k designs, and the eval-count profiler behind
//!   `design --profile` / the `design_figs` experiment.
//!
//! Entry points that accept a sink: `NocSim::run_telemetry` /
//! `run_timeline_telemetry`, `schedule::run_schedule_obs` /
//! `run_expanded_obs`, `fabric::run_fabric_obs`,
//! `serving::run_serving_obs`, and the CLI flags
//! `--metrics` / `--trace out.json`; for the design flow,
//! `DesignConfig::observer` / `NocDesigner::observe` /
//! `Ctx::observe_search` and the CLI flags `--search-trace` /
//! `--profile`. The `hotspot_figs` experiment packages the heatmap and
//! tail series as report artifacts; `design_figs` packages the search
//! trace.

pub mod hist;
pub mod search;
pub mod sink;
pub mod trace;

pub use hist::LogHistogram;
pub use search::{
    record_stage, search_sink, sink_trace, validate_search_trace, SearchSink, SearchStage,
    SearchTrace,
};
pub use sink::{class_line, ClassPercentiles, Instant, LatencyPercentiles, Span, Telemetry};
pub use trace::{chrome_trace, validate_chrome_trace};

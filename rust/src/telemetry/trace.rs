//! Chrome-trace / Perfetto export of the gated timeline.
//!
//! [`chrome_trace`] turns a [`Telemetry`] sink's spans and instants into
//! the Trace Event Format consumed by `chrome://tracing` and
//! <https://ui.perfetto.dev>: an object with a `traceEvents` array of
//! complete-duration events (`ph: "X"`, one per phase×microbatch
//! instance / collective step / fabric wire hop, `tid` = pipeline
//! stage), global instants (`ph: "i"`) for fault reroutes, and counter
//! events (`ph: "C"`) tracking network utilization and event-queue
//! depth per time bucket. Timestamps are simulated cycles reported as
//! microseconds — the viewer only needs a consistent unit.
//!
//! [`validate_chrome_trace`] is the Rust-side schema check the CI jq
//! validation mirrors: `tests/telemetry.rs` runs it on every exported
//! trace, so a malformed event can't reach an artifact.

use std::collections::BTreeMap;

use crate::util::json::Json;

use super::sink::Telemetry;

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn num(v: u64) -> Json {
    Json::Num(v as f64)
}

/// Build the Chrome-trace document for a finished run.
pub fn chrome_trace(tel: &Telemetry) -> Json {
    let mut events = Vec::new();
    for s in &tel.spans {
        events.push(obj(vec![
            ("name", Json::Str(s.name.clone())),
            ("cat", Json::Str(s.cat.to_string())),
            ("ph", Json::Str("X".into())),
            ("ts", num(s.start)),
            ("dur", num(s.end - s.start)),
            ("pid", num(0)),
            ("tid", num(s.tid as u64)),
        ]));
    }
    for i in &tel.instants {
        events.push(obj(vec![
            ("name", Json::Str(i.name.clone())),
            ("cat", Json::Str("fault".into())),
            ("ph", Json::Str("i".into())),
            ("s", Json::Str("g".into())),
            ("ts", num(i.t)),
            ("pid", num(0)),
            ("tid", num(0)),
        ]));
    }
    // counter tracks: aggregate link utilization and event-queue depth
    // per time-series bucket (one sample at each bucket start)
    let util = tel.utilization_series();
    for (r, u) in util.iter().enumerate() {
        let ts = r as u64 * tel.bucket_cycles();
        events.push(obj(vec![
            ("name", Json::Str("link_utilization".into())),
            ("cat", Json::Str("metric".into())),
            ("ph", Json::Str("C".into())),
            ("ts", num(ts)),
            ("pid", num(0)),
            ("args", obj(vec![("util", Json::Num(*u))])),
        ]));
        events.push(obj(vec![
            ("name", Json::Str("queue_depth".into())),
            ("cat", Json::Str("metric".into())),
            ("ph", Json::Str("C".into())),
            ("ts", num(ts)),
            ("pid", num(0)),
            ("args", obj(vec![("depth", num(tel.queue_depth_at(r)))])),
        ]));
    }
    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::Str("ms".into())),
    ])
}

/// Schema check for an exported trace: `traceEvents` is an array, every
/// event carries `name`/`ph`/`ts` (string, string, number), complete
/// events (`X`) carry a non-negative `dur`, instants carry a scope `s`,
/// and counters carry an `args` object. Returns the first violation.
pub fn validate_chrome_trace(doc: &Json) -> Result<(), String> {
    let events = doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .ok_or("missing traceEvents array")?;
    for (i, ev) in events.iter().enumerate() {
        let fail = |what: &str| Err(format!("event {i}: {what}"));
        if ev.get("name").and_then(|n| n.as_str()).is_none() {
            return fail("missing name");
        }
        let ph = match ev.get("ph").and_then(|p| p.as_str()) {
            Some(p) => p,
            None => return fail("missing ph"),
        };
        let ts = match ev.get("ts").and_then(|t| t.as_f64()) {
            Some(t) => t,
            None => return fail("missing ts"),
        };
        if !ts.is_finite() || ts < 0.0 {
            return fail("non-finite or negative ts");
        }
        match ph {
            "X" => match ev.get("dur").and_then(|d| d.as_f64()) {
                Some(d) if d.is_finite() && d >= 0.0 => {}
                _ => return fail("X event without non-negative dur"),
            },
            "i" => {
                if ev.get("s").and_then(|s| s.as_str()).is_none() {
                    return fail("instant without scope");
                }
            }
            "C" => {
                if ev.get("args").and_then(|a| a.as_obj()).is_none() {
                    return fail("counter without args object");
                }
            }
            other => return Err(format!("event {i}: unknown ph {other:?}")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    fn sample_tel() -> Telemetry {
        let mut t = Telemetry::new();
        t.begin(2, 1, 4);
        t.wire_hop(0, 10, 4, 0);
        t.queue_sample(12, 3);
        t.span("F0 mb0".into(), "phase", 0, 0, 100);
        t.span("AR0".into(), "collective", 1, 100, 250);
        t.reroute(40, 3, 9);
        t
    }

    #[test]
    fn export_validates_and_roundtrips() {
        let tel = sample_tel();
        let doc = chrome_trace(&tel);
        validate_chrome_trace(&doc).unwrap();
        let text = doc.dump();
        let back = parse(&text).unwrap();
        validate_chrome_trace(&back).unwrap();
        let events = back.get("traceEvents").unwrap().as_arr().unwrap();
        // 2 spans + 1 instant + 2 counters per row
        assert!(events.len() >= 5, "{}", events.len());
        assert!(text.contains("\"ph\":\"X\""));
        assert!(text.contains("\"ph\":\"i\""));
        assert!(text.contains("reroute r3->t9"));
    }

    #[test]
    fn validator_rejects_malformed_events() {
        assert!(validate_chrome_trace(&parse("{}").unwrap()).is_err());
        let no_dur = r#"{"traceEvents":[{"name":"a","ph":"X","ts":0}]}"#;
        assert!(validate_chrome_trace(&parse(no_dur).unwrap()).is_err());
        let bad_ph = r#"{"traceEvents":[{"name":"a","ph":"Z","ts":0}]}"#;
        assert!(validate_chrome_trace(&parse(bad_ph).unwrap()).is_err());
        let ok = r#"{"traceEvents":[{"name":"a","ph":"X","ts":0,"dur":5}]}"#;
        assert!(validate_chrome_trace(&parse(ok).unwrap()).is_ok());
    }
}

//! Design-search observability: convergence traces and an eval-count
//! profiler for the AMOSA design flow (ROADMAP item 3's measurement
//! groundwork — before building the surrogate fast path, measure where
//! the ~10^5 evaluations per design go and how early the Pareto front
//! stabilizes).
//!
//! A [`SearchTrace`] is a set of [`SearchStage`]s, one per search pass of
//! a design: `placement` (mesh CPU/MC AMOSA), `wireline:k<k_max>` (the
//! Eqn 6-9 link-placement AMOSA, `:metal` suffix for the unbounded-reach
//! HetNoC ablation), and `wireless` (the greedy WI placement, counted by
//! its traffic-weighted-hop-count evaluations). AMOSA stages carry the
//! full per-temperature-level [`LevelStats`] series recorded by a
//! [`SearchObserver`]; flat stages carry an eval count only.
//!
//! Stages are kept in a canonical order (stage name, then serialized
//! content), so [`SearchTrace::record`] and [`SearchTrace::merge`] are
//! **commutative**: `Ctx::wirelines`' per-k parallel designs produce a
//! byte-identical trace at any `WIHETNOC_THREADS` (pinned by
//! `tests/search_obs.rs`). A [`SearchSink`] (`Arc<Mutex<SearchTrace>>`)
//! is the shareable handle `DesignConfig`/`Ctx` plumb through the design
//! flow — each search pass locks it once, at the end, to deposit its
//! finished stage.
//!
//! Exports: [`SearchTrace::to_json`] (validated by
//! [`validate_search_trace`] and the CI jq smoke), [`SearchTrace::to_csv`]
//! (one row per temperature level), and [`SearchTrace::profile_text`]
//! (the `design --profile` eval-attribution table).

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::optim::amosa::{LevelStats, SearchObserver};
use crate::util::json::Json;

/// Schema tag carried by every exported trace document.
pub const SEARCH_TRACE_SCHEMA: &str = "wihetnoc-search-trace-v1";

/// One search pass of a design: an AMOSA run (with its convergence
/// series) or a flat counted stage (greedy WI placement).
#[derive(Debug, Clone, PartialEq)]
pub struct SearchStage {
    /// Stage key: `placement`, `wireline:k6`, `wireline:k6:metal`,
    /// `wireless`.
    pub stage: String,
    /// Total problem evaluations attributed to this stage.
    pub evals: u64,
    /// Fixed hypervolume reference point (empty for flat stages).
    pub ref_point: Vec<f64>,
    /// Per-temperature-level snapshots (empty for flat stages).
    pub levels: Vec<LevelStats>,
}

impl SearchStage {
    /// Package a finished [`SearchObserver`] as a named stage.
    pub fn from_observer(stage: impl Into<String>, obs: &SearchObserver) -> SearchStage {
        SearchStage {
            stage: stage.into(),
            evals: obs.evals(),
            ref_point: obs.ref_point.clone(),
            levels: obs.levels.clone(),
        }
    }

    /// A counted stage without a convergence series (e.g. the greedy
    /// wireless placement, attributed by its objective evaluations).
    pub fn flat(stage: impl Into<String>, evals: u64) -> SearchStage {
        SearchStage { stage: stage.into(), evals, ref_point: Vec::new(), levels: Vec::new() }
    }

    /// Final best-so-far hypervolume (0.0 for flat stages).
    pub fn final_hypervolume(&self) -> f64 {
        self.levels.last().map_or(0.0, |l| l.hypervolume)
    }

    /// Cumulative evals at the first level whose hypervolume reaches
    /// `frac` of the final hypervolume. `None` for flat stages or a
    /// degenerate (zero) final hypervolume.
    pub fn evals_to_hv_fraction(&self, frac: f64) -> Option<u64> {
        let target = frac * self.final_hypervolume();
        if !(target > 0.0) {
            return None;
        }
        self.levels.iter().find(|l| l.hypervolume >= target).map(|l| l.evals)
    }

    /// Evaluations spent after the hypervolume last improved — the
    /// quantitative case for a surrogate-guided early stop ("X% of evals
    /// occur after the front stops moving"). 0 for flat stages.
    pub fn evals_after_front_stable(&self) -> u64 {
        let mut last_improve = self.levels.first().map_or(0, |l| l.evals);
        let mut prev = 0.0;
        for l in &self.levels {
            if l.hypervolume > prev {
                prev = l.hypervolume;
                last_improve = l.evals;
            }
        }
        self.evals.saturating_sub(last_improve)
    }

    fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("stage".into(), Json::Str(self.stage.clone()));
        o.insert("evals".into(), Json::Num(self.evals as f64));
        o.insert("ref_point".into(), num_arr(&self.ref_point));
        let levels = self
            .levels
            .iter()
            .map(|l| {
                let mut m = BTreeMap::new();
                m.insert("level".into(), Json::Num(l.level as f64));
                m.insert("temp".into(), Json::Num(l.temp));
                m.insert("evals".into(), Json::Num(l.evals as f64));
                m.insert("accepted".into(), Json::Num(l.accepted as f64));
                m.insert("accepted_uphill".into(), Json::Num(l.accepted_uphill as f64));
                m.insert("rejected".into(), Json::Num(l.rejected as f64));
                m.insert("dominated".into(), Json::Num(l.dominated as f64));
                m.insert("archived".into(), Json::Num(l.archived as f64));
                m.insert("archive_len".into(), Json::Num(l.archive_len as f64));
                m.insert("obj_min".into(), num_arr(&l.obj_min));
                m.insert("obj_max".into(), num_arr(&l.obj_max));
                m.insert("hypervolume".into(), Json::Num(l.hypervolume));
                m.insert(
                    "front".into(),
                    Json::Arr(l.front.iter().map(|p| num_arr(p)).collect()),
                );
                Json::Obj(m)
            })
            .collect();
        o.insert("levels".into(), Json::Arr(levels));
        Json::Obj(o)
    }
}

fn num_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&v| Json::Num(v)).collect())
}

/// The full search trace of one design (or several merged designs):
/// stages in canonical order, independent of recording order.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SearchTrace {
    stages: Vec<SearchStage>,
}

impl SearchTrace {
    pub fn new() -> SearchTrace {
        SearchTrace::default()
    }

    /// Stages in canonical order.
    pub fn stages(&self) -> &[SearchStage] {
        &self.stages
    }

    /// First stage with this key, if recorded.
    pub fn stage(&self, key: &str) -> Option<&SearchStage> {
        self.stages.iter().find(|s| s.stage == key)
    }

    /// Deposit a finished stage. Stages are re-sorted into the canonical
    /// order (name, then serialized content), so concurrent recorders
    /// produce the same trace bytes regardless of completion order.
    pub fn record(&mut self, stage: SearchStage) {
        self.stages.push(stage);
        self.canonicalize();
    }

    /// Commutative union: `a.merge(b)` and `b.merge(a)` yield identical
    /// traces — the per-k `Ctx::wirelines` fan-out merges worker-local
    /// results in any completion order.
    pub fn merge(&mut self, other: SearchTrace) {
        self.stages.extend(other.stages);
        self.canonicalize();
    }

    fn canonicalize(&mut self) {
        self.stages.sort_by(|a, b| {
            a.stage
                .cmp(&b.stage)
                .then_with(|| a.to_json().dump().cmp(&b.to_json().dump()))
        });
    }

    /// Total evaluations across all stages.
    pub fn total_evals(&self) -> u64 {
        self.stages.iter().map(|s| s.evals).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.stages.is_empty()
    }

    /// The exported trace document (stable key order via `util::json`).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("schema".into(), Json::Str(SEARCH_TRACE_SCHEMA.into()));
        o.insert("total_evals".into(), Json::Num(self.total_evals() as f64));
        o.insert(
            "stages".into(),
            Json::Arr(self.stages.iter().map(|s| s.to_json()).collect()),
        );
        Json::Obj(o)
    }

    /// One CSV row per temperature level (flat stages emit a single row
    /// with empty level fields).
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "stage,level,temp,evals,accepted,accepted_uphill,rejected,dominated,archived,archive_len,hypervolume\n",
        );
        for s in &self.stages {
            if s.levels.is_empty() {
                out.push_str(&format!("{},,,{},,,,,,,\n", s.stage, s.evals));
                continue;
            }
            for l in &s.levels {
                out.push_str(&format!(
                    "{},{},{},{},{},{},{},{},{},{},{}\n",
                    s.stage,
                    l.level,
                    l.temp,
                    l.evals,
                    l.accepted,
                    l.accepted_uphill,
                    l.rejected,
                    l.dominated,
                    l.archived,
                    l.archive_len,
                    l.hypervolume,
                ));
            }
        }
        out
    }

    /// The `design --profile` eval-attribution table: evaluations per
    /// stage, share of the total, and convergence headlines.
    pub fn profile_text(&self) -> String {
        let total = self.total_evals();
        let mut out = String::from(
            "eval attribution (design search)\n\
             stage                     evals   share%  levels  final_hv  evals_to_99%hv\n",
        );
        if self.stages.is_empty() {
            out.push_str("  (no search stages recorded — mesh architectures run no search)\n");
            return out;
        }
        for s in &self.stages {
            let share = 100.0 * s.evals as f64 / total.max(1) as f64;
            let (hv, to99) = if s.levels.is_empty() {
                ("-".to_string(), "-".to_string())
            } else {
                (
                    format!("{:.4}", s.final_hypervolume()),
                    s.evals_to_hv_fraction(0.99)
                        .map_or_else(|| "-".to_string(), |e| e.to_string()),
                )
            };
            out.push_str(&format!(
                "{:<24} {:>8}  {:>6.1}  {:>6}  {:>8}  {:>14}\n",
                s.stage,
                s.evals,
                share,
                if s.levels.is_empty() { "-".to_string() } else { s.levels.len().to_string() },
                hv,
                to99,
            ));
        }
        out.push_str(&format!("{:<24} {:>8}   100.0\n", "total", total));
        out
    }
}

/// Shareable trace sink: `Clone` + `Send + Sync`, so one sink threads
/// through `DesignConfig` into `par_map` design fan-outs. Each search
/// pass locks it exactly once, when its stage is finished.
pub type SearchSink = Arc<Mutex<SearchTrace>>;

/// A fresh empty sink.
pub fn search_sink() -> SearchSink {
    Arc::new(Mutex::new(SearchTrace::new()))
}

/// Deposit a finished stage into a sink (poisoned-lock-safe: a panicked
/// recorder does not lose the other workers' stages).
pub fn record_stage(sink: &SearchSink, stage: SearchStage) {
    match sink.lock() {
        Ok(mut t) => t.record(stage),
        Err(poison) => poison.into_inner().record(stage),
    }
}

/// Snapshot a sink's current trace.
pub fn sink_trace(sink: &SearchSink) -> SearchTrace {
    match sink.lock() {
        Ok(t) => t.clone(),
        Err(poison) => poison.into_inner().clone(),
    }
}

/// Schema check for an exported search-trace document — the Rust-side
/// mirror of the CI jq smoke, run by the tests on every artifact:
/// required keys, finite hypervolumes, per-stage monotone non-decreasing
/// hypervolume, and strictly increasing cumulative evals.
pub fn validate_search_trace(doc: &Json) -> Result<(), String> {
    let schema = doc
        .get("schema")
        .and_then(Json::as_str)
        .ok_or("missing schema tag")?;
    if schema != SEARCH_TRACE_SCHEMA {
        return Err(format!("unexpected schema '{schema}'"));
    }
    let total = doc
        .get("total_evals")
        .and_then(Json::as_f64)
        .ok_or("missing total_evals")?;
    let stages = doc
        .get("stages")
        .and_then(Json::as_arr)
        .ok_or("missing stages array")?;
    let mut sum = 0.0;
    for (i, s) in stages.iter().enumerate() {
        let name = s
            .get("stage")
            .and_then(Json::as_str)
            .ok_or(format!("stage {i}: missing name"))?;
        let evals = s
            .get("evals")
            .and_then(Json::as_f64)
            .ok_or(format!("stage {name}: missing evals"))?;
        if !(evals >= 0.0) {
            return Err(format!("stage {name}: negative evals"));
        }
        sum += evals;
        let levels = s
            .get("levels")
            .and_then(Json::as_arr)
            .ok_or(format!("stage {name}: missing levels"))?;
        let mut prev_hv = f64::NEG_INFINITY;
        let mut prev_evals = f64::NEG_INFINITY;
        for l in levels {
            let hv = l
                .get("hypervolume")
                .and_then(Json::as_f64)
                .ok_or(format!("stage {name}: level missing hypervolume"))?;
            if !hv.is_finite() || hv < 0.0 {
                return Err(format!("stage {name}: bad hypervolume {hv}"));
            }
            if hv < prev_hv {
                return Err(format!(
                    "stage {name}: hypervolume not monotone ({prev_hv} -> {hv})"
                ));
            }
            prev_hv = hv;
            let ev = l
                .get("evals")
                .and_then(Json::as_f64)
                .ok_or(format!("stage {name}: level missing evals"))?;
            if ev <= prev_evals {
                return Err(format!("stage {name}: evals not increasing"));
            }
            prev_evals = ev;
            for key in ["temp", "accepted", "rejected", "archive_len"] {
                if l.get(key).and_then(Json::as_f64).is_none() {
                    return Err(format!("stage {name}: level missing {key}"));
                }
            }
        }
        if !levels.is_empty() && prev_evals != evals {
            return Err(format!(
                "stage {name}: evals {evals} != last level's cumulative {prev_evals}"
            ));
        }
    }
    if sum != total {
        return Err(format!("total_evals {total} != stage sum {sum}"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    fn stage(name: &str, evals: u64, hv: &[f64]) -> SearchStage {
        let mut levels = Vec::new();
        let per = evals / hv.len().max(1) as u64;
        for (i, &h) in hv.iter().enumerate() {
            levels.push(LevelStats {
                level: i,
                temp: 10.0 * 0.9f64.powi(i as i32),
                evals: if i + 1 == hv.len() { evals } else { per * (i as u64 + 1) },
                accepted: per / 2,
                accepted_uphill: per / 4,
                rejected: per - per / 2,
                dominated: per / 3,
                archived: 2,
                archive_len: 3,
                obj_min: vec![0.1, 0.2],
                obj_max: vec![1.0, 2.0],
                hypervolume: h,
                front: vec![vec![0.1, 2.0], vec![1.0, 0.2]],
            });
        }
        SearchStage { stage: name.into(), evals, ref_point: vec![2.0, 3.0], levels }
    }

    #[test]
    fn merge_is_commutative_and_order_independent() {
        let a = stage("wireline:k4", 800, &[0.1, 0.5, 0.5]);
        let b = stage("wireline:k6", 900, &[0.2, 0.6, 0.7]);
        let c = SearchStage::flat("wireless", 120);
        let mut ab = SearchTrace::new();
        ab.record(a.clone());
        ab.record(b.clone());
        ab.record(c.clone());
        let mut ba = SearchTrace::new();
        ba.record(c);
        ba.record(b);
        ba.record(a);
        assert_eq!(ab.to_json().dump(), ba.to_json().dump());

        let mut m1 = SearchTrace::new();
        m1.merge(ab.clone());
        let mut m2 = ba.clone();
        m2.merge(SearchTrace::new());
        assert_eq!(m1, m2);
        assert_eq!(ab.total_evals(), 800 + 900 + 120);
    }

    #[test]
    fn json_roundtrips_and_validates() {
        let mut t = SearchTrace::new();
        t.record(stage("placement", 600, &[0.0, 0.3, 0.3, 0.4]));
        t.record(SearchStage::flat("wireless", 64));
        let doc = t.to_json();
        validate_search_trace(&doc).unwrap();
        let reparsed = json::parse(&doc.dump()).unwrap();
        validate_search_trace(&reparsed).unwrap();
        assert_eq!(reparsed, doc);
    }

    #[test]
    fn validator_rejects_broken_documents() {
        let mut t = SearchTrace::new();
        t.record(stage("placement", 600, &[0.4, 0.3])); // hv decreases
        let err = validate_search_trace(&t.to_json()).unwrap_err();
        assert!(err.contains("monotone"), "{err}");
        assert!(validate_search_trace(&Json::Num(3.0)).is_err());
        let parsed = json::parse(r#"{"schema":"nope","total_evals":0,"stages":[]}"#).unwrap();
        assert!(validate_search_trace(&parsed).is_err());
    }

    #[test]
    fn convergence_headlines() {
        let s = stage("wireline:k6", 1000, &[0.1, 0.8, 0.99, 1.0, 1.0]);
        assert_eq!(s.final_hypervolume(), 1.0);
        // 99% of 1.0 first reached at the third level (cumulative 600)
        assert_eq!(s.evals_to_hv_fraction(0.99), Some(600));
        // last improvement at level 3 (cumulative 800): 200 evals wasted
        assert_eq!(s.evals_after_front_stable(), 200);
        assert_eq!(SearchStage::flat("wireless", 9).evals_to_hv_fraction(0.99), None);
        assert_eq!(SearchStage::flat("wireless", 9).evals_after_front_stable(), 0);
    }

    #[test]
    fn csv_and_profile_render() {
        let mut t = SearchTrace::new();
        t.record(stage("placement", 600, &[0.1, 0.2, 0.3]));
        t.record(SearchStage::flat("wireless", 64));
        let csv = t.to_csv();
        assert!(csv.starts_with("stage,level,temp,evals,"));
        assert_eq!(csv.lines().count(), 1 + 3 + 1);
        let prof = t.profile_text();
        assert!(prof.contains("placement"));
        assert!(prof.contains("wireless"));
        assert!(prof.contains("total"));
        assert!(SearchTrace::new().profile_text().contains("no search stages"));
    }

    #[test]
    fn sink_records_and_snapshots() {
        let sink = search_sink();
        record_stage(&sink, SearchStage::flat("wireless", 5));
        record_stage(&sink, stage("placement", 100, &[0.5]));
        let t = sink_trace(&sink);
        assert_eq!(t.stages().len(), 2);
        assert_eq!(t.stages()[0].stage, "placement", "canonical order");
        assert_eq!(t.total_evals(), 105);
        assert!(t.stage("wireless").is_some());
    }
}

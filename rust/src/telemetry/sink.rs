//! The [`Telemetry`] sink: everything the simulator can tell an observer
//! without the observer ever talking back.
//!
//! A sink is handed to a `run_*` entry point as `Option<&mut Telemetry>`
//! (the same hooks-off-the-hot-path shape as the fault layer's
//! `Option<&SimFaults>`): `None` compiles to a never-taken branch per
//! hook site, keeping the off path allocation-free and byte-identical to
//! the pre-telemetry simulator. With a sink attached the hooks only
//! *read* simulation state — they never feed anything back — so the
//! [`crate::noc::sim::SimReport`] is byte-identical either way (pinned
//! by `tests/telemetry.rs` at 1/2/8 `WIHETNOC_THREADS`).
//!
//! What it collects (the paper's §3 traffic analysis, on our own
//! simulator):
//! * per-link flit counts bucketed into a utilization **time series**
//!   (fold-on-overflow: a fixed row budget, doubling the bucket width as
//!   the run outgrows it) plus the end-of-run link heatmap;
//! * **latency histograms** ([`LogHistogram`]) per pair class — CPU-MC,
//!   GPU-MC, CPU-GPU — with exact p50/p99/p999 semantics (ROADMAP 2);
//! * event-**queue depth** peaks and wireless-**channel occupancy** per
//!   time bucket, with retry/fallback counters unified from
//!   [`ResilienceStats`] at [`Telemetry::finish`];
//! * **per-tile active cycles** metered from hop events — the exact
//!   per-router activity ROADMAP item 5's overlap-energy accounting
//!   needs;
//! * phase/collective **spans** and fault-reroute **instants** recorded
//!   by the schedule/fabric layers, exported as a Chrome trace by
//!   [`crate::telemetry::trace::chrome_trace`].

use crate::faults::ResilienceStats;
use crate::noc::sim::{SimReport, PAIR_CPU_GPU, PAIR_CPU_MC, PAIR_GPU_MC};

use super::hist::LogHistogram;

/// Row budget of the time series; outgrowing it folds adjacent rows and
/// doubles [`Telemetry::bucket_cycles`], so memory stays bounded for
/// arbitrarily long runs while short runs keep fine resolution.
const MAX_ROWS: usize = 512;
/// Initial time-series bucket width in cycles.
const INITIAL_BUCKET_CYCLES: u64 = 256;

/// One completed slice of simulated time on one track of the timeline
/// (a phase×microbatch instance, a collective step, an analytic wire
/// hop). `tid` is the pipeline stage (tracks render as rows in
/// Perfetto); spans on one track never overlap — stage resource edges
/// serialize them.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub name: String,
    /// Category: `"phase"`, `"collective"`, `"fabric"`, or `"serve"`
    /// (one span per drained serving batch).
    pub cat: &'static str,
    pub tid: u32,
    pub start: u64,
    pub end: u64,
}

/// A point event (Chrome-trace instant): currently fault reroutes.
#[derive(Debug, Clone, PartialEq)]
pub struct Instant {
    pub name: String,
    pub t: u64,
}

/// p50/p99/p999 (plus count and mean) of one latency class.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ClassPercentiles {
    pub count: u64,
    pub mean: f64,
    pub p50: u64,
    pub p99: u64,
    pub p999: u64,
}

impl ClassPercentiles {
    /// Snapshot a histogram's percentile view. Public so per-tenant
    /// serving histograms render through the same machinery as the
    /// simulator's pair classes.
    pub fn of(h: &LogHistogram) -> ClassPercentiles {
        ClassPercentiles {
            count: h.count(),
            mean: h.mean(),
            p50: h.p50(),
            p99: h.p99(),
            p999: h.p999(),
        }
    }
}

/// One `--metrics`-style latency line for a named class (empty when the
/// class saw no samples). Shared by [`Telemetry::summary`] and the
/// serving CLI's per-tenant percentile block, so both render
/// identically.
pub fn class_line(name: &str, c: &ClassPercentiles) -> String {
    if c.count == 0 {
        return String::new();
    }
    format!(
        "  latency {name:<7} p50 {:>6}  p99 {:>6}  p999 {:>6}  (n={}, mean {:.1})",
        c.p50, c.p99, c.p999, c.count, c.mean
    )
}

/// Tail-latency percentiles per pair class — the payload a display layer
/// attaches to a report via
/// [`crate::noc::sim::SimReport::attach_percentiles`].
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencyPercentiles {
    pub all: ClassPercentiles,
    pub cpu_mc: ClassPercentiles,
    pub gpu_mc: ClassPercentiles,
    pub cpu_gpu: ClassPercentiles,
}

/// The metrics sink. Create one, pass `Some(&mut sink)` to a telemetry
/// entry point (`run_telemetry`, `run_schedule_obs`, `run_fabric_obs`,
/// CLI `--metrics`/`--trace`), then read the collected series,
/// histograms, and spans. A sink is reset at the start of each attached
/// run ([`Telemetry::begin`]); spans added *after* a run survive until
/// the next one.
#[derive(Debug, Clone, Default)]
pub struct Telemetry {
    nl: usize,
    nch: usize,
    /// Cycles per time-series row (doubles on fold).
    bucket_cycles: u64,
    rows: usize,
    /// Flits per link per row, row-major (`row * nl + link`).
    link_rows: Vec<u64>,
    /// Wireless busy cycles per channel per row (`row * nch + ch`).
    air_rows: Vec<u64>,
    /// Event-queue depth peak per row.
    queue_rows: Vec<u64>,
    /// End-to-end latency histograms per pair class.
    pub lat_all: LogHistogram,
    pub lat_cpu_mc: LogHistogram,
    pub lat_gpu_mc: LogHistogram,
    pub lat_cpu_gpu: LogHistogram,
    /// Wire-hop queueing delay (cycles a head waited for a busy link).
    pub queue_wait: LogHistogram,
    /// Per-tile active cycles: flit-traversals metered at each router's
    /// hop events (ROADMAP 5's exact-overlap energy input).
    pub tile_active: Vec<u64>,
    /// Timeline spans (phases, collective steps, wire hops).
    pub spans: Vec<Span>,
    /// Point events (fault reroutes).
    pub instants: Vec<Instant>,
    /// End-of-run per-link flit totals (the heatmap), copied from the
    /// report at [`Telemetry::finish`].
    pub link_flits: Vec<u64>,
    pub cycles: u64,
    pub delivered_packets: u64,
    /// Wireless MAC fallbacks, unified from the report.
    pub air_fallbacks: u64,
    /// Fault counters, unified from [`ResilienceStats`] (retries,
    /// fallback flits, reroutes) so one artifact carries both tiers.
    pub resilience: ResilienceStats,
}

impl Telemetry {
    pub fn new() -> Telemetry {
        Telemetry { bucket_cycles: INITIAL_BUCKET_CYCLES, ..Telemetry::default() }
    }

    /// Reset and size for a run (called by the simulator when the sink
    /// is attached). Spans and instants recorded before the run are
    /// dropped — record them after.
    pub fn begin(&mut self, num_links: usize, num_channels: usize, num_tiles: usize) {
        self.nl = num_links;
        self.nch = num_channels.max(1);
        self.bucket_cycles = INITIAL_BUCKET_CYCLES;
        self.rows = 0;
        self.link_rows.clear();
        self.air_rows.clear();
        self.queue_rows.clear();
        self.lat_all.reset();
        self.lat_cpu_mc.reset();
        self.lat_gpu_mc.reset();
        self.lat_cpu_gpu.reset();
        self.queue_wait.reset();
        self.tile_active.clear();
        self.tile_active.resize(num_tiles, 0);
        self.spans.clear();
        self.instants.clear();
        self.link_flits.clear();
        self.cycles = 0;
        self.delivered_packets = 0;
        self.air_fallbacks = 0;
        self.resilience = ResilienceStats::default();
    }

    /// Row index for cycle `t`, folding/growing the series as needed.
    #[inline]
    fn row_for(&mut self, t: u64) -> usize {
        let mut r = (t / self.bucket_cycles) as usize;
        while r >= MAX_ROWS {
            self.fold();
            r = (t / self.bucket_cycles) as usize;
        }
        if r >= self.rows {
            self.rows = r + 1;
            self.link_rows.resize(self.rows * self.nl, 0);
            self.air_rows.resize(self.rows * self.nch, 0);
            self.queue_rows.resize(self.rows, 0);
        }
        r
    }

    /// Halve the time resolution: combine adjacent row pairs (sum for
    /// flits/busy, max for queue-depth peaks) and double the bucket
    /// width. Totals are conserved exactly.
    fn fold(&mut self) {
        let new_rows = self.rows.div_ceil(2);
        for r in 0..new_rows {
            let (a, b) = (2 * r, 2 * r + 1);
            for l in 0..self.nl {
                let hi = if b < self.rows { self.link_rows[b * self.nl + l] } else { 0 };
                self.link_rows[r * self.nl + l] = self.link_rows[a * self.nl + l] + hi;
            }
            for c in 0..self.nch {
                let hi = if b < self.rows { self.air_rows[b * self.nch + c] } else { 0 };
                self.air_rows[r * self.nch + c] = self.air_rows[a * self.nch + c] + hi;
            }
            let hi = if b < self.rows { self.queue_rows[b] } else { 0 };
            self.queue_rows[r] = self.queue_rows[a].max(hi);
        }
        self.rows = new_rows;
        self.link_rows.truncate(new_rows * self.nl);
        self.air_rows.truncate(new_rows * self.nch);
        self.queue_rows.truncate(new_rows);
        self.bucket_cycles *= 2;
    }

    // ---- hot-path hooks (read-only views of simulator state) ----

    /// A head flit traversed router `tile` carrying `flits`.
    #[inline]
    pub fn hop(&mut self, tile: usize, flits: u64) {
        self.tile_active[tile] += flits;
    }

    /// A packet occupied wireline `link` from `start`, after waiting
    /// `wait` cycles for it to drain.
    #[inline]
    pub fn wire_hop(&mut self, link: usize, start: u64, flits: u64, wait: u64) {
        let nl = self.nl;
        let r = self.row_for(start);
        self.link_rows[r * nl + link] += flits;
        self.queue_wait.record(wait);
    }

    /// A packet occupied wireless `channel` for `ser` cycles from `start`.
    #[inline]
    pub fn air_hop(&mut self, channel: usize, start: u64, ser: u64) {
        let nch = self.nch;
        let r = self.row_for(start);
        self.air_rows[r * nch + channel] += ser;
    }

    /// Event-queue depth observed at cycle `t` (per-bucket peak).
    #[inline]
    pub fn queue_sample(&mut self, t: u64, depth: usize) {
        let r = self.row_for(t);
        if depth as u64 > self.queue_rows[r] {
            self.queue_rows[r] = depth as u64;
        }
    }

    /// A packet tail-delivered with end-to-end latency `lat`; `pair` is
    /// the simulator's pair-class code.
    #[inline]
    pub fn delivered(&mut self, pair: u8, lat: u64) {
        self.lat_all.record(lat);
        match pair {
            PAIR_CPU_MC => self.lat_cpu_mc.record(lat),
            PAIR_GPU_MC => self.lat_gpu_mc.record(lat),
            PAIR_CPU_GPU => self.lat_cpu_gpu.record(lat),
            _ => {}
        }
    }

    /// A packet re-rooted around a dead link at router `from` (fault
    /// path only — allocation here never touches fault-free runs).
    pub fn reroute(&mut self, t: u64, from: usize, dst: usize) {
        self.instants.push(Instant { name: format!("reroute r{from}->t{dst}"), t });
    }

    // ---- post-run recording ----

    /// Record a timeline span (schedule/fabric layers, after the run).
    pub fn span(
        &mut self,
        name: String,
        cat: &'static str,
        tid: u32,
        start: u64,
        end: u64,
    ) {
        debug_assert!(end >= start, "span ends before it starts");
        self.spans.push(Span { name, cat, tid, start, end });
    }

    /// Absorb the finished report: the link heatmap, run extent, and the
    /// unified retry/fallback/reroute counters.
    pub fn finish(&mut self, report: &SimReport) {
        self.link_flits = report.link_flits.clone();
        self.cycles = report.cycles;
        self.delivered_packets = report.delivered_packets;
        self.air_fallbacks = report.air_fallbacks;
        self.resilience = report.resilience.clone();
    }

    // ---- accessors ----

    /// Number of time-series rows collected so far.
    pub fn num_rows(&self) -> usize {
        self.rows
    }

    /// Cycles per time-series row.
    pub fn bucket_cycles(&self) -> u64 {
        self.bucket_cycles
    }

    /// Flits link `link` carried during row `row`.
    pub fn link_flits_at(&self, row: usize, link: usize) -> u64 {
        self.link_rows[row * self.nl + link]
    }

    /// Busy cycles channel `ch` spent during row `row`.
    pub fn air_busy_at(&self, row: usize, ch: usize) -> u64 {
        self.air_rows[row * self.nch + ch]
    }

    /// Peak event-queue depth during row `row`.
    pub fn queue_depth_at(&self, row: usize) -> u64 {
        self.queue_rows[row]
    }

    /// Peak event-queue depth over the whole run.
    pub fn queue_depth_peak(&self) -> u64 {
        self.queue_rows.iter().copied().max().unwrap_or(0)
    }

    /// Aggregate network utilization per row: flits moved during the row
    /// over `links x bucket_cycles` capacity.
    pub fn utilization_series(&self) -> Vec<f64> {
        let cap = (self.nl as u64 * self.bucket_cycles).max(1) as f64;
        (0..self.rows)
            .map(|r| {
                let flits: u64 =
                    (0..self.nl).map(|l| self.link_rows[r * self.nl + l]).sum();
                flits as f64 / cap
            })
            .collect()
    }

    /// The single headline number: p99 end-to-end latency over *all*
    /// delivered traffic, every pair class together.
    pub fn headline_p99(&self) -> u64 {
        self.lat_all.p99()
    }

    /// Tail-latency percentiles for every pair class.
    pub fn percentiles(&self) -> LatencyPercentiles {
        LatencyPercentiles {
            all: ClassPercentiles::of(&self.lat_all),
            cpu_mc: ClassPercentiles::of(&self.lat_cpu_mc),
            gpu_mc: ClassPercentiles::of(&self.lat_gpu_mc),
            cpu_gpu: ClassPercentiles::of(&self.lat_cpu_gpu),
        }
    }

    /// Links sorted hottest-first as `(link, flits)`, capped at `top`.
    pub fn hottest_links(&self, top: usize) -> Vec<(usize, u64)> {
        let mut v: Vec<(usize, u64)> =
            self.link_flits.iter().copied().enumerate().filter(|&(_, f)| f > 0).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.truncate(top);
        v
    }

    /// Human-readable summary (the CLI's `--metrics` output).
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let p = self.percentiles();
        let mut s = String::new();
        let _ = writeln!(
            s,
            "telemetry: {} packets over {} cycles ({} x {}-cycle buckets)",
            self.delivered_packets, self.cycles, self.rows, self.bucket_cycles
        );
        if p.all.count > 0 {
            let _ = writeln!(
                s,
                "  headline: all-traffic p99 {} cyc over {} packets",
                p.all.p99, p.all.count
            );
        }
        let class = |s: &mut String, name: &str, c: &ClassPercentiles| {
            let line = class_line(name, c);
            if !line.is_empty() {
                let _ = writeln!(s, "{line}");
            }
        };
        class(&mut s, "all", &p.all);
        class(&mut s, "cpu-mc", &p.cpu_mc);
        class(&mut s, "gpu-mc", &p.gpu_mc);
        class(&mut s, "cpu-gpu", &p.cpu_gpu);
        let hot = self.hottest_links(5);
        if !hot.is_empty() {
            let c = self.cycles.max(1) as f64;
            let _ = write!(s, "  hottest links:");
            for (l, f) in &hot {
                let _ = write!(s, " #{l} ({f} flits, {:.2} util)", *f as f64 / c);
            }
            let _ = writeln!(s);
        }
        let _ = writeln!(
            s,
            "  queue depth peak {} | wire-queue wait p99 {} cyc | {} air fallbacks | {} reroutes | {} retries",
            self.queue_depth_peak(),
            self.queue_wait.p99(),
            self.air_fallbacks,
            self.resilience.packets_rerouted,
            self.resilience.retries,
        );
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_folds_but_conserves_totals() {
        let mut t = Telemetry::new();
        t.begin(2, 1, 4);
        // spread hits far past the initial MAX_ROWS * bucket window
        let horizon = INITIAL_BUCKET_CYCLES * MAX_ROWS as u64 * 8;
        let mut total = 0u64;
        let step = horizon / 1000;
        for i in 0..1000u64 {
            t.wire_hop(i as usize % 2, i * step, 3, 0);
            total += 3;
        }
        assert!(t.num_rows() <= MAX_ROWS);
        assert!(t.bucket_cycles() > INITIAL_BUCKET_CYCLES, "must have folded");
        let sum: u64 =
            (0..t.num_rows()).map(|r| t.link_flits_at(r, 0) + t.link_flits_at(r, 1)).sum();
        assert_eq!(sum, total, "folding must conserve flit totals");
    }

    #[test]
    fn queue_peak_folds_as_max() {
        let mut t = Telemetry::new();
        t.begin(1, 1, 1);
        t.queue_sample(0, 7);
        t.queue_sample(INITIAL_BUCKET_CYCLES * MAX_ROWS as u64 * 2, 3);
        assert_eq!(t.queue_depth_peak(), 7);
    }

    #[test]
    fn class_routing_and_summary() {
        let mut t = Telemetry::new();
        t.begin(1, 1, 2);
        t.delivered(PAIR_CPU_MC, 10);
        t.delivered(PAIR_GPU_MC, 20);
        t.delivered(PAIR_CPU_GPU, 30);
        t.delivered(0, 40);
        let p = t.percentiles();
        assert_eq!(p.all.count, 4);
        assert_eq!(p.cpu_mc.count, 1);
        assert_eq!(p.gpu_mc.p50, 20);
        assert_eq!(p.cpu_gpu.p50, 30);
        t.hop(1, 5);
        assert_eq!(t.tile_active, vec![0, 5]);
        let s = t.summary();
        assert!(s.contains("cpu-gpu"), "{s}");
        // the aggregate headline line rides above the class lines
        assert!(s.contains("headline: all-traffic p99"), "{s}");
        assert_eq!(t.headline_p99(), p.all.p99);
    }

    #[test]
    fn class_line_matches_the_summary_rendering() {
        let mut t = Telemetry::new();
        t.begin(1, 1, 1);
        t.delivered(PAIR_CPU_MC, 10);
        let p = t.percentiles();
        let line = class_line("cpu-mc", &p.cpu_mc);
        assert!(t.summary().contains(&line), "{line}");
        assert!(class_line("empty", &ClassPercentiles::default()).is_empty());
    }
}

//! Micro-benchmark harness (criterion is not vendored; `cargo bench`
//! targets use `harness = false` and drive this).
//!
//! Methodology: warmup runs, then `samples` timed batches; reports
//! median and MAD so stray scheduler noise does not skew results.
//!
//! Results are machine-readable: [`Bencher::to_json`] serializes the
//! run, and [`merge_run`] folds it into a `BENCH_sim.json` document that
//! keeps one entry per label — record a `baseline` run before a perf
//! change and re-bench afterwards to get a committed before/after pair
//! (see README §Performance).

use std::collections::BTreeMap;
use std::time::Instant;

use crate::util::json::{self, Json};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mad_ns: f64,
    pub samples: usize,
    /// Optional throughput annotation (items per iteration).
    pub items: Option<f64>,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("median_ns".into(), Json::Num(self.median_ns));
        m.insert("mad_ns".into(), Json::Num(self.mad_ns));
        m.insert("samples".into(), Json::Num(self.samples as f64));
        m.insert(
            "items".into(),
            self.items.map(Json::Num).unwrap_or(Json::Null),
        );
        Json::Obj(m)
    }

    pub fn report(&self) -> String {
        let per_item = self
            .items
            .map(|n| format!("  ({:.1} Mitems/s)", n / self.median_ns * 1e3))
            .unwrap_or_default();
        format!(
            "{:<44} {:>12}  ±{:>10}{}",
            self.name,
            fmt_ns(self.median_ns),
            fmt_ns(self.mad_ns),
            per_item
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

pub struct Bencher {
    pub warmup: usize,
    pub samples: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, samples: 7, results: Vec::new() }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: 1, samples: 3, results: Vec::new() }
    }

    /// Time `f`, which performs one full iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_items(name, None, &mut f)
    }

    /// Time `f` and annotate items/iteration for throughput reporting.
    pub fn bench_items<F: FnMut()>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut F,
    ) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut times: Vec<f64> = (0..self.samples)
            .map(|_| {
                let t0 = Instant::now();
                f();
                t0.elapsed().as_nanos() as f64
            })
            .collect();
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = times[times.len() / 2];
        let mut dev: Vec<f64> = times.iter().map(|t| (t - median).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mad = dev[dev.len() / 2];
        let r = BenchResult {
            name: name.to_string(),
            median_ns: median,
            mad_ns: mad,
            samples: self.samples,
            items,
        };
        println!("{}", r.report());
        self.results.push(r);
        self.results.last().unwrap()
    }

    /// Serialize this run: `{"results": [...], <meta key/values>}`.
    pub fn to_json(&self, meta: &[(&str, Json)]) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "results".into(),
            Json::Arr(self.results.iter().map(BenchResult::to_json).collect()),
        );
        for (k, v) in meta {
            m.insert((*k).into(), v.clone());
        }
        Json::Obj(m)
    }
}

/// Fold a labeled run into a `BENCH_sim.json` document (creating the
/// skeleton if `existing` is empty/invalid). The document keeps one run
/// per label in `runs`, so `baseline` survives later `current` updates:
///
/// ```json
/// {"schema": 1, "runs": [{"label": "baseline", ...}, {"label": "current", ...}]}
/// ```
pub fn merge_run(existing: &str, label: &str, run: Json) -> Json {
    let mut doc = match json::parse(existing) {
        Ok(Json::Obj(m)) => m,
        _ => BTreeMap::new(),
    };
    doc.insert("schema".into(), Json::Num(1.0));
    let mut runs: Vec<Json> = match doc.remove("runs") {
        Some(Json::Arr(v)) => v,
        _ => Vec::new(),
    };
    runs.retain(|r| r.get("label").and_then(Json::as_str) != Some(label));
    let run = match run {
        Json::Obj(mut m) => {
            m.insert("label".into(), Json::Str(label.to_string()));
            Json::Obj(m)
        }
        other => other,
    };
    runs.push(run);
    doc.insert("runs".into(), Json::Arr(runs));
    Json::Obj(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher { warmup: 1, samples: 3, results: Vec::new() };
        let mut acc = 0u64;
        let r = b.bench("spin", || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(r.median_ns > 0.0);
        assert!(acc > 0);
    }

    #[test]
    fn formats() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(1500.0).contains("µs"));
        assert!(fmt_ns(2.5e6).contains("ms"));
        assert!(fmt_ns(3.2e9).contains(" s"));
    }

    #[test]
    fn json_roundtrip_and_label_merge() {
        let mut b = Bencher { warmup: 0, samples: 1, results: Vec::new() };
        b.bench("x", || {});
        let run = b.to_json(&[("effort", Json::Str("quick".into()))]);
        assert_eq!(run.get("effort").and_then(Json::as_str), Some("quick"));
        // first write becomes the baseline of an empty document
        let doc = merge_run("", "baseline", run.clone());
        let runs = doc.get("runs").and_then(Json::as_arr).unwrap();
        assert_eq!(runs.len(), 1);
        // a current run lands next to it ...
        let doc = merge_run(&doc.dump(), "current", run.clone());
        assert_eq!(doc.get("runs").and_then(Json::as_arr).unwrap().len(), 2);
        // ... and re-benching replaces current while baseline survives
        let doc = merge_run(&doc.dump(), "current", run);
        let runs = doc.get("runs").and_then(Json::as_arr).unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].get("label").and_then(Json::as_str), Some("baseline"));
        assert_eq!(runs[1].get("label").and_then(Json::as_str), Some("current"));
        assert!(json::parse(&doc.dump()).is_ok());
    }
}

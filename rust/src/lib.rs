//! # WiHetNoC — wireless-enabled heterogeneous NoC for CNN training
//!
//! Reproduction of Choi et al., *On-Chip Communication Network for
//! Efficient Training of Deep Convolutional Networks on Heterogeneous
//! Manycore Systems* (IEEE TC 2017), generalized beyond the paper's 8x8
//! chip by a typed scenario API.
//!
//! ## The typed API
//!
//! Four pillars describe any evaluation:
//!
//! * [`Platform`] — *what chip*: a `width x height` grid with a CPU/GPU/MC
//!   mix and a placement policy, validated at construction. Parses from
//!   strings: `"8x8"` (the paper's 56 GPU / 4 CPU / 4 MC die), `"4x4"`,
//!   `"12x12:cpus=8,mcs=8,placement=corners"`, ...
//! * [`ModelId`] — *what workload*: a named preset (`lenet`, `cdbnet`,
//!   `alexnet`, `vgg11`, `resnet-lite`) or any CNN written in the
//!   [`workload`] architecture DSL (`"conv:5x5x20 pool:2 ... dense:10"`),
//!   mapped onto the tiles by a [`MappingPolicy`] (data-parallel
//!   replicas or pipelined layer stages), lowered to NoC traffic by
//!   [`workload::lower`], and laid out in time by a [`SchedulePolicy`]
//!   (`serial`, `gpipe:M`, `1f1b:M` — overlapping microbatch phases
//!   simulated concurrently by [`schedule::run_schedule`]).
//! * [`Scenario`] — *what experiment*: platform + workload + mapping +
//!   interconnect ([`noc::builder::NocKind`]) + [`Effort`]/seed/batch,
//!   optionally scaled out to a multi-chip [`Fabric`] (`N` replicated
//!   chips with alpha-beta inter-chip links running a gradient-allreduce
//!   — see [`fabric`]). The single input to design, simulation, and the
//!   experiment harnesses.
//!
//! The paper's evaluation itself is typed too: every table/figure is an
//! [`experiments::Experiment`] in a registry, and each harness returns a
//! structured [`experiments::Report`] (scalar/series/table sections with
//! units and paper-stated expected values) that renders as text, CSV, or
//! JSON — see [`experiments::run`] / [`experiments::run_many`].
//! * [`noc::builder::NocDesigner`] — *how to build it*: a fluent builder
//!   that runs the paper's design flow (AMOSA wireline optimization,
//!   wireless overlay, ALASH routing) with knobs scaled to the platform.
//!
//! Every fallible entry point returns [`WihetError`]; user input (model
//! names, NoC names, experiment ids, platform strings) never panics.
//!
//! ```no_run
//! use wihetnoc::noc::builder::{NocDesigner, NocKind};
//! use wihetnoc::{ModelId, Platform, Scenario, WihetError};
//!
//! // The paper's chip ...
//! let paper = Scenario::paper();
//! // ... or any platform you can describe:
//! let edge: Platform = "4x4:cpus=2,mcs=2".parse()?;
//! let scenario = Scenario::new(edge, ModelId::CdbNet).with_seed(7);
//! let wihet = NocDesigner::for_scenario(&scenario)?.build()?;
//! let mesh = NocDesigner::for_scenario(&scenario)?.kind(NocKind::MeshXyYx).build()?;
//! # let _ = (paper, wihet, mesh);
//! # Ok::<(), WihetError>(())
//! ```
//!
//! ## Architecture
//!
//! Three layers; Python is never on the request path:
//! * **L1/L2 (build-time Python)**: Pallas conv/pool/dense kernels and the
//!   LeNet/CDBNet training step in JAX, AOT-lowered to HLO text under
//!   `artifacts/` by `make artifacts`.
//! * **L3 (this crate)**: the PJRT runtime executes the artifacts while
//!   the NoC toolchain — traffic model, AMOSA design-space optimizer,
//!   cycle-level simulator, energy model — evaluates mesh / HetNoC /
//!   WiHetNoC architectures running that workload.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod bench;
pub mod coordinator;
pub mod energy;
pub mod error;
pub mod experiments;
pub mod fabric;
pub mod faults;
pub mod model;
pub mod noc;
pub mod optim;
pub mod runtime;
pub mod scenario;
pub mod schedule;
pub mod serving;
pub mod telemetry;
pub mod traffic;
pub mod util;
pub mod workload;

pub use error::WihetError;
pub use fabric::{Collective, Fabric};
pub use faults::FaultPlan;
pub use model::{Platform, PlacementPolicy};
pub use scenario::{Effort, ModelId, Scenario, ScenarioKey};
pub use schedule::SchedulePolicy;
pub use serving::ServingSpec;
pub use workload::{ArchSpec, MappingPolicy};

//! # WiHetNoC — wireless-enabled heterogeneous NoC for CNN training
//!
//! Reproduction of Choi et al., *On-Chip Communication Network for
//! Efficient Training of Deep Convolutional Networks on Heterogeneous
//! Manycore Systems* (IEEE TC 2017). See DESIGN.md for the system
//! inventory and EXPERIMENTS.md for paper-vs-measured results.
//!
//! Architecture (three layers, Python never on the request path):
//! * **L1/L2 (build-time Python)**: Pallas conv/pool/dense kernels and the
//!   LeNet/CDBNet training step in JAX, AOT-lowered to HLO text under
//!   `artifacts/` by `make artifacts`.
//! * **L3 (this crate)**: the PJRT runtime executes the artifacts while
//!   the NoC toolchain — traffic model, AMOSA design-space optimizer,
//!   cycle-level simulator, energy model — evaluates mesh / HetNoC /
//!   WiHetNoC architectures running that workload.

pub mod bench;
pub mod coordinator;
pub mod energy;
pub mod experiments;
pub mod model;
pub mod noc;
pub mod optim;
pub mod runtime;
pub mod traffic;
pub mod util;

//! Network energy accounting from a `SimReport`: wireline link, router,
//! and wireless channel energies, plus the per-message EDP the paper's
//! Fig 18 reports.

use crate::energy::params::EnergyParams;
use crate::noc::sim::SimReport;
use crate::noc::topology::Topology;

#[derive(Debug, Clone, Default)]
pub struct NetworkEnergy {
    pub wire_pj: f64,
    pub router_pj: f64,
    pub wireless_pj: f64,
}

impl NetworkEnergy {
    pub fn total_pj(&self) -> f64 {
        self.wire_pj + self.router_pj + self.wireless_pj
    }

    pub fn total_joules(&self) -> f64 {
        self.total_pj() * 1e-12
    }
}

/// Aggregate network energy of a simulation run.
pub fn network_energy_pj(topo: &Topology, rep: &SimReport, p: &EnergyParams) -> NetworkEnergy {
    let mut wire = 0.0;
    for (li, link) in topo.links.iter().enumerate() {
        wire += rep.link_flits[li] as f64 * p.wire_flit_pj(link.length_mm);
    }
    let mut router = 0.0;
    for (r, &flits) in rep.router_flits.iter().enumerate() {
        // +1 local (core) port on top of the inter-tile ports
        router += flits as f64 * p.router_flit_pj(topo.degree(r) + 1);
    }
    let wireless: f64 = rep
        .air_flits
        .iter()
        .map(|&f| f as f64 * p.wireless_flit_pj())
        .sum();
    NetworkEnergy { wire_pj: wire, router_pj: router, wireless_pj: wireless }
}

/// Per-message EDP (pJ x cycles): mean message energy times mean latency —
/// the quantity plotted in Fig 18.
pub fn message_edp(topo: &Topology, rep: &SimReport, p: &EnergyParams) -> f64 {
    if rep.delivered_packets == 0 {
        return 0.0;
    }
    let e = network_energy_pj(topo, rep, p).total_pj() / rep.delivered_packets as f64;
    e * rep.latency.mean()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::SystemConfig;
    use crate::noc::routing::RouteSet;
    use crate::noc::sim::{Message, MsgClass, NocSim, SimConfig};
    use crate::noc::wireless::WirelessSpec;

    fn run_one(src: usize, dst: usize) -> (Topology, SimReport) {
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rs = RouteSet::xy(&sys, &topo);
        let air = WirelessSpec::new(0);
        let sim = NocSim::new(&sys, &topo, &rs, &air, SimConfig::default());
        let rep = sim.run(&[Message { src, dst, flits: 1, class: MsgClass::Control, inject_at: 0 }]);
        (topo, rep)
    }

    #[test]
    fn energy_scales_with_hops() {
        let p = EnergyParams::default();
        let (t1, r1) = run_one(0, 1);
        let (t2, r2) = run_one(0, 63);
        let e1 = network_energy_pj(&t1, &r1, &p).total_pj();
        let e2 = network_energy_pj(&t2, &r2, &p).total_pj();
        assert!(e2 > 10.0 * e1, "e1 {e1} e2 {e2}");
    }

    #[test]
    fn exact_one_hop_energy() {
        let p = EnergyParams::default();
        let (t, r) = run_one(0, 1);
        let want = p.wire_flit_pj(2.5) + p.router_flit_pj(t.degree(0) + 1);
        let got = network_energy_pj(&t, &r, &p).total_pj();
        assert!((got - want).abs() < 1e-9, "got {got} want {want}");
    }

    #[test]
    fn message_edp_positive() {
        let p = EnergyParams::default();
        let (t, r) = run_one(0, 63);
        assert!(message_edp(&t, &r, &p) > 0.0);
    }

    #[test]
    fn empty_report_zero() {
        let p = EnergyParams::default();
        let sys = SystemConfig::paper_8x8();
        let topo = Topology::mesh(&sys);
        let rep = SimReport {
            link_flits: vec![0; topo.links.len()],
            router_flits: vec![0; topo.n],
            air_flits: vec![0; 1],
            link_busy: vec![0; topo.links.len()],
            air_busy: vec![0; 1],
            ..Default::default()
        };
        assert_eq!(message_edp(&topo, &rep, &p), 0.0);
    }
}

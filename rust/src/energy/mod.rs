//! Energy and EDP models: network (router + wireline + wireless per-flit
//! energies) and full system (core power x execution time + network).

pub mod network;
pub mod params;
pub mod system;

pub use network::{network_energy_pj, message_edp, NetworkEnergy};
pub use params::EnergyParams;
pub use system::{
    core_energy_from_counters, full_system_run, full_system_run_fabric, full_system_run_faults,
    full_system_run_scheduled, full_system_run_serving, FullSystemReport,
};

//! Energy/power constants (28 nm, DSENT-style scaling; wireless figures
//! from the paper §4.2.4). All relative comparisons in the paper's
//! evaluation are reproduced with these constants; absolute joules are
//! simulator-grade estimates (DESIGN.md §2).

#[derive(Debug, Clone)]
pub struct EnergyParams {
    /// Wireline signaling energy per bit per mm (repeated global wire).
    pub wire_pj_per_bit_mm: f64,
    /// Router traversal energy per flit for a `ports`-port router:
    /// `router_base_pj + router_port_pj * ports^2` — the crossbar and
    /// allocators scale quadratically with radix, which is what turns the
    /// Fig 11 EDP curve back up past k_max = 6.
    pub router_base_pj: f64,
    pub router_port_pj: f64,
    /// Wireless energy per bit (paper: 1.3 pJ/bit at 16 Gbps, 20 mm).
    pub wireless_pj_per_bit: f64,
    /// Inter-chip SerDes energy per bit for the multi-chip fabric links
    /// (typical 2-6 pJ/bit for organic-package SerDes; well above any
    /// on-chip hop, which is what makes the gradient exchange the
    /// dominant energy term at scale).
    pub interchip_pj_per_bit: f64,
    /// Flit width in bits.
    pub flit_bits: f64,
    /// Core active/idle power (W) by tile kind.
    pub gpu_active_w: f64,
    pub gpu_idle_w: f64,
    pub cpu_active_w: f64,
    pub cpu_idle_w: f64,
    pub mc_active_w: f64,
    pub mc_idle_w: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        EnergyParams {
            wire_pj_per_bit_mm: 0.075,
            router_base_pj: 2.0,
            router_port_pj: 0.35,
            wireless_pj_per_bit: 1.3,
            interchip_pj_per_bit: 4.0,
            flit_bits: 128.0,
            gpu_active_w: 1.25,
            gpu_idle_w: 0.30,
            cpu_active_w: 3.00,
            cpu_idle_w: 0.50,
            mc_active_w: 1.50,
            mc_idle_w: 0.40,
        }
    }
}

impl EnergyParams {
    /// Energy (pJ) for one flit to cross a wireline link of `mm`.
    pub fn wire_flit_pj(&self, mm: f64) -> f64 {
        self.wire_pj_per_bit_mm * self.flit_bits * mm
    }

    /// Energy (pJ) for one flit to traverse a router with `ports` ports.
    pub fn router_flit_pj(&self, ports: usize) -> f64 {
        self.router_base_pj + self.router_port_pj * (ports * ports) as f64
    }

    /// Energy (pJ) for one flit over a wireless channel.
    pub fn wireless_flit_pj(&self) -> f64 {
        self.wireless_pj_per_bit * self.flit_bits
    }

    /// Energy (J) to move `bytes` across one inter-chip fabric link.
    pub fn interchip_bytes_j(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 * self.interchip_pj_per_bit * 1e-12
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wireless_beats_long_multihop_wire() {
        // The premise of §4.2.3: a 20 mm wireless hop must cost less than
        // the equivalent multi-hop wireline path (8 x 2.5 mm links + 8
        // 4-port routers).
        let p = EnergyParams::default();
        let air = p.wireless_flit_pj();
        let wire_path = 8.0 * (p.wire_flit_pj(2.5) + p.router_flit_pj(4));
        assert!(air < wire_path, "air {air} vs wire {wire_path}");
    }

    #[test]
    fn wireless_loses_on_short_hops() {
        let p = EnergyParams::default();
        let air = p.wireless_flit_pj();
        let one_hop = p.wire_flit_pj(2.5) + p.router_flit_pj(4);
        assert!(air > one_hop);
    }

    #[test]
    fn router_energy_grows_with_radix() {
        let p = EnergyParams::default();
        assert!(p.router_flit_pj(7) > p.router_flit_pj(4));
    }

    #[test]
    fn interchip_bit_dwarfs_onchip_bit() {
        // the premise of the fabric energy model: one inter-chip byte
        // costs more than a full wireless hop of the same byte
        let p = EnergyParams::default();
        let serdes = p.interchip_bytes_j(16);
        let air = p.wireless_flit_pj() * 1e-12; // one 16-byte flit
        assert!(serdes > air, "serdes {serdes} vs air {air}");
        assert!((p.interchip_bytes_j(1_000_000_000) - 0.032).abs() < 1e-9);
    }
}

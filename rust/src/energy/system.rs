//! Full-system execution-time and EDP model (paper §5.5, Fig 19).
//!
//! Per phase (layer x pass) the execution time is
//!
//!   exec = max(duration, flits / simulated_throughput) + cpu_stall + gpu_stall
//!
//! where `duration` is the compute/bandwidth model from `traffic::phases`,
//! the max() term captures a saturated network extending the phase, and
//! the stall terms convert simulated round-trip latencies into lost core
//! cycles: CPUs block on memory (memory-level parallelism ~4 across the
//! four cores), GPUs hide latency up to `gpu_hide_cycles` via warp
//! switching and only stall beyond it.
//!
//! Energy = per-tile active/idle power x phase time + network energy from
//! the simulator (scaled back up when the trace was downsampled).
//! Full-system EDP = total energy x total time.

use crate::energy::network::network_energy_pj;
use crate::energy::params::EnergyParams;
use crate::faults::{FaultPlan, ResilienceStats};
use crate::model::cnn::{LayerKind, Pass};
use crate::model::{SystemConfig, TileKind};
use crate::noc::builder::NocInstance;
use crate::noc::sim::{NocSim, SimConfig, SimReport};
use crate::serving::{run_serving_obs, ServingSpec, TenantMix};
use crate::telemetry::Telemetry;
use crate::traffic::phases::TrafficModel;
use crate::traffic::trace::{phase_trace, TraceConfig};
use crate::util::rng::Rng;

/// Stall-model constants.
#[derive(Debug, Clone)]
pub struct StallModel {
    /// Outstanding misses a CPU core overlaps.
    pub cpu_mlp: f64,
    /// Round-trip cycles a GPU SM hides via multithreading.
    pub gpu_hide_cycles: f64,
    /// Outstanding misses per GPU tile.
    pub gpu_mlp: f64,
}

impl Default for StallModel {
    fn default() -> Self {
        StallModel { cpu_mlp: 4.0, gpu_hide_cycles: 120.0, gpu_mlp: 16.0 }
    }
}

#[derive(Debug, Clone)]
pub struct PhaseResult {
    pub tag: String,
    pub pass: Pass,
    pub kind: LayerKind,
    /// Simulated mean packet latency (cycles).
    pub latency: f64,
    pub cpu_mc_latency: f64,
    /// Per-message EDP (pJ x cycles).
    pub msg_edp: f64,
    /// Modeled execution cycles including stalls.
    pub exec_cycles: f64,
    /// Network energy for the full (unscaled) phase, Joules.
    pub network_j: f64,
    pub throughput: f64,
}

#[derive(Debug, Clone)]
pub struct FullSystemReport {
    pub noc: String,
    pub model: String,
    /// Per-(layer x pass) results. Empty for scheduled (overlapping)
    /// runs, where phases execute concurrently and only aggregate
    /// network metrics are meaningful.
    pub per_phase: Vec<PhaseResult>,
    pub exec_cycles: f64,
    pub exec_seconds: f64,
    pub network_j: f64,
    pub core_j: f64,
    pub total_j: f64,
    /// Full-system EDP in Joule-seconds.
    pub edp: f64,
    /// The training-timeline schedule this run executed ("serial",
    /// "gpipe:M", "1f1b:M").
    pub schedule: String,
    /// Pipeline idle share of the scheduled timeline (0.0 for serial).
    pub bubble_fraction: f64,
    /// Makespan speedup over the back-to-back serial reference (1.0 for
    /// serial).
    pub speedup_vs_serial: f64,
    /// Chips in the data-parallel fabric (1 = single-chip run; the
    /// energy/time figures above are always *per chip*).
    pub fabric_chips: usize,
    /// Inter-chip SerDes energy of the gradient exchange across the
    /// whole fabric, Joules (0.0 for a single chip).
    pub interchip_j: f64,
    /// Wire share of a serialized iteration, percent (see
    /// [`crate::fabric::FabricReport::comm_overhead_pct`]).
    pub comm_overhead_pct: f64,
    /// Fabric-level EDP: `(chips x total_j + interchip_j) x
    /// exec_seconds`. Equals `edp` for a single chip.
    pub fabric_edp: f64,
    /// Fault-injection accounting aggregated over every simulated phase
    /// (all zeros for fault-free runs).
    pub resilience: ResilienceStats,
}

/// Run every phase of `tm` through the simulator on `inst` and assemble
/// the full-system report.
pub fn full_system_run(
    sys: &SystemConfig,
    inst: &NocInstance,
    tm: &TrafficModel,
    trace_cfg: &TraceConfig,
    energy: &EnergyParams,
    stall: &StallModel,
) -> FullSystemReport {
    full_system_run_faults(sys, inst, tm, trace_cfg, energy, stall, &FaultPlan::none())
        .expect("the empty fault plan always compiles")
}

/// [`full_system_run`] under a [`FaultPlan`]: the plan is compiled once
/// against the instance and every phase's simulation runs with it; the
/// report aggregates the per-phase resilience counters (faults injected
/// is the per-run count, not a per-phase sum). [`FaultPlan::none`]
/// delegates byte-identically to the fault-free path.
pub fn full_system_run_faults(
    sys: &SystemConfig,
    inst: &NocInstance,
    tm: &TrafficModel,
    trace_cfg: &TraceConfig,
    energy: &EnergyParams,
    stall: &StallModel,
    plan: &FaultPlan,
) -> crate::error::Result<FullSystemReport> {
    let mut rng = Rng::new(trace_cfg.seed);
    let sim_cfg = SimConfig::default();
    let fx = if plan.has_noc_faults() {
        Some(plan.compile(&inst.topo, &inst.routes, &inst.air, sim_cfg.nominal_flits)?)
    } else {
        None
    };
    let mut sim = NocSim::new(sys, &inst.topo, &inst.routes, &inst.air, sim_cfg);
    if let Some(f) = &fx {
        sim = sim.with_faults(f);
    }
    let inv_scale = 1.0 / trace_cfg.scale;

    let mut per_phase = Vec::new();
    let mut exec_total = 0.0f64;
    let mut net_j = 0.0f64;
    let mut core_j = 0.0f64;
    let mut resilience = ResilienceStats::default();

    for p in &tm.phases {
        let (msgs, _dur) = phase_trace(sys, p, 0, trace_cfg, &mut rng);
        let rep: SimReport = sim.run(&msgs);
        resilience.packets_rerouted += rep.resilience.packets_rerouted;
        resilience.retries += rep.resilience.retries;
        resilience.fallback_flits += rep.resilience.fallback_flits;
        resilience.undeliverable_after_repair += rep.resilience.undeliverable_after_repair;
        let e = network_energy_pj(&inst.topo, &rep, energy);
        let phase_net_j = e.total_pj() * inv_scale * 1e-12;

        // stalls from unscaled message counts
        let lines = |b: u64| b.div_ceil(sys.line_bytes) as f64;
        let cpu_msgs = lines(p.cpu_read_bytes) + lines(p.cpu_write_bytes);
        let gpu_msgs = lines(p.gpu_read_bytes) + lines(p.gpu_write_bytes);
        let rt = 2.0; // request + reply legs per memory access
        let cpu_lat = rep.cpu_mc_latency.mean();
        let gpu_lat = rep.gpu_mc_latency.mean();
        let cpu_stall =
            cpu_msgs * rt * cpu_lat / (stall.cpu_mlp * sys.cpus().len().max(1) as f64);
        let gpu_stall = gpu_msgs * rt * (gpu_lat - stall.gpu_hide_cycles).max(0.0)
            / (stall.gpu_mlp * sys.gpus().len().max(1) as f64);

        // saturation: the network cannot drain flits faster than its
        // simulated throughput
        let thr = rep.throughput().max(1e-9);
        let total_flits = p.total_flits(sys) as f64;
        let comm_cycles = total_flits / thr;
        let exec = (p.duration_cycles as f64).max(comm_cycles) + cpu_stall + gpu_stall;
        exec_total += exec;
        net_j += phase_net_j;

        // core energy over this phase
        let secs = exec / sys.noc_clock_hz;
        let gpus_active = p.gpu_read_bytes + p.gpu_write_bytes > 0;
        let cpus_active = p.cpu_read_bytes + p.cpu_write_bytes > 0;
        for t in &sys.tiles {
            let w = match t {
                TileKind::Gpu => {
                    if gpus_active { energy.gpu_active_w } else { energy.gpu_idle_w }
                }
                TileKind::Cpu => {
                    if cpus_active { energy.cpu_active_w } else { energy.cpu_idle_w }
                }
                TileKind::Mc => energy.mc_active_w,
            };
            core_j += w * secs;
        }

        per_phase.push(PhaseResult {
            tag: p.tag.clone(),
            pass: p.pass,
            kind: p.kind,
            latency: rep.latency.mean(),
            cpu_mc_latency: cpu_lat,
            msg_edp: crate::energy::network::message_edp(&inst.topo, &rep, energy),
            exec_cycles: exec,
            network_j: phase_net_j,
            throughput: thr,
        });
    }

    if let Some(f) = &fx {
        // per-run count: the same plan is live in every phase's sim
        resilience.faults_injected = f.faults_injected;
    }

    let exec_seconds = exec_total / sys.noc_clock_hz;
    let total_j = net_j + core_j;
    Ok(FullSystemReport {
        noc: inst.kind.as_str().to_string(),
        model: tm.model.clone(),
        per_phase,
        exec_cycles: exec_total,
        exec_seconds,
        network_j: net_j,
        core_j,
        total_j,
        edp: total_j * exec_seconds,
        schedule: "serial".to_string(),
        bubble_fraction: 0.0,
        speedup_vs_serial: 1.0,
        fabric_chips: 1,
        interchip_j: 0.0,
        comm_overhead_pct: 0.0,
        fabric_edp: total_j * exec_seconds,
        resilience,
    })
}

/// Full-system run under a training-timeline schedule. `serial`
/// delegates to [`full_system_run`] (byte-identical); overlapping
/// schedules run the whole iteration as one gated concurrent simulation
/// ([`crate::schedule::run_schedule`]) and derive system time and energy
/// from the realized timeline:
///
/// * execution = realized makespan (rescaled to the full trace) plus the
///   usual CPU/GPU stall terms from the aggregate round-trip latencies;
/// * network energy from the aggregate simulation report;
/// * core energy = idle/MC baseline over the makespan plus an
///   (active - idle) increment over each instance's realized
///   release->drain span, weighted by its participating tiles. Overlap
///   shortens the idle baseline — that is where scheduled EDP wins come
///   from.
pub fn full_system_run_scheduled(
    sys: &SystemConfig,
    inst: &NocInstance,
    tm: &TrafficModel,
    schedule: &crate::schedule::SchedulePolicy,
    trace_cfg: &TraceConfig,
    energy: &EnergyParams,
    stall: &StallModel,
) -> crate::error::Result<FullSystemReport> {
    if schedule.is_serial() {
        return Ok(full_system_run(sys, inst, tm, trace_cfg, energy, stall));
    }
    let sr = crate::schedule::run_schedule(sys, inst, tm, schedule, trace_cfg)?;
    let inv_scale = 1.0 / trace_cfg.scale;
    let net_j = network_energy_pj(&inst.topo, &sr.sim, energy).total_pj() * inv_scale * 1e-12;

    // stall terms from unscaled message counts and aggregate latencies
    let lines = |b: u64| b.div_ceil(sys.line_bytes) as f64;
    let (mut cpu_msgs, mut gpu_msgs) = (0.0f64, 0.0f64);
    for p in &tm.phases {
        cpu_msgs += lines(p.cpu_read_bytes) + lines(p.cpu_write_bytes);
        gpu_msgs += lines(p.gpu_read_bytes) + lines(p.gpu_write_bytes);
    }
    let rt = 2.0;
    let cpu_lat = sr.sim.cpu_mc_latency.mean();
    let gpu_lat = sr.sim.gpu_mc_latency.mean();
    let cpu_stall = cpu_msgs * rt * cpu_lat / (stall.cpu_mlp * sys.cpus().len().max(1) as f64);
    let gpu_stall = gpu_msgs * rt * (gpu_lat - stall.gpu_hide_cycles).max(0.0)
        / (stall.gpu_mlp * sys.gpus().len().max(1) as f64);
    let exec_total = sr.makespan as f64 * inv_scale + cpu_stall + gpu_stall;
    let exec_seconds = exec_total / sys.noc_clock_hz;

    // core energy: idle/MC baseline over the makespan + active increments
    // over the realized instance spans
    let makespan_secs = sr.makespan as f64 * inv_scale / sys.noc_clock_hz;
    let mut baseline_w = 0.0;
    for t in &sys.tiles {
        baseline_w += match t {
            TileKind::Gpu => energy.gpu_idle_w,
            TileKind::Cpu => energy.cpu_idle_w,
            TileKind::Mc => energy.mc_active_w,
        };
    }
    let cyc_to_secs = inv_scale / sys.noc_clock_hz;
    let gpu_active_j =
        sr.gpu_tile_busy_cycles as f64 * cyc_to_secs * (energy.gpu_active_w - energy.gpu_idle_w);
    let cpu_active_j = sr.cpu_busy_cycles as f64
        * cyc_to_secs
        * sys.cpus().len() as f64
        * (energy.cpu_active_w - energy.cpu_idle_w);
    let core_j = baseline_w * makespan_secs + gpu_active_j + cpu_active_j;

    let total_j = net_j + core_j;
    Ok(FullSystemReport {
        noc: inst.kind.as_str().to_string(),
        model: tm.model.clone(),
        per_phase: Vec::new(),
        exec_cycles: exec_total,
        exec_seconds,
        network_j: net_j,
        core_j,
        total_j,
        edp: total_j * exec_seconds,
        schedule: schedule.to_string(),
        bubble_fraction: sr.bubble_fraction,
        speedup_vs_serial: sr.speedup_vs_serial,
        fabric_chips: 1,
        interchip_j: 0.0,
        comm_overhead_pct: 0.0,
        fabric_edp: total_j * exec_seconds,
        resilience: sr.sim.resilience.clone(),
    })
}

/// Full-system run on a multi-chip [`crate::fabric::Fabric`]. The
/// single-chip fabric delegates to [`full_system_run_scheduled`]
/// (byte-identical — the acceptance bar of `tests/fabric_sim.rs`);
/// otherwise one chip's gated iteration — backward pass overlapping the
/// allreduce's on-chip traffic — is simulated
/// ([`crate::fabric::run_fabric`]), the iteration end also waits for the
/// analytic alpha-beta wire pipeline, and the report grows the fabric
/// terms: inter-chip SerDes energy for every chip's wire bytes and the
/// fabric-level EDP over all chips.
#[allow(clippy::too_many_arguments)]
pub fn full_system_run_fabric(
    sys: &SystemConfig,
    inst: &NocInstance,
    tm: &TrafficModel,
    schedule: &crate::schedule::SchedulePolicy,
    fabric: &crate::fabric::Fabric,
    grad_bytes: u64,
    trace_cfg: &TraceConfig,
    energy: &EnergyParams,
    stall: &StallModel,
) -> crate::error::Result<FullSystemReport> {
    if fabric.is_single() {
        fabric.validate()?;
        return full_system_run_scheduled(sys, inst, tm, schedule, trace_cfg, energy, stall);
    }
    let fr = crate::fabric::run_fabric(sys, inst, tm, schedule, fabric, grad_bytes, trace_cfg)?;
    let sr = &fr.schedule;
    let inv_scale = 1.0 / trace_cfg.scale;
    let net_j = network_energy_pj(&inst.topo, &sr.sim, energy).total_pj() * inv_scale * 1e-12;

    // stall terms: the base phases plus the allreduce's MC crossings
    let lines = |b: u64| b.div_ceil(sys.line_bytes) as f64;
    let (mut cpu_msgs, mut gpu_msgs) = (0.0f64, 0.0f64);
    for p in &tm.phases {
        cpu_msgs += lines(p.cpu_read_bytes) + lines(p.cpu_write_bytes);
        gpu_msgs += lines(p.gpu_read_bytes) + lines(p.gpu_write_bytes);
    }
    gpu_msgs += 2.0 * lines(fr.wire_bytes_per_chip); // shard out + reduced shard in
    let rt = 2.0;
    let cpu_lat = sr.sim.cpu_mc_latency.mean();
    let gpu_lat = sr.sim.gpu_mc_latency.mean();
    let cpu_stall = cpu_msgs * rt * cpu_lat / (stall.cpu_mlp * sys.cpus().len().max(1) as f64);
    let gpu_stall = gpu_msgs * rt * (gpu_lat - stall.gpu_hide_cycles).max(0.0)
        / (stall.gpu_mlp * sys.gpus().len().max(1) as f64);
    let exec_total = fr.iteration_cycles as f64 * inv_scale + cpu_stall + gpu_stall;
    let exec_seconds = exec_total / sys.noc_clock_hz;

    // core energy: idle/MC baseline over the whole iteration (a chip
    // waiting on the wire still burns idle power) + active increments
    // over the realized instance spans
    let iter_secs = fr.iteration_cycles as f64 * inv_scale / sys.noc_clock_hz;
    let mut baseline_w = 0.0;
    for t in &sys.tiles {
        baseline_w += match t {
            TileKind::Gpu => energy.gpu_idle_w,
            TileKind::Cpu => energy.cpu_idle_w,
            TileKind::Mc => energy.mc_active_w,
        };
    }
    let cyc_to_secs = inv_scale / sys.noc_clock_hz;
    let gpu_active_j =
        sr.gpu_tile_busy_cycles as f64 * cyc_to_secs * (energy.gpu_active_w - energy.gpu_idle_w);
    let cpu_active_j = sr.cpu_busy_cycles as f64
        * cyc_to_secs
        * sys.cpus().len() as f64
        * (energy.cpu_active_w - energy.cpu_idle_w);
    let core_j = baseline_w * iter_secs + gpu_active_j + cpu_active_j;

    let total_j = net_j + core_j;
    let interchip_j =
        energy.interchip_bytes_j(fr.wire_bytes_per_chip) * fabric.chips as f64;
    Ok(FullSystemReport {
        noc: inst.kind.as_str().to_string(),
        model: tm.model.clone(),
        per_phase: Vec::new(),
        exec_cycles: exec_total,
        exec_seconds,
        network_j: net_j,
        core_j,
        total_j,
        edp: total_j * exec_seconds,
        schedule: schedule.to_string(),
        bubble_fraction: sr.bubble_fraction,
        speedup_vs_serial: sr.speedup_vs_serial,
        fabric_chips: fabric.chips,
        interchip_j,
        comm_overhead_pct: fr.comm_overhead_pct,
        fabric_edp: (fabric.chips as f64 * total_j + interchip_j) * exec_seconds,
        resilience: fr.resilience.clone(),
    })
}

/// Core energy from the exact per-tile router-active counters
/// ([`Telemetry::tile_active`]) instead of release→drain span charging —
/// the ROADMAP item 5 wiring. Every tile burns its idle power (MCs their
/// active power) over the whole makespan; GPU/CPU tiles additionally pay
/// `active - idle` over their *metered* active cycles. The counters
/// count flit-traversals, which can exceed wall-clock cycles on a hot
/// router, so each tile's activity is clamped to the makespan — the
/// charge never exceeds the all-active envelope.
pub fn core_energy_from_counters(
    sys: &SystemConfig,
    tile_active: &[u64],
    makespan_cycles: u64,
    inv_scale: f64,
    energy: &EnergyParams,
) -> f64 {
    let cyc_to_secs = inv_scale / sys.noc_clock_hz;
    let makespan_secs = makespan_cycles as f64 * cyc_to_secs;
    let mut core_j = 0.0;
    for (i, t) in sys.tiles.iter().enumerate() {
        let (idle_w, active_w) = match t {
            TileKind::Gpu => (energy.gpu_idle_w, energy.gpu_active_w),
            TileKind::Cpu => (energy.cpu_idle_w, energy.cpu_active_w),
            TileKind::Mc => (energy.mc_active_w, energy.mc_active_w),
        };
        let active = tile_active.get(i).copied().unwrap_or(0).min(makespan_cycles);
        core_j += idle_w * makespan_secs + (active_w - idle_w) * active as f64 * cyc_to_secs;
    }
    core_j
}

/// Full-system run of an open-loop serving workload
/// ([`crate::serving::run_serving`]): every tenant's batches coexist in
/// one gated simulation, execution time is the realized makespan
/// (rescaled to the full trace), and core energy comes from the exact
/// per-tile active counters via [`core_energy_from_counters`] — serving
/// has no per-phase span accounting to charge against, which is exactly
/// the case the counter path was built for. The report's `schedule`
/// field carries `serving:<spec>`; `per_phase` is empty like every
/// concurrent run.
pub fn full_system_run_serving(
    sys: &SystemConfig,
    inst: &NocInstance,
    mix: &TenantMix,
    spec: &ServingSpec,
    trace_cfg: &TraceConfig,
    energy: &EnergyParams,
) -> crate::error::Result<FullSystemReport> {
    let mut tel = Telemetry::new();
    let r = run_serving_obs(sys, inst, mix, spec, trace_cfg, &FaultPlan::none(), Some(&mut tel))?;
    let inv_scale = 1.0 / trace_cfg.scale;
    let net_j = network_energy_pj(&inst.topo, &r.sim, energy).total_pj() * inv_scale * 1e-12;
    let exec_total = r.makespan as f64 * inv_scale;
    let exec_seconds = exec_total / sys.noc_clock_hz;
    let core_j = core_energy_from_counters(sys, &tel.tile_active, r.makespan, inv_scale, energy);
    let total_j = net_j + core_j;
    let model: Vec<&str> = r.tenants.iter().map(|t| t.name.as_str()).collect();
    Ok(FullSystemReport {
        noc: inst.kind.as_str().to_string(),
        model: model.join("+"),
        per_phase: Vec::new(),
        exec_cycles: exec_total,
        exec_seconds,
        network_j: net_j,
        core_j,
        total_j,
        edp: total_j * exec_seconds,
        schedule: format!("serving:{spec}"),
        bubble_fraction: 0.0,
        speedup_vs_serial: 1.0,
        fabric_chips: 1,
        interchip_j: 0.0,
        comm_overhead_pct: 0.0,
        fabric_edp: total_j * exec_seconds,
        resilience: r.sim.resilience.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::lenet;
    use crate::noc::builder::{mesh_opt, wi_het_noc_quick};
    use crate::traffic::phases::model_phases;

    fn quick_cfg() -> TraceConfig {
        TraceConfig { scale: 0.05, ..Default::default() }
    }

    #[test]
    fn report_is_positive_and_consistent() {
        let sys = SystemConfig::paper_8x8();
        let tm = model_phases(&sys, &lenet(), 32);
        let inst = mesh_opt(&sys, false);
        let rep = full_system_run(
            &sys,
            &inst,
            &tm,
            &quick_cfg(),
            &EnergyParams::default(),
            &StallModel::default(),
        );
        assert_eq!(rep.per_phase.len(), tm.phases.len());
        assert!(rep.exec_seconds > 0.0);
        assert!(rep.network_j > 0.0);
        assert!(rep.core_j > 0.0);
        assert!((rep.total_j - (rep.network_j + rep.core_j)).abs() < 1e-12);
        assert!((rep.edp - rep.total_j * rep.exec_seconds).abs() < 1e-15);
        // exec includes the compute model at minimum
        assert!(rep.exec_cycles >= tm.total_cycles() as f64 * 0.99);
    }

    #[test]
    fn scheduled_run_overlaps_and_stays_consistent() {
        use crate::schedule::SchedulePolicy;
        use crate::workload::{lower_id, MappingPolicy};
        use crate::ModelId;

        let sys = SystemConfig::paper_8x8();
        let tm = lower_id(
            &ModelId::LeNet,
            &MappingPolicy::LayerPipelined { stages: 2 },
            &sys,
            32,
        )
        .unwrap();
        let inst = mesh_opt(&sys, true);
        let cfg = TraceConfig { scale: 0.05, ..Default::default() };
        let e = EnergyParams::default();
        let s = StallModel::default();
        let serial = full_system_run_scheduled(
            &sys, &inst, &tm, &SchedulePolicy::Serial, &cfg, &e, &s,
        )
        .unwrap();
        assert_eq!(serial.schedule, "serial");
        assert!(serial.speedup_vs_serial == 1.0 && serial.bubble_fraction == 0.0);
        let gp = full_system_run_scheduled(
            &sys,
            &inst,
            &tm,
            &SchedulePolicy::GPipe { microbatches: 4 },
            &cfg,
            &e,
            &s,
        )
        .unwrap();
        assert_eq!(gp.schedule, "gpipe:4");
        assert!(gp.per_phase.is_empty());
        assert!(gp.exec_seconds > 0.0 && gp.network_j > 0.0 && gp.core_j > 0.0);
        assert!((gp.total_j - (gp.network_j + gp.core_j)).abs() < 1e-12);
        assert!((gp.edp - gp.total_j * gp.exec_seconds).abs() < 1e-15);
        assert!((0.0..=1.0).contains(&gp.bubble_fraction));
    }

    #[test]
    fn fabric_run_adds_interchip_terms() {
        use crate::fabric::Fabric;
        use crate::schedule::SchedulePolicy;
        use crate::workload::{lower_id, MappingPolicy};
        use crate::ModelId;

        let sys = SystemConfig::paper_8x8();
        let tm = lower_id(
            &ModelId::LeNet,
            &MappingPolicy::LayerPipelined { stages: 2 },
            &sys,
            32,
        )
        .unwrap();
        let grad = ModelId::LeNet.spec().total_weight_bytes();
        let inst = mesh_opt(&sys, true);
        let cfg = TraceConfig { scale: 0.05, ..Default::default() };
        let e = EnergyParams::default();
        let s = StallModel::default();
        let policy = SchedulePolicy::GPipe { microbatches: 4 };

        let one = full_system_run_fabric(
            &sys, &inst, &tm, &policy, &Fabric::single(), grad, &cfg, &e, &s,
        )
        .unwrap();
        let base =
            full_system_run_scheduled(&sys, &inst, &tm, &policy, &cfg, &e, &s).unwrap();
        assert_eq!(one.exec_cycles, base.exec_cycles, "fabric=1 must delegate");
        assert_eq!(one.fabric_chips, 1);
        assert_eq!(one.interchip_j, 0.0);
        assert_eq!(one.fabric_edp, one.edp);

        let four: Fabric = "4:topo=ring".parse().unwrap();
        let r = full_system_run_fabric(&sys, &inst, &tm, &policy, &four, grad, &cfg, &e, &s)
            .unwrap();
        assert_eq!(r.fabric_chips, 4);
        assert!(r.interchip_j > 0.0);
        assert!(r.comm_overhead_pct > 0.0);
        assert!(r.exec_seconds > base.exec_seconds, "the wire must cost time");
        assert!(r.fabric_edp > 4.0 * r.edp - 1e-12, "fabric EDP covers all chips");
        let expect_ic = e.interchip_bytes_j(
            crate::fabric::wire_bytes_per_chip(4, grad),
        ) * 4.0;
        assert!((r.interchip_j - expect_ic).abs() < 1e-12);
    }

    #[test]
    fn faulted_run_accounts_and_none_delegates() {
        let sys = SystemConfig::paper_8x8();
        let tm = model_phases(&sys, &lenet(), 32);
        let inst = mesh_opt(&sys, true);
        let cfg = quick_cfg();
        let e = EnergyParams::default();
        let s = StallModel::default();
        let clean = full_system_run(&sys, &inst, &tm, &cfg, &e, &s);
        assert_eq!(clean.resilience, ResilienceStats::default());

        let none =
            full_system_run_faults(&sys, &inst, &tm, &cfg, &e, &s, &FaultPlan::none()).unwrap();
        assert_eq!(none.exec_cycles, clean.exec_cycles, "none() must delegate");
        assert_eq!(none.network_j, clean.network_j);
        assert_eq!(none.resilience, ResilienceStats::default());

        // kill one mesh link: the residual is connected, so nothing is
        // lost after repair but the detours cost energy/time accounting
        let plan: FaultPlan = "wire:link=0".parse().unwrap();
        let faulted =
            full_system_run_faults(&sys, &inst, &tm, &cfg, &e, &s, &plan).unwrap();
        assert_eq!(faulted.resilience.faults_injected, 1);
        assert_eq!(faulted.resilience.undeliverable_after_repair, 0);
        assert_eq!(faulted.per_phase.len(), clean.per_phase.len());
        assert!(faulted.exec_seconds > 0.0 && faulted.network_j > 0.0);
    }

    #[test]
    fn counter_energy_spans_idle_to_all_active() {
        let sys = SystemConfig::paper_8x8();
        let e = EnergyParams::default();
        let makespan = 10_000u64;
        let inv_scale = 20.0;
        let idle = core_energy_from_counters(&sys, &vec![0; sys.tiles.len()], makespan, inv_scale, &e);
        assert!(idle > 0.0, "idle baseline still burns power");
        let busy =
            core_energy_from_counters(&sys, &vec![makespan; sys.tiles.len()], makespan, inv_scale, &e);
        assert!(busy > idle, "all-active must cost more than idle");
        // counters are clamped: overshooting the makespan changes nothing
        let over = core_energy_from_counters(
            &sys,
            &vec![makespan * 100; sys.tiles.len()],
            makespan,
            inv_scale,
            &e,
        );
        assert_eq!(over, busy, "activity is clamped to the makespan");
        // a short counter slice is padded with zeros, not an error
        let partial = core_energy_from_counters(&sys, &[makespan; 4], makespan, inv_scale, &e);
        assert!(partial >= idle && partial <= busy);
    }

    #[test]
    fn serving_run_is_positive_and_labeled() {
        use crate::ModelId;
        let sys = SystemConfig::paper_8x8();
        let inst = mesh_opt(&sys, true);
        let mix = TenantMix::single(ModelId::LeNet);
        let spec: ServingSpec = "poisson:rate=0.2,seed=3;n=12".parse().unwrap();
        let cfg = TraceConfig { scale: 0.02, ..Default::default() };
        let rep = full_system_run_serving(&sys, &inst, &mix, &spec, &cfg, &EnergyParams::default())
            .unwrap();
        assert_eq!(rep.model, "lenet");
        assert!(rep.schedule.starts_with("serving:poisson"), "schedule={}", rep.schedule);
        assert!(rep.per_phase.is_empty());
        assert!(rep.exec_seconds > 0.0 && rep.network_j > 0.0 && rep.core_j > 0.0);
        assert!((rep.total_j - (rep.network_j + rep.core_j)).abs() < 1e-12);
        assert!((rep.edp - rep.total_j * rep.exec_seconds).abs() < 1e-15);
        assert_eq!(rep.fabric_chips, 1);
        assert_eq!(rep.fabric_edp, rep.edp);
        assert_eq!(rep.resilience, ResilienceStats::default());
    }

    #[test]
    fn wihetnoc_cuts_cpu_latency_vs_mesh() {
        let sys = SystemConfig::paper_8x8();
        let tm = model_phases(&sys, &lenet(), 32);
        let mesh = mesh_opt(&sys, false);
        let wihet = wi_het_noc_quick(&sys, 3);
        let cfg = quick_cfg();
        let e = EnergyParams::default();
        let s = StallModel::default();
        let rm = full_system_run(&sys, &mesh, &tm, &cfg, &e, &s);
        let rw = full_system_run(&sys, &wihet, &tm, &cfg, &e, &s);
        let mean_cpu = |r: &FullSystemReport| {
            let v: Vec<f64> = r
                .per_phase
                .iter()
                .filter(|p| p.cpu_mc_latency > 0.0)
                .map(|p| p.cpu_mc_latency)
                .collect();
            v.iter().sum::<f64>() / v.len().max(1) as f64
        };
        assert!(
            mean_cpu(&rw) < mean_cpu(&rm),
            "wihetnoc cpu lat {} vs mesh {}",
            mean_cpu(&rw),
            mean_cpu(&rm)
        );
    }
}

//! Typed scenario description — the single input to design, simulation,
//! and the experiment harnesses.
//!
//! A [`Scenario`] bundles *what chip* ([`Platform`]), *what workload*
//! ([`ModelId`]), *what interconnect* ([`NocKind`]) and *how hard to try*
//! ([`Effort`] + seed). Everything downstream — [`crate::noc::builder::NocDesigner`],
//! [`crate::experiments::Ctx`], the CLI — consumes a `Scenario` instead of
//! ad-hoc strings, so an unknown model or a malformed platform is a
//! [`WihetError`] at the boundary rather than a `panic!` deep inside.

use std::fmt;
use std::str::FromStr;

use crate::error::WihetError;
use crate::model::cnn::{cdbnet, lenet, ModelSpec};
use crate::model::platform::Platform;
use crate::model::SystemConfig;
use crate::noc::builder::NocKind;

/// The CNN workloads of the paper (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelId {
    LeNet,
    CdbNet,
}

impl ModelId {
    pub const ALL: [ModelId; 2] = [ModelId::LeNet, ModelId::CdbNet];

    pub fn as_str(&self) -> &'static str {
        match self {
            ModelId::LeNet => "lenet",
            ModelId::CdbNet => "cdbnet",
        }
    }

    /// The layer-by-layer workload description for this model.
    pub fn spec(&self) -> ModelSpec {
        match self {
            ModelId::LeNet => lenet(),
            ModelId::CdbNet => cdbnet(),
        }
    }
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

impl FromStr for ModelId {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "lenet" => Ok(ModelId::LeNet),
            "cdbnet" => Ok(ModelId::CdbNet),
            other => Err(WihetError::UnknownModel(other.to_string())),
        }
    }
}

/// Simulation/optimization effort level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Effort {
    /// CI-grade: tiny AMOSA budgets, heavily downsampled traces.
    Quick,
    /// Paper-grade: full budgets (used for EXPERIMENTS.md numbers).
    Full,
}

impl Effort {
    pub fn as_str(&self) -> &'static str {
        match self {
            Effort::Quick => "quick",
            Effort::Full => "full",
        }
    }
}

impl fmt::Display for Effort {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

impl FromStr for Effort {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "quick" => Ok(Effort::Quick),
            "full" => Ok(Effort::Full),
            other => Err(WihetError::InvalidArg(format!(
                "effort must be quick|full, got '{other}'"
            ))),
        }
    }
}

/// One fully-specified evaluation scenario: platform x workload x NoC x
/// effort/seed. Construct with [`Scenario::new`] and the `with_*` setters.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Scenario {
    pub platform: Platform,
    pub model: ModelId,
    pub noc: NocKind,
    pub effort: Effort,
    pub seed: u64,
    /// Training batch size the traffic model is derived at.
    pub batch: usize,
}

impl Scenario {
    /// A scenario with the crate defaults: WiHetNoC, quick effort,
    /// seed 42, batch 32.
    pub fn new(platform: Platform, model: ModelId) -> Self {
        Scenario {
            platform,
            model,
            noc: NocKind::WiHetNoc,
            effort: Effort::Quick,
            seed: 42,
            batch: 32,
        }
    }

    /// The paper's headline scenario: LeNet on the 8x8 chip, WiHetNoC.
    pub fn paper() -> Self {
        Scenario::new(Platform::paper(), ModelId::LeNet)
    }

    pub fn with_noc(mut self, noc: NocKind) -> Self {
        self.noc = noc;
        self
    }

    pub fn with_effort(mut self, effort: Effort) -> Self {
        self.effort = effort;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Build the concrete tile grid this scenario runs on.
    pub fn build_system(&self) -> Result<SystemConfig, WihetError> {
        self.platform.build()
    }
}

/// Typed cache key: a workload on one concrete tile placement. Two
/// placements that happen to share a human-readable tag hash differently,
/// which is what makes [`crate::experiments::Ctx`]'s traffic cache safe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ScenarioKey {
    pub model: ModelId,
    /// Fingerprint of the tile-kind assignment (see
    /// [`SystemConfig::placement_key`]).
    pub placement: u64,
}

impl ScenarioKey {
    pub fn new(model: ModelId, sys: &SystemConfig) -> Self {
        ScenarioKey { model, placement: sys.placement_key() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_parse_roundtrip() {
        for m in ModelId::ALL {
            assert_eq!(m.as_str().parse::<ModelId>().unwrap(), m);
            assert_eq!(format!("{m}"), m.as_str());
        }
        assert!(matches!(
            "resnet".parse::<ModelId>(),
            Err(WihetError::UnknownModel(_))
        ));
    }

    #[test]
    fn effort_parse() {
        assert_eq!("quick".parse::<Effort>().unwrap(), Effort::Quick);
        assert_eq!("FULL".parse::<Effort>().unwrap(), Effort::Full);
        assert!("medium".parse::<Effort>().is_err());
    }

    #[test]
    fn scenario_defaults_and_setters() {
        let sc = Scenario::paper().with_seed(7).with_batch(16);
        assert_eq!(sc.model, ModelId::LeNet);
        assert_eq!(sc.noc, NocKind::WiHetNoc);
        assert_eq!(sc.seed, 7);
        assert_eq!(sc.batch, 16);
        let sys = sc.build_system().unwrap();
        assert_eq!(sys.num_tiles(), 64);
    }

    #[test]
    fn keys_distinguish_placements() {
        let sys = SystemConfig::paper_8x8();
        let mut tiles = sys.tiles.clone();
        tiles.swap(0, 27); // move a CPU to the corner
        let other = sys.with_tiles(tiles);
        let a = ScenarioKey::new(ModelId::LeNet, &sys);
        let b = ScenarioKey::new(ModelId::LeNet, &other);
        let c = ScenarioKey::new(ModelId::CdbNet, &sys);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, ScenarioKey::new(ModelId::LeNet, &sys.clone()));
    }
}

//! Typed scenario description — the single input to design, simulation,
//! and the experiment harnesses.
//!
//! A [`Scenario`] bundles *what chip* ([`Platform`]), *what workload*
//! ([`ModelId`] — a named preset or an inline architecture-DSL spec),
//! *how it is mapped* ([`MappingPolicy`]), *what interconnect*
//! ([`NocKind`]) and *how hard to try* ([`Effort`] + seed). Everything
//! downstream — [`crate::noc::builder::NocDesigner`],
//! [`crate::experiments::Ctx`], the CLI — consumes a `Scenario` instead of
//! ad-hoc strings, so an unknown model or a malformed platform is a
//! [`WihetError`] at the boundary rather than a `panic!` deep inside.

use std::fmt;
use std::str::FromStr;
use std::sync::Arc;

use crate::error::WihetError;
use crate::fabric::Fabric;
use crate::faults::FaultPlan;
use crate::model::cnn::{cdbnet, lenet, ModelSpec};
use crate::model::platform::Platform;
use crate::model::SystemConfig;
use crate::noc::builder::NocKind;
use crate::schedule::SchedulePolicy;
use crate::serving::ServingSpec;
use crate::workload::{preset, ArchSpec, MappingPolicy};

/// A CNN workload: one of the named presets, or a custom architecture
/// parsed from the workload DSL (see [`crate::workload::GRAMMAR`]).
///
/// `LeNet`/`CdbNet` are the paper's Table 1 models; `AlexNet`, `Vgg11`,
/// and `ResNetLite` open non-paper workloads. `Custom` carries a
/// validated [`ArchSpec`] behind an `Arc`, so `ModelId` stays cheap to
/// clone and hash (cache keys hash the spec by content).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ModelId {
    LeNet,
    CdbNet,
    AlexNet,
    Vgg11,
    ResNetLite,
    /// Invariant: the spec shape-checks ([`ArchSpec::shapes`] succeeds).
    /// Construct via [`ModelId::custom`] or string parsing — both
    /// validate; hand-building an invalid `ArchSpec` into this variant
    /// voids the crate's no-panic guarantee ([`ModelId::spec`] and the
    /// traffic caches `expect` the invariant).
    Custom(Arc<ArchSpec>),
}

impl ModelId {
    /// The CNN workloads of the paper (Table 1) — what the paper-figure
    /// harnesses iterate.
    pub const ALL: [ModelId; 2] = [ModelId::LeNet, ModelId::CdbNet];

    /// Every named preset, in menu order.
    pub const PRESETS: [ModelId; 5] = [
        ModelId::LeNet,
        ModelId::CdbNet,
        ModelId::AlexNet,
        ModelId::Vgg11,
        ModelId::ResNetLite,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            ModelId::LeNet => "lenet",
            ModelId::CdbNet => "cdbnet",
            ModelId::AlexNet => "alexnet",
            ModelId::Vgg11 => "vgg11",
            ModelId::ResNetLite => "resnet-lite",
            ModelId::Custom(_) => "custom",
        }
    }

    /// A custom workload from a validated architecture spec.
    pub fn custom(arch: ArchSpec) -> Result<ModelId, WihetError> {
        arch.shapes()?;
        Ok(ModelId::Custom(Arc::new(arch)))
    }

    /// The architecture description of this workload (DSL form).
    pub fn arch(&self) -> ArchSpec {
        match self {
            ModelId::Custom(a) => (**a).clone(),
            named => preset(named.as_str()).expect("built-in presets exist"),
        }
    }

    /// The layer-by-layer workload description for this model.
    pub fn spec(&self) -> ModelSpec {
        match self {
            // Table 1 straight from the source (the DSL presets are
            // asserted equal to these in workload::presets tests).
            ModelId::LeNet => lenet(),
            ModelId::CdbNet => cdbnet(),
            ModelId::Custom(a) => {
                a.shapes().expect("custom specs are validated at construction").spec
            }
            named => named.arch().shapes().expect("built-in presets are valid").spec,
        }
    }
}

impl fmt::Display for ModelId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            // custom workloads display as their (round-trippable) DSL
            ModelId::Custom(a) => fmt::Display::fmt(a, f),
            named => f.pad(named.as_str()),
        }
    }
}

impl FromStr for ModelId {
    type Err = WihetError;

    /// Preset name, or — when the string looks like a DSL spec (contains
    /// `:` or several items) — a full architecture spec.
    fn from_str(s: &str) -> Result<Self, WihetError> {
        let t = s.trim();
        match t.to_ascii_lowercase().replace('_', "-").as_str() {
            "lenet" => Ok(ModelId::LeNet),
            "cdbnet" => Ok(ModelId::CdbNet),
            "alexnet" => Ok(ModelId::AlexNet),
            "vgg11" => Ok(ModelId::Vgg11),
            "resnet-lite" => Ok(ModelId::ResNetLite),
            other => {
                if other.contains(':') || other.split_whitespace().count() > 1 {
                    // ArchSpec::from_str already shape-validates
                    Ok(ModelId::Custom(Arc::new(t.parse::<ArchSpec>()?)))
                } else {
                    Err(WihetError::UnknownModel(t.to_string()))
                }
            }
        }
    }
}

/// Simulation/optimization effort level.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Effort {
    /// CI-grade: tiny AMOSA budgets, heavily downsampled traces.
    Quick,
    /// Paper-grade: full budgets (used for EXPERIMENTS.md numbers).
    Full,
}

impl Effort {
    pub fn as_str(&self) -> &'static str {
        match self {
            Effort::Quick => "quick",
            Effort::Full => "full",
        }
    }
}

impl fmt::Display for Effort {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.pad(self.as_str())
    }
}

impl FromStr for Effort {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        match s.trim().to_ascii_lowercase().as_str() {
            "quick" => Ok(Effort::Quick),
            "full" => Ok(Effort::Full),
            other => Err(WihetError::InvalidArg(format!(
                "effort must be quick|full, got '{other}'"
            ))),
        }
    }
}

/// One fully-specified evaluation scenario: platform x workload x mapping
/// x NoC x effort/seed. Construct with [`Scenario::new`] and the `with_*`
/// setters.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Scenario {
    pub platform: Platform,
    pub model: ModelId,
    /// How the workload's layers are laid out on the platform's tiles.
    pub mapping: MappingPolicy,
    /// How the iteration's phases are laid out in time (serial, or
    /// overlapping microbatch schedules — see [`SchedulePolicy`]).
    pub schedule: SchedulePolicy,
    pub noc: NocKind,
    /// How many chip replicas train data-parallel, and over what
    /// inter-chip links (see [`Fabric`]; the single-chip default adds
    /// nothing).
    pub fabric: Fabric,
    /// Deterministic fault injection (see [`FaultPlan`]; the
    /// [`FaultPlan::none`] default delegates byte-identically to the
    /// fault-free paths).
    pub faults: FaultPlan,
    /// Open-loop inference serving (see [`ServingSpec`]; the
    /// [`ServingSpec::none`] default keeps every path the closed-loop
    /// training iteration it always was).
    pub serving: ServingSpec,
    pub effort: Effort,
    pub seed: u64,
    /// Training batch size the traffic model is derived at.
    pub batch: usize,
}

impl Scenario {
    /// A scenario with the crate defaults: identity mapping (`data:1`),
    /// serial schedule, WiHetNoC, single chip, quick effort, seed 42,
    /// batch 32.
    pub fn new(platform: Platform, model: ModelId) -> Self {
        Scenario {
            platform,
            model,
            mapping: MappingPolicy::default(),
            schedule: SchedulePolicy::default(),
            noc: NocKind::WiHetNoc,
            fabric: Fabric::single(),
            faults: FaultPlan::none(),
            serving: ServingSpec::none(),
            effort: Effort::Quick,
            seed: 42,
            batch: 32,
        }
    }

    /// The paper's headline scenario: LeNet on the 8x8 chip, WiHetNoC.
    pub fn paper() -> Self {
        Scenario::new(Platform::paper(), ModelId::LeNet)
    }

    pub fn with_mapping(mut self, mapping: MappingPolicy) -> Self {
        self.mapping = mapping;
        self
    }

    pub fn with_schedule(mut self, schedule: SchedulePolicy) -> Self {
        self.schedule = schedule;
        self
    }

    pub fn with_noc(mut self, noc: NocKind) -> Self {
        self.noc = noc;
        self
    }

    pub fn with_fabric(mut self, fabric: Fabric) -> Self {
        self.fabric = fabric;
        self
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = faults;
        self
    }

    pub fn with_serving(mut self, serving: ServingSpec) -> Self {
        self.serving = serving;
        self
    }

    pub fn with_effort(mut self, effort: Effort) -> Self {
        self.effort = effort;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Build the concrete tile grid this scenario runs on.
    pub fn build_system(&self) -> Result<SystemConfig, WihetError> {
        self.platform.build()
    }
}

/// Typed cache key: a workload, mapped one way, scheduled one way, on
/// one concrete tile placement and fabric. Two placements that happen to
/// share a human-readable tag hash differently, which is what makes
/// [`crate::experiments::Ctx`]'s traffic cache safe; two mappings — or
/// two schedules, two fabrics, two fault plans, or two serving specs —
/// of the same workload never alias either.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ScenarioKey {
    pub model: ModelId,
    /// Fingerprint of the tile-kind assignment (see
    /// [`SystemConfig::placement_key`]).
    pub placement: u64,
    pub mapping: MappingPolicy,
    pub schedule: SchedulePolicy,
    pub fabric: Fabric,
    pub faults: FaultPlan,
    pub serving: ServingSpec,
}

impl ScenarioKey {
    pub fn new(model: ModelId, sys: &SystemConfig) -> Self {
        ScenarioKey::with_mapping(model, sys, MappingPolicy::default())
    }

    pub fn with_mapping(model: ModelId, sys: &SystemConfig, mapping: MappingPolicy) -> Self {
        ScenarioKey::with_schedule(model, sys, mapping, SchedulePolicy::default())
    }

    pub fn with_schedule(
        model: ModelId,
        sys: &SystemConfig,
        mapping: MappingPolicy,
        schedule: SchedulePolicy,
    ) -> Self {
        ScenarioKey::with_fabric(model, sys, mapping, schedule, Fabric::single())
    }

    pub fn with_fabric(
        model: ModelId,
        sys: &SystemConfig,
        mapping: MappingPolicy,
        schedule: SchedulePolicy,
        fabric: Fabric,
    ) -> Self {
        ScenarioKey::with_faults(model, sys, mapping, schedule, fabric, FaultPlan::none())
    }

    pub fn with_faults(
        model: ModelId,
        sys: &SystemConfig,
        mapping: MappingPolicy,
        schedule: SchedulePolicy,
        fabric: Fabric,
        faults: FaultPlan,
    ) -> Self {
        ScenarioKey::with_serving(
            model,
            sys,
            mapping,
            schedule,
            fabric,
            faults,
            ServingSpec::none(),
        )
    }

    pub fn with_serving(
        model: ModelId,
        sys: &SystemConfig,
        mapping: MappingPolicy,
        schedule: SchedulePolicy,
        fabric: Fabric,
        faults: FaultPlan,
        serving: ServingSpec,
    ) -> Self {
        ScenarioKey {
            model,
            placement: sys.placement_key(),
            mapping,
            schedule,
            fabric,
            faults,
            serving,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_parse_roundtrip() {
        for m in ModelId::PRESETS {
            assert_eq!(m.as_str().parse::<ModelId>().unwrap(), m);
            assert_eq!(format!("{m}"), m.as_str());
        }
        assert_eq!("resnet_lite".parse::<ModelId>().unwrap(), ModelId::ResNetLite);
        assert!(matches!(
            "resnet".parse::<ModelId>(),
            Err(WihetError::UnknownModel(_))
        ));
    }

    #[test]
    fn custom_specs_parse_and_roundtrip() {
        let m: ModelId = "conv:5x5x20 pool:2 conv:5x5x50 pool:2 dense:500 dense:10"
            .parse()
            .unwrap();
        assert!(matches!(m, ModelId::Custom(_)));
        assert_eq!(m.spec().num_classes, 10);
        // Display emits the canonical DSL, which parses back to the same id
        let again: ModelId = m.to_string().parse().unwrap();
        assert_eq!(again, m);
        // malformed specs are InvalidSpec, not UnknownModel
        assert!(matches!(
            "conv:3x3".parse::<ModelId>(),
            Err(WihetError::InvalidSpec(_))
        ));
    }

    #[test]
    fn presets_have_specs() {
        for m in ModelId::PRESETS {
            let spec = m.spec();
            assert!(!spec.layers.is_empty(), "{m}");
            assert_eq!(spec.name, m.as_str());
            let arch = m.arch();
            assert_eq!(arch.name, m.as_str());
        }
    }

    #[test]
    fn effort_parse() {
        assert_eq!("quick".parse::<Effort>().unwrap(), Effort::Quick);
        assert_eq!("FULL".parse::<Effort>().unwrap(), Effort::Full);
        assert!("medium".parse::<Effort>().is_err());
    }

    #[test]
    fn scenario_defaults_and_setters() {
        let sc = Scenario::paper()
            .with_seed(7)
            .with_batch(16)
            .with_mapping(MappingPolicy::LayerPipelined { stages: 3 });
        assert_eq!(sc.model, ModelId::LeNet);
        assert_eq!(sc.noc, NocKind::WiHetNoc);
        assert_eq!(sc.seed, 7);
        assert_eq!(sc.batch, 16);
        assert_eq!(sc.mapping, MappingPolicy::LayerPipelined { stages: 3 });
        assert!(Scenario::paper().mapping.is_identity());
        let sys = sc.build_system().unwrap();
        assert_eq!(sys.num_tiles(), 64);
    }

    #[test]
    fn keys_distinguish_placements_and_mappings() {
        let sys = SystemConfig::paper_8x8();
        let mut tiles = sys.tiles.clone();
        tiles.swap(0, 27); // move a CPU to the corner
        let other = sys.with_tiles(tiles);
        let a = ScenarioKey::new(ModelId::LeNet, &sys);
        let b = ScenarioKey::new(ModelId::LeNet, &other);
        let c = ScenarioKey::new(ModelId::CdbNet, &sys);
        let d = ScenarioKey::with_mapping(
            ModelId::LeNet,
            &sys,
            MappingPolicy::DataParallel { replicas: 4 },
        );
        let e = ScenarioKey::with_schedule(
            ModelId::LeNet,
            &sys,
            MappingPolicy::default(),
            SchedulePolicy::GPipe { microbatches: 4 },
        );
        let f = ScenarioKey::with_fabric(
            ModelId::LeNet,
            &sys,
            MappingPolicy::default(),
            SchedulePolicy::default(),
            Fabric::new(4),
        );
        let g = ScenarioKey::with_faults(
            ModelId::LeNet,
            &sys,
            MappingPolicy::default(),
            SchedulePolicy::default(),
            Fabric::single(),
            "wire:link=3".parse().unwrap(),
        );
        let h = ScenarioKey::with_serving(
            ModelId::LeNet,
            &sys,
            MappingPolicy::default(),
            SchedulePolicy::default(),
            Fabric::single(),
            FaultPlan::none(),
            "poisson:rate=0.5".parse().unwrap(),
        );
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d, "mapping must be part of the key");
        assert_ne!(a, e, "schedule must be part of the key");
        assert_ne!(a, f, "fabric must be part of the key");
        assert_ne!(a, g, "fault plan must be part of the key");
        assert_ne!(a, h, "serving spec must be part of the key");
        assert_eq!(a, ScenarioKey::new(ModelId::LeNet, &sys.clone()));
        assert_eq!(a.fabric, Fabric::single(), "single chip is the default key fabric");
        assert_eq!(a.faults, FaultPlan::none(), "fault-free is the default key plan");
        assert_eq!(a.serving, ServingSpec::none(), "serving-off is the default key spec");
    }

    #[test]
    fn scenario_carries_a_schedule() {
        let sc = Scenario::paper();
        assert!(sc.schedule.is_serial());
        let sc = sc.with_schedule(SchedulePolicy::OneFOneB { microbatches: 8 });
        assert_eq!(sc.schedule, SchedulePolicy::OneFOneB { microbatches: 8 });
    }

    #[test]
    fn scenario_carries_a_fabric() {
        let sc = Scenario::paper();
        assert!(sc.fabric.is_single());
        let fabric: Fabric = "4:topo=ring".parse().unwrap();
        let sc = sc.with_fabric(fabric);
        assert_eq!(sc.fabric, fabric);
    }

    #[test]
    fn scenario_carries_a_fault_plan() {
        let sc = Scenario::paper();
        assert!(sc.faults.is_none());
        let plan: FaultPlan = "air:ch=1,from=0,burst=500".parse().unwrap();
        let sc = sc.with_faults(plan.clone());
        assert_eq!(sc.faults, plan);
    }

    #[test]
    fn scenario_carries_a_serving_spec() {
        let sc = Scenario::paper();
        assert!(sc.serving.is_none());
        let spec: ServingSpec = "poisson:rate=0.5;batch=8".parse().unwrap();
        let sc = sc.with_serving(spec.clone());
        assert_eq!(sc.serving, spec);
    }
}

//! Running an open-loop serving workload through the gated simulator.
//!
//! Each dispatched batch lowers to its model's *forward* phases only
//! (inference: no backward pass, no weight update) at the batch's
//! realized size. The first phase of a batch has no predecessors, so
//! the gated event loop releases it at cycle 0 and its absolute
//! `inject_at` offsets — `dispatch + t` from [`phase_trace`] — are the
//! open-loop injection clock; later phases gate on their predecessor's
//! drain exactly like schedule instances. Batches from every tenant
//! coexist in one simulation, so contention between tenants (and
//! between consecutive batches of one tenant) is modeled, not assumed.
//!
//! The entry chain mirrors the schedule runner:
//! [`run_serving`] → [`run_serving_faults`] → [`run_serving_obs`], with
//! [`FaultPlan::none`] installing no fault hooks and a `None` telemetry
//! sink recording nothing, so the plain entry point stays
//! byte-identical to the observed one.

use std::collections::HashMap;

use crate::error::WihetError;
use crate::faults::{FaultPlan, ResilienceStats};
use crate::model::SystemConfig;
use crate::noc::builder::NocInstance;
use crate::noc::sim::{Message, NocSim, SimConfig, SimReport};
use crate::telemetry::Telemetry;
use crate::traffic::phases::{Pass, TrafficModel};
use crate::traffic::trace::{phase_trace, TraceConfig};
use crate::util::rng::Rng;
use crate::workload::{lower_id, MappingPolicy};

use super::{batches, ServingSpec, TenantMix, TenantStats, GRAMMAR};

/// Outcome of one open-loop serving run on one NoC.
#[derive(Debug, Clone)]
pub struct ServingReport {
    /// Aggregate network report over every tenant's traffic.
    pub sim: SimReport,
    /// Last tail-delivery cycle of the run.
    pub makespan: u64,
    /// Request conservation over all tenants:
    /// `offered == delivered + queued + in_flight`.
    pub offered: u64,
    pub dispatched: u64,
    pub delivered: u64,
    pub in_flight: u64,
    pub queued: u64,
    /// Batches dispatched over all tenants.
    pub batches: u64,
    /// Per-tenant accounting, in [`TenantMix`] order.
    pub tenants: Vec<TenantStats>,
}

impl ServingReport {
    /// Fault-injection counters of the underlying simulation (all zero
    /// for fault-free runs).
    pub fn resilience(&self) -> &ResilienceStats {
        &self.sim.resilience
    }

    /// Delivered throughput over all tenants, requests per megacycle.
    pub fn delivered_rate_pmc(&self) -> f64 {
        self.delivered as f64 * 1e6 / self.makespan.max(1) as f64
    }
}

/// Tenant stream salt: decorrelates per-tenant arrival streams drawn
/// from one shared spec (golden-ratio stride, like splitmix).
fn tenant_salt(ti: usize) -> u64 {
    (ti as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Simulate `spec`'s open-loop request load of `mix` on `inst`.
pub fn run_serving(
    sys: &SystemConfig,
    inst: &NocInstance,
    mix: &TenantMix,
    spec: &ServingSpec,
    cfg: &TraceConfig,
) -> Result<ServingReport, WihetError> {
    run_serving_faults(sys, inst, mix, spec, cfg, &FaultPlan::none())
}

/// [`run_serving`] under a fault plan, compiled once against this NoC.
/// An empty plan ([`FaultPlan::none`]) installs no fault hooks at all,
/// so results stay byte-identical to [`run_serving`].
pub fn run_serving_faults(
    sys: &SystemConfig,
    inst: &NocInstance,
    mix: &TenantMix,
    spec: &ServingSpec,
    cfg: &TraceConfig,
    plan: &FaultPlan,
) -> Result<ServingReport, WihetError> {
    run_serving_obs(sys, inst, mix, spec, cfg, plan, None)
}

/// [`run_serving_faults`] with an optional telemetry sink: the sink
/// rides along the simulation and, once the run finishes, gets one span
/// per drained batch (name `"<tenant> b<k>"`, track = tenant index,
/// category `"serve"`, dispatch → drain). Reports are byte-identical
/// with or without a sink.
pub fn run_serving_obs(
    sys: &SystemConfig,
    inst: &NocInstance,
    mix: &TenantMix,
    spec: &ServingSpec,
    cfg: &TraceConfig,
    plan: &FaultPlan,
    mut tel: Option<&mut Telemetry>,
) -> Result<ServingReport, WihetError> {
    spec.validate()?;
    let arrival = spec.arrival.as_ref().ok_or_else(|| {
        WihetError::InvalidArg(format!(
            "serving run needs an arrival clause (spec is none)\n{GRAMMAR}"
        ))
    })?;
    if mix.is_empty() {
        return Err(WihetError::InvalidArg(
            "serving needs at least one tenant model".into(),
        ));
    }
    let fx = if plan.has_noc_faults() {
        let nominal = SimConfig::default().nominal_flits;
        Some(plan.compile(&inst.topo, &inst.routes, &inst.air, nominal)?)
    } else {
        None
    };

    // Arrival streams and batch layout are pure functions of the spec —
    // computed before any simulator state exists.
    let policy = spec.policy();
    let mut tenant_arrivals = Vec::with_capacity(mix.len());
    let mut tenant_batches = Vec::with_capacity(mix.len());
    for ti in 0..mix.len() {
        let arr = arrival.arrivals(spec.requests as usize, tenant_salt(ti))?;
        tenant_batches.push(batches(&arr, &policy));
        tenant_arrivals.push(arr);
    }

    // One message group per (batch, forward phase), one RNG stream over
    // the canonical (tenant, batch, phase) order — deterministic for a
    // given seed, like `timeline_groups`. Lowering is cached per
    // realized batch size; the traffic draw is per group.
    let mut rng = Rng::new(cfg.seed);
    let mut groups: Vec<Vec<Message>> = Vec::new();
    let mut preds: Vec<Vec<u32>> = Vec::new();
    let mut batch_last_group: Vec<Vec<usize>> = Vec::with_capacity(mix.len());
    for (ti, t) in mix.tenants.iter().enumerate() {
        let mut lowered: HashMap<usize, TrafficModel> = HashMap::new();
        let mut last_ids = Vec::with_capacity(tenant_batches[ti].len());
        for b in &tenant_batches[ti] {
            if !lowered.contains_key(&b.count) {
                let tm = lower_id(&t.model, &MappingPolicy::default(), sys, b.count)?;
                lowered.insert(b.count, tm);
            }
            let tm = &lowered[&b.count];
            let mut prev: Option<usize> = None;
            for phase in tm.pass_phases(Pass::Forward) {
                let start = if prev.is_none() { b.dispatch } else { 0 };
                let (msgs, _dur) = phase_trace(sys, phase, start, cfg, &mut rng);
                let g = groups.len();
                groups.push(msgs);
                preds.push(prev.map(|p| vec![p as u32]).unwrap_or_default());
                prev = Some(g);
            }
            last_ids.push(prev.expect("a lowered model always has forward phases"));
        }
        batch_last_group.push(last_ids);
    }

    let mut sim = NocSim::new(sys, &inst.topo, &inst.routes, &inst.air, SimConfig::default());
    if let Some(f) = &fx {
        sim = sim.with_faults(f);
    }
    let out = sim.run_timeline_telemetry(&groups, &preds, tel.as_deref_mut());

    if let Some(sink) = tel {
        for (ti, t) in mix.tenants.iter().enumerate() {
            for (bi, b) in tenant_batches[ti].iter().enumerate() {
                let d = out.drain[batch_last_group[ti][bi]];
                if d == u64::MAX {
                    continue; // horizon-cut batch: no span
                }
                sink.span(format!("{} b{bi}", t.name), "serve", ti as u32, b.dispatch, d);
            }
        }
    }

    let mut tenants = Vec::with_capacity(mix.len());
    for (ti, t) in mix.tenants.iter().enumerate() {
        let mut st = TenantStats::new(t.name.clone());
        let arr = &tenant_arrivals[ti];
        st.offered = arr.len() as u64;
        for (bi, b) in tenant_batches[ti].iter().enumerate() {
            st.dispatched += b.count as u64;
            st.batches += 1;
            let d = out.drain[batch_last_group[ti][bi]];
            if d == u64::MAX {
                st.in_flight += b.count as u64;
                continue;
            }
            st.delivered += b.count as u64;
            for &a in &arr[b.first..b.first + b.count] {
                st.e2e.record(d.saturating_sub(a));
                st.queue.record(b.dispatch.saturating_sub(a));
                st.net.record(d.saturating_sub(b.dispatch));
            }
        }
        st.queued = st.offered - st.dispatched;
        tenants.push(st);
    }

    let makespan = out.report.cycles;
    Ok(ServingReport {
        sim: out.report,
        makespan,
        offered: tenants.iter().map(|t| t.offered).sum(),
        dispatched: tenants.iter().map(|t| t.dispatched).sum(),
        delivered: tenants.iter().map(|t| t.delivered).sum(),
        in_flight: tenants.iter().map(|t| t.in_flight).sum(),
        queued: tenants.iter().map(|t| t.queued).sum(),
        batches: tenants.iter().map(|t| t.batches).sum(),
        tenants,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::builder::mesh_opt;
    use crate::ModelId;

    fn setup() -> (SystemConfig, NocInstance, TenantMix, ServingSpec, TraceConfig) {
        let sys = SystemConfig::paper_8x8();
        let inst = mesh_opt(&sys, true);
        let mix = TenantMix::single(ModelId::LeNet);
        let spec: ServingSpec = "poisson:rate=0.2,seed=3;n=12".parse().unwrap();
        let cfg = TraceConfig { scale: 0.02, ..Default::default() };
        (sys, inst, mix, spec, cfg)
    }

    #[test]
    fn serving_run_delivers_and_conserves() {
        let (sys, inst, mix, spec, cfg) = setup();
        let r = run_serving(&sys, &inst, &mix, &spec, &cfg).unwrap();
        assert_eq!(r.offered, 12);
        assert_eq!(r.offered, r.delivered + r.queued + r.in_flight, "conservation");
        assert!(r.delivered > 0, "open-loop traffic must drain");
        assert_eq!(r.sim.undelivered(), 0);
        assert!(r.makespan > 0);
        assert!(r.batches > 0 && r.batches <= r.offered);
        let t = &r.tenants[0];
        assert_eq!(t.e2e.count(), t.delivered);
        assert_eq!(t.queue.count(), t.delivered);
        assert!(t.e2e.p99() >= t.e2e.p50());
        // e2e = queue + net, so the e2e tail dominates the network tail
        assert!(t.e2e.p99() >= t.net.p99());
        assert!(t.queue.max() <= spec.timeout, "queue wait is timeout-bounded");
        assert!(r.delivered_rate_pmc() > 0.0);
    }

    #[test]
    fn none_fault_plan_and_sink_are_byte_identical() {
        let (sys, inst, mix, spec, cfg) = setup();
        let plain = run_serving(&sys, &inst, &mix, &spec, &cfg).unwrap();
        let none =
            run_serving_faults(&sys, &inst, &mix, &spec, &cfg, &FaultPlan::none()).unwrap();
        let mut tel = Telemetry::new();
        let obs = run_serving_obs(
            &sys,
            &inst,
            &mix,
            &spec,
            &cfg,
            &FaultPlan::none(),
            Some(&mut tel),
        )
        .unwrap();
        for r in [&none, &obs] {
            assert_eq!(r.sim.latency.sum, plain.sim.latency.sum);
            assert_eq!(r.sim.link_busy, plain.sim.link_busy);
            assert_eq!(r.makespan, plain.makespan);
            assert_eq!(r.delivered, plain.delivered);
        }
        assert_eq!(plain.resilience(), &ResilienceStats::default());
        let serve_spans = tel.spans.iter().filter(|s| s.cat == "serve").count();
        assert_eq!(serve_spans as u64, plain.batches, "one span per drained batch");
        assert!(tel.spans.iter().all(|s| s.cat != "serve" || s.end >= s.start));
    }

    #[test]
    fn multi_tenant_mix_shares_the_chip() {
        let (sys, inst, _, spec, cfg) = setup();
        let mix = TenantMix::new(vec![ModelId::LeNet, ModelId::CdbNet]);
        let r = run_serving(&sys, &inst, &mix, &spec, &cfg).unwrap();
        assert_eq!(r.tenants.len(), 2);
        assert_eq!(r.offered, 24, "12 requests per tenant");
        assert_eq!(r.offered, r.delivered + r.queued + r.in_flight);
        // salted streams: the two tenants must not batch identically
        let a: Vec<u64> = r.tenants.iter().map(|t| t.e2e.count()).collect();
        assert!(a.iter().all(|&c| c > 0), "both tenants delivered: {a:?}");
    }

    #[test]
    fn a_none_spec_is_rejected_at_the_run_boundary() {
        let (sys, inst, mix, _, cfg) = setup();
        let err =
            run_serving(&sys, &inst, &mix, &ServingSpec::none(), &cfg).unwrap_err();
        let WihetError::InvalidArg(msg) = err else { panic!("wrong variant") };
        assert!(msg.contains("serve grammar"), "{msg}");
    }
}

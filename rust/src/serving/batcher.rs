//! Continuous batching: group an arrival stream into dispatched
//! batches.
//!
//! A batch opens when the oldest undispatched request arrives and
//! dispatches on whichever comes first:
//!
//! * **size** — the `batch`-th request arrives (dispatch at its arrival
//!   cycle), or
//! * **timeout** — `timeout` cycles pass since the batch opened
//!   (dispatch at `open + timeout` with however many requests made it).
//!
//! The timeout bounds per-request queueing delay at light load —
//! without it, a lone request would wait forever for batch-mates and
//! the unloaded p99 baseline that knee detection divides by would be
//! meaningless. Batching is a pure function of the arrival stream and
//! the policy: no simulator feedback, which is exactly what "open loop"
//! means.

/// Continuous-batching knobs (see [`super::GRAMMAR`]'s `<load>` clause).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BatchPolicy {
    /// Dispatch when this many requests are waiting.
    pub batch: u32,
    /// Dispatch this many cycles after the oldest waiting request
    /// arrived, even if the batch is not full.
    pub timeout: u64,
}

/// One dispatched batch: requests `first .. first + count` of the
/// arrival stream, dispatched at `dispatch`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Batch {
    /// Cycle the batch enters the network.
    pub dispatch: u64,
    /// Index of the batch's first request in the arrival stream.
    pub first: usize,
    /// Number of requests in the batch (1 ..= policy.batch).
    pub count: usize,
}

/// Group a monotone arrival stream into dispatched batches. Every
/// arrival lands in exactly one batch; dispatch cycles are monotone
/// non-decreasing; per-request queueing delay (`dispatch - arrival`) is
/// at most `policy.timeout`.
pub fn batches(arrivals: &[u64], policy: &BatchPolicy) -> Vec<Batch> {
    let cap = policy.batch.max(1) as usize;
    let mut out = Vec::new();
    let mut i = 0;
    while i < arrivals.len() {
        let open = arrivals[i];
        let deadline = open + policy.timeout;
        let mut j = i + 1;
        while j - i < cap && j < arrivals.len() && arrivals[j] <= deadline {
            j += 1;
        }
        let count = j - i;
        let dispatch = if count == cap { arrivals[j - 1] } else { deadline };
        out.push(Batch { dispatch, first: i, count });
        i = j;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: BatchPolicy = BatchPolicy { batch: 4, timeout: 100 };

    #[test]
    fn full_batch_dispatches_at_the_filling_arrival() {
        let b = batches(&[10, 20, 30, 40, 500], &P);
        assert_eq!(b[0], Batch { dispatch: 40, first: 0, count: 4 });
        assert_eq!(b[1], Batch { dispatch: 600, first: 4, count: 1 });
    }

    #[test]
    fn timeout_dispatches_a_partial_batch() {
        let b = batches(&[10, 20, 300, 310], &P);
        // 10 and 20 time out at 110; 300/310 open a fresh batch
        assert_eq!(b[0], Batch { dispatch: 110, first: 0, count: 2 });
        assert_eq!(b[1], Batch { dispatch: 400, first: 2, count: 2 });
    }

    #[test]
    fn every_arrival_lands_in_exactly_one_batch() {
        let arrivals: Vec<u64> = (0..37).map(|i| i * 13).collect();
        let b = batches(&arrivals, &P);
        let covered: usize = b.iter().map(|x| x.count).sum();
        assert_eq!(covered, arrivals.len());
        for w in b.windows(2) {
            assert_eq!(w[0].first + w[0].count, w[1].first, "batches are contiguous");
            assert!(w[0].dispatch <= w[1].dispatch, "dispatch is monotone");
        }
    }

    #[test]
    fn queue_wait_is_bounded_by_the_timeout() {
        let arrivals: Vec<u64> = (0..50).map(|i| i * i).collect();
        for b in batches(&arrivals, &P) {
            for &a in &arrivals[b.first..b.first + b.count] {
                assert!(b.dispatch >= a, "dispatch before arrival");
                assert!(b.dispatch - a <= P.timeout, "wait {} > timeout", b.dispatch - a);
            }
        }
    }

    #[test]
    fn batch_of_one_dispatches_immediately() {
        let p = BatchPolicy { batch: 1, timeout: 100 };
        for b in batches(&[5, 6, 7], &p) {
            assert_eq!(b.count, 1);
        }
        assert_eq!(batches(&[5, 6, 7], &p)[0].dispatch, 5);
        assert!(batches(&[], &p).is_empty());
    }
}

//! Request arrival processes: deterministic seeded cycle stamps.
//!
//! An [`ArrivalProcess`] turns a spec clause into a monotone stream of
//! absolute arrival cycles. Streams derive only from (process fields,
//! tenant salt) — never from thread or workspace state — so serving
//! runs are byte-identical across `WIHETNOC_THREADS` settings, the same
//! guarantee [`crate::faults::FaultPlan::compile`] gives fault
//! injection.

use std::fmt;
use std::str::FromStr;

use crate::error::WihetError;
use crate::util::rng::Rng;

use super::{parse_num, GRAMMAR};

/// Default burst multiplier (`x`): the on-window arrival rate is
/// `rate * x`.
pub const DEFAULT_BURST_X: u32 = 4;

/// Stream-domain separators so a Poisson and a burst process with the
/// same seed/salt still draw from unrelated streams.
const POISSON_STREAM: u64 = 0x5049_534e_0000_0001;
const BURST_STREAM: u64 = 0x4255_5253_0000_0001;

/// A request arrival process (see [`GRAMMAR`]). Rates are stored as
/// integer requests-per-megacycle (`rate_pmc`) so the process is
/// `Hash + Eq` and can ride inside [`crate::ScenarioKey`]; the grammar's
/// `rate=<r>` is in requests per kilocycle, so `rate_pmc = r * 1000`.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ArrivalProcess {
    /// Memoryless arrivals: exponential inter-arrival gaps with mean
    /// `1e6 / rate_pmc` cycles.
    Poisson { rate_pmc: u64, seed: u64 },
    /// On/off-modulated Poisson: inside each `on`-cycle window of the
    /// `on + off` period the rate is boosted to `rate * x`; outside it
    /// runs at the base rate.
    Burst { rate_pmc: u64, on: u64, off: u64, x: u32 },
    /// Trace-driven: one absolute arrival cycle per line (blank lines
    /// and `#` comments skipped), sorted, truncated to the requested
    /// count. A shorter file simply offers fewer requests.
    Trace { file: String },
}

impl ArrivalProcess {
    /// Semantic checks beyond the grammar.
    pub fn validate(&self) -> Result<(), WihetError> {
        match self {
            ArrivalProcess::Poisson { rate_pmc, .. } => check_rate(*rate_pmc),
            ArrivalProcess::Burst { rate_pmc, on, x, .. } => {
                check_rate(*rate_pmc)?;
                if *on == 0 {
                    return Err(WihetError::InvalidArg(format!(
                        "burst: on-window must be >= 1 cycle\n{GRAMMAR}"
                    )));
                }
                if *x == 0 {
                    return Err(WihetError::InvalidArg(format!(
                        "burst: x multiplier must be >= 1\n{GRAMMAR}"
                    )));
                }
                check_rate((*rate_pmc).saturating_mul(*x as u64))
            }
            ArrivalProcess::Trace { file } => {
                if file.is_empty() {
                    return Err(WihetError::InvalidArg(format!(
                        "trace: clause needs file=<path>\n{GRAMMAR}"
                    )));
                }
                Ok(())
            }
        }
    }

    /// Generate the first `n` arrival cycles of this process, salted per
    /// tenant so tenants sharing one spec still see independent streams.
    /// Stochastic processes always succeed; `trace:` reads its file
    /// here (a shorter file offers fewer than `n` requests).
    pub fn arrivals(&self, n: usize, salt: u64) -> Result<Vec<u64>, WihetError> {
        match self {
            ArrivalProcess::Poisson { rate_pmc, seed } => {
                let mean_gap = 1e6 / *rate_pmc as f64;
                let mut rng = Rng::new(seed ^ salt ^ POISSON_STREAM);
                let mut t = 0f64;
                let mut out = Vec::with_capacity(n);
                for _ in 0..n {
                    t += exp_gap(&mut rng, mean_gap);
                    out.push(t as u64);
                }
                Ok(out)
            }
            ArrivalProcess::Burst { rate_pmc, on, off, x } => {
                let base_gap = 1e6 / *rate_pmc as f64;
                let period = on + off;
                let mut rng = Rng::new(salt ^ BURST_STREAM);
                let mut t = 0f64;
                let mut out = Vec::with_capacity(n);
                for _ in 0..n {
                    // draw the gap at the rate of the window the stream
                    // is currently in
                    let mean = if (t as u64) % period < *on {
                        base_gap / *x as f64
                    } else {
                        base_gap
                    };
                    t += exp_gap(&mut rng, mean);
                    out.push(t as u64);
                }
                Ok(out)
            }
            ArrivalProcess::Trace { file } => {
                let text = std::fs::read_to_string(file).map_err(|e| {
                    WihetError::InvalidArg(format!("trace:file={file}: {e}\n{GRAMMAR}"))
                })?;
                let mut out = Vec::new();
                for (ln, line) in text.lines().enumerate() {
                    let line = line.trim();
                    if line.is_empty() || line.starts_with('#') {
                        continue;
                    }
                    let cycle: u64 = line.parse().map_err(|_| {
                        WihetError::InvalidArg(format!(
                            "trace:file={file} line {}: '{line}' is not a cycle\n{GRAMMAR}",
                            ln + 1
                        ))
                    })?;
                    out.push(cycle);
                }
                out.sort_unstable();
                out.truncate(n);
                Ok(out)
            }
        }
    }
}

fn check_rate(rate_pmc: u64) -> Result<(), WihetError> {
    if rate_pmc == 0 {
        return Err(WihetError::InvalidArg(format!(
            "rate must be > 0 requests per kilocycle\n{GRAMMAR}"
        )));
    }
    // mean gap below one cycle cannot be represented on a cycle clock
    if rate_pmc > 1_000_000 {
        return Err(WihetError::InvalidArg(format!(
            "rate {} req/kcycle exceeds one request per cycle\n{GRAMMAR}",
            rate_pmc as f64 / 1000.0
        )));
    }
    Ok(())
}

/// One exponential inter-arrival gap with the given mean, in cycles.
/// `u` is in [0, 1), so `1 - u` is in (0, 1] and the gap is finite and
/// non-negative.
fn exp_gap(rng: &mut Rng, mean: f64) -> f64 {
    -(1.0 - rng.f64()).max(f64::MIN_POSITIVE).ln() * mean
}

impl fmt::Display for ArrivalProcess {
    /// Canonical form (defaults omitted); round-trips through
    /// [`ArrivalProcess::from_str`].
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArrivalProcess::Poisson { rate_pmc, seed } => {
                let mut s = format!("poisson:rate={}", *rate_pmc as f64 / 1000.0);
                if *seed != 0 {
                    s.push_str(&format!(",seed={seed}"));
                }
                f.pad(&s)
            }
            ArrivalProcess::Burst { rate_pmc, on, off, x } => {
                let mut s = format!(
                    "burst:rate={},on={on},off={off}",
                    *rate_pmc as f64 / 1000.0
                );
                if *x != DEFAULT_BURST_X {
                    s.push_str(&format!(",x={x}"));
                }
                f.pad(&s)
            }
            ArrivalProcess::Trace { file } => f.pad(&format!("trace:file={file}")),
        }
    }
}

fn parse_rate(v: &str) -> Result<u64, WihetError> {
    let r: f64 = parse_num("rate", v)?;
    if !r.is_finite() || r <= 0.0 {
        return Err(WihetError::InvalidArg(format!(
            "rate must be > 0 requests per kilocycle, got {v}\n{GRAMMAR}"
        )));
    }
    Ok(((r * 1000.0).round() as u64).max(1))
}

impl FromStr for ArrivalProcess {
    type Err = WihetError;

    fn from_str(s: &str) -> Result<Self, WihetError> {
        let clause = s.trim();
        let (head, rest) = clause.split_once(':').ok_or_else(|| {
            WihetError::InvalidArg(format!(
                "arrival clause '{clause}' needs a poisson:/burst:/trace: head\n{GRAMMAR}"
            ))
        })?;
        let mut kv = Vec::new();
        for item in rest.split(',') {
            let (k, v) = item.split_once('=').ok_or_else(|| {
                WihetError::InvalidArg(format!(
                    "expected key=value in arrival clause, got '{item}'\n{GRAMMAR}"
                ))
            })?;
            kv.push((k.trim(), v.trim()));
        }
        let get = |key: &str| kv.iter().find(|(k, _)| *k == key).map(|(_, v)| *v);
        let known = |allowed: &[&str]| -> Result<(), WihetError> {
            for (k, _) in &kv {
                if !allowed.contains(k) {
                    return Err(WihetError::InvalidArg(format!(
                        "unknown key '{k}' in {head}: arrival clause\n{GRAMMAR}"
                    )));
                }
            }
            Ok(())
        };
        let need = |key: &str| {
            get(key).ok_or_else(|| {
                WihetError::InvalidArg(format!("{head}: clause needs {key}=...\n{GRAMMAR}"))
            })
        };
        let p = match head.trim() {
            "poisson" => {
                known(&["rate", "seed"])?;
                ArrivalProcess::Poisson {
                    rate_pmc: parse_rate(need("rate")?)?,
                    seed: get("seed").map(|v| parse_num("seed", v)).transpose()?.unwrap_or(0),
                }
            }
            "burst" => {
                known(&["rate", "on", "off", "x"])?;
                ArrivalProcess::Burst {
                    rate_pmc: parse_rate(need("rate")?)?,
                    on: parse_num("on", need("on")?)?,
                    off: parse_num("off", need("off")?)?,
                    x: get("x")
                        .map(|v| parse_num("x", v))
                        .transpose()?
                        .unwrap_or(DEFAULT_BURST_X),
                }
            }
            "trace" => {
                known(&["file"])?;
                ArrivalProcess::Trace { file: need("file")?.to_string() }
            }
            other => {
                return Err(WihetError::InvalidArg(format!(
                    "unknown arrival process '{other}' (poisson|burst|trace)\n{GRAMMAR}"
                )));
            }
        };
        p.validate()?;
        Ok(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_streams_are_deterministic_and_salted() {
        let p: ArrivalProcess = "poisson:rate=0.5,seed=7".parse().unwrap();
        let a = p.arrivals(64, 1).unwrap();
        let b = p.arrivals(64, 1).unwrap();
        assert_eq!(a, b, "same (seed, salt) must replay the same stream");
        let c = p.arrivals(64, 2).unwrap();
        assert_ne!(a, c, "a different tenant salt must decorrelate the stream");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "arrivals are monotone");
    }

    #[test]
    fn poisson_mean_gap_tracks_the_rate() {
        // rate=0.5 req/kcycle -> mean gap 2000 cycles; 512 samples keep
        // the sample mean well within a factor of 2
        let p = ArrivalProcess::Poisson { rate_pmc: 500, seed: 3 };
        let a = p.arrivals(512, 0).unwrap();
        let mean = *a.last().unwrap() as f64 / a.len() as f64;
        assert!((1000.0..4000.0).contains(&mean), "mean gap {mean}");
    }

    #[test]
    fn burst_on_window_is_denser() {
        let p = ArrivalProcess::Burst { rate_pmc: 100, on: 10_000, off: 30_000, x: 8 };
        p.validate().unwrap();
        let a = p.arrivals(400, 5).unwrap();
        let period = 40_000u64;
        let on = a.iter().filter(|&&t| t % period < 10_000).count();
        let off = a.len() - on;
        // on-window holds 25% of the time but is 8x denser; with 400
        // samples it must clearly dominate
        assert!(on > off, "on-window {on} vs off-window {off} arrivals");
    }

    #[test]
    fn trace_reads_sorts_and_truncates() {
        let path = std::env::temp_dir().join("wihetnoc_serving_arrival_trace.txt");
        std::fs::write(&path, "# header\n300\n100\n\n200\n400\n").unwrap();
        let p = ArrivalProcess::Trace { file: path.to_string_lossy().into_owned() };
        assert_eq!(p.arrivals(3, 9).unwrap(), vec![100, 200, 300]);
        assert_eq!(p.arrivals(10, 9).unwrap(), vec![100, 200, 300, 400]);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn trace_errors_name_the_file() {
        let p = ArrivalProcess::Trace { file: "/nonexistent/arrivals.txt".into() };
        let WihetError::InvalidArg(msg) = p.arrivals(4, 0).unwrap_err() else {
            panic!("wrong variant");
        };
        assert!(msg.contains("/nonexistent/arrivals.txt"), "{msg}");
        assert!(msg.contains("serve grammar"), "{msg}");
    }

    #[test]
    fn rates_outside_the_cycle_clock_are_rejected() {
        assert!("poisson:rate=1001".parse::<ArrivalProcess>().is_err());
        assert!("poisson:rate=1000".parse::<ArrivalProcess>().is_ok());
        // burst boost must also stay under one request per cycle
        assert!("burst:rate=500,on=8,off=8,x=4".parse::<ArrivalProcess>().is_err());
    }
}
